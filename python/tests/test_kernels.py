"""Kernel-vs-oracle correctness: the CORE build-time signal.

Hypothesis sweeps fingerprints, filter sizes, probe counts, level/read/age
vectors; every property asserts the Pallas kernel (interpret mode) matches
the pure-jnp reference bit-for-bit (int outputs) or to fp tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bloom import bloom_probe
from compile.kernels.priority import priority_scores
from compile.kernels.ref import (
    K_MAX,
    bloom_probe_ref,
    migration_plan_ref,
    priority_scores_ref,
)
from compile.model import migration_plan_fn

u32 = st.integers(min_value=0, max_value=2**32 - 1)


def build_filter(fps, nbits, k):
    """Host-side filter construction mirroring rust/src/lsm/bloom.rs."""
    nwords = (nbits + 31) // 32
    nbits = nwords * 32
    words = np.zeros(nwords, dtype=np.uint32)
    for fp in np.asarray(fps, dtype=np.uint32):
        h1 = np.uint32(fp) * np.uint32(0x9E3779B1)
        h2 = (np.uint32(fp) * np.uint32(0x85EBCA77)) | np.uint32(1)
        for j in range(k):
            pos = int((h1 + np.uint32(j) * h2) % np.uint32(nbits))
            words[pos // 32] |= np.uint32(1) << np.uint32(pos % 32)
    return words, np.uint32(nbits)


# ---------------------------------------------------------------------------
# Bloom kernel
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    fps=st.lists(u32, min_size=1, max_size=64),
    probes=st.lists(u32, min_size=1, max_size=64),
    bits_per_key=st.integers(min_value=4, max_value=16),
    k=st.integers(min_value=1, max_value=K_MAX),
)
def test_bloom_kernel_matches_ref(fps, probes, bits_per_key, k):
    words, nbits = build_filter(fps, max(64, len(fps) * bits_per_key), k)
    q = jnp.asarray(np.asarray(probes, dtype=np.uint32))
    w = jnp.asarray(words)
    got = np.asarray(bloom_probe(q, w, nbits, np.uint32(k)))
    want = np.asarray(bloom_probe_ref(q, w, nbits, np.uint32(k)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    fps=st.lists(u32, min_size=1, max_size=128, unique=True),
    k=st.integers(min_value=1, max_value=8),
)
def test_bloom_no_false_negatives(fps, k):
    words, nbits = build_filter(fps, max(64, len(fps) * 10), k)
    q = jnp.asarray(np.asarray(fps, dtype=np.uint32))
    got = np.asarray(bloom_probe(q, jnp.asarray(words), nbits, np.uint32(k)))
    assert got.all(), "a built key must always probe positive"


def test_bloom_false_positive_rate_sane():
    rng = np.random.default_rng(7)
    members = rng.integers(0, 2**32, size=2000, dtype=np.uint32)
    words, nbits = build_filter(members, 2000 * 10, 6)
    others = rng.integers(0, 2**32, size=4000, dtype=np.uint32)
    others = np.setdiff1d(others, members)[:2048]
    hits = 0
    for i in range(0, len(others), 128):
        batch = others[i : i + 128]
        got = np.asarray(
            bloom_probe(jnp.asarray(batch), jnp.asarray(words), nbits, np.uint32(6))
        )
        hits += int(got.sum())
    rate = hits / len(others)
    assert rate < 0.05, f"false positive rate {rate}"


def test_bloom_empty_filter_rejects():
    words = np.zeros(8, dtype=np.uint32)
    q = jnp.asarray(np.arange(16, dtype=np.uint32))
    got = np.asarray(bloom_probe(q, jnp.asarray(words), np.uint32(256), np.uint32(6)))
    assert not got.any()


@pytest.mark.parametrize("batch", [1, 8, 128, 256])
@pytest.mark.parametrize("nwords", [2, 64, 8192])
def test_bloom_shapes(batch, nwords):
    fps = (np.arange(batch, dtype=np.uint64) * 2654435761 % (1 << 32)).astype(np.uint32)
    q = jnp.asarray(fps)
    words = jnp.asarray(np.full(nwords, 0xFFFFFFFF, dtype=np.uint32))
    got = np.asarray(bloom_probe(q, words, np.uint32(nwords * 32), np.uint32(6)))
    assert got.shape == (batch,)
    assert got.all(), "all-ones filter accepts everything"


# ---------------------------------------------------------------------------
# Priority kernel
# ---------------------------------------------------------------------------

levels_st = st.lists(st.integers(min_value=0, max_value=6), min_size=1, max_size=256)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_priority_kernel_matches_ref(data):
    levels = data.draw(levels_st)
    n = len(levels)
    reads = data.draw(
        st.lists(
            st.floats(min_value=0, max_value=1e7, allow_nan=False),
            min_size=n,
            max_size=n,
        )
    )
    ages = data.draw(
        st.lists(
            st.floats(min_value=1e-3, max_value=1e5, allow_nan=False),
            min_size=n,
            max_size=n,
        )
    )
    l = jnp.asarray(np.asarray(levels, np.int32))
    r = jnp.asarray(np.asarray(reads, np.float32))
    a = jnp.asarray(np.asarray(ages, np.float32))
    got = np.asarray(priority_scores(l, r, a))
    want = np.asarray(priority_scores_ref(l, r, a))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_priority_ordering_level_dominates():
    l = jnp.asarray(np.array([2, 3], np.int32))
    r = jnp.asarray(np.array([0.0, 1e9], np.float32))
    a = jnp.asarray(np.array([1.0, 1.0], np.float32))
    s = np.asarray(priority_scores(l, r, a))
    assert s[0] > s[1], "lower level must outrank any read rate"


def test_priority_ordering_rate_breaks_ties():
    l = jnp.asarray(np.array([3, 3], np.int32))
    r = jnp.asarray(np.array([10.0, 1000.0], np.float32))
    a = jnp.asarray(np.array([1.0, 1.0], np.float32))
    s = np.asarray(priority_scores(l, r, a))
    assert s[1] > s[0]


def test_priority_zero_age_guarded():
    l = jnp.asarray(np.array([1], np.int32))
    r = jnp.asarray(np.array([100.0], np.float32))
    a = jnp.asarray(np.array([0.0], np.float32))
    s = np.asarray(priority_scores(l, r, a))
    assert np.isfinite(s).all()


# ---------------------------------------------------------------------------
# L2 migration plan
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_migration_plan_matches_ref(data):
    n = data.draw(st.integers(min_value=1, max_value=64))
    levels = np.asarray(
        data.draw(st.lists(st.integers(0, 6), min_size=n, max_size=n)), np.int32
    )
    reads = np.asarray(
        data.draw(
            st.lists(st.floats(0, 1e6, allow_nan=False), min_size=n, max_size=n)
        ),
        np.float32,
    )
    ages = np.ones(n, np.float32)
    on_ssd = np.asarray(
        data.draw(st.lists(st.integers(0, 1), min_size=n, max_size=n)), np.int32
    )
    valid = np.asarray(
        data.draw(st.lists(st.integers(0, 1), min_size=n, max_size=n)), np.int32
    )
    got = migration_plan_fn(
        jnp.asarray(levels), jnp.asarray(reads), jnp.asarray(ages),
        jnp.asarray(on_ssd), jnp.asarray(valid),
    )
    want = migration_plan_ref(levels, reads, ages, on_ssd, valid)
    gs, ws = np.asarray(got[0]), np.asarray(want[0])
    np.testing.assert_allclose(gs, ws, rtol=1e-12)
    # Argmax/argmin may legitimately differ between equal-score entries;
    # require the *scores* at the chosen indices to agree, plus set
    # membership, which pins the semantics without over-constraining ties.
    for got_i, want_i, mask_val in ((int(got[1]), int(want[1]), 0), (int(got[2]), int(want[2]), 1)):
        assert (got_i == -1) == (want_i == -1)
        if got_i != -1:
            assert gs[got_i] == ws[want_i]
            assert valid[got_i] == 1 and on_ssd[got_i] == mask_val


def test_migration_plan_semantics():
    # SST 2 (L1, HDD, hot) must be the HDD candidate; SST 0 (L3, SSD, cold)
    # the SSD victim.
    levels = jnp.asarray(np.array([3, 2, 1, 0], np.int32))
    reads = jnp.asarray(np.array([0.0, 10.0, 500.0, 1.0], np.float32))
    ages = jnp.asarray(np.ones(4, np.float32))
    on_ssd = jnp.asarray(np.array([1, 1, 0, 0], np.int32))
    valid = jnp.asarray(np.ones(4, np.int32))
    _, hdd_best, ssd_worst = migration_plan_fn(levels, reads, ages, on_ssd, valid)
    assert int(hdd_best) == 3  # L0 beats L1 regardless of rate
    assert int(ssd_worst) == 0

    # Empty sets yield -1.
    none_valid = jnp.asarray(np.zeros(4, np.int32))
    _, hb, sw = migration_plan_fn(levels, reads, ages, on_ssd, none_valid)
    assert int(hb) == -1 and int(sw) == -1
