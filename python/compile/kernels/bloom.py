"""Layer-1 Pallas kernel: batched Bloom-filter probing.

The compute hot-spot of the LSM read path: for a batch of key
fingerprints, evaluate k double-hash probes against one SST's filter.
Tiled for VMEM: one fingerprint block and the (padded) filter words are
the kernel's resident working set; hashing is element-wise VPU work (no
MXU), with the K_MAX probe lanes vectorized along the minor dimension.

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU behaviour is estimated in DESIGN.md
(§Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import H1_MUL, H2_MUL, K_MAX


def _bloom_probe_kernel(fps_ref, words_ref, nbits_ref, k_ref, out_ref):
    fps = fps_ref[...]  # [B] uint32
    words = words_ref[...]  # [W] uint32
    nbits = jnp.maximum(nbits_ref[0], jnp.uint32(1))
    k = k_ref[0]
    h1 = fps * H1_MUL
    h2 = (fps * H2_MUL) | jnp.uint32(1)
    j = jnp.arange(K_MAX, dtype=jnp.uint32)[None, :]  # [1, K_MAX]
    pos = (h1[:, None] + j * h2[:, None]) % nbits  # [B, K_MAX]
    word = jnp.take(words, (pos // 32).astype(jnp.int32), axis=0)
    bit = (word >> (pos % 32)) & jnp.uint32(1)
    probe_ok = (bit == 1) | (j >= k)
    out_ref[...] = jnp.all(probe_ok, axis=1).astype(jnp.int32)


def bloom_probe(fps, words, nbits, k):
    """Batched Bloom probe via the Pallas kernel.

    Args:
      fps:   uint32[B] fingerprints.
      words: uint32[W] filter words.
      nbits: uint32 scalar (live bits).
      k:     uint32 scalar (probes, <= K_MAX).

    Returns: int32[B] membership flags.
    """
    b = fps.shape[0]
    return pl.pallas_call(
        _bloom_probe_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(
        fps.astype(jnp.uint32),
        words.astype(jnp.uint32),
        jnp.asarray(nbits, jnp.uint32).reshape((1,)),
        jnp.asarray(k, jnp.uint32).reshape((1,)),
    )
