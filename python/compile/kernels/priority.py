"""Layer-1 Pallas kernel: fused SST priority scoring (§3.4).

The migration scanner's hot-spot: for every live SST, compute
``score = -level * 1e12 + reads / age`` in one fused element-wise pass.
Lower level always outranks higher level; within a level the read rate
breaks ties. The score is computed and returned in **f64**: at f32, the
ulp near 6e12 is ~5e5, which would erase read-rate tie-breaks — f64 keeps
sub-milli-IOPS resolution across all level bands (and matches the Rust
`priority_score`, which is f64).

Tiling: the three input vectors and the output share one VMEM block; pure
VPU arithmetic. ``interpret=True`` as required for CPU PJRT execution.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _priority_kernel(levels_ref, reads_ref, ages_ref, out_ref):
    levels = levels_ref[...].astype(jnp.float64)
    reads = reads_ref[...].astype(jnp.float64)
    ages = jnp.maximum(ages_ref[...].astype(jnp.float64), 1e-9)
    out_ref[...] = -levels * 1e12 + reads / ages


def priority_scores(levels, reads, ages):
    """Fused priority scores via the Pallas kernel.

    Args:
      levels: int32[N]; reads: float32[N]; ages: float32[N] (seconds).

    Returns: float64[N] scores (higher = migrate-to-SSD first).
    """
    n = levels.shape[0]
    return pl.pallas_call(
        _priority_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float64),
        interpret=True,
    )(
        levels.astype(jnp.int32),
        reads.astype(jnp.float32),
        ages.astype(jnp.float32),
    )
