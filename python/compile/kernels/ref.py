"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest asserts kernel == ref across shape/dtype sweeps).

The Bloom hash scheme is shared bit-for-bit with the Rust implementation in
``rust/src/lsm/bloom.rs``:

    h1 = fp * 0x9E3779B1            (u32 wrap-around)
    h2 = fp * 0x85EBCA77 | 1
    pos_j = (h1 + j * h2) mod nbits     for j in 0..k

The priority score matches ``rust/src/policy::priority_score``:

    score = -level * 1e12 + reads / age
"""

import jax.numpy as jnp
import numpy as np

H1_MUL = np.uint32(0x9E3779B1)
H2_MUL = np.uint32(0x85EBCA77)

K_MAX = 16  # compile-time probe bound; runtime k <= K_MAX


def bloom_probe_ref(fps, words, nbits, k):
    """Reference batched Bloom probe.

    Args:
      fps:   uint32[B] key fingerprints (padding entries allowed).
      words: uint32[W] filter words (bit i lives at words[i//32] >> (i%32)).
      nbits: scalar uint32, number of live bits (<= W*32).
      k:     scalar uint32, number of probes (<= K_MAX).

    Returns: int32[B], 1 where the filter may contain the fingerprint.
    """
    fps = jnp.asarray(fps, jnp.uint32)
    words = jnp.asarray(words, jnp.uint32)
    nbits = jnp.asarray(nbits, jnp.uint32)
    k = jnp.asarray(k, jnp.uint32)
    h1 = fps * H1_MUL
    h2 = (fps * H2_MUL) | jnp.uint32(1)
    j = jnp.arange(K_MAX, dtype=jnp.uint32)[None, :]  # [1, K_MAX]
    pos = (h1[:, None] + j * h2[:, None]) % jnp.maximum(nbits, jnp.uint32(1))
    word = jnp.take(words, (pos // 32).astype(jnp.int32), axis=0)
    bit = (word >> (pos % 32)) & jnp.uint32(1)
    probe_ok = (bit == 1) | (j >= k)  # probes beyond k are vacuously true
    return jnp.all(probe_ok, axis=1).astype(jnp.int32)


def priority_scores_ref(levels, reads, ages):
    """Reference SST priority scores (§3.4).

    Args:
      levels: int32[N] LSM level of each SST.
      reads:  float32[N] total reads.
      ages:   float32[N] age in seconds (>= tiny epsilon).

    Returns: float64[N] scores; higher = higher migration priority.
    """
    levels = jnp.asarray(levels, jnp.int32).astype(jnp.float64)
    reads = jnp.asarray(reads, jnp.float32).astype(jnp.float64)
    ages = jnp.asarray(ages, jnp.float32).astype(jnp.float64)
    rate = reads / jnp.maximum(ages, 1e-9)
    return -levels * 1e12 + rate


def migration_plan_ref(levels, reads, ages, on_ssd, valid):
    """Reference L2 migration plan: scores + masked arg-extrema.

    Returns (scores f32[N], hdd_best i32, ssd_worst i32); the index values
    are -1 when the respective set is empty.
    """
    scores = priority_scores_ref(levels, reads, ages)
    valid = jnp.asarray(valid, jnp.int32) != 0
    on_ssd = jnp.asarray(on_ssd, jnp.int32) != 0
    neg = jnp.float64(-jnp.inf)
    pos = jnp.float64(jnp.inf)
    hdd_mask = valid & ~on_ssd
    ssd_mask = valid & on_ssd
    hdd_scores = jnp.where(hdd_mask, scores, neg)
    ssd_scores = jnp.where(ssd_mask, scores, pos)
    hdd_best = jnp.where(jnp.any(hdd_mask), jnp.argmax(hdd_scores), -1)
    ssd_worst = jnp.where(jnp.any(ssd_mask), jnp.argmin(ssd_scores), -1)
    return scores, hdd_best.astype(jnp.int32), ssd_worst.astype(jnp.int32)
