# Enable f64 throughout the compile path: the priority score encodes
# (level, read-rate) in one scalar and needs f64 resolution (f32 ulp at
# 6e12 is ~5e5, which would erase read-rate tie-breaks within a level).
import jax

jax.config.update("jax_enable_x64", True)
