"""Layer-2 JAX functions: the AOT entry points the Rust coordinator
executes through PJRT. Each wraps the Layer-1 Pallas kernels and fixes the
shapes the Rust runtime pads to (rust/src/runtime/mod.rs must agree).

Entry points
------------
* ``bloom_probe_fn``   — [BLOOM_BATCH] fingerprints × one padded filter.
* ``priority_fn``      — [PRIORITY_N] SST descriptors → scores.
* ``migration_plan_fn``— scores + masked arg-extrema: the full §3.4
  migration decision (best HDD candidate, worst SSD resident) in one call.
"""

import jax.numpy as jnp

from .kernels.bloom import bloom_probe
from .kernels.priority import priority_scores

# Fixed AOT shapes — keep in sync with rust/src/runtime/mod.rs.
BLOOM_BATCH = 128
BLOOM_WORDS = 8192
PRIORITY_N = 1024


def bloom_probe_fn(fps, words, nbits, k):
    """uint32[BLOOM_BATCH], uint32[BLOOM_WORDS], u32, u32 -> (i32[BLOOM_BATCH],)"""
    return (bloom_probe(fps, words, nbits, k),)


def priority_fn(levels, reads, ages):
    """i32[PRIORITY_N], f32[PRIORITY_N], f32[PRIORITY_N] -> (f64[PRIORITY_N],)"""
    return (priority_scores(levels, reads, ages),)


def migration_plan_fn(levels, reads, ages, on_ssd, valid):
    """Full migration decision (§3.4) on top of the L1 score kernel.

    Args (all [PRIORITY_N]):
      levels i32, reads f32, ages f32, on_ssd i32 (1 = SSD), valid i32.

    Returns (scores f32[N], hdd_best i32, ssd_worst i32); indices are -1
    when the set is empty.
    """
    scores = priority_scores(levels, reads, ages)
    validb = valid != 0
    ssdb = on_ssd != 0
    hdd_mask = validb & ~ssdb
    ssd_mask = validb & ssdb
    hdd_scores = jnp.where(hdd_mask, scores, jnp.float64(-jnp.inf))
    ssd_scores = jnp.where(ssd_mask, scores, jnp.float64(jnp.inf))
    hdd_best = jnp.where(jnp.any(hdd_mask), jnp.argmax(hdd_scores), -1)
    ssd_worst = jnp.where(jnp.any(ssd_mask), jnp.argmin(ssd_scores), -1)
    return scores, hdd_best.astype(jnp.int32), ssd_worst.astype(jnp.int32)
