"""AOT lowering: JAX (Layer 2) → HLO **text** → ``artifacts/*.hlo.txt``.

HLO text — NOT ``lowered.compile().serialize()`` — is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids which
the pinned xla_extension 0.5.1 (behind the Rust ``xla`` crate) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Run once via ``make artifacts``; the Rust binary is self-contained after.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    BLOOM_BATCH,
    BLOOM_WORDS,
    PRIORITY_N,
    bloom_probe_fn,
    migration_plan_fn,
    priority_fn,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_all():
    u32, i32, f32 = jnp.uint32, jnp.int32, jnp.float32
    n = (PRIORITY_N,)
    return {
        "bloom_probe": jax.jit(bloom_probe_fn).lower(
            spec((BLOOM_BATCH,), u32),
            spec((BLOOM_WORDS,), u32),
            spec((), u32),
            spec((), u32),
        ),
        "priority": jax.jit(priority_fn).lower(
            spec(n, i32), spec(n, f32), spec(n, f32)
        ),
        # The composed L2 "model": scores + the §3.4 decision extrema.
        "model": jax.jit(migration_plan_fn).lower(
            spec(n, i32), spec(n, f32), spec(n, f32), spec(n, i32), spec(n, i32)
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, lowered in lower_all().items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars  {path}")


if __name__ == "__main__":
    main()
