//! Three-layer integration: the AOT Pallas/JAX kernels executing inside
//! the Rust coordinator's request path. The whole target is compiled only
//! with the `xla` cargo feature (the default build uses the native
//! fallbacks), and skipped (cleanly) when `artifacts/` has not been built
//! yet.

#![cfg(feature = "xla")]

use std::rc::Rc;

use hhzs::config::Config;
use hhzs::coordinator::Engine;
use hhzs::policy::HhzsPolicy;
use hhzs::runtime::XlaKernels;
use hhzs::wire::Payload;
use hhzs::ycsb::{key_for, value_for};

fn kernels() -> Option<Rc<XlaKernels>> {
    if !XlaKernels::artifacts_present("artifacts") {
        eprintln!("skipping XLA e2e: run `make artifacts` first");
        return None;
    }
    Some(Rc::new(XlaKernels::load("artifacts").expect("load artifacts")))
}

fn loaded_engine(k: Rc<XlaKernels>) -> Engine {
    let mut cfg = Config::tiny();
    cfg.workload.load_objects = 20_000;
    let policy = HhzsPolicy::new(cfg.lsm.num_levels).with_scorer(k.clone());
    let mut e = Engine::new(cfg, Box::new(policy));
    e.attach_xla(k);
    for i in 0..20_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    e
}

#[test]
fn multi_get_parity_with_native_gets() {
    let Some(k) = kernels() else { return };
    let mut e = loaded_engine(k.clone());
    let keys: Vec<Vec<u8>> = (0..300u64)
        .map(|i| {
            if i % 7 == 0 {
                // Some keys that were never written.
                format!("user-missing-{i:08}").into_bytes()
            } else {
                key_for(i * 61 % 20_000, 24)
            }
        })
        .collect();
    let batched = e.multi_get(&keys);
    assert!(k.bloom_calls.get() > 0, "XLA bloom kernel must be dispatched");
    e.xla = None; // native path
    let native: Vec<Option<Payload>> = keys.iter().map(|key| e.get(key)).collect();
    assert_eq!(batched, native, "XLA-batched and native reads must agree");
    // Present keys found, missing keys absent.
    for (i, key) in keys.iter().enumerate() {
        if key.starts_with(b"user-missing") {
            assert!(batched[i].is_none());
        } else {
            assert!(batched[i].is_some(), "key {i} lost");
        }
    }
}

#[test]
fn xla_scored_migration_runs() {
    let Some(k) = kernels() else { return };
    let mut e = loaded_engine(k.clone());
    // Skewed reads to trigger popularity migration with XLA scoring.
    for round in 0..40 {
        for i in 0..50u64 {
            e.get(&key_for((i * 397 + round) % 20_000, 24));
        }
    }
    e.quiesce();
    assert!(
        k.priority_calls.get() > 0,
        "migration scans should dispatch the priority kernel"
    );
}

#[test]
fn xla_and_native_policies_make_same_decisions() {
    // Run the same deterministic workload with and without the XLA scorer;
    // placements + migrations must be identical (the scores are
    // numerically identical by the parity tests, so decisions must be too).
    let Some(k) = kernels() else { return };
    let run = |scorer: Option<Rc<XlaKernels>>| {
        let mut cfg = Config::tiny();
        cfg.workload.load_objects = 15_000;
        let mut policy = HhzsPolicy::new(cfg.lsm.num_levels);
        if let Some(s) = scorer {
            policy = policy.with_scorer(s);
        }
        let mut e = Engine::new(cfg, Box::new(policy));
        for i in 0..15_000u64 {
            e.put_payload(&key_for(i, 24), value_for(i, 1000));
        }
        for i in 0..3_000u64 {
            e.get(&key_for(i * 31 % 15_000, 24));
        }
        e.quiesce();
        (
            e.now,
            e.metrics.migrations_cap,
            e.metrics.migrations_pop,
            e.ssd_share_by_level(),
        )
    };
    let native = run(None);
    let xla = run(Some(k));
    assert_eq!(native, xla, "XLA-scored decisions must match native exactly");
}
