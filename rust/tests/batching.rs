//! Cross-shard group commit: equivalence, crash durability, saturation.
//!
//! Three properties pin the request-fusion layer (`[batch]` knobs):
//!
//! * **off ≡ default** — the knobs off (whatever the window/gap values
//!   say) and the degenerate `commit_batch_max = 1` both take the exact
//!   sync path, so the full §4.1 protocol digest — clock, metrics, SST
//!   layout, zenfs extents, WAL ios, device queue waits — is identical
//!   to a default run at shards ∈ {1, 4}. The committed golden in
//!   `tests/datapath.golden` pins the default itself, so transitively
//!   the knobs-off timeline is bit-identical to main.
//! * **acked-write durability** — a crash torn mid-fused-batch (both
//!   WAL-window points) loses at most the one record the injector tore;
//!   every other staged member replays from media and the recovery
//!   invariant sweep stays clean on all shards.
//! * **saturation** — with 64 closed-loop clients on 4 shards, growing
//!   the commit window strictly shrinks WAL `write_ios` and never grows
//!   the merged SSD queue wait, at equal acked ops: the amortization
//!   the tentpole exists for, pinned as a machine-independent DES fact.

use hhzs::config::Config;
use hhzs::exp::exp7::wal_write_ios;
use hhzs::metrics::Metrics;
use hhzs::shard::ShardedEngine;
use hhzs::ycsb::{key_for, Kind, RoutedSource, Spec, YcsbSource};
use hhzs::zone::Dev;

fn make_se(cfg: &Config) -> ShardedEngine {
    ShardedEngine::new(cfg, |c| hhzs::exp::common::make_policy("HHZS", c))
}

fn run_phase(se: &mut ShardedEngine, cfg: &Config, kind: Kind) {
    let clients = cfg.workload.clients;
    let router = se.router;
    let spec = Spec::from_config(cfg, kind);
    se.run(
        |s| Box::new(RoutedSource::new(YcsbSource::new(spec.clone(), clients), router, s)),
        clients,
        None,
        false,
    );
}

// ---------------------------------------------------------------------
// Equivalence: knobs off and batch-of-1 are the sync path, exactly
// ---------------------------------------------------------------------

fn proto_cfg(shards: usize) -> Config {
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 10_000;
    cfg.workload.ops = 3_000;
    cfg.shards = shards;
    cfg
}

/// Everything observable about a finished run, per shard — the datapath
/// digest plus the write-path counters group commit touches (WAL ios,
/// per-device queue wait).
fn digest(se: &ShardedEngine) -> Vec<String> {
    let mut out = Vec::new();
    for (s, e) in se.engines.iter().enumerate() {
        let m = &e.metrics;
        out.push(format!(
            "shard{s} now={} ops={} tput={:x} stalls={} flushes={} compactions={} \
             migr={} wal_over={} wal_ios={} qw={:?} p999={} cpuw={}:{}",
            e.now,
            m.ops_done,
            m.ops_per_sec().to_bits(),
            m.stalls,
            m.flushes,
            m.compactions,
            m.migration_bytes,
            e.pool.wal_overflows,
            wal_write_ios(m),
            m.queue_wait,
            m.read_lat.quantile(0.999),
            m.cpu_wait.n,
            m.cpu_wait.sum,
        ));
        for lvl in 0..e.version.num_levels() {
            for sst in e.version.level(lvl) {
                out.push(format!(
                    "shard{s} L{lvl} sst={} size={} n={}",
                    sst.id, sst.file_size, sst.num_entries
                ));
            }
        }
        for f in e.fs.files() {
            let extents: Vec<String> =
                f.extents.iter().map(|x| format!("{}:{}+{}", x.zone, x.offset, x.len)).collect();
            out.push(format!(
                "shard{s} file={} dev={} size={} extents=[{}]",
                f.id,
                f.dev.name(),
                f.size,
                extents.join(",")
            ));
        }
    }
    out
}

fn run_protocol_cfg(cfg: Config) -> Vec<String> {
    let mut se = make_se(&cfg);
    run_phase(&mut se, &cfg, Kind::Load);
    se.flush_all();
    run_phase(&mut se, &cfg, Kind::A);
    se.quiesce();
    digest(&se)
}

#[test]
fn knobs_off_and_batch_of_one_match_default_exactly() {
    for shards in [1usize, 4] {
        let base = run_protocol_cfg(proto_cfg(shards));

        // Knobs off: the window/gap values must be dead config — only the
        // two booleans gate anything.
        let mut off = proto_cfg(shards);
        off.batch.group_commit = false;
        off.batch.commit_window_ns = 123_456;
        off.batch.commit_batch_max = 7;
        off.batch.read_coalesce = false;
        off.batch.coalesce_gap_bytes = 1 << 20;
        assert_eq!(
            run_protocol_cfg(off),
            base,
            "{shards} shard(s): knobs-off run diverged from default"
        );

        // Degenerate batch of one: `group_commit = true, batch_max = 1`
        // must reduce to the sync path (a "batch" of one record fuses
        // nothing, so the committer disables itself).
        let mut one = proto_cfg(shards);
        one.batch.group_commit = true;
        one.batch.commit_batch_max = 1;
        one.batch.commit_window_ns = 500_000;
        let mut se = make_se(&one);
        run_phase(&mut se, &one, Kind::Load);
        se.flush_all();
        run_phase(&mut se, &one, Kind::A);
        se.quiesce();
        assert_eq!(
            se.engines[0].group_commit_staged_total(),
            0,
            "{shards} shard(s): batch_max = 1 must never stage"
        );
        assert_eq!(
            digest(&se),
            base,
            "{shards} shard(s): commit_batch_max = 1 diverged from the sync path"
        );
    }
}

#[test]
fn shards_share_one_committer() {
    let mut cfg = proto_cfg(4);
    cfg.batch.group_commit = true;
    let se = make_se(&cfg);
    for (s, e) in se.engines.iter().enumerate().skip(1) {
        assert!(
            se.engines[0].shares_group_committer_with(e),
            "shard {s} holds a private committer — cross-shard fusion impossible"
        );
    }
}

// ---------------------------------------------------------------------
// Crash durability: a tear mid-fused-batch loses at most the torn record
// ---------------------------------------------------------------------

#[test]
fn batched_crash_loses_at_most_the_torn_record() {
    for point in ["wal_before_memtable", "mid_zone_append"] {
        let mut cfg = Config::paper_scaled(2048);
        cfg.shards = 4;
        cfg.workload.load_objects = 400;
        cfg.workload.ops = 0;
        cfg.workload.clients = 8;
        cfg.batch.group_commit = true;
        cfg.batch.commit_window_ns = 100_000;
        cfg.batch.commit_batch_max = 8;
        cfg.crash.enabled = true;
        cfg.crash.point = point.into();
        cfg.crash.at_op = 40;
        cfg.crash.seed = 7;
        cfg.crash.shard = 0;

        let mut se = make_se(&cfg);
        run_phase(&mut se, &cfg, Kind::Load);

        assert!(
            se.engines[cfg.crash.shard].crash_fired(),
            "{point}: the injector never fired — the staged path skipped the crash hook"
        );
        assert!(
            se.engines[0].group_commit_staged_total() > 0,
            "{point}: group commit never engaged — the crash did not cross a fused batch"
        );

        // Every loaded key must be readable except (at most) the one the
        // injector tore mid-record: staged members are on media before
        // their batch closes, so recovery replays them even though their
        // acks were still pending when power was lost.
        let mut missing = Vec::new();
        for i in 0..cfg.workload.load_objects {
            let key = key_for(i, cfg.workload.key_size);
            if se.get(&key).is_none() {
                missing.push(i);
            }
        }
        assert!(
            missing.len() <= 1,
            "{point}: {} keys lost ({missing:?}) — fused batching dropped durable records",
            missing.len()
        );

        for (s, e) in se.engines.iter_mut().enumerate() {
            let violations = e.verify_recovery_invariants();
            assert!(
                violations.is_empty(),
                "{point}: shard {s} recovery invariants violated: {violations:?}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Saturation: wider windows fuse more, at equal acked ops
// ---------------------------------------------------------------------

/// Run load + YCSB A at 4 shards / 64 clients and return the A phase's
/// (acked ops, WAL write ios, merged SSD queue wait) — the deltas across
/// the mixed phase, where reads desynchronize the closed-loop clients
/// and the commit window is what decides how many stragglers fuse.
fn sweep_point(window_ns: Option<u64>) -> (u64, u64, u64) {
    let mut cfg = Config::paper_scaled(2048);
    cfg.shards = 4;
    cfg.workload.load_objects = 8_000;
    cfg.workload.ops = 4_000;
    cfg.workload.clients = 64;
    if let Some(w) = window_ns {
        cfg.batch.group_commit = true;
        cfg.batch.commit_window_ns = w;
        // Fill closure must never bind: the deadline is the variable
        // under test.
        cfg.batch.commit_batch_max = 1024;
    }
    let mut se = make_se(&cfg);
    run_phase(&mut se, &cfg, Kind::Load);
    se.flush_all();
    let before = se.merged_metrics();
    run_phase(&mut se, &cfg, Kind::A);
    let after = se.merged_metrics();
    if window_ns.is_some() {
        assert!(
            se.engines[0].group_commit_staged_total() > 0,
            "window {window_ns:?}: group commit never engaged"
        );
    }
    let ssd_wait = |m: &Metrics| m.queue_wait.get(&Dev::Ssd).copied().unwrap_or(0);
    (
        after.ops_done - before.ops_done,
        wal_write_ios(&after) - wal_write_ios(&before),
        ssd_wait(&after) - ssd_wait(&before),
    )
}

#[test]
fn wider_windows_fuse_strictly_more_at_equal_acked_ops() {
    let (ops_off, ios_off, _) = sweep_point(None);
    let (ops_w0, ios_w0, qw_w0) = sweep_point(Some(0));
    let (ops_w50, ios_w50, qw_w50) = sweep_point(Some(50_000));
    let (ops_w500, ios_w500, qw_w500) = sweep_point(Some(500_000));

    // Same acked work everywhere: fusion amortizes, it must not drop or
    // invent operations.
    assert_eq!(ops_off, ops_w0, "window 0 changed the acked op count");
    assert_eq!(ops_off, ops_w50, "window 50µs changed the acked op count");
    assert_eq!(ops_off, ops_w500, "window 500µs changed the acked op count");

    // WAL write ios strictly decrease as the window grows: even a
    // zero-width window fuses same-instant arrivals, and every widening
    // catches more of the read-desynchronized stragglers.
    assert!(
        ios_off > ios_w0,
        "window 0 did not fuse: off={ios_off} w0={ios_w0}"
    );
    assert!(
        ios_w0 > ios_w50,
        "50µs window fused no more than 0: w0={ios_w0} w50={ios_w50}"
    );
    assert!(
        ios_w50 > ios_w500,
        "500µs window fused no more than 50µs: w50={ios_w50} w500={ios_w500}"
    );

    // Under saturation the fused backlog drains faster than the
    // per-request one, so the merged SSD queue wait never grows with the
    // window.
    assert!(
        qw_w0 >= qw_w50 && qw_w50 >= qw_w500,
        "SSD queue wait grew with the window: w0={qw_w0} w50={qw_w50} w500={qw_w500}"
    );
}
