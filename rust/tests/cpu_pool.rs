//! Property suite for the shared background-CPU pool (`sim::cpu`).
//!
//! Two layers, per the acceptance criteria:
//!
//! * pool-level randomized sequences — admission/ordering invariants hold
//!   after every transition (flush never denied while a slot sits idle, a
//!   compaction grant always leaves a free slot per waiting flush, the
//!   fair cap binds, conservation across acquire/release);
//! * end-to-end DES runs over shards × `bg_threads` — at every DES event
//!   slots-in-use stays ≤ `bg_threads` *globally* (the phantom-thread
//!   fix: 4 shards used to simulate 4 × 12 threads), acquire/release
//!   conserve exactly one slot per started job, runs terminate even at
//!   `bg_threads ∈ {1, 2}`, and the pool's flush-priority counter stays
//!   clean.

use hhzs::config::{Config, CpuSched};
use hhzs::shard::ShardedEngine;
use hhzs::sim::cpu::CpuPool;
use hhzs::sim::rng::Rng;
use hhzs::ycsb::{Kind, Spec, YcsbSource};

// ---------------------------------------------------------------------
// Pool-level randomized admission properties
// ---------------------------------------------------------------------

#[test]
fn randomized_sequences_hold_every_admission_invariant() {
    let shard_counts = [1usize, 2, 4];
    let thread_counts = [1usize, 2, 3, 12];
    for case in 0..200u64 {
        let mut rng = Rng::new(0xC9_000 + case);
        let shards = shard_counts[rng.next_below(3) as usize];
        let total = thread_counts[rng.next_below(4) as usize];
        let sched =
            if rng.next_below(2) == 0 { CpuSched::Fair } else { CpuSched::WorkConserving };
        let mut pool = CpuPool::new(total, shards, sched);
        let ctx = format!("case {case}: total={total} shards={shards} sched={sched:?}");
        // Model: the running jobs as (shard, is_flush).
        let mut running: Vec<(usize, bool)> = Vec::new();
        for _ in 0..300 {
            let s = rng.next_below(shards as u64) as usize;
            match rng.next_below(3) {
                0 => {
                    let before = pool.in_use();
                    if pool.acquire_flush(s) {
                        running.push((s, true));
                        assert!(before < total, "{ctx}: flush granted beyond the bound");
                    } else {
                        // Flush priority: denial is legal ONLY with zero
                        // idle slots.
                        assert_eq!(before, total, "{ctx}: flush denied with an idle slot");
                    }
                }
                1 => {
                    if pool.acquire_compaction(s) {
                        running.push((s, false));
                        // A grant must leave ≥ 1 free slot per waiting
                        // flush and respect reservation + fair cap.
                        assert!(
                            pool.waiting_flushes() <= total - pool.in_use(),
                            "{ctx}: compaction grant starved a waiting flush"
                        );
                        assert!(
                            pool.shard_compactions(s) <= pool.compaction_cap(),
                            "{ctx}: fair cap exceeded on shard {s}"
                        );
                        let comp_held =
                            running.iter().filter(|(_, f)| !f).count();
                        assert!(
                            comp_held + pool.flush_reserved() <= total,
                            "{ctx}: compactions invaded the flush reservation"
                        );
                    }
                }
                _ => {
                    if !running.is_empty() {
                        let i = rng.next_below(running.len() as u64) as usize;
                        let (s, is_flush) = running.swap_remove(i);
                        if is_flush {
                            pool.release_flush(s);
                        } else {
                            pool.release_compaction(s);
                        }
                    }
                }
            }
            // Global transition invariants, checked at EVERY step.
            assert_eq!(pool.in_use(), running.len(), "{ctx}: slot conservation");
            assert!(pool.in_use() <= total, "{ctx}: slot bound");
            let per_shard_sum: usize = (0..shards).map(|s| pool.shard_in_use(s)).sum();
            assert_eq!(per_shard_sum, pool.in_use(), "{ctx}: per-shard ledger drift");
            let comp_sum: usize = (0..shards).map(|s| pool.shard_compactions(s)).sum();
            let comp_model = running.iter().filter(|(_, f)| !f).count();
            assert_eq!(comp_sum, comp_model, "{ctx}: compaction ledger drift");
            assert_eq!(
                pool.stats().flush_priority_violations,
                0,
                "{ctx}: flush priority violated"
            );
        }
        for (s, is_flush) in running.drain(..) {
            if is_flush {
                pool.release_flush(s);
            } else {
                pool.release_compaction(s);
            }
        }
        let st = pool.stats();
        assert_eq!(pool.in_use(), 0, "{ctx}: slots leaked");
        assert_eq!(st.acquires, st.releases, "{ctx}: acquire/release imbalance");
        assert!(st.high_water <= total, "{ctx}: high water {} > {total}", st.high_water);
    }
}

#[test]
fn waiting_flush_always_has_first_claim_on_freed_slots() {
    // Directed version of the ordering property: with every slot busy and
    // a flush waiting on another shard, no release may be consumed by a
    // compaction before that flush — across pool shapes.
    for &total in &[1usize, 2, 3, 12] {
        for &shards in &[2usize, 4] {
            let mut pool = CpuPool::new(total, shards, CpuSched::WorkConserving);
            let mut held = Vec::new();
            // Fill the pool (flush acquires ignore the reservation).
            for i in 0..total {
                let s = i % shards;
                assert!(pool.acquire_flush(s));
                held.push(s);
            }
            assert!(!pool.acquire_flush(shards - 1), "pool must be full");
            assert_eq!(pool.waiting_flushes(), 1);
            // Free slots one by one: while the flush waits, shard 0 must
            // never win a compaction slot ahead of it.
            while let Some(s) = held.pop() {
                pool.release_flush(s);
                assert!(
                    !pool.can_admit_compaction(0)
                        || pool.waiting_flushes() + 1 <= total - pool.in_use(),
                    "total={total} shards={shards}: compaction could starve the flush"
                );
                if pool.acquire_flush(shards - 1) {
                    assert_eq!(pool.waiting_flushes(), 0, "claim must clear on grant");
                    break;
                }
            }
            assert_eq!(pool.stats().flush_priority_violations, 0);
        }
    }
}

// ---------------------------------------------------------------------
// End-to-end DES runs: shards × bg_threads
// ---------------------------------------------------------------------

fn des_cfg(shards: usize, bg_threads: usize, sched: CpuSched) -> Config {
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 6_000;
    cfg.workload.ops = 1_500;
    cfg.shards = shards;
    cfg.lsm.bg_threads = bg_threads;
    cfg.lsm.cpu_sched = sched;
    cfg
}

#[test]
fn des_runs_bound_and_conserve_slots_globally() {
    for &shards in &[1usize, 2, 4] {
        for &bg in &[1usize, 2, 3, 12] {
            // Alternate the arbitration mode across the grid so both are
            // exercised at every shape.
            let sched = if (shards + bg) % 2 == 0 {
                CpuSched::Fair
            } else {
                CpuSched::WorkConserving
            };
            // ONE measured phase: `begin_phase` resets metrics, so the
            // job-ledger comparison below (pool acquires vs counted job
            // starts) is exact only over a single phase + its settling.
            let cfg = des_cfg(shards, bg, sched);
            let clients = cfg.workload.clients;
            let mut se =
                ShardedEngine::new(&cfg, |c| hhzs::exp::common::make_policy("HHZS", c));
            let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
            se.run_shared(&mut load, clients, None, false);
            se.flush_all();
            se.quiesce();
            let ctx = format!("shards={shards} bg_threads={bg} sched={sched:?}");
            let m = se.merged_metrics();
            assert_eq!(
                m.ops_done, cfg.workload.load_objects,
                "{ctx}: lost ops (termination)"
            );
            let st = se.cpu_pool_stats();
            // THE phantom-thread fix: the bound is bg_threads, not
            // shards × bg_threads — and it held at every DES event
            // (high_water is updated inside every acquire).
            assert!(
                st.high_water <= bg,
                "{ctx}: {} slots in use at some event (global bound {bg})",
                st.high_water
            );
            assert_eq!(st.in_use, 0, "{ctx}: slots leaked after quiesce");
            assert_eq!(st.acquires, st.releases, "{ctx}: acquire/release imbalance");
            // Conservation against the job ledger: exactly one acquire
            // per started flush/compaction (metrics count job starts).
            assert_eq!(
                st.acquires,
                m.flushes + m.compactions,
                "{ctx}: acquires must match started jobs"
            );
            assert!(m.flushes > 0, "{ctx}: workload must exercise flushes");
            assert_eq!(st.flush_priority_violations, 0, "{ctx}: flush priority");
            // cpu_wait samples exist for every job start (0 when a slot
            // was free immediately).
            assert_eq!(
                m.cpu_wait.n,
                m.flushes + m.compactions,
                "{ctx}: one cpu_wait sample per job"
            );
        }
    }
}

#[test]
fn fair_mode_caps_a_backlogged_shards_compaction_slots() {
    // Unit-level check of the knob the DES grid above only smoke-tests:
    // fair vs work-conserving admission differ exactly by the per-shard
    // cap.
    let mut fair = CpuPool::new(12, 4, CpuSched::Fair);
    let mut wc = CpuPool::new(12, 4, CpuSched::WorkConserving);
    assert_eq!(fair.compaction_cap(), 3);
    assert_eq!(wc.compaction_cap(), 12);
    let mut fair_got = 0;
    let mut wc_got = 0;
    for _ in 0..12 {
        fair_got += usize::from(fair.acquire_compaction(0));
        wc_got += usize::from(wc.acquire_compaction(0));
    }
    assert_eq!(fair_got, 3, "fair: shard 0 capped at ceil(12/4)");
    assert_eq!(wc_got, 10, "work-conserving: shard 0 bounded only by the reservation");
    // The capped slots are still available to OTHER shards under fair.
    assert!(fair.acquire_compaction(1));
}
