//! Async-frontend integration tests: one shared virtual clock, one shared
//! SSD/HDD FIFO pair for all shards, cross-shard scatter-gather scans, and
//! global pacing. (`shards = 1` ≡ seed engine is pinned bit-for-bit in
//! `tests/integration.rs`.)

use hhzs::config::Config;
use hhzs::coordinator::Engine;
use hhzs::exp::common::make_policy;
use hhzs::policy::HhzsPolicy;
use hhzs::shard::ShardedEngine;
use hhzs::ycsb::{key_for, value_for, Kind, Spec, YcsbSource};

fn small_cfg(shards: usize) -> Config {
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 20_000;
    cfg.workload.ops = 5_000;
    cfg.shards = shards;
    cfg
}

#[test]
fn four_shards_share_one_device_fifo_and_queue_behind_each_other() {
    let cfg = small_cfg(4);
    let clients = cfg.workload.clients;
    let mut se = ShardedEngine::new(&cfg, |c| make_policy("HHZS", c));
    // The substrate is genuinely shared: every shard's devices resolve to
    // the SAME FIFO timing server per physical device.
    for e in &se.engines[1..] {
        assert!(e.fs.ssd.timer.shares_with(&se.engines[0].fs.ssd.timer));
        assert!(e.fs.hdd.timer.shares_with(&se.engines[0].fs.hdd.timer));
    }
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, None, false);
    let m = se.merged_metrics();
    assert_eq!(m.ops_done, 20_000, "the frontend must conserve the op stream");
    // Contention is actually modeled: shards hammering one device pair on
    // one clock wait on each other's in-flight requests.
    assert!(
        m.total_queue_wait_ns() > 0,
        "4 shards on one FIFO pair must see device queue wait"
    );
    let waiting = se
        .engines
        .iter()
        .filter(|e| e.metrics.total_queue_wait_ns() > 0)
        .count();
    assert!(
        waiting >= 3,
        "cross-shard contention should reach most shards (saw {waiting}/4)"
    );
    // One clock, one FIFO: all shards agree on the device's next-free time.
    let free_ssd = se.engines[0].fs.ssd.timer.free_at();
    assert!(free_ssd > 0);
    for e in &se.engines[1..] {
        assert_eq!(e.fs.ssd.timer.free_at(), free_ssd);
    }
}

#[test]
fn scatter_gather_scan_matches_the_single_engine() {
    // The sharded scan fans out to every shard and k-way merges the
    // partials; over identical data it must count exactly what one engine
    // holding the union counts — which, with no tombstones, is
    // min(n, #keys >= start).
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 0;
    let total = 8_000u64;
    let mut single = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
    let mut cfg4 = cfg.clone();
    cfg4.shards = 4;
    let mut sharded = ShardedEngine::new(&cfg4, |c| make_policy("HHZS", c));
    for i in 0..total {
        single.put_payload(&key_for(i, 24), value_for(i, 500));
        sharded.put_payload(&key_for(i, 24), value_for(i, 500));
    }
    single.flush_all();
    single.quiesce();
    sharded.flush_all();
    sharded.quiesce();
    let mut keys: Vec<Vec<u8>> = (0..total).map(|i| key_for(i, 24)).collect();
    keys.sort();
    for (rank, n) in [(0usize, 64usize), (1_000, 500), (4_000, 3_000), (7_900, 500)] {
        let start = keys[rank].clone();
        let expected = (total as usize - rank).min(n);
        assert_eq!(single.scan(&start, n), expected, "single engine, rank {rank}, n {n}");
        assert_eq!(sharded.scan(&start, n), expected, "scatter-gather, rank {rank}, n {n}");
    }
}

#[test]
fn throttling_is_global_pacing_across_shards() {
    // The old sharded runner split the target evenly (`t / n`) across
    // per-shard client pools; the frontend paces ONE global client pool,
    // so the aggregate rate respects the global target directly.
    let cfg = small_cfg(4);
    let clients = cfg.workload.clients;
    let mut se = ShardedEngine::new(&cfg, |c| make_policy("HHZS", c));
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, Some(2_000.0), false);
    assert_eq!(se.merged_metrics().ops_done, 20_000);
    let tput = se.aggregate_ops_per_sec();
    assert!(tput <= 2_200.0, "global pacing exceeded: {tput:.0} ops/s vs target 2000");
}
