//! Async-frontend integration tests: one shared virtual clock, one shared
//! SSD/HDD FIFO pair and ONE shared `bg_threads` CPU pool for all shards,
//! cross-shard scatter-gather scans, and global pacing. (`shards = 1` ≡
//! seed engine is pinned bit-for-bit in `tests/integration.rs`, including
//! the CPU pool's ledger.)

use hhzs::config::Config;
use hhzs::coordinator::Engine;
use hhzs::exp::common::make_policy;
use hhzs::policy::HhzsPolicy;
use hhzs::shard::ShardedEngine;
use hhzs::ycsb::{key_for, value_for, Kind, Spec, YcsbSource};

fn small_cfg(shards: usize) -> Config {
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 20_000;
    cfg.workload.ops = 5_000;
    cfg.shards = shards;
    cfg
}

#[test]
fn four_shards_share_one_device_fifo_and_queue_behind_each_other() {
    let cfg = small_cfg(4);
    let clients = cfg.workload.clients;
    let mut se = ShardedEngine::new(&cfg, |c| make_policy("HHZS", c));
    // The substrate is genuinely shared: every shard's devices resolve to
    // the SAME FIFO timing server per physical device.
    for e in &se.engines[1..] {
        assert!(e.fs.ssd.timer.shares_with(&se.engines[0].fs.ssd.timer));
        assert!(e.fs.hdd.timer.shares_with(&se.engines[0].fs.hdd.timer));
    }
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, None, false);
    let m = se.merged_metrics();
    assert_eq!(m.ops_done, 20_000, "the frontend must conserve the op stream");
    // Contention is actually modeled: shards hammering one device pair on
    // one clock wait on each other's in-flight requests.
    assert!(
        m.total_queue_wait_ns() > 0,
        "4 shards on one FIFO pair must see device queue wait"
    );
    let waiting = se
        .engines
        .iter()
        .filter(|e| e.metrics.total_queue_wait_ns() > 0)
        .count();
    assert!(
        waiting >= 3,
        "cross-shard contention should reach most shards (saw {waiting}/4)"
    );
    // One clock, one FIFO: all shards agree on the device's next-free time.
    let free_ssd = se.engines[0].fs.ssd.timer.free_at();
    assert!(free_ssd > 0);
    for e in &se.engines[1..] {
        assert_eq!(e.fs.ssd.timer.free_at(), free_ssd);
    }
}

#[test]
fn scatter_gather_scan_matches_the_single_engine() {
    // The sharded scan fans out to every shard and k-way merges the
    // partials; over identical data it must count exactly what one engine
    // holding the union counts — which, with no tombstones, is
    // min(n, #keys >= start).
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 0;
    let total = 8_000u64;
    let mut single = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
    let mut cfg4 = cfg.clone();
    cfg4.shards = 4;
    let mut sharded = ShardedEngine::new(&cfg4, |c| make_policy("HHZS", c));
    for i in 0..total {
        single.put_payload(&key_for(i, 24), value_for(i, 500));
        sharded.put_payload(&key_for(i, 24), value_for(i, 500));
    }
    single.flush_all();
    single.quiesce();
    sharded.flush_all();
    sharded.quiesce();
    let mut keys: Vec<Vec<u8>> = (0..total).map(|i| key_for(i, 24)).collect();
    keys.sort();
    for (rank, n) in [(0usize, 64usize), (1_000, 500), (4_000, 3_000), (7_900, 500)] {
        let start = keys[rank].clone();
        let expected = (total as usize - rank).min(n);
        assert_eq!(single.scan(&start, n), expected, "single engine, rank {rank}, n {n}");
        assert_eq!(sharded.scan(&start, n), expected, "scatter-gather, rank {rank}, n {n}");
    }
}

#[test]
fn four_shards_share_one_cpu_pool_and_contend_for_two_threads() {
    // The phantom-thread fix, observably: 4 shards over bg_threads = 2
    // used to simulate 8 background threads (each shard privately assumed
    // the full pool). Now the pool is global: the run must terminate with
    // slots-in-use never exceeding 2 at any DES event, and with ready
    // jobs measurably *waiting* for CPU (merged cpu_wait > 0).
    let mut cfg = small_cfg(4);
    cfg.lsm.bg_threads = 2;
    let clients = cfg.workload.clients;
    let mut se = ShardedEngine::new(&cfg, |c| make_policy("HHZS", c));
    // The pool is genuinely shared: every engine draws from shard 0's.
    for e in &se.engines[1..] {
        assert!(e.shares_cpu_pool_with(&se.engines[0]));
    }
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, None, false);
    let m = se.merged_metrics();
    assert_eq!(m.ops_done, 20_000, "4-shard bg_threads=2 run must terminate cleanly");
    assert!(m.flushes > 0 && m.compactions > 0, "background work must run");
    assert!(
        m.cpu_wait.sum > 0,
        "4 shards contending for 2 threads must wait for CPU (sum = {})",
        m.cpu_wait.sum
    );
    let st = se.cpu_pool_stats();
    assert!(
        st.high_water <= 2,
        "global slot bound violated: {} slots in use at some event",
        st.high_water
    );
    assert_eq!(st.flush_priority_violations, 0);
    se.quiesce();
    let st = se.cpu_pool_stats();
    assert_eq!(st.in_use, 0, "slots leaked");
    assert_eq!(st.acquires, st.releases);
}

#[test]
fn one_shard_frontend_runs_identically_with_private_or_shared_pool_path() {
    // `ShardedEngine::new` at shards = 1 reconfigures the engine's own
    // pool in place (the identity); a raw Engine never goes through that
    // call. Both paths must produce the same DES timeline AND the same
    // CPU-pool ledger — the shared-pool extension of the bit-for-bit pin
    // (the full protocol pin lives in tests/integration.rs).
    let cfg = small_cfg(1);
    let clients = cfg.workload.clients;

    let mut raw = hhzs::coordinator::Engine::new(
        cfg.clone(),
        Box::new(HhzsPolicy::new(cfg.lsm.num_levels)),
    );
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    raw.run(&mut load, clients, None, false);

    let mut se = ShardedEngine::new(&cfg, |c| make_policy("HHZS", c));
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, None, false);

    assert_eq!(raw.now, se.engines[0].now, "virtual clocks diverged");
    let (a, b) = (&raw.metrics, &se.engines[0].metrics);
    assert_eq!(a.flushes, b.flushes);
    assert_eq!(a.compactions, b.compactions);
    assert_eq!(a.cpu_wait.n, b.cpu_wait.n, "cpu_wait sample counts diverged");
    assert_eq!(a.cpu_wait.sum, b.cpu_wait.sum, "cpu_wait totals diverged");
    let (sa, sb) = (raw.cpu_pool_stats(), se.cpu_pool_stats());
    assert_eq!(sa.acquires, sb.acquires, "pool ledgers diverged");
    assert_eq!(sa.high_water, sb.high_water);
}

#[test]
fn throttling_is_global_pacing_across_shards() {
    // The old sharded runner split the target evenly (`t / n`) across
    // per-shard client pools; the frontend paces ONE global client pool,
    // so the aggregate rate respects the global target directly.
    let cfg = small_cfg(4);
    let clients = cfg.workload.clients;
    let mut se = ShardedEngine::new(&cfg, |c| make_policy("HHZS", c));
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, Some(2_000.0), false);
    assert_eq!(se.merged_metrics().ops_done, 20_000);
    let tput = se.aggregate_ops_per_sec();
    assert!(tput <= 2_200.0, "global pacing exceeded: {tput:.0} ops/s vs target 2000");
}
