//! Property-based tests over the core invariants.
//!
//! No proptest crate is available in this offline environment, so this
//! file carries a small in-house property harness: deterministic seeds,
//! many random cases per property, and failing-seed reporting. Each
//! property documents the invariant it pins.

use hhzs::config::Config;
use hhzs::coordinator::Engine;
use hhzs::lsm::compaction::{merge_entries, split_outputs};
use hhzs::lsm::sst::{build_sst, search_block};
use hhzs::lsm::{Bloom, Entry, Key, MemTable, Payload};
use hhzs::policy::HhzsPolicy;
use hhzs::sim::rng::Rng;
use hhzs::zone::{Dev, Zone, ZoneState};

/// Run `cases` random trials of `prop`, reporting the failing seed.
fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ case;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property {name:?} failed at seed {seed:#x}: {e:?}");
        }
    }
}

fn rand_key(rng: &mut Rng) -> Vec<u8> {
    format!("user{:020}", rng.next_below(1 << 40)).into_bytes()
}

// ---------------------------------------------------------------------
// Zone invariants
// ---------------------------------------------------------------------

#[test]
fn prop_zone_wp_equals_bytes_written_since_reset() {
    forall("zone-wp", 50, |rng| {
        let cap = 512 + rng.next_below(4096);
        let mut z = Zone::new(cap);
        let mut written = 0u64;
        for _ in 0..100 {
            match rng.next_below(10) {
                0 => {
                    z.reset();
                    written = 0;
                }
                1 => z.finish(),
                _ => {
                    let n = 1 + rng.next_below(300);
                    let buf = vec![0u8; n as usize];
                    match z.append(&buf) {
                        Ok(off) => {
                            assert_eq!(off, written, "append lands at the write pointer");
                            written += n;
                        }
                        Err(_) => {
                            // Rejected: either full state or capacity.
                            assert!(
                                z.state() == ZoneState::Full || written + n > cap,
                                "append may only fail when full"
                            );
                        }
                    }
                }
            }
            assert_eq!(z.wp(), written, "wp tracks accepted bytes exactly");
            assert!(z.wp() <= cap);
            // Reads below wp always succeed; reads past wp always fail.
            if written > 0 {
                let off = rng.next_below(written);
                let len = 1 + rng.next_below(written - off);
                assert!(z.read(off, len).is_ok());
            }
            assert!(z.read(written, 1).is_err());
        }
    });
}

// ---------------------------------------------------------------------
// LSM merge invariants
// ---------------------------------------------------------------------

#[test]
fn prop_merge_is_sorted_deduped_and_newest_wins() {
    forall("merge", 40, |rng| {
        let streams: Vec<Vec<Entry>> = (0..1 + rng.next_below(5))
            .map(|s| {
                let mut m = std::collections::BTreeMap::new();
                for _ in 0..rng.next_below(80) {
                    let k = format!("k{:03}", rng.next_below(60)).into_bytes();
                    let seq = rng.next_below(1_000_000) * 10 + s;
                    let val = if rng.next_below(10) == 0 {
                        None
                    } else {
                        Some(Payload::fill(rng.next_below(256) as u8, 4))
                    };
                    // within a stream, last write wins (BTreeMap keyed by key)
                    let e = m.entry(k.clone()).or_insert((seq, val));
                    if seq > e.0 {
                        *e = (seq, val);
                    }
                }
                m.into_iter()
                    .map(|(key, (seq, value))| Entry { key: Key::from(key), seq, value })
                    .collect()
            })
            .collect();
        // Expected winner per key: max seq across streams.
        let mut expect: std::collections::BTreeMap<Key, (u64, Option<Payload>)> =
            Default::default();
        for st in &streams {
            for e in st {
                let slot = expect.entry(e.key.clone()).or_insert((e.seq, e.value));
                if e.seq > slot.0 {
                    *slot = (e.seq, e.value);
                }
            }
        }
        let merged = merge_entries(streams, false);
        assert_eq!(merged.len(), expect.len());
        for (got, (key, (seq, value))) in merged.iter().zip(expect.iter()) {
            assert_eq!(&got.key, key);
            assert_eq!(got.seq, *seq, "newest version must win for {key:?}");
            assert_eq!(&got.value, value);
        }
        for w in merged.windows(2) {
            assert!(w[0].key < w[1].key);
        }
    });
}

#[test]
fn prop_split_outputs_partition_exactly() {
    forall("split", 40, |rng| {
        let n = rng.next_below(500) as usize;
        let entries: Vec<Entry> = (0..n)
            .map(|i| Entry {
                key: format!("k{i:06}").into_bytes().into(),
                seq: i as u64,
                value: Some(Payload::fill(0, rng.next_below(200) as usize)),
            })
            .collect();
        let target = 256 + rng.next_below(4096);
        let ranges = split_outputs(&entries, target);
        let mut covered = 0usize;
        let mut expect_start = 0usize;
        for r in &ranges {
            assert_eq!(r.start, expect_start, "ranges contiguous");
            assert!(!r.is_empty());
            covered += r.len();
            expect_start = r.end;
        }
        assert_eq!(covered, n, "every entry in exactly one output");
    });
}

// ---------------------------------------------------------------------
// SST format invariants
// ---------------------------------------------------------------------

#[test]
fn prop_sst_lookup_finds_every_key_and_only_those() {
    forall("sst-lookup", 25, |rng| {
        let mut keys: Vec<Vec<u8>> = (0..1 + rng.next_below(400)).map(|_| rand_key(rng)).collect();
        keys.sort();
        keys.dedup();
        let entries: Vec<Entry> = keys
            .iter()
            .enumerate()
            .map(|(i, k)| Entry {
                key: k.clone().into(),
                seq: i as u64,
                value: Some(Payload::fill((i % 255) as u8, 1 + rng.next_below(64) as usize)),
            })
            .collect();
        let (meta, data) = build_sst(&entries, 7, 1, 512 + rng.next_below(4096), 10, 0);
        for e in &entries {
            let bi = meta.find_block(&e.key).expect("key within range");
            let h = &meta.blocks[bi];
            let block = data.slice_to_buf(h.offset, h.len as u64);
            assert_eq!(search_block(&block, &e.key).map(|r| r.to_entry()).as_ref(), Some(e));
        }
        // Keys not in the SST are never *returned* (bloom may pass, the
        // block search must still reject).
        for _ in 0..50 {
            let probe = rand_key(rng);
            if keys.binary_search(&probe).is_ok() {
                continue;
            }
            if let Some(bi) = meta.find_block(&probe) {
                let h = &meta.blocks[bi];
                let block = data.slice_to_buf(h.offset, h.len as u64);
                assert!(search_block(&block, &probe).is_none());
            }
        }
    });
}

#[test]
fn prop_bloom_never_false_negative() {
    forall("bloom", 30, |rng| {
        let fps: Vec<u32> =
            (0..1 + rng.next_below(3000)).map(|_| rng.next_u64() as u32).collect();
        let bits = 6 + rng.next_below(14) as u32;
        let b = Bloom::build(&fps, bits);
        for &fp in &fps {
            assert!(b.may_contain(fp), "false negative for {fp:#x} at {bits} bits/key");
        }
    });
}

// ---------------------------------------------------------------------
// MemTable vs model
// ---------------------------------------------------------------------

#[test]
fn prop_memtable_matches_btreemap_model() {
    forall("memtable-model", 30, |rng| {
        let mut mem = MemTable::new();
        let mut model: std::collections::BTreeMap<Vec<u8>, Option<Payload>> = Default::default();
        for seq in 0..400u64 {
            let k = format!("k{:02}", rng.next_below(40)).into_bytes();
            if rng.next_below(5) == 0 {
                mem.insert(Key::new(&k), seq, None);
                model.insert(k, None);
            } else {
                let v = Payload::fill(rng.next_below(256) as u8, 8);
                mem.insert(Key::new(&k), seq, Some(v));
                model.insert(k, Some(v));
            }
        }
        for (k, v) in &model {
            assert_eq!(mem.get(k), Some(*v), "model divergence at {k:?}");
        }
        assert_eq!(mem.len(), model.len());
    });
}

// ---------------------------------------------------------------------
// Whole-engine invariants under random op mixes
// ---------------------------------------------------------------------

#[test]
fn prop_engine_read_your_writes_and_zone_consistency() {
    forall("engine-rywr", 3, |rng| {
        let mut cfg = Config::tiny();
        cfg.workload.load_objects = 0;
        let mut e = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
        let mut model: std::collections::HashMap<Vec<u8>, Option<Payload>> = Default::default();
        for i in 0..12_000u64 {
            let k = format!("user{:016}", rng.next_below(4_000)).into_bytes();
            match rng.next_below(10) {
                0 => {
                    e.delete(&k);
                    model.insert(k, None);
                }
                1..=6 => {
                    let v = format!("v{i}").into_bytes();
                    e.put(&k, &v);
                    model.insert(k, Some(Payload::from_bytes(&v)));
                }
                _ => {
                    let got = e.get(&k);
                    let want = model.get(&k).copied().flatten();
                    assert_eq!(got, want, "read-your-writes violated for {k:?}");
                }
            }
        }
        e.quiesce();
        // Final audit: every model key reads back correctly after all
        // background reorganization.
        for (k, want) in model.iter().take(500) {
            assert_eq!(e.get(k), *want, "post-quiesce divergence at {k:?}");
        }
        // Zone-level audit: every live SST has a file; SSD SSTs sit in
        // exactly one zone; levels ≥1 are disjoint.
        for lvl in 1..e.version.num_levels() {
            assert!(e.version.disjoint(lvl));
        }
        for m in e.version.all_ssts() {
            let f = e.fs.file(m.id).expect("live SST backed by zones");
            if f.dev == Dev::Ssd {
                assert_eq!(f.extents.len(), 1);
            }
            assert_eq!(f.size, m.file_size);
        }
    });
}

// ---------------------------------------------------------------------
// Shard-subsystem invariants
// ---------------------------------------------------------------------

#[test]
fn prop_router_total_deterministic_and_stable_across_instances() {
    use hhzs::shard::Router;
    forall("router", 30, |rng| {
        let n = 1 + rng.next_below(16) as usize;
        let a = Router::new(n);
        let b = Router::new(n); // independent instance, same config
        for _ in 0..200 {
            let key = rand_key(rng);
            let s = a.route(&key);
            // Total: every key maps to exactly one shard in range.
            assert!(s < n, "key routed outside 0..{n}");
            // Deterministic: repeated and cross-instance routing agree.
            assert_eq!(s, a.route(&key), "routing must be a pure function");
            assert_eq!(s, b.route(&key), "instances must agree");
        }
    });
}

#[test]
fn prop_histogram_merge_totals_equal_sum_of_parts() {
    use hhzs::metrics::LogHistogram;
    forall("hist-merge", 30, |rng| {
        let parts = 1 + rng.next_below(8) as usize;
        let mut merged = LogHistogram::new();
        let mut shards = Vec::new();
        let mut all_values = Vec::new();
        for _ in 0..parts {
            let mut h = LogHistogram::new();
            for _ in 0..rng.next_below(500) {
                let v = 1 + rng.next_below(1 << 30);
                h.record(v);
                all_values.push(v);
            }
            shards.push(h);
        }
        for h in &shards {
            merged.merge(h);
        }
        let n_sum: u64 = shards.iter().map(|h| h.n).sum();
        let sum_sum: u128 = shards.iter().map(|h| h.sum).sum();
        assert_eq!(merged.n, n_sum, "merged count must equal the shard sum");
        assert_eq!(merged.sum, sum_sum, "merged latency mass must be conserved");
        if let Some(&max) = all_values.iter().max() {
            assert_eq!(merged.max, max);
            assert_eq!(merged.min, *all_values.iter().min().unwrap());
            // The merged p100 lands on the true maximum (capped bucket).
            assert_eq!(merged.quantile(1.0), merged.max.min(max));
        }
    });
}

#[test]
fn prop_metrics_merge_conserves_counters_and_traffic() {
    use hhzs::metrics::{Metrics, WriteCategory};
    forall("metrics-merge", 20, |rng| {
        let parts = 1 + rng.next_below(6) as usize;
        let mut shards: Vec<Metrics> = Vec::new();
        for _ in 0..parts {
            let mut m = Metrics::default();
            for _ in 0..rng.next_below(100) {
                let dev = if rng.next_below(2) == 0 { Dev::Ssd } else { Dev::Hdd };
                match rng.next_below(3) {
                    0 => m.record_write(WriteCategory::Wal, dev, 1 + rng.next_below(4096)),
                    1 => m.record_write(
                        WriteCategory::Sst(rng.next_below(7) as usize),
                        dev,
                        1 + rng.next_below(4096),
                    ),
                    _ => m.record_read(dev, 1 + rng.next_below(4096)),
                }
                m.ops_done += 1;
            }
            shards.push(m);
        }
        let mut merged = Metrics::default();
        for m in &shards {
            merged.merge(m);
        }
        let ops: u64 = shards.iter().map(|m| m.ops_done).sum();
        assert_eq!(merged.ops_done, ops);
        let write_bytes = |m: &Metrics| -> u64 {
            m.write_traffic.values().map(|c| c.bytes).sum()
        };
        let read_ios = |m: &Metrics| -> u64 { m.read_traffic.values().map(|c| c.ios).sum() };
        assert_eq!(
            write_bytes(&merged),
            shards.iter().map(write_bytes).sum::<u64>(),
            "write traffic must be conserved"
        );
        assert_eq!(
            read_ios(&merged),
            shards.iter().map(read_ios).sum::<u64>(),
            "read IOs must be conserved"
        );
    });
}

#[test]
fn prop_deterministic_replay() {
    // Same seed ⇒ bit-identical virtual timeline and metrics.
    let run = || {
        let mut cfg = Config::tiny();
        cfg.workload.load_objects = 20_000;
        let (engine, m) = hhzs::exp::common::load_fresh(&cfg, "HHZS", None, false);
        (engine.now, m.ops_per_sec().to_bits(), m.stalls, m.flushes, m.compactions)
    };
    assert_eq!(run(), run(), "DES must be deterministic for a fixed seed");
}
