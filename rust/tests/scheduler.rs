//! Scheduler-invariant property grid for the stall-aware wake policy.
//!
//! Three layers, per the acceptance criteria:
//!
//! * pool-level randomized properties — uniform priorities reduce the
//!   stall-aware order to FIFO's exactly (the golden-identity pin at the
//!   pool level), and a continuously waiting shard reaches the head of
//!   its class within a bounded number of wake rounds against arbitrary
//!   fresh competitors (aging = no starvation);
//! * end-to-end DES runs over shards {1,2,4,8} × bg_threads {1,2,3,12}
//!   × wake {fifo, stall_aware} — work conservation (one acquire per
//!   started job, zero leaks), the global slot bound, and zero
//!   flush-priority violations hold under BOTH wake policies, and FIFO
//!   never reports an avoided stall;
//! * a traced 4-shard stall-aware run replayed through the trace
//!   checker — priority-order compliance of every emitted wake round and
//!   fg-pool occupancy ≤ fg_threads, verified from the export alone.

use hhzs::config::{Config, CpuSched, WakePolicy};
use hhzs::shard::ShardedEngine;
use hhzs::sim::cpu::{CpuPool, AGE_STEP, RISK_MAX};
use hhzs::sim::rng::Rng;
use hhzs::ycsb::{Kind, Spec, YcsbSource};

// ---------------------------------------------------------------------
// Pool-level randomized properties
// ---------------------------------------------------------------------

/// Uniform priorities (equal risk, equal age) must make the stall-aware
/// wake order event-for-event identical to FIFO's, across random waiter
/// sets in both classes. This is the pool half of the guarantee that
/// `wake = stall_aware` with no pressure differential cannot perturb a
/// golden-pinned timeline.
#[test]
fn randomized_uniform_priority_wakes_match_fifo_order() {
    for case in 0..100u64 {
        let mut rng = Rng::new(0x5C4ED_000 + case);
        let shards = [2usize, 3, 4, 8][rng.next_below(4) as usize];
        let mut fifo = CpuPool::new(2, shards, CpuSched::WorkConserving);
        fifo.configure(shards, CpuSched::WorkConserving, WakePolicy::Fifo);
        let mut sa = CpuPool::new(2, shards, CpuSched::WorkConserving);
        sa.configure(shards, CpuSched::WorkConserving, WakePolicy::StallAware);
        let ctx = format!("case {case}: shards={shards}");
        for episode in 0..40 {
            // A random waiter set, mirrored into both pools; every shard
            // of the stall-aware pool carries the SAME risk score
            // (uniform ≠ zero — the clamp and the tie-break must not
            // reorder equals either).
            for s in 0..shards {
                match rng.next_below(3) {
                    0 => {
                        fifo.flush_denied(s);
                        sa.flush_denied(s);
                    }
                    1 => {
                        fifo.set_comp_waiter(s, true);
                        sa.set_comp_waiter(s, true);
                    }
                    _ => {}
                }
            }
            let risk = rng.next_below(RISK_MAX * 2);
            for s in 0..shards {
                sa.set_stall_risk(s, risk);
            }
            assert_eq!(
                fifo.take_wake_list(),
                sa.take_wake_list(),
                "{ctx} episode {episode}: uniform priorities must wake in FIFO order"
            );
            // End every waiting episode so ages stay uniform (zero) —
            // a shard that stops waiting resets its age by contract.
            for s in 0..shards {
                fifo.set_comp_waiter(s, false);
                fifo.clear_flush_waiter(s);
                sa.set_comp_waiter(s, false);
                sa.clear_flush_waiter(s);
            }
        }
        assert_eq!(
            sa.stats().stalls_avoided,
            0,
            "{ctx}: no promotion may fire under uniform priorities"
        );
    }
}

/// Bounded wait: a zero-risk shard that keeps waiting must reach the
/// head of its class within `RISK_MAX / AGE_STEP + O(shards)` wake
/// rounds, no matter what risks its competitors refresh to — the aging
/// term outgrows any clamped live score, and winners reset their age on
/// acquire while the victim's keeps compounding.
#[test]
fn aged_waiter_reaches_the_head_within_bounded_rounds() {
    for case in 0..50u64 {
        let mut rng = Rng::new(0xA6ED_000 + case);
        let shards = [2usize, 3, 4, 8][rng.next_below(4) as usize];
        let victim = shards - 1;
        let mut p = CpuPool::new(1, shards, CpuSched::WorkConserving);
        p.configure(shards, CpuSched::WorkConserving, WakePolicy::StallAware);
        assert!(p.acquire_compaction(0));
        let mut holder = 0usize;
        p.set_comp_waiter(victim, true);
        p.set_stall_risk(victim, 0);
        // Worst case: competitors rotate through the slot with max risk,
        // so the longest-unreset competitor holds eff 1024 + 256·(C-1);
        // the victim (largest shard index — loses every tie) overtakes
        // within shards + 4 rounds. The bound below is deliberately
        // looser so it pins the mechanism, not the exact constant.
        let bound = (RISK_MAX / AGE_STEP) as usize + 2 * shards + 4;
        let mut won = false;
        for _ in 0..bound {
            for s in 0..shards - 1 {
                if s != holder {
                    p.set_comp_waiter(s, true);
                }
                p.set_stall_risk(s, rng.next_below(RISK_MAX * 2));
            }
            p.release_compaction(holder);
            let list = p.take_wake_list();
            let head = list[0];
            if head == victim {
                won = true;
                break;
            }
            assert!(p.acquire_compaction(head), "the offered head must be admissible");
            holder = head;
        }
        assert!(
            won,
            "case {case}: shards={shards}: victim still starved after {bound} wake rounds"
        );
    }
}

// ---------------------------------------------------------------------
// End-to-end DES grid: shards × bg_threads × wake policy
// ---------------------------------------------------------------------

fn des_cfg(shards: usize, bg_threads: usize, wake: WakePolicy) -> Config {
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 6_000;
    cfg.workload.ops = 1_500;
    cfg.shards = shards;
    cfg.lsm.bg_threads = bg_threads;
    cfg.lsm.wake = wake;
    // Alternate the hold-cap policy across the grid so both arbitration
    // modes are exercised under both wake policies.
    cfg.lsm.cpu_sched =
        if (shards + bg_threads) % 2 == 0 { CpuSched::Fair } else { CpuSched::WorkConserving };
    // The substrate must host the shard count (same widening as Exp#7).
    let hdd_per_sst = cfg.hdd_zones_per_sst();
    cfg.geometry.ssd_zones = cfg.geometry.ssd_zones.max(2 * shards as u32);
    cfg.geometry.hdd_zones = cfg.geometry.hdd_zones.max(shards as u32 * hdd_per_sst);
    cfg
}

/// Work conservation, the global slot bound, and flush priority across
/// the full grid — the stall-aware policy reorders who is OFFERED a
/// freed slot, so none of the pool's hard ledgers may move.
#[test]
fn des_grid_conserves_work_under_both_wake_policies() {
    for &wake in &[WakePolicy::Fifo, WakePolicy::StallAware] {
        for &shards in &[1usize, 2, 4, 8] {
            for &bg in &[1usize, 2, 3, 12] {
                let cfg = des_cfg(shards, bg, wake);
                let clients = cfg.workload.clients;
                let mut se =
                    ShardedEngine::new(&cfg, |c| hhzs::exp::common::make_policy("HHZS", c));
                let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
                se.run_shared(&mut load, clients, None, false);
                se.flush_all();
                se.quiesce();
                let ctx = format!("shards={shards} bg_threads={bg} wake={}", wake.as_str());
                let m = se.merged_metrics();
                assert_eq!(
                    m.ops_done, cfg.workload.load_objects,
                    "{ctx}: lost ops (termination)"
                );
                let st = se.cpu_pool_stats();
                assert!(
                    st.high_water <= bg,
                    "{ctx}: {} slots in use at some event (global bound {bg})",
                    st.high_water
                );
                assert_eq!(st.in_use, 0, "{ctx}: slots leaked after quiesce");
                assert_eq!(st.acquires, st.releases, "{ctx}: acquire/release imbalance");
                assert_eq!(
                    st.acquires,
                    m.flushes + m.compactions,
                    "{ctx}: acquires must match started jobs"
                );
                assert!(m.flushes > 0, "{ctx}: workload must exercise flushes");
                assert_eq!(st.flush_priority_violations, 0, "{ctx}: flush priority");
                assert_eq!(
                    m.cpu_wait.n,
                    m.flushes + m.compactions,
                    "{ctx}: one cpu_wait sample per job"
                );
                if wake == WakePolicy::Fifo {
                    assert_eq!(st.stalls_avoided, 0, "{ctx}: FIFO cannot avoid stalls");
                    assert_eq!(m.stalls_avoided, 0, "{ctx}: FIFO engines saw a promotion");
                }
            }
        }
    }
}

/// With one shard there is never a competing waiter, so the stall-aware
/// policy must reproduce the FIFO timeline exactly — same virtual end
/// time, same job and op counts, same latency sums. (The committed
/// golden digests pin the FIFO side; this pins stall_aware onto it.)
#[test]
fn single_shard_stall_aware_timeline_is_identical_to_fifo() {
    let run = |wake: WakePolicy| {
        let mut cfg = des_cfg(1, 2, wake);
        cfg.workload.ops = 1_000;
        let clients = cfg.workload.clients;
        let mut se = ShardedEngine::new(&cfg, |c| hhzs::exp::common::make_policy("HHZS", c));
        let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
        se.run_shared(&mut load, clients, None, false);
        se.flush_all();
        let mut a = YcsbSource::new(Spec::from_config(&cfg, Kind::A), clients);
        se.run_shared(&mut a, clients, None, false);
        se.quiesce();
        let m = se.merged_metrics();
        (
            se.engines[0].now,
            m.ops_done,
            m.flushes,
            m.compactions,
            m.stall_ns,
            (m.read_lat.n, m.read_lat.sum),
            (m.write_lat.n, m.write_lat.sum),
            m.stalls_avoided,
        )
    };
    let fifo = run(WakePolicy::Fifo);
    let sa = run(WakePolicy::StallAware);
    assert_eq!(fifo, sa, "a single-shard stall-aware run diverged from FIFO");
    assert_eq!(sa.7, 0, "one shard can never be promoted past itself");
}

// ---------------------------------------------------------------------
// Traced replay: the checker re-derives the scheduler's decisions
// ---------------------------------------------------------------------

/// A contended 4-shard stall-aware run with the foreground pool on,
/// exported and replayed through `trace::check_export`: every WAKE round
/// must be flush-class-first, non-increasing in effective priority with
/// the shard tie-break, and consistent with the last traced RISK; every
/// FG grant must match a greedy earliest-slot replay (occupancy ≤
/// fg_threads). `bg_threads = 1` maximizes wake traffic.
#[test]
fn traced_stall_aware_run_passes_the_scheduler_replay() {
    let mut cfg = des_cfg(4, 1, WakePolicy::StallAware);
    cfg.lsm.fg_threads = 2;
    cfg.trace.enabled = true;
    cfg.trace.buffer_events = 2_000_000;
    let clients = cfg.workload.clients;
    let mut se = ShardedEngine::new(&cfg, |c| hhzs::exp::common::make_policy("HHZS", c));
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, None, false);
    se.flush_all();
    let mut a = YcsbSource::new(Spec::from_config(&cfg, Kind::A), clients);
    se.run_shared(&mut a, clients, None, false);
    se.quiesce();
    let export = se.export_trace_string();
    assert!(export.contains("RISK|"), "stall-aware run must trace risk pushes");
    assert!(export.contains("WAKE|"), "contended run must trace wake rounds");
    assert!(export.contains("FG|"), "fg_threads = 2 run must trace foreground grants");
    let report = hhzs::trace::check_export(&export).expect("export must parse");
    assert!(
        report.ok(),
        "scheduler replay found violations: {:?}",
        report.violations
    );
}

/// The foreground pool's saturation signal and its off-switch identity:
/// with `fg_threads` below the closed-loop client count per-op CPU must
/// queue (measured wait > 0), and with the pool off no sample may ever
/// be recorded (the seed's contention-free arithmetic).
#[test]
fn fg_pool_saturation_measures_wait_and_stays_silent_when_off() {
    let run = |fg: usize| {
        let mut cfg = des_cfg(2, 12, WakePolicy::StallAware);
        cfg.lsm.fg_threads = fg;
        let clients = cfg.workload.clients;
        let mut se = ShardedEngine::new(&cfg, |c| hhzs::exp::common::make_policy("HHZS", c));
        let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
        se.run_shared(&mut load, clients, None, false);
        se.quiesce();
        se.merged_metrics()
    };
    let off = run(0);
    assert_eq!(off.fg_cpu_wait.n, 0, "fg_threads = 0 must never record a wait sample");
    let on = run(2);
    assert!(
        on.fg_cpu_wait.n > 0 && on.fg_cpu_wait.sum > 0,
        "8 clients on 2 fg slots measured no foreground CPU wait (n={}, sum={})",
        on.fg_cpu_wait.n,
        on.fg_cpu_wait.sum
    );
}
