//! Equivalence pinning for the zero-materialization data path.
//!
//! The streaming compaction merge and the synthetic-payload wire format
//! must be *observably identical* to the seed engine's materialized
//! pipeline: same output SST bytes (ids, sizes, block handles, bloom
//! words), same DES timeline, same metrics. That is pinned two ways:
//!
//! * **entry level** — the reference pipeline (`merge_entries` +
//!   `split_outputs` + rebuild) survives as plain `lsm::compaction`
//!   library functions, and a randomized property (tombstones, shadowed
//!   versions, 0-length values, arbitrary block/SST sizes) keeps the
//!   streaming merge byte-identical to it;
//! * **end to end** — the engine itself runs ONLY the streaming path
//!   (`Engine::reference_datapath` held green from PR 2 to PR 4 and was
//!   retired so the merge-code surface is single again); the full §4.1
//!   protocol at shards ∈ {1, 4} is digested (virtual clock, metrics,
//!   complete SST layout, zenfs extent map, CPU-wait samples) and pinned
//!   against the committed golden file `tests/golden/datapath.golden`,
//!   plus a same-binary determinism double-run. Any intentional timeline
//!   change regenerates the golden: `UPDATE_GOLDEN=1 cargo test --test
//!   datapath`, then commit the file.

use std::sync::Arc;

use hhzs::config::Config;
use hhzs::coordinator::Engine;
use hhzs::lsm::compaction::{merge_entries, split_outputs, streaming_merge, OutputShape};
use hhzs::lsm::sst::{build_sst, SstBuilder, SstMeta};
use hhzs::lsm::{Entry, Payload, KEY_OVERHEAD};
use hhzs::shard::ShardedEngine;
use hhzs::sim::rng::Rng;
use hhzs::wire::{WireBuf, ENTRY_HEADER};
use hhzs::ycsb::{Kind, RoutedSource, Spec, YcsbSource};

// ---------------------------------------------------------------------
// Streaming merge ≡ reference pipeline (entry level)
// ---------------------------------------------------------------------

/// Random sorted streams sharing a key population: shadowed versions and
/// tombstones included. Seqs are globally unique (monotone counter).
fn random_streams(rng: &mut Rng) -> Vec<Vec<Entry>> {
    let n_streams = 1 + rng.next_below(5) as usize;
    let mut seq = 0u64;
    (0..n_streams)
        .map(|_| {
            let mut m: std::collections::BTreeMap<Vec<u8>, Entry> = Default::default();
            for _ in 0..rng.next_below(120) {
                let key = format!("user{:06}", rng.next_below(90)).into_bytes();
                seq += 1;
                let value = if rng.next_below(8) == 0 {
                    None // tombstone
                } else {
                    Some(Payload::fill(
                        rng.next_below(256) as u8,
                        rng.next_below(300) as usize, // includes 0-length
                    ))
                };
                m.insert(key.clone(), Entry { key: key.into(), seq, value });
            }
            m.into_values().collect()
        })
        .collect()
}

fn assert_same_sst(a: &SstMeta, da: &WireBuf, b: &SstMeta, db: &WireBuf, ctx: &str) {
    assert_eq!(a.id, b.id, "{ctx}: id");
    assert_eq!(a.level, b.level, "{ctx}: level");
    assert_eq!(a.smallest, b.smallest, "{ctx}: smallest");
    assert_eq!(a.largest, b.largest, "{ctx}: largest");
    assert_eq!(a.file_size, b.file_size, "{ctx}: file_size");
    assert_eq!(a.num_entries, b.num_entries, "{ctx}: num_entries");
    assert_eq!(a.blocks, b.blocks, "{ctx}: block handles");
    assert_eq!(a.index, b.index, "{ctx}: separator index");
    assert_eq!(a.bloom.words(), b.bloom.words(), "{ctx}: bloom words");
    assert_eq!(a.bloom.nbits(), b.bloom.nbits(), "{ctx}: bloom nbits");
    assert_eq!(a.bloom.k(), b.bloom.k(), "{ctx}: bloom k");
    assert_eq!(da, db, "{ctx}: serialized data");
}

#[test]
fn streaming_merge_outputs_are_byte_identical_to_reference() {
    for case in 0..40u64 {
        let mut rng = Rng::new(0xDA7A ^ case);
        let streams = random_streams(&mut rng);
        let in_block = 256 + rng.next_below(4096);
        let out_block = 256 + rng.next_below(4096);
        let sst_size = 512 + rng.next_below(16_384);
        let drop_tombstones = rng.next_below(2) == 1;

        // Build one input SST per non-empty stream.
        let mut inputs: Vec<(Arc<SstMeta>, WireBuf)> = Vec::new();
        for (i, entries) in streams.iter().enumerate() {
            if entries.is_empty() {
                continue;
            }
            let (meta, data) = build_sst(entries, 1 + i as u64, 1, in_block, 10, 0);
            inputs.push((meta, data));
        }
        let metas: Vec<Arc<SstMeta>> = inputs.iter().map(|(m, _)| m.clone()).collect();

        // Streaming path: block-cursor merge over the built SSTs.
        let shape =
            OutputShape { sst_size, block_size: out_block, bloom_bits_per_key: 10 };
        let builders = streaming_merge(&metas, Vec::new(), drop_tombstones, shape, |m, h| {
            let (_, data) =
                inputs.iter().find(|(im, _)| im.id == m.id).expect("fetch known SST");
            data.slice_to_buf(h.offset, h.len as u64)
        });
        let streaming: Vec<(SstMeta, WireBuf)> = builders
            .into_iter()
            .enumerate()
            .map(|(k, b)| b.finish(100 + k as u64, 2, 7))
            .collect();

        // Reference path: materialize, merge, split, rebuild.
        let merged = merge_entries(streams.clone(), drop_tombstones);
        let ranges = split_outputs(&merged, sst_size);
        let reference: Vec<(SstMeta, WireBuf)> = ranges
            .into_iter()
            .enumerate()
            .map(|(k, r)| {
                let mut b = SstBuilder::new(out_block, 10);
                for e in &merged[r] {
                    b.add(e);
                }
                b.finish(100 + k as u64, 2, 7)
            })
            .collect();

        assert_eq!(
            streaming.len(),
            reference.len(),
            "case {case}: output SST count (drop={drop_tombstones})"
        );
        for ((ma, da), (mb, db)) in streaming.iter().zip(reference.iter()) {
            assert_same_sst(ma, da, mb, db, &format!("case {case}"));
        }
    }
}

// ---------------------------------------------------------------------
// End-to-end digest: committed golden, shards ∈ {1, 4}
// ---------------------------------------------------------------------

fn proto_cfg(shards: usize) -> Config {
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 20_000;
    cfg.workload.ops = 5_000;
    cfg.shards = shards;
    cfg
}

/// Everything observable about a finished run, per shard: virtual clock,
/// metrics, the full SST layout (ids, sizes, block offsets), and the
/// zenfs file map (sizes, devices, extents).
fn digest(se: &ShardedEngine) -> Vec<String> {
    let mut out = Vec::new();
    for (s, e) in se.engines.iter().enumerate() {
        let m = &e.metrics;
        out.push(format!(
            "shard{s} now={} ops={} tput={:x} stalls={} flushes={} compactions={} \
             migr={} wal_over={} p999={} cpuw={}:{}",
            e.now,
            m.ops_done,
            m.ops_per_sec().to_bits(),
            m.stalls,
            m.flushes,
            m.compactions,
            m.migration_bytes,
            e.pool.wal_overflows,
            m.read_lat.quantile(0.999),
            m.cpu_wait.n,
            m.cpu_wait.sum,
        ));
        for lvl in 0..e.version.num_levels() {
            for sst in e.version.level(lvl) {
                let blocks: Vec<String> =
                    sst.blocks.iter().map(|h| format!("{}+{}", h.offset, h.len)).collect();
                out.push(format!(
                    "shard{s} L{lvl} sst={} size={} n={} blocks=[{}]",
                    sst.id,
                    sst.file_size,
                    sst.num_entries,
                    blocks.join(",")
                ));
            }
        }
        for f in e.fs.files() {
            let extents: Vec<String> = f
                .extents
                .iter()
                .map(|x| format!("{}:{}+{}", x.zone, x.offset, x.len))
                .collect();
            out.push(format!(
                "shard{s} file={} dev={} size={} extents=[{}]",
                f.id,
                f.dev.name(),
                f.size,
                extents.join(",")
            ));
        }
    }
    out
}

fn run_protocol(shards: usize) -> Vec<String> {
    run_protocol_cfg(proto_cfg(shards))
}

fn run_protocol_cfg(cfg: Config) -> Vec<String> {
    let clients = cfg.workload.clients;
    let mut se = ShardedEngine::new(&cfg, |c| hhzs::exp::common::make_policy("HHZS", c));
    let router = se.router;
    let load = Spec::from_config(&cfg, Kind::Load);
    se.run(
        |s| Box::new(RoutedSource::new(YcsbSource::new(load.clone(), clients), router, s)),
        clients,
        None,
        false,
    );
    se.flush_all();
    let a = Spec::from_config(&cfg, Kind::A);
    se.run(
        |s| Box::new(RoutedSource::new(YcsbSource::new(a.clone(), clients), router, s)),
        clients,
        None,
        false,
    );
    se.quiesce();
    digest(&se)
}

/// FNV-1a over the digest lines — compact enough to commit, sensitive to
/// any observable change (clock, metrics, SST layout, extents).
fn fnv1a(lines: &[String]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for l in lines {
        for b in l.as_bytes().iter().chain(b"\n") {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/datapath.golden")
}

#[test]
fn e2e_digest_matches_committed_golden() {
    let mut measured = String::from(
        "# Golden end-to-end digests of the streaming data path (FNV-1a over\n\
         # the full per-shard digest: clock, metrics, SST layout, extents,\n\
         # cpu_wait). Regenerate after an INTENDED timeline change with\n\
         #   UPDATE_GOLDEN=1 cargo test --test datapath\n\
         # and commit this file.\n",
    );
    for shards in [1usize, 4] {
        let digest = run_protocol(shards);
        // Same-binary determinism: the DES must reproduce itself exactly —
        // the property that makes a committed golden meaningful at all.
        let again = run_protocol(shards);
        assert_eq!(digest, again, "{shards} shard(s): nondeterministic digest");
        measured.push_str(&format!(
            "shards={} lines={} fnv1a={:016x}\n",
            shards,
            digest.len(),
            fnv1a(&digest)
        ));
    }
    let path = golden_path();
    let committed = std::fs::read_to_string(&path).unwrap_or_default();
    let update = std::env::var("UPDATE_GOLDEN").is_ok();
    if update || committed.contains("placeholder") || committed.is_empty() {
        // Self-priming (mirrors the BENCH_2.json placeholder flow: this
        // repo's build container cannot run cargo, so first execution —
        // locally or in CI — materializes the measured golden; committing
        // it arms the strict comparison below for every later run).
        std::fs::write(&path, &measured).expect("write golden digest file");
        eprintln!(
            "[datapath] wrote measured golden to {} — commit it to pin the timeline",
            path.display()
        );
        return;
    }
    let want: Vec<&str> =
        committed.lines().filter(|l| l.starts_with("shards=")).collect();
    let got: Vec<&str> = measured.lines().filter(|l| l.starts_with("shards=")).collect();
    assert_eq!(
        got, want,
        "end-to-end digest diverged from the committed golden; if the \
         timeline change is intended, regenerate with UPDATE_GOLDEN=1 \
         cargo test --test datapath and commit tests/golden/datapath.golden"
    );
}

// ---------------------------------------------------------------------
// Crash injection: armed-but-unfired is observationally free
// ---------------------------------------------------------------------

#[test]
fn armed_unfired_injector_is_observationally_free() {
    // An armed crash injector whose trigger never crosses only reads the
    // clock/op counter, so the full §4.1 protocol must stay bit-identical
    // to the untraced baseline — the same digest the committed golden
    // pins. Any divergence means arming alone perturbed the DES.
    for shards in [1usize, 4] {
        let baseline = run_protocol(shards);
        let mut cfg = proto_cfg(shards);
        cfg.crash.enabled = true;
        cfg.crash.point = "mid_flush".into();
        cfg.crash.at_op = u64::MAX; // armed, never crossing
        let armed = run_protocol_cfg(cfg);
        assert_eq!(
            baseline, armed,
            "{shards} shard(s): an armed-but-unfired crash injector perturbed the timeline"
        );
    }
}

// ---------------------------------------------------------------------
// Tracing: deterministic, observationally free, checker-clean
// ---------------------------------------------------------------------

/// [`run_protocol`] with the trace ring armed; returns the digest plus
/// the finished export JSON.
fn run_protocol_traced(shards: usize) -> (Vec<String>, String) {
    let mut cfg = proto_cfg(shards);
    cfg.trace.enabled = true;
    // Headroom over the default ring: a dropped event would make the
    // checker's sum invariants unverifiable and fail the test early.
    cfg.trace.buffer_events = 1 << 22;
    let clients = cfg.workload.clients;
    let mut se = ShardedEngine::new(&cfg, |c| hhzs::exp::common::make_policy("HHZS", c));
    let router = se.router;
    let load = Spec::from_config(&cfg, Kind::Load);
    se.run(
        |s| Box::new(RoutedSource::new(YcsbSource::new(load.clone(), clients), router, s)),
        clients,
        None,
        false,
    );
    se.flush_all();
    let a = Spec::from_config(&cfg, Kind::A);
    se.run(
        |s| Box::new(RoutedSource::new(YcsbSource::new(a.clone(), clients), router, s)),
        clients,
        None,
        false,
    );
    se.quiesce();
    let export = se.export_trace_string();
    (digest(&se), export)
}

#[test]
fn tracing_is_deterministic_and_observationally_free() {
    for shards in [1usize, 4] {
        // Tracing must not perturb the DES: the traced run's digest
        // (clock, metrics, SST layout, extents) is bit-identical to the
        // untraced run's — the golden-file guarantee holds with the ring
        // on, off, or absent from the config.
        let untraced = run_protocol(shards);
        let (digest1, export1) = run_protocol_traced(shards);
        assert_eq!(
            digest1, untraced,
            "{shards} shard(s): tracing changed the observable timeline"
        );
        // Same seed, same binary ⇒ byte-identical export JSON.
        let (_, export2) = run_protocol_traced(shards);
        assert_eq!(export1, export2, "{shards} shard(s): nondeterministic trace export");
        // And the export must replay clean through every DES invariant:
        // non-overlapping device busy intervals, CPU occupancy ≤
        // bg_threads, flush priority respected, span pairing, and the
        // per-phase wait/stall sums matching Metrics exactly.
        let report = hhzs::trace::check_export(&export1).expect("parse trace export");
        assert!(
            report.ok(),
            "{shards} shard(s): trace checker violations: {:#?}",
            report.violations
        );
        assert!(report.events > 0, "{shards} shard(s): empty trace");
        assert!(report.dev_intervals > 0, "{shards} shard(s): no device intervals");
        assert!(report.jobs_closed > 0, "{shards} shard(s): no job spans");
        assert!(
            report.snapshots >= shards,
            "{shards} shard(s): missing per-shard snapshots"
        );
    }
}

// ---------------------------------------------------------------------
// O(entries) memory: resident bytes do not scale with value_size
// ---------------------------------------------------------------------

#[test]
fn resident_bytes_track_entries_not_payload_bytes() {
    let run = |value_size: usize| {
        let mut cfg = Config::paper_scaled(2048);
        cfg.workload.load_objects = 20_000;
        cfg.workload.value_size = value_size;
        // Paging off: this pins the value-synthesis claim alone. With
        // demand paging on, dehydration drives both sides toward zero
        // and the ratio stops measuring anything.
        cfg.residency.paging = false;
        let mut e = Engine::new(
            cfg.clone(),
            Box::new(hhzs::policy::HhzsPolicy::new(cfg.lsm.num_levels)),
        );
        let clients = cfg.workload.clients;
        let mut src = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
        e.run(&mut src, clients, None, false);
        e.quiesce();
        (e.fs.phys_bytes(), e.fs.ssd.written_bytes() + e.fs.hdd.written_bytes())
    };
    let (phys_small, logical_small) = run(100);
    let (phys_big, logical_big) = run(2000);
    // Logical (accounted) bytes scale with the payload...
    assert!(
        logical_big > logical_small * 5,
        "logical bytes must scale with value_size: {logical_small} -> {logical_big}"
    );
    // ...resident bytes do not (headers + keys + index/bloom only).
    assert!(
        phys_big < phys_small * 3 / 2,
        "resident bytes must not scale with value_size: {phys_small} -> {phys_big}"
    );
}

// ---------------------------------------------------------------------
// O(unique-key-bytes) memory: interned arena + prefix-compressed blocks
// ---------------------------------------------------------------------

/// One full protocol run at `key_size`; returns the per-SST-file resident
/// accounting needed to isolate *key* bytes: (resident key bytes summed
/// over live SSTs, total SST entries, post-sweep arena stats, live SSTs).
fn key_memory_run(key_size: usize) -> (u64, u64, hhzs::lsm::KeyArenaStats, u64) {
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 20_000;
    cfg.workload.ops = 5_000;
    cfg.workload.key_size = key_size;
    cfg.workload.value_size = 100;
    // Paging off: the interning/prefix-compression claims are about the
    // hydrated physical form; dehydrated key descriptors would hide a
    // compression regression entirely.
    cfg.residency.paging = false;
    let mut e = Engine::new(
        cfg.clone(),
        Box::new(hhzs::policy::HhzsPolicy::new(cfg.lsm.num_levels)),
    );
    let clients = cfg.workload.clients;
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    e.run(&mut load, clients, None, false);
    e.flush_all();
    // Update-heavy phase: the same keys get re-written, so without
    // interning/compression resident key bytes would scale with the
    // duplication factor (MemTable + WAL + every L0 copy).
    let mut a = YcsbSource::new(Spec::from_config(&cfg, Kind::A), clients);
    e.run(&mut a, clients, None, false);
    e.flush_all();
    e.quiesce();
    let metas: Vec<Arc<SstMeta>> = e.version.all_ssts().cloned().collect();
    let mut key_bytes = 0u64;
    let mut entries = 0u64;
    for m in &metas {
        let data = e.fs.read_file_untimed(m.id, 0, m.file_size).expect("live SST");
        // Resident bytes of this file minus entry headers = the resident
        // KEY bytes (values are synthetic and the index/bloom reservation
        // is a weightless pad run; suffixes + restart keys remain).
        key_bytes += data.phys_len() as u64 - m.num_entries * ENTRY_HEADER as u64;
        entries += m.num_entries;
    }
    e.key_arena().sweep();
    (key_bytes, entries, e.key_arena().stats(), metas.len() as u64)
}

#[test]
fn resident_key_bytes_scale_with_unique_key_bytes_not_dup_factor() {
    let (key24, n24, s24, _) = key_memory_run(24);
    let (key64, n64, _, _) = key_memory_run(64);
    let (key128, n128, s128, ssts128) = key_memory_run(128);
    // The Vec<u8>-everywhere baseline, measured in the SAME runs: every
    // block entry storing its full key.
    let full64 = n64 * 64;
    let full128 = n128 * 128;
    // Acceptance: at key_len 128 the per-entry resident key cost is at
    // least 2x below the full-key baseline (suffix + amortized restart
    // keys only).
    assert!(
        key128 * 2 <= full128,
        "prefix compression must at least halve resident key bytes at k=128: \
         resident {key128} vs full {full128} over {n128} entries"
    );
    assert!(
        key64 * 2 <= full64,
        "prefix compression must at least halve resident key bytes at k=64: \
         resident {key64} vs full {full64} over {n64} entries"
    );
    // Flatness: growing the key 24 -> 128 (5.33x logical) must grow the
    // resident key bytes far slower — the zero-padded middle is absorbed
    // by shared prefixes, so only restart keys grow linearly.
    let per24 = key24 as f64 / n24.max(1) as f64;
    let per128 = key128 as f64 / n128.max(1) as f64;
    let ratio_phys = per128 / per24.max(1e-9);
    let ratio_logical = 128.0 / 24.0;
    assert!(
        ratio_phys < ratio_logical * 0.75,
        "resident key bytes track suffixes, not key_len: per-entry \
         {per24:.1} -> {per128:.1} ({ratio_phys:.2}x) vs logical {ratio_logical:.2}x"
    );
    // The arena side of the claim: YCSB-A re-writes hot keys, and every
    // re-write must dedup against the interned copy...
    assert!(s24.hits > 0 && s128.hits > 0, "updates must hit the intern table");
    // ...and epoch reclamation (Version GC -> retire -> sweep) keeps the
    // LIVE arena at O(live references): after the final flush the only
    // holders are the SST bounds (2 per SST), not the 20k-key history.
    assert!(
        s128.unique <= 2 * ssts128 + 64,
        "arena must reclaim dead keys: {} live uniques for {} SSTs",
        s128.unique,
        ssts128
    );
    assert!(s128.reclaimed > 0, "sweeps must have reclaimed flushed keys");
    assert_eq!(
        s128.bytes,
        s128.unique * (128 + KEY_OVERHEAD as u64),
        "gauge counts unique key bytes + overhead exactly"
    );
}

// ---------------------------------------------------------------------
// Demand-paged residency: observationally free, exact gauge partition
// ---------------------------------------------------------------------

#[test]
fn demand_paging_is_observationally_free() {
    // Dehydrating zone-resident blocks to descriptors and rehydrating on
    // demand must not move a single observable: the full §4.1 protocol's
    // digest (virtual clock, metrics, SST layout, extents, cpu_wait) is
    // bit-identical with paging on (the default the committed golden
    // pins) and off.
    for shards in [1usize, 4] {
        let paged = run_protocol(shards);
        let mut cfg = proto_cfg(shards);
        cfg.residency.paging = false;
        let unpaged = run_protocol_cfg(cfg);
        assert_eq!(
            paged, unpaged,
            "{shards} shard(s): demand paging changed the observable timeline"
        );
    }
}

#[test]
fn residency_gauges_partition_resident_bytes_exactly() {
    // Conservation at every phase boundary, per shard:
    //   ssd + hdd + wal + cache == fs.phys_bytes() + block_cache.phys_bytes()
    // The identity holds by construction today; this pins it against a
    // future gauge source that forgets to join the partition.
    fn check(se: &mut ShardedEngine, paging: bool, shards: usize, phase: &str) {
        for (s, e) in se.engines.iter_mut().enumerate() {
            e.stamp_residency_gauges();
            let m = &e.metrics;
            let sum = m.resident_ssd_bytes
                + m.resident_hdd_bytes
                + m.resident_wal_bytes
                + m.resident_cache_bytes;
            let want = e.fs.phys_bytes() + e.cache.phys_bytes();
            assert_eq!(
                sum, want,
                "paging={paging} shards={shards} shard {s} at {phase}: \
                 resident gauges do not partition the physical bytes"
            );
        }
    }
    for paging in [true, false] {
        for shards in [1usize, 4] {
            let mut cfg = proto_cfg(shards);
            cfg.workload.load_objects = 8_000;
            cfg.workload.ops = 2_000;
            cfg.residency.paging = paging;
            let clients = cfg.workload.clients;
            let mut se =
                ShardedEngine::new(&cfg, |c| hhzs::exp::common::make_policy("HHZS", c));
            let router = se.router;
            let load = Spec::from_config(&cfg, Kind::Load);
            se.run(
                |s| {
                    Box::new(RoutedSource::new(
                        YcsbSource::new(load.clone(), clients),
                        router,
                        s,
                    ))
                },
                clients,
                None,
                false,
            );
            check(&mut se, paging, shards, "load");
            se.flush_all();
            check(&mut se, paging, shards, "reopen");
            let a = Spec::from_config(&cfg, Kind::A);
            se.run(
                |s| {
                    Box::new(RoutedSource::new(YcsbSource::new(a.clone(), clients), router, s))
                },
                clients,
                None,
                false,
            );
            check(&mut se, paging, shards, "ycsb-a");
            se.quiesce();
            check(&mut se, paging, shards, "quiesce");
        }
    }
}

// ---------------------------------------------------------------------
// Dehydrated decode ≡ hydrated decode across arbitrary cuts (randomized)
// ---------------------------------------------------------------------

#[test]
fn dehydrated_buffers_decode_identically_across_arbitrary_cuts() {
    for case in 0..20u64 {
        let mut rng = Rng::new(0xD1_11D ^ case);
        // YCSB-generated keys (synthesizable — they dehydrate) mixed with
        // opaque keys (they must stay resident untouched), plus the usual
        // value shapes: tombstones, 0-length, random fills.
        let mut keys: std::collections::BTreeSet<Vec<u8>> = Default::default();
        for _ in 0..30 + rng.next_below(200) {
            let k = if rng.next_below(5) == 0 {
                format!("opaque-{:05}", rng.next_below(10_000)).into_bytes()
            } else {
                hhzs::ycsb::key_for(rng.next_below(1_000_000), 24)
            };
            keys.insert(k);
        }
        let entries: Vec<Entry> = keys
            .into_iter()
            .enumerate()
            .map(|(i, k)| Entry {
                key: k.into(),
                seq: i as u64,
                value: match i % 5 {
                    0 => None,
                    1 => Some(Payload::fill(i as u8, 0)),
                    _ => Some(Payload::fill(i as u8, rng.next_below(300) as usize)),
                },
            })
            .collect();
        let block_size = 256 + rng.next_below(2048);
        let (meta, data) = build_sst(&entries, 1, 0, block_size, 10, 0);
        let body_len = meta.blocks.last().map(|h| h.offset + h.len as u64).unwrap_or(0);
        let body = data.slice_to_buf(0, body_len);

        let paged = body.dehydrate_copy().expect("YCSB-keyed blocks must elide heads");
        assert_eq!(paged.len(), body.len(), "case {case}: logical length");
        assert!(
            paged.phys_len() < body.phys_len(),
            "case {case}: dehydration must shrink resident bytes"
        );
        // Decode equivalence on the dehydrated form itself.
        let got: Vec<Entry> = paged.entries().map(|e| e.to_entry()).collect();
        assert_eq!(got, entries, "case {case}: dehydrated decode");
        // Hydration restores the exact physical bytes.
        let mut back = paged.clone();
        back.hydrate();
        assert!(back.is_hydrated(), "case {case}: hydrate left heads elided");
        assert_eq!(
            back.phys_bytes(),
            body.phys_bytes(),
            "case {case}: hydrate is not bit-identical"
        );
        // Arbitrary cuts — uniform over the body, so plenty land mid
        // KeySynthRun (a head spans ENTRY_HEADER + klen = 38 bytes at
        // klen 24): slice, re-join, and both the dehydrated decode and a
        // post-rejoin hydration must still be exact.
        for _ in 0..16 {
            let cut = rng.next_below(body_len + 1);
            let mut joined = paged.slice_to_buf(0, cut);
            joined.append_buf(&paged.slice_to_buf(cut, body_len - cut));
            assert_eq!(joined.len(), paged.len(), "case {case}: cut {cut} length");
            let rejoined: Vec<Entry> = joined.entries().map(|e| e.to_entry()).collect();
            assert_eq!(rejoined, entries, "case {case}: lossy at cut {cut}");
            let mut h = joined.clone();
            h.hydrate();
            assert!(h.is_hydrated(), "case {case}: cut {cut} hydrate incomplete");
            let hydrated: Vec<Entry> = h.entries().map(|e| e.to_entry()).collect();
            assert_eq!(hydrated, entries, "case {case}: cut {cut} hydrated decode");
        }
    }
}

// ---------------------------------------------------------------------
// Prefix-compressed block decode ≡ uncompressed decode (randomized)
// ---------------------------------------------------------------------

#[test]
fn prefix_compressed_blocks_decode_identically_to_uncompressed() {
    for case in 0..25u64 {
        let mut rng = Rng::new(0x9EF1_C0DE ^ case);
        // Sorted unique keys mixing shapes: long zero-padded ones whose
        // shared prefixes clear MIN_SHARED_PREFIX (so blocks really carry
        // PrefixRuns), short prefix-ish ones stored whole, and unrelated
        // ones (so `shared` ranges over 0..=klen).
        let mut keys: std::collections::BTreeSet<Vec<u8>> = Default::default();
        for _ in 0..20 + rng.next_below(250) {
            let k: Vec<u8> = match rng.next_below(4) {
                0 => format!("user{:060}", rng.next_below(100_000)).into_bytes(),
                1 => format!("user{:04}", rng.next_below(500)).into_bytes(),
                2 => format!("z{}", rng.next_below(30)).into_bytes(),
                _ => (0..1 + rng.next_below(40))
                    .map(|_| b'a' + rng.next_below(5) as u8)
                    .collect(),
            };
            keys.insert(k);
        }
        let entries: Vec<Entry> = keys
            .into_iter()
            .enumerate()
            .map(|(i, k)| Entry {
                key: k.into(),
                seq: i as u64,
                value: match i % 5 {
                    0 => None,
                    1 => Some(Payload::fill(i as u8, 0)),
                    _ => Some(Payload::fill(i as u8, rng.next_below(200) as usize)),
                },
            })
            .collect();
        let block_size = 128 + rng.next_below(2048);
        let (meta, data) = build_sst(&entries, 1, 0, block_size, 10, 0);

        // Per block: the prefix-compressed decode equals the decode of a
        // plain (full-key) re-encoding, entry for entry, at identical
        // logical size.
        let mut at = 0usize;
        for h in &meta.blocks {
            let block = data.slice_to_buf(h.offset, h.len as u64);
            let got: Vec<Entry> = block.entries().map(|e| e.to_entry()).collect();
            let n = got.len();
            assert_eq!(&got[..], &entries[at..at + n], "case {case}: block {}", h.offset);
            let mut plain = WireBuf::new();
            for e in &got {
                plain.push_entry(&e.key, e.seq, e.value);
            }
            assert_eq!(plain.len(), h.len as u64, "case {case}: logical size must match");
            let replain: Vec<Entry> = plain.entries().map(|e| e.to_entry()).collect();
            assert_eq!(got, replain, "case {case}: compressed != uncompressed decode");
            assert!(block.phys_len() <= plain.phys_len(), "case {case}: compression grew");
            at += n;
        }
        assert_eq!(at, entries.len(), "case {case}: every entry decoded exactly once");

        // Zone-boundary style: cut the data region anywhere, re-join, and
        // the whole body must still decode to every entry.
        let body_len = meta.blocks.last().map(|h| h.offset + h.len as u64).unwrap_or(0);
        let body = data.slice_to_buf(0, body_len);
        let whole: Vec<Entry> = body.entries().map(|e| e.to_entry()).collect();
        assert_eq!(whole, entries, "case {case}: contiguous body decode");
        for _ in 0..16 {
            let cut = rng.next_below(body_len + 1);
            let mut joined = body.slice_to_buf(0, cut);
            joined.append_buf(&body.slice_to_buf(cut, body_len - cut));
            let rejoined: Vec<Entry> = joined.entries().map(|e| e.to_entry()).collect();
            assert_eq!(rejoined, entries, "case {case}: lossy at cut {cut}");
        }
    }
}
