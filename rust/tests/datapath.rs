//! Equivalence pinning for the zero-materialization data path.
//!
//! The streaming compaction merge and the synthetic-payload wire format
//! must be *observably identical* to the seed engine's materialized
//! pipeline: same output SST bytes (ids, sizes, block handles, bloom
//! words), same DES timeline, same metrics. That is pinned two ways:
//!
//! * **entry level** — the reference pipeline (`merge_entries` +
//!   `split_outputs` + rebuild) survives as plain `lsm::compaction`
//!   library functions, and a randomized property (tombstones, shadowed
//!   versions, 0-length values, arbitrary block/SST sizes) keeps the
//!   streaming merge byte-identical to it;
//! * **end to end** — the engine itself runs ONLY the streaming path
//!   (`Engine::reference_datapath` held green from PR 2 to PR 4 and was
//!   retired so the merge-code surface is single again); the full §4.1
//!   protocol at shards ∈ {1, 4} is digested (virtual clock, metrics,
//!   complete SST layout, zenfs extent map, CPU-wait samples) and pinned
//!   against the committed golden file `tests/golden/datapath.golden`,
//!   plus a same-binary determinism double-run. Any intentional timeline
//!   change regenerates the golden: `UPDATE_GOLDEN=1 cargo test --test
//!   datapath`, then commit the file.

use std::sync::Arc;

use hhzs::config::Config;
use hhzs::coordinator::Engine;
use hhzs::lsm::compaction::{merge_entries, split_outputs, streaming_merge, OutputShape};
use hhzs::lsm::sst::{build_sst, SstBuilder, SstMeta};
use hhzs::lsm::{Entry, Payload};
use hhzs::shard::ShardedEngine;
use hhzs::sim::rng::Rng;
use hhzs::wire::WireBuf;
use hhzs::ycsb::{Kind, RoutedSource, Spec, YcsbSource};

// ---------------------------------------------------------------------
// Streaming merge ≡ reference pipeline (entry level)
// ---------------------------------------------------------------------

/// Random sorted streams sharing a key population: shadowed versions and
/// tombstones included. Seqs are globally unique (monotone counter).
fn random_streams(rng: &mut Rng) -> Vec<Vec<Entry>> {
    let n_streams = 1 + rng.next_below(5) as usize;
    let mut seq = 0u64;
    (0..n_streams)
        .map(|_| {
            let mut m: std::collections::BTreeMap<Vec<u8>, Entry> = Default::default();
            for _ in 0..rng.next_below(120) {
                let key = format!("user{:06}", rng.next_below(90)).into_bytes();
                seq += 1;
                let value = if rng.next_below(8) == 0 {
                    None // tombstone
                } else {
                    Some(Payload::fill(
                        rng.next_below(256) as u8,
                        rng.next_below(300) as usize, // includes 0-length
                    ))
                };
                m.insert(key.clone(), Entry { key, seq, value });
            }
            m.into_values().collect()
        })
        .collect()
}

fn assert_same_sst(a: &SstMeta, da: &WireBuf, b: &SstMeta, db: &WireBuf, ctx: &str) {
    assert_eq!(a.id, b.id, "{ctx}: id");
    assert_eq!(a.level, b.level, "{ctx}: level");
    assert_eq!(a.smallest, b.smallest, "{ctx}: smallest");
    assert_eq!(a.largest, b.largest, "{ctx}: largest");
    assert_eq!(a.file_size, b.file_size, "{ctx}: file_size");
    assert_eq!(a.num_entries, b.num_entries, "{ctx}: num_entries");
    assert_eq!(a.blocks, b.blocks, "{ctx}: block handles");
    assert_eq!(a.bloom.words(), b.bloom.words(), "{ctx}: bloom words");
    assert_eq!(a.bloom.nbits(), b.bloom.nbits(), "{ctx}: bloom nbits");
    assert_eq!(a.bloom.k(), b.bloom.k(), "{ctx}: bloom k");
    assert_eq!(da, db, "{ctx}: serialized data");
}

#[test]
fn streaming_merge_outputs_are_byte_identical_to_reference() {
    for case in 0..40u64 {
        let mut rng = Rng::new(0xDA7A ^ case);
        let streams = random_streams(&mut rng);
        let in_block = 256 + rng.next_below(4096);
        let out_block = 256 + rng.next_below(4096);
        let sst_size = 512 + rng.next_below(16_384);
        let drop_tombstones = rng.next_below(2) == 1;

        // Build one input SST per non-empty stream.
        let mut inputs: Vec<(Arc<SstMeta>, WireBuf)> = Vec::new();
        for (i, entries) in streams.iter().enumerate() {
            if entries.is_empty() {
                continue;
            }
            let (meta, data) = build_sst(entries, 1 + i as u64, 1, in_block, 10, 0);
            inputs.push((meta, data));
        }
        let metas: Vec<Arc<SstMeta>> = inputs.iter().map(|(m, _)| m.clone()).collect();

        // Streaming path: block-cursor merge over the built SSTs.
        let shape =
            OutputShape { sst_size, block_size: out_block, bloom_bits_per_key: 10 };
        let builders = streaming_merge(&metas, Vec::new(), drop_tombstones, shape, |m, h| {
            let (_, data) =
                inputs.iter().find(|(im, _)| im.id == m.id).expect("fetch known SST");
            data.slice_to_buf(h.offset, h.len as u64)
        });
        let streaming: Vec<(SstMeta, WireBuf)> = builders
            .into_iter()
            .enumerate()
            .map(|(k, b)| b.finish(100 + k as u64, 2, 7))
            .collect();

        // Reference path: materialize, merge, split, rebuild.
        let merged = merge_entries(streams.clone(), drop_tombstones);
        let ranges = split_outputs(&merged, sst_size);
        let reference: Vec<(SstMeta, WireBuf)> = ranges
            .into_iter()
            .enumerate()
            .map(|(k, r)| {
                let mut b = SstBuilder::new(out_block, 10);
                for e in &merged[r] {
                    b.add(e);
                }
                b.finish(100 + k as u64, 2, 7)
            })
            .collect();

        assert_eq!(
            streaming.len(),
            reference.len(),
            "case {case}: output SST count (drop={drop_tombstones})"
        );
        for ((ma, da), (mb, db)) in streaming.iter().zip(reference.iter()) {
            assert_same_sst(ma, da, mb, db, &format!("case {case}"));
        }
    }
}

// ---------------------------------------------------------------------
// End-to-end digest: committed golden, shards ∈ {1, 4}
// ---------------------------------------------------------------------

fn proto_cfg(shards: usize) -> Config {
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 20_000;
    cfg.workload.ops = 5_000;
    cfg.shards = shards;
    cfg
}

/// Everything observable about a finished run, per shard: virtual clock,
/// metrics, the full SST layout (ids, sizes, block offsets), and the
/// zenfs file map (sizes, devices, extents).
fn digest(se: &ShardedEngine) -> Vec<String> {
    let mut out = Vec::new();
    for (s, e) in se.engines.iter().enumerate() {
        let m = &e.metrics;
        out.push(format!(
            "shard{s} now={} ops={} tput={:x} stalls={} flushes={} compactions={} \
             migr={} wal_over={} p999={} cpuw={}:{}",
            e.now,
            m.ops_done,
            m.ops_per_sec().to_bits(),
            m.stalls,
            m.flushes,
            m.compactions,
            m.migration_bytes,
            e.pool.wal_overflows,
            m.read_lat.quantile(0.999),
            m.cpu_wait.n,
            m.cpu_wait.sum,
        ));
        for lvl in 0..e.version.num_levels() {
            for sst in e.version.level(lvl) {
                let blocks: Vec<String> =
                    sst.blocks.iter().map(|h| format!("{}+{}", h.offset, h.len)).collect();
                out.push(format!(
                    "shard{s} L{lvl} sst={} size={} n={} blocks=[{}]",
                    sst.id,
                    sst.file_size,
                    sst.num_entries,
                    blocks.join(",")
                ));
            }
        }
        for f in e.fs.files() {
            let extents: Vec<String> = f
                .extents
                .iter()
                .map(|x| format!("{}:{}+{}", x.zone, x.offset, x.len))
                .collect();
            out.push(format!(
                "shard{s} file={} dev={} size={} extents=[{}]",
                f.id,
                f.dev.name(),
                f.size,
                extents.join(",")
            ));
        }
    }
    out
}

fn run_protocol(shards: usize) -> Vec<String> {
    let cfg = proto_cfg(shards);
    let clients = cfg.workload.clients;
    let mut se = ShardedEngine::new(&cfg, |c| hhzs::exp::common::make_policy("HHZS", c));
    let router = se.router;
    let load = Spec::from_config(&cfg, Kind::Load);
    se.run(
        |s| Box::new(RoutedSource::new(YcsbSource::new(load.clone(), clients), router, s)),
        clients,
        None,
        false,
    );
    se.flush_all();
    let a = Spec::from_config(&cfg, Kind::A);
    se.run(
        |s| Box::new(RoutedSource::new(YcsbSource::new(a.clone(), clients), router, s)),
        clients,
        None,
        false,
    );
    se.quiesce();
    digest(&se)
}

/// FNV-1a over the digest lines — compact enough to commit, sensitive to
/// any observable change (clock, metrics, SST layout, extents).
fn fnv1a(lines: &[String]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for l in lines {
        for b in l.as_bytes().iter().chain(b"\n") {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/datapath.golden")
}

#[test]
fn e2e_digest_matches_committed_golden() {
    let mut measured = String::from(
        "# Golden end-to-end digests of the streaming data path (FNV-1a over\n\
         # the full per-shard digest: clock, metrics, SST layout, extents,\n\
         # cpu_wait). Regenerate after an INTENDED timeline change with\n\
         #   UPDATE_GOLDEN=1 cargo test --test datapath\n\
         # and commit this file.\n",
    );
    for shards in [1usize, 4] {
        let digest = run_protocol(shards);
        // Same-binary determinism: the DES must reproduce itself exactly —
        // the property that makes a committed golden meaningful at all.
        let again = run_protocol(shards);
        assert_eq!(digest, again, "{shards} shard(s): nondeterministic digest");
        measured.push_str(&format!(
            "shards={} lines={} fnv1a={:016x}\n",
            shards,
            digest.len(),
            fnv1a(&digest)
        ));
    }
    let path = golden_path();
    let committed = std::fs::read_to_string(&path).unwrap_or_default();
    let update = std::env::var("UPDATE_GOLDEN").is_ok();
    if update || committed.contains("placeholder") || committed.is_empty() {
        // Self-priming (mirrors the BENCH_2.json placeholder flow: this
        // repo's build container cannot run cargo, so first execution —
        // locally or in CI — materializes the measured golden; committing
        // it arms the strict comparison below for every later run).
        std::fs::write(&path, &measured).expect("write golden digest file");
        eprintln!(
            "[datapath] wrote measured golden to {} — commit it to pin the timeline",
            path.display()
        );
        return;
    }
    let want: Vec<&str> =
        committed.lines().filter(|l| l.starts_with("shards=")).collect();
    let got: Vec<&str> = measured.lines().filter(|l| l.starts_with("shards=")).collect();
    assert_eq!(
        got, want,
        "end-to-end digest diverged from the committed golden; if the \
         timeline change is intended, regenerate with UPDATE_GOLDEN=1 \
         cargo test --test datapath and commit tests/golden/datapath.golden"
    );
}

// ---------------------------------------------------------------------
// O(entries) memory: resident bytes do not scale with value_size
// ---------------------------------------------------------------------

#[test]
fn resident_bytes_track_entries_not_payload_bytes() {
    let run = |value_size: usize| {
        let mut cfg = Config::paper_scaled(2048);
        cfg.workload.load_objects = 20_000;
        cfg.workload.value_size = value_size;
        let mut e = Engine::new(
            cfg.clone(),
            Box::new(hhzs::policy::HhzsPolicy::new(cfg.lsm.num_levels)),
        );
        let clients = cfg.workload.clients;
        let mut src = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
        e.run(&mut src, clients, None, false);
        e.quiesce();
        (e.fs.phys_bytes(), e.fs.ssd.written_bytes() + e.fs.hdd.written_bytes())
    };
    let (phys_small, logical_small) = run(100);
    let (phys_big, logical_big) = run(2000);
    // Logical (accounted) bytes scale with the payload...
    assert!(
        logical_big > logical_small * 5,
        "logical bytes must scale with value_size: {logical_small} -> {logical_big}"
    );
    // ...resident bytes do not (headers + keys + index/bloom only).
    assert!(
        phys_big < phys_small * 3 / 2,
        "resident bytes must not scale with value_size: {phys_small} -> {phys_big}"
    );
}
