//! Cross-module integration tests: the paper's qualitative claims, checked
//! end-to-end on small-but-shape-preserving configurations.

use hhzs::config::Config;
use hhzs::exp::common::{load_and_run, load_fresh, make_policy, run_phase};
use hhzs::metrics::WriteCategory;
use hhzs::ycsb::Kind;
use hhzs::zone::Dev;

fn small_cfg() -> Config {
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 60_000; // ~60 MiB, ~6x the 10.5 MiB SSD
    cfg.workload.ops = 15_000;
    cfg
}

/// Shape-preserving scale for scheme-vs-scheme comparisons. At 1/2048 the
/// geometry degenerates (SSTs of ~500 KiB, 10 MiB SSD) and relative scheme
/// rankings get noisy; 1/1024 is the smallest scale where the paper's
/// rankings are stable (it is also the `Profile::Quick` experiment scale).
fn compare_cfg() -> Config {
    let mut cfg = Config::paper_scaled(1024);
    cfg.workload.load_objects = 120_000; // ~120 MiB, ~5.7x the SSD
    cfg.workload.ops = 30_000;
    cfg
}

#[test]
fn o1_actual_sizes_exceed_targets_during_load() {
    // O1: the actual size of low levels can significantly exceed the
    // target size under write-intensive loads.
    let cfg = small_cfg();
    let (_, m) = load_fresh(&cfg, "B4", None, true);
    assert!(!m.level_samples.is_empty(), "sampler must fire during load");
    let max_l0 = m.level_samples.iter().map(|s| s.level_bytes[0]).max().unwrap();
    assert!(
        max_l0 > cfg.lsm.l0_target,
        "L0 should overshoot its target during load: max {} vs target {}",
        max_l0,
        cfg.lsm.l0_target
    );
}

#[test]
fn o2_b4_displaces_low_levels() {
    // O2: with h too large (B4), L3 SSTs crowd out L0/L1 writes from the
    // SSD; B3 keeps a higher share of low-level writes on the SSD than B4.
    let cfg = small_cfg();
    let (_, m3) = load_fresh(&cfg, "B3", None, false);
    let (_, m4) = load_fresh(&cfg, "B4", None, false);
    let low = |m: &hhzs::metrics::Metrics| {
        (m.ssd_write_fraction(Some(WriteCategory::Sst(0)))
            + m.ssd_write_fraction(Some(WriteCategory::Sst(1))))
            / 2.0
    };
    assert!(
        low(&m3) > low(&m4),
        "B3 should keep more L0/L1 writes on the SSD than B4 ({:.2} vs {:.2})",
        low(&m3),
        low(&m4)
    );
}

#[test]
fn o3_throttling_does_not_fix_overshoot() {
    let cfg = small_cfg();
    let (_, unthrottled) = load_fresh(&cfg, "B4", None, true);
    let base = unthrottled.ops_per_sec();
    let (_, throttled) = load_fresh(&cfg, "B4", Some(base * 0.5), true);
    let max_l0 = throttled.level_samples.iter().map(|s| s.level_bytes[0]).max().unwrap();
    // Throttling reduces pressure but the overshoot phenomenon persists.
    assert!(
        max_l0 > cfg.lsm.l0_target,
        "L0 still overshoots target under throttling: {max_l0}"
    );
    assert!(throttled.ops_per_sec() <= base * 0.55, "throttle respected");
}

#[test]
fn o4_reads_bottlenecked_by_hdd_for_basics() {
    // O4: most read traffic of the basic schemes lands on the HDD.
    let cfg = small_cfg();
    let (_, m) = load_and_run(&cfg, "B3", Kind::C, 0.9);
    assert!(
        m.hdd_read_fraction() > 0.5,
        "basic schemes should serve most reads from the HDD ({:.2})",
        m.hdd_read_fraction()
    );
}

#[test]
fn hhzs_beats_b3_on_mixed_skewed_workload() {
    // The headline: HHZS > B3 (and AUTO) under a skewed mixed workload.
    let cfg = compare_cfg();
    let (_, b3) = load_and_run(&cfg, "B3", Kind::Mixed { read_pct: 50 }, 1.1);
    let (_, auto_) = load_and_run(&cfg, "AUTO", Kind::Mixed { read_pct: 50 }, 1.1);
    let (_, hhzs) = load_and_run(&cfg, "HHZS", Kind::Mixed { read_pct: 50 }, 1.1);
    assert!(
        hhzs.ops_per_sec() > b3.ops_per_sec(),
        "HHZS ({:.0}) must beat B3 ({:.0})",
        hhzs.ops_per_sec(),
        b3.ops_per_sec()
    );
    assert!(
        hhzs.ops_per_sec() > auto_.ops_per_sec(),
        "HHZS ({:.0}) must beat AUTO ({:.0})",
        hhzs.ops_per_sec(),
        auto_.ops_per_sec()
    );
}

#[test]
fn migration_reduces_hdd_read_share() {
    // Exp#2 mechanism: P+M serves fewer reads from the HDD than P.
    let cfg = small_cfg();
    let (_, p) = load_and_run(&cfg, "P", Kind::Mixed { read_pct: 50 }, 0.9);
    let (_, pm) = load_and_run(&cfg, "P+M", Kind::Mixed { read_pct: 50 }, 0.9);
    assert!(
        pm.hdd_read_fraction() < p.hdd_read_fraction(),
        "migration should cut HDD reads: P+M {:.2} vs P {:.2}",
        pm.hdd_read_fraction(),
        p.hdd_read_fraction()
    );
    assert!(pm.migrations_pop > 0, "popularity migration must engage");
}

#[test]
fn caching_adds_ssd_cache_hits_on_read_heavy_skew() {
    // Exp#2 mechanism: +C produces SSD-cache hits on hot HDD blocks.
    let mut cfg = small_cfg();
    cfg.workload.ops = 25_000;
    let (_, full) = load_and_run(&cfg, "P+M+C", Kind::C, 1.2);
    assert!(
        full.ssd_cache_hits > 0,
        "the SSD cache should serve hot HDD blocks under α=1.2 reads"
    );
}

#[test]
fn wal_guaranteed_on_ssd_for_hhzs_but_not_basics() {
    // §3.2: HHZS reserves WAL zones, so WAL writes never spill to HDD;
    // B4 fills the SSD with SSTs and spills WAL to the HDD (O2).
    let cfg = small_cfg();
    let (_, hhzs) = load_fresh(&cfg, "HHZS", None, false);
    assert!(
        hhzs.ssd_write_fraction(Some(WriteCategory::Wal)) > 0.999,
        "HHZS WAL must stay on the SSD: {:.3}",
        hhzs.ssd_write_fraction(Some(WriteCategory::Wal))
    );
    let (_, b4) = load_fresh(&cfg, "B4", None, false);
    assert!(
        b4.ssd_write_fraction(Some(WriteCategory::Wal)) < 0.999,
        "B4's WAL should partly spill to the HDD: {:.3}",
        b4.ssd_write_fraction(Some(WriteCategory::Wal))
    );
}

#[test]
fn exp6_mechanism_higher_migration_rate_worse_tail() {
    // Fig 10 mechanism: faster migration → more interference in the
    // extreme read tail; the p99.99 at 64 MiB/s should exceed the one at
    // 1 MiB/s.
    let mut slow = small_cfg();
    slow.hhzs.migration_rate_bps = 1.0 * 1024.0 * 1024.0;
    let mut fast = small_cfg();
    fast.hhzs.migration_rate_bps = 64.0 * 1024.0 * 1024.0;
    let (_, m_slow) = load_and_run(&slow, "P+M", Kind::Mixed { read_pct: 50 }, 0.9);
    let (_, m_fast) = load_and_run(&fast, "P+M", Kind::Mixed { read_pct: 50 }, 0.9);
    // Compare only when both runs actually migrated.
    if m_slow.migration_bytes > 0 && m_fast.migration_bytes > 0 {
        assert!(
            m_fast.read_lat.quantile(0.9999) as f64
                >= m_slow.read_lat.quantile(0.9999) as f64 * 0.8,
            "fast-migration tail should not be drastically better: fast {} slow {}",
            m_fast.read_lat.quantile(0.9999),
            m_slow.read_lat.quantile(0.9999)
        );
    }
}

#[test]
fn workload_d_and_e_run_clean() {
    // Latest-reads (D) and scans (E) exercise distinct paths; both must
    // complete with sensible metrics under every scheme.
    let mut cfg = small_cfg();
    cfg.workload.ops = 6_000;
    for scheme in ["B3", "HHZS"] {
        let (mut e, _) = load_fresh(&cfg, scheme, None, false);
        let d = run_phase(&mut e, &cfg, Kind::D, 0.9);
        assert_eq!(d.ops_done, 6_000);
        assert!(d.reads_done > 5_000);
        let s = run_phase(&mut e, &cfg, Kind::E, 0.9);
        assert_eq!(s.ops_done, 6_000);
        assert!(s.scans_done > 5_000);
        assert!(s.scan_lat.n > 0);
    }
}

#[test]
fn auto_space_cutoffs_steer_ssts_to_hdd() {
    // AUTO's space rules (< 13.3% → M pinned at 1; < 8% → no SSTs to SSD)
    // steer the bulk of SST bytes to the HDD once the SSD tightens, while
    // the WAL stays on the reserved SSD pool.
    let cfg = small_cfg();
    let (engine, m) = load_fresh(&cfg, "AUTO", None, false);
    assert!(
        m.ssd_write_fraction(Some(WriteCategory::Wal)) > 0.999,
        "AUTO reserves the WAL on the SSD as HHZS does (§4.1)"
    );
    let mut ssd_bytes = 0u64;
    let mut hdd_bytes = 0u64;
    for f in engine.fs.files() {
        match f.dev {
            Dev::Ssd => ssd_bytes += f.size,
            Dev::Hdd => hdd_bytes += f.size,
        }
    }
    assert!(
        hdd_bytes > ssd_bytes,
        "with a 6x-SSD dataset most SST bytes must end on the HDD ({ssd_bytes} vs {hdd_bytes})"
    );
}

#[test]
fn crash_recovery_replays_wal() {
    use hhzs::coordinator::Engine;
    use hhzs::policy::HhzsPolicy;
    use hhzs::wire::Payload;
    use hhzs::ycsb::{key_for, value_for};
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 0;
    let mut e = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
    // Enough writes to span flushed SSTs AND a live tail in the WAL.
    for i in 0..3_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    // Overwrite a few keys so recovery must respect seqno ordering.
    for i in 0..50u64 {
        e.put(&key_for(i, 24), b"post-overwrite");
    }
    let replayed = e.crash_and_recover();
    assert!(replayed > 0, "a live WAL tail must exist and be replayed");
    // Every key readable after recovery, with the latest value winning.
    for i in (0..3_000u64).step_by(37) {
        let want =
            if i < 50 { Payload::from_bytes(b"post-overwrite") } else { value_for(i, 1000) };
        assert_eq!(e.get(&key_for(i, 24)), Some(want), "key {i} lost in crash");
    }
    // The store keeps working after recovery.
    e.put(b"post-crash-key", b"v");
    assert_eq!(e.get(b"post-crash-key"), Some(Payload::from_bytes(b"v")));
    e.quiesce();
    for lvl in 1..e.version.num_levels() {
        assert!(e.version.disjoint(lvl));
    }
}

#[test]
fn crash_recovery_mid_compaction_discards_orphans() {
    use hhzs::coordinator::Engine;
    use hhzs::policy::HhzsPolicy;
    use hhzs::ycsb::{key_for, value_for};
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 0;
    let mut e = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
    for i in 0..8_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    // Crash with background work likely in flight (no quiesce).
    e.crash_and_recover();
    // Version SSTs and zenfs files must be 1:1 (no orphaned zones).
    let version_ids: std::collections::HashSet<u64> =
        e.version.all_ssts().map(|m| m.id).collect();
    for f in e.fs.files() {
        assert!(
            version_ids.contains(&f.id),
            "orphan file {} survived recovery",
            f.id
        );
    }
    for i in (0..8_000u64).step_by(111) {
        assert_eq!(e.get(&key_for(i, 24)), Some(value_for(i, 1000)), "key {i}");
    }
}

#[test]
fn sharded_one_shard_reproduces_single_engine_bit_for_bit() {
    use hhzs::shard::ShardedEngine;
    use hhzs::ycsb::{RoutedSource, Spec, YcsbSource};
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 20_000;
    cfg.workload.ops = 5_000;
    cfg.shards = 1;
    let clients = cfg.workload.clients;

    // Reference: the seed single-engine §4.1 protocol.
    let (mut single, single_load) = load_fresh(&cfg, "HHZS", None, false);
    let single_a = run_phase(&mut single, &cfg, Kind::A, cfg.workload.zipf_alpha);

    // Same protocol through the shard subsystem at shards = 1.
    let mut se = ShardedEngine::new(&cfg, |c| make_policy("HHZS", c));
    let router = se.router;
    let load = Spec::from_config(&cfg, Kind::Load);
    se.run(
        |s| Box::new(RoutedSource::new(YcsbSource::new(load.clone(), clients), router, s)),
        clients,
        None,
        false,
    );
    let sharded_load = se.merged_metrics();
    se.flush_all();
    se.rebalance_migration_budgets();
    let a = Spec::from_config(&cfg, Kind::A);
    se.run(
        |s| Box::new(RoutedSource::new(YcsbSource::new(a.clone(), clients), router, s)),
        clients,
        None,
        false,
    );
    let sharded_a = se.merged_metrics();

    // Same seed ⇒ identical virtual timeline and identical numbers.
    assert_eq!(single.now, se.engines[0].now, "virtual clocks diverged");
    for (name, s, m) in
        [("load", &single_load, &sharded_load), ("A", &single_a, &sharded_a)]
    {
        assert_eq!(s.ops_done, m.ops_done, "{name}: ops");
        assert_eq!(
            s.ops_per_sec().to_bits(),
            m.ops_per_sec().to_bits(),
            "{name}: throughput must be bit-identical"
        );
        assert_eq!(s.stalls, m.stalls, "{name}: stalls");
        assert_eq!(s.flushes, m.flushes, "{name}: flushes");
        assert_eq!(s.compactions, m.compactions, "{name}: compactions");
        assert_eq!(s.migration_bytes, m.migration_bytes, "{name}: migration bytes");
        assert_eq!(
            s.read_lat.quantile(0.999),
            m.read_lat.quantile(0.999),
            "{name}: read tail"
        );
        // The shared CPU pool at shards = 1 is the seed's busy_threads
        // arithmetic: identical slot-wait accounting, sample for sample.
        assert_eq!(s.cpu_wait.n, m.cpu_wait.n, "{name}: cpu_wait samples");
        assert_eq!(s.cpu_wait.sum, m.cpu_wait.sum, "{name}: cpu_wait total");
    }
    // And the pool ledgers themselves agree (acquires, high water).
    let (ss, ms) = (single.cpu_pool_stats(), se.cpu_pool_stats());
    assert_eq!(ss.acquires, ms.acquires, "pool acquire ledgers diverged");
    assert_eq!(ss.releases, ms.releases, "pool release ledgers diverged");
    assert_eq!(ss.high_water, ms.high_water, "pool high-water marks diverged");
}

#[test]
fn sharded_frontend_conserves_ops_on_the_shared_device_pair() {
    // Exp#7's acceptance property at test scale. PR 1 asserted
    // near-linear scaling here, which was an artifact of each shard
    // owning a private virtual clock and device pair; the async frontend
    // models the paper's actual testbed — one shared SSD/HDD pair behind
    // one clock — so aggregate throughput is bounded by the shared
    // devices. What must hold now: exact op conservation at every shard
    // count, every shard participating, cross-shard device contention
    // actually modeled (non-zero merged queue wait), and no pathological
    // collapse from sharding (each count is deterministic, so these are
    // fixed comparisons, not statistical ones).
    let mut cfg = Config::paper_scaled(1024);
    cfg.workload.load_objects = 60_000;
    cfg.workload.ops = 15_000;
    let mut tputs = Vec::new();
    for n in [1usize, 2, 4] {
        let (_, a_tput, m, per_shard, shard_m) = hhzs::exp::exp7::run_one(&cfg, n);
        assert_eq!(m.ops_done, 15_000, "{n} shards lost ops");
        assert_eq!(per_shard.len(), n);
        assert_eq!(shard_m.len(), n);
        assert_eq!(
            shard_m.iter().map(|sm| sm.ops_done).sum::<u64>(),
            m.ops_done,
            "per-shard metrics must partition the merged ops"
        );
        assert!(
            per_shard.iter().all(|&ops| ops > 0),
            "an idle shard at n={n}: {per_shard:?}"
        );
        assert!(a_tput > 0.0);
        if n == 4 {
            assert!(
                m.total_queue_wait_ns() > 0,
                "4 shards hammering one device pair must queue"
            );
        }
        tputs.push(a_tput);
    }
    assert!(
        tputs[2] > tputs[0] * 0.3,
        "sharing one device pair must not collapse throughput ({:.0} vs {:.0})",
        tputs[2],
        tputs[0]
    );
}

#[test]
fn all_schemes_survive_full_protocol() {
    // Smoke every scheme through load + a mixed phase without panics and
    // with exact op accounting.
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 25_000;
    cfg.workload.ops = 4_000;
    for scheme in ["B1", "B2", "B3", "B4", "B3+M", "AUTO", "P", "P+M", "P+M+C"] {
        let p = make_policy(scheme, &cfg);
        assert!(!p.name().is_empty());
        let (_, m) = load_and_run(&cfg, scheme, Kind::A, 0.9);
        assert_eq!(m.ops_done, 4_000, "{scheme} lost operations");
    }
}

#[test]
fn crash_mid_flush_reclaims_installed_outputs() {
    // A crashed flush must reclaim the outputs it had already installed
    // (symmetric with compaction): zero orphan files, free-zone accounting
    // restored, and WAL replay restoring every acked write.
    use hhzs::coordinator::Engine;
    use hhzs::policy::HhzsPolicy;
    use hhzs::ycsb::{key_for, value_for};
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 0;
    cfg.crash.enabled = true;
    cfg.crash.point = "mid_flush".into();
    cfg.crash.at_op = 120;
    cfg.crash.seed = 9;
    let mut e = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
    for i in 0..2_000u64 {
        if e.crash_fired() {
            break;
        }
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    assert!(e.crash_fired(), "mid_flush injector never fired");
    // 1:1 between zenfs files and the recovered version: zero orphans.
    let mut version_ids = std::collections::HashSet::new();
    for lvl in 0..e.version.num_levels() {
        for m in e.version.level(lvl) {
            version_ids.insert(m.id);
        }
    }
    let mut files = 0usize;
    for f in e.fs.files() {
        assert!(version_ids.contains(&f.id), "orphan file {} leaked by crashed flush", f.id);
        files += 1;
    }
    assert_eq!(files, version_ids.len(), "version references a deleted file");
    // Free-zone accounting: the I3 checker flags any zone still holding
    // bytes of a reclaimed flush output (or any unreferenced zone).
    assert!(e.verify_recovery_invariants().is_empty());
    // Replay restored the writes the crashed flush was persisting.
    for i in (0..100u64).step_by(7) {
        assert_eq!(e.get(&key_for(i, 24)), Some(value_for(i, 1000)), "key {i}");
    }
}

#[test]
fn double_crash_recovery_is_idempotent() {
    use hhzs::coordinator::Engine;
    use hhzs::policy::HhzsPolicy;
    use hhzs::ycsb::{key_for, value_for};
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 0;
    let mut e = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
    for i in 0..3_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    let first = e.crash_and_recover();
    // Crash again before anything new is written: the surviving media is
    // unchanged, so the second recovery must replay identically.
    let second = e.crash_and_recover();
    assert_eq!(first, second, "same surviving media must replay identically");
    for i in (0..3_000u64).step_by(41) {
        assert_eq!(e.get(&key_for(i, 24)), Some(value_for(i, 1000)), "key {i}");
    }
    assert!(e.verify_recovery_invariants().is_empty());
}

#[test]
fn crash_during_recovery_converges() {
    // MidRecovery double fault: the first replay is aborted at an
    // RNG-chosen entry, volatile state dropped again, and the rerun from
    // the same (untouched) media must converge to the full acked prefix.
    use hhzs::coordinator::Engine;
    use hhzs::policy::HhzsPolicy;
    use hhzs::wire::Payload;
    use hhzs::ycsb::{key_for, value_for};
    let mut cfg = Config::paper_scaled(2048);
    cfg.workload.load_objects = 0;
    cfg.crash.enabled = true;
    cfg.crash.point = "mid_recovery".into();
    cfg.crash.at_op = 400;
    cfg.crash.seed = 5;
    let mut e = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
    for i in 0..1_000u64 {
        if e.crash_fired() {
            break;
        }
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    assert!(e.crash_fired(), "mid_recovery injector never fired");
    // The fire tore the 400th record (never acked); everything before it
    // survives the aborted-and-rerun replay.
    for i in (0..399u64).step_by(13) {
        assert_eq!(e.get(&key_for(i, 24)), Some(value_for(i, 1000)), "key {i}");
    }
    assert!(e.verify_recovery_invariants().is_empty());
    // And the store keeps working after the double fault.
    e.put(b"post-double-fault", b"v");
    assert_eq!(e.get(b"post-double-fault"), Some(Payload::from_bytes(b"v")));
}
