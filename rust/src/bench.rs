//! `hhzs bench wallclock` — the BENCH_2 wall-clock/memory benchmark.
//!
//! Measures what the zero-materialization data path is for: how many
//! simulated operations the DES executes per *wall-clock* second, and
//! that peak memory tracks entry count rather than payload bytes.
//!
//! The benchmark runs the §4.1 protocol (load, reopen, YCSB-A) on a
//! shape-preserving geometry at 10× the test-default dataset (quick mode
//! runs the 1× dataset for CI), sweeping `value_size` to demonstrate that
//! wall time and resident bytes are independent of payload size, and runs
//! the same protocol at 4 shards through the async frontend (one shared
//! clock, device pair, and CPU pool) so the sharded path's wall cost —
//! and its background-CPU contention (`cpu_wait_ns`) — is tracked.
//!
//! Results are written as `BENCH_2.json`; CI uploads it as an artifact on
//! every push so the perf trajectory accumulates.
//!
//! ## The `--gate` regression gate
//!
//! Two tiers, both read from the committed `BENCH_2.json`:
//!
//! * **Invariant gates — always armed.** Machine-independent same-run
//!   checks: the value-size sweep's resident-byte ratio must stay flat
//!   (the O(entries) claim), the key-length sweep's resident-byte ratio
//!   must stay within the same runs' logical ratio + slack (the
//!   O(unique-key-bytes) claim of the interned-key arena and the
//!   restart-point prefix-compressed blocks), the 4-shard frontend may
//!   not be catastrophically slower than the single-engine run on the
//!   same machine, and every row must clear an absolute sanity floor in
//!   sim-ops/wall-sec (set so only a pathological slowdown — not runner
//!   variance — trips it). Thresholds live in the committed file's
//!   `gates` section; built-in defaults apply if absent.
//! * **Baseline gate — armed by a measured baseline.** When the committed
//!   file carries measured `runs` (i.e. it is a promoted CI artifact, not
//!   the schema placeholder), any matching row that drops below 70% of
//!   its baseline sim-ops/wall-sec fails the build. Refresh procedure:
//!   see PERF.md (download the `BENCH_2` artifact from a green main run
//!   and commit it as `BENCH_2.json`).

use std::time::Instant;

use crate::config::{Config, WakePolicy};
use crate::coordinator::Engine;
use crate::policy::HhzsPolicy;
use crate::shard::ShardedEngine;
use crate::ycsb::{Kind, Spec, YcsbSource};

/// One measured run.
#[derive(Clone, Debug)]
pub struct WallclockRun {
    pub label: String,
    pub objects: u64,
    pub ops: u64,
    pub value_size: usize,
    pub key_size: usize,
    pub shards: usize,
    pub wall_secs: f64,
    /// Simulated operations executed per wall-clock second.
    pub sim_ops_per_wall_sec: f64,
    /// Throughput inside the simulation (virtual time).
    pub virtual_ops_per_sec: f64,
    /// Total virtual ns ready background jobs waited for a CPU slot in the
    /// measured YCSB-A phase (merged across shards; 0 with idle slots).
    pub cpu_wait_ns: u128,
    /// Total virtual ns foreground ops waited for a `fg_threads` slot
    /// (merged; 0 when the foreground pool is off).
    pub fg_wait_ns: u128,
    /// Wake rounds where the stall-aware policy redirected a freed CPU
    /// slot past the FIFO head toward the shard closest to a write stall
    /// (pool-global over the whole run; always 0 under FIFO wakes).
    pub stalls_avoided: u64,
    /// VmHWM after this run (process-wide high-water mark, monotone).
    pub peak_rss_bytes: u64,
    /// Physically resident zone bytes at the end of the run.
    pub zone_phys_bytes: u64,
    /// Logical (accounted) zone bytes at the end of the run.
    pub zone_logical_bytes: u64,
    /// Resident interned-key bytes of the key arena at the end of the
    /// measured phase (the `Metrics::key_arena_bytes` gauge).
    pub key_arena_bytes: u64,
    /// Sum of the four `Metrics::resident_*_bytes` gauges at the end of
    /// the measured phase: everything demand paging keeps hydrated for
    /// zones, WAL windows, and caches (merged across shards).
    pub resident_bytes: u64,
    /// Whether block-granular demand paging was on for this run. The
    /// legacy sweep rows run with it OFF so their phys-ratio gates keep
    /// pinning the prefix-compression/interning claims (dehydration would
    /// send both sides of those ratios to ~0 and mask a regression).
    pub paging: bool,
    /// Median members per fused WAL group-commit append in the measured
    /// phase (0 when group commit is off — ungrouped appends are not
    /// sampled).
    pub wal_group_p50: u64,
    /// Coalesced SST read accesses (each carrying >= 2 member block
    /// reads) in the measured phase; 0 with read coalescing off.
    pub fused_reads: u64,
}

/// Peak resident set size of this process (VmHWM), or 0 if unavailable.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

fn bench_cfg(objects: u64, ops: u64, value_size: usize, key_size: usize, paging: bool) -> Config {
    // 1/512 paper scale: ~42 MiB SSD, ~4 GiB HDD — holds the 10× dataset
    // at every swept value size.
    let mut cfg = Config::paper_scaled(512);
    cfg.workload.load_objects = objects;
    cfg.workload.ops = ops;
    cfg.workload.value_size = value_size;
    cfg.workload.key_size = key_size;
    cfg.residency.paging = paging;
    cfg
}

fn resident_total(m: &crate::metrics::Metrics) -> u64 {
    m.resident_ssd_bytes + m.resident_hdd_bytes + m.resident_wal_bytes + m.resident_cache_bytes
}

/// Run load + YCSB-A once and measure it.
pub fn run_one(
    label: &str,
    objects: u64,
    ops: u64,
    value_size: usize,
    key_size: usize,
    paging: bool,
) -> WallclockRun {
    let cfg = bench_cfg(objects, ops, value_size, key_size, paging);
    let mut e = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
    let clients = cfg.workload.clients;
    let t0 = Instant::now();
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    e.run(&mut load, clients, None, false);
    let load_virtual = e.metrics.ops_per_sec();
    e.flush_all();
    let mut a = YcsbSource::new(Spec::from_config(&cfg, Kind::A), clients);
    e.run(&mut a, clients, None, false);
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let total_ops = objects + ops;
    WallclockRun {
        label: label.to_string(),
        objects,
        ops,
        value_size,
        key_size,
        shards: 1,
        wall_secs: wall,
        sim_ops_per_wall_sec: total_ops as f64 / wall,
        virtual_ops_per_sec: if e.metrics.ops_per_sec() > 0.0 {
            e.metrics.ops_per_sec()
        } else {
            load_virtual
        },
        cpu_wait_ns: e.metrics.cpu_wait.sum,
        fg_wait_ns: e.metrics.fg_cpu_wait.sum,
        stalls_avoided: e.cpu_pool_stats().stalls_avoided,
        peak_rss_bytes: peak_rss_bytes(),
        zone_phys_bytes: e.fs.phys_bytes(),
        zone_logical_bytes: e.fs.ssd.written_bytes() + e.fs.hdd.written_bytes(),
        key_arena_bytes: e.metrics.key_arena_bytes,
        resident_bytes: resident_total(&e.metrics),
        paging,
        wal_group_p50: e.metrics.wal_group_size.quantile(0.5),
        fused_reads: e.metrics.fused_reads,
    }
}

/// Run load + YCSB-A through the sharded async frontend (one shared
/// clock, device pair, and `bg_threads` CPU pool over `shards` engines)
/// and measure it. `wake` picks the freed-slot wake order; `fg_threads`
/// enables the contended foreground pool (the saturated rows raise the
/// closed-loop client count above the slot count so per-op CPU queues).
pub fn run_one_sharded(
    label: &str,
    objects: u64,
    ops: u64,
    value_size: usize,
    shards: usize,
    paging: bool,
    wake: WakePolicy,
    fg_threads: usize,
    batch: Option<&crate::config::BatchConfig>,
) -> WallclockRun {
    let mut cfg = bench_cfg(objects, ops, value_size, 24, paging);
    cfg.shards = shards;
    cfg.lsm.wake = wake;
    cfg.lsm.fg_threads = fg_threads;
    if fg_threads > 0 {
        cfg.workload.clients = cfg.workload.clients.max(4 * fg_threads);
    }
    if let Some(b) = batch {
        cfg.batch = b.clone();
        // Fused windows need concurrent writers to have anything to fuse.
        cfg.workload.clients = cfg.workload.clients.max(32);
    }
    let mut se = ShardedEngine::new(&cfg, |c| Box::new(HhzsPolicy::new(c.lsm.num_levels)));
    let clients = cfg.workload.clients;
    let t0 = Instant::now();
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, None, false);
    let load_virtual = se.aggregate_ops_per_sec();
    se.flush_all();
    let mut a = YcsbSource::new(Spec::from_config(&cfg, Kind::A), clients);
    se.run_shared(&mut a, clients, None, false);
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let total_ops = objects + ops;
    let a_virtual = se.aggregate_ops_per_sec();
    let merged = se.merged_metrics();
    let (mut phys, mut logical) = (0u64, 0u64);
    for e in &se.engines {
        phys += e.fs.phys_bytes();
        logical += e.fs.ssd.written_bytes() + e.fs.hdd.written_bytes();
    }
    WallclockRun {
        label: label.to_string(),
        objects,
        ops,
        value_size,
        key_size: 24,
        shards,
        wall_secs: wall,
        sim_ops_per_wall_sec: total_ops as f64 / wall,
        virtual_ops_per_sec: if a_virtual > 0.0 { a_virtual } else { load_virtual },
        cpu_wait_ns: merged.cpu_wait.sum,
        fg_wait_ns: merged.fg_cpu_wait.sum,
        stalls_avoided: se.cpu_pool_stats().stalls_avoided,
        peak_rss_bytes: peak_rss_bytes(),
        zone_phys_bytes: phys,
        zone_logical_bytes: logical,
        key_arena_bytes: merged.key_arena_bytes,
        resident_bytes: resident_total(&merged),
        paging,
        wal_group_p50: merged.wal_group_size.quantile(0.5),
        fused_reads: merged.fused_reads,
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn run_to_json(r: &WallclockRun) -> String {
    format!(
        concat!(
            "    {{\n",
            "      \"label\": \"{}\",\n",
            "      \"objects\": {},\n",
            "      \"ops\": {},\n",
            "      \"value_size\": {},\n",
            "      \"key_size\": {},\n",
            "      \"shards\": {},\n",
            "      \"wall_secs\": {:.3},\n",
            "      \"sim_ops_per_wall_sec\": {:.1},\n",
            "      \"virtual_ops_per_sec\": {:.1},\n",
            "      \"cpu_wait_ns\": {},\n",
            "      \"fg_wait_ns\": {},\n",
            "      \"stalls_avoided\": {},\n",
            "      \"peak_rss_bytes\": {},\n",
            "      \"zone_phys_bytes\": {},\n",
            "      \"zone_logical_bytes\": {},\n",
            "      \"key_arena_bytes\": {},\n",
            "      \"resident_bytes\": {},\n",
            "      \"paging\": {},\n",
            "      \"wal_group_p50\": {},\n",
            "      \"fused_reads\": {}\n",
            "    }}"
        ),
        json_escape(&r.label),
        r.objects,
        r.ops,
        r.value_size,
        r.key_size,
        r.shards,
        r.wall_secs,
        r.sim_ops_per_wall_sec,
        r.virtual_ops_per_sec,
        r.cpu_wait_ns,
        r.fg_wait_ns,
        r.stalls_avoided,
        r.peak_rss_bytes,
        r.zone_phys_bytes,
        r.zone_logical_bytes,
        r.key_arena_bytes,
        r.resident_bytes,
        r.paging,
        r.wal_group_p50,
        r.fused_reads,
    )
}

/// Scan a `"key": <number>` pair out of our own stable JSON schema
/// (hand-rolled — no JSON crate in this offline build).
fn scan_f64(json: &str, key: &str) -> Option<f64> {
    let needle = format!("\"{key}\": ");
    let i = json.find(&needle)?;
    let rest = &json[i + needle.len()..];
    let end = rest.find([',', '\n', '}'])?;
    rest[..end].trim().parse().ok()
}

/// Extract `(label, sim_ops_per_wall_sec)` pairs from a previously written
/// BENCH_2.json. Returns `None` for the committed placeholder (no
/// measurements) or anything unparsable — the per-row baseline gate then
/// skips with a note (the invariant gates still run).
fn parse_baseline(json: &str) -> Option<Vec<(String, f64)>> {
    if json.contains("\"placeholder\": true") {
        return None;
    }
    let mut out = Vec::new();
    let mut rest = json;
    while let Some(i) = rest.find("\"label\": \"") {
        rest = &rest[i + "\"label\": \"".len()..];
        let end = rest.find('"')?;
        let label = rest[..end].to_string();
        let value = scan_f64(rest, "sim_ops_per_wall_sec")?;
        out.push((label, value));
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Machine-independent invariant thresholds; overridable via the committed
/// BENCH_2.json's `gates` section, so tightening them is a data change.
#[derive(Clone, Copy, Debug)]
pub struct GateThresholds {
    /// Max allowed v4000/v1000 resident-zone-byte ratio (O(entries)
    /// memory: resident bytes must not scale with payload bytes).
    pub zone_phys_ratio_max: f64,
    /// Max allowed slowdown of the 4-shard frontend row vs the
    /// single-engine streaming row measured in the SAME process (so
    /// runner speed divides out).
    pub sharded4_slowdown_max: f64,
    /// Absolute sanity floor for every row's sim-ops/wall-sec. The one
    /// wall-clock-dependent gate, so it is set pathologically low (the
    /// quick bench's slowest row would need > 5 minutes of wall time to
    /// trip it): it exists to catch accidental complexity blowups (e.g.
    /// a quadratic hot path), never runner variance. Tighten it via the
    /// committed `gates` section once a measured baseline establishes
    /// the runner class's real range.
    pub min_sim_ops_per_wall_sec: f64,
    /// Key-length sweep gate: the k128/k24 resident-zone-byte ratio may
    /// exceed the same runs' *logical* byte ratio by at most this slack.
    /// With prefix-compressed blocks the physical ratio sits near 1
    /// (suffixes don't grow with zero-padded key length); storing full
    /// keys per entry would push it toward (14+128)/(14+24) ≈ 3.7 and
    /// trip the gate. Machine-independent: both ratios come from one
    /// process on one machine.
    pub key_phys_ratio_slack: f64,
}

impl Default for GateThresholds {
    fn default() -> Self {
        GateThresholds {
            zone_phys_ratio_max: 1.35,
            sharded4_slowdown_max: 12.0,
            min_sim_ops_per_wall_sec: 250.0,
            key_phys_ratio_slack: 0.5,
        }
    }
}

impl GateThresholds {
    fn from_json(json: &str) -> Self {
        let mut g = GateThresholds::default();
        if let Some(v) = scan_f64(json, "zone_phys_ratio_max") {
            g.zone_phys_ratio_max = v;
        }
        if let Some(v) = scan_f64(json, "sharded4_slowdown_max") {
            g.sharded4_slowdown_max = v;
        }
        if let Some(v) = scan_f64(json, "min_sim_ops_per_wall_sec") {
            g.min_sim_ops_per_wall_sec = v;
        }
        if let Some(v) = scan_f64(json, "key_phys_ratio_slack") {
            g.key_phys_ratio_slack = v;
        }
        g
    }
}

/// Allowed wall-clock throughput regression against a *measured* baseline
/// before the gate trips: a run's sim-ops/wall-sec may not drop below 70%
/// of the committed baseline's. The 30% margin is deliberately wide
/// because the baseline is an absolute number measured on whatever machine
/// committed it — CI runners are heterogeneous. Commit baselines from the
/// same runner class CI uses (PERF.md has the procedure).
const GATE_MIN_RATIO: f64 = 0.7;

/// The `hhzs bench wallclock` driver. `quick` runs the CI-sized dataset.
/// Writes `out` (JSON) and prints a human summary. With `gate`, the file
/// at `out` is first read as the committed baseline: the invariant gates
/// always arm (thresholds from its `gates` section or defaults), and the
/// per-row 30% baseline gate arms when it carries measured runs.
pub fn run_wallclock(quick: bool, out: &str, gate: bool) -> std::io::Result<()> {
    // Read the committed file (thresholds + baseline) BEFORE overwriting
    // it — and read the thresholds even without --gate, so an ungated
    // local refresh re-emits the committed gate values instead of
    // silently resetting them to the defaults.
    let committed = std::fs::read_to_string(out).ok();
    let thresholds =
        committed.as_deref().map(GateThresholds::from_json).unwrap_or_default();
    let baseline = committed.as_deref().and_then(parse_baseline);
    if gate && baseline.is_none() {
        eprintln!(
            "[bench] gate: no measured rows in {out} (placeholder or missing) — \
             invariant gates only; commit a CI-artifact BENCH_2.json to arm the \
             per-row baseline gate (see PERF.md)"
        );
    }
    // "1×" is the test-default dataset (Config::tiny): 60k objects.
    let (objects, ops, scale_label) = if quick {
        (60_000u64, 20_000u64, "1x")
    } else {
        (600_000u64, 60_000u64, "10x")
    };
    let mut runs: Vec<WallclockRun> = Vec::new();
    // Value-size sweep: wall time and resident bytes must not scale with
    // payload bytes (the O(entries) claim). The big-value run goes FIRST:
    // VmHWM is process-monotone, so the high-water mark it sets bounds the
    // 4× -payload footprint; `zone_phys_bytes` is the per-run flatness
    // signal (peak_rss_bytes of later runs inherits earlier marks).
    // The sweep rows run with demand paging OFF: their phys-ratio gates
    // pin the prefix-compression and key-interning claims, and with
    // paging on dehydration drives both sides of those ratios toward
    // zero — a regression would hide inside the noise. The paged row
    // below measures (and records) what paging saves.
    for value_size in [4000usize, 1000] {
        let label = format!("streaming-{scale_label}-v{value_size}");
        eprintln!("[bench] {label}: {objects} objects + {ops} YCSB-A ops ...");
        let r = run_one(&label, objects, ops, value_size, 24, false);
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s, rss {} MiB, zone phys {} MiB / logical {} MiB",
            r.wall_secs,
            r.sim_ops_per_wall_sec,
            r.peak_rss_bytes >> 20,
            r.zone_phys_bytes >> 20,
            r.zone_logical_bytes >> 20,
        );
        runs.push(r);
    }
    // The sharded frontend row: same protocol at 4 shards over one shared
    // clock, device pair, and CPU pool — tracks the frontend's wall cost
    // and the background-CPU contention the shared pool now models.
    {
        let label = format!("sharded4-{scale_label}-v1000");
        eprintln!("[bench] {label}: 4-shard frontend ...");
        let r = run_one_sharded(&label, objects, ops, 1000, 4, false, WakePolicy::Fifo, 0, None);
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s, cpu wait {:.1}ms",
            r.wall_secs,
            r.sim_ops_per_wall_sec,
            r.cpu_wait_ns as f64 / 1e6,
        );
        runs.push(r);
    }
    // Key-length sweep: resident bytes must track *unique suffix* bytes,
    // not entries × key_len — the interned-arena + restart-point-prefix
    // claim. Small values sharpen the signal (keys dominate the physical
    // form; values are synthetic either way).
    for key_size in [24usize, 128] {
        let label = format!("streaming-{scale_label}-k{key_size}-v100");
        eprintln!("[bench] {label}: key_len {key_size} sweep ...");
        let r = run_one(&label, objects, ops, 100, key_size, false);
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s, zone phys {} KiB, key arena {} KiB",
            r.wall_secs,
            r.sim_ops_per_wall_sec,
            r.zone_phys_bytes >> 10,
            r.key_arena_bytes >> 10,
        );
        runs.push(r);
    }

    // The paged row: the production default (demand paging on), same
    // shape as the v1000 streaming row. `resident_bytes` records the
    // working set paging keeps hydrated; the exp7 --quick CI smoke gates
    // its flatness against keyspace growth.
    {
        let label = format!("streaming-{scale_label}-v1000-paged");
        eprintln!("[bench] {label}: demand-paged residency ...");
        let r = run_one(&label, objects, ops, 1000, 24, true);
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s, zone phys {} KiB, resident {} KiB \
             (unpaged zone phys {} KiB)",
            r.wall_secs,
            r.sim_ops_per_wall_sec,
            r.zone_phys_bytes >> 10,
            r.resident_bytes >> 10,
            runs[1].zone_phys_bytes >> 10,
        );
        runs.push(r);
    }

    // The scheduler rows (appended AFTER the positional rows the gate
    // ratios index): the same 4-shard protocol under stall-aware wakes at
    // equal bg_threads, and the fg-saturated shape (fg_threads = 8,
    // clients raised above the slot count) where per-op CPU queues and
    // the run crosses from device-bound to CPU-bound.
    {
        let label = "sharded4-stall-aware".to_string();
        eprintln!("[bench] {label}: 4-shard frontend, stall-aware wakes ...");
        let r =
            run_one_sharded(&label, objects, ops, 1000, 4, false, WakePolicy::StallAware, 0, None);
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s, cpu wait {:.1}ms, \
             stalls avoided {}",
            r.wall_secs,
            r.sim_ops_per_wall_sec,
            r.cpu_wait_ns as f64 / 1e6,
            r.stalls_avoided,
        );
        runs.push(r);
    }
    {
        let label = "sharded4-fg8-saturated".to_string();
        eprintln!("[bench] {label}: 4-shard frontend, fg_threads = 8, saturating clients ...");
        let r =
            run_one_sharded(&label, objects, ops, 1000, 4, false, WakePolicy::StallAware, 8, None);
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s, fg wait {:.1}ms, \
             stalls avoided {}",
            r.wall_secs,
            r.sim_ops_per_wall_sec,
            r.fg_wait_ns as f64 / 1e6,
            r.stalls_avoided,
        );
        runs.push(r);
    }

    // The request-fusion rows (appended after the positional rows, like
    // the scheduler rows): the 4-shard protocol with cross-shard WAL
    // group commit, and with SST read coalescing, each against the same
    // saturating client pool. `wal_group_p50` / `fused_reads` in the JSON
    // are the evidence the fusion layer engaged.
    {
        let label = "sharded4-group-commit".to_string();
        eprintln!("[bench] {label}: 4-shard frontend, WAL group commit ...");
        let batch = crate::config::BatchConfig {
            group_commit: true,
            commit_batch_max: 64,
            ..Default::default()
        };
        let r = run_one_sharded(
            &label, objects, ops, 1000, 4, false, WakePolicy::Fifo, 0, Some(&batch),
        );
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s, wal group p50 {}",
            r.wall_secs, r.sim_ops_per_wall_sec, r.wal_group_p50,
        );
        runs.push(r);
    }
    {
        let label = "sharded4-read-coalesce".to_string();
        eprintln!("[bench] {label}: 4-shard frontend, fused SST reads ...");
        let batch = crate::config::BatchConfig { read_coalesce: true, ..Default::default() };
        let r = run_one_sharded(
            &label, objects, ops, 1000, 4, false, WakePolicy::Fifo, 0, Some(&batch),
        );
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s, fused reads {}",
            r.wall_secs, r.sim_ops_per_wall_sec, r.fused_reads,
        );
        runs.push(r);
    }

    // runs[0] = streaming v4000, runs[1] = streaming v1000, runs[2] = sharded4 v1000,
    // runs[3] = streaming k24 v100, runs[4] = streaming k128 v100,
    // runs[5] = streaming v1000 paged, runs[6] = sharded4-stall-aware,
    // runs[7] = sharded4-fg8-saturated, runs[8] = sharded4-group-commit,
    // runs[9] = sharded4-read-coalesce. The gate ratios below index
    // runs[0..6] positionally — append new rows after, never between.
    let phys_ratio = runs[0].zone_phys_bytes as f64 / runs[1].zone_phys_bytes.max(1) as f64;
    let logical_ratio =
        runs[0].zone_logical_bytes as f64 / runs[1].zone_logical_bytes.max(1) as f64;
    let sharded4_slowdown =
        runs[1].sim_ops_per_wall_sec / runs[2].sim_ops_per_wall_sec.max(1e-9);
    let key_phys_ratio = runs[4].zone_phys_bytes as f64 / runs[3].zone_phys_bytes.max(1) as f64;
    let key_logical_ratio =
        runs[4].zone_logical_bytes as f64 / runs[3].zone_logical_bytes.max(1) as f64;
    eprintln!(
        "[bench] value-size 4x sweep: zone phys ratio {phys_ratio:.2} (flat = O(entries)), \
         logical ratio {logical_ratio:.2}; 4-shard frontend slowdown vs single: \
         {sharded4_slowdown:.2}x; key-length 24→128 sweep: phys ratio {key_phys_ratio:.2} \
         vs logical {key_logical_ratio:.2} (flat = O(unique-key-bytes))"
    );

    let runs_json: Vec<String> = runs.iter().map(run_to_json).collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"wallclock\",\n",
            "  \"quick\": {},\n",
            "  \"note\": \"sim_ops_per_wall_sec = simulated client ops executed per wall-clock ",
            "second (load + YCSB-A). zone_phys_bytes must stay flat across the value_size ",
            "sweep (O(entries) memory) AND across the key_size sweep relative to the logical ",
            "ratio (O(unique-key-bytes) memory: interned keys + restart-point prefix-compressed ",
            "blocks); zone_logical_bytes scales with payload bytes. key_arena_bytes is the ",
            "resident interned-key gauge at the end of the measured phase. ",
            "peak_rss_bytes is the process-wide VmHWM and is monotone across runs (the ",
            "4x-payload run executes first so its mark bounds that footprint); use ",
            "zone_phys_bytes for per-run comparisons. cpu_wait_ns is the merged virtual time ",
            "ready flush/compaction jobs waited for a slot of the shared bg_threads CPU pool ",
            "during the measured YCSB-A phase; fg_wait_ns is the analogous wait of foreground ",
            "per-op CPU charges on the fg_threads pool (0 when off), and stalls_avoided counts ",
            "wake rounds where the stall-aware policy redirected a freed slot past the FIFO ",
            "head (always 0 under fifo wakes). resident_bytes sums the four ",
            "resident_*_bytes gauges (zones + WAL + caches kept hydrated by demand paging); ",
            "the sweep rows run with paging = false so their phys ratios keep pinning the ",
            "compression claims, the -paged row runs the production default. wal_group_p50 is ",
            "the median member count per fused WAL group-commit append and fused_reads the ",
            "coalesced SST read count (both 0 with the [batch] knobs off). The gates ",
            "section feeds the always-armed invariant gates of `bench wallclock --gate`.\",\n",
            "  \"gates\": {{\n",
            "    \"zone_phys_ratio_max\": {:.3},\n",
            "    \"sharded4_slowdown_max\": {:.3},\n",
            "    \"min_sim_ops_per_wall_sec\": {:.1},\n",
            "    \"key_phys_ratio_slack\": {:.3}\n",
            "  }},\n",
            "  \"value_size_sweep\": {{ \"zone_phys_ratio\": {:.3}, \"zone_logical_ratio\": {:.3} }},\n",
            "  \"key_size_sweep\": {{ \"zone_phys_ratio\": {:.3}, \"zone_logical_ratio\": {:.3} }},\n",
            "  \"sharded4_slowdown\": {:.3},\n",
            "  \"runs\": [\n{}\n  ]\n",
            "}}\n"
        ),
        quick,
        thresholds.zone_phys_ratio_max,
        thresholds.sharded4_slowdown_max,
        thresholds.min_sim_ops_per_wall_sec,
        thresholds.key_phys_ratio_slack,
        phys_ratio,
        logical_ratio,
        key_phys_ratio,
        key_logical_ratio,
        sharded4_slowdown,
        runs_json.join(",\n"),
    );
    std::fs::write(out, json)?;
    eprintln!("[bench] wrote {out}");

    if !gate {
        return Ok(());
    }
    let mut failures = Vec::new();
    // Invariant gates — always armed.
    if phys_ratio > thresholds.zone_phys_ratio_max {
        failures.push(format!(
            "zone_phys_ratio {:.3} > {:.3}: resident bytes scale with payload bytes \
             (O(entries) memory regressed)",
            phys_ratio, thresholds.zone_phys_ratio_max
        ));
    }
    if sharded4_slowdown > thresholds.sharded4_slowdown_max {
        failures.push(format!(
            "4-shard frontend {:.2}x slower than single-engine (max {:.2}x)",
            sharded4_slowdown, thresholds.sharded4_slowdown_max
        ));
    }
    if key_phys_ratio > key_logical_ratio + thresholds.key_phys_ratio_slack {
        failures.push(format!(
            "key-length sweep: zone phys ratio {:.3} exceeds logical ratio {:.3} + {:.3} \
             (resident key bytes scale with key_len — interning/prefix compression regressed)",
            key_phys_ratio, key_logical_ratio, thresholds.key_phys_ratio_slack
        ));
    }
    for r in &runs {
        if r.sim_ops_per_wall_sec < thresholds.min_sim_ops_per_wall_sec {
            failures.push(format!(
                "{}: {:.0} sim-ops/s below the {:.0} sanity floor",
                r.label, r.sim_ops_per_wall_sec, thresholds.min_sim_ops_per_wall_sec
            ));
        }
    }
    // Per-row baseline gate — armed by a measured (promoted) baseline.
    // Labels present in only one side are ignored so adding/renaming rows
    // never wedges CI.
    if let Some(base) = baseline {
        for r in &runs {
            if let Some((_, old)) = base.iter().find(|(l, _)| *l == r.label) {
                let ratio = r.sim_ops_per_wall_sec / old.max(1e-9);
                eprintln!(
                    "[bench] gate: {} {:.0} vs baseline {:.0} sim-ops/s ({:.2}x)",
                    r.label, r.sim_ops_per_wall_sec, old, ratio
                );
                if ratio < GATE_MIN_RATIO {
                    failures.push(format!(
                        "{}: {:.0} -> {:.0} sim-ops/s ({:.0}% of baseline)",
                        r.label,
                        old,
                        r.sim_ops_per_wall_sec,
                        ratio * 100.0
                    ));
                }
            }
        }
    }
    if !failures.is_empty() {
        return Err(std::io::Error::other(format!(
            "wallclock regression gate: {}",
            failures.join("; ")
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_thresholds_parse_and_default() {
        let d = GateThresholds::default();
        assert!(d.zone_phys_ratio_max > 1.0);
        let json = "{\n  \"gates\": {\n    \"zone_phys_ratio_max\": 1.5,\n    \
                    \"sharded4_slowdown_max\": 9.0,\n    \
                    \"min_sim_ops_per_wall_sec\": 123.0,\n    \
                    \"key_phys_ratio_slack\": 0.7\n  }\n}\n";
        let g = GateThresholds::from_json(json);
        assert_eq!(g.zone_phys_ratio_max, 1.5);
        assert_eq!(g.sharded4_slowdown_max, 9.0);
        assert_eq!(g.min_sim_ops_per_wall_sec, 123.0);
        assert_eq!(g.key_phys_ratio_slack, 0.7);
        // Missing keys keep defaults.
        let g = GateThresholds::from_json("{}");
        assert_eq!(g.sharded4_slowdown_max, d.sharded4_slowdown_max);
        assert_eq!(g.key_phys_ratio_slack, d.key_phys_ratio_slack);
    }

    #[test]
    fn placeholder_baseline_yields_no_rows() {
        assert!(parse_baseline("{ \"placeholder\": true, \"runs\": [] }").is_none());
        let measured = "{ \"runs\": [ { \"label\": \"x\", \
                        \"sim_ops_per_wall_sec\": 42.0 } ] }";
        let rows = parse_baseline(measured).unwrap();
        assert_eq!(rows, vec![("x".to_string(), 42.0)]);
    }
}
