//! `hhzs bench wallclock` — the BENCH_2 wall-clock/memory benchmark.
//!
//! Measures what the zero-materialization data path is for: how many
//! simulated operations the DES executes per *wall-clock* second, and
//! that peak memory tracks entry count rather than payload bytes.
//!
//! The benchmark runs the §4.1 protocol (load, reopen, YCSB-A) on a
//! shape-preserving geometry at 10× the test-default dataset (quick mode
//! runs the 1× dataset for CI), sweeping `value_size` to demonstrate that
//! wall time and resident bytes are independent of payload size, and runs
//! the load once through the retained reference (materialize-everything)
//! merge pipeline for a same-binary comparison of the streaming merge.
//!
//! Results are written as `BENCH_2.json`; CI uploads it as an artifact on
//! every push so the perf trajectory accumulates.

use std::time::Instant;

use crate::config::Config;
use crate::coordinator::Engine;
use crate::policy::HhzsPolicy;
use crate::shard::ShardedEngine;
use crate::ycsb::{Kind, Spec, YcsbSource};

/// One measured run.
#[derive(Clone, Debug)]
pub struct WallclockRun {
    pub label: String,
    pub objects: u64,
    pub ops: u64,
    pub value_size: usize,
    pub reference_datapath: bool,
    pub wall_secs: f64,
    /// Simulated operations executed per wall-clock second.
    pub sim_ops_per_wall_sec: f64,
    /// Throughput inside the simulation (virtual time).
    pub virtual_ops_per_sec: f64,
    /// VmHWM after this run (process-wide high-water mark, monotone).
    pub peak_rss_bytes: u64,
    /// Physically resident zone bytes at the end of the run.
    pub zone_phys_bytes: u64,
    /// Logical (accounted) zone bytes at the end of the run.
    pub zone_logical_bytes: u64,
}

/// Peak resident set size of this process (VmHWM), or 0 if unavailable.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

fn bench_cfg(objects: u64, ops: u64, value_size: usize) -> Config {
    // 1/512 paper scale: ~42 MiB SSD, ~4 GiB HDD — holds the 10× dataset
    // at every swept value size.
    let mut cfg = Config::paper_scaled(512);
    cfg.workload.load_objects = objects;
    cfg.workload.ops = ops;
    cfg.workload.value_size = value_size;
    cfg
}

/// Run load + YCSB-A once and measure it.
pub fn run_one(
    label: &str,
    objects: u64,
    ops: u64,
    value_size: usize,
    reference: bool,
) -> WallclockRun {
    let cfg = bench_cfg(objects, ops, value_size);
    let mut e = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
    e.reference_datapath = reference;
    let clients = cfg.workload.clients;
    let t0 = Instant::now();
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    e.run(&mut load, clients, None, false);
    let load_virtual = e.metrics.ops_per_sec();
    e.flush_all();
    let mut a = YcsbSource::new(Spec::from_config(&cfg, Kind::A), clients);
    e.run(&mut a, clients, None, false);
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let total_ops = objects + ops;
    WallclockRun {
        label: label.to_string(),
        objects,
        ops,
        value_size,
        reference_datapath: reference,
        wall_secs: wall,
        sim_ops_per_wall_sec: total_ops as f64 / wall,
        virtual_ops_per_sec: if e.metrics.ops_per_sec() > 0.0 {
            e.metrics.ops_per_sec()
        } else {
            load_virtual
        },
        peak_rss_bytes: peak_rss_bytes(),
        zone_phys_bytes: e.fs.phys_bytes(),
        zone_logical_bytes: e.fs.ssd.written_bytes() + e.fs.hdd.written_bytes(),
    }
}

/// Run load + YCSB-A through the sharded async frontend (one shared
/// clock + device pair over `shards` engines) and measure it. Tracks the
/// new path's DES wall-clock cost next to the single-engine rows.
pub fn run_one_sharded(
    label: &str,
    objects: u64,
    ops: u64,
    value_size: usize,
    shards: usize,
) -> WallclockRun {
    let mut cfg = bench_cfg(objects, ops, value_size);
    cfg.shards = shards;
    let mut se = ShardedEngine::new(&cfg, |c| Box::new(HhzsPolicy::new(c.lsm.num_levels)));
    let clients = cfg.workload.clients;
    let t0 = Instant::now();
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, None, false);
    let load_virtual = se.aggregate_ops_per_sec();
    se.flush_all();
    let mut a = YcsbSource::new(Spec::from_config(&cfg, Kind::A), clients);
    se.run_shared(&mut a, clients, None, false);
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let total_ops = objects + ops;
    let a_virtual = se.aggregate_ops_per_sec();
    let (mut phys, mut logical) = (0u64, 0u64);
    for e in &se.engines {
        phys += e.fs.phys_bytes();
        logical += e.fs.ssd.written_bytes() + e.fs.hdd.written_bytes();
    }
    WallclockRun {
        label: label.to_string(),
        objects,
        ops,
        value_size,
        reference_datapath: false,
        wall_secs: wall,
        sim_ops_per_wall_sec: total_ops as f64 / wall,
        virtual_ops_per_sec: if a_virtual > 0.0 { a_virtual } else { load_virtual },
        peak_rss_bytes: peak_rss_bytes(),
        zone_phys_bytes: phys,
        zone_logical_bytes: logical,
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn run_to_json(r: &WallclockRun) -> String {
    format!(
        concat!(
            "    {{\n",
            "      \"label\": \"{}\",\n",
            "      \"objects\": {},\n",
            "      \"ops\": {},\n",
            "      \"value_size\": {},\n",
            "      \"reference_datapath\": {},\n",
            "      \"wall_secs\": {:.3},\n",
            "      \"sim_ops_per_wall_sec\": {:.1},\n",
            "      \"virtual_ops_per_sec\": {:.1},\n",
            "      \"peak_rss_bytes\": {},\n",
            "      \"zone_phys_bytes\": {},\n",
            "      \"zone_logical_bytes\": {}\n",
            "    }}"
        ),
        json_escape(&r.label),
        r.objects,
        r.ops,
        r.value_size,
        r.reference_datapath,
        r.wall_secs,
        r.sim_ops_per_wall_sec,
        r.virtual_ops_per_sec,
        r.peak_rss_bytes,
        r.zone_phys_bytes,
        r.zone_logical_bytes,
    )
}

/// Extract `(label, sim_ops_per_wall_sec)` pairs from a previously written
/// BENCH_2.json. Hand-rolled scanner over our own stable schema (no JSON
/// crate in this offline build). Returns `None` for the committed
/// placeholder (no measurements) or anything unparsable — the gate then
/// skips with a note instead of failing the build.
fn parse_baseline(json: &str) -> Option<Vec<(String, f64)>> {
    if json.contains("\"placeholder\": true") {
        return None;
    }
    let mut out = Vec::new();
    let mut rest = json;
    while let Some(i) = rest.find("\"label\": \"") {
        rest = &rest[i + "\"label\": \"".len()..];
        let end = rest.find('"')?;
        let label = rest[..end].to_string();
        let j = rest.find("\"sim_ops_per_wall_sec\": ")?;
        let num = &rest[j + "\"sim_ops_per_wall_sec\": ".len()..];
        let num_end = num.find([',', '\n', '}'])?;
        let value: f64 = num[..num_end].trim().parse().ok()?;
        out.push((label, value));
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Allowed wall-clock throughput regression before the gate trips: a run's
/// sim-ops/wall-sec may not drop below 70% of the committed baseline's.
/// The 30% margin is deliberately wide because the baseline is an absolute
/// number measured on whatever machine committed it — CI runners are
/// heterogeneous, so a tight margin would trip on runner variance rather
/// than code. Commit baselines from the same runner class CI uses; if the
/// gate still proves noisy, move it to same-run relative ratios (e.g.
/// streaming vs reference rows) instead of cross-run absolutes.
const GATE_MIN_RATIO: f64 = 0.7;

/// The `hhzs bench wallclock` driver. `quick` runs the CI-sized dataset.
/// Writes `out` (JSON) and prints a human summary. With `gate`, the file
/// at `out` is first read as the committed baseline and the process fails
/// if any matching row's sim-ops/wall-sec regressed by more than 30%.
pub fn run_wallclock(quick: bool, out: &str, gate: bool) -> std::io::Result<()> {
    let baseline = if gate {
        match std::fs::read_to_string(out).ok().as_deref().and_then(parse_baseline) {
            Some(b) => Some(b),
            None => {
                eprintln!(
                    "[bench] gate: no measured baseline in {out} (placeholder or missing) — \
                     recording only, not gating"
                );
                None
            }
        }
    } else {
        None
    };
    // "1×" is the test-default dataset (Config::tiny): 60k objects.
    let (objects, ops, scale_label) = if quick {
        (60_000u64, 20_000u64, "1x")
    } else {
        (600_000u64, 60_000u64, "10x")
    };
    let mut runs: Vec<WallclockRun> = Vec::new();
    // Value-size sweep: wall time and resident bytes must not scale with
    // payload bytes (the O(entries) claim). The big-value run goes FIRST:
    // VmHWM is process-monotone, so the high-water mark it sets bounds the
    // 4× -payload footprint; `zone_phys_bytes` is the per-run flatness
    // signal (peak_rss_bytes of later runs inherits earlier marks).
    for value_size in [4000usize, 1000] {
        let label = format!("streaming-{scale_label}-v{value_size}");
        eprintln!("[bench] {label}: {objects} objects + {ops} YCSB-A ops ...");
        let r = run_one(&label, objects, ops, value_size, false);
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s, rss {} MiB, zone phys {} MiB / logical {} MiB",
            r.wall_secs,
            r.sim_ops_per_wall_sec,
            r.peak_rss_bytes >> 20,
            r.zone_phys_bytes >> 20,
            r.zone_logical_bytes >> 20,
        );
        runs.push(r);
    }
    // Same-binary merge-path comparison: the retained reference
    // (materialize-everything) pipeline vs the streaming merge.
    {
        let label = format!("reference-{scale_label}-v1000");
        eprintln!("[bench] {label}: reference merge pipeline ...");
        let r = run_one(&label, objects, ops, 1000, true);
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s",
            r.wall_secs, r.sim_ops_per_wall_sec
        );
        runs.push(r);
    }

    // The sharded frontend row: same protocol at 4 shards over one shared
    // clock + device pair, so the new path's wall cost is tracked.
    {
        let label = format!("sharded4-{scale_label}-v1000");
        eprintln!("[bench] {label}: 4-shard frontend ...");
        let r = run_one_sharded(&label, objects, ops, 1000, 4);
        eprintln!(
            "[bench] {label}: {:.1}s wall, {:.0} sim-ops/s",
            r.wall_secs, r.sim_ops_per_wall_sec
        );
        runs.push(r);
    }

    // runs[0] = streaming v4000, runs[1] = streaming v1000, runs[2] = reference v1000.
    let phys_ratio = runs[0].zone_phys_bytes as f64 / runs[1].zone_phys_bytes.max(1) as f64;
    let logical_ratio =
        runs[0].zone_logical_bytes as f64 / runs[1].zone_logical_bytes.max(1) as f64;
    let merge_speedup = runs[2].wall_secs / runs[1].wall_secs.max(1e-9);
    eprintln!(
        "[bench] value-size 4x sweep: zone phys ratio {phys_ratio:.2} (flat = O(entries)), \
         logical ratio {logical_ratio:.2}; streaming vs reference merge: {merge_speedup:.2}x"
    );

    let runs_json: Vec<String> = runs.iter().map(run_to_json).collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"wallclock\",\n",
            "  \"quick\": {},\n",
            "  \"note\": \"sim_ops_per_wall_sec = simulated client ops executed per wall-clock ",
            "second (load + YCSB-A). zone_phys_bytes must stay flat across the value_size ",
            "sweep (O(entries) memory); zone_logical_bytes scales with payload bytes. ",
            "peak_rss_bytes is the process-wide VmHWM and is monotone across runs (the ",
            "4x-payload run executes first so its mark bounds that footprint); use ",
            "zone_phys_bytes for per-run comparisons. The reference run uses the retained ",
            "pre-refactor materialize-everything merge pipeline in the same binary.\",\n",
            "  \"value_size_sweep\": {{ \"zone_phys_ratio\": {:.3}, \"zone_logical_ratio\": {:.3} }},\n",
            "  \"streaming_vs_reference_wall_ratio\": {:.3},\n",
            "  \"runs\": [\n{}\n  ]\n",
            "}}\n"
        ),
        quick,
        phys_ratio,
        logical_ratio,
        merge_speedup,
        runs_json.join(",\n"),
    );
    std::fs::write(out, json)?;
    eprintln!("[bench] wrote {out}");

    // Regression gate: compare against the committed baseline (read before
    // the overwrite above). Labels present in only one side are ignored so
    // adding/renaming rows never wedges CI.
    if let Some(base) = baseline {
        let mut regressions = Vec::new();
        for r in &runs {
            if let Some((_, old)) = base.iter().find(|(l, _)| *l == r.label) {
                let ratio = r.sim_ops_per_wall_sec / old.max(1e-9);
                eprintln!(
                    "[bench] gate: {} {:.0} vs baseline {:.0} sim-ops/s ({:.2}x)",
                    r.label, r.sim_ops_per_wall_sec, old, ratio
                );
                if ratio < GATE_MIN_RATIO {
                    regressions.push(format!(
                        "{}: {:.0} -> {:.0} sim-ops/s ({:.0}% of baseline)",
                        r.label,
                        old,
                        r.sim_ops_per_wall_sec,
                        ratio * 100.0
                    ));
                }
            }
        }
        if !regressions.is_empty() {
            return Err(std::io::Error::other(format!(
                "wallclock regression gate: sim-ops/wall-sec dropped >30% vs baseline: {}",
                regressions.join("; ")
            )));
        }
    }
    Ok(())
}
