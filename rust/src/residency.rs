//! Block-granular demand-paged residency — the per-domain manager that
//! decides whether zone-resident [`crate::wire::WireBuf`] contents keep
//! their physical bytes (entry headers + key suffixes) in RAM.
//!
//! The paging model: bytes at rest on a zoned device are *cold* and may
//! dehydrate to compact [`crate::wire::KeySynthRun`] descriptors; every
//! hydrated copy that leaves the device through a read — a block-cache
//! entry, an in-flight compaction/scan cursor's current block, a
//! WAL-recovery window — is a *pin* that keeps those bytes resident for
//! exactly as long as the copy lives. The [`crate::zone::ZonedDevice`]
//! read/write paths are the single choke point: `append` pages out
//! ([`Residency::page_out`]), every read pages in
//! ([`Residency::page_in`]), so zones, the WAL, and the SSD cache zones
//! all hold paged buffers without any per-caller plumbing.
//!
//! Paging is observationally free by construction: dehydration never
//! changes a buffer's *logical* length, and every size, offset, write
//! pointer, device-time charge, and digest in the simulator derives from
//! logical lengths. Rehydration costs host CPU only — zero virtual time.
//! One manager is shared across all shards of a domain (rebound in
//! `ShardedEngine::new` exactly like the shared timers/CPU pool/key
//! arena), so the paging knob and the paging counters are domain-global.

use crate::wire::WireBuf;
use std::cell::RefCell;
use std::rc::Rc;

/// Host-side paging counters (diagnostics; never part of the DES
/// timeline or digests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Entry heads elided across all `page_out` calls.
    pub dehydrated_runs: u64,
    /// Entry heads re-rendered across all `page_in` calls.
    pub rehydrated_runs: u64,
    /// Physical bytes released by dehydration (headers + keys).
    pub bytes_elided: u64,
    /// Physical bytes re-materialized by rehydration.
    pub bytes_restored: u64,
}

/// The per-domain residency manager. See the module docs.
#[derive(Debug)]
pub struct Residency {
    paging: bool,
    pub stats: ResidencyStats,
}

/// Shared handle: one manager per domain, one `Rc` per device.
pub type ResidencyHandle = Rc<RefCell<Residency>>;

impl Residency {
    /// A fresh manager; `paging = false` keeps every physical byte
    /// resident forever (the pre-residency behavior, bit-identical).
    pub fn new(paging: bool) -> ResidencyHandle {
        Rc::new(RefCell::new(Residency { paging, stats: ResidencyStats::default() }))
    }

    pub fn paging(&self) -> bool {
        self.paging
    }

    /// Page a buffer out on its way to a zone: returns the dehydrated
    /// copy when paging is on and something elides, `None` when the
    /// caller should append the original unchanged (no copy is made).
    pub fn page_out(&mut self, buf: &WireBuf) -> Option<WireBuf> {
        if !self.paging {
            return None;
        }
        let out = buf.dehydrate_copy()?;
        let elided = out.key_runs().len() - buf.key_runs().len();
        self.stats.dehydrated_runs += elided as u64;
        self.stats.bytes_elided += (buf.phys_len() - out.phys_len()) as u64;
        Some(out)
    }

    /// Page a buffer in on its way out of a zone: rehydrates
    /// unconditionally (data at rest may be dehydrated even after the
    /// paging knob is turned off mid-run — reads must always return
    /// fully resident bytes; the hydrated copy is the caller's pin).
    pub fn page_in(&mut self, buf: &mut WireBuf) {
        if buf.is_hydrated() {
            return;
        }
        let before = buf.phys_len();
        self.stats.rehydrated_runs += buf.key_runs().len() as u64;
        buf.hydrate();
        self.stats.bytes_restored += (buf.phys_len() - before) as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::Payload;

    fn entry_buf() -> WireBuf {
        let mut b = WireBuf::new();
        for i in 0..4u64 {
            b.push_entry(&crate::ycsb::key_for(i, 24), i, Some(Payload::fill(1, 50)));
        }
        b
    }

    #[test]
    fn page_out_then_in_round_trips_and_counts() {
        let h = Residency::new(true);
        let b = entry_buf();
        let mut d = h.borrow_mut().page_out(&b).expect("paging on elides");
        assert!(d.phys_len() < b.phys_len());
        assert_eq!(d.len(), b.len());
        h.borrow_mut().page_in(&mut d);
        assert_eq!(d, b);
        let stats = h.borrow().stats;
        assert_eq!(stats.dehydrated_runs, 4);
        assert_eq!(stats.rehydrated_runs, 4);
        assert_eq!(stats.bytes_elided, stats.bytes_restored);
        assert_eq!(stats.bytes_elided, 4 * (14 + 24));
    }

    #[test]
    fn paging_off_never_copies_but_still_hydrates_reads() {
        let h = Residency::new(false);
        let b = entry_buf();
        assert!(h.borrow_mut().page_out(&b).is_none());
        // A buffer dehydrated while the knob was on must still hydrate
        // on read after the knob is switched off.
        let mut d = b.dehydrate_copy().unwrap();
        h.borrow_mut().page_in(&mut d);
        assert_eq!(d, b);
    }

    #[test]
    fn page_out_skips_opaque_buffers() {
        let h = Residency::new(true);
        let raw = WireBuf::from_bytes(&[7u8; 4096]);
        assert!(h.borrow_mut().page_out(&raw).is_none());
        assert_eq!(h.borrow().stats, ResidencyStats::default());
    }
}
