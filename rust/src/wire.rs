//! Zero-materialization wire buffers — the data-plane representation that
//! makes simulator cost proportional to *entry count* instead of payload
//! bytes, and (since the key-interning refactor) proportional to *suffix
//! bytes* instead of full key bytes inside prefix-compressed blocks.
//!
//! A [`WireBuf`] is a byte string with two lengths:
//!
//! * a **logical** length — the exact number of bytes the materialized
//!   encoding would occupy. Every size, offset, block handle, zone write
//!   pointer, device-time charge, and metric in the simulator is computed
//!   from logical lengths, so the whole DES behaves bit-identically to an
//!   engine that stores real payload bytes;
//! * a **physical** length — what is actually resident in RAM. Entry
//!   headers and key *suffixes* are stored physically; value payloads are
//!   carried as [`SynthRun`]s (logical length + 32-bit content
//!   fingerprint) occupying zero physical bytes, and restart-point shared
//!   key prefixes are carried as [`PrefixRun`]s that point back at the
//!   restart key's bytes elsewhere in the same buffer.
//!
//! The logical layout of one encoded entry is byte-compatible with the
//! seed engine's on-disk format:
//!
//! ```text
//! [klen u16][vlen u32][seq u64][key: klen bytes][value: vlen bytes]
//! ```
//!
//! where `vlen == u32::MAX` marks a tombstone. Physically the value bytes
//! are elided (identity survives as the run's fingerprint) and, for
//! entries pushed with [`WireBuf::push_entry_shared`], the first `shared`
//! key bytes are elided too — they are recovered from the restart key the
//! run references, so decode returns the exact key that was written.
//! Decoded keys are [`KeyView`]s: a zero-copy two-part borrow
//! (shared-prefix slice + suffix slice) comparing exactly like the
//! contiguous key.
//!
//! Buffers can be sliced at *arbitrary* logical offsets (zenfs splits
//! files at HDD zone-capacity boundaries that may fall inside a value or
//! a shared prefix): runs are split into partial runs and re-assembled
//! transparently on concatenation; a slice that severs a prefix run from
//! its restart key simply stops decoding (the truncation contract).

use crate::sim::rng::fingerprint32;

/// Logical size of an encoded entry header (klen + vlen + seq).
pub const ENTRY_HEADER: usize = 14;

/// Compact stand-in for value bytes: logical length plus a 32-bit content
/// fingerprint. Payload equality is only meaningful between payloads built
/// by the same constructor ([`Payload::from_bytes`] fingerprints real
/// bytes; [`Payload::fill`] fingerprints the `(byte, len)` fill pattern in
/// O(1) without materializing it).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Payload {
    /// Logical value size in bytes (drives all size accounting).
    pub len: u32,
    /// 32-bit content fingerprint (identity only, not invertible).
    pub fingerprint: u32,
}

impl Payload {
    /// Fingerprint real bytes (API boundary: `Engine::put`, tests).
    pub fn from_bytes(bytes: &[u8]) -> Payload {
        Payload { len: bytes.len() as u32, fingerprint: fingerprint32(bytes) }
    }

    /// Fingerprint the fill pattern "`len` copies of `byte`" in O(1) —
    /// the YCSB value generator's shape (`vec![b; value_size]` in the
    /// seed engine) without touching `len` bytes.
    pub fn fill(byte: u8, len: usize) -> Payload {
        if len == 0 {
            return Payload::from_bytes(&[]);
        }
        // splitmix64 over (len, byte).
        let mut z = (((len as u64) << 8) | byte as u64).wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        Payload { len: len as u32, fingerprint: ((z >> 32) ^ z) as u32 }
    }
}

/// One synthetic (payload) run inside a [`WireBuf`]: `len` logical bytes
/// at `log_off`, zero physical bytes, identified by the value fingerprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SynthRun {
    /// Logical offset of the run within its buffer.
    pub log_off: u64,
    /// Logical bytes covered by this run.
    pub len: u32,
    /// Fingerprint of the (whole) value this run belongs to. Partial runs
    /// produced by slicing carry the full value's fingerprint.
    pub fp: u32,
    /// Synthetic bytes in all earlier runs (prefix sum for O(log n)
    /// logical→physical offset translation).
    synth_before: u64,
}

/// One elided shared-key-prefix run: `len` logical key bytes at `log_off`
/// that are not stored physically — they are the bytes at logical offset
/// `src_log` (the restart key's prefix) of the SAME buffer. `src_log` is
/// signed: slicing can strand a run after its source, leaving a negative
/// (undecodable until re-joined) reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefixRun {
    pub log_off: u64,
    pub len: u32,
    pub src_log: i64,
    /// Prefix-elided bytes in all earlier prefix runs.
    elided_before: u64,
}

/// One dehydrated entry head: the header + full key of a single encoded
/// entry carried as a compact synthetic record — `span()` logical bytes
/// at `log_off`, zero physical bytes. Only entries whose key is the
/// deterministic YCSB form `"user" + decimal digits` dehydrate (the key
/// analogue of value [`SynthRun`]s): the digit-field value `key_num` is
/// recovered by [`crate::ycsb::parse_user_key`], which verifies at
/// dehydration time that re-rendering it at width `klen - 4` reproduces
/// the key byte-for-byte, so rehydration is bit-identical by
/// construction. Runs always cover a full head; a slice that cuts one
/// materializes the overlapped bytes instead of storing a partial run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KeySynthRun {
    /// Logical offset of the entry head (header byte 0) in its buffer.
    pub log_off: u64,
    /// Full key length, including the 4-byte `"user"` tag.
    pub klen: u16,
    /// The key's digit-field value (`fnv1a(item) mod 10^(klen-4)`).
    pub key_num: u64,
    /// The entry's sequence number (header field).
    pub seq: u64,
    /// The entry's raw `vlen` header field (`u32::MAX` = tombstone).
    pub vlen_raw: u32,
    /// Head bytes elided by all earlier key runs (prefix sum for
    /// O(log n) logical→physical offset translation).
    elided_before: u64,
}

impl KeySynthRun {
    /// Logical bytes covered: the entry header plus the whole key.
    pub fn span(&self) -> u64 {
        ENTRY_HEADER as u64 + self.klen as u64
    }

    /// Materialize the covered bytes (header + key) onto `out`.
    fn render_onto(&self, out: &mut Vec<u8>) {
        let mut hdr = [0u8; ENTRY_HEADER];
        hdr[0..2].copy_from_slice(&self.klen.to_le_bytes());
        hdr[2..6].copy_from_slice(&self.vlen_raw.to_le_bytes());
        hdr[6..14].copy_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&hdr);
        let start = out.len();
        out.extend_from_slice(b"user");
        out.resize(start + self.klen as usize, 0);
        crate::ycsb::render_key_digits(self.key_num, &mut out[start + 4..]);
    }
}

/// A zero-copy decoded key: the restart key's shared prefix plus this
/// entry's stored suffix, borrowed from the buffer. Compares exactly
/// like the contiguous `prefix ++ suffix` byte string (equal views hash
/// equal, but the hash is NOT interchangeable with `<[u8] as Hash>` —
/// materialize through [`crate::lsm::KeyRef`] for byte-keyed maps); a
/// non-compressed key is simply `(empty, full)`.
#[derive(Clone, Copy)]
pub struct KeyView<'a> {
    pre: &'a [u8],
    suf: &'a [u8],
}

impl<'a> KeyView<'a> {
    pub fn new(pre: &'a [u8], suf: &'a [u8]) -> KeyView<'a> {
        KeyView { pre, suf }
    }

    pub fn from_slice(s: &'a [u8]) -> KeyView<'a> {
        KeyView { pre: &[], suf: s }
    }

    pub fn len(&self) -> usize {
        self.pre.len() + self.suf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The key bytes, in order.
    pub fn bytes(&self) -> impl Iterator<Item = u8> + 'a {
        self.pre.iter().chain(self.suf.iter()).copied()
    }

    pub fn to_vec(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(self.len());
        v.extend_from_slice(self.pre);
        v.extend_from_slice(self.suf);
        v
    }

    /// Overwrite `out` with this key's bytes (reused-buffer form).
    pub fn copy_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(self.pre);
        out.extend_from_slice(self.suf);
    }

    /// Lexicographic comparison against a contiguous key (the chunked
    /// slice-compare loop of [`Ord`] — one code path for all orderings).
    pub fn cmp_bytes(&self, other: &[u8]) -> std::cmp::Ordering {
        self.cmp(&KeyView::from_slice(other))
    }

    pub fn eq_bytes(&self, other: &[u8]) -> bool {
        self.len() == other.len() && self.cmp_bytes(other) == std::cmp::Ordering::Equal
    }
}

impl PartialEq for KeyView<'_> {
    fn eq(&self, other: &KeyView<'_>) -> bool {
        self.len() == other.len() && self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for KeyView<'_> {}

impl PartialOrd for KeyView<'_> {
    fn partial_cmp(&self, other: &KeyView<'_>) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for KeyView<'_> {
    /// Lexicographic over the concatenated segments, comparing aligned
    /// chunks with slice (memcmp) compares.
    fn cmp(&self, other: &KeyView<'_>) -> std::cmp::Ordering {
        let (mut a0, mut a1) = (self.pre, self.suf);
        let (mut b0, mut b1) = (other.pre, other.suf);
        loop {
            if a0.is_empty() {
                a0 = std::mem::take(&mut a1);
            }
            if b0.is_empty() {
                b0 = std::mem::take(&mut b1);
            }
            if a0.is_empty() || b0.is_empty() {
                // One side exhausted: the longer remainder is greater.
                return (a0.len() + a1.len()).cmp(&(b0.len() + b1.len()));
            }
            let n = a0.len().min(b0.len());
            match a0[..n].cmp(&b0[..n]) {
                std::cmp::Ordering::Equal => {
                    a0 = &a0[n..];
                    b0 = &b0[n..];
                }
                ord => return ord,
            }
        }
    }
}

impl std::hash::Hash for KeyView<'_> {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        for b in self.bytes() {
            state.write_u8(b);
        }
    }
}

impl std::fmt::Debug for KeyView<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KeyView({:?})", String::from_utf8_lossy(&self.to_vec()))
    }
}

/// A decoded entry borrowing its key from the buffer it was decoded from
/// (the zero-copy view used by point lookups, scans, and the streaming
/// compaction merge).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EntryRef<'a> {
    pub key: KeyView<'a>,
    pub seq: u64,
    /// `None` is a tombstone.
    pub value: Option<Payload>,
}

impl EntryRef<'_> {
    /// Logical encoded size of this entry.
    pub fn encoded_len(&self) -> usize {
        ENTRY_HEADER + self.key.len() + self.value.map_or(0, |p| p.len as usize)
    }
}

/// Raw decode result carrying buffer positions instead of borrows (used by
/// cursors that own their buffer, e.g. the compaction block streams). The
/// key is two physical ranges: the (possibly empty) shared prefix at the
/// restart key, and the stored suffix.
#[derive(Clone, Copy, Debug)]
pub(crate) struct RawEntry {
    pub pre_off: usize,
    pub pre_len: usize,
    pub suf_off: usize,
    pub suf_len: usize,
    pub seq: u64,
    pub value: Option<Payload>,
    pub next_log: u64,
    pub next_phys: usize,
    pub next_run: usize,
    pub next_prun: usize,
}

/// The zero-materialization byte buffer. See the module docs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WireBuf {
    phys: Vec<u8>,
    /// Synthetic (value) runs sorted by `log_off`; runs never overlap and
    /// always lie inside the value region of exactly one encoded entry.
    runs: Vec<SynthRun>,
    /// Elided shared-key-prefix runs sorted by `log_off`; each lies at the
    /// start of exactly one encoded entry's key region.
    prefix_runs: Vec<PrefixRun>,
    /// Dehydrated entry heads sorted by `log_off` (residency paging);
    /// disjoint from each other and from the two run lists above, each
    /// covering exactly one entry's header + key.
    key_runs: Vec<KeySynthRun>,
    log_len: u64,
}

impl WireBuf {
    pub fn new() -> WireBuf {
        WireBuf::default()
    }

    /// A buffer of real bytes only (no synthetic runs).
    pub fn from_bytes(bytes: &[u8]) -> WireBuf {
        WireBuf {
            phys: bytes.to_vec(),
            runs: Vec::new(),
            prefix_runs: Vec::new(),
            key_runs: Vec::new(),
            log_len: bytes.len() as u64,
        }
    }

    /// Logical length — the materialized encoding's byte count.
    pub fn len(&self) -> u64 {
        self.log_len
    }

    pub fn is_empty(&self) -> bool {
        self.log_len == 0
    }

    /// Physically resident bytes (headers + key suffixes + padding).
    pub fn phys_len(&self) -> usize {
        self.phys.len()
    }

    /// The physical bytes (raw-byte buffers: identical to the content).
    pub fn phys_bytes(&self) -> &[u8] {
        &self.phys
    }

    pub fn runs(&self) -> &[SynthRun] {
        &self.runs
    }

    pub fn prefix_runs(&self) -> &[PrefixRun] {
        &self.prefix_runs
    }

    pub fn key_runs(&self) -> &[KeySynthRun] {
        &self.key_runs
    }

    /// True when no entry heads are dehydrated — every logical byte that
    /// is not a synthetic value/prefix/padding run is resident in `phys`.
    pub fn is_hydrated(&self) -> bool {
        self.key_runs.is_empty()
    }

    pub fn clear(&mut self) {
        self.phys.clear();
        self.runs.clear();
        self.prefix_runs.clear();
        self.key_runs.clear();
        self.log_len = 0;
    }

    pub fn reserve_phys(&mut self, additional: usize) {
        self.phys.reserve(additional);
    }

    fn total_synth(&self) -> u64 {
        self.runs.last().map_or(0, |r| r.synth_before + r.len as u64)
    }

    fn total_elided(&self) -> u64 {
        self.prefix_runs.last().map_or(0, |r| r.elided_before + r.len as u64)
    }

    fn total_key_elided(&self) -> u64 {
        self.key_runs.last().map_or(0, |r| r.elided_before + r.span())
    }

    /// Append real bytes.
    pub fn push_bytes(&mut self, bytes: &[u8]) {
        self.phys.extend_from_slice(bytes);
        self.log_len += bytes.len() as u64;
    }

    /// Append `n` zero bytes (physically resident).
    pub fn push_zeros(&mut self, n: usize) {
        self.phys.extend(std::iter::repeat(0u8).take(n));
        self.log_len += n as u64;
    }

    /// Append `n` logical padding bytes occupying zero physical bytes (a
    /// fingerprint-0 synthetic run). The SST index/bloom reservation uses
    /// this: those structures live decoded in `SstMeta`, so keeping the
    /// on-media copy as physical zeros would charge them against
    /// residency twice. Unlike [`WireBuf::push_zeros`] — whose zeros
    /// decode as bogus empty entries — decode treats synthetic padding
    /// as opaque and stops there.
    pub fn push_pad(&mut self, n: usize) {
        if n == 0 {
            return;
        }
        let synth_before = self.total_synth();
        self.runs.push(SynthRun { log_off: self.log_len, len: n as u32, fp: 0, synth_before });
        self.log_len += n as u64;
    }

    /// Append a value payload as a synthetic run (`p.len` logical bytes,
    /// zero physical).
    pub fn push_payload(&mut self, p: Payload) {
        if p.len == 0 {
            return;
        }
        let synth_before = self.total_synth();
        self.runs.push(SynthRun {
            log_off: self.log_len,
            len: p.len,
            fp: p.fingerprint,
            synth_before,
        });
        self.log_len += p.len as u64;
    }

    fn push_header(&mut self, klen: usize, seq: u64, value: Option<Payload>) {
        let mut hdr = [0u8; ENTRY_HEADER];
        hdr[0..2].copy_from_slice(&(klen as u16).to_le_bytes());
        let vlen = match value {
            Some(p) => p.len,
            None => u32::MAX,
        };
        hdr[2..6].copy_from_slice(&vlen.to_le_bytes());
        hdr[6..14].copy_from_slice(&seq.to_le_bytes());
        self.push_bytes(&hdr);
    }

    /// Append one encoded entry (header + full key physically, value as a
    /// run).
    pub fn push_entry(&mut self, key: &[u8], seq: u64, value: Option<Payload>) {
        self.push_header(key.len(), seq, value);
        self.push_bytes(key);
        if let Some(p) = value {
            self.push_payload(p);
        }
    }

    /// Append one encoded entry whose first `shared` key bytes equal the
    /// bytes at logical offset `src_log` of THIS buffer (the restart key
    /// of the running interval, which must be stored fully physically).
    /// Logical layout and length are identical to [`WireBuf::push_entry`];
    /// physically only the suffix after `shared` lands in RAM.
    pub fn push_entry_shared(
        &mut self,
        key: &[u8],
        shared: usize,
        src_log: u64,
        seq: u64,
        value: Option<Payload>,
    ) {
        debug_assert!(shared <= key.len());
        debug_assert!(src_log + shared as u64 <= self.log_len, "source must precede the entry");
        if shared == 0 {
            self.push_entry(key, seq, value);
            return;
        }
        self.push_header(key.len(), seq, value);
        let elided_before = self.total_elided();
        self.prefix_runs.push(PrefixRun {
            log_off: self.log_len,
            len: shared as u32,
            src_log: src_log as i64,
            elided_before,
        });
        self.log_len += shared as u64;
        self.push_bytes(&key[shared..]);
        if let Some(p) = value {
            self.push_payload(p);
        }
    }

    /// Physical offset of logical position `log`. Positions strictly
    /// inside a synthetic, prefix, or key run map to the run's physical
    /// start.
    fn phys_of(&self, log: u64) -> usize {
        let idx = self.runs.partition_point(|r| r.log_off < log);
        let synth = if idx == 0 {
            0
        } else {
            let r = &self.runs[idx - 1];
            r.synth_before + (r.len as u64).min(log - r.log_off)
        };
        let pidx = self.prefix_runs.partition_point(|r| r.log_off < log);
        let elided = if pidx == 0 {
            0
        } else {
            let r = &self.prefix_runs[pidx - 1];
            r.elided_before + (r.len as u64).min(log - r.log_off)
        };
        let kidx = self.key_runs.partition_point(|r| r.log_off < log);
        let kelided = if kidx == 0 {
            0
        } else {
            let r = &self.key_runs[kidx - 1];
            r.elided_before + r.span().min(log - r.log_off)
        };
        (log - synth - elided - kelided) as usize
    }

    /// Copy out the logical range `[off, off + len)` as an owned buffer.
    /// Slicing may split runs; each synthetic part keeps the full value's
    /// fingerprint, each prefix part keeps a source reference to its own
    /// first byte (possibly negative when the source falls before the
    /// slice), and decoding re-joins adjacent parts.
    pub fn slice_to_buf(&self, off: u64, len: u64) -> WireBuf {
        let end = off + len;
        assert!(end <= self.log_len, "slice [{off}, {end}) outside len {}", self.log_len);
        let ps = self.phys_of(off);
        let pe = self.phys_of(end);
        let first = self.runs.partition_point(|r| r.log_off + r.len as u64 <= off);
        let mut runs = Vec::new();
        let mut synth_acc = 0u64;
        for r in &self.runs[first..] {
            if r.log_off >= end {
                break;
            }
            let s = r.log_off.max(off);
            let e = (r.log_off + r.len as u64).min(end);
            runs.push(SynthRun {
                log_off: s - off,
                len: (e - s) as u32,
                fp: r.fp,
                synth_before: synth_acc,
            });
            synth_acc += e - s;
        }
        let pfirst = self.prefix_runs.partition_point(|r| r.log_off + r.len as u64 <= off);
        let mut prefix_runs = Vec::new();
        let mut elided_acc = 0u64;
        for r in &self.prefix_runs[pfirst..] {
            if r.log_off >= end {
                break;
            }
            let s = r.log_off.max(off);
            let e = (r.log_off + r.len as u64).min(end);
            prefix_runs.push(PrefixRun {
                log_off: s - off,
                len: (e - s) as u32,
                // Source of the part's FIRST byte, rebased to slice coords.
                src_log: r.src_log + (s - r.log_off) as i64 - off as i64,
                elided_before: elided_acc,
            });
            elided_acc += e - s;
        }
        // Key runs always cover a full entry head: fully-contained runs
        // are carried over (rebased), while a run cut by either slice
        // edge materializes its overlapped bytes — partial key runs are
        // never stored. `phys_of` maps positions inside a key run to the
        // run's physical start, so the materialized head fragment sits
        // exactly before (slice starts mid-run) or after (slice ends
        // mid-run) the copied physical range.
        let kfirst = self.key_runs.partition_point(|r| r.log_off + r.span() <= off);
        let mut key_runs = Vec::new();
        let mut head_frag: Vec<u8> = Vec::new();
        let mut tail_frag: Vec<u8> = Vec::new();
        let mut key_acc = 0u64;
        let mut rendered: Vec<u8> = Vec::new();
        for r in &self.key_runs[kfirst..] {
            if r.log_off >= end {
                break;
            }
            let r_end = r.log_off + r.span();
            if r.log_off >= off && r_end <= end {
                key_runs.push(KeySynthRun {
                    log_off: r.log_off - off,
                    elided_before: key_acc,
                    ..*r
                });
                key_acc += r.span();
            } else {
                rendered.clear();
                r.render_onto(&mut rendered);
                let s = (off.max(r.log_off) - r.log_off) as usize;
                let e = (end.min(r_end) - r.log_off) as usize;
                if r.log_off < off {
                    head_frag.extend_from_slice(&rendered[s..e]);
                } else {
                    tail_frag.extend_from_slice(&rendered[s..e]);
                }
            }
        }
        let mut phys = head_frag;
        phys.extend_from_slice(&self.phys[ps..pe]);
        phys.extend_from_slice(&tail_frag);
        WireBuf { phys, runs, prefix_runs, key_runs, log_len: len }
    }

    /// Append another buffer's content (logical concatenation).
    pub fn append_buf(&mut self, other: &WireBuf) {
        let base_log = self.log_len;
        let base_synth = self.total_synth();
        let base_elided = self.total_elided();
        let base_kelided = self.total_key_elided();
        self.phys.extend_from_slice(&other.phys);
        for r in &other.runs {
            self.runs.push(SynthRun {
                log_off: base_log + r.log_off,
                len: r.len,
                fp: r.fp,
                synth_before: base_synth + r.synth_before,
            });
        }
        for r in &other.prefix_runs {
            self.prefix_runs.push(PrefixRun {
                log_off: base_log + r.log_off,
                len: r.len,
                src_log: r.src_log + base_log as i64,
                elided_before: base_elided + r.elided_before,
            });
        }
        for r in &other.key_runs {
            self.key_runs.push(KeySynthRun {
                log_off: base_log + r.log_off,
                elided_before: base_kelided + r.elided_before,
                ..*r
            });
        }
        self.log_len += other.log_len;
    }

    /// Dehydrate: scan for entries whose head (header + key) can be
    /// elided into a [`KeySynthRun`] — a fully-physical, non-prefix-
    /// compressed head whose key parses as `"user" + digits`
    /// ([`crate::ycsb::parse_user_key`], which verifies the re-render is
    /// byte-identical) and is not referenced as a prefix-run source
    /// (restart keys must stay physical for prefix decode). Returns the
    /// dehydrated copy, or `None` when nothing elides so the caller can
    /// keep the original without copying. Logical length, slicing,
    /// concatenation, and post-[`WireBuf::hydrate`] bytes are all
    /// bit-identical to the original; already-dehydrated runs are kept
    /// (the scan steps over them), making dehydration idempotent.
    pub fn dehydrate_copy(&self) -> Option<WireBuf> {
        if self.phys.is_empty() {
            return None;
        }
        // Logical ranges referenced as prefix sources; heads intersecting
        // any of them must stay resident.
        let mut src_ranges: Vec<(u64, u64)> = self
            .prefix_runs
            .iter()
            .filter(|r| r.src_log >= 0)
            .map(|r| (r.src_log as u64, r.src_log as u64 + r.len as u64))
            .collect();
        src_ranges.sort_unstable();
        let head_in_source = |s: u64, e: u64| {
            let i = src_ranges.partition_point(|&(_, re)| re <= s);
            src_ranges.get(i).is_some_and(|&(rs, _)| rs < e)
        };
        let mut cands: Vec<KeySynthRun> = Vec::new();
        let (mut log, mut phys, mut run, mut prun) = (0u64, 0usize, 0usize, 0usize);
        let mut kidx = 0usize;
        loop {
            // Step over an already-dehydrated entry (head run + value).
            if let Some(r) = self.key_runs.get(kidx) {
                if r.log_off == log {
                    log += r.span();
                    if r.vlen_raw != u32::MAX {
                        log += r.vlen_raw as u64;
                    }
                    phys = self.phys_of(log);
                    run = self.runs.partition_point(|x| x.log_off < log);
                    prun = self.prefix_runs.partition_point(|x| x.log_off < log);
                    kidx += 1;
                    continue;
                }
            }
            let Some(raw) = self.decode_entry_raw(log, phys, run, prun) else {
                // Resync: a zone-boundary slice can start mid-value, so
                // the cursor may sit inside a synthetic run — skip to its
                // end (the next entry head) and retry. Anything else
                // (end, torn tail, padding, severed prefix, raw bytes)
                // is opaque and the remainder stays resident.
                let i = self.runs.partition_point(|r| r.log_off + r.len as u64 <= log);
                if let Some(r) = self.runs.get(i) {
                    if r.log_off <= log {
                        log = r.log_off + r.len as u64;
                        phys = self.phys_of(log);
                        run = self.runs.partition_point(|x| x.log_off < log);
                        prun = self.prefix_runs.partition_point(|x| x.log_off < log);
                        continue;
                    }
                }
                break;
            };
            if raw.pre_len == 0 && raw.suf_len > 0 {
                let head_end = log + ENTRY_HEADER as u64 + raw.suf_len as u64;
                if !head_in_source(log, head_end) {
                    let key = &self.phys[raw.suf_off..raw.suf_off + raw.suf_len];
                    if let Some(key_num) = crate::ycsb::parse_user_key(key) {
                        cands.push(KeySynthRun {
                            log_off: log,
                            klen: raw.suf_len as u16,
                            key_num,
                            seq: raw.seq,
                            vlen_raw: match raw.value {
                                None => u32::MAX,
                                Some(p) => p.len,
                            },
                            elided_before: 0, // fixed after the merge below
                        });
                    }
                }
            }
            log = raw.next_log;
            phys = raw.next_phys;
            run = raw.next_run;
            prun = raw.next_prun;
        }
        if cands.is_empty() {
            return None;
        }
        // Build the copy: drop each candidate's (contiguous) physical
        // head bytes and merge old + new key runs in logical order.
        let mut new_phys: Vec<u8> = Vec::with_capacity(self.phys.len());
        let mut src = 0usize;
        for c in &cands {
            let p = self.phys_of(c.log_off);
            new_phys.extend_from_slice(&self.phys[src..p]);
            src = p + c.span() as usize;
        }
        new_phys.extend_from_slice(&self.phys[src..]);
        let mut key_runs: Vec<KeySynthRun> =
            Vec::with_capacity(self.key_runs.len() + cands.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.key_runs.len() || j < cands.len() {
            let old_next = j >= cands.len()
                || (i < self.key_runs.len() && self.key_runs[i].log_off < cands[j].log_off);
            if old_next {
                key_runs.push(self.key_runs[i]);
                i += 1;
            } else {
                key_runs.push(cands[j]);
                j += 1;
            }
        }
        let mut acc = 0u64;
        for r in &mut key_runs {
            r.elided_before = acc;
            acc += r.span();
        }
        let old_total = self.phys.len() as u64 + self.total_key_elided();
        debug_assert_eq!(new_phys.len() as u64 + acc, old_total);
        Some(WireBuf {
            phys: new_phys,
            runs: self.runs.clone(),
            prefix_runs: self.prefix_runs.clone(),
            key_runs,
            log_len: self.log_len,
        })
    }

    /// Rehydrate every dehydrated entry head back into physical bytes —
    /// the bit-identical inverse of [`WireBuf::dehydrate_copy`] (keys
    /// re-render through the same fixed-width generator that was
    /// round-trip-verified at dehydration time). Idempotent; value and
    /// prefix runs are untouched.
    pub fn hydrate(&mut self) {
        if self.key_runs.is_empty() {
            return;
        }
        let mut phys =
            Vec::with_capacity(self.phys.len() + self.total_key_elided() as usize);
        let mut src = 0usize;
        for i in 0..self.key_runs.len() {
            let r = self.key_runs[i];
            let p = self.phys_of(r.log_off);
            phys.extend_from_slice(&self.phys[src..p]);
            r.render_onto(&mut phys);
            src = p;
        }
        phys.extend_from_slice(&self.phys[src..]);
        self.phys = phys;
        self.key_runs.clear();
    }

    /// Decode the entry at the given cursor positions. Returns `None` at
    /// end-of-buffer or on truncation/malformation (mirrors the seed
    /// decoder's truncation semantics; a prefix run severed from its
    /// restart key counts as truncation).
    pub(crate) fn decode_entry_raw(
        &self,
        log: u64,
        phys: usize,
        run: usize,
        prun: usize,
    ) -> Option<RawEntry> {
        if log >= self.log_len || phys + ENTRY_HEADER > self.phys.len() {
            return None;
        }
        // A dehydrated entry head at (or overlapping) the cursor cannot
        // be decoded zero-copy — its key bytes are not resident. Treat it
        // as truncation, exactly like a severed prefix run: callers
        // rehydrate (the device read path always does) before decoding.
        if !self.key_runs.is_empty() {
            let k = self.key_runs.partition_point(|r| r.log_off + r.span() <= log);
            if let Some(r) = self.key_runs.get(k) {
                if r.log_off < log + ENTRY_HEADER as u64 {
                    return None;
                }
            }
        }
        let klen = u16::from_le_bytes(self.phys[phys..phys + 2].try_into().unwrap()) as usize;
        let vlen_raw = u32::from_le_bytes(self.phys[phys + 2..phys + 6].try_into().unwrap());
        let seq = u64::from_le_bytes(self.phys[phys + 6..phys + 14].try_into().unwrap());
        let key_log = log + ENTRY_HEADER as u64;
        // Collect the (contiguous) elided prefix of this key, if any.
        let mut next_prun = prun;
        let mut shared = 0usize;
        let mut src_start: i64 = 0;
        while let Some(r) = self.prefix_runs.get(next_prun) {
            if r.log_off != key_log + shared as u64 || shared >= klen {
                break;
            }
            if shared == 0 {
                src_start = r.src_log;
            } else if r.src_log != src_start + shared as i64 {
                return None; // parts of one prefix must share one source
            }
            shared += r.len as usize;
            next_prun += 1;
        }
        if shared > klen {
            return None;
        }
        let (pre_off, pre_len) = if shared > 0 {
            if src_start < 0 {
                return None; // source severed by slicing
            }
            let src = src_start as u64;
            if src + shared as u64 > self.log_len {
                return None;
            }
            let sp = self.phys_of(src);
            let se = self.phys_of(src + shared as u64);
            if se - sp != shared || se > self.phys.len() {
                return None; // source region not fully physical
            }
            (sp, shared)
        } else {
            (0, 0)
        };
        let suf_len = klen - shared;
        let suf_off = phys + ENTRY_HEADER;
        if suf_off + suf_len > self.phys.len() {
            return None;
        }
        let mut next_log = key_log + klen as u64;
        let next_phys = suf_off + suf_len;
        let mut next_run = run;
        let value = if vlen_raw == u32::MAX {
            None
        } else if vlen_raw == 0 {
            Some(Payload::from_bytes(&[]))
        } else {
            let vlen = vlen_raw as u64;
            if next_log + vlen > self.log_len {
                return None;
            }
            let mut covered = 0u64;
            let mut fp: Option<u32> = None;
            while covered < vlen {
                let r = self.runs.get(next_run)?;
                if r.log_off != next_log + covered || covered + r.len as u64 > vlen {
                    return None; // run/value mismatch: malformed buffer
                }
                fp.get_or_insert(r.fp);
                covered += r.len as u64;
                next_run += 1;
            }
            next_log += vlen;
            Some(Payload { len: vlen_raw, fingerprint: fp.unwrap_or(0) })
        };
        if next_log > self.log_len {
            return None;
        }
        Some(RawEntry {
            pre_off,
            pre_len,
            suf_off,
            suf_len,
            seq,
            value,
            next_log,
            next_phys,
            next_run,
            next_prun,
        })
    }

    /// The two-part borrowed key of a decoded entry.
    pub(crate) fn key_view_at(
        &self,
        pre_off: usize,
        pre_len: usize,
        suf_off: usize,
        suf_len: usize,
    ) -> KeyView<'_> {
        KeyView::new(
            &self.phys[pre_off..pre_off + pre_len],
            &self.phys[suf_off..suf_off + suf_len],
        )
    }

    /// Iterate the encoded entries (zero-copy keys).
    pub fn entries(&self) -> EntryCursor<'_> {
        EntryCursor { buf: self, log: 0, phys: 0, run: 0, prun: 0 }
    }
}

/// Sequential zero-copy decoder over a [`WireBuf`].
pub struct EntryCursor<'a> {
    buf: &'a WireBuf,
    log: u64,
    phys: usize,
    run: usize,
    prun: usize,
}

impl<'a> Iterator for EntryCursor<'a> {
    type Item = EntryRef<'a>;

    fn next(&mut self) -> Option<EntryRef<'a>> {
        let raw = self.buf.decode_entry_raw(self.log, self.phys, self.run, self.prun)?;
        self.log = raw.next_log;
        self.phys = raw.next_phys;
        self.run = raw.next_run;
        self.prun = raw.next_prun;
        Some(EntryRef {
            key: self.buf.key_view_at(raw.pre_off, raw.pre_len, raw.suf_off, raw.suf_len),
            seq: raw.seq,
            value: raw.value,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logical_layout_matches_materialized_format() {
        let mut b = WireBuf::new();
        b.push_entry(b"user123", 42, Some(Payload::fill(7, 100)));
        // 14-byte header + 7-byte key + 100 value bytes, logically.
        assert_eq!(b.len(), 14 + 7 + 100);
        // Physically only header + key are resident.
        assert_eq!(b.phys_len(), 14 + 7);
        let e = b.entries().next().unwrap();
        assert_eq!(e.key.to_vec(), b"user123");
        assert_eq!(e.seq, 42);
        assert_eq!(e.value, Some(Payload::fill(7, 100)));
        assert_eq!(e.encoded_len() as u64, b.len());
    }

    #[test]
    fn tombstone_and_empty_value_roundtrip() {
        let mut b = WireBuf::new();
        b.push_entry(b"k", 1, None);
        b.push_entry(b"l", 2, Some(Payload::from_bytes(&[])));
        let es: Vec<_> = b.entries().collect();
        assert_eq!(es.len(), 2);
        assert_eq!(es[0].value, None);
        assert_eq!(es[1].value, Some(Payload::from_bytes(&[])));
        assert_eq!(b.len(), 14 + 1 + 14 + 1);
    }

    #[test]
    fn many_entries_decode_in_order() {
        let mut b = WireBuf::new();
        let payloads: Vec<Payload> =
            (0..50u64).map(|i| Payload::fill((i % 251) as u8, 64 + i as usize)).collect();
        for (i, p) in payloads.iter().enumerate() {
            b.push_entry(format!("key{i:03}").as_bytes(), i as u64, Some(*p));
        }
        let decoded: Vec<_> = b.entries().collect();
        assert_eq!(decoded.len(), 50);
        for (i, e) in decoded.iter().enumerate() {
            assert_eq!(e.key.to_vec(), format!("key{i:03}").as_bytes());
            assert_eq!(e.seq, i as u64);
            assert_eq!(e.value, Some(payloads[i]));
        }
    }

    #[test]
    fn slice_at_entry_boundaries_preserves_entries() {
        let mut b = WireBuf::new();
        let mut offsets = vec![0u64];
        for i in 0..10u64 {
            b.push_entry(format!("k{i}").as_bytes(), i, Some(Payload::fill(1, 500)));
            offsets.push(b.len());
        }
        for w in offsets.windows(2) {
            let s = b.slice_to_buf(w[0], w[1] - w[0]);
            let es: Vec<_> = s.entries().collect();
            assert_eq!(es.len(), 1);
            assert_eq!(es[0].value, Some(Payload::fill(1, 500)));
        }
    }

    #[test]
    fn arbitrary_split_and_reassembly_is_lossless() {
        // Split the buffer at every possible logical offset (including
        // inside headers, keys, and synthetic runs) and re-concatenate:
        // the result must decode identically.
        let mut b = WireBuf::new();
        for i in 0..8u64 {
            let v = if i % 3 == 0 { None } else { Some(Payload::fill(i as u8, 37)) };
            b.push_entry(format!("key{i}").as_bytes(), i, v);
        }
        let want: Vec<(Vec<u8>, u64, Option<Payload>)> =
            b.entries().map(|e| (e.key.to_vec(), e.seq, e.value)).collect();
        for cut in 0..=b.len() {
            let mut joined = b.slice_to_buf(0, cut);
            joined.append_buf(&b.slice_to_buf(cut, b.len() - cut));
            assert_eq!(joined.len(), b.len());
            let got: Vec<(Vec<u8>, u64, Option<Payload>)> =
                joined.entries().map(|e| (e.key.to_vec(), e.seq, e.value)).collect();
            assert_eq!(got, want, "lossy split at {cut}");
        }
    }

    /// A restart-compressed stretch: entry 0 is the restart (full key),
    /// entries 1.. share its prefix via `push_entry_shared`.
    fn prefixed_buf() -> (WireBuf, Vec<(Vec<u8>, u64, Option<Payload>)>) {
        let keys: Vec<Vec<u8>> = (0..8u64)
            .map(|i| format!("user00000000{i:03}").into_bytes())
            .collect();
        let mut b = WireBuf::new();
        let mut want = Vec::new();
        let mut restart_log = 0u64;
        for (i, k) in keys.iter().enumerate() {
            let v = if i % 3 == 2 { None } else { Some(Payload::fill(i as u8, 29)) };
            if i == 0 {
                restart_log = b.len() + ENTRY_HEADER as u64;
                b.push_entry(k, i as u64, v);
            } else {
                let shared = k.len() - 3; // "user00000000" + distinct tail
                b.push_entry_shared(k, shared, restart_log, i as u64, v);
            }
            want.push((k.clone(), i as u64, v));
        }
        (b, want)
    }

    #[test]
    fn shared_prefix_entries_decode_exactly_and_compactly() {
        let (b, want) = prefixed_buf();
        let got: Vec<(Vec<u8>, u64, Option<Payload>)> =
            b.entries().map(|e| (e.key.to_vec(), e.seq, e.value)).collect();
        assert_eq!(got, want);
        // Logical length equals the uncompressed encoding's...
        let mut plain = WireBuf::new();
        for (k, s, v) in &want {
            plain.push_entry(k, *s, *v);
        }
        assert_eq!(b.len(), plain.len(), "prefix elision must not change logical size");
        // ...while the physical form drops the shared prefixes.
        let elided: usize = (want.len() - 1) * (want[0].0.len() - 3);
        assert_eq!(b.phys_len() + elided, plain.phys_len());
    }

    #[test]
    fn shared_prefix_split_and_reassembly_is_lossless() {
        let (b, want) = prefixed_buf();
        for cut in 0..=b.len() {
            let mut joined = b.slice_to_buf(0, cut);
            joined.append_buf(&b.slice_to_buf(cut, b.len() - cut));
            assert_eq!(joined.len(), b.len());
            let got: Vec<(Vec<u8>, u64, Option<Payload>)> =
                joined.entries().map(|e| (e.key.to_vec(), e.seq, e.value)).collect();
            assert_eq!(got, want, "lossy split at {cut}");
        }
    }

    #[test]
    fn severed_prefix_source_stops_decoding() {
        let (b, want) = prefixed_buf();
        // A slice starting at the second entry keeps its prefix run but
        // not the restart key it points at: decode must stop, not invent
        // key bytes.
        let second = (ENTRY_HEADER + want[0].0.len() + 29) as u64;
        let tail = b.slice_to_buf(second, b.len() - second);
        assert_eq!(tail.entries().count(), 0);
    }

    #[test]
    fn key_view_orders_like_contiguous_bytes() {
        let v = KeyView::new(b"user00", b"42");
        assert_eq!(v.len(), 8);
        assert_eq!(v.to_vec(), b"user0042");
        assert_eq!(v.cmp_bytes(b"user0042"), std::cmp::Ordering::Equal);
        assert!(v.eq_bytes(b"user0042"));
        assert_eq!(v.cmp_bytes(b"user0041"), std::cmp::Ordering::Greater);
        assert_eq!(v.cmp_bytes(b"user00421"), std::cmp::Ordering::Less);
        assert_eq!(v, KeyView::from_slice(b"user0042"));
        assert!(v < KeyView::new(b"user0", b"1"));
        assert!(KeyView::from_slice(b"a") < KeyView::new(b"a", b"a"));
    }

    #[test]
    fn truncated_buffer_stops_decoding() {
        let mut b = WireBuf::new();
        b.push_entry(b"abc", 3, Some(Payload::fill(1, 50)));
        // Cut one logical byte off the value.
        let t = b.slice_to_buf(0, b.len() - 1);
        assert_eq!(t.entries().count(), 0);
        // Cut into the key.
        let t = b.slice_to_buf(0, 15);
        assert_eq!(t.entries().count(), 0);
    }

    #[test]
    fn raw_byte_buffers_behave_like_vecs() {
        let mut b = WireBuf::from_bytes(b"hello");
        b.push_bytes(b" world");
        assert_eq!(b.len(), 11);
        assert_eq!(b.phys_bytes(), b"hello world");
        let s = b.slice_to_buf(6, 5);
        assert_eq!(s.phys_bytes(), b"world");
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn fill_payload_is_deterministic_and_len_aware() {
        assert_eq!(Payload::fill(7, 100), Payload::fill(7, 100));
        assert_ne!(Payload::fill(7, 100), Payload::fill(7, 101));
        assert_ne!(Payload::fill(7, 100), Payload::fill(8, 100));
        assert_eq!(Payload::fill(9, 0), Payload::from_bytes(&[]));
    }

    #[test]
    fn zeros_padding_is_physical() {
        let mut b = WireBuf::new();
        b.push_zeros(128);
        assert_eq!(b.len(), 128);
        assert_eq!(b.phys_len(), 128);
        assert!(b.phys_bytes().iter().all(|&x| x == 0));
    }

    #[test]
    fn synthetic_padding_is_weightless_and_survives_slicing() {
        let mut b = WireBuf::new();
        b.push_entry(b"user123", 1, Some(Payload::fill(3, 40)));
        b.push_pad(128);
        assert_eq!(b.len(), 14 + 7 + 40 + 128);
        assert_eq!(b.phys_len(), 14 + 7);
        // Decode yields the entry and stops at the opaque padding.
        assert_eq!(b.entries().count(), 1);
        // Slice/rejoin through the pad region stays lossless + weightless.
        for cut in [b.len() - 130, b.len() - 64, b.len() - 1] {
            let mut joined = b.slice_to_buf(0, cut);
            joined.append_buf(&b.slice_to_buf(cut, b.len() - cut));
            assert_eq!(joined.len(), b.len());
            assert_eq!(joined.phys_len(), b.phys_len());
            assert_eq!(joined.entries().count(), 1);
        }
    }

    /// A buffer mixing dehydratable YCSB entries with entries that must
    /// stay resident: a non-user key, a tombstone, and an empty value.
    fn user_buf() -> WireBuf {
        let mut b = WireBuf::new();
        b.push_entry(&crate::ycsb::key_for(11, 24), 1, Some(Payload::fill(1, 33)));
        b.push_entry(b"key-0007", 2, Some(Payload::fill(2, 21)));
        b.push_entry(&crate::ycsb::key_for(12, 24), 3, None);
        b.push_entry(&crate::ycsb::key_for(13, 16), 4, Some(Payload::from_bytes(&[])));
        b.push_entry(&crate::ycsb::key_for(14, 24), 5, Some(Payload::fill(4, 57)));
        b
    }

    #[test]
    fn dehydrate_elides_user_heads_and_hydrates_bit_identically() {
        let b = user_buf();
        let d = b.dehydrate_copy().expect("user keys must dehydrate");
        assert_eq!(d.len(), b.len(), "logical length is invariant");
        assert_eq!(d.key_runs().len(), 4);
        // Only the non-user entry's head stays resident.
        assert_eq!(d.phys_len(), ENTRY_HEADER + 8);
        assert!(!d.is_hydrated());
        let mut h = d.clone();
        h.hydrate();
        assert_eq!(h, b, "hydrate must invert dehydrate bit-identically");
        h.hydrate();
        assert_eq!(h, b, "hydrate is idempotent");
        // Dehydrating the dehydrated copy finds nothing new.
        assert!(d.dehydrate_copy().is_none(), "dehydration is stable");
    }

    #[test]
    fn dehydrated_buffers_split_and_reassemble_losslessly() {
        // Satellite property at the unit level: cut the dehydrated buffer
        // at EVERY logical offset (including mid-KeySynthRun), rejoin,
        // hydrate — the result must equal the never-dehydrated buffer's
        // identically-cut form, and decode identically.
        let b = user_buf();
        let d = b.dehydrate_copy().unwrap();
        let want: Vec<(Vec<u8>, u64, Option<Payload>)> =
            b.entries().map(|e| (e.key.to_vec(), e.seq, e.value)).collect();
        assert_eq!(want.len(), 5);
        for cut in 0..=d.len() {
            let mut joined = d.slice_to_buf(0, cut);
            joined.append_buf(&d.slice_to_buf(cut, d.len() - cut));
            assert_eq!(joined.len(), b.len());
            let mut hydrated = joined.clone();
            hydrated.hydrate();
            let mut plain = b.slice_to_buf(0, cut);
            plain.append_buf(&b.slice_to_buf(cut, b.len() - cut));
            assert_eq!(hydrated, plain, "lossy dehydrated split at {cut}");
            let got: Vec<(Vec<u8>, u64, Option<Payload>)> =
                hydrated.entries().map(|e| (e.key.to_vec(), e.seq, e.value)).collect();
            assert_eq!(got, want, "decode diverged at cut {cut}");
        }
    }

    #[test]
    fn slicing_a_key_run_materializes_the_cut_head() {
        let b = user_buf();
        let d = b.dehydrate_copy().unwrap();
        let r = d.key_runs()[0];
        // A slice ending strictly inside the first head: the overlapped
        // bytes come back as real bytes, identical to the plain slice.
        let mid = r.log_off + r.span() / 2;
        let cut = d.slice_to_buf(0, mid);
        assert!(cut.key_runs().is_empty(), "partial key runs are never stored");
        assert_eq!(cut, b.slice_to_buf(0, mid));
        // A slice starting inside the head likewise.
        let tail = d.slice_to_buf(mid, d.len() - mid);
        let mut tail_h = tail.clone();
        tail_h.hydrate();
        assert_eq!(tail_h, b.slice_to_buf(mid, b.len() - mid));
    }

    #[test]
    fn decode_stops_at_a_dehydrated_head() {
        let mut b = WireBuf::new();
        b.push_entry(b"key-0001", 1, Some(Payload::fill(1, 10)));
        b.push_entry(&crate::ycsb::key_for(5, 24), 2, Some(Payload::fill(2, 10)));
        b.push_entry(b"key-0002", 3, Some(Payload::fill(3, 10)));
        let d = b.dehydrate_copy().unwrap();
        // Zero-copy decode cannot cross the elided head: truncation
        // semantics, like a severed prefix run.
        assert_eq!(d.entries().count(), 1);
        let mut h = d.clone();
        h.hydrate();
        assert_eq!(h.entries().count(), 3);
        assert_eq!(h, b);
    }

    #[test]
    fn dehydrate_skips_prefix_sources_and_prefixed_entries() {
        // Restart keys are prefix-run sources and compressed entries have
        // elided prefixes: neither may dehydrate, so the whole
        // prefix-compressed stretch stays resident.
        let (b, _want) = prefixed_buf();
        assert!(b.dehydrate_copy().is_none());
    }

    #[test]
    fn dehydrate_ignores_opaque_buffers() {
        assert!(WireBuf::from_bytes(b"raw bytes, not entries").dehydrate_copy().is_none());
        assert!(WireBuf::new().dehydrate_copy().is_none());
        let mut z = WireBuf::new();
        z.push_zeros(64);
        assert!(z.dehydrate_copy().is_none(), "bogus zero entries have empty keys");
    }

    #[test]
    fn dehydrate_stops_at_torn_tails_and_keeps_them_resident() {
        // A truncated final record (power loss mid-append) must survive
        // dehydrate → hydrate with its torn bytes intact.
        let b = user_buf();
        let torn = b.slice_to_buf(0, b.len() - 7);
        let d = torn.dehydrate_copy().unwrap();
        let mut h = d.clone();
        h.hydrate();
        assert_eq!(h, torn);
        let want: Vec<_> = torn.entries().map(|e| (e.key.to_vec(), e.seq)).collect();
        let got: Vec<_> = h.entries().map(|e| (e.key.to_vec(), e.seq)).collect();
        assert_eq!(got, want);
    }
}
