//! Zero-materialization wire buffers — the data-plane representation that
//! makes simulator cost proportional to *entry count* instead of payload
//! bytes.
//!
//! A [`WireBuf`] is a byte string with two lengths:
//!
//! * a **logical** length — the exact number of bytes the materialized
//!   encoding would occupy. Every size, offset, block handle, zone write
//!   pointer, device-time charge, and metric in the simulator is computed
//!   from logical lengths, so the whole DES behaves bit-identically to an
//!   engine that stores real payload bytes;
//! * a **physical** length — what is actually resident in RAM. Entry
//!   headers and keys are stored physically; value payloads are carried as
//!   [`SynthRun`]s (logical length + 32-bit content fingerprint) occupying
//!   zero physical bytes.
//!
//! The logical layout of one encoded entry is byte-compatible with the
//! seed engine's on-disk format:
//!
//! ```text
//! [klen u16][vlen u32][seq u64][key: klen bytes][value: vlen bytes]
//! ```
//!
//! where `vlen == u32::MAX` marks a tombstone. Physically the value bytes
//! are elided; their identity survives as the run's fingerprint, so
//! decode returns the exact [`Payload`] that was written (WAL replay, SST
//! reads, and SSD-cache round trips are loss-free).
//!
//! Buffers can be sliced at *arbitrary* logical offsets (zenfs splits
//! files at HDD zone-capacity boundaries that may fall inside a value):
//! a run is then split into partial runs that each carry the full value's
//! fingerprint, and decoding re-assembles them transparently.

use crate::sim::rng::fingerprint32;

/// Logical size of an encoded entry header (klen + vlen + seq).
pub const ENTRY_HEADER: usize = 14;

/// Compact stand-in for value bytes: logical length plus a 32-bit content
/// fingerprint. Payload equality is only meaningful between payloads built
/// by the same constructor ([`Payload::from_bytes`] fingerprints real
/// bytes; [`Payload::fill`] fingerprints the `(byte, len)` fill pattern in
/// O(1) without materializing it).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Payload {
    /// Logical value size in bytes (drives all size accounting).
    pub len: u32,
    /// 32-bit content fingerprint (identity only, not invertible).
    pub fingerprint: u32,
}

impl Payload {
    /// Fingerprint real bytes (API boundary: `Engine::put`, tests).
    pub fn from_bytes(bytes: &[u8]) -> Payload {
        Payload { len: bytes.len() as u32, fingerprint: fingerprint32(bytes) }
    }

    /// Fingerprint the fill pattern "`len` copies of `byte`" in O(1) —
    /// the YCSB value generator's shape (`vec![b; value_size]` in the
    /// seed engine) without touching `len` bytes.
    pub fn fill(byte: u8, len: usize) -> Payload {
        if len == 0 {
            return Payload::from_bytes(&[]);
        }
        // splitmix64 over (len, byte).
        let mut z = (((len as u64) << 8) | byte as u64).wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        Payload { len: len as u32, fingerprint: ((z >> 32) ^ z) as u32 }
    }
}

/// One synthetic (payload) run inside a [`WireBuf`]: `len` logical bytes
/// at `log_off`, zero physical bytes, identified by the value fingerprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SynthRun {
    /// Logical offset of the run within its buffer.
    pub log_off: u64,
    /// Logical bytes covered by this run.
    pub len: u32,
    /// Fingerprint of the (whole) value this run belongs to. Partial runs
    /// produced by slicing carry the full value's fingerprint.
    pub fp: u32,
    /// Synthetic bytes in all earlier runs (prefix sum for O(log n)
    /// logical→physical offset translation).
    synth_before: u64,
}

/// A decoded entry borrowing its key from the buffer it was decoded from
/// (the zero-copy view used by point lookups, scans, and the streaming
/// compaction merge).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EntryRef<'a> {
    pub key: &'a [u8],
    pub seq: u64,
    /// `None` is a tombstone.
    pub value: Option<Payload>,
}

impl EntryRef<'_> {
    /// Logical encoded size of this entry.
    pub fn encoded_len(&self) -> usize {
        ENTRY_HEADER + self.key.len() + self.value.map_or(0, |p| p.len as usize)
    }
}

/// Raw decode result carrying buffer positions instead of borrows (used by
/// cursors that own their buffer, e.g. the compaction block streams).
#[derive(Clone, Copy, Debug)]
pub(crate) struct RawEntry {
    pub key_off: usize,
    pub key_len: usize,
    pub seq: u64,
    pub value: Option<Payload>,
    pub next_log: u64,
    pub next_phys: usize,
    pub next_run: usize,
}

/// The zero-materialization byte buffer. See the module docs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WireBuf {
    phys: Vec<u8>,
    /// Synthetic runs sorted by `log_off`; runs never overlap and always
    /// lie inside the value region of exactly one encoded entry.
    runs: Vec<SynthRun>,
    log_len: u64,
}

impl WireBuf {
    pub fn new() -> WireBuf {
        WireBuf::default()
    }

    /// A buffer of real bytes only (no synthetic runs).
    pub fn from_bytes(bytes: &[u8]) -> WireBuf {
        WireBuf { phys: bytes.to_vec(), runs: Vec::new(), log_len: bytes.len() as u64 }
    }

    /// Logical length — the materialized encoding's byte count.
    pub fn len(&self) -> u64 {
        self.log_len
    }

    pub fn is_empty(&self) -> bool {
        self.log_len == 0
    }

    /// Physically resident bytes (headers + keys + padding).
    pub fn phys_len(&self) -> usize {
        self.phys.len()
    }

    /// The physical bytes (raw-byte buffers: identical to the content).
    pub fn phys_bytes(&self) -> &[u8] {
        &self.phys
    }

    pub fn runs(&self) -> &[SynthRun] {
        &self.runs
    }

    pub fn clear(&mut self) {
        self.phys.clear();
        self.runs.clear();
        self.log_len = 0;
    }

    pub fn reserve_phys(&mut self, additional: usize) {
        self.phys.reserve(additional);
    }

    fn total_synth(&self) -> u64 {
        self.runs.last().map_or(0, |r| r.synth_before + r.len as u64)
    }

    /// Append real bytes.
    pub fn push_bytes(&mut self, bytes: &[u8]) {
        self.phys.extend_from_slice(bytes);
        self.log_len += bytes.len() as u64;
    }

    /// Append `n` zero bytes (SST index/bloom padding).
    pub fn push_zeros(&mut self, n: usize) {
        self.phys.extend(std::iter::repeat(0u8).take(n));
        self.log_len += n as u64;
    }

    /// Append a value payload as a synthetic run (`p.len` logical bytes,
    /// zero physical).
    pub fn push_payload(&mut self, p: Payload) {
        if p.len == 0 {
            return;
        }
        let synth_before = self.total_synth();
        self.runs.push(SynthRun {
            log_off: self.log_len,
            len: p.len,
            fp: p.fingerprint,
            synth_before,
        });
        self.log_len += p.len as u64;
    }

    /// Append one encoded entry (header + key physically, value as a run).
    pub fn push_entry(&mut self, key: &[u8], seq: u64, value: Option<Payload>) {
        let mut hdr = [0u8; ENTRY_HEADER];
        hdr[0..2].copy_from_slice(&(key.len() as u16).to_le_bytes());
        let vlen = match value {
            Some(p) => p.len,
            None => u32::MAX,
        };
        hdr[2..6].copy_from_slice(&vlen.to_le_bytes());
        hdr[6..14].copy_from_slice(&seq.to_le_bytes());
        self.push_bytes(&hdr);
        self.push_bytes(key);
        if let Some(p) = value {
            self.push_payload(p);
        }
    }

    /// Physical offset of logical position `log`. Positions strictly
    /// inside a synthetic run map to the run's physical start.
    fn phys_of(&self, log: u64) -> usize {
        let idx = self.runs.partition_point(|r| r.log_off < log);
        let synth = if idx == 0 {
            0
        } else {
            let r = &self.runs[idx - 1];
            r.synth_before + (r.len as u64).min(log - r.log_off)
        };
        (log - synth) as usize
    }

    /// Copy out the logical range `[off, off + len)` as an owned buffer.
    /// Slicing may split a synthetic run; each part keeps the full value's
    /// fingerprint, and decoding re-joins adjacent parts.
    pub fn slice_to_buf(&self, off: u64, len: u64) -> WireBuf {
        let end = off + len;
        assert!(end <= self.log_len, "slice [{off}, {end}) outside len {}", self.log_len);
        let ps = self.phys_of(off);
        let pe = self.phys_of(end);
        let first = self.runs.partition_point(|r| r.log_off + r.len as u64 <= off);
        let mut runs = Vec::new();
        let mut synth_acc = 0u64;
        for r in &self.runs[first..] {
            if r.log_off >= end {
                break;
            }
            let s = r.log_off.max(off);
            let e = (r.log_off + r.len as u64).min(end);
            runs.push(SynthRun {
                log_off: s - off,
                len: (e - s) as u32,
                fp: r.fp,
                synth_before: synth_acc,
            });
            synth_acc += e - s;
        }
        WireBuf { phys: self.phys[ps..pe].to_vec(), runs, log_len: len }
    }

    /// Append another buffer's content (logical concatenation).
    pub fn append_buf(&mut self, other: &WireBuf) {
        let base_log = self.log_len;
        let base_synth = self.total_synth();
        self.phys.extend_from_slice(&other.phys);
        for r in &other.runs {
            self.runs.push(SynthRun {
                log_off: base_log + r.log_off,
                len: r.len,
                fp: r.fp,
                synth_before: base_synth + r.synth_before,
            });
        }
        self.log_len += other.log_len;
    }

    /// Decode the entry at the given cursor positions. Returns `None` at
    /// end-of-buffer or on truncation/malformation (mirrors the seed
    /// decoder's truncation semantics).
    pub(crate) fn decode_entry_raw(&self, log: u64, phys: usize, run: usize) -> Option<RawEntry> {
        if log >= self.log_len || phys + ENTRY_HEADER > self.phys.len() {
            return None;
        }
        let klen = u16::from_le_bytes(self.phys[phys..phys + 2].try_into().unwrap()) as usize;
        let vlen_raw = u32::from_le_bytes(self.phys[phys + 2..phys + 6].try_into().unwrap());
        let seq = u64::from_le_bytes(self.phys[phys + 6..phys + 14].try_into().unwrap());
        let key_off = phys + ENTRY_HEADER;
        if key_off + klen > self.phys.len() {
            return None;
        }
        let mut next_log = log + (ENTRY_HEADER + klen) as u64;
        let next_phys = key_off + klen;
        let mut next_run = run;
        let value = if vlen_raw == u32::MAX {
            None
        } else if vlen_raw == 0 {
            Some(Payload::from_bytes(&[]))
        } else {
            let vlen = vlen_raw as u64;
            if next_log + vlen > self.log_len {
                return None;
            }
            let mut covered = 0u64;
            let mut fp: Option<u32> = None;
            while covered < vlen {
                let r = self.runs.get(next_run)?;
                if r.log_off != next_log + covered || covered + r.len as u64 > vlen {
                    return None; // run/value mismatch: malformed buffer
                }
                fp.get_or_insert(r.fp);
                covered += r.len as u64;
                next_run += 1;
            }
            next_log += vlen;
            Some(Payload { len: vlen_raw, fingerprint: fp.unwrap_or(0) })
        };
        if next_log > self.log_len {
            return None;
        }
        Some(RawEntry { key_off, key_len: klen, seq, value, next_log, next_phys, next_run })
    }

    pub(crate) fn key_at(&self, key_off: usize, key_len: usize) -> &[u8] {
        &self.phys[key_off..key_off + key_len]
    }

    /// Iterate the encoded entries (zero-copy keys).
    pub fn entries(&self) -> EntryCursor<'_> {
        EntryCursor { buf: self, log: 0, phys: 0, run: 0 }
    }
}

/// Sequential zero-copy decoder over a [`WireBuf`].
pub struct EntryCursor<'a> {
    buf: &'a WireBuf,
    log: u64,
    phys: usize,
    run: usize,
}

impl<'a> Iterator for EntryCursor<'a> {
    type Item = EntryRef<'a>;

    fn next(&mut self) -> Option<EntryRef<'a>> {
        let raw = self.buf.decode_entry_raw(self.log, self.phys, self.run)?;
        self.log = raw.next_log;
        self.phys = raw.next_phys;
        self.run = raw.next_run;
        Some(EntryRef {
            key: self.buf.key_at(raw.key_off, raw.key_len),
            seq: raw.seq,
            value: raw.value,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logical_layout_matches_materialized_format() {
        let mut b = WireBuf::new();
        b.push_entry(b"user123", 42, Some(Payload::fill(7, 100)));
        // 14-byte header + 7-byte key + 100 value bytes, logically.
        assert_eq!(b.len(), 14 + 7 + 100);
        // Physically only header + key are resident.
        assert_eq!(b.phys_len(), 14 + 7);
        let e = b.entries().next().unwrap();
        assert_eq!(e.key, b"user123");
        assert_eq!(e.seq, 42);
        assert_eq!(e.value, Some(Payload::fill(7, 100)));
        assert_eq!(e.encoded_len() as u64, b.len());
    }

    #[test]
    fn tombstone_and_empty_value_roundtrip() {
        let mut b = WireBuf::new();
        b.push_entry(b"k", 1, None);
        b.push_entry(b"l", 2, Some(Payload::from_bytes(&[])));
        let es: Vec<_> = b.entries().collect();
        assert_eq!(es.len(), 2);
        assert_eq!(es[0].value, None);
        assert_eq!(es[1].value, Some(Payload::from_bytes(&[])));
        assert_eq!(b.len(), 14 + 1 + 14 + 1);
    }

    #[test]
    fn many_entries_decode_in_order() {
        let mut b = WireBuf::new();
        let payloads: Vec<Payload> =
            (0..50u64).map(|i| Payload::fill((i % 251) as u8, 64 + i as usize)).collect();
        for (i, p) in payloads.iter().enumerate() {
            b.push_entry(format!("key{i:03}").as_bytes(), i as u64, Some(*p));
        }
        let decoded: Vec<_> = b.entries().collect();
        assert_eq!(decoded.len(), 50);
        for (i, e) in decoded.iter().enumerate() {
            assert_eq!(e.key, format!("key{i:03}").as_bytes());
            assert_eq!(e.seq, i as u64);
            assert_eq!(e.value, Some(payloads[i]));
        }
    }

    #[test]
    fn slice_at_entry_boundaries_preserves_entries() {
        let mut b = WireBuf::new();
        let mut offsets = vec![0u64];
        for i in 0..10u64 {
            b.push_entry(format!("k{i}").as_bytes(), i, Some(Payload::fill(1, 500)));
            offsets.push(b.len());
        }
        for w in offsets.windows(2) {
            let s = b.slice_to_buf(w[0], w[1] - w[0]);
            let es: Vec<_> = s.entries().collect();
            assert_eq!(es.len(), 1);
            assert_eq!(es[0].value, Some(Payload::fill(1, 500)));
        }
    }

    #[test]
    fn arbitrary_split_and_reassembly_is_lossless() {
        // Split the buffer at every possible logical offset (including
        // inside headers, keys, and synthetic runs) and re-concatenate:
        // the result must decode identically.
        let mut b = WireBuf::new();
        for i in 0..8u64 {
            let v = if i % 3 == 0 { None } else { Some(Payload::fill(i as u8, 37)) };
            b.push_entry(format!("key{i}").as_bytes(), i, v);
        }
        let want: Vec<(Vec<u8>, u64, Option<Payload>)> =
            b.entries().map(|e| (e.key.to_vec(), e.seq, e.value)).collect();
        for cut in 0..=b.len() {
            let mut joined = b.slice_to_buf(0, cut);
            joined.append_buf(&b.slice_to_buf(cut, b.len() - cut));
            assert_eq!(joined.len(), b.len());
            let got: Vec<(Vec<u8>, u64, Option<Payload>)> =
                joined.entries().map(|e| (e.key.to_vec(), e.seq, e.value)).collect();
            assert_eq!(got, want, "lossy split at {cut}");
        }
    }

    #[test]
    fn truncated_buffer_stops_decoding() {
        let mut b = WireBuf::new();
        b.push_entry(b"abc", 3, Some(Payload::fill(1, 50)));
        // Cut one logical byte off the value.
        let t = b.slice_to_buf(0, b.len() - 1);
        assert_eq!(t.entries().count(), 0);
        // Cut into the key.
        let t = b.slice_to_buf(0, 15);
        assert_eq!(t.entries().count(), 0);
    }

    #[test]
    fn raw_byte_buffers_behave_like_vecs() {
        let mut b = WireBuf::from_bytes(b"hello");
        b.push_bytes(b" world");
        assert_eq!(b.len(), 11);
        assert_eq!(b.phys_bytes(), b"hello world");
        let s = b.slice_to_buf(6, 5);
        assert_eq!(s.phys_bytes(), b"world");
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn fill_payload_is_deterministic_and_len_aware() {
        assert_eq!(Payload::fill(7, 100), Payload::fill(7, 100));
        assert_ne!(Payload::fill(7, 100), Payload::fill(7, 101));
        assert_ne!(Payload::fill(7, 100), Payload::fill(8, 100));
        assert_eq!(Payload::fill(9, 0), Payload::from_bytes(&[]));
    }

    #[test]
    fn zeros_padding_is_physical() {
        let mut b = WireBuf::new();
        b.push_zeros(128);
        assert_eq!(b.len(), 128);
        assert_eq!(b.phys_len(), 128);
        assert!(b.phys_bytes().iter().all(|&x| x == 0));
    }
}
