//! Table 1: device performance statistics — fio-like QD1 microbenchmarks
//! (1 MiB sequential reads/writes, 4 KiB random reads) on both simulated
//! zoned devices, plus the cost figures.

use crate::config::{paper, Config, MIB};
use crate::report::Table;
use crate::sim::{AccessKind, DeviceTimer};

pub struct DeviceBench {
    pub seq_read_mibs: f64,
    pub seq_write_mibs: f64,
    pub rand_read_iops: f64,
}

/// QD1 microbenchmark of one device profile.
pub fn bench_device(profile: &crate::config::DeviceProfile) -> DeviceBench {
    let mut t = DeviceTimer::new(profile.clone());
    let mut now = 0u64;
    let n = 2_000u64;
    for _ in 0..n {
        now = t.access(now, AccessKind::SeqRead, MIB).1;
    }
    let seq_read_mibs = n as f64 / (now as f64 / 1e9);
    let mut t = DeviceTimer::new(profile.clone());
    let mut now = 0u64;
    for _ in 0..n {
        now = t.access(now, AccessKind::SeqWrite, MIB).1;
    }
    let seq_write_mibs = n as f64 / (now as f64 / 1e9);
    let mut t = DeviceTimer::new(profile.clone());
    let mut now = 0u64;
    let m = 20_000u64;
    for _ in 0..m {
        now = t.access(now, AccessKind::RandRead, 4096).1;
    }
    let rand_read_iops = m as f64 / (now as f64 / 1e9);
    DeviceBench { seq_read_mibs, seq_write_mibs, rand_read_iops }
}

pub fn run(csv_dir: Option<&str>) {
    let cfg = Config::default();
    let ssd = bench_device(&cfg.ssd);
    let hdd = bench_device(&cfg.hdd);
    let mut t = Table::new(
        "Table 1: device statistics (simulated QD1, 1 MiB seq / 4 KiB rand)",
        &["metric", "ZN540 (ZNS SSD)", "paper", "ST14000 (HM-SMR HDD)", "paper"],
    );
    t.row(vec![
        "seq read (MiB/s)".into(),
        format!("{:.1}", ssd.seq_read_mibs),
        format!("{:.1}", paper::SSD_SEQ_READ_MIBS),
        format!("{:.1}", hdd.seq_read_mibs),
        format!("{:.1}", paper::HDD_SEQ_READ_MIBS),
    ]);
    t.row(vec![
        "seq write (MiB/s)".into(),
        format!("{:.1}", ssd.seq_write_mibs),
        format!("{:.1}", paper::SSD_SEQ_WRITE_MIBS),
        format!("{:.1}", hdd.seq_write_mibs),
        format!("{:.1}", paper::HDD_SEQ_WRITE_MIBS),
    ]);
    t.row(vec![
        "rand read (IO/s)".into(),
        format!("{:.1}", ssd.rand_read_iops),
        format!("{:.1}", paper::SSD_RAND_READ_IOPS),
        format!("{:.1}", hdd.rand_read_iops),
        format!("{:.1}", paper::HDD_RAND_READ_IOPS),
    ]);
    t.row(vec![
        "price (US$/GiB)".into(),
        format!("{:.3}", paper::SSD_PRICE_GIB),
        format!("{:.3}", paper::SSD_PRICE_GIB),
        format!("{:.3}", paper::HDD_PRICE_GIB),
        format!("{:.3}", paper::HDD_PRICE_GIB),
    ]);
    t.emit(csv_dir, "table1");
    println!(
        "  random-read gap: {:.1}x (paper: 147.2x); price gap: {:.1}x (paper: 13.1x)\n",
        ssd.rand_read_iops / hdd.rand_read_iops,
        paper::SSD_PRICE_GIB / paper::HDD_PRICE_GIB
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_devices_match_table1_within_5pct() {
        let cfg = Config::default();
        let ssd = bench_device(&cfg.ssd);
        let hdd = bench_device(&cfg.hdd);
        let close = |a: f64, b: f64| (a - b).abs() / b < 0.05;
        assert!(close(ssd.seq_read_mibs, paper::SSD_SEQ_READ_MIBS), "{}", ssd.seq_read_mibs);
        assert!(close(ssd.seq_write_mibs, paper::SSD_SEQ_WRITE_MIBS), "{}", ssd.seq_write_mibs);
        assert!(close(ssd.rand_read_iops, paper::SSD_RAND_READ_IOPS), "{}", ssd.rand_read_iops);
        assert!(close(hdd.seq_read_mibs, paper::HDD_SEQ_READ_MIBS), "{}", hdd.seq_read_mibs);
        assert!(close(hdd.rand_read_iops, paper::HDD_RAND_READ_IOPS), "{}", hdd.rand_read_iops);
    }
}
