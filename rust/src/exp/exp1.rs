//! Exp#1 (Fig 5): YCSB core workloads A–F + load, comparing B3, AUTO, and
//! HHZS. Also reports the % of per-level data resident on the SSD at the
//! end of workload A (Fig 5(b)).

use crate::report::{fmt_pct, Table};
use crate::ycsb::Kind;

use super::common::{load_and_run, load_fresh, ExpOpts};

pub const SCHEMES: [&str; 3] = ["B3", "AUTO", "HHZS"];

pub fn run(opts: &ExpOpts) {
    let cfg = &opts.cfg;
    let csv = opts.csv_dir.as_deref();
    let workloads = [
        (Kind::A, "A"),
        (Kind::B, "B"),
        (Kind::C, "C"),
        (Kind::D, "D"),
        (Kind::E, "E"),
        (Kind::F, "F"),
    ];

    let mut tput: Vec<Vec<f64>> = vec![Vec::new(); SCHEMES.len()];
    // Load throughput per scheme.
    for (si, s) in SCHEMES.iter().enumerate() {
        println!("exp1: {s} load...");
        let (_, m) = load_fresh(cfg, s, None, false);
        tput[si].push(m.ops_per_sec());
    }
    let mut fig5b: Option<Vec<(u64, u64)>> = None;
    for (kind, label) in workloads {
        for (si, s) in SCHEMES.iter().enumerate() {
            println!("exp1: {s} workload {label}...");
            let (engine, m) = load_and_run(cfg, s, kind, cfg.workload.zipf_alpha);
            tput[si].push(m.ops_per_sec());
            if kind == Kind::A && *s == "HHZS" {
                fig5b = Some(engine.ssd_share_by_level());
            }
        }
    }

    let mut t = Table::new(
        "Fig 5(a): throughput normalized to B3 (B3 row shows absolute OPS)",
        &["scheme", "load", "A", "B", "C", "D", "E", "F"],
    );
    for (si, s) in SCHEMES.iter().enumerate() {
        let mut row = vec![s.to_string()];
        for (wi, v) in tput[si].iter().enumerate() {
            if si == 0 {
                row.push(format!("{v:.0}"));
            } else {
                let b3 = tput[0][wi];
                row.push(format!("{:.2}x", v / b3.max(1e-9)));
            }
        }
        t.row(row);
    }
    t.emit(csv, "exp1_fig5a");

    if let Some(share) = fig5b {
        let mut t = Table::new(
            "Fig 5(b): % of data in SSD per level at the end of workload A (HHZS)",
            &["level", "ssd bytes", "total bytes", "% in SSD"],
        );
        for (lvl, (ssd, all)) in share.iter().enumerate() {
            if *all == 0 {
                continue;
            }
            t.row(vec![
                format!("L{lvl}"),
                format!("{ssd}"),
                format!("{all}"),
                fmt_pct(*ssd as f64 / *all as f64),
            ]);
        }
        t.emit(csv, "exp1_fig5b");
    }
}
