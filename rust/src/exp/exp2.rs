//! Exp#2 (Fig 6): performance breakdown — how much each HHZS technique
//! contributes. Schemes: B3, B3+M, P, P+M, P+M+C (= full HHZS), over load
//! and the W1–W4 mixes.

use crate::report::Table;
use crate::ycsb::Kind;

use super::common::{load_and_run, load_fresh, ExpOpts};

pub const SCHEMES: [&str; 5] = ["B3", "B3+M", "P", "P+M", "P+M+C"];

/// The four W workloads of §4.2: (reads %, α).
pub const W: [(u32, f64, &str); 4] =
    [(10, 0.9, "W1"), (50, 0.9, "W2"), (50, 1.2, "W3"), (100, 1.2, "W4")];

pub fn run(opts: &ExpOpts) {
    let cfg = &opts.cfg;
    let csv = opts.csv_dir.as_deref();
    let mut tput: Vec<Vec<f64>> = vec![Vec::new(); SCHEMES.len()];

    for (si, s) in SCHEMES.iter().enumerate() {
        println!("exp2: {s} load...");
        let (_, m) = load_fresh(cfg, s, None, false);
        tput[si].push(m.ops_per_sec());
    }
    for (read_pct, alpha, label) in W {
        for (si, s) in SCHEMES.iter().enumerate() {
            println!("exp2: {s} {label} ({read_pct}% reads, α={alpha})...");
            let kind =
                if read_pct == 100 { Kind::C } else { Kind::Mixed { read_pct } };
            let (_, m) = load_and_run(cfg, s, kind, alpha);
            tput[si].push(m.ops_per_sec());
        }
    }

    let mut t = Table::new(
        "Fig 6: breakdown — throughput normalized to B3 (B3 row absolute OPS)",
        &["scheme", "load", "W1 10%r .9", "W2 50%r .9", "W3 50%r 1.2", "W4 100%r 1.2"],
    );
    for (si, s) in SCHEMES.iter().enumerate() {
        let mut row = vec![s.to_string()];
        for (wi, v) in tput[si].iter().enumerate() {
            if si == 0 {
                row.push(format!("{v:.0}"));
            } else {
                row.push(format!("{:.2}x", v / tput[0][wi].max(1e-9)));
            }
        }
        t.row(row);
    }
    t.emit(csv, "exp2_fig6");
}
