//! Ablation study (extension beyond the paper's figures): quantify the
//! design choices DESIGN.md calls out.
//!
//! * **Hints** — HHZS with compaction-hint storage demands disabled
//!   (`HHZS-nohints`): the tiering level sees only current allocations,
//!   not in-flight compaction output (§3.3 Step 1 ablated).
//! * **Cache-zone budget** — the WAL+cache pool size (§3.2 fixes it at
//!   max-WAL/zone-capacity = 2): sweep 2/4/8 zones on a read-heavy skewed
//!   workload to show the SSD-cache capacity trade-off.

use crate::report::Table;
use crate::ycsb::Kind;

use super::common::{load_and_run, ExpOpts};

pub fn run(opts: &ExpOpts) {
    let cfg = &opts.cfg;
    let csv = opts.csv_dir.as_deref();

    // ---- hint ablation ---------------------------------------------------
    let mut t = Table::new(
        "Ablation A: compaction-hint storage demands (50%r mixes)",
        &["scheme", "a=0.9 OPS", "a=1.1 OPS", "hdd-read a=1.1"],
    );
    for s in ["HHZS", "HHZS-nohints", "B3"] {
        println!("ablate: {s}...");
        let (_, m09) = load_and_run(cfg, s, Kind::Mixed { read_pct: 50 }, 0.9);
        let (_, m11) = load_and_run(cfg, s, Kind::Mixed { read_pct: 50 }, 1.1);
        t.row(vec![
            s.to_string(),
            format!("{:.0}", m09.ops_per_sec()),
            format!("{:.0}", m11.ops_per_sec()),
            format!("{:.1}%", m11.hdd_read_fraction() * 100.0),
        ]);
    }
    t.emit(csv, "ablate_hints");

    // ---- cache-zone budget -----------------------------------------------
    let mut t = Table::new(
        "Ablation B: WAL+cache pool size (workload C, a=1.2)",
        &["pool zones", "OPS", "ssd-cache hits", "hdd-read %"],
    );
    for zones in [2u32, 4, 8] {
        println!("ablate: pool={zones} zones...");
        let mut c = cfg.clone();
        c.geometry.wal_cache_zones = zones;
        let (_, m) = load_and_run(&c, "HHZS", Kind::C, 1.2);
        t.row(vec![
            format!("{zones}"),
            format!("{:.0}", m.ops_per_sec()),
            format!("{}", m.ssd_cache_hits),
            format!("{:.1}%", m.hdd_read_fraction() * 100.0),
        ]);
    }
    t.emit(csv, "ablate_pool");
}
