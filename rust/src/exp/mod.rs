//! Experiment harness: one driver per table/figure of the paper's
//! evaluation (see DESIGN.md §5 for the index).
//!
//! | driver   | paper artefact |
//! |----------|----------------|
//! | `table1` | Table 1 — device statistics |
//! | `fig2`   | Fig 2(a)–(i) — basic-scheme motivating analysis |
//! | `exp1`   | Fig 5 — YCSB A–F |
//! | `exp2`   | Fig 6 — technique breakdown |
//! | `exp3`   | Fig 7 — skewness sweep |
//! | `exp4`   | Fig 8 — read-ratio sweep |
//! | `exp5`   | Fig 9 — SSD-size sweep |
//! | `exp6`   | Fig 10 — migration-rate tail latencies |
//! | `exp7`   | beyond the paper — shard-count scalability (1..256) |
//!
//! `exp7-quick` (= `exp7 --quick` on the CLI) is the CI smoke shape of the
//! shard sweep: shards {8, 64} at 1×/4× keyspace with the always-on
//! residency-flatness gate.

pub mod ablate;
pub mod common;
pub mod exp1;
pub mod exp2;
pub mod exp3;
pub mod exp4;
pub mod exp5;
pub mod exp6;
pub mod exp7;
pub mod fig2;
pub mod table1;

pub use common::{ExpOpts, Profile};

/// Run an experiment by name ("all" runs everything).
pub fn run(name: &str, opts: &ExpOpts) -> anyhow::Result<()> {
    match name {
        "table1" => table1::run(opts.csv_dir.as_deref()),
        "fig2" => fig2::run(opts),
        "exp1" => exp1::run(opts),
        "exp2" => exp2::run(opts),
        "exp3" => exp3::run(opts),
        "exp4" => exp4::run(opts),
        "exp5" => exp5::run(opts),
        "exp6" => exp6::run(opts),
        "exp7" => exp7::run(opts),
        "exp7-quick" => exp7::run_quick(opts),
        "ablate" => ablate::run(opts),
        "all" => {
            for e in ["table1", "fig2", "exp1", "exp2", "exp3", "exp4", "exp5", "exp6", "exp7"] {
                run(e, opts)?;
            }
        }
        other => anyhow::bail!(
            "unknown experiment {other:?} (expected table1|fig2|exp1..exp7|all)"
        ),
    }
    Ok(())
}
