//! Exp#6 (Fig 10): impact of the migration rate limit on read tail
//! latencies. P+M (no caching, as §4.2), rates 1–64 MiB/s, 50/50 mix at
//! α = 0.9; reports p99 / p99.9 / p99.99 read latencies.

use crate::config::MIB;
use crate::report::Table;
use crate::sim::fmt_ns;
use crate::ycsb::Kind;

use super::common::{load_and_run, ExpOpts};

pub const RATES_MIB: [f64; 7] = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0];

pub fn run(opts: &ExpOpts) {
    let csv = opts.csv_dir.as_deref();
    let mut t = Table::new(
        "Fig 10: read tail latency vs migration rate (P+M, 50%r, α=0.9)",
        &["rate", "p99", "p99.9", "p99.99", "migrations", "migr bytes"],
    );
    for rate in RATES_MIB {
        println!("exp6: migration rate {rate} MiB/s...");
        let mut cfg = opts.cfg.clone();
        cfg.hhzs.migration_rate_bps = rate * MIB as f64;
        let (_, m) = load_and_run(&cfg, "P+M", Kind::Mixed { read_pct: 50 }, 0.9);
        t.row(vec![
            format!("{rate} MiB/s"),
            fmt_ns(m.read_lat.quantile(0.99)),
            fmt_ns(m.read_lat.quantile(0.999)),
            fmt_ns(m.read_lat.quantile(0.9999)),
            format!("{}", m.migrations_cap + m.migrations_pop),
            format!("{}", m.migration_bytes),
        ]);
    }
    t.emit(csv, "exp6_fig10");
}
