//! Exp#7 (beyond the paper): shard-count behaviour on the shared pair.
//!
//! Runs the §4.1 protocol (fresh load, then YCSB A) with the full HHZS
//! policy at 1/2/4/8 shards through the async frontend: one client pool,
//! one virtual clock, and ONE shared SSD/HDD pair — every shard's
//! flush/compaction/migration traffic lands on the same device FIFOs, so
//! what this experiment now measures is cross-shard device contention
//! (aggregate queue wait), cross-shard background-CPU contention (all
//! shards draw flush/compaction slots from ONE `bg_threads` pool; the
//! `cpu wait` column is the virtual time ready jobs spent waiting for a
//! slot), and how partitioning reshapes the tree (smaller per-shard
//! trees, shallower reads) — not the PR 1 fiction of `n` independent
//! device pairs and thread pools. Deterministic for a fixed seed: the
//! frontend routes one global op stream over seed-identical DES engines.

use crate::report::Table;
use crate::shard::ShardedEngine;
use crate::ycsb::{Kind, Spec, YcsbSource};
use crate::zone::Dev;

use super::common::{make_policy, ExpOpts};

pub const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Load + YCSB A at `n` shards; returns (load ops/s, A ops/s, merged A
/// metrics, per-shard A ops, per-shard A metrics).
pub fn run_one(
    cfg: &crate::config::Config,
    n: usize,
) -> (f64, f64, crate::metrics::Metrics, Vec<u64>, Vec<crate::metrics::Metrics>) {
    let mut cfg = cfg.clone();
    cfg.shards = n;
    let mut se = ShardedEngine::new(&cfg, |c| make_policy("HHZS", c));
    let clients = cfg.workload.clients;

    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, None, false);
    let load_tput = se.aggregate_ops_per_sec();
    se.flush_all();
    se.rebalance_migration_budgets();

    let mut a = YcsbSource::new(Spec::from_config(&cfg, Kind::A), clients);
    se.run_shared(&mut a, clients, None, false);
    let a_tput = se.aggregate_ops_per_sec();
    (load_tput, a_tput, se.merged_metrics(), se.ops_per_shard(), se.per_shard_metrics())
}

pub fn run(opts: &ExpOpts) {
    let csv = opts.csv_dir.as_deref();
    let mut t = Table::new(
        "Exp#7: shard count on one shared SSD/HDD pair (HHZS, fresh load + YCSB A per count)",
        &[
            "shards",
            "load ops/s",
            "A ops/s",
            "A vs 1-shard",
            "A read p99 ns",
            "A read p99.9 ns",
            "queue wait ms",
            "cpu wait ms",
            "key arena KiB",
            "balance max/min",
            "migrations",
        ],
    );
    // The stall/wait breakdown behind the aggregate columns: who stalls
    // and who waits is uneven under Zipf (hot shards draw more CPU slots
    // and queue more device time), which the merged row averages away.
    let mut bt = Table::new(
        "Exp#7 breakdown: per-shard write stalls and waits (YCSB A phase)",
        &[
            "shards",
            "shard",
            "ops",
            "stalls",
            "stall ms",
            "ssd queue wait ms",
            "hdd queue wait ms",
            "cpu wait ms",
        ],
    );
    let mut base_a: Option<f64> = None;
    for &n in &SHARD_COUNTS {
        println!("exp7: {n} shard(s)...");
        let (load_tput, a_tput, m, per_shard, shard_m) = run_one(&opts.cfg, n);
        for (s, sm) in shard_m.iter().enumerate() {
            bt.row(vec![
                n.to_string(),
                s.to_string(),
                sm.ops_done.to_string(),
                sm.stalls.to_string(),
                format!("{:.2}", sm.stall_ns as f64 / 1e6),
                format!("{:.2}", sm.queue_wait.get(&Dev::Ssd).copied().unwrap_or(0) as f64 / 1e6),
                format!("{:.2}", sm.queue_wait.get(&Dev::Hdd).copied().unwrap_or(0) as f64 / 1e6),
                format!("{:.2}", sm.cpu_wait.sum as f64 / 1e6),
            ]);
        }
        let speedup = match base_a {
            None => {
                base_a = Some(a_tput);
                1.0
            }
            Some(b) => a_tput / b.max(1e-9),
        };
        let max_ops = per_shard.iter().copied().max().unwrap_or(0);
        let min_ops = per_shard.iter().copied().min().unwrap_or(0);
        t.row(vec![
            n.to_string(),
            format!("{load_tput:.0}"),
            format!("{a_tput:.0}"),
            format!("{speedup:.2}x"),
            m.read_lat.quantile(0.99).to_string(),
            m.read_lat.quantile(0.999).to_string(),
            format!("{:.1}", m.total_queue_wait_ns() as f64 / 1e6),
            format!("{:.1}", m.cpu_wait.sum as f64 / 1e6),
            format!("{:.1}", m.key_arena_bytes as f64 / 1024.0),
            format!("{:.2}", max_ops as f64 / (min_ops.max(1)) as f64),
            (m.migrations_cap + m.migrations_pop).to_string(),
        ]);
    }
    t.emit(csv, "exp7_shards");
    bt.emit(csv, "exp7_shard_breakdown");
}
