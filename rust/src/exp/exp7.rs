//! Exp#7 (beyond the paper): shard-count behaviour on the shared pair.
//!
//! Runs the §4.1 protocol (fresh load, then YCSB A) with the full HHZS
//! policy at 1..256 shards through the async frontend: one client pool,
//! one virtual clock, and ONE shared SSD/HDD pair — every shard's
//! flush/compaction/migration traffic lands on the same device FIFOs, so
//! what this experiment now measures is cross-shard device contention
//! (aggregate queue wait), cross-shard background-CPU contention (all
//! shards draw flush/compaction slots from ONE `bg_threads` pool; the
//! `cpu wait` column is the virtual time ready jobs spent waiting for a
//! slot), and how partitioning reshapes the tree (smaller per-shard
//! trees, shallower reads) — not the PR 1 fiction of `n` independent
//! device pairs and thread pools. Deterministic for a fixed seed: the
//! frontend routes one global op stream over seed-identical DES engines.
//!
//! Paper-scale keyspaces (≥ 1M unique keys) and the high shard counts are
//! hostable because physical residency is demand-paged: zone-resident
//! YCSB data dehydrates to compact descriptors (see [`crate::residency`]),
//! so the `resident MiB` column tracks the *working set* (pinned cache
//! copies, WAL windows, torn tails) rather than the logical dataset.

use crate::config::{Config, WakePolicy};
use crate::metrics::{Metrics, WriteCategory};
use crate::report::Table;
use crate::shard::ShardedEngine;
use crate::ycsb::{Kind, Spec, YcsbSource};
use crate::zone::Dev;

use super::common::{make_policy, ExpOpts};

pub const SHARD_COUNTS: [usize; 7] = [1, 2, 4, 8, 16, 64, 256];

/// Sum of the four physical-residency gauges — everything the run keeps
/// hydrated in host memory on behalf of zones, WAL, and caches. The
/// gauges are per-shard and sum on merge, so this is the domain total.
pub fn resident_total_bytes(m: &Metrics) -> u64 {
    m.resident_ssd_bytes + m.resident_hdd_bytes + m.resident_wal_bytes + m.resident_cache_bytes
}

/// Device-visible WAL write requests (both devices) — the request count
/// group commit amortizes: a fused append counts once however many
/// members it carried.
pub fn wal_write_ios(m: &Metrics) -> u64 {
    m.write_traffic
        .iter()
        .filter(|((cat, _), _)| matches!(cat, WriteCategory::Wal))
        .map(|(_, c)| c.ios)
        .sum()
}

/// Load + YCSB A at `n` shards; returns (load ops/s, A ops/s, merged A
/// metrics, per-shard A ops, per-shard A metrics).
pub fn run_one(
    cfg: &crate::config::Config,
    n: usize,
) -> (f64, f64, crate::metrics::Metrics, Vec<u64>, Vec<crate::metrics::Metrics>) {
    let mut cfg = cfg.clone();
    cfg.shards = n;
    // The substrate must host the shard count: carve() insists on ≥ 1
    // pool zone + 1 SST zone per shard on the SSD and a full SST's worth
    // of HDD zones each. Widen the zone counts (never shrink the shard
    // count) so every row runs the identical workload.
    cfg.geometry.ssd_zones = cfg.geometry.ssd_zones.max(2 * n as u32);
    cfg.geometry.hdd_zones = cfg.geometry.hdd_zones.max(n as u32 * cfg.hdd_zones_per_sst());
    let mut se = ShardedEngine::new(&cfg, |c| make_policy("HHZS", c));
    let clients = cfg.workload.clients;

    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, None, false);
    let load_tput = se.aggregate_ops_per_sec();
    se.flush_all();
    se.rebalance_migration_budgets();

    let mut a = YcsbSource::new(Spec::from_config(&cfg, Kind::A), clients);
    se.run_shared(&mut a, clients, None, false);
    let a_tput = se.aggregate_ops_per_sec();
    (load_tput, a_tput, se.merged_metrics(), se.ops_per_shard(), se.per_shard_metrics())
}

/// The wake-policy comparison table's header (shared by the full run and
/// the `--quick` CI smoke so the CSVs line up).
fn sched_table(title: &'static str) -> Table {
    Table::new(
        title,
        &[
            "sched",
            "fg threads",
            "A ops/s",
            "A read p99 ns",
            "stall ms",
            "stalls avoided",
            "cpu wait ms",
            "fg wait ms",
        ],
    )
}

/// One row of the scheduler comparison: the §4.1 protocol at `shards`
/// shards under the given wake policy and foreground pool, at EQUAL
/// `bg_threads` across rows. Returns the merged A-phase metrics for the
/// gates. The saturated variant (`fg > 0`) raises the closed-loop client
/// count above the slot count, so per-op CPU queues and the run crosses
/// from device-bound to CPU-bound — `fg wait ms` is the evidence.
fn sched_row(t: &mut Table, base: &Config, shards: usize, wake: WakePolicy, fg: usize) -> Metrics {
    let mut cfg = base.clone();
    cfg.lsm.wake = wake;
    cfg.lsm.fg_threads = fg;
    if fg > 0 {
        cfg.workload.clients = cfg.workload.clients.max(4 * fg);
    }
    println!("exp7 sched: {} fg_threads={fg} at {shards} shard(s)...", wake.as_str());
    let (_, a_tput, m, _, _) = run_one(&cfg, shards);
    t.row(vec![
        wake.as_str().to_string(),
        fg.to_string(),
        format!("{a_tput:.0}"),
        m.read_lat.quantile(0.99).to_string(),
        format!("{:.2}", m.stall_ns as f64 / 1e6),
        m.stalls_avoided.to_string(),
        format!("{:.2}", m.cpu_wait.sum as f64 / 1e6),
        format!("{:.2}", m.fg_cpu_wait.sum as f64 / 1e6),
    ]);
    m
}

/// The request-fusion comparison table's header (shared by the full run
/// and the `--quick` CI gate so the CSVs line up).
fn batching_table(title: &'static str) -> Table {
    Table::new(
        title,
        &[
            "mode",
            "A ops/s",
            "acked ops",
            "wal write ios",
            "wal group p50",
            "ssd queue wait ms",
            "fused reads",
            "wal pad KiB",
        ],
    )
}

/// One row of the request-fusion comparison: the §4.1 protocol at
/// `shards` shards, with the batching knobs off or on (group commit at
/// the default 100 µs window plus read coalescing), under a saturating
/// closed-loop client pool so commit windows actually fill. Returns the
/// merged A-phase metrics for the gates.
fn batching_row(t: &mut Table, base: &Config, shards: usize, on: bool) -> Metrics {
    let mut cfg = base.clone();
    if on {
        cfg.batch.group_commit = true;
        cfg.batch.commit_batch_max = 64;
        cfg.batch.read_coalesce = true;
    }
    // Saturation: enough concurrent writers that a commit window catches
    // many staged records — the regime the fusion layer is built for.
    cfg.workload.clients = cfg.workload.clients.max(32);
    println!(
        "exp7 batching: group_commit={} at {shards} shard(s)...",
        if on { "on" } else { "off" }
    );
    let (_, a_tput, m, _, _) = run_one(&cfg, shards);
    t.row(vec![
        if on { "grouped" } else { "off" }.to_string(),
        format!("{a_tput:.0}"),
        m.ops_done.to_string(),
        wal_write_ios(&m).to_string(),
        m.wal_group_size.quantile(0.5).to_string(),
        format!("{:.2}", m.queue_wait.get(&Dev::Ssd).copied().unwrap_or(0) as f64 / 1e6),
        m.fused_reads.to_string(),
        format!("{:.1}", m.wal_pad_bytes as f64 / 1024.0),
    ]);
    m
}

pub fn run(opts: &ExpOpts) {
    let csv = opts.csv_dir.as_deref();
    let mut cfg = opts.cfg.clone();
    // Paper-scale keyspace: the shard sweep is only interesting when every
    // row serves ≥ 1M unique keys (the dehydrated descriptors make this
    // hostable — the logical dataset no longer has to fit in host RAM).
    cfg.workload.load_objects = cfg.workload.load_objects.max(1_000_000);
    let mut t = Table::new(
        "Exp#7: shard count on one shared SSD/HDD pair (HHZS, fresh load + YCSB A per count)",
        &[
            "shards",
            "load ops/s",
            "A ops/s",
            "A vs 1-shard",
            "A read p99 ns",
            "A read p99.9 ns",
            "queue wait ms",
            "cpu wait ms",
            "key arena KiB",
            "resident MiB",
            "balance max/min",
            "migrations",
            "wal ios",
        ],
    );
    // The stall/wait breakdown behind the aggregate columns: who stalls
    // and who waits is uneven under Zipf (hot shards draw more CPU slots
    // and queue more device time), which the merged row averages away.
    let mut bt = Table::new(
        "Exp#7 breakdown: per-shard write stalls and waits (YCSB A phase)",
        &[
            "shards",
            "shard",
            "ops",
            "stalls",
            "stall ms",
            "ssd queue wait ms",
            "hdd queue wait ms",
            "cpu wait ms",
        ],
    );
    let mut base_a: Option<f64> = None;
    for &n in &SHARD_COUNTS {
        println!("exp7: {n} shard(s)...");
        let (load_tput, a_tput, m, per_shard, shard_m) = run_one(&cfg, n);
        for (s, sm) in shard_m.iter().enumerate() {
            bt.row(vec![
                n.to_string(),
                s.to_string(),
                sm.ops_done.to_string(),
                sm.stalls.to_string(),
                format!("{:.2}", sm.stall_ns as f64 / 1e6),
                format!("{:.2}", sm.queue_wait.get(&Dev::Ssd).copied().unwrap_or(0) as f64 / 1e6),
                format!("{:.2}", sm.queue_wait.get(&Dev::Hdd).copied().unwrap_or(0) as f64 / 1e6),
                format!("{:.2}", sm.cpu_wait.sum as f64 / 1e6),
            ]);
        }
        let speedup = match base_a {
            None => {
                base_a = Some(a_tput);
                1.0
            }
            Some(b) => a_tput / b.max(1e-9),
        };
        let max_ops = per_shard.iter().copied().max().unwrap_or(0);
        let min_ops = per_shard.iter().copied().min().unwrap_or(0);
        t.row(vec![
            n.to_string(),
            format!("{load_tput:.0}"),
            format!("{a_tput:.0}"),
            format!("{speedup:.2}x"),
            m.read_lat.quantile(0.99).to_string(),
            m.read_lat.quantile(0.999).to_string(),
            format!("{:.1}", m.total_queue_wait_ns() as f64 / 1e6),
            format!("{:.1}", m.cpu_wait.sum as f64 / 1e6),
            format!("{:.1}", m.key_arena_bytes as f64 / 1024.0),
            format!("{:.2}", resident_total_bytes(&m) as f64 / (1024.0 * 1024.0)),
            format!("{:.2}", max_ops as f64 / (min_ops.max(1)) as f64),
            (m.migrations_cap + m.migrations_pop).to_string(),
            wal_write_ios(&m).to_string(),
        ]);
    }
    t.emit(csv, "exp7_shards");
    bt.emit(csv, "exp7_shard_breakdown");

    // The stall-aware scheduler vs FIFO at 4 shards and equal
    // bg_threads, plus the fg-saturated row (clients > fg slots): the
    // device-bound → CPU-bound crossover.
    let mut st = sched_table(
        "Exp#7 scheduler: stall-aware vs FIFO wakes at 4 shards (equal bg_threads)",
    );
    sched_row(&mut st, &cfg, 4, WakePolicy::Fifo, 0);
    sched_row(&mut st, &cfg, 4, WakePolicy::StallAware, 0);
    sched_row(&mut st, &cfg, 4, WakePolicy::StallAware, 8);
    st.emit(csv, "exp7_sched");

    // Request fusion off vs on at 4 shards under a saturating client
    // pool: what cross-shard group commit does to the device-visible WAL
    // request count and the shared SSD's queue.
    let mut ft = batching_table(
        "Exp#7 batching: cross-shard group commit + read coalescing at 4 shards (saturated)",
    );
    batching_row(&mut ft, &cfg, 4, false);
    batching_row(&mut ft, &cfg, 4, true);
    ft.emit(csv, "exp7_batching");
}

/// CI smoke: shards {8, 64} at 1× and 4× keyspace with the always-on
/// residency-flatness gate.
///
/// The gate is machine-independent — every input is a deterministic
/// virtual byte count, no wallclock — and pins the tentpole property:
/// with demand paging, *resident* bytes track the working set (block
/// cache pins, WAL windows, torn tails), not the logical dataset. Under
/// an equal working set (same ops, same cache budget), quadrupling the
/// keyspace must not grow residency past 1.5× (+ a small absolute slack
/// so near-zero baselines don't amplify into flaky ratios).
pub fn run_quick(opts: &ExpOpts) {
    let csv = opts.csv_dir.as_deref();
    let mut base = opts.cfg.clone();
    base.workload.load_objects = 60_000;
    base.workload.ops = 20_000;
    let mut t = Table::new(
        "Exp#7 --quick: residency flatness vs keyspace (HHZS, load + YCSB A)",
        &["shards", "keyspace", "load ops/s", "A ops/s", "resident MiB", "resident/1x"],
    );
    for &n in &[8usize, 64] {
        let mut resident_1x: u64 = 0;
        for scale in [1u64, 4] {
            let mut cfg = base.clone();
            cfg.workload.load_objects *= scale;
            println!("exp7 --quick: {n} shard(s), {scale}x keyspace...");
            let (load_tput, a_tput, m, _, _) = run_one(&cfg, n);
            let resident = resident_total_bytes(&m);
            let ratio = if scale == 1 {
                resident_1x = resident;
                1.0
            } else {
                resident as f64 / resident_1x.max(1) as f64
            };
            t.row(vec![
                n.to_string(),
                format!("{scale}x"),
                format!("{load_tput:.0}"),
                format!("{a_tput:.0}"),
                format!("{:.2}", resident as f64 / (1024.0 * 1024.0)),
                format!("{ratio:.2}"),
            ]);
            if scale > 1 {
                let bound = resident_1x + resident_1x / 2 + 256 * 1024;
                assert!(
                    resident <= bound,
                    "residency flatness gate: {n} shards at {scale}x keyspace holds \
                     {resident} resident bytes > bound {bound} (1.5 × {resident_1x} + slack) — \
                     resident memory is scaling with the dataset, not the working set"
                );
            }
        }
    }
    t.emit(csv, "exp7_quick_residency");
    println!("exp7 --quick: residency flatness gate passed");

    // Scheduler smoke at the quick scale: stall-aware vs FIFO at 4
    // shards and equal bg_threads, plus the fg-saturated row. Gated on
    // the machine-independent invariants (all inputs are deterministic
    // virtual quantities): FIFO never reports an avoided stall, the
    // contention-free rows never accrue foreground CPU wait, and the
    // saturated row must measure some — the CPU-bound crossover exists.
    let mut st = sched_table(
        "Exp#7 --quick scheduler: stall-aware vs FIFO wakes at 4 shards",
    );
    let fifo = sched_row(&mut st, &base, 4, WakePolicy::Fifo, 0);
    let sa = sched_row(&mut st, &base, 4, WakePolicy::StallAware, 0);
    let sat = sched_row(&mut st, &base, 4, WakePolicy::StallAware, 8);
    st.emit(csv, "exp7_quick_sched");
    assert_eq!(fifo.stalls_avoided, 0, "FIFO wakes cannot avoid stalls");
    assert_eq!(fifo.fg_cpu_wait.n, 0, "fg_threads = 0 must stay contention-free");
    assert_eq!(sa.fg_cpu_wait.n, 0, "fg_threads = 0 must stay contention-free");
    assert!(
        sat.fg_cpu_wait.sum > 0,
        "saturated fg pool (clients > slots) measured zero foreground CPU wait"
    );
    println!("exp7 --quick: scheduler comparison gates passed");

    // Request-fusion gate — machine-independent (every input is a
    // deterministic virtual count): at 4 shards under a saturating client
    // pool, cross-shard group commit must ack the SAME ops with at most
    // half the device-visible WAL requests and no higher shared-SSD queue
    // wait. The 2× floor is conservative: a filled 100 µs window fuses
    // tens of records, but overflow fallbacks and tail windows keep some
    // singleton appends.
    let mut ft = batching_table(
        "Exp#7 --quick batching: cross-shard group commit at 4 shards (saturated)",
    );
    let off = batching_row(&mut ft, &base, 4, false);
    let on = batching_row(&mut ft, &base, 4, true);
    ft.emit(csv, "exp7_quick_batching");
    assert_eq!(
        off.ops_done, on.ops_done,
        "group commit must ack exactly the ops the ungrouped run acked"
    );
    assert_eq!(off.wal_group_size.n, 0, "off path must never sample a group size");
    assert!(on.wal_group_size.n > 0, "grouped run never closed a fused batch");
    let (ios_off, ios_on) = (wal_write_ios(&off), wal_write_ios(&on));
    assert!(
        2 * ios_on <= ios_off,
        "group commit gate: {ios_on} grouped WAL write ios > 0.5 x {ios_off} ungrouped \
         at equal acked ops — the fusion layer is not amortizing requests"
    );
    let qw = |m: &Metrics| m.queue_wait.get(&Dev::Ssd).copied().unwrap_or(0);
    assert!(
        qw(&on) <= qw(&off),
        "group commit gate: grouped SSD queue wait {} ns > ungrouped {} ns — \
         batching made the shared device queue worse",
        qw(&on),
        qw(&off)
    );
    println!("exp7 --quick: group-commit fusion gate passed");
}
