//! Exp#4 (Fig 8): impact of the read-write ratio — 10% to 90% reads at
//! α = 0.9, for B3, AUTO, and HHZS.

use crate::report::Table;
use crate::ycsb::Kind;

use super::common::{load_and_run, ExpOpts};

pub const READ_PCTS: [u32; 5] = [10, 30, 50, 70, 90];
pub const SCHEMES: [&str; 3] = ["B3", "AUTO", "HHZS"];

pub fn run(opts: &ExpOpts) {
    let cfg = &opts.cfg;
    let csv = opts.csv_dir.as_deref();
    let mut t = Table::new(
        "Fig 8: throughput (OPS) vs read percentage (α=0.9)",
        &["scheme", "10%", "30%", "50%", "70%", "90%"],
    );
    for s in SCHEMES {
        let mut row = vec![s.to_string()];
        for pct in READ_PCTS {
            println!("exp4: {s} {pct}% reads...");
            let (_, m) = load_and_run(cfg, s, Kind::Mixed { read_pct: pct }, 0.9);
            row.push(format!("{:.0}", m.ops_per_sec()));
        }
        t.row(row);
    }
    t.emit(csv, "exp4_fig8");
}
