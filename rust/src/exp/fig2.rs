//! Figure 2: the motivating analysis of the basic placement schemes
//! (§2.3, observations O1–O4).
//!
//! * (a)/(d): boxplots of actual WAL/L0–L4 sizes while loading under B4,
//!   without/with write throttling — O1/O3: actual sizes blow past targets.
//! * (b)/(e): % of write traffic to the SSD per category for B1–B4 — O2.
//! * (c)/(f): load throughput for B1–B4 — O2.
//! * (g): reads per SST at L3 under B4, SSD residents vs top HDD residents
//!   — O4: hot SSTs strand on the HDD.
//! * (h)/(i): % read traffic to HDD and read throughput, α ∈ {0.9, 1.2}.

use crate::metrics::{Metrics, WriteCategory};
use crate::report::{fmt_bytes, fmt_pct, Table};
use crate::ycsb::Kind;
use crate::zone::Dev;

use super::common::{load_fresh, run_phase, ExpOpts, ALL_BASICS};

fn boxplot(samples: &[u64]) -> (u64, u64, u64, u64, u64) {
    let mut s = samples.to_vec();
    s.sort_unstable();
    if s.is_empty() {
        return (0, 0, 0, 0, 0);
    }
    let q = |f: f64| s[((s.len() - 1) as f64 * f) as usize];
    (s[0], q(0.25), q(0.5), q(0.75), s[s.len() - 1])
}

fn sizes_table(title: &str, cfg: &crate::config::Config, m: &Metrics, csv: Option<&str>, name: &str) {
    let mut t = Table::new(
        title,
        &["level", "target", "min", "q1", "median", "q3", "max", "max/target"],
    );
    let num_levels = m.level_samples.first().map_or(0, |s| s.level_bytes.len());
    // WAL row.
    let wal: Vec<u64> = m.level_samples.iter().map(|s| s.wal_bytes).collect();
    let (mn, q1, md, q3, mx) = boxplot(&wal);
    let wal_target = cfg.geometry.wal_cache_zones as u64 * cfg.geometry.ssd_zone_cap;
    t.row(vec![
        "WAL".into(),
        fmt_bytes(wal_target),
        fmt_bytes(mn),
        fmt_bytes(q1),
        fmt_bytes(md),
        fmt_bytes(q3),
        fmt_bytes(mx),
        format!("{:.1}x", mx as f64 / wal_target.max(1) as f64),
    ]);
    for lvl in 0..num_levels.min(5) {
        let vals: Vec<u64> = m.level_samples.iter().map(|s| s.level_bytes[lvl]).collect();
        let (mn, q1, md, q3, mx) = boxplot(&vals);
        let target = match lvl {
            0 | 1 => cfg.lsm.l0_target,
            _ => cfg.lsm.l0_target * cfg.lsm.level_multiplier.pow(lvl as u32 - 1),
        };
        t.row(vec![
            format!("L{lvl}"),
            fmt_bytes(target),
            fmt_bytes(mn),
            fmt_bytes(q1),
            fmt_bytes(md),
            fmt_bytes(q3),
            fmt_bytes(mx),
            format!("{:.1}x", mx as f64 / target as f64),
        ]);
    }
    t.emit(csv, name);
}

fn traffic_table(
    title: &str,
    results: &[(String, Metrics)],
    csv: Option<&str>,
    name: &str,
) {
    let mut t = Table::new(title, &["scheme", "WAL", "L0", "L1", "L2", "L3", "L4", "total"]);
    for (scheme, m) in results {
        let mut row = vec![scheme.clone()];
        row.push(fmt_pct(m.ssd_write_fraction(Some(WriteCategory::Wal))));
        for lvl in 0..5 {
            row.push(fmt_pct(m.ssd_write_fraction(Some(WriteCategory::Sst(lvl)))));
        }
        row.push(fmt_pct(m.ssd_write_fraction(None)));
        t.row(row);
    }
    t.emit(csv, name);
}

fn tput_table(title: &str, results: &[(String, Metrics)], csv: Option<&str>, name: &str) {
    let mut t = Table::new(title, &["scheme", "OPS", "stalls"]);
    for (scheme, m) in results {
        t.row(vec![
            scheme.clone(),
            format!("{:.0}", m.ops_per_sec()),
            format!("{}", m.stalls),
        ]);
    }
    t.emit(csv, name);
}

pub fn run(opts: &ExpOpts) {
    let cfg = &opts.cfg;
    let csv = opts.csv_dir.as_deref();

    // ---- (a)-(c): unthrottled loads over B1..B4 -----------------------
    println!("fig2: loading under B1..B4 (unthrottled)...");
    let mut loads: Vec<(String, Metrics)> = Vec::new();
    let mut b4_sizes: Option<Metrics> = None;
    for s in ALL_BASICS {
        let (_, m) = load_fresh(cfg, s, None, true);
        if s == "B4" {
            b4_sizes = Some(m.clone_for_samples());
        }
        loads.push((s.to_string(), m));
    }
    sizes_table(
        "Fig 2(a): actual sizes while loading (B4, no throttling)",
        cfg,
        b4_sizes.as_ref().unwrap(),
        csv,
        "fig2a_sizes",
    );
    traffic_table(
        "Fig 2(b): % write traffic to SSD by category (no throttling)",
        &loads,
        csv,
        "fig2b_traffic",
    );
    tput_table("Fig 2(c): load throughput (OPS)", &loads, csv, "fig2c_load");

    // ---- (d)-(f): throttled loads --------------------------------------
    // The paper throttles to 6,000 OPS — below every basic scheme's load
    // throughput. We scale the same way: 60% of the slowest basic scheme.
    let min_tput =
        loads.iter().map(|(_, m)| m.ops_per_sec()).fold(f64::INFINITY, f64::min);
    let target = (min_tput * 0.6).max(100.0);
    println!("fig2: loading under B1..B4 (throttled to {target:.0} OPS)...");
    let mut tloads: Vec<(String, Metrics)> = Vec::new();
    let mut b4_tsizes: Option<Metrics> = None;
    for s in ALL_BASICS {
        let (_, m) = load_fresh(cfg, s, Some(target), true);
        if s == "B4" {
            b4_tsizes = Some(m.clone_for_samples());
        }
        tloads.push((s.to_string(), m));
    }
    sizes_table(
        &format!("Fig 2(d): actual sizes while loading (B4, throttled {target:.0} OPS)"),
        cfg,
        b4_tsizes.as_ref().unwrap(),
        csv,
        "fig2d_sizes",
    );
    traffic_table(
        "Fig 2(e): % write traffic to SSD by category (throttled)",
        &tloads,
        csv,
        "fig2e_traffic",
    );
    tput_table("Fig 2(f): load throughput, throttled (OPS)", &tloads, csv, "fig2f_load");

    // ---- (g): reads per L3 SST under B4 --------------------------------
    println!("fig2: B4 + skewed reads (α=0.9) for per-SST read counts...");
    let (mut e, _) = load_fresh(cfg, "B4", None, false);
    let m = run_phase(&mut e, cfg, Kind::C, 0.9);
    let mut ssd_l3: Vec<(u64, u64)> = Vec::new();
    let mut hdd_l3: Vec<(u64, u64)> = Vec::new();
    for (sst, (lvl, dev, n)) in &m.sst_reads {
        if *lvl == 3 {
            match dev {
                Dev::Ssd => ssd_l3.push((*sst, *n)),
                Dev::Hdd => hdd_l3.push((*sst, *n)),
            }
        }
    }
    hdd_l3.sort_by_key(|(_, n)| std::cmp::Reverse(*n));
    let mut t = Table::new(
        "Fig 2(g): reads per SST at L3 (B4, α=0.9): SSD residents vs top-5 HDD",
        &["sst", "device", "reads"],
    );
    for (sst, n) in ssd_l3.iter().take(5) {
        t.row(vec![format!("{sst}"), "SSD".into(), format!("{n}")]);
    }
    for (sst, n) in hdd_l3.iter().take(5) {
        t.row(vec![format!("{sst}"), "HDD".into(), format!("{n}")]);
    }
    t.emit(csv, "fig2g_sst_reads");

    // ---- (h)/(i): read traffic split and read throughput ----------------
    let mut t_h = Table::new(
        "Fig 2(h): % read traffic to HDD",
        &["scheme", "α=0.9", "α=1.2"],
    );
    let mut t_i = Table::new(
        "Fig 2(i): read throughput (OPS)",
        &["scheme", "α=0.9", "α=1.2"],
    );
    for s in ALL_BASICS {
        println!("fig2: {s} reads at α=0.9 / α=1.2 ...");
        let (mut e9, _) = load_fresh(cfg, s, None, false);
        let m9 = run_phase(&mut e9, cfg, Kind::C, 0.9);
        let (mut e12, _) = load_fresh(cfg, s, None, false);
        let m12 = run_phase(&mut e12, cfg, Kind::C, 1.2);
        t_h.row(vec![
            s.to_string(),
            fmt_pct(m9.hdd_read_fraction()),
            fmt_pct(m12.hdd_read_fraction()),
        ]);
        t_i.row(vec![
            s.to_string(),
            format!("{:.0}", m9.ops_per_sec()),
            format!("{:.0}", m12.ops_per_sec()),
        ]);
    }
    t_h.emit(csv, "fig2h_read_traffic");
    t_i.emit(csv, "fig2i_read_tput");
}

impl Metrics {
    /// Shallow copy carrying only the level samples (boxplot input).
    pub fn clone_for_samples(&self) -> Metrics {
        let mut m = Metrics::default();
        m.level_samples = self.level_samples.clone();
        m
    }
}
