//! Exp#5 (Fig 9): impact of the SSD size — 20/40/60/80 available SSD
//! zones, over (a) the load and (b) a 50/50 mixed workload at α = 0.9,
//! comparing B1–B4, AUTO, P, and full HHZS.

use crate::report::Table;
use crate::ycsb::Kind;

use super::common::{load_and_run, load_fresh, ExpOpts};

pub const ZONE_COUNTS: [u32; 4] = [20, 40, 60, 80];
pub const SCHEMES: [&str; 7] = ["B1", "B2", "B3", "B4", "AUTO", "P", "HHZS"];

pub fn run(opts: &ExpOpts) {
    let csv = opts.csv_dir.as_deref();
    let headers = ["scheme", "20 zones", "40 zones", "60 zones", "80 zones"];
    let mut t_load = Table::new("Fig 9(a): load throughput (OPS) vs SSD size", &headers);
    let mut t_mixed = Table::new(
        "Fig 9(b): mixed 50%r/50%w α=0.9 throughput (OPS) vs SSD size",
        &headers,
    );
    for s in SCHEMES {
        let mut row_load = vec![s.to_string()];
        let mut row_mixed = vec![s.to_string()];
        for zones in ZONE_COUNTS {
            println!("exp5: {s} with {zones} SSD zones...");
            let mut cfg = opts.cfg.clone();
            cfg.geometry.ssd_zones = zones;
            let (_, m) = load_fresh(&cfg, s, None, false);
            row_load.push(format!("{:.0}", m.ops_per_sec()));
            let (_, m) = load_and_run(&cfg, s, Kind::Mixed { read_pct: 50 }, 0.9);
            row_mixed.push(format!("{:.0}", m.ops_per_sec()));
        }
        t_load.row(row_load);
        t_mixed.row(row_mixed);
    }
    t_load.emit(csv, "exp5_fig9a");
    t_mixed.emit(csv, "exp5_fig9b");
}
