//! Exp#3 (Fig 7): impact of workload skewness — α from 0.8 to 1.2 with a
//! 50/50 read-write mix, for B3, AUTO, and HHZS.

use crate::report::Table;
use crate::ycsb::Kind;

use super::common::{load_and_run, ExpOpts};

pub const ALPHAS: [f64; 5] = [0.8, 0.9, 1.0, 1.1, 1.2];
pub const SCHEMES: [&str; 3] = ["B3", "AUTO", "HHZS"];

pub fn run(opts: &ExpOpts) {
    let cfg = &opts.cfg;
    let csv = opts.csv_dir.as_deref();
    let mut t = Table::new(
        "Fig 7: throughput (OPS) vs skewness (50% reads / 50% writes)",
        &["scheme", "α=0.8", "α=0.9", "α=1.0", "α=1.1", "α=1.2"],
    );
    for s in SCHEMES {
        let mut row = vec![s.to_string()];
        for alpha in ALPHAS {
            println!("exp3: {s} α={alpha}...");
            let (_, m) = load_and_run(cfg, s, Kind::Mixed { read_pct: 50 }, alpha);
            row.push(format!("{:.0}", m.ops_per_sec()));
        }
        t.row(row);
    }
    t.emit(csv, "exp3_fig7");
}
