//! Shared experiment machinery: scheme construction, the load-then-measure
//! protocol of §4.1 ("before evaluating each workload ... we always first
//! clear the storage and load the KV objects"), and result records.

use crate::config::Config;
use crate::coordinator::Engine;
use crate::metrics::Metrics;
use crate::policy::{AutoPolicy, BasicPolicy, HhzsPolicy, Policy};
use crate::ycsb::{Kind, Spec, YcsbSource};

/// Build a placement scheme by its paper name.
///
/// `B1..B4` — basic schemes (§2.3); `B3+M` — basic + migration (Exp#2);
/// `AUTO` — SpanDB automated placement (§4.1); `P` / `P+M` / `P+M+C` —
/// HHZS ablations (Exp#2); `HHZS` — the full system (= `P+M+C`).
pub fn make_policy(name: &str, cfg: &Config) -> Box<dyn Policy> {
    let nl = cfg.lsm.num_levels;
    match name {
        "B1" => Box::new(BasicPolicy::new(1)),
        "B2" => Box::new(BasicPolicy::new(2)),
        "B3" => Box::new(BasicPolicy::new(3)),
        "B4" => Box::new(BasicPolicy::new(4)),
        "B3+M" => Box::new(BasicPolicy::with_migration(3)),
        "AUTO" => Box::new(AutoPolicy::new()),
        "P" => Box::new(HhzsPolicy::placement_only(nl)),
        "P+M" => Box::new(HhzsPolicy::placement_migration(nl)),
        "P+M+C" | "HHZS" => Box::new(HhzsPolicy::new(nl)),
        "HHZS-nohints" => Box::new(HhzsPolicy::without_demand_hints(nl)),
        other => panic!("unknown scheme {other:?}"),
    }
}

pub const ALL_BASICS: [&str; 4] = ["B1", "B2", "B3", "B4"];

/// Summary of one measured phase.
#[derive(Clone, Debug)]
pub struct PhaseResult {
    pub scheme: String,
    pub phase: String,
    pub ops_per_sec: f64,
    pub hdd_read_frac: f64,
    pub ssd_write_frac: f64,
    pub read_p99_ns: u64,
    pub read_p999_ns: u64,
    pub read_p9999_ns: u64,
    pub stalls: u64,
    pub migrations: u64,
    pub ssd_cache_hits: u64,
}

impl PhaseResult {
    pub fn from_metrics(scheme: &str, phase: &str, m: &Metrics) -> Self {
        PhaseResult {
            scheme: scheme.into(),
            phase: phase.into(),
            ops_per_sec: m.ops_per_sec(),
            hdd_read_frac: m.hdd_read_fraction(),
            ssd_write_frac: m.ssd_write_fraction(None),
            read_p99_ns: m.read_lat.quantile(0.99),
            read_p999_ns: m.read_lat.quantile(0.999),
            read_p9999_ns: m.read_lat.quantile(0.9999),
            stalls: m.stalls,
            migrations: m.migrations_cap + m.migrations_pop,
            ssd_cache_hits: m.ssd_cache_hits,
        }
    }
}

/// Fresh engine with a fresh load of `cfg.workload.load_objects` objects
/// (the §4.1 protocol). Returns the engine and the load-phase metrics.
pub fn load_fresh(
    cfg: &Config,
    scheme: &str,
    throttle: Option<f64>,
    sample: bool,
) -> (Engine, Metrics) {
    let mut engine = Engine::new(cfg.clone(), make_policy(scheme, cfg));
    let spec = Spec::from_config(cfg, Kind::Load);
    let mut src = YcsbSource::new(spec, cfg.workload.clients);
    engine.run(&mut src, cfg.workload.clients, throttle, sample);
    let m = std::mem::take(&mut engine.metrics);
    // YCSB's load and run phases are separate DB sessions: the reopen
    // between them flushes all MemTables and empties the WAL.
    engine.flush_all();
    (engine, m)
}

/// Run one measured workload phase on an already-loaded engine.
pub fn run_phase(engine: &mut Engine, cfg: &Config, kind: Kind, alpha: f64) -> Metrics {
    let mut spec = Spec::from_config(cfg, kind);
    spec.alpha = alpha;
    let mut src = YcsbSource::new(spec, cfg.workload.clients);
    engine.run(&mut src, cfg.workload.clients, None, false);
    std::mem::take(&mut engine.metrics)
}

/// Load + measure in one call (fresh storage per workload, §4.1).
pub fn load_and_run(cfg: &Config, scheme: &str, kind: Kind, alpha: f64) -> (Engine, Metrics) {
    let (mut engine, _) = load_fresh(cfg, scheme, None, false);
    let m = run_phase(&mut engine, cfg, kind, alpha);
    (engine, m)
}

/// Quick/default/full sizing for experiment drivers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// Small but shape-preserving (CI / cargo bench default).
    Quick,
    /// The EXPERIMENTS.md reference profile.
    Default,
    /// Closer to paper proportions (slow).
    Full,
}

impl Profile {
    pub fn config(&self) -> Config {
        match self {
            Profile::Quick => {
                let mut c = Config::paper_scaled(1024);
                c.workload.load_objects = 120_000; // ~120 MiB ≈ 5.7× SSD
                c.workload.ops = 40_000;
                c
            }
            Profile::Default => {
                let mut c = Config::paper_scaled(256);
                c.workload.load_objects = 500_000; // ~0.5 GiB ≈ 6× SSD
                c.workload.ops = 150_000;
                c
            }
            Profile::Full => {
                let mut c = Config::paper_scaled(64);
                c.workload.load_objects = 2_000_000; // ~2 GiB ≈ 6× SSD
                c.workload.ops = 1_000_000;
                c
            }
        }
    }

    pub fn from_str(s: &str) -> Option<Profile> {
        match s {
            "quick" => Some(Profile::Quick),
            "default" => Some(Profile::Default),
            "full" => Some(Profile::Full),
            _ => None,
        }
    }
}

/// Options shared by all experiment drivers.
pub struct ExpOpts {
    pub cfg: Config,
    pub csv_dir: Option<String>,
}

impl ExpOpts {
    pub fn new(profile: Profile) -> Self {
        ExpOpts { cfg: profile.config(), csv_dir: Some("results".into()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_scheme_names_construct() {
        let cfg = Config::tiny();
        for s in ["B1", "B2", "B3", "B4", "B3+M", "AUTO", "P", "P+M", "P+M+C", "HHZS"] {
            let p = make_policy(s, &cfg);
            if s == "HHZS" {
                assert_eq!(p.name(), "HHZS");
            } else if s == "P+M+C" {
                assert_eq!(p.name(), "HHZS");
            } else {
                assert_eq!(p.name(), s);
            }
        }
    }

    #[test]
    #[should_panic]
    fn unknown_scheme_panics() {
        make_policy("B9", &Config::tiny());
    }

    #[test]
    fn profiles_scale_monotonically() {
        let q = Profile::Quick.config();
        let d = Profile::Default.config();
        let f = Profile::Full.config();
        assert!(q.workload.load_objects < d.workload.load_objects);
        assert!(d.workload.load_objects < f.workload.load_objects);
        // All profiles keep dataset ≫ SSD (the experiments' core tension).
        for c in [q, d, f] {
            assert!(c.workload.load_objects * 1024 > 3 * c.ssd_capacity());
        }
    }

    #[test]
    fn load_and_phase_protocol() {
        let mut cfg = Config::tiny();
        cfg.workload.load_objects = 15_000;
        cfg.workload.ops = 3_000;
        let (mut e, load_m) = load_fresh(&cfg, "B3", None, false);
        assert_eq!(load_m.writes_done, 15_000);
        let m = run_phase(&mut e, &cfg, Kind::C, 0.9);
        assert_eq!(m.reads_done, 3_000);
        assert!(m.ops_per_sec() > 0.0);
    }
}
