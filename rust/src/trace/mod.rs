//! Deterministic virtual-time tracing: causal spans over the DES.
//!
//! Every emission is stamped on the *virtual* clock (never wall time), so a
//! trace is a pure function of `(config, seed)` — two runs of the same
//! workload produce byte-identical exports, and tracing is observation-only:
//! enabling it must not move a single virtual timestamp (pinned by the
//! golden-digest tests in `tests/datapath.rs`).
//!
//! Architecture:
//!
//! * [`TraceSink`] — a cloneable handle, `None` when tracing is off. The hot
//!   path costs one `Option` test when disabled and the event-constructor
//!   closure is never called, so the off path compiles to (almost) nothing.
//!   All shards of a [`crate::shard::ShardedEngine`] share ONE sink (rebound
//!   like the CPU pool and device timers), so events land in global
//!   `(time, seq)` order — the order the frontend processes them in.
//! * [`TraceBuf`] — a bounded ring (drop-oldest). A full ring never blocks
//!   or reallocates; it counts `dropped`, and the checker refuses to verify
//!   sum invariants over a lossy trace.
//! * [`Event`] — the span/event taxonomy with causal ids (shard, job id,
//!   SST id, zone id, client id). Each event renders to one pipe-delimited
//!   record (`Event::line`); the export embeds both those records (the
//!   machine-checkable form) and Chrome-trace/Perfetto `traceEvents` (the
//!   human-visual form) in one JSON file.
//! * [`check_export`] — the second correctness oracle: replays an export
//!   and asserts (1) job spans and CPU slot spans are well-nested and
//!   properly paired per resource, (2) per-device busy intervals never
//!   overlap (the QD1 FIFO contract), (3) concurrent CPU spans never exceed
//!   `bg_threads` and the replayed slot count matches the pool's reported
//!   occupancy, (4) flush-priority reservations are never violated by a
//!   compaction admission, and (5) per shard, summed trace queue/CPU wait
//!   and stall counts equal the `Metrics` snapshots *exactly*.
//!
//! Span taxonomy (pipe records, one per line in `hhzsEvents`):
//!
//! ```text
//! DEV|dev|kind|bytes|issue|start|finish        device service interval (QD1 FIFO)
//! DEV|dev|kind|bytes|issue|start|finish|members   fused interval (members >= 2 logical reqs)
//! IO|dev|op|shard|job|sst|bytes|wait|at        one Metrics::record_queue_wait site
//! CPUWAIT|shard|kind|job|wait|at               one Metrics::cpu_wait sample
//! ACQ|shard|kind|job|at|in_use                 CPU slot acquired (occupancy after)
//! REL|shard|kind|job|at|in_use                 CPU slot released (occupancy after)
//! DENY|shard|at                                flush admission denied (waiter set)
//! UNWAIT|shard|at                              flush waiter cleared without a grant
//! JOB|shard|kind|job|queued|at                 job span opens (queued <= at)
//! JOBEND|shard|kind|job|at                     job span closes
//! MIGS|shard|sst|from|to|at                    migration span opens
//! MIGE|shard|sst|at                            migration span closes
//! STALL|shard|client|at                        writer parked (one Metrics::stalls)
//! UNSTALL|shard|client|at|dur                  parked op executed after dur ns
//! ZAPP|dev|zone|bytes|at                       zone append committed
//! ZRST|dev|zone|at                             zone reset
//! ZTRUNC|dev|zone|wp|at                        power-loss truncation (crash)
//! CRASH|shard|point|at                         crash injector fired
//! RECOV|shard|replayed|at                      recovery complete (WAL replay)
//! CADM|shard|sst|zone|bytes|at                 SSD cache admit
//! CEVT|shard|zone|at                           SSD cache zone evicted
//! HINT|shard|kind|at                           hint issued to the policy
//! RISK|shard|score|at                          stall-risk score pushed to the pool
//! WAKE|shard|class|risk|age|rank|round|at      one slot of a stall-aware wake round
//! FG|shard|start|cost|wait|at                  foreground CPU charge (fg pool)
//! SNAP|shard|at|stalls|stall_ns|qw_ssd|qw_hdd|cpuw_n|cpuw_sum|ops|fl|comp|fgw_n|fgw_sum
//!                                              Metrics snapshot (phase boundary)
//! BATCHO|id|dev|at                             group-commit batch opens (first record staged)
//! BATCHC|id|dev|members|bytes|start|finish|at  batch closes: ONE fused device append
//! BATCHA|id|shard|client|bytes|staged|ack      one member op acked (ack >= fused finish)
//! FUSE|dev|shard|members|bytes|member_bytes|gap|at  coalesced SST read access
//! WALPAD|shard|dev|zone|bytes|at               WAL zone tail stranded (record didn't fit)
//! ```
//!
//! The checker replays BATCH/FUSE causally: every BATCHO must close, the
//! fused access's byte total must equal the sum of its BATCHA members, the
//! member count must match, and no ack may precede the fused finish.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, VecDeque};
use std::rc::Rc;

use crate::hints::{CompactionHint, Hint};
use crate::metrics::Metrics;
use crate::sim::{AccessKind, Ns};
use crate::zone::{Dev, ZoneId};

/// Default ring capacity (events). At roughly 100 bytes/event this bounds
/// the trace memory to ~100 MiB fully loaded; small CI workloads fit with
/// large margin, and the checker rejects a trace that overflowed.
pub const DEFAULT_BUFFER_EVENTS: usize = 1 << 20;

/// Which background job kind a CPU span / job span belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum JobKind {
    Flush,
    Compaction,
}

impl JobKind {
    pub fn name(self) -> &'static str {
        match self {
            JobKind::Flush => "flush",
            JobKind::Compaction => "comp",
        }
    }
}

/// Which datapath an `IO` record (a `Metrics::record_queue_wait` mirror)
/// came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoOp {
    Wal,
    WalOverflow,
    WalRecover,
    CacheRead,
    CacheWrite,
    BlockRead,
    ScanRead,
    CompactionRead,
    SstWrite,
    MigrationRead,
    MigrationWrite,
}

impl IoOp {
    pub fn name(self) -> &'static str {
        match self {
            IoOp::Wal => "wal",
            IoOp::WalOverflow => "wal_of",
            IoOp::WalRecover => "wal_rec",
            IoOp::CacheRead => "cache_rd",
            IoOp::CacheWrite => "cache_wr",
            IoOp::BlockRead => "block_rd",
            IoOp::ScanRead => "scan_rd",
            IoOp::CompactionRead => "comp_rd",
            IoOp::SstWrite => "sst_wr",
            IoOp::MigrationRead => "mig_rd",
            IoOp::MigrationWrite => "mig_wr",
        }
    }
}

/// Short label for a hint, for `HINT` records.
pub fn hint_kind(h: &Hint) -> &'static str {
    match h {
        Hint::Flush(_) => "flush",
        Hint::Compaction(CompactionHint::Start { .. }) => "comp_start",
        Hint::Compaction(CompactionHint::OutputSst { .. }) => "comp_out",
        Hint::Compaction(CompactionHint::Finish { .. }) => "comp_fin",
        Hint::CacheEvict(_) => "cache_evict",
    }
}

fn kind_name(k: AccessKind) -> &'static str {
    match k {
        AccessKind::SeqRead => "seq_rd",
        AccessKind::SeqWrite => "seq_wr",
        AccessKind::RandRead => "rnd_rd",
    }
}

/// One trace event. See the module docs for the record schema.
#[derive(Clone, Debug)]
pub enum Event {
    /// A device service interval from the QD1 FIFO timer: queued at
    /// `issue`, served `[start, finish)`. `members > 1` marks a fused
    /// access carrying that many logical requests in one transfer (the
    /// record then grows an eighth field; plain accesses keep the
    /// original 7-field form byte-for-byte).
    Dev { dev: Dev, kind: AccessKind, bytes: u64, issue: Ns, start: Ns, finish: Ns, members: u32 },
    /// One `Metrics::record_queue_wait` site, with causal ids.
    Io {
        dev: Dev,
        op: IoOp,
        shard: usize,
        job: Option<u64>,
        sst: Option<u64>,
        bytes: u64,
        wait: Ns,
        at: Ns,
    },
    /// One `Metrics::cpu_wait` sample (recorded at job admission).
    CpuWait { shard: usize, kind: JobKind, job: u64, wait: Ns, at: Ns },
    /// CPU slot acquired; `in_use` is pool occupancy *after* the acquire.
    CpuAcquire { shard: usize, kind: JobKind, job: u64, at: Ns, in_use: usize },
    /// CPU slot released; `in_use` is pool occupancy *after* the release.
    CpuRelease { shard: usize, kind: JobKind, job: u64, at: Ns, in_use: usize },
    /// Flush admission denied — the pool marked this shard a flush waiter.
    FlushDenied { shard: usize, at: Ns },
    /// Flush waiter cleared without a grant (flush no longer wanted).
    FlushUnwait { shard: usize, at: Ns },
    /// Background job span opens (`queued` is when it became ready).
    JobStart { shard: usize, kind: JobKind, job: u64, queued: Ns, at: Ns },
    /// Background job span closes.
    JobEnd { shard: usize, kind: JobKind, job: u64, at: Ns },
    /// Migration span opens for one SST.
    MigStart { shard: usize, sst: u64, from: Dev, to: Dev, at: Ns },
    /// Migration span closes (completed or aborted).
    MigEnd { shard: usize, sst: u64, at: Ns },
    /// A writer parked on a write stall (one `Metrics::stalls`).
    Stall { shard: usize, client: usize, at: Ns },
    /// A previously parked op executed `dur` ns after issue.
    Unstall { shard: usize, client: usize, at: Ns, dur: Ns },
    /// Zone append committed (write pointer advanced by `bytes`).
    ZoneAppend { dev: Dev, zone: ZoneId, bytes: u64, at: Ns },
    /// Zone reset.
    ZoneReset { dev: Dev, zone: ZoneId, at: Ns },
    /// Power-loss truncation: the zone's write pointer landed at `wp`
    /// (possibly mid-record) when the crash injector fired.
    ZoneTrunc { dev: Dev, zone: ZoneId, wp: u64, at: Ns },
    /// The crash injector fired at `at` (virtual power loss).
    CrashFired { shard: usize, point: &'static str, at: Ns },
    /// Recovery finished: `replayed` WAL entries were re-applied.
    Recovered { shard: usize, replayed: u64, at: Ns },
    /// SSD cache admitted a block of `sst`.
    CacheAdmit { shard: usize, sst: u64, zone: ZoneId, bytes: u64, at: Ns },
    /// SSD cache evicted (reset) a cache zone.
    CacheEvict { shard: usize, zone: ZoneId, at: Ns },
    /// The engine issued a hint to the policy.
    HintIssued { shard: usize, kind: &'static str, at: Ns },
    /// A shard pushed a new stall-risk score to the shared CPU pool
    /// (emitted on change only; the checker tracks the latest per shard).
    StallRisk { shard: usize, score: u64, at: Ns },
    /// One slot of a stall-aware wake round: the pool offered the slot at
    /// `rank` within `round` to `shard` with the recorded risk/age. The
    /// checker replays every round and asserts flush-class-first ordering
    /// and non-increasing effective priority within each class.
    SchedWake { shard: usize, flush: bool, risk: u64, age: u64, rank: usize, round: u64, at: Ns },
    /// A foreground op charged `cost` ns against the fg pool: issued at
    /// `at`, granted a slot at `start` after `wait` ns of queueing.
    FgCharge { shard: usize, start: Ns, cost: Ns, wait: Ns, at: Ns },
    /// Per-shard `Metrics` snapshot at a phase boundary (and once at
    /// export). The checker verifies segment sums against these exactly.
    Snapshot {
        shard: usize,
        at: Ns,
        stalls: u64,
        stall_ns: Ns,
        qw_ssd: Ns,
        qw_hdd: Ns,
        cpuw_n: u64,
        cpuw_sum: u128,
        ops: u64,
        flushes: u64,
        compactions: u64,
        fgw_n: u64,
        fgw_sum: u128,
    },
    /// A group-commit batch opened: the first WAL record of a window was
    /// staged. `id` is the causal key tying BATCHO/BATCHC/BATCHA together.
    BatchOpen { id: u64, dev: Dev, at: Ns },
    /// The batch closed: ONE fused device append of `bytes` (the sum of
    /// all member records) served `[start, finish)` for `members` ops.
    BatchClose { id: u64, dev: Dev, members: u32, bytes: u64, start: Ns, finish: Ns, at: Ns },
    /// One member of a closed batch acked: the op staged its record at
    /// `staged` and completes at `ack >= finish` (device durability plus
    /// any residual CPU time).
    BatchAck { id: u64, shard: usize, client: usize, bytes: u64, staged: Ns, ack: Ns },
    /// A coalesced SST read: `members` block requests fused into one
    /// device access of `bytes` = `member_bytes` data + `gap_bytes`
    /// read-and-discarded gap.
    ReadFuse { dev: Dev, shard: usize, members: u32, bytes: u64, member_bytes: u64, gap_bytes: u64, at: Ns },
    /// The active WAL zone's tail remainder was stranded because the next
    /// record didn't fit (mirrors `Metrics::wal_pad_bytes`).
    WalPad { shard: usize, dev: Dev, zone: ZoneId, bytes: u64, at: Ns },
}

fn opt(v: Option<u64>) -> String {
    v.map_or_else(|| "-".to_string(), |x| x.to_string())
}

impl Event {
    /// Snapshot constructor from a live `Metrics`.
    pub fn snapshot(shard: usize, at: Ns, m: &Metrics) -> Event {
        Event::Snapshot {
            shard,
            at,
            stalls: m.stalls,
            stall_ns: m.stall_ns,
            qw_ssd: m.queue_wait.get(&Dev::Ssd).copied().unwrap_or(0),
            qw_hdd: m.queue_wait.get(&Dev::Hdd).copied().unwrap_or(0),
            cpuw_n: m.cpu_wait.n,
            cpuw_sum: m.cpu_wait.sum,
            ops: m.ops_done,
            flushes: m.flushes,
            compactions: m.compactions,
            fgw_n: m.fg_cpu_wait.n,
            fgw_sum: m.fg_cpu_wait.sum,
        }
    }

    /// The pipe-delimited record for this event (see module docs).
    pub fn line(&self) -> String {
        match self {
            Event::Dev { dev, kind, bytes, issue, start, finish, members } => {
                if *members > 1 {
                    format!(
                        "DEV|{}|{}|{bytes}|{issue}|{start}|{finish}|{members}",
                        dev.name(),
                        kind_name(*kind)
                    )
                } else {
                    format!(
                        "DEV|{}|{}|{bytes}|{issue}|{start}|{finish}",
                        dev.name(),
                        kind_name(*kind)
                    )
                }
            }
            Event::Io { dev, op, shard, job, sst, bytes, wait, at } => format!(
                "IO|{}|{}|{shard}|{}|{}|{bytes}|{wait}|{at}",
                dev.name(),
                op.name(),
                opt(*job),
                opt(*sst)
            ),
            Event::CpuWait { shard, kind, job, wait, at } => {
                format!("CPUWAIT|{shard}|{}|{job}|{wait}|{at}", kind.name())
            }
            Event::CpuAcquire { shard, kind, job, at, in_use } => {
                format!("ACQ|{shard}|{}|{job}|{at}|{in_use}", kind.name())
            }
            Event::CpuRelease { shard, kind, job, at, in_use } => {
                format!("REL|{shard}|{}|{job}|{at}|{in_use}", kind.name())
            }
            Event::FlushDenied { shard, at } => format!("DENY|{shard}|{at}"),
            Event::FlushUnwait { shard, at } => format!("UNWAIT|{shard}|{at}"),
            Event::JobStart { shard, kind, job, queued, at } => {
                format!("JOB|{shard}|{}|{job}|{queued}|{at}", kind.name())
            }
            Event::JobEnd { shard, kind, job, at } => {
                format!("JOBEND|{shard}|{}|{job}|{at}", kind.name())
            }
            Event::MigStart { shard, sst, from, to, at } => {
                format!("MIGS|{shard}|{sst}|{}|{}|{at}", from.name(), to.name())
            }
            Event::MigEnd { shard, sst, at } => format!("MIGE|{shard}|{sst}|{at}"),
            Event::Stall { shard, client, at } => format!("STALL|{shard}|{client}|{at}"),
            Event::Unstall { shard, client, at, dur } => {
                format!("UNSTALL|{shard}|{client}|{at}|{dur}")
            }
            Event::ZoneAppend { dev, zone, bytes, at } => {
                format!("ZAPP|{}|{zone}|{bytes}|{at}", dev.name())
            }
            Event::ZoneReset { dev, zone, at } => format!("ZRST|{}|{zone}|{at}", dev.name()),
            Event::ZoneTrunc { dev, zone, wp, at } => {
                format!("ZTRUNC|{}|{zone}|{wp}|{at}", dev.name())
            }
            Event::CrashFired { shard, point, at } => format!("CRASH|{shard}|{point}|{at}"),
            Event::Recovered { shard, replayed, at } => format!("RECOV|{shard}|{replayed}|{at}"),
            Event::CacheAdmit { shard, sst, zone, bytes, at } => {
                format!("CADM|{shard}|{sst}|{zone}|{bytes}|{at}")
            }
            Event::CacheEvict { shard, zone, at } => format!("CEVT|{shard}|{zone}|{at}"),
            Event::HintIssued { shard, kind, at } => format!("HINT|{shard}|{kind}|{at}"),
            Event::StallRisk { shard, score, at } => format!("RISK|{shard}|{score}|{at}"),
            Event::SchedWake { shard, flush, risk, age, rank, round, at } => format!(
                "WAKE|{shard}|{}|{risk}|{age}|{rank}|{round}|{at}",
                if *flush { "flush" } else { "comp" }
            ),
            Event::FgCharge { shard, start, cost, wait, at } => {
                format!("FG|{shard}|{start}|{cost}|{wait}|{at}")
            }
            Event::Snapshot {
                shard,
                at,
                stalls,
                stall_ns,
                qw_ssd,
                qw_hdd,
                cpuw_n,
                cpuw_sum,
                ops,
                flushes,
                compactions,
                fgw_n,
                fgw_sum,
            } => format!(
                "SNAP|{shard}|{at}|{stalls}|{stall_ns}|{qw_ssd}|{qw_hdd}|{cpuw_n}|{cpuw_sum}|{ops}|{flushes}|{compactions}|{fgw_n}|{fgw_sum}"
            ),
            Event::BatchOpen { id, dev, at } => format!("BATCHO|{id}|{}|{at}", dev.name()),
            Event::BatchClose { id, dev, members, bytes, start, finish, at } => format!(
                "BATCHC|{id}|{}|{members}|{bytes}|{start}|{finish}|{at}",
                dev.name()
            ),
            Event::BatchAck { id, shard, client, bytes, staged, ack } => {
                format!("BATCHA|{id}|{shard}|{client}|{bytes}|{staged}|{ack}")
            }
            Event::ReadFuse { dev, shard, members, bytes, member_bytes, gap_bytes, at } => format!(
                "FUSE|{}|{shard}|{members}|{bytes}|{member_bytes}|{gap_bytes}|{at}",
                dev.name()
            ),
            Event::WalPad { shard, dev, zone, bytes, at } => {
                format!("WALPAD|{shard}|{}|{zone}|{bytes}|{at}", dev.name())
            }
        }
    }
}

/// The bounded event ring. Full ⇒ drop-oldest + count (never blocks, never
/// reallocates past capacity); `now` is the last virtual time any emitter
/// stamped, used by emission sites that have no clock of their own (zone
/// resets on untimed paths).
#[derive(Debug)]
pub struct TraceBuf {
    cap: usize,
    now: Ns,
    dropped: u64,
    events: VecDeque<Event>,
}

impl TraceBuf {
    fn push(&mut self, ev: Event) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }
}

/// Cloneable tracing handle. `Default` (and [`TraceSink::disabled`]) is the
/// no-op sink: one branch on the hot path, the event closure never runs.
#[derive(Clone, Debug, Default)]
pub struct TraceSink(Option<Rc<RefCell<TraceBuf>>>);

impl TraceSink {
    pub fn disabled() -> TraceSink {
        TraceSink(None)
    }

    pub fn enabled(buffer_events: usize) -> TraceSink {
        TraceSink(Some(Rc::new(RefCell::new(TraceBuf {
            cap: buffer_events.max(1),
            now: 0,
            dropped: 0,
            events: VecDeque::new(),
        }))))
    }

    pub fn from_config(t: &crate::config::TraceConfig) -> TraceSink {
        if t.enabled {
            TraceSink::enabled(t.buffer_events)
        } else {
            TraceSink::disabled()
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Emit one event. The closure is only invoked when tracing is on, so
    /// argument construction costs nothing on the disabled path.
    #[inline]
    pub fn emit(&self, f: impl FnOnce() -> Event) {
        if let Some(buf) = &self.0 {
            let ev = f();
            buf.borrow_mut().push(ev);
        }
    }

    /// Advance the sink's clock hint (for emission sites without a clock).
    #[inline]
    pub fn stamp(&self, now: Ns) {
        if let Some(buf) = &self.0 {
            buf.borrow_mut().now = now;
        }
    }

    /// Last stamped virtual time (0 when disabled).
    pub fn now_hint(&self) -> Ns {
        self.0.as_ref().map_or(0, |b| b.borrow().now)
    }

    pub fn len(&self) -> usize {
        self.0.as_ref().map_or(0, |b| b.borrow().events.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dropped(&self) -> u64 {
        self.0.as_ref().map_or(0, |b| b.borrow().dropped)
    }

    /// Two handles share one ring (the sharing invariant the shard layer
    /// establishes, mirroring `SharedTimer::shares_with`).
    pub fn shares_with(&self, other: &TraceSink) -> bool {
        match (&self.0, &other.0) {
            (Some(a), Some(b)) => Rc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// All pipe records in emission (= global DES) order.
    pub fn lines(&self) -> Vec<String> {
        self.0
            .as_ref()
            .map_or_else(Vec::new, |b| b.borrow().events.iter().map(|e| e.line()).collect())
    }

    /// Render the full export: Perfetto `traceEvents` + `hhzsMeta` +
    /// `hhzsEvents` in one JSON object. Deterministic: pure function of
    /// the buffered events (no wall clock, no randomness).
    pub fn export_string(&self, shards: usize, bg_threads: usize, fg_threads: usize) -> String {
        let (lines, perfetto, dropped) = match &self.0 {
            Some(buf) => {
                let b = buf.borrow();
                let lines: Vec<String> = b.events.iter().map(|e| e.line()).collect();
                (lines, perfetto_events(&b, shards), b.dropped)
            }
            None => (Vec::new(), Vec::new(), 0),
        };
        let mut out = String::new();
        out.push_str("{\n\"traceEvents\": [\n");
        out.push_str(&perfetto.join(",\n"));
        out.push_str("\n],\n");
        out.push_str(&format!(
            "\"hhzsMeta\": {{\"shards\": {shards}, \"bg_threads\": {bg_threads}, \
             \"fg_threads\": {fg_threads}, \"events\": {}, \"dropped\": {dropped}}},\n",
            lines.len()
        ));
        out.push_str("\"hhzsEvents\": [\n");
        let quoted: Vec<String> = lines.iter().map(|l| format!("\"{l}\"")).collect();
        out.push_str(&quoted.join(",\n"));
        out.push_str("\n]\n}\n");
        out
    }
}

/// Microsecond timestamp with nanosecond remainder, Chrome-trace style.
fn us(ns: Ns) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

fn dev_tid(dev: Dev) -> u32 {
    match dev {
        Dev::Ssd => 1,
        Dev::Hdd => 2,
    }
}

fn slice(pid: usize, tid: usize, ts: Ns, dur: Ns, name: &str) -> String {
    format!(
        "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\"ts\":{},\"dur\":{},\"name\":\"{name}\"}}",
        us(ts),
        us(dur)
    )
}

fn instant(pid: usize, tid: usize, ts: Ns, name: &str) -> String {
    format!(
        "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{tid},\"ts\":{},\"s\":\"t\",\"name\":\"{name}\"}}",
        us(ts)
    )
}

fn meta_name(pid: usize, tid: Option<usize>, what: &str, name: &str) -> String {
    match tid {
        Some(t) => format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{t},\"name\":\"{what}\",\"args\":{{\"name\":\"{name}\"}}}}"
        ),
        None => format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"name\":\"{what}\",\"args\":{{\"name\":\"{name}\"}}}}"
        ),
    }
}

/// Build the Perfetto view: pid 1 = devices (service + queue lanes), pid 2
/// = the shared CPU pool (one lane per concurrently held slot, assigned
/// deterministically lowest-free-first), pid `3+s` = shard `s` (job spans,
/// queued spans, stalls, migrations, instants).
fn perfetto_events(buf: &TraceBuf, shards: usize) -> Vec<String> {
    let mut body: Vec<String> = Vec::new();
    let mut free_lanes: BinaryHeap<std::cmp::Reverse<usize>> = BinaryHeap::new();
    let mut next_lane = 0usize;
    let mut cpu_open: BTreeMap<(usize, JobKind, u64), (Ns, usize)> = BTreeMap::new();
    let mut job_open: BTreeMap<(usize, JobKind, u64), (Ns, Ns)> = BTreeMap::new();
    let mut mig_open: BTreeMap<(usize, u64), (Dev, Dev, Ns)> = BTreeMap::new();
    for ev in &buf.events {
        match ev {
            Event::Dev { dev, kind, bytes, issue, start, finish, .. } => {
                let t = dev_tid(*dev) as usize;
                body.push(slice(1, t, *start, finish - start, &format!(
                    "{} {bytes}B",
                    kind_name(*kind)
                )));
                if start > issue {
                    body.push(slice(1, t + 2, *issue, start - issue, &format!(
                        "queue {}",
                        kind_name(*kind)
                    )));
                }
            }
            Event::CpuAcquire { shard, kind, job, at, .. } => {
                let lane = match free_lanes.pop() {
                    Some(std::cmp::Reverse(l)) => l,
                    None => {
                        next_lane += 1;
                        next_lane - 1
                    }
                };
                cpu_open.insert((*shard, *kind, *job), (*at, lane));
            }
            Event::CpuRelease { shard, kind, job, at, .. } => {
                if let Some((t0, lane)) = cpu_open.remove(&(*shard, *kind, *job)) {
                    body.push(slice(2, lane + 1, t0, at - t0, &format!(
                        "{} s{shard} j{job}",
                        kind.name()
                    )));
                    free_lanes.push(std::cmp::Reverse(lane));
                }
            }
            Event::FlushDenied { shard, at } => {
                body.push(instant(3 + shard, 5, *at, "flush denied"));
            }
            Event::FlushUnwait { shard, at } => {
                body.push(instant(3 + shard, 5, *at, "flush unwaited"));
            }
            Event::JobStart { shard, kind, job, queued, at } => {
                job_open.insert((*shard, *kind, *job), (*queued, *at));
            }
            Event::JobEnd { shard, kind, job, at } => {
                if let Some((queued, t0)) = job_open.remove(&(*shard, *kind, *job)) {
                    if queued < t0 {
                        body.push(slice(3 + shard, 2, queued, t0 - queued, &format!(
                            "{} j{job} queued",
                            kind.name()
                        )));
                    }
                    body.push(slice(3 + shard, 1, t0, at - t0, &format!(
                        "{} j{job}",
                        kind.name()
                    )));
                }
            }
            Event::MigStart { shard, sst, from, to, at } => {
                mig_open.insert((*shard, *sst), (*from, *to, *at));
            }
            Event::MigEnd { shard, sst, at } => {
                if let Some((from, to, t0)) = mig_open.remove(&(*shard, *sst)) {
                    body.push(slice(3 + shard, 4, t0, at - t0, &format!(
                        "migrate sst{sst} {}->{}",
                        from.name(),
                        to.name()
                    )));
                }
            }
            Event::Stall { shard, client, at } => {
                body.push(instant(3 + shard, 3, *at, &format!("stall c{client}")));
            }
            Event::Unstall { shard, client, at, dur } => {
                body.push(slice(3 + shard, 3, at - dur, *dur, &format!("stalled c{client}")));
            }
            Event::ZoneReset { dev, zone, at } => {
                body.push(instant(1, dev_tid(*dev) as usize, *at, &format!("reset z{zone}")));
            }
            Event::ZoneTrunc { dev, zone, wp, at } => {
                body.push(instant(1, dev_tid(*dev) as usize, *at, &format!(
                    "power-loss trunc z{zone} wp={wp}"
                )));
            }
            Event::CrashFired { shard, point, at } => {
                body.push(instant(3 + shard, 1, *at, &format!("CRASH {point}")));
            }
            Event::Recovered { shard, replayed, at } => {
                body.push(instant(3 + shard, 1, *at, &format!("recovered {replayed} entries")));
            }
            Event::CacheAdmit { shard, sst, zone, at, .. } => {
                body.push(instant(3 + shard, 5, *at, &format!("cache admit sst{sst} z{zone}")));
            }
            Event::CacheEvict { shard, zone, at } => {
                body.push(instant(3 + shard, 5, *at, &format!("cache evict z{zone}")));
            }
            Event::HintIssued { shard, kind, at } => {
                body.push(instant(3 + shard, 5, *at, &format!("hint {kind}")));
            }
            // High-volume / bookkeeping records stay pipe-only.
            Event::Io { .. }
            | Event::CpuWait { .. }
            | Event::ZoneAppend { .. }
            | Event::StallRisk { .. }
            | Event::SchedWake { .. }
            | Event::FgCharge { .. }
            | Event::Snapshot { .. }
            | Event::BatchOpen { .. }
            | Event::BatchClose { .. }
            | Event::BatchAck { .. }
            | Event::ReadFuse { .. }
            | Event::WalPad { .. } => {}
        }
    }
    let mut out: Vec<String> = Vec::new();
    out.push(meta_name(1, None, "process_name", "devices"));
    out.push(meta_name(1, Some(1), "thread_name", "ssd service"));
    out.push(meta_name(1, Some(2), "thread_name", "hdd service"));
    out.push(meta_name(1, Some(3), "thread_name", "ssd queue"));
    out.push(meta_name(1, Some(4), "thread_name", "hdd queue"));
    out.push(meta_name(2, None, "process_name", "cpu-pool"));
    for l in 0..next_lane {
        out.push(meta_name(2, Some(l + 1), "thread_name", &format!("slot {l}")));
    }
    for s in 0..shards {
        out.push(meta_name(3 + s, None, "process_name", &format!("shard {s}")));
        out.push(meta_name(3 + s, Some(1), "thread_name", "jobs"));
        out.push(meta_name(3 + s, Some(2), "thread_name", "job queue"));
        out.push(meta_name(3 + s, Some(3), "thread_name", "stalls"));
        out.push(meta_name(3 + s, Some(4), "thread_name", "migrations"));
        out.push(meta_name(3 + s, Some(5), "thread_name", "hints"));
    }
    out.extend(body);
    out
}

// ---------------------------------------------------------------------
// The trace checker: replay an export, assert the DES invariants.
// ---------------------------------------------------------------------

/// Result of a [`check_export`] replay.
#[derive(Debug, Default)]
pub struct CheckReport {
    pub events: usize,
    pub dev_intervals: usize,
    pub jobs_closed: usize,
    pub snapshots: usize,
    pub max_concurrent_cpu: usize,
    pub violations: Vec<String>,
}

impl CheckReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn summary(&self) -> String {
        format!(
            "{} events, {} device intervals, {} job spans, {} snapshots, \
             peak cpu {} — {}",
            self.events,
            self.dev_intervals,
            self.jobs_closed,
            self.snapshots,
            self.max_concurrent_cpu,
            if self.ok() {
                "OK".to_string()
            } else {
                format!("{} VIOLATION(S)", self.violations.len())
            }
        )
    }
}

/// Scan `"key": <int>` inside the `hhzsMeta` object.
fn scan_meta_u64(json: &str, key: &str) -> Option<u64> {
    let meta = json.find("\"hhzsMeta\"")?;
    let rest = &json[meta..];
    let end = rest.find('}')?;
    let obj = &rest[..end];
    let pat = format!("\"{key}\": ");
    let at = obj.find(&pat)? + pat.len();
    let digits: String = obj[at..].chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// Pull the pipe records back out of an export.
fn extract_lines(json: &str) -> Result<Vec<String>, String> {
    let at = json.find("\"hhzsEvents\": [").ok_or("no hhzsEvents array in file")?;
    let mut out = Vec::new();
    let bytes = json.as_bytes();
    let mut i = at + "\"hhzsEvents\": [".len();
    loop {
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        match bytes.get(i) {
            Some(b']') => return Ok(out),
            Some(b'"') => {
                let start = i + 1;
                let mut j = start;
                while j < bytes.len() && bytes[j] != b'"' {
                    j += 1;
                }
                if j >= bytes.len() {
                    return Err("unterminated record string".into());
                }
                out.push(json[start..j].to_string());
                i = j + 1;
            }
            Some(b',') => i += 1,
            _ => return Err("malformed hhzsEvents array".into()),
        }
    }
}

/// The flush-slot reservation the shared pool holds back from compactions
/// (must mirror `CpuPool::flush_reserved`).
fn flush_reserved(total: usize) -> usize {
    match total {
        0 | 1 => 0,
        t => 2.min(t - 1),
    }
}

#[derive(Clone, Default)]
struct ShardAcc {
    qw_ssd: u64,
    qw_hdd: u64,
    cpuw_n: u64,
    cpuw_sum: u128,
    stalls: u64,
    stall_ns: u64,
    fgw_n: u64,
    fgw_sum: u128,
    any: bool,
}

/// Replay pipe records and verify the invariant families. `shards`,
/// `bg_threads` and `fg_threads` come from the export's `hhzsMeta`.
pub fn check_lines(
    lines: &[String],
    shards: usize,
    bg_threads: usize,
    fg_threads: usize,
    dropped: u64,
) -> CheckReport {
    let mut r = CheckReport { events: lines.len(), ..Default::default() };
    if dropped > 0 {
        r.violations.push(format!(
            "ring buffer dropped {dropped} events — sum invariants unverifiable; \
             raise [trace] buffer_events"
        ));
        return r;
    }
    let reserved = flush_reserved(bg_threads);
    let mut dev_last_finish: BTreeMap<String, u64> = BTreeMap::new();
    let mut in_use: usize = 0;
    let mut cpu_open: BTreeSet<(usize, String, u64)> = BTreeSet::new();
    let mut job_open: BTreeMap<(usize, String, u64), u64> = BTreeMap::new();
    let mut mig_open: BTreeSet<(usize, u64)> = BTreeSet::new();
    let mut flush_wait = vec![false; shards.max(1)];
    let mut acc = vec![ShardAcc::default(); shards.max(1)];
    // Scheduler replay state: latest pushed risk per shard, the previous
    // slot of the current wake round, and the fg pool's slot clocks.
    let mut last_risk = vec![0u64; shards.max(1)];
    let mut wake_prev: Option<(u64, usize, bool, u64, usize)> = None;
    let mut fg_busy = vec![0u64; fg_threads];
    // Group-commit batch replay: id -> (dev, closed, expected members,
    // expected bytes, fused finish, acked members, acked bytes).
    struct BatchSt {
        dev: String,
        closed: bool,
        members: u64,
        bytes: u64,
        finish: u64,
        seen_members: u64,
        seen_bytes: u64,
    }
    let mut batches: BTreeMap<u64, BatchSt> = BTreeMap::new();
    for (i, l) in lines.iter().enumerate() {
        let f: Vec<&str> = l.split('|').collect();
        let mut bad = false;
        let mut num = |s: &str| -> u64 {
            s.parse().unwrap_or_else(|_| {
                bad = true;
                0
            })
        };
        macro_rules! viol {
            ($($arg:tt)*) => { r.violations.push(format!("record {i} [{l}]: {}", format!($($arg)*))) };
        }
        match f.first().copied() {
            Some("DEV") if f.len() == 7 || f.len() == 8 => {
                let (issue, start, finish) = (num(f[4]), num(f[5]), num(f[6]));
                if issue > start || start > finish {
                    viol!("service interval not ordered issue<=start<=finish");
                }
                if f.len() == 8 && num(f[7]) < 2 {
                    viol!("fused DEV record with members < 2 (plain accesses stay 7-field)");
                }
                let prev = dev_last_finish.entry(f[1].to_string()).or_insert(0);
                if start < *prev {
                    viol!("busy interval overlaps previous finish {prev} on {}", f[1]);
                }
                *prev = (*prev).max(finish);
                r.dev_intervals += 1;
            }
            Some("IO") if f.len() == 9 => {
                let shard = num(f[3]) as usize;
                let wait = num(f[7]);
                if shard >= acc.len() {
                    viol!("shard out of range");
                } else {
                    let a = &mut acc[shard];
                    a.any = true;
                    match f[1] {
                        "ssd" => a.qw_ssd += wait,
                        "hdd" => a.qw_hdd += wait,
                        d => viol!("unknown device {d}"),
                    }
                }
            }
            Some("CPUWAIT") if f.len() == 6 => {
                let shard = num(f[1]) as usize;
                let wait = num(f[4]);
                if shard >= acc.len() {
                    viol!("shard out of range");
                } else {
                    acc[shard].any = true;
                    acc[shard].cpuw_n += 1;
                    acc[shard].cpuw_sum += wait as u128;
                }
            }
            Some("ACQ") if f.len() == 6 => {
                let shard = num(f[1]) as usize;
                let job = num(f[3]);
                let reported = num(f[5]) as usize;
                in_use += 1;
                if in_use != reported {
                    viol!("replayed occupancy {in_use} != pool-reported {reported}");
                    in_use = reported; // resync so one slip doesn't cascade
                }
                if in_use > bg_threads {
                    viol!("concurrent CPU spans {in_use} exceed bg_threads {bg_threads}");
                }
                r.max_concurrent_cpu = r.max_concurrent_cpu.max(in_use);
                if !cpu_open.insert((shard, f[2].to_string(), job)) {
                    viol!("slot acquired twice without release");
                }
                if shard < flush_wait.len() && f[2] == "flush" {
                    flush_wait[shard] = false;
                }
                if f[2] == "comp" {
                    let waiting = flush_wait.iter().filter(|w| **w).count();
                    if waiting + reported > bg_threads {
                        viol!(
                            "flush priority violated: {waiting} flush waiter(s) but \
                             compaction admission left occupancy {reported}/{bg_threads}"
                        );
                    }
                    if reported > bg_threads - reserved {
                        viol!(
                            "compaction admission broke the {reserved}-slot flush \
                             reservation ({reported}/{bg_threads})"
                        );
                    }
                }
            }
            Some("REL") if f.len() == 6 => {
                let shard = num(f[1]) as usize;
                let job = num(f[3]);
                let reported = num(f[5]) as usize;
                if !cpu_open.remove(&(shard, f[2].to_string(), job)) {
                    viol!("slot released without a matching acquire");
                }
                in_use = in_use.saturating_sub(1);
                if in_use != reported {
                    viol!("replayed occupancy {in_use} != pool-reported {reported}");
                    in_use = reported;
                }
            }
            Some("DENY") if f.len() == 3 => {
                let shard = num(f[1]) as usize;
                if shard < flush_wait.len() {
                    flush_wait[shard] = true;
                }
            }
            Some("UNWAIT") if f.len() == 3 => {
                let shard = num(f[1]) as usize;
                if shard < flush_wait.len() {
                    flush_wait[shard] = false;
                }
            }
            Some("JOB") if f.len() == 6 => {
                let key = (num(f[1]) as usize, f[2].to_string(), num(f[3]));
                let (queued, at) = (num(f[4]), num(f[5]));
                if queued > at {
                    viol!("job queued after it started");
                }
                if job_open.insert(key, at).is_some() {
                    viol!("job span opened twice");
                }
            }
            Some("JOBEND") if f.len() == 5 => {
                let key = (num(f[1]) as usize, f[2].to_string(), num(f[3]));
                let at = num(f[4]);
                match job_open.remove(&key) {
                    Some(start) if at < start => viol!("job span ends before it starts"),
                    Some(_) => r.jobs_closed += 1,
                    None => viol!("job span closed without an open"),
                }
            }
            Some("MIGS") if f.len() == 6 => {
                if !mig_open.insert((num(f[1]) as usize, num(f[2]))) {
                    viol!("migration span opened twice for one SST");
                }
            }
            Some("MIGE") if f.len() == 4 => {
                if !mig_open.remove(&(num(f[1]) as usize, num(f[2]))) {
                    viol!("migration span closed without an open");
                }
            }
            Some("STALL") if f.len() == 4 => {
                let shard = num(f[1]) as usize;
                if shard < acc.len() {
                    acc[shard].any = true;
                    acc[shard].stalls += 1;
                }
            }
            Some("UNSTALL") if f.len() == 5 => {
                let shard = num(f[1]) as usize;
                let dur = num(f[4]);
                if shard < acc.len() {
                    acc[shard].any = true;
                    acc[shard].stall_ns += dur;
                }
            }
            Some("RISK") if f.len() == 4 => {
                let shard = num(f[1]) as usize;
                let score = num(f[2]);
                if shard >= last_risk.len() {
                    viol!("shard out of range");
                } else {
                    last_risk[shard] = score;
                }
            }
            Some("WAKE") if f.len() == 8 => {
                let shard = num(f[1]) as usize;
                let flush = match f[2] {
                    "flush" => true,
                    "comp" => false,
                    c => {
                        viol!("unknown wake class {c}");
                        false
                    }
                };
                let (risk, age) = (num(f[3]), num(f[4]));
                let rank = num(f[5]) as usize;
                let round = num(f[6]);
                if shard >= last_risk.len() {
                    viol!("shard out of range");
                } else if risk != last_risk[shard] {
                    viol!(
                        "wake risk {risk} != last traced RISK {} for shard {shard}",
                        last_risk[shard]
                    );
                }
                let eff = crate::sim::cpu::effective_priority(risk, age);
                match wake_prev {
                    Some((pround, prank, pflush, peff, pshard)) if pround == round => {
                        if rank != prank + 1 {
                            viol!("wake rank {rank} not contiguous after {prank} in round {round}");
                        }
                        if flush && !pflush {
                            viol!("flush-class waiter ranked after a compaction waiter");
                        }
                        if flush == pflush {
                            if eff > peff {
                                viol!(
                                    "priority order violated: rank {rank} eff {eff} > \
                                     rank {prank} eff {peff}"
                                );
                            }
                            if eff == peff && shard <= pshard {
                                viol!("shard tie-break violated at equal priority");
                            }
                        }
                    }
                    _ => {
                        if rank != 0 {
                            viol!("wake round {round} does not start at rank 0");
                        }
                    }
                }
                wake_prev = Some((round, rank, flush, eff, shard));
            }
            Some("FG") if f.len() == 6 => {
                let shard = num(f[1]) as usize;
                let (start, cost, wait, at) = (num(f[2]), num(f[3]), num(f[4]), num(f[5]));
                if fg_busy.is_empty() {
                    viol!("FG record in a trace with fg_threads = 0");
                } else {
                    let slot = (0..fg_busy.len()).min_by_key(|&i| (fg_busy[i], i)).unwrap();
                    let expect = at.max(fg_busy[slot]);
                    if start != expect {
                        viol!(
                            "fg grant at {start} != replayed earliest slot time {expect} \
                             (fg-pool occupancy must stay <= fg_threads {fg_threads})"
                        );
                    }
                    if wait != start.saturating_sub(at) {
                        viol!("fg wait {wait} != start - issue {}", start.saturating_sub(at));
                    }
                    fg_busy[slot] = start.max(fg_busy[slot]) + cost;
                }
                if shard >= acc.len() {
                    viol!("shard out of range");
                } else {
                    acc[shard].any = true;
                    acc[shard].fgw_n += 1;
                    acc[shard].fgw_sum += wait as u128;
                }
            }
            Some("SNAP") if f.len() == 14 => {
                let shard = num(f[1]) as usize;
                if shard >= acc.len() {
                    viol!("shard out of range");
                } else {
                    let a = &acc[shard];
                    let (stalls, stall_ns) = (num(f[3]), num(f[4]));
                    let (qw_ssd, qw_hdd) = (num(f[5]), num(f[6]));
                    let cpuw_n = num(f[7]);
                    let cpuw_sum: u128 = f[8].parse().unwrap_or(u128::MAX);
                    let fgw_n = num(f[12]);
                    let fgw_sum: u128 = f[13].parse().unwrap_or(u128::MAX);
                    if a.stalls != stalls {
                        viol!("trace stalls {} != Metrics::stalls {stalls}", a.stalls);
                    }
                    if a.stall_ns != stall_ns {
                        viol!("trace stall ns {} != Metrics::stall_ns {stall_ns}", a.stall_ns);
                    }
                    if a.qw_ssd != qw_ssd {
                        viol!("trace ssd wait {} != Metrics::queue_wait {qw_ssd}", a.qw_ssd);
                    }
                    if a.qw_hdd != qw_hdd {
                        viol!("trace hdd wait {} != Metrics::queue_wait {qw_hdd}", a.qw_hdd);
                    }
                    if a.cpuw_n != cpuw_n || a.cpuw_sum != cpuw_sum {
                        viol!(
                            "trace cpu wait {}:{} != Metrics::cpu_wait {cpuw_n}:{cpuw_sum}",
                            a.cpuw_n,
                            a.cpuw_sum
                        );
                    }
                    if a.fgw_n != fgw_n || a.fgw_sum != fgw_sum {
                        viol!(
                            "trace fg wait {}:{} != Metrics::fg_cpu_wait {fgw_n}:{fgw_sum}",
                            a.fgw_n,
                            a.fgw_sum
                        );
                    }
                    acc[shard] = ShardAcc::default();
                    r.snapshots += 1;
                }
            }
            Some("ZAPP") if f.len() == 5 => {}
            Some("ZRST") if f.len() == 4 => {}
            Some("ZTRUNC") if f.len() == 5 => {}
            Some("CRASH") if f.len() == 4 => {
                let shard = num(f[1]) as usize;
                if shard >= acc.len() {
                    viol!("shard out of range");
                } else {
                    // The crash unwind resets the victim's scheduler state
                    // (risk, age, promotion) without emitting a RISK record.
                    last_risk[shard] = 0;
                }
            }
            Some("RECOV") if f.len() == 4 => {
                let shard = num(f[1]) as usize;
                if shard >= acc.len() {
                    viol!("shard out of range");
                }
            }
            Some("CADM") if f.len() == 6 => {}
            Some("CEVT") if f.len() == 4 => {}
            Some("HINT") if f.len() == 4 => {}
            Some("BATCHO") if f.len() == 4 => {
                let id = num(f[1]);
                let st = BatchSt {
                    dev: f[2].to_string(),
                    closed: false,
                    members: 0,
                    bytes: 0,
                    finish: 0,
                    seen_members: 0,
                    seen_bytes: 0,
                };
                if batches.insert(id, st).is_some() {
                    viol!("batch id {id} opened twice");
                }
            }
            Some("BATCHC") if f.len() == 8 => {
                let id = num(f[1]);
                let (members, bytes) = (num(f[3]), num(f[4]));
                let (start, finish, at) = (num(f[5]), num(f[6]), num(f[7]));
                if members == 0 {
                    viol!("batch closed with zero members");
                }
                if at > start || start > finish {
                    viol!("fused append interval not ordered close<=start<=finish");
                }
                match batches.get_mut(&id) {
                    None => viol!("batch id {id} closed without an open"),
                    Some(b) if b.closed => viol!("batch id {id} closed twice"),
                    Some(b) => {
                        if b.dev != f[2] {
                            viol!("batch id {id} closed on {} but opened on {}", f[2], b.dev);
                        }
                        b.closed = true;
                        b.members = members;
                        b.bytes = bytes;
                        b.finish = finish;
                    }
                }
            }
            Some("BATCHA") if f.len() == 7 => {
                let id = num(f[1]);
                let shard = num(f[2]) as usize;
                let bytes = num(f[4]);
                let (staged, ack) = (num(f[5]), num(f[6]));
                if shard >= acc.len() {
                    viol!("shard out of range");
                }
                if staged > ack {
                    viol!("member acked before it staged");
                }
                match batches.get_mut(&id) {
                    None => viol!("member ack for unknown batch id {id}"),
                    Some(b) if !b.closed => viol!("member acked before batch id {id} closed"),
                    Some(b) => {
                        if ack < b.finish {
                            viol!("ack {ack} precedes the fused finish {} of batch {id}", b.finish);
                        }
                        b.seen_members += 1;
                        b.seen_bytes += bytes;
                    }
                }
            }
            Some("FUSE") if f.len() == 8 => {
                let shard = num(f[2]) as usize;
                let members = num(f[3]);
                let (bytes, member_bytes, gap) = (num(f[4]), num(f[5]), num(f[6]));
                if shard >= acc.len() {
                    viol!("shard out of range");
                }
                if members < 2 {
                    viol!("fused read with fewer than 2 members");
                }
                if bytes != member_bytes + gap {
                    viol!(
                        "fused read bytes {bytes} != member bytes {member_bytes} + gap {gap} \
                         (byte conservation)"
                    );
                }
            }
            Some("WALPAD") if f.len() == 6 => {
                let shard = num(f[1]) as usize;
                if shard >= acc.len() {
                    viol!("shard out of range");
                }
                if num(f[4]) == 0 {
                    viol!("zero-byte WAL pad record");
                }
            }
            _ => viol!("unknown or malformed record"),
        }
        if bad {
            r.violations.push(format!("record {i} [{l}]: unparseable number"));
        }
    }
    for (key, _) in job_open {
        r.violations.push(format!("job span never closed: shard {} {} j{}", key.0, key.1, key.2));
    }
    for key in cpu_open {
        r.violations.push(format!("CPU slot never released: shard {} {} j{}", key.0, key.1, key.2));
    }
    if in_use != 0 {
        r.violations.push(format!("{in_use} CPU slot(s) still held at end of trace"));
    }
    for (id, b) in &batches {
        if !b.closed {
            r.violations.push(format!("batch id {id} never closed"));
            continue;
        }
        if b.seen_members != b.members {
            r.violations.push(format!(
                "batch id {id}: {} member ack(s) != fused member count {}",
                b.seen_members, b.members
            ));
        }
        if b.seen_bytes != b.bytes {
            r.violations.push(format!(
                "batch id {id}: member bytes {} != fused access bytes {} (byte conservation)",
                b.seen_bytes, b.bytes
            ));
        }
    }
    for (s, a) in acc.iter().enumerate() {
        if a.any {
            r.violations.push(format!(
                "shard {s}: waits/stalls recorded after the final snapshot — \
                 export must emit a closing SNAP per shard"
            ));
        }
    }
    r
}

/// Check a rendered export string (the `--trace` output file format).
pub fn check_export(json: &str) -> Result<CheckReport, String> {
    let shards =
        scan_meta_u64(json, "shards").ok_or("missing hhzsMeta.shards — not an hhzs trace?")?;
    let bg = scan_meta_u64(json, "bg_threads").ok_or("missing hhzsMeta.bg_threads")?;
    // Absent in pre-fg traces: treat as an uncontended foreground.
    let fg = scan_meta_u64(json, "fg_threads").unwrap_or(0);
    let dropped = scan_meta_u64(json, "dropped").unwrap_or(0);
    let lines = extract_lines(json)?;
    Ok(check_lines(&lines, shards as usize, bg as usize, fg as usize, dropped))
}

/// Check a trace file on disk (`hhzs trace check <file>`).
pub fn check_file(path: &str) -> Result<CheckReport, String> {
    let json =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    check_export(&json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_never_runs_the_closure() {
        let t = TraceSink::disabled();
        t.emit(|| panic!("closure must not run on the disabled path"));
        t.stamp(42);
        assert!(!t.is_enabled());
        assert_eq!(t.len(), 0);
        assert_eq!(t.now_hint(), 0);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let t = TraceSink::enabled(3);
        for i in 0..5u64 {
            t.emit(|| Event::Stall { shard: 0, client: i as usize, at: i });
        }
        assert_eq!(t.len(), 3);
        assert_eq!(t.dropped(), 2);
        let lines = t.lines();
        assert_eq!(lines[0], "STALL|0|2|2", "oldest two must have been dropped");
    }

    #[test]
    fn stamp_feeds_clockless_sites() {
        let t = TraceSink::enabled(8);
        t.stamp(1_000);
        assert_eq!(t.now_hint(), 1_000);
        let u = t.clone();
        u.stamp(2_000);
        assert_eq!(t.now_hint(), 2_000, "clones share the ring and the clock hint");
        assert!(t.shares_with(&u));
        assert!(!t.shares_with(&TraceSink::enabled(8)));
    }

    fn consistent_lines() -> Vec<String> {
        [
            "DEV|ssd|seq_wr|4096|0|0|100",
            "DEV|ssd|rnd_rd|4096|50|100|180",
            "IO|ssd|wal|0|-|-|4096|0|0",
            "IO|ssd|block_rd|0|-|7|4096|50|50",
            "STALL|0|3|60",
            "JOB|0|flush|1|80|90",
            "ACQ|0|flush|1|90|1",
            "CPUWAIT|0|flush|1|10|90",
            "UNSTALL|0|3|95|35",
            "REL|0|flush|1|120|0",
            "JOBEND|0|flush|1|120",
            "ZAPP|ssd|2|4096|100",
            "ZRST|ssd|2|110",
            "HINT|0|flush|120",
            "SNAP|0|130|1|35|50|0|1|10|5|1|0|0|0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect()
    }

    #[test]
    fn checker_accepts_a_consistent_trace() {
        let r = check_lines(&consistent_lines(), 1, 2, 0, 0);
        assert!(r.ok(), "unexpected violations: {:?}", r.violations);
        assert_eq!(r.dev_intervals, 2);
        assert_eq!(r.jobs_closed, 1);
        assert_eq!(r.snapshots, 1);
        assert_eq!(r.max_concurrent_cpu, 1);
    }

    #[test]
    fn checker_rejects_overlapping_device_intervals() {
        let lines: Vec<String> = [
            "DEV|ssd|seq_wr|1|0|0|100",
            "DEV|ssd|seq_wr|1|0|99|150",
            "SNAP|0|1|0|0|0|0|0|0|0|0|0|0|0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = check_lines(&lines, 1, 2, 0, 0);
        assert_eq!(r.violations.len(), 1);
        assert!(r.violations[0].contains("overlaps"), "{:?}", r.violations);
    }

    #[test]
    fn checker_rejects_cpu_overcommit() {
        let lines: Vec<String> = ["ACQ|0|flush|1|0|1", "ACQ|0|comp|2|0|2", "ACQ|0|comp|3|0|3"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let r = check_lines(&lines, 1, 2, 0, 0);
        assert!(
            r.violations.iter().any(|v| v.contains("exceed bg_threads")),
            "{:?}",
            r.violations
        );
    }

    #[test]
    fn checker_rejects_flush_priority_violation() {
        // One flush waiter, 2 threads: a compaction filling the last slot
        // (occupancy 2/2) starves the waiting flush.
        let lines: Vec<String> = [
            "ACQ|0|comp|1|0|1",
            "DENY|1|5",
            "ACQ|0|comp|2|10|2",
            "REL|0|comp|1|20|1",
            "REL|0|comp|2|20|0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = check_lines(&lines, 2, 2, 0, 0);
        assert!(r.violations.iter().any(|v| v.contains("flush priority")), "{:?}", r.violations);
    }

    #[test]
    fn checker_rejects_snapshot_sum_mismatch() {
        let lines: Vec<String> = ["STALL|0|1|10", "SNAP|0|20|0|0|0|0|0|0|0|0|0|0|0"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let r = check_lines(&lines, 1, 2, 0, 0);
        assert!(r.violations.iter().any(|v| v.contains("Metrics::stalls")), "{:?}", r.violations);
    }

    #[test]
    fn checker_rejects_unbalanced_spans_and_lossy_rings() {
        let lines: Vec<String> =
            ["JOB|0|flush|1|0|0", "ACQ|0|flush|1|0|1"].iter().map(|s| s.to_string()).collect();
        let r = check_lines(&lines, 1, 2, 0, 0);
        assert!(r.violations.iter().any(|v| v.contains("never closed")), "{:?}", r.violations);
        assert!(r.violations.iter().any(|v| v.contains("never released")), "{:?}", r.violations);
        let r = check_lines(&lines, 1, 2, 0, 3);
        assert!(r.violations.iter().any(|v| v.contains("dropped 3")), "{:?}", r.violations);
    }

    #[test]
    fn checker_accepts_crash_and_recovery_records() {
        let lines: Vec<String> = [
            "JOB|0|flush|1|0|0",
            "ACQ|0|flush|1|0|1",
            "ZTRUNC|ssd|3|117|50",
            "CRASH|0|mid_flush|50",
            // The crash path unwinds the open spans before recovery.
            "REL|0|flush|1|50|0",
            "JOBEND|0|flush|1|50",
            "RECOV|0|42|60",
            "SNAP|0|70|0|0|0|0|0|0|0|0|0|0|0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = check_lines(&lines, 1, 2, 0, 0);
        assert!(r.ok(), "unexpected violations: {:?}", r.violations);
        // A crash record naming a shard outside the domain is rejected.
        let bad = vec!["CRASH|7|mid_flush|50".to_string()];
        assert!(!check_lines(&bad, 1, 2, 0, 0).ok());
    }

    #[test]
    fn export_round_trips_through_the_checker() {
        let t = TraceSink::enabled(1 << 10);
        t.emit(|| Event::Dev {
            dev: Dev::Ssd,
            kind: AccessKind::SeqWrite,
            bytes: 4096,
            issue: 0,
            start: 0,
            finish: 100,
            members: 1,
        });
        t.emit(|| Event::Io {
            dev: Dev::Ssd,
            op: IoOp::Wal,
            shard: 0,
            job: None,
            sst: None,
            bytes: 4096,
            wait: 0,
            at: 0,
        });
        t.emit(|| Event::Snapshot {
            shard: 0,
            at: 100,
            stalls: 0,
            stall_ns: 0,
            qw_ssd: 0,
            qw_hdd: 0,
            cpuw_n: 0,
            cpuw_sum: 0,
            ops: 1,
            flushes: 0,
            compactions: 0,
            fgw_n: 0,
            fgw_sum: 0,
        });
        let json = t.export_string(1, 2, 0);
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"hhzsMeta\""));
        assert!(json.contains("\"fg_threads\": 0"));
        let r = check_export(&json).expect("export parses");
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.events, 3);
        // Export is a pure function of the buffer.
        assert_eq!(json, t.export_string(1, 2, 0));
    }

    #[test]
    fn checker_replays_wake_rounds_and_rejects_priority_inversions() {
        // A consistent stall_aware round: shard 1 pushed risk 900, shard 0
        // risk 100; flush class first, then comps by effective priority.
        let good: Vec<String> = [
            "RISK|1|900|10",
            "RISK|0|100|10",
            "WAKE|2|flush|0|0|0|1|20",
            "WAKE|1|comp|900|0|1|1|20",
            "WAKE|0|comp|100|0|2|1|20",
            "SNAP|0|30|0|0|0|0|0|0|0|0|0|0|0",
            "SNAP|1|30|0|0|0|0|0|0|0|0|0|0|0",
            "SNAP|2|30|0|0|0|0|0|0|0|0|0|0|0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = check_lines(&good, 3, 2, 0, 0);
        assert!(r.ok(), "unexpected violations: {:?}", r.violations);

        // A grant that skipped the higher-priority waiter is rejected.
        let inverted: Vec<String> = [
            "RISK|1|900|10",
            "RISK|0|100|10",
            "WAKE|0|comp|100|0|0|1|20",
            "WAKE|1|comp|900|0|1|1|20",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = check_lines(&inverted, 3, 2, 0, 0);
        assert!(r.violations.iter().any(|v| v.contains("priority order")), "{:?}", r.violations);

        // A compaction waiter ranked ahead of a flush waiter is rejected.
        let class: Vec<String> = ["WAKE|0|comp|0|0|0|1|20", "WAKE|1|flush|0|0|1|1|20"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let r = check_lines(&class, 3, 2, 0, 0);
        assert!(r.violations.iter().any(|v| v.contains("flush-class")), "{:?}", r.violations);

        // A wake recording a risk that was never pushed is rejected.
        let stale: Vec<String> = ["WAKE|0|comp|77|0|0|1|20"].iter().map(|s| s.to_string()).collect();
        let r = check_lines(&stale, 3, 2, 0, 0);
        assert!(r.violations.iter().any(|v| v.contains("last traced RISK")), "{:?}", r.violations);
    }

    #[test]
    fn checker_replays_the_fg_pool_and_rejects_overcommit() {
        // Two slots: ops at t=0,0 run immediately; the third queues 100ns.
        let good: Vec<String> = [
            "FG|0|0|100|0|0",
            "FG|0|0|100|0|0",
            "FG|0|100|50|100|0",
            "SNAP|0|200|0|0|0|0|0|0|0|0|0|3|100",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = check_lines(&good, 1, 2, 2, 0);
        assert!(r.ok(), "unexpected violations: {:?}", r.violations);

        // Claiming an immediate grant while both slots are busy is an
        // occupancy violation.
        let over: Vec<String> = ["FG|0|0|100|0|0", "FG|0|0|100|0|0", "FG|0|0|50|0|0"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let r = check_lines(&over, 1, 2, 2, 0);
        assert!(r.violations.iter().any(|v| v.contains("fg grant")), "{:?}", r.violations);

        // FG records are impossible in an uncontended (fg_threads=0) trace.
        let none: Vec<String> = ["FG|0|0|100|0|0"].iter().map(|s| s.to_string()).collect();
        let r = check_lines(&none, 1, 2, 0, 0);
        assert!(r.violations.iter().any(|v| v.contains("fg_threads = 0")), "{:?}", r.violations);

        // A wait that disagrees with start - issue is rejected.
        let lied: Vec<String> = ["FG|0|0|100|5|0"].iter().map(|s| s.to_string()).collect();
        let r = check_lines(&lied, 1, 2, 2, 0);
        assert!(r.violations.iter().any(|v| v.contains("fg wait")), "{:?}", r.violations);

        // SNAP fg-wait sums must match the accumulated FG records.
        let sums: Vec<String> = ["FG|0|100|50|100|0", "SNAP|0|200|0|0|0|0|0|0|0|0|0|1|0"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let r = check_lines(&sums, 1, 2, 2, 0);
        assert!(r.violations.iter().any(|v| v.contains("fg wait")), "{:?}", r.violations);
    }

    #[test]
    fn plain_dev_record_keeps_the_seven_field_form() {
        // The off path must not grow a byte: members <= 1 renders exactly
        // the pre-fusion record.
        let plain = Event::Dev {
            dev: Dev::Ssd,
            kind: AccessKind::SeqWrite,
            bytes: 4096,
            issue: 0,
            start: 5,
            finish: 100,
            members: 1,
        };
        assert_eq!(plain.line(), "DEV|ssd|seq_wr|4096|0|5|100");
        let fused = Event::Dev {
            dev: Dev::Ssd,
            kind: AccessKind::SeqWrite,
            bytes: 4096,
            issue: 0,
            start: 5,
            finish: 100,
            members: 3,
        };
        assert_eq!(fused.line(), "DEV|ssd|seq_wr|4096|0|5|100|3");
    }

    #[test]
    fn checker_replays_batches_and_pins_byte_conservation() {
        let good: Vec<String> = [
            "BATCHO|1|ssd|10",
            "DEV|ssd|seq_wr|3000|60|60|100|3",
            "BATCHC|1|ssd|3|3000|60|100|60",
            "BATCHA|1|0|0|1000|10|100",
            "BATCHA|1|1|2|1000|25|101",
            "BATCHA|1|0|5|1000|60|100",
            "SNAP|0|200|0|0|0|0|0|0|0|0|0|0|0",
            "SNAP|1|200|0|0|0|0|0|0|0|0|0|0|0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = check_lines(&good, 2, 2, 0, 0);
        assert!(r.ok(), "unexpected violations: {:?}", r.violations);

        // Member bytes that don't sum to the fused access are rejected.
        let short: Vec<String> = [
            "BATCHO|1|ssd|10",
            "BATCHC|1|ssd|2|3000|60|100|60",
            "BATCHA|1|0|0|1000|10|100",
            "BATCHA|1|0|1|1000|20|100",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = check_lines(&short, 1, 2, 0, 0);
        assert!(
            r.violations.iter().any(|v| v.contains("byte conservation")),
            "{:?}",
            r.violations
        );

        // An ack before the fused finish is rejected.
        let early: Vec<String> = [
            "BATCHO|1|ssd|10",
            "BATCHC|1|ssd|1|1000|60|100|60",
            "BATCHA|1|0|0|1000|10|99",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = check_lines(&early, 1, 2, 0, 0);
        assert!(
            r.violations.iter().any(|v| v.contains("precedes the fused finish")),
            "{:?}",
            r.violations
        );

        // A batch that never closes is rejected.
        let open: Vec<String> = ["BATCHO|9|ssd|10"].iter().map(|s| s.to_string()).collect();
        let r = check_lines(&open, 1, 2, 0, 0);
        assert!(r.violations.iter().any(|v| v.contains("never closed")), "{:?}", r.violations);

        // Acks before the close (or for unknown ids) are rejected.
        let stray: Vec<String> =
            ["BATCHO|3|ssd|10", "BATCHA|3|0|0|100|10|20"].iter().map(|s| s.to_string()).collect();
        let r = check_lines(&stray, 1, 2, 0, 0);
        assert!(
            r.violations.iter().any(|v| v.contains("before batch id 3 closed")),
            "{:?}",
            r.violations
        );
    }

    #[test]
    fn checker_pins_fuse_and_walpad_records() {
        let good: Vec<String> = [
            "FUSE|ssd|0|2|8192|8192|0|10",
            "FUSE|hdd|0|3|16384|12288|4096|20",
            "WALPAD|0|ssd|4|100|30",
            "SNAP|0|40|0|0|0|0|0|0|0|0|0|0|0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = check_lines(&good, 1, 2, 0, 0);
        assert!(r.ok(), "unexpected violations: {:?}", r.violations);

        let bad_sum: Vec<String> =
            ["FUSE|ssd|0|2|8192|4096|0|10"].iter().map(|s| s.to_string()).collect();
        let r = check_lines(&bad_sum, 1, 2, 0, 0);
        assert!(
            r.violations.iter().any(|v| v.contains("byte conservation")),
            "{:?}",
            r.violations
        );

        let lone: Vec<String> = ["FUSE|ssd|0|1|4096|4096|0|10"].iter().map(|s| s.to_string()).collect();
        let r = check_lines(&lone, 1, 2, 0, 0);
        assert!(
            r.violations.iter().any(|v| v.contains("fewer than 2 members")),
            "{:?}",
            r.violations
        );

        let zero: Vec<String> = ["WALPAD|0|ssd|4|0|30"].iter().map(|s| s.to_string()).collect();
        let r = check_lines(&zero, 1, 2, 0, 0);
        assert!(r.violations.iter().any(|v| v.contains("zero-byte")), "{:?}", r.violations);

        // A fused DEV record must carry >= 2 members.
        let dev1: Vec<String> =
            ["DEV|ssd|seq_wr|4096|0|0|100|1"].iter().map(|s| s.to_string()).collect();
        let r = check_lines(&dev1, 1, 2, 0, 0);
        assert!(r.violations.iter().any(|v| v.contains("members < 2")), "{:?}", r.violations);
    }

    #[test]
    fn microsecond_timestamps_are_exact() {
        assert_eq!(us(0), "0.000");
        assert_eq!(us(1_500), "1.500");
        assert_eq!(us(999), "0.999");
        assert_eq!(us(1_000_001), "1000.001");
    }

    #[test]
    fn flush_reservation_mirrors_the_pool() {
        assert_eq!(flush_reserved(0), 0);
        assert_eq!(flush_reserved(1), 0);
        assert_eq!(flush_reserved(2), 1);
        assert_eq!(flush_reserved(3), 2);
        assert_eq!(flush_reserved(12), 2);
    }
}
