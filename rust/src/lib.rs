//! # HHZS — Hinted Hybrid Zoned Storage for LSM-tree KV stores
//!
//! A full reproduction of *"Efficient LSM-Tree Key-Value Data Management on
//! Hybrid SSD/HDD Zoned Storage"* (Li, Wang, Lee; 2022).
//!
//! The crate is organized as a three-layer system, scaled out by a shard
//! tier on top:
//!
//! * **Shard tier ([`shard`])** — stripes the key space over `N`
//!   independent engines sharing the hybrid substrate: a deterministic
//!   hash router, a substrate lease layer (zone quotas, per-shard
//!   WAL/cache pool reservations, strided file-id namespaces), a
//!   cross-shard migration-budget arbiter (§3.4 split), an async request
//!   frontend (ONE virtual clock, ONE shared SSD/HDD FIFO pair, and ONE
//!   shared `bg_threads` CPU pool for all shards, cross-shard
//!   scatter-gather scans, global pacing), and merged metrics.
//!   `shards = 1` reproduces the single-engine system bit-for-bit.
//! * **Layer 3 (this crate)** — the coordinator: a discrete-event-simulated
//!   hybrid zoned-storage substrate ([`zone`], [`sim`]), a zone-aware file
//!   layer ([`zenfs`]), a from-scratch LSM-tree KV store ([`lsm`]), the
//!   paper's hint bus ([`hints`]) and the three HHZS techniques plus all
//!   baselines ([`policy`]), driven by the DES engine in [`coordinator`] —
//!   instantiable once per shard.
//! * **Layer 2 (python/compile/model.py)** — JAX functions for the batched
//!   Bloom-probe and migration-priority hot spots, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels backing those
//!   functions; executed from Rust via the PJRT runtime in [`runtime`]
//!   (behind the off-by-default `xla` cargo feature; the default build
//!   uses the bit-identical native fallbacks).
//!
//! The experiment harness in [`exp`] regenerates every table and figure of
//! the paper's evaluation (Table 1, Figure 2, Exp#1–Exp#6) plus the
//! beyond-paper Exp#7 shard study on the shared device pair.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod crashtest;
pub mod exp;
pub mod hints;
pub mod lsm;
pub mod metrics;
pub mod policy;
pub mod report;
pub mod residency;
pub mod runtime;
pub mod shard;
pub mod sim;
pub mod trace;
pub mod wire;
pub mod ycsb;
pub mod zenfs;
pub mod zone;

pub use config::Config;
