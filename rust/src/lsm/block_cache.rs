//! In-memory block cache (§2.2) — an exact LRU over `(sst, block_offset)`
//! with byte-budget capacity. Evictions are *returned to the caller* so the
//! engine can forward them to the policy as cache hints (§3.1: the cache
//! hint identifies the SST and the offset of the evicted data block).
//!
//! Blocks are [`WireBuf`]s: the byte budget charges their *logical* size
//! (identical hit/miss/eviction behaviour to a cache of materialized
//! blocks) while residency costs only the compact physical bytes. Under
//! demand paging, admission is a *pin*: every cached block is a hydrated
//! copy (device reads page in), and eviction/invalidation is the unpin —
//! freed slab nodes release their `Arc<WireBuf>` immediately so the
//! bytes do not linger until slab reuse. [`BlockCache::phys_bytes`]
//! reports the pinned resident total. A per-SST index of resident blocks
//! makes [`BlockCache::invalidate_sst`] O(blocks of that SST) instead of
//! a full-map walk.

use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

use crate::wire::WireBuf;

use super::SstId;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockKey {
    pub sst: SstId,
    pub offset: u64,
}

/// An evicted block, handed to the policy as a cache hint.
pub struct Evicted {
    pub key: BlockKey,
    pub data: Arc<WireBuf>,
}

struct Node {
    key: BlockKey,
    data: Arc<WireBuf>,
    prev: usize,
    next: usize,
}

const NIL: usize = usize::MAX;

/// Exact LRU with O(1) get/insert via an intrusive list over a slab.
pub struct BlockCache {
    capacity_bytes: u64,
    used_bytes: u64,
    map: HashMap<BlockKey, usize>,
    /// Resident block offsets per SST (ordered for deterministic
    /// invalidation), so deletion-time invalidation never scans the map.
    by_sst: HashMap<SstId, BTreeSet<u64>>,
    slab: Vec<Node>,
    free: Vec<usize>,
    head: usize, // most recent
    tail: usize, // least recent
    pub hits: u64,
    pub misses: u64,
}

impl BlockCache {
    pub fn new(capacity_bytes: u64) -> Self {
        BlockCache {
            capacity_bytes,
            used_bytes: 0,
            map: HashMap::new(),
            by_sst: HashMap::new(),
            slab: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            hits: 0,
            misses: 0,
        }
    }

    fn detach(&mut self, i: usize) {
        let (p, n) = (self.slab[i].prev, self.slab[i].next);
        if p != NIL {
            self.slab[p].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.slab[n].prev = p;
        } else {
            self.tail = p;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.slab[i].prev = NIL;
        self.slab[i].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    fn index_remove(&mut self, key: &BlockKey) {
        if let Some(set) = self.by_sst.get_mut(&key.sst) {
            set.remove(&key.offset);
            if set.is_empty() {
                self.by_sst.remove(&key.sst);
            }
        }
    }

    pub fn get(&mut self, key: &BlockKey) -> Option<Arc<WireBuf>> {
        if let Some(&i) = self.map.get(key) {
            self.detach(i);
            self.push_front(i);
            self.hits += 1;
            Some(self.slab[i].data.clone())
        } else {
            self.misses += 1;
            None
        }
    }

    /// Peek without touching LRU order or counters.
    pub fn contains(&self, key: &BlockKey) -> bool {
        self.map.contains_key(key)
    }

    /// Insert a block; returns everything evicted to make room.
    pub fn insert(&mut self, key: BlockKey, data: Arc<WireBuf>) -> Vec<Evicted> {
        let mut evicted = Vec::new();
        if self.capacity_bytes == 0 {
            return vec![Evicted { key, data }];
        }
        if let Some(&i) = self.map.get(&key) {
            // Refresh existing.
            self.used_bytes -= self.slab[i].data.len();
            self.used_bytes += data.len();
            self.slab[i].data = data;
            self.detach(i);
            self.push_front(i);
            return evicted;
        }
        let len = data.len();
        // Evict LRU until it fits.
        while self.used_bytes + len > self.capacity_bytes && self.tail != NIL {
            let t = self.tail;
            let node_key = self.slab[t].key;
            // Take the Arc out of the freed node: eviction must unpin
            // the block's resident bytes, not park them in the slab.
            let node_data = std::mem::replace(&mut self.slab[t].data, Arc::new(WireBuf::new()));
            self.detach(t);
            self.map.remove(&node_key);
            self.index_remove(&node_key);
            self.used_bytes -= node_data.len();
            self.free.push(t);
            evicted.push(Evicted { key: node_key, data: node_data });
        }
        if len > self.capacity_bytes {
            // Block bigger than the whole cache: pass it straight through.
            evicted.push(Evicted { key, data });
            return evicted;
        }
        let node = Node { key, data, prev: NIL, next: NIL };
        let i = if let Some(i) = self.free.pop() {
            self.slab[i] = node;
            i
        } else {
            self.slab.push(node);
            self.slab.len() - 1
        };
        self.map.insert(key, i);
        self.by_sst.entry(key.sst).or_default().insert(key.offset);
        self.push_front(i);
        self.used_bytes += len;
        evicted
    }

    /// Drop all blocks of an SST (called when compaction deletes it).
    /// O(resident blocks of that SST) via the per-SST index.
    pub fn invalidate_sst(&mut self, sst: SstId) {
        let Some(offsets) = self.by_sst.remove(&sst) else { return };
        for offset in offsets {
            let k = BlockKey { sst, offset };
            if let Some(i) = self.map.remove(&k) {
                let data = std::mem::replace(&mut self.slab[i].data, Arc::new(WireBuf::new()));
                self.used_bytes -= data.len();
                self.detach(i);
                self.free.push(i);
            }
        }
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }
    /// Physically resident bytes pinned by the cache. Live nodes only —
    /// freed slab slots hold empty buffers and contribute nothing.
    pub fn phys_bytes(&self) -> u64 {
        self.map.values().map(|&i| self.slab[i].data.phys_len() as u64).sum()
    }
    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
    pub fn hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            0.0
        } else {
            self.hits as f64 / (self.hits + self.misses) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk(n: usize) -> Arc<WireBuf> {
        Arc::new(WireBuf::from_bytes(&vec![0u8; n]))
    }

    #[test]
    fn hit_after_insert() {
        let mut c = BlockCache::new(10_000);
        let k = BlockKey { sst: 1, offset: 0 };
        c.insert(k, blk(100));
        assert!(c.get(&k).is_some());
        assert_eq!(c.hits, 1);
    }

    #[test]
    fn lru_evicts_oldest_first() {
        let mut c = BlockCache::new(300);
        for i in 0..3u64 {
            c.insert(BlockKey { sst: 1, offset: i * 100 }, blk(100));
        }
        // Touch offset 0 so offset 100 becomes LRU.
        c.get(&BlockKey { sst: 1, offset: 0 });
        let ev = c.insert(BlockKey { sst: 1, offset: 900 }, blk(100));
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].key.offset, 100);
        assert!(c.contains(&BlockKey { sst: 1, offset: 0 }));
    }

    #[test]
    fn capacity_respected() {
        let mut c = BlockCache::new(1000);
        for i in 0..100u64 {
            c.insert(BlockKey { sst: 2, offset: i }, blk(100));
        }
        assert!(c.used_bytes() <= 1000);
        assert_eq!(c.len(), 10);
    }

    #[test]
    fn oversized_block_passes_through() {
        let mut c = BlockCache::new(100);
        let ev = c.insert(BlockKey { sst: 1, offset: 0 }, blk(500));
        assert_eq!(ev.len(), 1);
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn invalidate_sst_removes_only_that_sst() {
        let mut c = BlockCache::new(10_000);
        c.insert(BlockKey { sst: 1, offset: 0 }, blk(10));
        c.insert(BlockKey { sst: 1, offset: 1 }, blk(10));
        c.insert(BlockKey { sst: 2, offset: 0 }, blk(10));
        c.invalidate_sst(1);
        assert!(!c.contains(&BlockKey { sst: 1, offset: 0 }));
        assert!(c.contains(&BlockKey { sst: 2, offset: 0 }));
        assert_eq!(c.used_bytes(), 10);
    }

    #[test]
    fn per_sst_index_stays_in_sync_with_evictions() {
        let mut c = BlockCache::new(300);
        for i in 0..10u64 {
            c.insert(BlockKey { sst: i % 2, offset: i * 100 }, blk(100));
        }
        // Only 3 resident; invalidate both SSTs → cache fully empty.
        c.invalidate_sst(0);
        c.invalidate_sst(1);
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
        assert!(c.by_sst.is_empty(), "index must not leak evicted blocks");
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let mut c = BlockCache::new(250);
        c.insert(BlockKey { sst: 1, offset: 0 }, blk(100));
        c.insert(BlockKey { sst: 1, offset: 100 }, blk(100));
        let ev = c.insert(BlockKey { sst: 1, offset: 0 }, blk(100));
        assert!(ev.is_empty());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn zero_capacity_cache_bypasses() {
        let mut c = BlockCache::new(0);
        let ev = c.insert(BlockKey { sst: 1, offset: 0 }, blk(10));
        assert_eq!(ev.len(), 1);
        assert!(c.get(&BlockKey { sst: 1, offset: 0 }).is_none());
    }

    #[test]
    fn eviction_unpins_resident_bytes() {
        let mut c = BlockCache::new(200);
        let a = blk(100);
        c.insert(BlockKey { sst: 1, offset: 0 }, a.clone());
        c.insert(BlockKey { sst: 1, offset: 100 }, blk(100));
        assert_eq!(c.phys_bytes(), 200);
        // Evicting offset 0 must drop the slab's Arc, not just the map entry.
        c.insert(BlockKey { sst: 1, offset: 200 }, blk(100));
        assert!(!c.contains(&BlockKey { sst: 1, offset: 0 }));
        assert_eq!(Arc::strong_count(&a), 1, "freed slab node must release the block");
        assert_eq!(c.phys_bytes(), 200);
        c.invalidate_sst(1);
        assert_eq!(c.phys_bytes(), 0);
    }

    #[test]
    fn slab_reuse_after_eviction() {
        let mut c = BlockCache::new(200);
        for i in 0..50u64 {
            c.insert(BlockKey { sst: 1, offset: i }, blk(100));
        }
        // Slab should not have grown unboundedly.
        assert!(c.slab.len() <= 4, "slab len = {}", c.slab.len());
    }
}
