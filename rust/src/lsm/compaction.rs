//! Compaction merge (§2.2): k-way merge-sort of sorted entry streams,
//! discarding shadowed versions, splitting outputs at the target SST size.
//!
//! Two implementations with pinned-identical output:
//!
//! * [`streaming_merge`] — the production path: a cursor-based k-way merge
//!   over per-SST block readers that feeds [`SstBuilder`]s incrementally.
//!   Memory is bounded by O(one block per input) plus the (compact,
//!   prefix-compressed) output buffers; nothing is materialized per
//!   entry, and keys flow through as zero-copy [`KeyView`]s borrowing the
//!   resident blocks' prefix-shared bytes.
//! * [`merge_entries`] + [`split_outputs`] — the seed engine's
//!   materialize-everything pipeline, retained as the reference
//!   implementation for the scan path and the equivalence tests that pin
//!   the streaming path byte-for-byte against it.

use std::sync::Arc;

use crate::wire::{KeyView, WireBuf};

use super::sst::{BlockHandle, SstBuilder, SstMeta};
use super::{Entry, Key, Payload};

/// Merge sorted entry streams into one deduplicated sorted stream.
///
/// `streams[i]` takes precedence over `streams[j]` for equal keys when the
/// entry's sequence number is higher (standard LSM semantics — seqnos are
/// globally unique and monotone). Tombstones are dropped entirely when
/// `drop_tombstones` (bottom-level compaction); otherwise they propagate.
pub fn merge_entries(streams: Vec<Vec<Entry>>, drop_tombstones: bool) -> Vec<Entry> {
    // Binary-heap k-way merge: smallest key first; newest seq first on ties.
    use std::collections::BinaryHeap;

    struct Item {
        e: Entry,
        src: usize,
    }
    impl PartialEq for Item {
        fn eq(&self, other: &Self) -> bool {
            self.e.key == other.e.key && self.e.seq == other.e.seq
        }
    }
    impl Eq for Item {}
    impl PartialOrd for Item {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Item {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            // BinaryHeap is a max-heap; we want smallest key first, and for
            // equal keys the *newest* (highest seq) first.
            other
                .e
                .key
                .cmp(&self.e.key)
                .then_with(|| self.e.seq.cmp(&other.e.seq))
        }
    }

    let total: usize = streams.iter().map(|s| s.len()).sum();
    let mut heap: BinaryHeap<Item> = BinaryHeap::with_capacity(streams.len());
    let mut iters: Vec<std::vec::IntoIter<Entry>> =
        streams.into_iter().map(|s| s.into_iter()).collect();
    for (src, it) in iters.iter_mut().enumerate() {
        if let Some(e) = it.next() {
            heap.push(Item { e, src });
        }
    }
    let mut out: Vec<Entry> = Vec::with_capacity(total);
    // Interned keys make the dedup cursor a refcount bump, not a byte copy.
    let mut last_key: Option<Key> = None;
    while let Some(Item { e, src }) = heap.pop() {
        if let Some(next) = iters[src].next() {
            debug_assert!(next.key >= e.key, "input stream not sorted");
            heap.push(Item { e: next, src });
        }
        let dup = last_key.as_ref() == Some(&e.key);
        if dup {
            continue; // older version of a key we already emitted
        }
        last_key = Some(e.key.clone());
        if e.value.is_none() && drop_tombstones {
            continue;
        }
        out.push(e);
    }
    out
}

/// Split merged entries into output SSTs of at most `sst_size` encoded
/// bytes each; returns the entry ranges.
pub fn split_outputs(entries: &[Entry], sst_size: u64) -> Vec<std::ops::Range<usize>> {
    let mut out = Vec::new();
    let mut start = 0usize;
    let mut bytes = 0u64;
    for (i, e) in entries.iter().enumerate() {
        bytes += e.encoded_len() as u64;
        if bytes >= sst_size {
            out.push(start..i + 1);
            start = i + 1;
            bytes = 0;
        }
    }
    if start < entries.len() {
        out.push(start..entries.len());
    }
    out
}

/// Shape parameters of the streaming merge's outputs.
#[derive(Clone, Copy, Debug)]
pub struct OutputShape {
    /// Rotate to a new output SST once this many encoded bytes are added.
    pub sst_size: u64,
    pub block_size: u64,
    pub bloom_bits_per_key: u32,
}

/// The decoded-but-not-copied current entry of one SST block stream:
/// positions into the stream's resident block (two-part key — shared
/// prefix at the block's restart key, plus the stored suffix).
#[derive(Clone, Copy)]
struct RawCur {
    pre_off: usize,
    pre_len: usize,
    suf_off: usize,
    suf_len: usize,
    seq: u64,
    value: Option<Payload>,
}

/// Cursor over one SST's entries, fetching one data block at a time.
///
/// The resident block is this cursor's residency *pin*: blocks arrive
/// hydrated (device reads page in), the cursor's zero-copy key views
/// borrow their bytes, and the pin is released when the next fetch
/// replaces the block — so a merge keeps exactly one hydrated block per
/// input resident regardless of how much cold data it streams over.
struct SstStream {
    meta: Arc<SstMeta>,
    next_block: usize,
    block: WireBuf,
    log: u64,
    phys: usize,
    run: usize,
    prun: usize,
    cur: Option<RawCur>,
}

impl SstStream {
    fn new(meta: Arc<SstMeta>) -> SstStream {
        SstStream {
            meta,
            next_block: 0,
            block: WireBuf::new(),
            log: 0,
            phys: 0,
            run: 0,
            prun: 0,
            cur: None,
        }
    }

    fn advance<F>(&mut self, fetch: &mut F)
    where
        F: FnMut(&SstMeta, &BlockHandle) -> WireBuf,
    {
        loop {
            if let Some(raw) = self.block.decode_entry_raw(self.log, self.phys, self.run, self.prun)
            {
                self.log = raw.next_log;
                self.phys = raw.next_phys;
                self.run = raw.next_run;
                self.prun = raw.next_prun;
                self.cur = Some(RawCur {
                    pre_off: raw.pre_off,
                    pre_len: raw.pre_len,
                    suf_off: raw.suf_off,
                    suf_len: raw.suf_len,
                    seq: raw.seq,
                    value: raw.value,
                });
                return;
            }
            if self.next_block >= self.meta.blocks.len() {
                self.cur = None;
                return;
            }
            // Exhausted the resident block — fetch the next one. Memory
            // stays bounded at one block per input stream.
            let h = self.meta.blocks[self.next_block];
            self.block = fetch(&self.meta, &h);
            debug_assert!(
                self.block.is_hydrated(),
                "merge cursors pin hydrated blocks — fetch must page in"
            );
            self.next_block += 1;
            self.log = 0;
            self.phys = 0;
            self.run = 0;
            self.prun = 0;
        }
    }
}

/// One input of the streaming merge.
enum Source {
    /// In-memory sorted entries (flush path).
    Mem { entries: Vec<Entry>, pos: usize },
    /// Lazily-read SST blocks (compaction path).
    Sst(SstStream),
}

impl Source {
    fn key(&self) -> Option<KeyView<'_>> {
        match self {
            Source::Mem { entries, pos } => entries.get(*pos).map(|e| e.key.view()),
            Source::Sst(s) => s
                .cur
                .as_ref()
                .map(|c| s.block.key_view_at(c.pre_off, c.pre_len, c.suf_off, c.suf_len)),
        }
    }

    /// Seq of the current entry (only called while `key()` is `Some`).
    fn seq(&self) -> u64 {
        match self {
            Source::Mem { entries, pos } => entries[*pos].seq,
            Source::Sst(s) => s.cur.as_ref().expect("current entry").seq,
        }
    }

    fn value(&self) -> Option<Payload> {
        match self {
            Source::Mem { entries, pos } => entries[*pos].value,
            Source::Sst(s) => s.cur.as_ref().expect("current entry").value,
        }
    }

    fn advance<F>(&mut self, fetch: &mut F)
    where
        F: FnMut(&SstMeta, &BlockHandle) -> WireBuf,
    {
        match self {
            Source::Mem { pos, .. } => *pos += 1,
            Source::Sst(s) => s.advance(fetch),
        }
    }
}

/// Streaming k-way merge: merges `mem_inputs` (owned sorted runs) and
/// `sst_inputs` (block-cursor streams fed by `fetch`) into sealed
/// [`SstBuilder`]s, rotating outputs at `shape.sst_size` encoded bytes.
///
/// Produces builders whose finished SSTs are byte-identical (sizes, block
/// handles, bloom words) to the reference `merge_entries` +
/// [`split_outputs`] pipeline — pinned by `tests/datapath.rs`.
pub fn streaming_merge<F>(
    sst_inputs: &[Arc<SstMeta>],
    mem_inputs: Vec<Vec<Entry>>,
    drop_tombstones: bool,
    shape: OutputShape,
    mut fetch: F,
) -> Vec<SstBuilder>
where
    F: FnMut(&SstMeta, &BlockHandle) -> WireBuf,
{
    let mut sources: Vec<Source> = Vec::with_capacity(mem_inputs.len() + sst_inputs.len());
    for entries in mem_inputs {
        sources.push(Source::Mem { entries, pos: 0 });
    }
    for meta in sst_inputs {
        let mut s = SstStream::new(meta.clone());
        s.advance(&mut fetch); // prime the first entry
        sources.push(Source::Sst(s));
    }

    let new_builder = |shape: &OutputShape| {
        SstBuilder::with_capacity(
            shape.block_size,
            shape.bloom_bits_per_key,
            shape.sst_size + shape.sst_size / 8,
        )
    };
    let mut builders: Vec<SstBuilder> = Vec::new();
    let mut cur = new_builder(&shape);
    let mut bytes = 0u64;
    // Reused last-emitted-key buffer for dedup (no per-entry allocation).
    let mut last_key: Vec<u8> = Vec::new();
    let mut have_last = false;

    loop {
        // Pick the source with the smallest key; ties (same key in several
        // inputs) go to the newest version (highest seq), as in the
        // reference heap merge. A linear scan is O(k) per entry where the
        // heap would be O(log k): sources hold their current key as a
        // borrow of their resident block, which a std BinaryHeap cannot
        // store without copying every key, and k is small (all-of-L0 plus
        // the overlapping run of the next level).
        let mut best: Option<usize> = None;
        for (i, s) in sources.iter().enumerate() {
            let Some(k) = s.key() else { continue };
            best = match best {
                None => Some(i),
                Some(j) => {
                    let kj = sources[j].key().expect("best has a key");
                    match k.cmp(&kj) {
                        std::cmp::Ordering::Less => Some(i),
                        std::cmp::Ordering::Greater => Some(j),
                        std::cmp::Ordering::Equal => {
                            if s.seq() > sources[j].seq() {
                                Some(i)
                            } else {
                                Some(j)
                            }
                        }
                    }
                }
            };
        }
        let Some(i) = best else { break };
        {
            let key = sources[i].key().expect("picked source has a key");
            let dup = have_last && key.eq_bytes(&last_key);
            if !dup {
                key.copy_into(&mut last_key);
                have_last = true;
                let value = sources[i].value();
                if !(value.is_none() && drop_tombstones) {
                    bytes += (crate::wire::ENTRY_HEADER
                        + key.len()
                        + value.map_or(0, |p| p.len as usize)) as u64;
                    cur.add_parts(key, sources[i].seq(), value);
                    if bytes >= shape.sst_size {
                        builders.push(std::mem::replace(&mut cur, new_builder(&shape)));
                        bytes = 0;
                    }
                }
            }
        }
        sources[i].advance(&mut fetch);
    }
    if !cur.is_empty() {
        builders.push(cur);
    }
    builders
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(key: &str, seq: u64, val: Option<&str>) -> Entry {
        Entry {
            key: Key::new(key.as_bytes()),
            seq,
            value: val.map(|v| Payload::from_bytes(v.as_bytes())),
        }
    }

    #[test]
    fn newest_version_wins() {
        let merged = merge_entries(
            vec![
                vec![e("a", 5, Some("new")), e("b", 2, Some("b1"))],
                vec![e("a", 1, Some("old")), e("c", 3, Some("c1"))],
            ],
            false,
        );
        assert_eq!(merged.len(), 3);
        assert_eq!(merged[0], e("a", 5, Some("new")));
        assert_eq!(merged[1], e("b", 2, Some("b1")));
        assert_eq!(merged[2], e("c", 3, Some("c1")));
    }

    #[test]
    fn tombstone_shadows_then_drops_at_bottom() {
        let streams = vec![
            vec![e("a", 9, None)],          // newer tombstone
            vec![e("a", 1, Some("alive"))], // older put
        ];
        let kept = merge_entries(streams.clone(), false);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].value, None);
        let dropped = merge_entries(streams, true);
        assert!(dropped.is_empty());
    }

    #[test]
    fn output_sorted_and_unique() {
        let mut streams = Vec::new();
        for s in 0..5u64 {
            let v: Vec<Entry> = (0..200u64)
                .map(|i| e(&format!("k{:05}", (i * 7 + s * 3) % 500), s * 1000 + i, Some("v")))
                .collect();
            let mut v = v;
            v.sort_by(|a, b| a.key.cmp(&b.key));
            streams.push(v);
        }
        let merged = merge_entries(streams, false);
        for w in merged.windows(2) {
            assert!(w[0].key < w[1].key, "sorted & deduped");
        }
    }

    #[test]
    fn merge_empty_streams() {
        assert!(merge_entries(vec![], false).is_empty());
        assert!(merge_entries(vec![vec![], vec![]], false).is_empty());
    }

    #[test]
    fn split_outputs_respects_size() {
        let entries: Vec<Entry> =
            (0..100u64).map(|i| e(&format!("k{i:04}"), i, Some("0123456789"))).collect();
        let per = entries[0].encoded_len() as u64;
        let ranges = split_outputs(&entries, per * 10);
        assert_eq!(ranges.len(), 10);
        let covered: usize = ranges.iter().map(|r| r.len()).sum();
        assert_eq!(covered, 100);
        // Ranges are contiguous and ordered.
        let mut expect = 0;
        for r in &ranges {
            assert_eq!(r.start, expect);
            expect = r.end;
        }
    }

    #[test]
    fn split_outputs_single_when_small() {
        let entries: Vec<Entry> = (0..5u64).map(|i| e(&format!("k{i}"), i, Some("v"))).collect();
        let ranges = split_outputs(&entries, 1 << 20);
        assert_eq!(ranges.len(), 1);
        assert_eq!(ranges[0], 0..5);
    }

    #[test]
    fn streaming_merge_of_mem_streams_matches_reference() {
        let streams = vec![
            vec![e("a", 5, Some("new")), e("b", 2, Some("b1")), e("d", 7, None)],
            vec![e("a", 1, Some("old")), e("c", 3, Some("c1")), e("d", 4, Some("dead"))],
        ];
        let shape = OutputShape { sst_size: 1 << 20, block_size: 4096, bloom_bits_per_key: 10 };
        for drop in [false, true] {
            let reference = merge_entries(streams.clone(), drop);
            let builders =
                streaming_merge(&[], streams.clone(), drop, shape, |_, _| unreachable!());
            let mut ref_b = SstBuilder::new(4096, 10);
            for ent in &reference {
                ref_b.add(ent);
            }
            if reference.is_empty() {
                assert!(builders.is_empty());
                continue;
            }
            assert_eq!(builders.len(), 1);
            let (m1, d1) = builders.into_iter().next().unwrap().finish(9, 1, 0);
            let (m2, d2) = ref_b.finish(9, 1, 0);
            assert_eq!(d1, d2, "drop={drop}");
            assert_eq!(m1.num_entries, m2.num_entries);
            assert_eq!(m1.blocks, m2.blocks);
            assert_eq!(m1.index, m2.index);
        }
    }
}
