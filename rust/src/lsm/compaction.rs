//! Compaction merge (§2.2): k-way merge-sort of sorted entry streams,
//! discarding shadowed versions, splitting outputs at the target SST size.

use super::Entry;

/// Merge sorted entry streams into one deduplicated sorted stream.
///
/// `streams[i]` takes precedence over `streams[j]` for equal keys when the
/// entry's sequence number is higher (standard LSM semantics — seqnos are
/// globally unique and monotone). Tombstones are dropped entirely when
/// `drop_tombstones` (bottom-level compaction); otherwise they propagate.
pub fn merge_entries(streams: Vec<Vec<Entry>>, drop_tombstones: bool) -> Vec<Entry> {
    // Binary-heap k-way merge: smallest key first; newest seq first on ties.
    use std::collections::BinaryHeap;

    struct Item {
        e: Entry,
        src: usize,
    }
    impl PartialEq for Item {
        fn eq(&self, other: &Self) -> bool {
            self.e.key == other.e.key && self.e.seq == other.e.seq
        }
    }
    impl Eq for Item {}
    impl PartialOrd for Item {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Item {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            // BinaryHeap is a max-heap; we want smallest key first, and for
            // equal keys the *newest* (highest seq) first.
            other
                .e
                .key
                .cmp(&self.e.key)
                .then_with(|| self.e.seq.cmp(&other.e.seq))
        }
    }

    let total: usize = streams.iter().map(|s| s.len()).sum();
    let mut heap: BinaryHeap<Item> = BinaryHeap::with_capacity(streams.len());
    let mut iters: Vec<std::vec::IntoIter<Entry>> =
        streams.into_iter().map(|s| s.into_iter()).collect();
    for (src, it) in iters.iter_mut().enumerate() {
        if let Some(e) = it.next() {
            heap.push(Item { e, src });
        }
    }
    let mut out: Vec<Entry> = Vec::with_capacity(total);
    let mut last_key: Option<Vec<u8>> = None;
    while let Some(Item { e, src }) = heap.pop() {
        if let Some(next) = iters[src].next() {
            debug_assert!(next.key >= e.key, "input stream not sorted");
            heap.push(Item { e: next, src });
        }
        let dup = last_key.as_deref() == Some(e.key.as_slice());
        if dup {
            continue; // older version of a key we already emitted
        }
        last_key = Some(e.key.clone());
        if e.value.is_none() && drop_tombstones {
            continue;
        }
        out.push(e);
    }
    out
}

/// Split merged entries into output SSTs of at most `sst_size` encoded
/// bytes each; returns the entry ranges.
pub fn split_outputs(entries: &[Entry], sst_size: u64) -> Vec<std::ops::Range<usize>> {
    let mut out = Vec::new();
    let mut start = 0usize;
    let mut bytes = 0u64;
    for (i, e) in entries.iter().enumerate() {
        bytes += e.encoded_len() as u64;
        if bytes >= sst_size {
            out.push(start..i + 1);
            start = i + 1;
            bytes = 0;
        }
    }
    if start < entries.len() {
        out.push(start..entries.len());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(key: &str, seq: u64, val: Option<&str>) -> Entry {
        Entry {
            key: key.as_bytes().to_vec(),
            seq,
            value: val.map(|v| v.as_bytes().to_vec()),
        }
    }

    #[test]
    fn newest_version_wins() {
        let merged = merge_entries(
            vec![
                vec![e("a", 5, Some("new")), e("b", 2, Some("b1"))],
                vec![e("a", 1, Some("old")), e("c", 3, Some("c1"))],
            ],
            false,
        );
        assert_eq!(merged.len(), 3);
        assert_eq!(merged[0], e("a", 5, Some("new")));
        assert_eq!(merged[1], e("b", 2, Some("b1")));
        assert_eq!(merged[2], e("c", 3, Some("c1")));
    }

    #[test]
    fn tombstone_shadows_then_drops_at_bottom() {
        let streams = vec![
            vec![e("a", 9, None)],          // newer tombstone
            vec![e("a", 1, Some("alive"))], // older put
        ];
        let kept = merge_entries(streams.clone(), false);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].value, None);
        let dropped = merge_entries(streams, true);
        assert!(dropped.is_empty());
    }

    #[test]
    fn output_sorted_and_unique() {
        let mut streams = Vec::new();
        for s in 0..5u64 {
            let v: Vec<Entry> = (0..200u64)
                .map(|i| e(&format!("k{:05}", (i * 7 + s * 3) % 500), s * 1000 + i, Some("v")))
                .collect();
            let mut v = v;
            v.sort_by(|a, b| a.key.cmp(&b.key));
            streams.push(v);
        }
        let merged = merge_entries(streams, false);
        for w in merged.windows(2) {
            assert!(w[0].key < w[1].key, "sorted & deduped");
        }
    }

    #[test]
    fn merge_empty_streams() {
        assert!(merge_entries(vec![], false).is_empty());
        assert!(merge_entries(vec![vec![], vec![]], false).is_empty());
    }

    #[test]
    fn split_outputs_respects_size() {
        let entries: Vec<Entry> =
            (0..100u64).map(|i| e(&format!("k{i:04}"), i, Some("0123456789"))).collect();
        let per = entries[0].encoded_len() as u64;
        let ranges = split_outputs(&entries, per * 10);
        assert_eq!(ranges.len(), 10);
        let covered: usize = ranges.iter().map(|r| r.len()).sum();
        assert_eq!(covered, 100);
        // Ranges are contiguous and ordered.
        let mut expect = 0;
        for r in &ranges {
            assert_eq!(r.start, expect);
            expect = r.end;
        }
    }

    #[test]
    fn split_outputs_single_when_small() {
        let entries: Vec<Entry> = (0..5u64).map(|i| e(&format!("k{i}"), i, Some("v"))).collect();
        let ranges = split_outputs(&entries, 1 << 20);
        assert_eq!(ranges.len(), 1);
        assert_eq!(ranges[0], 0..5);
    }
}
