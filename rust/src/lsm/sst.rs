//! SSTable format (§2.2): data blocks of ~4 KiB, an index block mapping
//! first-keys to block offsets, and a Bloom filter over all keys.
//!
//! The serialized layout written to zones is
//! `[data blocks][index block][bloom block]`; the index and Bloom filter
//! are also kept in memory in [`SstMeta`] (as RocksDB does via pinned
//! meta-blocks), so point reads cost exactly one data-block I/O.
//!
//! All offsets and sizes are *logical* ([`WireBuf`] lengths) — identical
//! to a materialized encoding — while the resident bytes are the compact
//! physical form (headers + keys + padding only).

use std::sync::Arc;

use crate::sim::rng::fingerprint32;
use crate::wire::{EntryRef, WireBuf};

use super::{Bloom, Entry, Key, Payload, SstId};

/// Location of one data block inside the SST file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockHandle {
    pub offset: u64,
    pub len: u32,
    /// First user key in the block (index entry).
    pub first_key: Key,
}

/// In-memory metadata for one immutable SSTable.
#[derive(Clone, Debug)]
pub struct SstMeta {
    pub id: SstId,
    pub level: usize,
    pub smallest: Key,
    pub largest: Key,
    /// Total serialized file size (data + index + bloom).
    pub file_size: u64,
    pub num_entries: u64,
    pub blocks: Vec<BlockHandle>,
    pub bloom: Bloom,
    /// Virtual creation time (ns) — the "age" input of SST priorities (§3.4).
    pub created_at: u64,
}

impl SstMeta {
    /// Binary-search the index for the block that may contain `key`.
    pub fn find_block(&self, key: &[u8]) -> Option<usize> {
        if self.blocks.is_empty() || key < self.smallest.as_slice() || key > self.largest.as_slice()
        {
            return None;
        }
        // partition_point: first block whose first_key > key, minus one.
        let idx = self.blocks.partition_point(|b| b.first_key.as_slice() <= key);
        if idx == 0 {
            None
        } else {
            Some(idx - 1)
        }
    }

    /// Key-range overlap test (used for compaction input selection).
    pub fn overlaps(&self, lo: &[u8], hi: &[u8]) -> bool {
        self.smallest.as_slice() <= hi && self.largest.as_slice() >= lo
    }
}

/// Builds the serialized form of one SST from sorted entries.
pub struct SstBuilder {
    block_size: u64,
    bits_per_key: u32,
    data: WireBuf,
    blocks: Vec<BlockHandle>,
    cur_block_start: u64,
    cur_block_first: Option<Key>,
    fps: Vec<u32>,
    smallest: Option<Key>,
    largest: Option<Key>,
    num_entries: u64,
}

impl SstBuilder {
    pub fn new(block_size: u64, bits_per_key: u32) -> Self {
        Self::with_capacity(block_size, bits_per_key, 0)
    }

    /// Pre-reserve the physical buffer. `data_capacity` is the expected
    /// *logical* output size; the physical form is far smaller (headers +
    /// keys), so a small fraction is reserved.
    pub fn with_capacity(block_size: u64, bits_per_key: u32, data_capacity: u64) -> Self {
        let mut data = WireBuf::new();
        data.reserve_phys((data_capacity / 16) as usize);
        SstBuilder {
            block_size,
            bits_per_key,
            data,
            blocks: Vec::new(),
            cur_block_start: 0,
            cur_block_first: None,
            fps: Vec::new(),
            smallest: None,
            largest: None,
            num_entries: 0,
        }
    }

    /// Append one entry (entries MUST arrive in sorted key order).
    pub fn add(&mut self, e: &Entry) {
        self.add_parts(&e.key, e.seq, e.value);
    }

    /// Append one entry from borrowed parts (the streaming-merge feed).
    pub fn add_parts(&mut self, key: &[u8], seq: u64, value: Option<Payload>) {
        debug_assert!(
            self.largest.as_ref().map_or(true, |l| l.as_slice() < key),
            "entries must be added in strictly increasing key order"
        );
        if self.cur_block_first.is_none() {
            self.cur_block_first = Some(key.to_vec());
            self.cur_block_start = self.data.len();
        }
        self.data.push_entry(key, seq, value);
        self.fps.push(fingerprint32(key));
        if self.smallest.is_none() {
            self.smallest = Some(key.to_vec());
        }
        self.largest = Some(key.to_vec());
        self.num_entries += 1;
        if self.data.len() - self.cur_block_start >= self.block_size {
            self.seal_block();
        }
    }

    fn seal_block(&mut self) {
        if let Some(first) = self.cur_block_first.take() {
            self.blocks.push(BlockHandle {
                offset: self.cur_block_start,
                len: (self.data.len() - self.cur_block_start) as u32,
                first_key: first,
            });
        }
    }

    /// Current serialized (logical) data size, for output-SST targeting.
    pub fn data_len(&self) -> u64 {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.num_entries == 0
    }

    /// Finish: returns the in-memory meta and the full serialized buffer.
    pub fn finish(mut self, id: SstId, level: usize, created_at: u64) -> (SstMeta, WireBuf) {
        self.seal_block();
        let bloom = Bloom::build(&self.fps, self.bits_per_key);
        // Serialize index + bloom after the data so the file size is honest.
        let index_bytes: usize =
            self.blocks.iter().map(|b| 12 + b.first_key.len()).sum::<usize>() + 8;
        let mut data = self.data;
        data.push_zeros(index_bytes + bloom.byte_len());
        let meta = SstMeta {
            id,
            level,
            smallest: self.smallest.unwrap_or_default(),
            largest: self.largest.unwrap_or_default(),
            file_size: data.len(),
            num_entries: self.num_entries,
            blocks: self.blocks,
            bloom,
            created_at,
        };
        (meta, data)
    }
}

/// Search a data block for `key`, returning a zero-copy entry view.
pub fn search_block<'a>(block: &'a WireBuf, key: &[u8]) -> Option<EntryRef<'a>> {
    for e in block.entries() {
        match e.key.cmp(key) {
            std::cmp::Ordering::Equal => return Some(e),
            std::cmp::Ordering::Greater => return None, // sorted — passed it
            std::cmp::Ordering::Less => {}
        }
    }
    None
}

/// Decode all entries of a data block into owned form (tests / reference
/// paths; the hot paths iterate [`WireBuf::entries`] without cloning).
pub fn decode_block(block: &WireBuf) -> Vec<Entry> {
    block.entries().map(|e| e.to_entry()).collect()
}

/// Convenience: build an SST from sorted entries in one call.
pub fn build_sst(
    entries: &[Entry],
    id: SstId,
    level: usize,
    block_size: u64,
    bits_per_key: u32,
    created_at: u64,
) -> (Arc<SstMeta>, WireBuf) {
    let mut b = SstBuilder::new(block_size, bits_per_key);
    for e in entries {
        b.add(e);
    }
    let (meta, data) = b.finish(id, level, created_at);
    (Arc::new(meta), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries(n: u64) -> Vec<Entry> {
        (0..n)
            .map(|i| Entry {
                key: format!("user{i:08}").into_bytes(),
                seq: i,
                value: Some(Payload::fill((i % 251) as u8, 100)),
            })
            .collect()
    }

    fn block_of(data: &WireBuf, h: &BlockHandle) -> WireBuf {
        data.slice_to_buf(h.offset, h.len as u64)
    }

    #[test]
    fn build_and_point_lookup_every_key() {
        let es = entries(500);
        let (meta, data) = build_sst(&es, 1, 0, 4096, 10, 0);
        assert!(meta.blocks.len() > 5, "should split into many blocks");
        for e in &es {
            let bi = meta.find_block(&e.key).expect("block for key");
            let block = block_of(&data, &meta.blocks[bi]);
            let found = search_block(&block, &e.key).expect("entry in block");
            assert_eq!(found.to_entry(), *e);
        }
    }

    #[test]
    fn missing_keys_return_none() {
        let es = entries(100);
        let (meta, data) = build_sst(&es, 1, 0, 4096, 10, 0);
        // Key lexically inside the range but absent.
        let probe = b"user00000050x".to_vec();
        if let Some(bi) = meta.find_block(&probe) {
            let block = block_of(&data, &meta.blocks[bi]);
            assert!(search_block(&block, &probe).is_none());
        }
        // Key outside the range.
        assert!(meta.find_block(b"zzz").is_none());
        assert!(meta.find_block(b"aaa").is_none());
    }

    #[test]
    fn block_sizes_near_target() {
        let es = entries(1000);
        let (meta, _) = build_sst(&es, 1, 0, 4096, 10, 0);
        for h in &meta.blocks[..meta.blocks.len() - 1] {
            assert!(h.len as u64 >= 4096, "sealed block below target");
            assert!((h.len as u64) < 4096 + 200, "block far above target");
        }
    }

    #[test]
    fn file_size_includes_index_and_bloom() {
        let es = entries(1000);
        let (meta, data) = build_sst(&es, 1, 0, 4096, 10, 0);
        assert_eq!(meta.file_size, data.len());
        let data_bytes: u64 = meta.blocks.iter().map(|b| b.len as u64).sum();
        assert!(meta.file_size > data_bytes, "index/bloom accounted");
    }

    #[test]
    fn physical_size_excludes_payload_bytes() {
        let es = entries(1000);
        let (_, data) = build_sst(&es, 1, 0, 4096, 10, 0);
        // 1000 entries × 100-byte values are logical-only.
        assert!(data.len() > 100 * 1000, "logical size counts values");
        assert!(
            (data.phys_len() as u64) < data.len() - 90 * 1000,
            "payload bytes must not be resident: phys={} logical={}",
            data.phys_len(),
            data.len()
        );
    }

    #[test]
    fn smallest_largest_and_overlap() {
        let es = entries(100);
        let (meta, _) = build_sst(&es, 1, 2, 4096, 10, 0);
        assert_eq!(meta.smallest, b"user00000000".to_vec());
        assert_eq!(meta.largest, b"user00000099".to_vec());
        assert!(meta.overlaps(b"user00000050", b"user00000060"));
        assert!(meta.overlaps(b"user", b"user00000000"));
        assert!(!meta.overlaps(b"v", b"w"));
    }

    #[test]
    fn decode_block_roundtrip() {
        let es = entries(50);
        let (meta, data) = build_sst(&es, 1, 0, 100_000_000, 10, 0);
        assert_eq!(meta.blocks.len(), 1);
        let block = block_of(&data, &meta.blocks[0]);
        assert_eq!(decode_block(&block), es);
    }

    #[test]
    fn bloom_rejects_most_absent_keys() {
        let es = entries(1000);
        let (meta, _) = build_sst(&es, 1, 0, 4096, 10, 0);
        let mut rejected = 0;
        for i in 0..1000u64 {
            let probe = format!("other{i:08}");
            if !meta.bloom.may_contain(crate::sim::rng::fingerprint32(probe.as_bytes())) {
                rejected += 1;
            }
        }
        assert!(rejected > 950, "rejected={rejected}");
    }
}
