//! SSTable format (§2.2): data blocks of ~4 KiB, an index block mapping
//! first-keys to block offsets, and a Bloom filter over all keys.
//!
//! The serialized layout written to zones is
//! `[data blocks][index block][bloom block]`; the index and Bloom filter
//! are also kept in memory in [`SstMeta`] (as RocksDB does via pinned
//! meta-blocks), so point reads cost exactly one data-block I/O.
//!
//! All offsets and sizes are *logical* ([`WireBuf`] lengths) — identical
//! to a materialized encoding — while the resident bytes are the compact
//! physical form. Since the key-interning refactor that compact form is
//! restart-point prefix-compressed (RocksDB block restarts, interval
//! [`RESTART_INTERVAL`]): every 16th entry of a data block stores its
//! full key, the rest store only the suffix after the restart key's
//! shared prefix, and the in-memory index keeps truncated separators in a
//! [`KeyIndex`]. Lookup behaviour is bit-identical to full-key storage —
//! comparisons always see the exact reconstructed key — so the DES
//! timeline (and the golden e2e digests) do not move.

use std::sync::Arc;

use crate::sim::rng::fingerprint32;
use crate::wire::{EntryRef, KeyView, WireBuf, ENTRY_HEADER};

use super::key::{common_prefix_len, KeyIndex, MIN_SHARED_PREFIX, RESTART_INTERVAL};
use super::{Bloom, Entry, Key, Payload, SstId};

/// Location of one data block inside the SST file. The block's first key
/// lives in the owning [`SstMeta`]'s prefix-compressed [`KeyIndex`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockHandle {
    pub offset: u64,
    pub len: u32,
}

/// In-memory metadata for one immutable SSTable.
#[derive(Clone, Debug)]
pub struct SstMeta {
    pub id: SstId,
    pub level: usize,
    pub smallest: Key,
    pub largest: Key,
    /// Total serialized file size (data + index + bloom).
    pub file_size: u64,
    pub num_entries: u64,
    pub blocks: Vec<BlockHandle>,
    /// First key of every block, prefix-compressed (one entry per
    /// [`BlockHandle`], same order).
    pub index: KeyIndex,
    pub bloom: Bloom,
    /// Virtual creation time (ns) — the "age" input of SST priorities (§3.4).
    pub created_at: u64,
}

impl SstMeta {
    /// Binary-search the index for the block that may contain `key`.
    /// Exactly `partition_point(first_key <= key) - 1` over the full
    /// first-keys (the truncated index reconstructs them losslessly).
    pub fn find_block(&self, key: &[u8]) -> Option<usize> {
        if self.blocks.is_empty() || key < self.smallest.as_slice() || key > self.largest.as_slice()
        {
            return None;
        }
        let idx = self.index.partition_point_leq(key);
        if idx == 0 {
            None
        } else {
            Some(idx - 1)
        }
    }

    /// First key of block `i` (zero-copy view into the index).
    pub fn block_first_key(&self, i: usize) -> KeyView<'_> {
        self.index.key(i)
    }

    /// Key-range overlap test (used for compaction input selection).
    pub fn overlaps(&self, lo: &[u8], hi: &[u8]) -> bool {
        self.smallest.as_slice() <= hi && self.largest.as_slice() >= lo
    }
}

/// Builds the serialized form of one SST from sorted entries, restart-point
/// prefix-compressing both the data blocks and the first-key index.
pub struct SstBuilder {
    block_size: u64,
    bits_per_key: u32,
    data: WireBuf,
    blocks: Vec<BlockHandle>,
    index: KeyIndex,
    cur_block_start: u64,
    /// First key of the open block (empty = no open block).
    cur_block_first: Vec<u8>,
    cur_block_open: bool,
    /// The running restart key (fully stored in `data`) and the logical
    /// offset of its key bytes.
    restart_key: Vec<u8>,
    restart_key_log: u64,
    since_restart: usize,
    /// Reused contiguous materialization of the incoming key.
    key_buf: Vec<u8>,
    /// The previous key (order assertion + `largest`).
    last_key: Vec<u8>,
    fps: Vec<u32>,
    smallest: Option<Key>,
    num_entries: u64,
}

impl SstBuilder {
    pub fn new(block_size: u64, bits_per_key: u32) -> Self {
        Self::with_capacity(block_size, bits_per_key, 0)
    }

    /// Pre-reserve the physical buffer. `data_capacity` is the expected
    /// *logical* output size; the physical form is far smaller (headers +
    /// key suffixes), so a small fraction is reserved.
    pub fn with_capacity(block_size: u64, bits_per_key: u32, data_capacity: u64) -> Self {
        let mut data = WireBuf::new();
        data.reserve_phys((data_capacity / 16) as usize);
        SstBuilder {
            block_size,
            bits_per_key,
            data,
            blocks: Vec::new(),
            index: KeyIndex::new(),
            cur_block_start: 0,
            cur_block_first: Vec::new(),
            cur_block_open: false,
            restart_key: Vec::new(),
            restart_key_log: 0,
            since_restart: 0,
            key_buf: Vec::new(),
            last_key: Vec::new(),
            fps: Vec::new(),
            smallest: None,
            num_entries: 0,
        }
    }

    /// Append one entry (entries MUST arrive in sorted key order).
    pub fn add(&mut self, e: &Entry) {
        self.add_parts(e.key.view(), e.seq, e.value);
    }

    /// Append one entry from a borrowed (possibly two-part) key — the
    /// streaming-merge feed.
    pub fn add_parts(&mut self, key: KeyView<'_>, seq: u64, value: Option<Payload>) {
        key.copy_into(&mut self.key_buf);
        debug_assert!(
            self.num_entries == 0 || self.last_key.as_slice() < self.key_buf.as_slice(),
            "entries must be added in strictly increasing key order"
        );
        if !self.cur_block_open {
            self.cur_block_open = true;
            self.cur_block_first.clone_from(&self.key_buf);
            self.cur_block_start = self.data.len();
            self.since_restart = 0; // every block starts at a restart
        }
        if self.since_restart == 0 || self.since_restart >= RESTART_INTERVAL {
            // Restart point: full key physically; later entries in the
            // interval reference it.
            self.restart_key_log = self.data.len() + ENTRY_HEADER as u64;
            self.data.push_entry(&self.key_buf, seq, value);
            self.restart_key.clone_from(&self.key_buf);
            self.since_restart = 1;
        } else {
            // Elide only prefixes long enough to pay for their run
            // metadata (see [`MIN_SHARED_PREFIX`]); shorter ones store
            // the key whole, which push_entry_shared does at shared = 0.
            let mut shared = common_prefix_len(&self.restart_key, &self.key_buf);
            if shared < MIN_SHARED_PREFIX {
                shared = 0;
            }
            self.data.push_entry_shared(&self.key_buf, shared, self.restart_key_log, seq, value);
            self.since_restart += 1;
        }
        self.fps.push(fingerprint32(&self.key_buf));
        if self.smallest.is_none() {
            self.smallest = Some(Key::new(&self.key_buf));
        }
        std::mem::swap(&mut self.last_key, &mut self.key_buf);
        self.num_entries += 1;
        if self.data.len() - self.cur_block_start >= self.block_size {
            self.seal_block();
        }
    }

    fn seal_block(&mut self) {
        if self.cur_block_open {
            self.cur_block_open = false;
            self.blocks.push(BlockHandle {
                offset: self.cur_block_start,
                len: (self.data.len() - self.cur_block_start) as u32,
            });
            self.index.push(&self.cur_block_first);
        }
    }

    /// Current serialized (logical) data size, for output-SST targeting.
    pub fn data_len(&self) -> u64 {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.num_entries == 0
    }

    /// Finish: returns the in-memory meta and the full serialized buffer.
    pub fn finish(mut self, id: SstId, level: usize, created_at: u64) -> (SstMeta, WireBuf) {
        self.seal_block();
        let bloom = Bloom::build(&self.fps, self.bits_per_key);
        // Serialize index + bloom after the data so the file size is
        // honest. The serialized index charges the FULL first-key lengths
        // (12 + klen per block): truncation is a resident-memory
        // optimization, never a logical-size change. The reservation is a
        // weightless pad, not physical zeros: the decoded index and bloom
        // already live (and are charged) in `SstMeta`, so resident copies
        // of their serialized form would double-count them — and unlike
        // zeros, a pad run stops entry decoding instead of reading as a
        // stream of bogus empty entries.
        let index_bytes: usize =
            (0..self.index.len()).map(|i| 12 + self.index.key_len(i)).sum::<usize>() + 8;
        let mut data = self.data;
        data.push_pad(index_bytes + bloom.byte_len());
        let meta = SstMeta {
            id,
            level,
            smallest: self.smallest.unwrap_or_default(),
            largest: Key::new(&self.last_key),
            file_size: data.len(),
            num_entries: self.num_entries,
            blocks: self.blocks,
            index: self.index,
            bloom,
            created_at,
        };
        (meta, data)
    }
}

/// Search a data block for `key`, returning a zero-copy entry view.
pub fn search_block<'a>(block: &'a WireBuf, key: &[u8]) -> Option<EntryRef<'a>> {
    for e in block.entries() {
        match e.key.cmp_bytes(key) {
            std::cmp::Ordering::Equal => return Some(e),
            std::cmp::Ordering::Greater => return None, // sorted — passed it
            std::cmp::Ordering::Less => {}
        }
    }
    None
}

/// Decode all entries of a data block into owned form (tests / reference
/// paths; the hot paths iterate [`WireBuf::entries`] without cloning).
pub fn decode_block(block: &WireBuf) -> Vec<Entry> {
    block.entries().map(|e| e.to_entry()).collect()
}

/// Convenience: build an SST from sorted entries in one call.
pub fn build_sst(
    entries: &[Entry],
    id: SstId,
    level: usize,
    block_size: u64,
    bits_per_key: u32,
    created_at: u64,
) -> (Arc<SstMeta>, WireBuf) {
    let mut b = SstBuilder::new(block_size, bits_per_key);
    for e in entries {
        b.add(e);
    }
    let (meta, data) = b.finish(id, level, created_at);
    (Arc::new(meta), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries(n: u64) -> Vec<Entry> {
        (0..n)
            .map(|i| Entry {
                key: format!("user{i:08}").into_bytes().into(),
                seq: i,
                value: Some(Payload::fill((i % 251) as u8, 100)),
            })
            .collect()
    }

    fn block_of(data: &WireBuf, h: &BlockHandle) -> WireBuf {
        data.slice_to_buf(h.offset, h.len as u64)
    }

    #[test]
    fn build_and_point_lookup_every_key() {
        let es = entries(500);
        let (meta, data) = build_sst(&es, 1, 0, 4096, 10, 0);
        assert!(meta.blocks.len() > 5, "should split into many blocks");
        for e in &es {
            let bi = meta.find_block(&e.key).expect("block for key");
            let block = block_of(&data, &meta.blocks[bi]);
            let found = search_block(&block, &e.key).expect("entry in block");
            assert_eq!(found.to_entry(), *e);
        }
    }

    #[test]
    fn missing_keys_return_none() {
        let es = entries(100);
        let (meta, data) = build_sst(&es, 1, 0, 4096, 10, 0);
        // Key lexically inside the range but absent.
        let probe = b"user00000050x".to_vec();
        if let Some(bi) = meta.find_block(&probe) {
            let block = block_of(&data, &meta.blocks[bi]);
            assert!(search_block(&block, &probe).is_none());
        }
        // Key outside the range.
        assert!(meta.find_block(b"zzz").is_none());
        assert!(meta.find_block(b"aaa").is_none());
    }

    #[test]
    fn block_sizes_near_target() {
        let es = entries(1000);
        let (meta, _) = build_sst(&es, 1, 0, 4096, 10, 0);
        for h in &meta.blocks[..meta.blocks.len() - 1] {
            assert!(h.len as u64 >= 4096, "sealed block below target");
            assert!((h.len as u64) < 4096 + 200, "block far above target");
        }
    }

    #[test]
    fn file_size_includes_index_and_bloom() {
        let es = entries(1000);
        let (meta, data) = build_sst(&es, 1, 0, 4096, 10, 0);
        assert_eq!(meta.file_size, data.len());
        let data_bytes: u64 = meta.blocks.iter().map(|b| b.len as u64).sum();
        assert!(meta.file_size > data_bytes, "index/bloom accounted");
        // The serialized index charges FULL first-key lengths even though
        // the resident index is truncated.
        let index_logical: u64 = (0..meta.index.len())
            .map(|i| 12 + meta.index.key_len(i) as u64)
            .sum::<u64>()
            + 8;
        assert_eq!(meta.file_size, data_bytes + index_logical + meta.bloom.byte_len() as u64);
    }

    /// Long zero-padded keys (48 B) whose shared prefixes clear
    /// [`MIN_SHARED_PREFIX`], so the builder actually elides them.
    fn long_key_entries(n: u64) -> Vec<Entry> {
        (0..n)
            .map(|i| Entry {
                key: format!("user{i:044}").into_bytes().into(),
                seq: i,
                value: Some(Payload::fill((i % 251) as u8, 100)),
            })
            .collect()
    }

    #[test]
    fn physical_size_excludes_payload_and_shared_prefix_bytes() {
        let es = long_key_entries(1000);
        let (_, data) = build_sst(&es, 1, 0, 4096, 10, 0);
        // 1000 entries × 100-byte values are logical-only.
        assert!(data.len() > 100 * 1000, "logical size counts values");
        assert!(
            (data.phys_len() as u64) < data.len() - 90 * 1000,
            "payload bytes must not be resident: phys={} logical={}",
            data.phys_len(),
            data.len()
        );
        // Restart-point compression: dense zero-padded 48-byte keys share
        // ≥ MIN_SHARED_PREFIX bytes with their restart key, so resident
        // key bytes must be well under entries × key_len (48 KB full).
        let plain: usize = es.iter().map(|e| ENTRY_HEADER + e.key.len()).sum();
        assert!(
            data.phys_len() < plain - 20_000,
            "shared key prefixes must be elided: phys={} full={plain}",
            data.phys_len()
        );
        // Short (12-byte) keys stay whole: eliding under MIN_SHARED_PREFIX
        // bytes would cost more run metadata than it saves.
        let short = entries(200);
        let (_, sdata) = build_sst(&short, 2, 0, 4096, 10, 0);
        assert!(sdata.prefix_runs().is_empty(), "short keys must not be compressed");
    }

    #[test]
    fn prefix_compressed_blocks_decode_like_plain_encoding() {
        let es = long_key_entries(300);
        let (meta, data) = build_sst(&es, 1, 0, 2048, 10, 0);
        // Every block decodes to exactly its slice of the input, and a
        // plain (uncompressed) re-encoding of those entries has the SAME
        // logical length as the block.
        let mut at = 0usize;
        for h in &meta.blocks {
            let block = block_of(&data, h);
            let decoded = decode_block(&block);
            let n = decoded.len();
            assert_eq!(&decoded[..], &es[at..at + n], "block at {}", h.offset);
            let mut plain = WireBuf::new();
            for e in &decoded {
                e.encode_into(&mut plain);
            }
            assert_eq!(plain.len(), h.len as u64, "logical block size unchanged");
            assert!(plain.phys_len() >= block.phys_len(), "compression never grows");
            at += n;
        }
        assert_eq!(at, es.len());
    }

    #[test]
    fn truncated_separator_index_matches_full_key_partition() {
        let es = entries(400);
        let (meta, data) = build_sst(&es, 1, 0, 1024, 10, 0);
        // Reference: the actual first key of every block, read back from
        // the data itself.
        let firsts: Vec<Vec<u8>> = meta
            .blocks
            .iter()
            .map(|h| block_of(&data, h).entries().next().unwrap().key.to_vec())
            .collect();
        for (i, f) in firsts.iter().enumerate() {
            assert_eq!(meta.block_first_key(i).to_vec(), *f, "index key {i}");
        }
        // Present keys, absent gap keys, and off-by-one probes must all
        // select the same block as a full-first-key partition would.
        let mut probes: Vec<Vec<u8>> = es.iter().map(|e| e.key.to_vec()).collect();
        for i in 0..400u64 {
            probes.push(format!("user{:08}x", i).into_bytes());
            probes.push(format!("user{:07}", i).into_bytes());
        }
        for p in &probes {
            let want = if meta.blocks.is_empty()
                || p.as_slice() < meta.smallest.as_slice()
                || p.as_slice() > meta.largest.as_slice()
            {
                None
            } else {
                match firsts.partition_point(|f| f.as_slice() <= p.as_slice()) {
                    0 => None,
                    i => Some(i - 1),
                }
            };
            assert_eq!(meta.find_block(p), want, "probe {:?}", String::from_utf8_lossy(p));
        }
    }

    #[test]
    fn smallest_largest_and_overlap() {
        let es = entries(100);
        let (meta, _) = build_sst(&es, 1, 2, 4096, 10, 0);
        assert_eq!(meta.smallest.as_slice(), b"user00000000");
        assert_eq!(meta.largest.as_slice(), b"user00000099");
        assert!(meta.overlaps(b"user00000050", b"user00000060"));
        assert!(meta.overlaps(b"user", b"user00000000"));
        assert!(!meta.overlaps(b"v", b"w"));
    }

    #[test]
    fn decode_block_roundtrip() {
        let es = entries(50);
        let (meta, data) = build_sst(&es, 1, 0, 100_000_000, 10, 0);
        assert_eq!(meta.blocks.len(), 1);
        let block = block_of(&data, &meta.blocks[0]);
        assert_eq!(decode_block(&block), es);
    }

    #[test]
    fn sst_files_dehydrate_and_rehydrate_bit_identically() {
        let es = entries(300);
        let (meta, data) = build_sst(&es, 1, 0, 2048, 10, 0);
        let data_bytes: u64 = meta.blocks.iter().map(|b| b.len as u64).sum();
        // The index/bloom reservation is a weightless pad that stops
        // decoding — not zeros that read as bogus empty entries.
        let pad = data.slice_to_buf(data_bytes, meta.file_size - data_bytes);
        assert_eq!(pad.phys_len(), 0);
        assert_eq!(pad.entries().count(), 0);
        // Dehydrating the whole file elides every entry head; every block
        // sliced out of the paged file hydrates to exactly the block
        // sliced from the resident file.
        let paged = data.dehydrate_copy().expect("user keys elide");
        assert_eq!(paged.len(), data.len());
        assert!(paged.phys_len() < data.phys_len());
        for h in &meta.blocks {
            let mut b = paged.slice_to_buf(h.offset, h.len as u64);
            b.hydrate();
            assert_eq!(b, block_of(&data, h));
        }
        // Point lookups over hydrated blocks behave identically.
        for e in es.iter().step_by(7) {
            let h = &meta.blocks[meta.find_block(&e.key).unwrap()];
            let mut block = paged.slice_to_buf(h.offset, h.len as u64);
            block.hydrate();
            assert_eq!(search_block(&block, &e.key).unwrap().to_entry(), *e);
        }
    }

    #[test]
    fn bloom_rejects_most_absent_keys() {
        let es = entries(1000);
        let (meta, _) = build_sst(&es, 1, 0, 4096, 10, 0);
        let mut rejected = 0;
        for i in 0..1000u64 {
            let probe = format!("other{i:08}");
            if !meta.bloom.may_contain(crate::sim::rng::fingerprint32(probe.as_bytes())) {
                rejected += 1;
            }
        }
        assert!(rejected > 950, "rejected={rejected}");
    }
}
