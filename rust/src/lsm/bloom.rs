//! Per-SST Bloom filter (§2.2) using double hashing over 32-bit key
//! fingerprints.
//!
//! The hash scheme is shared bit-for-bit with the Pallas kernel in
//! `python/compile/kernels/bloom.py`: `h1 = fp * 0x9E3779B1`,
//! `h2 = fp * 0x85EBCA77 | 1`, probe `j` at `(h1 + j*h2) mod nbits`
//! (all u32 wrap-around arithmetic). The XLA-backed prober in
//! [`crate::runtime`] must agree with this implementation exactly — that
//! parity is asserted by integration tests and the pytest oracle.

pub const H1_MUL: u32 = 0x9E3779B1;
pub const H2_MUL: u32 = 0x85EBCA77;

#[derive(Clone, Debug)]
pub struct Bloom {
    words: Vec<u32>,
    nbits: u32,
    k: u32,
}

impl Bloom {
    /// Number of probes for a given bits-per-key budget (ln2 * b, clamped).
    pub fn probes_for(bits_per_key: u32) -> u32 {
        ((bits_per_key as f64 * 0.69) as u32).clamp(1, 30)
    }

    /// Build a filter over the given key fingerprints.
    pub fn build(fps: &[u32], bits_per_key: u32) -> Self {
        let nbits = ((fps.len() as u64 * bits_per_key as u64).max(64)) as u32;
        // Round up to a whole number of 32-bit words.
        let nwords = nbits.div_ceil(32);
        let nbits = nwords * 32;
        let k = Self::probes_for(bits_per_key);
        let mut b = Bloom { words: vec![0u32; nwords as usize], nbits, k };
        for &fp in fps {
            let h1 = fp.wrapping_mul(H1_MUL);
            let h2 = fp.wrapping_mul(H2_MUL) | 1;
            for j in 0..k {
                let pos = h1.wrapping_add(j.wrapping_mul(h2)) % nbits;
                b.words[(pos / 32) as usize] |= 1 << (pos % 32);
            }
        }
        b
    }

    /// The k probe positions for a fingerprint (shared with the kernel).
    #[inline]
    pub fn positions(&self, fp: u32) -> impl Iterator<Item = u32> + '_ {
        let h1 = fp.wrapping_mul(H1_MUL);
        let h2 = fp.wrapping_mul(H2_MUL) | 1;
        let nbits = self.nbits;
        (0..self.k).map(move |j| h1.wrapping_add(j.wrapping_mul(h2)) % nbits)
    }

    #[inline]
    pub fn may_contain(&self, fp: u32) -> bool {
        for pos in self.positions(fp) {
            if self.words[(pos / 32) as usize] & (1 << (pos % 32)) == 0 {
                return false;
            }
        }
        true
    }

    pub fn nbits(&self) -> u32 {
        self.nbits
    }
    pub fn k(&self) -> u32 {
        self.k
    }
    pub fn words(&self) -> &[u32] {
        &self.words
    }
    /// Serialized size in bytes (counted into the SST file size).
    pub fn byte_len(&self) -> usize {
        self.words.len() * 4 + 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::fingerprint32;

    fn fps(n: u64, salt: u64) -> Vec<u32> {
        (0..n).map(|i| fingerprint32(&(i * 2 + salt).to_be_bytes())).collect()
    }

    #[test]
    fn no_false_negatives() {
        let keys = fps(4000, 0);
        let b = Bloom::build(&keys, 10);
        for &fp in &keys {
            assert!(b.may_contain(fp));
        }
    }

    #[test]
    fn false_positive_rate_reasonable() {
        let keys = fps(4000, 0);
        let b = Bloom::build(&keys, 10);
        // Probe keys disjoint from the build set (odd salt).
        let probes = fps(20_000, 1);
        let fp_hits = probes.iter().filter(|&&f| b.may_contain(f)).count();
        let rate = fp_hits as f64 / probes.len() as f64;
        // 10 bits/key, 6 probes → theoretical ~0.9%; allow < 3%.
        assert!(rate < 0.03, "fp rate = {rate}");
    }

    #[test]
    fn empty_filter_has_min_size() {
        let b = Bloom::build(&[], 10);
        assert!(b.nbits() >= 64);
        assert!(!b.may_contain(12345));
    }

    #[test]
    fn k_matches_bits_per_key() {
        assert_eq!(Bloom::probes_for(10), 6);
        assert_eq!(Bloom::probes_for(1), 1);
    }

    #[test]
    fn positions_deterministic_and_in_range() {
        let b = Bloom::build(&fps(100, 0), 10);
        let p1: Vec<u32> = b.positions(777).collect();
        let p2: Vec<u32> = b.positions(777).collect();
        assert_eq!(p1, p2);
        assert_eq!(p1.len(), b.k() as usize);
        assert!(p1.iter().all(|&p| p < b.nbits()));
    }

    #[test]
    fn nbits_word_aligned() {
        let b = Bloom::build(&fps(123, 0), 10);
        assert_eq!(b.nbits() % 32, 0);
        assert_eq!(b.words().len() as u32 * 32, b.nbits());
    }
}
