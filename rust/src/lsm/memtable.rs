//! In-memory write buffer (§2.2). A `MemTable` accumulates puts/deletes
//! until it reaches the configured size, becomes immutable, and is flushed
//! to an L0 SSTable by a background job.
//!
//! Values are synthetic [`Payload`]s; the byte budget charges their
//! *logical* length, so seal/flush timing is identical to a memtable
//! holding real bytes.

use std::collections::BTreeMap;

use super::{Entry, Key, Payload};

/// Per-entry bookkeeping overhead charged against the memtable budget
/// (rough skiplist-node equivalent).
const ENTRY_OVERHEAD: usize = 48;

#[derive(Default, Clone)]
pub struct MemTable {
    map: BTreeMap<Key, (u64, Option<Payload>)>,
    approx_bytes: usize,
    /// Bytes of WAL records backing this memtable (for WAL accounting).
    pub wal_bytes: u64,
}

impl MemTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a put or delete. Returns the net byte growth.
    pub fn insert(&mut self, key: Key, seq: u64, value: Option<Payload>) -> usize {
        let add = key.len() + value.map_or(0, |p| p.len as usize) + ENTRY_OVERHEAD;
        let old = self.map.insert(key, (seq, value));
        let sub = old.map_or(0, |(_, v)| v.map_or(0, |p| p.len as usize));
        self.approx_bytes += add;
        self.approx_bytes = self.approx_bytes.saturating_sub(sub);
        add
    }

    /// Point lookup. `Some(None)` means "deleted here" (tombstone).
    pub fn get(&self, key: &[u8]) -> Option<Option<Payload>> {
        self.map.get(key).map(|(_, v)| *v)
    }

    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Drain into sorted entries for flushing.
    pub fn into_entries(self) -> Vec<Entry> {
        self.map
            .into_iter()
            .map(|(key, (seq, value))| Entry { key, seq, value })
            .collect()
    }

    /// Range scan within the memtable (used by the merged scan path).
    pub fn range(&self, from: &[u8], limit: usize) -> Vec<(&Key, u64, Option<Payload>)> {
        self.map
            .range(from.to_vec()..)
            .take(limit)
            .map(|(k, (s, v))| (k, *s, *v))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(bytes: &[u8]) -> Payload {
        Payload::from_bytes(bytes)
    }

    #[test]
    fn put_get() {
        let mut m = MemTable::new();
        m.insert(b"a".to_vec(), 1, Some(p(b"va")));
        assert_eq!(m.get(b"a"), Some(Some(p(b"va"))));
        assert_eq!(m.get(b"b"), None);
    }

    #[test]
    fn newer_overwrites() {
        let mut m = MemTable::new();
        m.insert(b"k".to_vec(), 1, Some(p(b"v1")));
        m.insert(b"k".to_vec(), 2, Some(p(b"v2")));
        assert_eq!(m.get(b"k"), Some(Some(p(b"v2"))));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn tombstone_visible() {
        let mut m = MemTable::new();
        m.insert(b"k".to_vec(), 1, Some(p(b"v")));
        m.insert(b"k".to_vec(), 2, None);
        assert_eq!(m.get(b"k"), Some(None));
    }

    #[test]
    fn size_grows_with_inserts() {
        let mut m = MemTable::new();
        let before = m.approx_bytes();
        for i in 0..100u32 {
            m.insert(i.to_be_bytes().to_vec(), i as u64, Some(Payload::fill(0, 100)));
        }
        assert!(m.approx_bytes() > before + 100 * 100);
    }

    #[test]
    fn into_entries_sorted() {
        let mut m = MemTable::new();
        for k in [b"c".to_vec(), b"a".to_vec(), b"b".to_vec()] {
            m.insert(k, 1, Some(p(b"v")));
        }
        let es = m.into_entries();
        let keys: Vec<&[u8]> = es.iter().map(|e| e.key.as_slice()).collect();
        assert_eq!(keys, vec![b"a".as_slice(), b"b", b"c"]);
    }

    #[test]
    fn range_scan() {
        let mut m = MemTable::new();
        for i in 0..10u8 {
            m.insert(vec![i], 1, Some(Payload::fill(i, 1)));
        }
        let r = m.range(&[5], 3);
        assert_eq!(r.len(), 3);
        assert_eq!(r[0].0, &vec![5u8]);
        assert_eq!(r[2].0, &vec![7u8]);
    }
}
