//! In-memory write buffer (§2.2). A `MemTable` accumulates puts/deletes
//! until it reaches the configured size, becomes immutable, and is flushed
//! to an L0 SSTable by a background job.
//!
//! Values are synthetic [`Payload`]s and keys are interned [`Key`]s; the
//! byte budget charges the values' *logical* length plus each resident
//! key's bytes and arena bookkeeping ([`KEY_OVERHEAD`]), so seal/flush
//! timing matches a memtable holding real bytes. Accounting is
//! *symmetric*: an overwrite charges only the value-length delta — the
//! replaced version's key bytes and node overhead are not re-charged (the
//! seed double-charged them and never credited the replaced key, so
//! `approx_bytes` drifted high under update-heavy YCSB-A).

use std::collections::BTreeMap;

use super::key::KEY_OVERHEAD;
use super::{Entry, Key, Payload};

/// Per-entry bookkeeping overhead charged against the memtable budget
/// (rough skiplist-node equivalent).
const ENTRY_OVERHEAD: usize = 48;

#[derive(Default, Clone)]
pub struct MemTable {
    map: BTreeMap<Key, (u64, Option<Payload>)>,
    approx_bytes: usize,
    /// Bytes of WAL records backing this memtable (for WAL accounting).
    pub wal_bytes: u64,
}

impl MemTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a put or delete; `approx_bytes` moves by the exact budget
    /// delta (callers read [`MemTable::approx_bytes`] for seal decisions).
    pub fn insert(&mut self, key: Key, seq: u64, value: Option<Payload>) {
        let klen = key.len();
        let vlen = value.map_or(0, |p| p.len as usize);
        match self.map.insert(key, (seq, value)) {
            None => {
                // New key: charge key bytes + arena bookkeeping + node
                // overhead + value bytes.
                self.approx_bytes += klen + KEY_OVERHEAD + ENTRY_OVERHEAD + vlen;
            }
            Some((_, old)) => {
                // Overwrite: the key, its arena slot, and the node are
                // reused — only the value length moves.
                let sub = old.map_or(0, |p| p.len as usize);
                self.approx_bytes += vlen;
                self.approx_bytes = self.approx_bytes.saturating_sub(sub);
            }
        }
    }

    /// Point lookup. `Some(None)` means "deleted here" (tombstone).
    pub fn get(&self, key: &[u8]) -> Option<Option<Payload>> {
        self.map.get(key).map(|(_, v)| *v)
    }

    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Drain into sorted entries for flushing (key refs move, no copies).
    pub fn into_entries(self) -> Vec<Entry> {
        self.map
            .into_iter()
            .map(|(key, (seq, value))| Entry { key, seq, value })
            .collect()
    }

    /// Range scan within the memtable (used by the merged scan path).
    pub fn range(&self, from: &[u8], limit: usize) -> Vec<(&Key, u64, Option<Payload>)> {
        self.map
            .range::<[u8], _>(from..)
            .take(limit)
            .map(|(k, (s, v))| (k, *s, *v))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(bytes: &[u8]) -> Payload {
        Payload::from_bytes(bytes)
    }

    fn k(bytes: &[u8]) -> Key {
        Key::new(bytes)
    }

    #[test]
    fn put_get() {
        let mut m = MemTable::new();
        m.insert(k(b"a"), 1, Some(p(b"va")));
        assert_eq!(m.get(b"a"), Some(Some(p(b"va"))));
        assert_eq!(m.get(b"b"), None);
    }

    #[test]
    fn newer_overwrites() {
        let mut m = MemTable::new();
        m.insert(k(b"k"), 1, Some(p(b"v1")));
        m.insert(k(b"k"), 2, Some(p(b"v2")));
        assert_eq!(m.get(b"k"), Some(Some(p(b"v2"))));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn tombstone_visible() {
        let mut m = MemTable::new();
        m.insert(k(b"k"), 1, Some(p(b"v")));
        m.insert(k(b"k"), 2, None);
        assert_eq!(m.get(b"k"), Some(None));
    }

    #[test]
    fn size_grows_with_inserts() {
        let mut m = MemTable::new();
        let before = m.approx_bytes();
        for i in 0..100u32 {
            m.insert(i.to_be_bytes().to_vec().into(), i as u64, Some(Payload::fill(0, 100)));
        }
        assert!(m.approx_bytes() > before + 100 * 100);
    }

    #[test]
    fn overwrite_accounting_is_symmetric() {
        // Regression (seed bug): every overwrite re-charged the key bytes
        // and node overhead but credited only the replaced payload, so
        // `approx_bytes` drifted up by `klen + overhead` per update and
        // update-heavy workloads sealed memtables early.
        let mut m = MemTable::new();
        let key = b"user00000000000000000007";
        m.insert(k(key), 1, Some(Payload::fill(1, 500)));
        let one = m.approx_bytes();
        assert_eq!(one, key.len() + KEY_OVERHEAD + 48 + 500);
        for seq in 2..200u64 {
            m.insert(k(key), seq, Some(Payload::fill(seq as u8, 500)));
        }
        assert_eq!(m.approx_bytes(), one, "overwrites must not leak budget");
        // Value growth/shrink moves the budget by exactly the delta.
        m.insert(k(key), 200, Some(Payload::fill(0, 700)));
        assert_eq!(m.approx_bytes(), one + 200);
        m.insert(k(key), 201, Some(Payload::fill(0, 100)));
        assert_eq!(m.approx_bytes(), one - 400);
        // Tombstone overwrite credits the payload.
        m.insert(k(key), 202, None);
        assert_eq!(m.approx_bytes(), one - 500);
    }

    #[test]
    fn into_entries_sorted() {
        let mut m = MemTable::new();
        for key in [b"c", b"a", b"b"] {
            m.insert(k(key), 1, Some(p(b"v")));
        }
        let es = m.into_entries();
        let keys: Vec<&[u8]> = es.iter().map(|e| e.key.as_slice()).collect();
        assert_eq!(keys, vec![b"a".as_slice(), b"b", b"c"]);
    }

    #[test]
    fn range_scan() {
        let mut m = MemTable::new();
        for i in 0..10u8 {
            m.insert(k(&[i]), 1, Some(Payload::fill(i, 1)));
        }
        let r = m.range(&[5], 3);
        assert_eq!(r.len(), 3);
        assert_eq!(r[0].0.as_slice(), &[5u8]);
        assert_eq!(r[2].0.as_slice(), &[7u8]);
    }
}
