//! In-memory write buffer (§2.2). A `MemTable` accumulates puts/deletes
//! until it reaches the configured size, becomes immutable, and is flushed
//! to an L0 SSTable by a background job.

use std::collections::BTreeMap;

use super::{Entry, Key};

/// Per-entry bookkeeping overhead charged against the memtable budget
/// (rough skiplist-node equivalent).
const ENTRY_OVERHEAD: usize = 48;

#[derive(Default, Clone)]
pub struct MemTable {
    map: BTreeMap<Key, (u64, Option<Vec<u8>>)>,
    approx_bytes: usize,
    /// Bytes of WAL records backing this memtable (for WAL accounting).
    pub wal_bytes: u64,
}

impl MemTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a put or delete. Returns the net byte growth.
    pub fn insert(&mut self, key: Key, seq: u64, value: Option<Vec<u8>>) -> usize {
        let add = key.len() + value.as_ref().map_or(0, |v| v.len()) + ENTRY_OVERHEAD;
        let old = self.map.insert(key, (seq, value));
        let sub = old.map_or(0, |(_, v)| v.as_ref().map_or(0, |v| v.len()));
        self.approx_bytes += add;
        self.approx_bytes = self.approx_bytes.saturating_sub(sub);
        add
    }

    /// Point lookup. `Some(None)` means "deleted here" (tombstone).
    pub fn get(&self, key: &[u8]) -> Option<Option<&Vec<u8>>> {
        self.map.get(key).map(|(_, v)| v.as_ref())
    }

    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Drain into sorted entries for flushing.
    pub fn into_entries(self) -> Vec<Entry> {
        self.map
            .into_iter()
            .map(|(key, (seq, value))| Entry { key, seq, value })
            .collect()
    }

    /// Range scan within the memtable (used by the merged scan path).
    pub fn range(&self, from: &[u8], limit: usize) -> Vec<(&Key, u64, Option<&Vec<u8>>)> {
        self.map
            .range(from.to_vec()..)
            .take(limit)
            .map(|(k, (s, v))| (k, *s, v.as_ref()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get() {
        let mut m = MemTable::new();
        m.insert(b"a".to_vec(), 1, Some(b"va".to_vec()));
        assert_eq!(m.get(b"a"), Some(Some(&b"va".to_vec())));
        assert_eq!(m.get(b"b"), None);
    }

    #[test]
    fn newer_overwrites() {
        let mut m = MemTable::new();
        m.insert(b"k".to_vec(), 1, Some(b"v1".to_vec()));
        m.insert(b"k".to_vec(), 2, Some(b"v2".to_vec()));
        assert_eq!(m.get(b"k"), Some(Some(&b"v2".to_vec())));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn tombstone_visible() {
        let mut m = MemTable::new();
        m.insert(b"k".to_vec(), 1, Some(b"v".to_vec()));
        m.insert(b"k".to_vec(), 2, None);
        assert_eq!(m.get(b"k"), Some(None));
    }

    #[test]
    fn size_grows_with_inserts() {
        let mut m = MemTable::new();
        let before = m.approx_bytes();
        for i in 0..100u32 {
            m.insert(i.to_be_bytes().to_vec(), i as u64, Some(vec![0u8; 100]));
        }
        assert!(m.approx_bytes() > before + 100 * 100);
    }

    #[test]
    fn into_entries_sorted() {
        let mut m = MemTable::new();
        for k in [b"c".to_vec(), b"a".to_vec(), b"b".to_vec()] {
            m.insert(k, 1, Some(b"v".to_vec()));
        }
        let es = m.into_entries();
        let keys: Vec<&[u8]> = es.iter().map(|e| e.key.as_slice()).collect();
        assert_eq!(keys, vec![b"a".as_slice(), b"b", b"c"]);
    }

    #[test]
    fn range_scan() {
        let mut m = MemTable::new();
        for i in 0..10u8 {
            m.insert(vec![i], 1, Some(vec![i]));
        }
        let r = m.range(&[5], 3);
        assert_eq!(r.len(), 3);
        assert_eq!(r[0].0, &vec![5u8]);
        assert_eq!(r[2].0, &vec![7u8]);
    }
}
