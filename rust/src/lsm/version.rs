//! The version set: which SSTs live at which level, target sizes, and
//! compaction picking (§2.2).
//!
//! L0 files may overlap and are searched newest-first; L1+ files are
//! key-disjoint and sorted, searched by binary partition. Target sizes
//! follow RocksDB defaults: `target(L_i) = target(L1) * m^(i-1)` with the
//! paper's §4.1 values (L0 = L1 = 1 GiB-scaled, m = 10).

use std::sync::Arc;

use super::{Key, SstId, SstMeta};

/// A picked compaction: inputs from `level`, overlapping inputs from
/// `level + 1`, outputs go to `level + 1`.
#[derive(Clone, Debug)]
pub struct CompactionPick {
    pub level: usize,
    pub inputs_lo: Vec<Arc<SstMeta>>,
    pub inputs_hi: Vec<Arc<SstMeta>>,
}

impl CompactionPick {
    pub fn output_level(&self) -> usize {
        self.level + 1
    }
    pub fn all_inputs(&self) -> impl Iterator<Item = &Arc<SstMeta>> {
        self.inputs_lo.iter().chain(self.inputs_hi.iter())
    }
    pub fn input_ids(&self) -> Vec<SstId> {
        self.all_inputs().map(|m| m.id).collect()
    }
    pub fn input_bytes(&self) -> u64 {
        self.all_inputs().map(|m| m.file_size).sum()
    }
}

pub struct Version {
    /// levels[0] is L0 in flush order (oldest first; search newest-first).
    /// levels[i>=1] sorted by smallest key, disjoint ranges.
    levels: Vec<Vec<Arc<SstMeta>>>,
    l0_target: u64,
    level_multiplier: u64,
    l0_compaction_trigger: usize,
    /// Round-robin compaction cursor per level (RocksDB-style). Interned
    /// keys: advancing the cursor shares the picked SST's `largest`
    /// allocation instead of copying it.
    cursors: Vec<Key>,
}

impl Version {
    pub fn new(num_levels: usize, l0_target: u64, level_multiplier: u64, l0_trigger: usize) -> Self {
        Version {
            levels: vec![Vec::new(); num_levels],
            l0_target,
            level_multiplier,
            l0_compaction_trigger: l0_trigger,
            cursors: vec![Key::default(); num_levels],
        }
    }

    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    pub fn level(&self, i: usize) -> &[Arc<SstMeta>] {
        &self.levels[i]
    }

    pub fn level_bytes(&self, i: usize) -> u64 {
        self.levels[i].iter().map(|m| m.file_size).sum()
    }

    pub fn total_ssts(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    pub fn all_ssts(&self) -> impl Iterator<Item = &Arc<SstMeta>> {
        self.levels.iter().flatten()
    }

    /// Target size of level `i` (§4.1: L0 = L1 = base; L_{i+1} = 10 × L_i).
    pub fn target_bytes(&self, i: usize) -> u64 {
        match i {
            0 | 1 => self.l0_target,
            _ => self.l0_target * self.level_multiplier.pow(i as u32 - 1),
        }
    }

    /// Insert a flushed SST at L0.
    pub fn add_l0(&mut self, sst: Arc<SstMeta>) {
        debug_assert_eq!(sst.level, 0);
        self.levels[0].push(sst);
    }

    /// Remove one L0 SST by id (crash unwind of a flush that installed
    /// outputs but never committed: the file is deleted from zenfs and its
    /// version entry must go with it). Returns true when it was present.
    pub fn remove_l0(&mut self, id: SstId) -> bool {
        let before = self.levels[0].len();
        self.levels[0].retain(|m| m.id != id);
        self.levels[0].len() != before
    }

    /// Install compaction outputs and remove inputs atomically.
    ///
    /// Input removal is a set lookup per SST (not a scan of the id slice),
    /// and the sorted output level is rebuilt by a single merge pass with
    /// the (key-ascending) outputs instead of a full re-sort.
    pub fn apply_compaction(
        &mut self,
        level: usize,
        input_ids: &[SstId],
        mut outputs: Vec<Arc<SstMeta>>,
    ) {
        let out_level = level + 1;
        let ids: std::collections::HashSet<SstId> = input_ids.iter().copied().collect();
        self.levels[level].retain(|m| !ids.contains(&m.id));
        self.levels[out_level].retain(|m| !ids.contains(&m.id));
        if outputs.is_empty() {
            debug_assert!(self.disjoint(out_level));
            return;
        }
        debug_assert!(outputs.iter().all(|o| o.level == out_level));
        // Compaction emits outputs in ascending key order already; sorting
        // here only guards direct callers (tests) that pass arbitrary sets.
        outputs.sort_by(|a, b| a.smallest.cmp(&b.smallest));
        let existing = std::mem::take(&mut self.levels[out_level]);
        let mut merged = Vec::with_capacity(existing.len() + outputs.len());
        let mut it_e = existing.into_iter().peekable();
        let mut it_o = outputs.into_iter().peekable();
        loop {
            match (it_e.peek(), it_o.peek()) {
                (Some(e), Some(o)) => {
                    // On equal keys keep the existing file first (what the
                    // seed's stable sort of appended outputs produced).
                    if e.smallest <= o.smallest {
                        merged.push(it_e.next().unwrap());
                    } else {
                        merged.push(it_o.next().unwrap());
                    }
                }
                (Some(_), None) => merged.push(it_e.next().unwrap()),
                (None, Some(_)) => merged.push(it_o.next().unwrap()),
                (None, None) => break,
            }
        }
        self.levels[out_level] = merged;
        debug_assert!(self.disjoint(out_level));
    }

    /// Check the disjointness invariant of a level (test/debug helper).
    pub fn disjoint(&self, level: usize) -> bool {
        if level == 0 {
            return true;
        }
        self.levels[level].windows(2).all(|w| w[0].largest < w[1].smallest)
    }

    /// Candidate SSTs for a point lookup, in search order: all overlapping
    /// L0 files newest-first, then ≤1 file per deeper level.
    pub fn candidates_for(&self, key: &[u8]) -> Vec<Arc<SstMeta>> {
        let mut out = Vec::new();
        for m in self.levels[0].iter().rev() {
            if m.smallest.as_slice() <= key && key <= m.largest.as_slice() {
                out.push(m.clone());
            }
        }
        for lvl in self.levels.iter().skip(1) {
            let i = lvl.partition_point(|m| m.largest.as_slice() < key);
            if i < lvl.len() && lvl[i].smallest.as_slice() <= key {
                out.push(lvl[i].clone());
            }
        }
        out
    }

    /// SSTs at `level` overlapping `[lo, hi]`.
    pub fn overlapping(&self, level: usize, lo: &[u8], hi: &[u8]) -> Vec<Arc<SstMeta>> {
        self.levels[level].iter().filter(|m| m.overlaps(lo, hi)).cloned().collect()
    }

    /// Compaction score of a level (>1.0 ⇒ wants compaction).
    pub fn score(&self, level: usize) -> f64 {
        if level == 0 {
            self.levels[0].len() as f64 / self.l0_compaction_trigger as f64
        } else {
            self.level_bytes(level) as f64 / self.target_bytes(level) as f64
        }
    }

    /// Pick the highest-score compaction, excluding SSTs in `busy` (already
    /// being compacted) and levels in `busy_levels`. Commits the
    /// round-robin cursor (see [`Version::select_compaction`] for the
    /// read-only selection).
    pub fn pick_compaction(
        &mut self,
        busy: &dyn Fn(SstId) -> bool,
        busy_level: &dyn Fn(usize) -> bool,
    ) -> Option<CompactionPick> {
        let pick = self.select_compaction(busy, busy_level)?;
        if pick.level > 0 {
            // Commit the round-robin cursor only once the pick is actually
            // returned: an abandoned pick (busy L+1 inputs) must retry the
            // same file on the next attempt, not skip it until the cursor
            // wraps.
            self.cursors[pick.level] = pick.inputs_lo[0].largest.clone();
        }
        Some(pick)
    }

    /// Would [`Version::pick_compaction`] return a pick right now? Pure
    /// probe — no cursor commit — used by the scheduler to detect (and
    /// meter) compactions starved of a CPU slot without perturbing the
    /// round-robin state.
    pub fn compaction_ready(
        &self,
        busy: &dyn Fn(SstId) -> bool,
        busy_level: &dyn Fn(usize) -> bool,
    ) -> bool {
        self.select_compaction(busy, busy_level).is_some()
    }

    /// The selection body of [`Version::pick_compaction`], side-effect
    /// free: what would be compacted, with the cursor untouched.
    fn select_compaction(
        &self,
        busy: &dyn Fn(SstId) -> bool,
        busy_level: &dyn Fn(usize) -> bool,
    ) -> Option<CompactionPick> {
        let last = self.levels.len() - 1;
        let mut best: Option<(f64, usize)> = None;
        for lvl in 0..last {
            if busy_level(lvl) || busy_level(lvl + 1) {
                continue;
            }
            let s = self.score(lvl);
            if s >= 1.0 && best.map_or(true, |(bs, _)| s > bs) {
                best = Some((s, lvl));
            }
        }
        let (_, level) = best?;
        if level == 0 {
            // Compact every L0 file (RocksDB merges all of L0 at once).
            let inputs_lo: Vec<_> = self.levels[0].iter().cloned().collect();
            if inputs_lo.is_empty() || inputs_lo.iter().any(|m| busy(m.id)) {
                return None;
            }
            let lo = inputs_lo.iter().map(|m| m.smallest.clone()).min().unwrap();
            let hi = inputs_lo.iter().map(|m| m.largest.clone()).max().unwrap();
            let inputs_hi = self.overlapping(1, &lo, &hi);
            if inputs_hi.iter().any(|m| busy(m.id)) {
                return None;
            }
            return Some(CompactionPick { level: 0, inputs_lo, inputs_hi });
        }
        // Round-robin pick: first file with smallest > cursor, else first.
        let files = &self.levels[level];
        if files.is_empty() {
            return None;
        }
        let cursor = &self.cursors[level];
        let start = files.partition_point(|m| m.smallest.as_slice() <= cursor.as_slice());
        let pick = files.get(start).or_else(|| files.first())?.clone();
        if busy(pick.id) {
            return None;
        }
        let inputs_hi = self.overlapping(level + 1, &pick.smallest, &pick.largest);
        if inputs_hi.iter().any(|m| busy(m.id)) {
            return None;
        }
        Some(CompactionPick { level, inputs_lo: vec![pick], inputs_hi })
    }

    /// Find an SST anywhere by id.
    pub fn find(&self, id: SstId) -> Option<Arc<SstMeta>> {
        self.all_ssts().find(|m| m.id == id).cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::Entry;
    use crate::lsm::sst::build_sst;

    fn sst(id: SstId, level: usize, lo: u64, hi: u64) -> Arc<SstMeta> {
        let entries: Vec<Entry> = (lo..=hi)
            .map(|i| Entry {
                key: format!("user{i:08}").into_bytes().into(),
                seq: id * 1000 + i,
                value: Some(crate::lsm::Payload::fill(0, 16)),
            })
            .collect();
        let (mut meta, _) = build_sst(&entries, id, level, 4096, 10, 0);
        Arc::get_mut(&mut Arc::clone(&meta)); // no-op, meta is fresh
        let mut m = (*meta).clone();
        m.level = level;
        Arc::new(m)
    }

    fn version() -> Version {
        Version::new(7, 1 << 20, 10, 4)
    }

    #[test]
    fn target_sizes_exponential() {
        let v = version();
        assert_eq!(v.target_bytes(0), 1 << 20);
        assert_eq!(v.target_bytes(1), 1 << 20);
        assert_eq!(v.target_bytes(2), 10 << 20);
        assert_eq!(v.target_bytes(3), 100 << 20);
    }

    #[test]
    fn l0_candidates_newest_first() {
        let mut v = version();
        v.add_l0(sst(1, 0, 0, 100));
        v.add_l0(sst(2, 0, 50, 150));
        let c = v.candidates_for(b"user00000060");
        assert_eq!(c[0].id, 2, "newest L0 first");
        assert_eq!(c[1].id, 1);
    }

    #[test]
    fn deeper_levels_binary_search() {
        let mut v = version();
        v.apply_compaction(0, &[], vec![sst(10, 1, 0, 99), sst(11, 1, 200, 299)]);
        let c = v.candidates_for(b"user00000250");
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].id, 11);
        // Key in the gap between files → no candidate.
        assert!(v.candidates_for(b"user00000150").is_empty());
    }

    #[test]
    fn l0_score_counts_files() {
        let mut v = version();
        for i in 0..4 {
            v.add_l0(sst(i, 0, i * 10, i * 10 + 5));
        }
        assert!(v.score(0) >= 1.0);
    }

    #[test]
    fn pick_l0_takes_all_l0_and_overlap() {
        let mut v = version();
        for i in 0..4 {
            v.add_l0(sst(i + 1, 0, 0, 100));
        }
        v.apply_compaction(0, &[], vec![sst(10, 1, 0, 50), sst(11, 1, 200, 250)]);
        let p = v.pick_compaction(&|_| false, &|_| false).unwrap();
        assert_eq!(p.level, 0);
        assert_eq!(p.inputs_lo.len(), 4);
        // Only the overlapping L1 file joins.
        assert_eq!(p.inputs_hi.len(), 1);
        assert_eq!(p.inputs_hi[0].id, 10);
    }

    #[test]
    fn apply_compaction_removes_inputs_adds_outputs() {
        let mut v = version();
        for i in 0..4 {
            v.add_l0(sst(i + 1, 0, 0, 100));
        }
        let p = v.pick_compaction(&|_| false, &|_| false).unwrap();
        let ids = p.input_ids();
        v.apply_compaction(0, &ids, vec![sst(20, 1, 0, 100)]);
        assert_eq!(v.level(0).len(), 0);
        assert_eq!(v.level(1).len(), 1);
        assert_eq!(v.level(1)[0].id, 20);
        assert!(v.disjoint(1));
    }

    #[test]
    fn busy_inputs_block_pick() {
        let mut v = version();
        for i in 0..4 {
            v.add_l0(sst(i + 1, 0, 0, 100));
        }
        assert!(v.pick_compaction(&|id| id == 2, &|_| false).is_none());
        assert!(v.pick_compaction(&|_| false, &|l| l == 1).is_none());
        assert!(v.pick_compaction(&|_| false, &|_| false).is_some());
    }

    #[test]
    fn round_robin_cursor_advances() {
        let mut v = version();
        // Two oversized L1 files (target 1 MiB; each file has big values).
        let big: Vec<Entry> = (0..3000u64)
            .map(|i| Entry {
                key: format!("user{i:08}").into_bytes().into(),
                seq: i,
                value: Some(crate::lsm::Payload::fill(0, 400)),
            })
            .collect();
        let (m1, _) = build_sst(&big[..1500], 1, 1, 4096, 10, 0);
        let (m2, _) = build_sst(&big[1500..], 2, 1, 4096, 10, 0);
        v.apply_compaction(0, &[], vec![m1, m2]);
        assert!(v.score(1) >= 1.0);
        let p1 = v.pick_compaction(&|_| false, &|_| false).unwrap();
        let first = p1.inputs_lo[0].id;
        let p2 = v.pick_compaction(&|_| false, &|_| false).unwrap();
        assert_ne!(p2.inputs_lo[0].id, first, "cursor should advance");
    }

    #[test]
    fn abandoned_pick_does_not_advance_the_cursor() {
        // Regression: the round-robin cursor used to advance BEFORE the
        // `inputs_hi` busy check, so a pick abandoned because its L+1
        // input was mid-compaction skipped that file until the cursor
        // wrapped. An abandoned pick must retry the same file.
        let mut v = version();
        let big: Vec<Entry> = (0..3000u64)
            .map(|i| Entry {
                key: format!("user{i:08}").into_bytes().into(),
                seq: i,
                value: Some(crate::lsm::Payload::fill(0, 400)),
            })
            .collect();
        let (m1, _) = build_sst(&big[..1500], 1, 1, 4096, 10, 0);
        let (m2, _) = build_sst(&big[1500..], 2, 1, 4096, 10, 0);
        v.apply_compaction(0, &[], vec![m1, m2]);
        assert!(v.score(1) >= 1.0);
        // An L2 file overlapping file 1's range, currently busy.
        let l2: Vec<Entry> = (0..1000u64)
            .map(|i| Entry {
                key: format!("user{i:08}").into_bytes().into(),
                seq: 10_000 + i,
                value: Some(crate::lsm::Payload::fill(0, 16)),
            })
            .collect();
        let (l2_sst, _) = build_sst(&l2, 30, 2, 4096, 10, 0);
        v.apply_compaction(1, &[], vec![l2_sst]);
        // The pick of file 1 is abandoned: its L2 overlap is busy.
        assert!(v.pick_compaction(&|id| id == 30, &|_| false).is_none());
        // Once the L2 input frees up, the SAME file must be picked —
        // before the fix the cursor had moved on and file 2 was returned.
        let p = v.pick_compaction(&|_| false, &|_| false).unwrap();
        assert_eq!(p.inputs_lo[0].id, 1, "abandoned pick skipped its file");
        assert_eq!(p.inputs_hi.len(), 1);
        assert_eq!(p.inputs_hi[0].id, 30);
    }

    #[test]
    fn ready_probe_does_not_move_the_cursor() {
        // The scheduler probes for starved compactions on every denied
        // slot; the probe must leave the round-robin state untouched.
        let mut v = version();
        let big: Vec<Entry> = (0..3000u64)
            .map(|i| Entry {
                key: format!("user{i:08}").into_bytes().into(),
                seq: i,
                value: Some(crate::lsm::Payload::fill(0, 400)),
            })
            .collect();
        let (m1, _) = build_sst(&big[..1500], 1, 1, 4096, 10, 0);
        let (m2, _) = build_sst(&big[1500..], 2, 1, 4096, 10, 0);
        v.apply_compaction(0, &[], vec![m1, m2]);
        for _ in 0..3 {
            assert!(v.compaction_ready(&|_| false, &|_| false));
        }
        let p1 = v.pick_compaction(&|_| false, &|_| false).unwrap();
        assert_eq!(p1.inputs_lo[0].id, 1, "probes must not advance the cursor");
        for _ in 0..3 {
            assert!(v.compaction_ready(&|_| false, &|_| false));
        }
        let p2 = v.pick_compaction(&|_| false, &|_| false).unwrap();
        assert_eq!(p2.inputs_lo[0].id, 2, "cursor advances only on real picks");
    }

    #[test]
    fn find_by_id() {
        let mut v = version();
        v.add_l0(sst(42, 0, 0, 10));
        assert!(v.find(42).is_some());
        assert!(v.find(43).is_none());
    }
}
