//! A from-scratch LSM-tree KV store (§2.2): MemTables, SSTables with 4-KiB
//! data blocks + index + Bloom filter, a LRU block cache that emits
//! eviction hints, a leveled version set, and the compaction merge.
//!
//! The store is deliberately RocksDB-shaped (target sizes, L0 triggers,
//! flush of immutable MemTables, leveled compaction with overlapping-range
//! input selection) because the paper's observations O1–O4 are properties
//! of that shape.
//!
//! Values are carried as synthetic [`Payload`]s (length + fingerprint)
//! rather than materialized bytes — see [`crate::wire`]. Keys are
//! ref-counted interned [`KeyRef`]s backed by a per-clock-domain
//! [`KeyArena`] (see [`key`]), and SST blocks/indexes store them
//! restart-point prefix-compressed. All on-disk sizes and offsets are
//! computed from logical lengths and are therefore byte-identical to an
//! engine storing real values and full keys.

pub mod block_cache;
pub mod bloom;
pub mod compaction;
pub mod key;
pub mod memtable;
pub mod sst;
pub mod version;

pub use block_cache::BlockCache;
pub use bloom::Bloom;
pub use compaction::merge_entries;
pub use key::{
    KeyArena, KeyArenaStats, KeyIndex, KeyRef, KEY_OVERHEAD, MIN_SHARED_PREFIX, RESTART_INTERVAL,
};
pub use memtable::MemTable;
pub use sst::{BlockHandle, SstBuilder, SstMeta};
pub use version::{CompactionPick, Version};

pub use crate::wire::{EntryCursor, EntryRef, KeyView, Payload, WireBuf};

/// SSTable identifier (also the zenfs file id of the SST).
pub type SstId = u64;

/// User key (24 B in the paper's workloads, but arbitrary here): a
/// ref-counted interned key — cloning shares one allocation per unique
/// key instead of copying the bytes.
pub type Key = KeyRef;

/// A versioned KV entry. `value == None` is a tombstone.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    pub key: Key,
    pub seq: u64,
    pub value: Option<Payload>,
}

impl Entry {
    /// On-disk (logical) encoded size of this entry.
    pub fn encoded_len(&self) -> usize {
        crate::wire::ENTRY_HEADER + self.key.len() + self.value.map_or(0, |p| p.len as usize)
    }

    pub fn encode_into(&self, out: &mut WireBuf) {
        out.push_entry(&self.key, self.seq, self.value);
    }
}

impl EntryRef<'_> {
    /// Owned copy of a borrowed decoded entry (one key allocation; intern
    /// through a [`KeyArena`] instead where the key should be shared).
    pub fn to_entry(&self) -> Entry {
        Entry { key: KeyRef::from_view(self.key), seq: self.seq, value: self.value }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_roundtrip() {
        let e = Entry { key: Key::new(b"user123"), seq: 42, value: Some(Payload::fill(7, 100)) };
        let mut buf = WireBuf::new();
        e.encode_into(&mut buf);
        assert_eq!(buf.len(), e.encoded_len() as u64);
        let d = buf.entries().next().unwrap();
        assert_eq!(d.to_entry(), e);
    }

    #[test]
    fn tombstone_roundtrip() {
        let e = Entry { key: Key::new(b"k"), seq: 1, value: None };
        let mut buf = WireBuf::new();
        e.encode_into(&mut buf);
        let d = buf.entries().next().unwrap();
        assert_eq!(d.value, None);
    }

    #[test]
    fn decode_multiple_sequential() {
        let mut buf = WireBuf::new();
        let entries: Vec<Entry> = (0..10)
            .map(|i| Entry {
                key: format!("key{i:03}").into_bytes().into(),
                seq: i,
                value: Some(Payload::fill(i as u8, 8)),
            })
            .collect();
        for e in &entries {
            e.encode_into(&mut buf);
        }
        let out: Vec<Entry> = buf.entries().map(|e| e.to_entry()).collect();
        assert_eq!(out, entries);
    }

    #[test]
    fn truncated_decode_returns_none() {
        let e = Entry { key: Key::new(b"abc"), seq: 3, value: Some(Payload::fill(1, 50)) };
        let mut buf = WireBuf::new();
        e.encode_into(&mut buf);
        let truncated = buf.slice_to_buf(0, buf.len() - 1);
        assert_eq!(truncated.entries().count(), 0);
    }

    #[test]
    fn encoded_len_matches_seed_on_disk_format() {
        // The accounting invariant: logical size == the seed engine's
        // materialized `2 + 4 + 8 + klen + vlen` encoding.
        let e = Entry { key: vec![0u8; 24].into(), seq: 9, value: Some(Payload::fill(3, 1000)) };
        assert_eq!(e.encoded_len(), 2 + 4 + 8 + 24 + 1000);
        let t = Entry { key: vec![0u8; 24].into(), seq: 9, value: None };
        assert_eq!(t.encoded_len(), 2 + 4 + 8 + 24);
    }
}
