//! A from-scratch LSM-tree KV store (§2.2): MemTables, SSTables with 4-KiB
//! data blocks + index + Bloom filter, a LRU block cache that emits
//! eviction hints, a leveled version set, and the compaction merge.
//!
//! The store is deliberately RocksDB-shaped (target sizes, L0 triggers,
//! flush of immutable MemTables, leveled compaction with overlapping-range
//! input selection) because the paper's observations O1–O4 are properties
//! of that shape.

pub mod block_cache;
pub mod bloom;
pub mod compaction;
pub mod memtable;
pub mod sst;
pub mod version;

pub use block_cache::BlockCache;
pub use bloom::Bloom;
pub use compaction::merge_entries;
pub use memtable::MemTable;
pub use sst::{BlockHandle, SstBuilder, SstMeta};
pub use version::{CompactionPick, Version};

/// SSTable identifier (also the zenfs file id of the SST).
pub type SstId = u64;

/// User key bytes (24 B in the paper's workloads, but arbitrary here).
pub type Key = Vec<u8>;

/// A versioned KV entry. `value == None` is a tombstone.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    pub key: Key,
    pub seq: u64,
    pub value: Option<Vec<u8>>,
}

impl Entry {
    /// On-disk encoded size of this entry.
    pub fn encoded_len(&self) -> usize {
        2 + 4 + 8 + self.key.len() + self.value.as_ref().map_or(0, |v| v.len())
    }

    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.key.len() as u16).to_le_bytes());
        match &self.value {
            Some(v) => out.extend_from_slice(&(v.len() as u32).to_le_bytes()),
            None => out.extend_from_slice(&u32::MAX.to_le_bytes()),
        }
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.key);
        if let Some(v) = &self.value {
            out.extend_from_slice(v);
        }
    }

    /// Decode one entry from `buf[at..]`; returns the entry and the next
    /// offset, or None at end-of-buffer / truncation.
    pub fn decode_from(buf: &[u8], at: usize) -> Option<(Entry, usize)> {
        if at + 14 > buf.len() {
            return None;
        }
        let klen = u16::from_le_bytes(buf[at..at + 2].try_into().unwrap()) as usize;
        let vlen_raw = u32::from_le_bytes(buf[at + 2..at + 6].try_into().unwrap());
        let seq = u64::from_le_bytes(buf[at + 6..at + 14].try_into().unwrap());
        let mut p = at + 14;
        if p + klen > buf.len() {
            return None;
        }
        let key = buf[p..p + klen].to_vec();
        p += klen;
        let value = if vlen_raw == u32::MAX {
            None
        } else {
            let vlen = vlen_raw as usize;
            if p + vlen > buf.len() {
                return None;
            }
            let v = buf[p..p + vlen].to_vec();
            p += vlen;
            Some(v)
        };
        Some((Entry { key, seq, value }, p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_roundtrip() {
        let e = Entry { key: b"user123".to_vec(), seq: 42, value: Some(vec![7u8; 100]) };
        let mut buf = Vec::new();
        e.encode_into(&mut buf);
        assert_eq!(buf.len(), e.encoded_len());
        let (d, next) = Entry::decode_from(&buf, 0).unwrap();
        assert_eq!(d, e);
        assert_eq!(next, buf.len());
    }

    #[test]
    fn tombstone_roundtrip() {
        let e = Entry { key: b"k".to_vec(), seq: 1, value: None };
        let mut buf = Vec::new();
        e.encode_into(&mut buf);
        let (d, _) = Entry::decode_from(&buf, 0).unwrap();
        assert_eq!(d.value, None);
    }

    #[test]
    fn decode_multiple_sequential() {
        let mut buf = Vec::new();
        let entries: Vec<Entry> = (0..10)
            .map(|i| Entry {
                key: format!("key{i:03}").into_bytes(),
                seq: i,
                value: Some(vec![i as u8; 8]),
            })
            .collect();
        for e in &entries {
            e.encode_into(&mut buf);
        }
        let mut at = 0;
        let mut out = Vec::new();
        while let Some((e, next)) = Entry::decode_from(&buf, at) {
            out.push(e);
            at = next;
        }
        assert_eq!(out, entries);
    }

    #[test]
    fn truncated_decode_returns_none() {
        let e = Entry { key: b"abc".to_vec(), seq: 3, value: Some(vec![1; 50]) };
        let mut buf = Vec::new();
        e.encode_into(&mut buf);
        assert!(Entry::decode_from(&buf[..buf.len() - 1], 0).is_none());
        assert!(Entry::decode_from(&buf, buf.len()).is_none());
    }
}
