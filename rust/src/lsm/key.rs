//! Interned keys and compact key storage.
//!
//! Three pieces, all aimed at one invariant: resident key memory is
//! O(unique-key-bytes), not O(entries × key_len × duplication-factor):
//!
//! * [`KeyRef`] — a ref-counted immutable key (`Rc<[u8]>`). Every layer
//!   that used to own a `Vec<u8>` copy of a key (MemTable nodes, `SstMeta`
//!   bounds, compaction cursors, scan results) now shares one allocation
//!   per unique key; cloning a `KeyRef` is a refcount bump.
//! * [`KeyArena`] — the per-clock-domain interner backing those refs: an
//!   append-only logical arena of unique key bytes with a hash table for
//!   dedup and **epoch-based reclamation tied to Version GC** — the engine
//!   retires an epoch whenever compaction deletes SSTs (the only point
//!   where key references die in bulk), and every few epochs the arena
//!   sweeps entries whose only remaining reference is the arena itself.
//!   Shards of one frontend share ONE arena (rebound in
//!   `ShardedEngine::new` exactly like the shared `CpuPool`).
//! * [`KeyIndex`] — restart-point prefix-compressed storage for the SST
//!   index's separator keys: every [`RESTART_INTERVAL`]-th first-key is
//!   stored whole, the rest store only the suffix after their restart
//!   key's shared prefix (the bytes physically kept *are* the truncated
//!   separators). Lookups compare the exact reconstructed key, so block
//!   selection is bit-identical to an index of full `Vec<u8>` first-keys
//!   — which is what keeps the DES timeline and the golden e2e digests
//!   unchanged — while resident index bytes shrink to
//!   O(restarts × key_len + entries × suffix_len).
//!
//! The same restart-point scheme compresses the *data blocks* themselves;
//! that half lives in [`crate::wire`] (`WireBuf::push_entry_shared`)
//! because it has to survive arbitrary logical slicing at zone
//! boundaries.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::sim::rng::fnv1a;
use crate::wire::KeyView;

/// Per-interned-key bookkeeping overhead charged to the arena gauge (and
/// by the MemTable byte budget): the `Rc` header plus the dedup-table
/// slot, rounded to a small constant.
pub const KEY_OVERHEAD: usize = 16;

/// Restart-point interval shared by the data-block and index compressors:
/// one fully-stored key every `RESTART_INTERVAL` entries, suffix-only
/// entries in between (RocksDB's default block restart interval).
pub const RESTART_INTERVAL: usize = 16;

/// Minimum shared-prefix length worth eliding from a data-block entry: a
/// `PrefixRun` costs ~32 resident bytes of run metadata that the
/// byte-vector gauges (`phys_len`, `zone_phys_bytes`) do not count, so
/// eliding fewer bytes than that would *grow* real memory while
/// reporting shrinkage. Entries whose shared prefix is shorter (e.g. the
/// default 24-byte hashed YCSB keys, which share only ~8-12 bytes with
/// their restart key) are stored whole — exactly the seed's residency.
pub const MIN_SHARED_PREFIX: usize = 32;

/// Sweep cadence: the arena scans for dead entries every this many
/// retired epochs (an epoch retires on every Version GC).
const SWEEP_EPOCHS: u64 = 8;

// ---------------------------------------------------------------------
// KeyRef
// ---------------------------------------------------------------------

/// A ref-counted immutable user key. Order, equality, and hashing are
/// all over the key *bytes*, so `KeyRef` is a drop-in map key wherever
/// `Vec<u8>` was one (including `&[u8]` lookups via `Borrow`).
#[derive(Clone)]
pub struct KeyRef(Rc<[u8]>);

impl KeyRef {
    /// An owned (not interned) key — one allocation, shared by clones.
    pub fn new(bytes: &[u8]) -> KeyRef {
        KeyRef(Rc::from(bytes))
    }

    /// Materialize a (possibly two-part) borrowed [`KeyView`] — two slice
    /// copies, one allocation. (Intern through a [`KeyArena`] instead
    /// when the key should be shared/deduplicated.)
    pub fn from_view(v: KeyView<'_>) -> KeyRef {
        KeyRef(Rc::from(v.to_vec()))
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.0
    }

    pub fn view(&self) -> KeyView<'_> {
        KeyView::from_slice(&self.0)
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self.0.to_vec()
    }

    /// Do two refs share one allocation? (Interning diagnostic.)
    pub fn ptr_eq(a: &KeyRef, b: &KeyRef) -> bool {
        Rc::ptr_eq(&a.0, &b.0)
    }

    /// Number of live references, the arena's among them.
    fn refcount(&self) -> usize {
        Rc::strong_count(&self.0)
    }
}

impl Default for KeyRef {
    fn default() -> KeyRef {
        KeyRef(Rc::from(&b""[..]))
    }
}

impl std::ops::Deref for KeyRef {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.0
    }
}

impl std::borrow::Borrow<[u8]> for KeyRef {
    fn borrow(&self) -> &[u8] {
        &self.0
    }
}

impl PartialEq for KeyRef {
    fn eq(&self, other: &KeyRef) -> bool {
        self.0 == other.0
    }
}
impl Eq for KeyRef {}

impl PartialOrd for KeyRef {
    fn partial_cmp(&self, other: &KeyRef) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for KeyRef {
    fn cmp(&self, other: &KeyRef) -> std::cmp::Ordering {
        self.0.cmp(&other.0)
    }
}

impl std::hash::Hash for KeyRef {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        // Hash the bytes (consistent with `Borrow<[u8]>`).
        self.0.hash(state)
    }
}

impl std::fmt::Debug for KeyRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KeyRef({:?})", String::from_utf8_lossy(&self.0))
    }
}

impl From<Vec<u8>> for KeyRef {
    fn from(v: Vec<u8>) -> KeyRef {
        KeyRef(Rc::from(v))
    }
}

impl From<&[u8]> for KeyRef {
    fn from(v: &[u8]) -> KeyRef {
        KeyRef::new(v)
    }
}

// ---------------------------------------------------------------------
// KeyArena
// ---------------------------------------------------------------------

/// Snapshot of the arena's bookkeeping.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KeyArenaStats {
    /// Resident unique-key bytes + [`KEY_OVERHEAD`] each — the
    /// `key_arena_bytes` gauge.
    pub bytes: u64,
    /// Live interned keys.
    pub unique: u64,
    /// Total intern calls.
    pub interns: u64,
    /// Intern calls satisfied by an existing entry.
    pub hits: u64,
    /// Epochs retired (one per Version GC).
    pub epochs: u64,
    /// Keys reclaimed by sweeps so far.
    pub reclaimed: u64,
}

struct ArenaInner {
    /// fnv1a(key) → interned keys with that hash (collisions chained).
    table: HashMap<u64, Vec<KeyRef>>,
    stats: KeyArenaStats,
}

/// The interner. Cheap to clone — clones share one arena (the handle is
/// an `Rc`), which is how every shard of a frontend domain binds to the
/// same key storage.
#[derive(Clone)]
pub struct KeyArena {
    inner: Rc<RefCell<ArenaInner>>,
}

impl Default for KeyArena {
    fn default() -> Self {
        KeyArena::new()
    }
}

impl KeyArena {
    pub fn new() -> KeyArena {
        KeyArena {
            inner: Rc::new(RefCell::new(ArenaInner {
                table: HashMap::new(),
                stats: KeyArenaStats::default(),
            })),
        }
    }

    /// Do two handles share one arena?
    pub fn shares_with(&self, other: &KeyArena) -> bool {
        Rc::ptr_eq(&self.inner, &other.inner)
    }

    /// The shared lookup-or-insert body: `make` supplies the ref to adopt
    /// on a miss (a fresh copy for [`KeyArena::intern`], the caller's own
    /// allocation for [`KeyArena::intern_ref`]).
    fn intern_with(&self, bytes: &[u8], make: impl FnOnce() -> KeyRef) -> KeyRef {
        let h = fnv1a(bytes);
        let inner = &mut *self.inner.borrow_mut();
        inner.stats.interns += 1;
        let bucket = inner.table.entry(h).or_default();
        if let Some(k) = bucket.iter().find(|k| k.as_slice() == bytes) {
            let k = k.clone();
            inner.stats.hits += 1;
            return k;
        }
        let k = make();
        debug_assert_eq!(k.as_slice(), bytes);
        bucket.push(k.clone());
        inner.stats.unique += 1;
        inner.stats.bytes += (bytes.len() + KEY_OVERHEAD) as u64;
        k
    }

    /// Intern `key`: return the canonical [`KeyRef`] for these bytes,
    /// storing them once on first sight.
    pub fn intern(&self, key: &[u8]) -> KeyRef {
        self.intern_with(key, || KeyRef::new(key))
    }

    /// Canonicalize an already-owned ref: if the bytes are interned,
    /// return the canonical ref; otherwise adopt THIS allocation into the
    /// arena (no copy) and return it.
    pub fn intern_ref(&self, key: &KeyRef) -> KeyRef {
        self.intern_with(key.as_slice(), || key.clone())
    }

    /// Retire an epoch. Called by the engine whenever Version GC deletes
    /// SSTs (the bulk-death point for key references); every
    /// [`SWEEP_EPOCHS`] retirements the arena sweeps dead entries so
    /// reclamation cost amortizes to O(live) per GC wave.
    pub fn retire_epoch(&self) {
        let due = {
            let inner = &mut *self.inner.borrow_mut();
            inner.stats.epochs += 1;
            inner.stats.epochs % SWEEP_EPOCHS == 0
        };
        if due {
            self.sweep();
        }
    }

    /// Drop every interned key whose only remaining reference is the
    /// arena itself. Returns the number reclaimed.
    pub fn sweep(&self) -> u64 {
        let inner = &mut *self.inner.borrow_mut();
        let mut reclaimed = 0u64;
        let mut bytes_freed = 0u64;
        inner.table.retain(|_, bucket| {
            bucket.retain(|k| {
                if k.refcount() > 1 {
                    true
                } else {
                    reclaimed += 1;
                    bytes_freed += (k.len() + KEY_OVERHEAD) as u64;
                    false
                }
            });
            !bucket.is_empty()
        });
        inner.stats.unique -= reclaimed;
        inner.stats.bytes -= bytes_freed;
        inner.stats.reclaimed += reclaimed;
        reclaimed
    }

    /// Resident unique-key bytes (incl. per-key overhead) — the
    /// `key_arena_bytes` gauge.
    pub fn bytes(&self) -> u64 {
        self.inner.borrow().stats.bytes
    }

    pub fn stats(&self) -> KeyArenaStats {
        self.inner.borrow().stats
    }
}

// ---------------------------------------------------------------------
// KeyIndex
// ---------------------------------------------------------------------

/// One index entry: where its (truncated) stored bytes live in the
/// shared byte pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct IndexEntry {
    /// Byte-pool offset of this entry's restart key (itself when
    /// `shared == 0`).
    restart_off: u32,
    /// Bytes shared with the restart key (0 at restarts).
    shared: u16,
    /// Byte-pool offset of the stored suffix.
    suffix_off: u32,
    suffix_len: u16,
}

/// Restart-point prefix-compressed first-key index of one SST. Stores the
/// truncated separators physically while exposing the exact full keys for
/// comparison, so `find_block` behaves bit-for-bit like the old
/// `Vec<BlockHandle { first_key: Vec<u8> }>` index.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct KeyIndex {
    bytes: Vec<u8>,
    entries: Vec<IndexEntry>,
}

impl KeyIndex {
    pub fn new() -> KeyIndex {
        KeyIndex::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Append the next separator key (keys MUST arrive in ascending
    /// order — they are block first-keys of one SST).
    pub fn push(&mut self, key: &[u8]) {
        assert!(key.len() <= u16::MAX as usize, "separator key too long");
        if self.entries.len() % RESTART_INTERVAL == 0 {
            let off = self.bytes.len() as u32;
            self.bytes.extend_from_slice(key);
            self.entries.push(IndexEntry {
                restart_off: off,
                shared: 0,
                suffix_off: off,
                suffix_len: key.len() as u16,
            });
            return;
        }
        // The restart key of the running interval.
        let restart_idx = (self.entries.len() / RESTART_INTERVAL) * RESTART_INTERVAL;
        let restart = self.entries[restart_idx];
        debug_assert_eq!(restart.shared, 0);
        let restart_len = restart.suffix_len as usize;
        let restart_bytes =
            &self.bytes[restart.restart_off as usize..restart.restart_off as usize + restart_len];
        let shared = common_prefix_len(restart_bytes, key);
        let off = self.bytes.len() as u32;
        self.bytes.extend_from_slice(&key[shared..]);
        self.entries.push(IndexEntry {
            restart_off: restart.restart_off,
            shared: shared as u16,
            suffix_off: off,
            suffix_len: (key.len() - shared) as u16,
        });
    }

    /// The exact `i`-th separator key as a zero-copy two-part view.
    pub fn key(&self, i: usize) -> KeyView<'_> {
        let e = self.entries[i];
        KeyView::new(
            &self.bytes[e.restart_off as usize..e.restart_off as usize + e.shared as usize],
            &self.bytes[e.suffix_off as usize..e.suffix_off as usize + e.suffix_len as usize],
        )
    }

    /// Full (logical) length of the `i`-th separator — what the
    /// serialized index charges, independent of truncation.
    pub fn key_len(&self, i: usize) -> usize {
        let e = self.entries[i];
        e.shared as usize + e.suffix_len as usize
    }

    /// Physically resident bytes of this index (truncated separators).
    pub fn stored_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Number of entries whose key is `<= key` — exactly
    /// `partition_point(|e| e.first_key <= key)` over the full keys.
    pub fn partition_point_leq(&self, key: &[u8]) -> usize {
        let mut lo = 0usize;
        let mut hi = self.entries.len();
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.key(mid).cmp_bytes(key) != std::cmp::Ordering::Greater {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

/// Length of the longest common prefix of two byte strings.
pub fn common_prefix_len(a: &[u8], b: &[u8]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keyref_orders_and_borrows_like_bytes() {
        let a = KeyRef::new(b"abc");
        let b = KeyRef::new(b"abd");
        assert!(a < b);
        assert_eq!(a, KeyRef::from(b"abc".to_vec()));
        let mut m: std::collections::BTreeMap<KeyRef, u32> = Default::default();
        m.insert(a.clone(), 1);
        assert_eq!(m.get(b"abc".as_slice()), Some(&1));
        assert_eq!(m.range::<[u8], _>(b"ab".as_slice()..).count(), 1);
    }

    #[test]
    fn intern_dedups_to_one_allocation() {
        let arena = KeyArena::new();
        let a = arena.intern(b"user0001");
        let b = arena.intern(b"user0001");
        assert!(KeyRef::ptr_eq(&a, &b));
        let s = arena.stats();
        assert_eq!(s.unique, 1);
        assert_eq!(s.interns, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.bytes, (8 + KEY_OVERHEAD) as u64);
    }

    #[test]
    fn intern_ref_adopts_without_copy() {
        let arena = KeyArena::new();
        let k = KeyRef::new(b"bound");
        let c = arena.intern_ref(&k);
        assert!(KeyRef::ptr_eq(&k, &c));
        // A later intern of the same bytes returns the adopted ref.
        let again = arena.intern(b"bound");
        assert!(KeyRef::ptr_eq(&k, &again));
        assert_eq!(arena.stats().unique, 1);
    }

    #[test]
    fn sweep_reclaims_dead_keys_only() {
        let arena = KeyArena::new();
        let live = arena.intern(b"live-key");
        {
            let _dead = arena.intern(b"dead-key");
        }
        assert_eq!(arena.stats().unique, 2);
        let reclaimed = arena.sweep();
        assert_eq!(reclaimed, 1);
        let s = arena.stats();
        assert_eq!(s.unique, 1);
        assert_eq!(s.bytes, (live.len() + KEY_OVERHEAD) as u64);
        // The live key is still canonical.
        assert!(KeyRef::ptr_eq(&live, &arena.intern(b"live-key")));
    }

    #[test]
    fn epochs_sweep_on_cadence() {
        let arena = KeyArena::new();
        {
            let _k = arena.intern(b"transient");
        }
        for _ in 0..SWEEP_EPOCHS - 1 {
            arena.retire_epoch();
        }
        assert_eq!(arena.stats().unique, 1, "not yet swept");
        arena.retire_epoch();
        assert_eq!(arena.stats().unique, 0, "sweep on the cadence epoch");
        assert_eq!(arena.stats().reclaimed, 1);
    }

    #[test]
    fn shared_handles_see_one_arena() {
        let a = KeyArena::new();
        let b = a.clone();
        assert!(a.shares_with(&b));
        let k1 = a.intern(b"k");
        let k2 = b.intern(b"k");
        assert!(KeyRef::ptr_eq(&k1, &k2));
        assert!(!a.shares_with(&KeyArena::new()));
    }

    #[test]
    fn key_index_reconstructs_exact_keys() {
        let keys: Vec<Vec<u8>> =
            (0..100u64).map(|i| format!("user{i:012}").into_bytes()).collect();
        let mut idx = KeyIndex::new();
        for k in &keys {
            idx.push(k);
        }
        assert_eq!(idx.len(), keys.len());
        for (i, k) in keys.iter().enumerate() {
            assert_eq!(idx.key(i).to_vec(), *k, "entry {i}");
            assert_eq!(idx.key_len(i), k.len());
        }
        // Truncation actually happened: shared "user0000000" prefixes are
        // stored once per restart interval, not per entry.
        assert!(
            idx.stored_bytes() < keys.iter().map(|k| k.len()).sum::<usize>() / 2,
            "stored {} of {} raw bytes",
            idx.stored_bytes(),
            keys.iter().map(|k| k.len()).sum::<usize>()
        );
    }

    #[test]
    fn key_index_partition_matches_full_key_partition() {
        let keys: Vec<Vec<u8>> =
            (0..200u64).map(|i| format!("user{:06}", i * 3).into_bytes()).collect();
        let mut idx = KeyIndex::new();
        for k in &keys {
            idx.push(k);
        }
        // Probe every present key, every gap neighbour, and the extremes:
        // the compressed partition must equal the full-key partition.
        let mut probes: Vec<Vec<u8>> = keys.clone();
        probes.push(b"user".to_vec());
        probes.push(b"zzz".to_vec());
        for i in 0..200u64 {
            probes.push(format!("user{:06}", i * 3 + 1).into_bytes());
        }
        for p in &probes {
            let want = keys.partition_point(|k| k.as_slice() <= p.as_slice());
            assert_eq!(idx.partition_point_leq(p), want, "probe {p:?}");
        }
    }

    #[test]
    fn key_index_handles_unrelated_keys() {
        let keys: Vec<&[u8]> = vec![b"a", b"ab", b"b", b"ba", b"c", b"ca"];
        let mut idx = KeyIndex::new();
        for k in &keys {
            idx.push(k);
        }
        for (i, k) in keys.iter().enumerate() {
            assert_eq!(idx.key(i).to_vec(), k.to_vec());
        }
    }

    #[test]
    fn common_prefix() {
        assert_eq!(common_prefix_len(b"abcd", b"abxy"), 2);
        assert_eq!(common_prefix_len(b"abc", b"abc"), 3);
        assert_eq!(common_prefix_len(b"abc", b"abcd"), 3);
        assert_eq!(common_prefix_len(b"x", b"y"), 0);
        assert_eq!(common_prefix_len(b"", b"y"), 0);
    }
}
