//! The basic data placement schemes B1–B4 (§2.3) and the B3+M ablation
//! (§4.2 Exp#2).
//!
//! `Bh` stores the WAL and the SSTs at levels `L_0 .. L_{h-1}` on the SSD
//! and everything else on the HDD. If the SSD is full, writes fall through
//! to the HDD (no stalls, no migration) — exactly the behaviour whose
//! limitations O1–O4 motivate HHZS.
//!
//! `B3+M` adds workload-aware migration restricted to the static layout:
//! it moves SSTs at `L_0..L_{h-1}` found on the HDD back to the SSD when
//! zones free up, but never moves higher levels to the SSD (B3 requires
//! L3/L4 to live on the HDD).

use crate::config::Config;
use crate::hints::Hint;
use crate::lsm::SstId;
use crate::sim::Ns;
use crate::zone::Dev;

use super::{
    priority_score, MigrationKind, MigrationOp, Policy, SstOrigin, SstStats, View,
};

pub struct BasicPolicy {
    /// Level threshold `h`: levels < h go to the SSD.
    pub h: usize,
    /// Enable the migration ablation (B3+M in Exp#2).
    pub migration: bool,
    stats: SstStats,
}

impl BasicPolicy {
    pub fn new(h: usize) -> Self {
        BasicPolicy { h, migration: false, stats: SstStats::default() }
    }

    pub fn with_migration(h: usize) -> Self {
        BasicPolicy { h, migration: true, stats: SstStats::default() }
    }
}

impl Policy for BasicPolicy {
    fn name(&self) -> String {
        if self.migration {
            format!("B{}+M", self.h)
        } else {
            format!("B{}", self.h)
        }
    }

    fn reserved_pool_zones(&self, _cfg: &Config) -> u32 {
        0 // basic schemes do not reserve WAL zones (§2.3)
    }

    fn on_hint(&mut self, _hint: &Hint, _view: &View) {}

    fn on_sst_read(&mut self, sst: SstId, dev: Dev, now: Ns) {
        self.stats.on_read(sst, dev, now);
    }

    fn on_sst_deleted(&mut self, sst: SstId) {
        self.stats.on_deleted(sst);
    }

    fn place_sst(&mut self, level: usize, _size: u64, _origin: SstOrigin, _view: &View) -> Dev {
        if level < self.h {
            Dev::Ssd
        } else {
            Dev::Hdd
        }
    }

    fn pick_migration(&mut self, view: &View) -> Option<MigrationOp> {
        if !self.migration || view.ssd_free() == 0 {
            return None;
        }
        // Highest-priority low-level SST currently stranded on the HDD.
        let mut best: Option<(f64, SstId)> = None;
        for lvl in 0..self.h.min(view.version.num_levels()) {
            for m in view.version.level(lvl) {
                if view.fs.file_dev(m.id) != Some(Dev::Hdd) || (view.busy_ssts)(m.id) {
                    continue;
                }
                let score =
                    priority_score(lvl, self.stats.read_rate(m.id, m.created_at, view.now));
                if best.map_or(true, |(s, _)| score > s) {
                    best = Some((score, m.id));
                }
            }
        }
        best.map(|(_, sst)| MigrationOp {
            sst,
            to: Dev::Ssd,
            kind: MigrationKind::Popularity,
            swap_with: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names() {
        assert_eq!(BasicPolicy::new(3).name(), "B3");
        assert_eq!(BasicPolicy::with_migration(3).name(), "B3+M");
    }

    #[test]
    fn static_threshold_placement() {
        let mut p = BasicPolicy::new(3);
        // place_sst ignores the view for basic schemes; build a dummy view
        // via the engine-free helper below is overkill — the decision is a
        // pure function of the level.
        // (Integration behaviour with fallback is covered in engine tests.)
        let cfg = Config::tiny();
        let fs = crate::zenfs::ZenFs::new(
            cfg.geometry.ssd_zone_cap,
            4,
            cfg.geometry.hdd_zone_cap,
            16,
            cfg.ssd.clone(),
            cfg.hdd.clone(),
        );
        let version = crate::lsm::Version::new(7, 1 << 20, 10, 4);
        let busy = |_: SstId| false;
        let view = View {
            now: 0,
            cfg: &cfg,
            fs: &fs,
            version: &version,
            wal_zones_in_use: 0,
            busy_ssts: &busy,
        };
        assert_eq!(p.place_sst(0, 1, SstOrigin::Flush, &view), Dev::Ssd);
        assert_eq!(p.place_sst(2, 1, SstOrigin::Compaction, &view), Dev::Ssd);
        assert_eq!(p.place_sst(3, 1, SstOrigin::Compaction, &view), Dev::Hdd);
        assert_eq!(p.place_sst(4, 1, SstOrigin::Compaction, &view), Dev::Hdd);
    }

    #[test]
    fn no_migration_unless_enabled() {
        let mut p = BasicPolicy::new(3);
        let cfg = Config::tiny();
        let fs = crate::zenfs::ZenFs::new(
            cfg.geometry.ssd_zone_cap,
            4,
            cfg.geometry.hdd_zone_cap,
            16,
            cfg.ssd.clone(),
            cfg.hdd.clone(),
        );
        let version = crate::lsm::Version::new(7, 1 << 20, 10, 4);
        let busy = |_: SstId| false;
        let view = View {
            now: 0,
            cfg: &cfg,
            fs: &fs,
            version: &version,
            wal_zones_in_use: 0,
            busy_ssts: &busy,
        };
        assert!(p.pick_migration(&view).is_none());
    }
}
