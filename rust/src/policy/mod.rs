//! Placement/migration/caching policies: the paper's HHZS plus all the
//! baselines it is evaluated against (B1–B4 and SpanDB's AUTO).
//!
//! A [`Policy`] makes *decisions*; the DES engine in [`crate::coordinator`]
//! executes them (allocates zones, charges I/O, runs rate-limited migration
//! chunks). Policies receive every hint the KV store emits (§3.1) plus
//! per-SST read notifications, and keep whatever state they need — HHZS
//! keeps storage demands and SST read-rate mappings exactly as §3.3/§3.4
//! describe.

pub mod auto;
pub mod basic;
pub mod hhzs;

pub use auto::AutoPolicy;
pub use basic::BasicPolicy;
pub use hhzs::HhzsPolicy;

use std::collections::HashMap;

use crate::config::Config;
use crate::hints::Hint;
use crate::lsm::{SstId, Version};
use crate::sim::Ns;
use crate::zenfs::ZenFs;
use crate::zone::Dev;

/// Where a to-be-written SST came from (flushing vs compaction — the two
/// hint sources of §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SstOrigin {
    Flush,
    Compaction,
}

/// A migration decision (§3.4). `swap_with` implements popularity
/// migration's swap case: move `swap_with` (SSD → HDD) first to free the
/// zone, then `sst` (HDD → SSD).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MigrationOp {
    pub sst: SstId,
    pub to: Dev,
    pub kind: MigrationKind,
    pub swap_with: Option<SstId>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigrationKind {
    Capacity,
    Popularity,
}

/// Read-only view of system state handed to policy decision points.
pub struct View<'a> {
    pub now: Ns,
    pub cfg: &'a Config,
    pub fs: &'a ZenFs,
    pub version: &'a Version,
    /// Zones currently holding live WAL data (the §3.3 proxy for the L0
    /// storage demand).
    pub wal_zones_in_use: u32,
    /// SSTs that are inputs of a running compaction (excluded from
    /// migration per §3.4) or currently being migrated.
    pub busy_ssts: &'a dyn Fn(SstId) -> bool,
}

impl<'a> View<'a> {
    /// SSD zones usable for SSTs (C_ssd in §3.3).
    pub fn c_ssd(&self) -> u32 {
        self.fs.ssd_file_zones_total()
    }

    /// Empty SSD zones available for SSTs right now.
    pub fn ssd_free(&self) -> u32 {
        self.fs.ssd_file_zones_free()
    }

    /// Number of SSTs of `level` resident on the SSD (A_i in §3.3 — one
    /// SSD zone per SST).
    pub fn allocated_ssd(&self, level: usize) -> u32 {
        self.version
            .level(level)
            .iter()
            .filter(|m| self.fs.file_dev(m.id) == Some(Dev::Ssd))
            .count() as u32
    }
}

/// Per-SST read statistics used for SST priorities (§3.4): HHZS "keeps the
/// mappings between each SST and its level, total number of reads, and age
/// in memory".
#[derive(Default, Clone)]
pub struct SstStats {
    reads: HashMap<SstId, u64>,
    /// Sliding-window HDD read counter (for the popularity trigger).
    window_start: Ns,
    window_hdd_reads: u64,
    hdd_read_rate: f64,
}

/// Window length for the HDD read-rate estimate (1 virtual second).
const RATE_WINDOW: Ns = 1_000_000_000;

impl SstStats {
    pub fn on_read(&mut self, sst: SstId, dev: Dev, now: Ns) {
        *self.reads.entry(sst).or_insert(0) += 1;
        if now.saturating_sub(self.window_start) > RATE_WINDOW {
            self.hdd_read_rate =
                self.window_hdd_reads as f64 / (now - self.window_start).max(1) as f64 * 1e9;
            self.window_start = now;
            self.window_hdd_reads = 0;
        }
        if dev == Dev::Hdd {
            self.window_hdd_reads += 1;
        }
    }

    pub fn on_deleted(&mut self, sst: SstId) {
        self.reads.remove(&sst);
    }

    pub fn reads(&self, sst: SstId) -> u64 {
        self.reads.get(&sst).copied().unwrap_or(0)
    }

    /// Read rate in IOPS: total reads / age (§3.4).
    pub fn read_rate(&self, sst: SstId, created_at: Ns, now: Ns) -> f64 {
        let age_s = (now.saturating_sub(created_at)).max(1) as f64 / 1e9;
        self.reads(sst) as f64 / age_s
    }

    /// Recent aggregate HDD read IOPS (popularity-migration trigger §3.4).
    pub fn hdd_read_rate(&self, now: Ns) -> f64 {
        if now.saturating_sub(self.window_start) > RATE_WINDOW {
            // Window elapsed without updates — decay toward the live count.
            self.window_hdd_reads as f64 / (now - self.window_start).max(1) as f64 * 1e9
        } else {
            self.hdd_read_rate
                .max(self.window_hdd_reads as f64 / (now - self.window_start).max(1) as f64 * 1e9)
        }
    }
}

/// SST priority (§3.4): lower level ⇒ higher priority; same level ⇒ higher
/// read rate wins. Encoded as a single f64 score (shared with the Pallas
/// priority kernel: `score = -level * 1e12 + read_rate`).
pub fn priority_score(level: usize, read_rate: f64) -> f64 {
    -(level as f64) * 1e12 + read_rate
}

/// The policy interface.
pub trait Policy {
    fn name(&self) -> String;

    /// SSD zones to reserve at startup for the WAL(+cache) pool. HHZS and
    /// AUTO reserve `cfg.geometry.wal_cache_zones`; the basic schemes
    /// reserve none (§2.3 writes the WAL to any empty SSD zone).
    fn reserved_pool_zones(&self, cfg: &Config) -> u32;

    /// Application-hinted SSD caching enabled (§3.5)?
    fn ssd_cache_enabled(&self) -> bool {
        false
    }

    /// Receive a hint from the KV store (§3.1).
    fn on_hint(&mut self, hint: &Hint, view: &View);

    /// A data block of `sst` was read from `dev`.
    fn on_sst_read(&mut self, sst: SstId, dev: Dev, now: Ns);

    /// An SST was deleted (compaction inputs reclaimed).
    fn on_sst_deleted(&mut self, sst: SstId);

    /// Choose the device for a new SST of `level` (fallback to HDD when the
    /// chosen device has no empty zones is applied by the engine).
    fn place_sst(&mut self, level: usize, size: u64, origin: SstOrigin, view: &View) -> Dev;

    /// Choose the device for new WAL zone allocation in dynamic-WAL mode
    /// (basic schemes). Reserved-pool policies never get asked.
    fn place_wal(&mut self, view: &View) -> Dev {
        if view.ssd_free() > 0 {
            Dev::Ssd
        } else {
            Dev::Hdd
        }
    }

    /// Migration decision point, called on each policy tick while the
    /// migration actor is idle (§3.4).
    fn pick_migration(&mut self, view: &View) -> Option<MigrationOp>;

    /// Periodic tick (AUTO uses it for throughput-threshold tuning).
    fn tick(&mut self, _now: Ns, _view: &View) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_lower_level_always_wins() {
        assert!(priority_score(0, 0.0) > priority_score(1, 1e9));
        assert!(priority_score(2, 5.0) > priority_score(3, 1e6));
    }

    #[test]
    fn priority_same_level_read_rate_breaks_tie() {
        assert!(priority_score(3, 100.0) > priority_score(3, 1.0));
    }

    #[test]
    fn sst_stats_read_rate() {
        let mut s = SstStats::default();
        for _ in 0..100 {
            s.on_read(7, Dev::Hdd, 1_000_000);
        }
        // 100 reads over 2 seconds of age = 50 IOPS.
        let rate = s.read_rate(7, 0, 2_000_000_000);
        assert!((rate - 50.0).abs() < 1.0, "rate={rate}");
        s.on_deleted(7);
        assert_eq!(s.reads(7), 0);
    }

    #[test]
    fn hdd_rate_window() {
        let mut s = SstStats::default();
        // 200 HDD reads within the first second.
        for i in 0..200u64 {
            s.on_read(1, Dev::Hdd, i * 5_000_000);
        }
        // Trigger a window rollover past 1s.
        s.on_read(1, Dev::Ssd, 1_200_000_000);
        assert!(s.hdd_read_rate(1_200_000_000) > 100.0);
    }
}
