//! Storage-demand tracking (§3.3 Step 1).
//!
//! The demand of `L_0` is the number of WAL zones currently in use (the
//! proxy for MemTable bytes awaiting flush). The demand of `L_i (i ≥ 1)`
//! is maintained from compaction hints:
//!
//! * **Start** of a compaction writing to `L_i`: demand += number of
//!   selected input SSTs (the maximum number of SSTs the job can emit);
//! * each **OutputSst** written to `L_i`: demand -= 1;
//! * **Finish**: demand -= (selected − actually generated), clearing the
//!   remainder the job did not use.

use std::collections::HashMap;

/// Per-level storage demand in SST units (≈ SSD zones, since one SST fills
/// one SSD zone, §3.2).
#[derive(Default, Debug)]
pub struct DemandTracker {
    /// demand[level] for levels ≥ 1 (L0 comes from WAL zones).
    demand: Vec<i64>,
    /// job id → (output level, selected inputs, outputs emitted so far).
    jobs: HashMap<u64, (usize, i64, i64)>,
}

impl DemandTracker {
    pub fn new(num_levels: usize) -> Self {
        DemandTracker { demand: vec![0; num_levels], jobs: HashMap::new() }
    }

    pub fn on_compaction_start(&mut self, job: u64, output_level: usize, selected: usize) {
        self.demand[output_level] += selected as i64;
        self.jobs.insert(job, (output_level, selected as i64, 0));
    }

    pub fn on_output_sst(&mut self, job: u64, level: usize) {
        if let Some((out_level, _, emitted)) = self.jobs.get_mut(&job) {
            debug_assert_eq!(*out_level, level);
            *emitted += 1;
            self.demand[level] -= 1;
        }
    }

    pub fn on_compaction_finish(&mut self, job: u64) {
        if let Some((level, selected, emitted)) = self.jobs.remove(&job) {
            // Clear the unused remainder (selected − generated).
            self.demand[level] -= selected - emitted;
        }
    }

    /// Demand of level `i ≥ 1` in SSTs (never negative).
    pub fn demand(&self, level: usize) -> u32 {
        self.demand.get(level).map_or(0, |d| (*d).max(0) as u32)
    }

    /// Number of compactions currently in flight.
    pub fn active_jobs(&self) -> usize {
        self.jobs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demand_lifecycle_exact_outputs() {
        let mut d = DemandTracker::new(5);
        d.on_compaction_start(1, 2, 4);
        assert_eq!(d.demand(2), 4);
        for _ in 0..4 {
            d.on_output_sst(1, 2);
        }
        assert_eq!(d.demand(2), 0);
        d.on_compaction_finish(1);
        assert_eq!(d.demand(2), 0);
    }

    #[test]
    fn demand_lifecycle_fewer_outputs() {
        let mut d = DemandTracker::new(5);
        d.on_compaction_start(7, 3, 5);
        d.on_output_sst(7, 3);
        d.on_output_sst(7, 3);
        assert_eq!(d.demand(3), 3);
        // Job finishes having produced only 2 of 5 potential SSTs.
        d.on_compaction_finish(7);
        assert_eq!(d.demand(3), 0);
    }

    #[test]
    fn concurrent_jobs_same_level() {
        let mut d = DemandTracker::new(5);
        d.on_compaction_start(1, 2, 2);
        d.on_compaction_start(2, 2, 3);
        assert_eq!(d.demand(2), 5);
        assert_eq!(d.active_jobs(), 2);
        d.on_output_sst(2, 2);
        assert_eq!(d.demand(2), 4);
        d.on_compaction_finish(1);
        assert_eq!(d.demand(2), 2);
        d.on_compaction_finish(2);
        assert_eq!(d.demand(2), 0);
    }

    #[test]
    fn unknown_job_output_ignored() {
        let mut d = DemandTracker::new(5);
        d.on_output_sst(99, 2);
        assert_eq!(d.demand(2), 0);
        d.on_compaction_finish(99); // no panic
    }

    #[test]
    fn never_negative() {
        let mut d = DemandTracker::new(5);
        d.on_compaction_start(1, 1, 1);
        d.on_output_sst(1, 1);
        d.on_output_sst(1, 1); // engine bug shouldn't wedge the tracker
        assert_eq!(d.demand(1), 0);
    }
}
