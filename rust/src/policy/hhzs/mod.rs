//! HHZS (§3): hint-driven placement, workload-aware migration, and
//! application-hinted caching for hybrid zoned storage.
//!
//! * **Write-guided data placement** (§3.3): storage demands per level from
//!   flushing/compaction hints → tiering level `L_t` → the 4-step zone
//!   selection rule.
//! * **Workload-aware migration** (§3.4): capacity migration (SSD → HDD
//!   when the tiering level over-occupies the SSD) and popularity migration
//!   (HDD → SSD when the HDD read rate is the bottleneck), priority =
//!   (level, read rate), excluding SSTs selected by running compactions.
//! * **Application-hinted caching** (§3.5): enabled via
//!   [`Policy::ssd_cache_enabled`]; the cache-zone mechanics (admission on
//!   block-cache eviction, FIFO zone-granular eviction, mapping table +
//!   FIFO queue) live in [`crate::coordinator::walcache`].
//!
//! The ablations of Exp#2 map to constructor flags: `P` (placement only),
//! `P+M` (placement + migration), `P+M+C` (full HHZS).

pub mod demand;

use crate::config::Config;
use crate::hints::{CompactionHint, Hint};
use crate::lsm::SstId;
use crate::sim::Ns;
use crate::zone::Dev;

use self::demand::DemandTracker;
use super::{
    priority_score, MigrationKind, MigrationOp, Policy, SstOrigin, SstStats, View,
};

pub struct HhzsPolicy {
    demands: DemandTracker,
    stats: SstStats,
    /// Enable workload-aware migration (the +M of Exp#2).
    pub migration: bool,
    /// Enable application-hinted SSD caching (the +C of Exp#2).
    pub caching: bool,
    /// IDs selected as inputs by running compactions (excluded from
    /// migration, §3.4: they will be deleted at the end of compaction).
    in_compaction: std::collections::HashSet<SstId>,
    /// Optional AOT-compiled priority kernel (Layer 1/2 via PJRT); when
    /// attached, migration scans score SSTs through XLA instead of the
    /// native loop. Falls back to native for > PRIORITY_N SSTs.
    scorer: Option<std::rc::Rc<crate::runtime::XlaKernels>>,
    /// Decisions scored by the XLA kernel (perf accounting).
    pub xla_scored_picks: u64,
    /// Ablation (not in the paper): ignore compaction-hint storage demands
    /// (D_i = 0 for i ≥ 1) — quantifies how much the §3.1 hints buy over
    /// an allocation-only tiering level.
    pub use_demand_hints: bool,
}

impl HhzsPolicy {
    /// Full HHZS (P+M+C).
    pub fn new(num_levels: usize) -> Self {
        HhzsPolicy {
            demands: DemandTracker::new(num_levels),
            stats: SstStats::default(),
            migration: true,
            caching: true,
            in_compaction: Default::default(),
            scorer: None,
            xla_scored_picks: 0,
            use_demand_hints: true,
        }
    }

    /// The hint-blind ablation (demands from hints disabled).
    pub fn without_demand_hints(num_levels: usize) -> Self {
        let mut p = Self::new(num_levels);
        p.use_demand_hints = false;
        p
    }

    /// Attach the AOT priority kernel (request-path XLA scoring).
    pub fn with_scorer(mut self, k: std::rc::Rc<crate::runtime::XlaKernels>) -> Self {
        self.scorer = Some(k);
        self
    }

    /// Write-guided placement only (the `P` ablation).
    pub fn placement_only(num_levels: usize) -> Self {
        let mut p = Self::new(num_levels);
        p.migration = false;
        p.caching = false;
        p
    }

    /// Placement + migration (the `P+M` ablation).
    pub fn placement_migration(num_levels: usize) -> Self {
        let mut p = Self::new(num_levels);
        p.caching = false;
        p
    }

    /// Storage demand of a level (§3.3 Step 1): D_0 = WAL zones in use;
    /// D_i (i≥1) from compaction hints.
    pub fn storage_demand(&self, level: usize, view: &View) -> u32 {
        if level == 0 {
            view.wal_zones_in_use
        } else if self.use_demand_hints {
            self.demands.demand(level)
        } else {
            0
        }
    }

    /// Tiering level `L_t` (§3.3 Step 2): smallest `t` such that the
    /// cumulative allocation+demand up to `t` reaches C_ssd. If everything
    /// fits, the tiering level is past the last level (all SSTs → SSD).
    pub fn tiering_level(&self, view: &View) -> usize {
        let c_ssd = view.c_ssd() as i64;
        let mut acc = 0i64;
        for lvl in 0..view.version.num_levels() {
            acc += view.allocated_ssd(lvl) as i64 + self.storage_demand(lvl, view) as i64;
            if acc >= c_ssd {
                return lvl;
            }
        }
        view.version.num_levels()
    }

    /// SSD zones reserved for SSTs at the tiering level (§3.3 Step 3).
    pub fn reserved_for_tiering(&self, t: usize, view: &View) -> i64 {
        let c_ssd = view.c_ssd() as i64;
        let mut below = 0i64;
        for lvl in 0..t {
            below += view.allocated_ssd(lvl) as i64 + self.storage_demand(lvl, view) as i64;
        }
        (c_ssd - below).max(0)
    }

    /// Score every eligible SST: `(score, id, on_ssd)`. Uses the AOT XLA
    /// priority kernel when attached (and the SST count fits the lowered
    /// shape), the native loop otherwise — both produce identical scores
    /// (asserted by tests and the pytest oracle).
    fn scored_ssts(&mut self, view: &View) -> Vec<(f64, SstId, bool)> {
        let mut metas = Vec::new();
        for m in view.version.all_ssts() {
            let dev = view.fs.file_dev(m.id);
            if dev.is_none() || self.in_compaction.contains(&m.id) || (view.busy_ssts)(m.id) {
                continue;
            }
            metas.push((m.clone(), dev == Some(Dev::Ssd)));
        }
        if let Some(k) = &self.scorer {
            if metas.len() <= crate::runtime::PRIORITY_N {
                let levels: Vec<i32> = metas.iter().map(|(m, _)| m.level as i32).collect();
                let reads: Vec<f32> =
                    metas.iter().map(|(m, _)| self.stats.reads(m.id) as f32).collect();
                let ages: Vec<f32> = metas
                    .iter()
                    .map(|(m, _)| {
                        (view.now.saturating_sub(m.created_at)).max(1) as f32 / 1e9
                    })
                    .collect();
                if let Ok(scores) = k.priority_scores(&levels, &reads, &ages) {
                    self.xla_scored_picks += 1;
                    return metas
                        .iter()
                        .zip(scores)
                        .map(|((m, on_ssd), s)| (s, m.id, *on_ssd))
                        .collect();
                }
            }
        }
        metas
            .into_iter()
            .map(|(m, on_ssd)| {
                let s =
                    priority_score(m.level, self.stats.read_rate(m.id, m.created_at, view.now));
                (s, m.id, on_ssd)
            })
            .collect()
    }

    /// Lowest-priority SST currently resident on the SSD (capacity-
    /// migration victim / popularity-swap victim).
    fn lowest_priority_on_ssd(&mut self, view: &View) -> Option<(f64, SstId)> {
        self.scored_ssts(view)
            .into_iter()
            .filter(|(_, _, on_ssd)| *on_ssd)
            .map(|(s, id, _)| (s, id))
            .min_by(|a, b| a.0.total_cmp(&b.0))
    }

    /// Highest-priority SST on the HDD (popularity-migration candidate).
    fn highest_priority_on_hdd(&mut self, view: &View) -> Option<(f64, SstId)> {
        self.scored_ssts(view)
            .into_iter()
            .filter(|(_, _, on_ssd)| !*on_ssd)
            .map(|(s, id, _)| (s, id))
            .max_by(|a, b| a.0.total_cmp(&b.0))
    }

    /// Capacity migration (§3.4): triggered when the tiering level has more
    /// SSTs on the SSD than its reservation, or any SST above the tiering
    /// level sits on the SSD.
    ///
    /// The second condition is additionally gated on actual space pressure
    /// (free zones not covering the outstanding lower-level demands): §3.4
    /// motivates capacity migration by "when the storage demands of the
    /// lower levels increase, HHZS needs to reserve more SSD zones" — an
    /// above-tiering SST (e.g. one that popularity migration promoted) is
    /// only a problem when those demands cannot be absorbed by free zones.
    /// Without this gate, short demand spikes from every compaction evict
    /// hot promoted SSTs and the migration pipeline thrashes.
    fn pick_capacity_migration(&mut self, view: &View) -> Option<MigrationOp> {
        let t = self.tiering_level(view);
        let demands_thru_t: u32 =
            (0..=t.min(view.version.num_levels() - 1)).map(|l| self.storage_demand(l, view)).sum();
        let pressure = view.ssd_free() < demands_thru_t;
        if !pressure {
            return None;
        }
        let over_tiering = if t < view.version.num_levels() {
            (view.allocated_ssd(t) as i64) > self.reserved_for_tiering(t, view)
        } else {
            false
        };
        let above_tiering =
            (t + 1..view.version.num_levels()).any(|lvl| view.allocated_ssd(lvl) > 0);
        if !(over_tiering || above_tiering) {
            return None;
        }
        let (_, sst) = self.lowest_priority_on_ssd(view)?;
        Some(MigrationOp { sst, to: Dev::Hdd, kind: MigrationKind::Capacity, swap_with: None })
    }

    /// Popularity migration (§3.4): triggered when the aggregate HDD read
    /// rate exceeds half the HDD's max random-read IOPS.
    fn pick_popularity_migration(&mut self, view: &View) -> Option<MigrationOp> {
        let threshold = view.cfg.hhzs.hdd_rate_threshold * view.cfg.hdd.rand_read_iops;
        if self.stats.hdd_read_rate(view.now) <= threshold {
            return None;
        }
        let (cand_score, sst) = self.highest_priority_on_hdd(view)?;
        // Enough free zones for the demands below the tiering level?
        let t = self.tiering_level(view);
        let demands_below: u32 = (0..t).map(|l| self.storage_demand(l, view)).sum();
        if view.ssd_free() as i64 > demands_below as i64 {
            return Some(MigrationOp {
                sst,
                to: Dev::Ssd,
                kind: MigrationKind::Popularity,
                swap_with: None,
            });
        }
        // Otherwise swap with the lowest-priority SSD resident — only
        // worthwhile if the candidate outranks the victim.
        let (victim_score, victim) = self.lowest_priority_on_ssd(view)?;
        if victim == sst || cand_score <= victim_score {
            return None;
        }
        Some(MigrationOp {
            sst,
            to: Dev::Ssd,
            kind: MigrationKind::Popularity,
            swap_with: Some(victim),
        })
    }
}

impl Policy for HhzsPolicy {
    fn name(&self) -> String {
        let base = match (self.migration, self.caching) {
            (true, true) => "HHZS",
            (true, false) => "P+M",
            (false, false) => "P",
            (false, true) => "P+C",
        };
        if self.use_demand_hints {
            base.into()
        } else {
            format!("{base}-nohints")
        }
    }

    fn reserved_pool_zones(&self, cfg: &Config) -> u32 {
        cfg.geometry.wal_cache_zones
    }

    fn ssd_cache_enabled(&self) -> bool {
        self.caching
    }

    fn on_hint(&mut self, hint: &Hint, _view: &View) {
        match hint {
            Hint::Flush(_) => {}
            Hint::Compaction(CompactionHint::Start { job, inputs, output_level }) => {
                self.demands.on_compaction_start(*job, *output_level, inputs.len());
                self.in_compaction.extend(inputs.iter().copied());
            }
            Hint::Compaction(CompactionHint::OutputSst { job, level, .. }) => {
                self.demands.on_output_sst(*job, *level);
            }
            Hint::Compaction(CompactionHint::Finish { job, .. }) => {
                self.demands.on_compaction_finish(*job);
            }
            Hint::CacheEvict(_) => {
                // Cache admission mechanics live in the engine's pool
                // manager; the policy only gates them via ssd_cache_enabled.
            }
        }
    }

    fn on_sst_read(&mut self, sst: SstId, dev: Dev, now: Ns) {
        self.stats.on_read(sst, dev, now);
    }

    fn on_sst_deleted(&mut self, sst: SstId) {
        self.stats.on_deleted(sst);
        self.in_compaction.remove(&sst);
    }

    /// §3.3 Step 4: SSD for (i) flush output, (ii) levels below `L_t`,
    /// (iii) `L_t` while reserved zones remain; HDD otherwise. The engine
    /// applies the "no empty SSD zone → HDD" fallback.
    fn place_sst(&mut self, level: usize, _size: u64, origin: SstOrigin, view: &View) -> Dev {
        if origin == SstOrigin::Flush {
            return Dev::Ssd;
        }
        let t = self.tiering_level(view);
        if level < t {
            return Dev::Ssd;
        }
        if level == t {
            let reserved = self.reserved_for_tiering(t, view);
            if (view.allocated_ssd(t) as i64) < reserved {
                return Dev::Ssd;
            }
        }
        Dev::Hdd
    }

    fn pick_migration(&mut self, view: &View) -> Option<MigrationOp> {
        if !self.migration {
            return None;
        }
        self.pick_capacity_migration(view)
            .or_else(|| self.pick_popularity_migration(view))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::sst::build_sst;
    use crate::lsm::{Entry, Version};
    use crate::zenfs::ZenFs;

    /// Build a harness: `ssd_zones` file zones, SSTs placed as specified
    /// `(id, level, dev)`. Each SST is tiny but occupies one SSD zone or
    /// one HDD-zone set, matching §3.2.
    struct Harness {
        cfg: Config,
        fs: ZenFs,
        version: Version,
    }

    fn harness(ssd_zones: u32, placements: &[(SstId, usize, Dev)]) -> Harness {
        let cfg = Config::tiny();
        let mut fs = ZenFs::new(
            cfg.geometry.ssd_zone_cap,
            ssd_zones,
            cfg.geometry.hdd_zone_cap,
            256,
            cfg.ssd.clone(),
            cfg.hdd.clone(),
        );
        let mut version = Version::new(7, 10 << 20, 10, 100);
        for (i, (id, level, dev)) in placements.iter().enumerate() {
            let lo = i as u64 * 1000;
            let entries: Vec<Entry> = (lo..lo + 10)
                .map(|k| Entry {
                    key: format!("user{k:012}").into_bytes().into(),
                    seq: k,
                    value: Some(crate::lsm::Payload::fill(0, 64)),
                })
                .collect();
            let (meta, data) = build_sst(&entries, *id, *level, 4096, 10, 0);
            fs.create_file(0, *id, *dev, &data, false).unwrap();
            if *level == 0 {
                version.add_l0(meta);
            } else {
                version.apply_compaction(*level - 1, &[], vec![meta]);
            }
        }
        Harness { cfg, fs, version }
    }

    fn not_busy(_: SstId) -> bool {
        false
    }

    #[test]
    fn tiering_level_accumulates_to_cssd() {
        // 4 SSD zones; L0 has 2 SSTs on SSD + demand 2 (WAL zones) → L0
        // alone reaches C_ssd → t = 0.
        let h = harness(4, &[(1, 0, Dev::Ssd), (2, 0, Dev::Ssd)]);
        let p = HhzsPolicy::new(7);
        let v = View {
            now: 0,
            cfg: &h.cfg,
            fs: &h.fs,
            version: &h.version,
            wal_zones_in_use: 2,
            busy_ssts: &not_busy,
        };
        assert_eq!(p.tiering_level(&v), 0);
    }

    #[test]
    fn tiering_level_past_last_when_everything_fits() {
        let h = harness(10, &[(1, 0, Dev::Ssd), (2, 1, Dev::Ssd)]);
        let p = HhzsPolicy::new(7);
        let v = View {
            now: 0,
            cfg: &h.cfg,
            fs: &h.fs,
            version: &h.version,
            wal_zones_in_use: 1,
            busy_ssts: &not_busy,
        };
        assert_eq!(p.tiering_level(&v), 7);
        // Everything goes to SSD.
        let mut p = p;
        assert_eq!(p.place_sst(3, 1, SstOrigin::Compaction, &v), Dev::Ssd);
    }

    #[test]
    fn flush_always_targets_ssd() {
        let h = harness(2, &[(1, 0, Dev::Ssd), (2, 0, Dev::Ssd)]);
        let mut p = HhzsPolicy::new(7);
        let v = View {
            now: 0,
            cfg: &h.cfg,
            fs: &h.fs,
            version: &h.version,
            wal_zones_in_use: 2,
            busy_ssts: &not_busy,
        };
        assert_eq!(p.place_sst(0, 1, SstOrigin::Flush, &v), Dev::Ssd);
    }

    #[test]
    fn compaction_demand_moves_tiering_level() {
        // 6 SSD zones, 2 L1 SSTs on SSD. Without demand, everything fits.
        let h = harness(6, &[(1, 1, Dev::Ssd), (2, 1, Dev::Ssd)]);
        let mut p = HhzsPolicy::new(7);
        let v = View {
            now: 0,
            cfg: &h.cfg,
            fs: &h.fs,
            version: &h.version,
            wal_zones_in_use: 1,
            busy_ssts: &not_busy,
        };
        assert_eq!(p.tiering_level(&v), 7);
        // A compaction into L1 selecting 3 SSTs raises D_1 to 3:
        // cum(L0)=1, cum(L1)=1+2+3=6 ≥ 6 → t=1.
        p.on_hint(
            &Hint::Compaction(CompactionHint::Start {
                job: 1,
                inputs: vec![10, 11, 12],
                output_level: 1,
            }),
            &v,
        );
        assert_eq!(p.tiering_level(&v), 1);
        // L1 reservation: C_ssd − cum(below L1) = 6 − 1 = 5; A_1 = 2 < 5 →
        // L1 SSTs still go to SSD; L2 goes to HDD.
        assert_eq!(p.place_sst(1, 1, SstOrigin::Compaction, &v), Dev::Ssd);
        assert_eq!(p.place_sst(2, 1, SstOrigin::Compaction, &v), Dev::Hdd);
        // Finish clears the demand.
        p.on_hint(&Hint::Compaction(CompactionHint::Finish { job: 1, outputs: vec![], output_level: 1 }), &v);
        assert_eq!(p.tiering_level(&v), 7);
    }

    #[test]
    fn capacity_migration_evicts_above_tiering() {
        // 3 SSD zones; L0 demand (2 WAL) + 1 L0 SST → cum(L0)=3 ≥ 3 → t=0.
        // An L3 SST sits on the SSD → capacity migration must evict it.
        let h = harness(3, &[(1, 0, Dev::Ssd), (2, 3, Dev::Ssd), (3, 3, Dev::Hdd)]);
        let mut p = HhzsPolicy::new(7);
        let v = View {
            now: 0,
            cfg: &h.cfg,
            fs: &h.fs,
            version: &h.version,
            wal_zones_in_use: 2,
            busy_ssts: &not_busy,
        };
        assert_eq!(p.tiering_level(&v), 0);
        let op = p.pick_migration(&v).expect("capacity migration");
        assert_eq!(op.kind, MigrationKind::Capacity);
        assert_eq!(op.sst, 2, "lowest priority = deepest level on SSD");
        assert_eq!(op.to, Dev::Hdd);
    }

    #[test]
    fn popularity_migration_when_hdd_hot() {
        // Plenty of SSD room (t past last level ⇒ no capacity pressure).
        let h = harness(8, &[(1, 2, Dev::Ssd), (2, 3, Dev::Hdd), (3, 3, Dev::Hdd)]);
        let mut p = HhzsPolicy::new(7);
        // Drive the HDD read rate above 0.5 × 115 IOPS: 200 reads of SST 2
        // within one virtual second.
        for i in 0..200u64 {
            p.on_sst_read(2, Dev::Hdd, i * 4_000_000);
        }
        p.on_sst_read(2, Dev::Hdd, 1_100_000_000); // roll the window
        let v = View {
            now: 1_200_000_000,
            cfg: &h.cfg,
            fs: &h.fs,
            version: &h.version,
            wal_zones_in_use: 0,
            busy_ssts: &not_busy,
        };
        let op = p.pick_migration(&v).expect("popularity migration");
        assert_eq!(op.kind, MigrationKind::Popularity);
        assert_eq!(op.sst, 2, "hottest HDD SST");
        assert_eq!(op.to, Dev::Ssd);
        assert!(op.swap_with.is_none(), "free zones available → plain move");
    }

    #[test]
    fn popularity_swaps_when_ssd_full() {
        // 2 SSD zones, both occupied by L3 SSTs; hot L3 SST on HDD.
        let h = harness(2, &[(1, 3, Dev::Ssd), (2, 3, Dev::Ssd), (3, 3, Dev::Hdd)]);
        let mut p = HhzsPolicy::new(7);
        for i in 0..300u64 {
            p.on_sst_read(3, Dev::Hdd, i * 3_000_000);
        }
        p.on_sst_read(3, Dev::Hdd, 1_100_000_000);
        let v = View {
            now: 1_200_000_000,
            cfg: &h.cfg,
            fs: &h.fs,
            version: &h.version,
            wal_zones_in_use: 0,
            busy_ssts: &not_busy,
        };
        let op = p.pick_migration(&v).expect("swap");
        assert_eq!(op.sst, 3);
        assert!(op.swap_with.is_some());
        assert_ne!(op.swap_with.unwrap(), 3);
    }

    #[test]
    fn compaction_inputs_excluded_from_migration() {
        let h = harness(3, &[(1, 0, Dev::Ssd), (2, 3, Dev::Ssd)]);
        let mut p = HhzsPolicy::new(7);
        let v = View {
            now: 0,
            cfg: &h.cfg,
            fs: &h.fs,
            version: &h.version,
            wal_zones_in_use: 2,
            busy_ssts: &not_busy,
        };
        // SST 2 is selected by a compaction → not migratable.
        p.on_hint(
            &Hint::Compaction(CompactionHint::Start {
                job: 9,
                inputs: vec![2],
                output_level: 4,
            }),
            &v,
        );
        let op = p.pick_migration(&v);
        // Only remaining candidate is SST 1 (L0) — but L0 is below the
        // tiering level, so it is never "above tiering". The tiering level
        // is 0 here and A_0(=1) ≤ reserved(=3), so no capacity migration.
        assert!(op.is_none() || op.unwrap().sst != 2);
    }

    #[test]
    fn ablation_flags() {
        assert_eq!(HhzsPolicy::new(7).name(), "HHZS");
        assert_eq!(HhzsPolicy::placement_only(7).name(), "P");
        assert_eq!(HhzsPolicy::placement_migration(7).name(), "P+M");
        assert!(!HhzsPolicy::placement_only(7).ssd_cache_enabled());
        assert!(HhzsPolicy::new(7).ssd_cache_enabled());
    }
}
