//! Re-implementation of SpanDB's automated placement (AUTO) following the
//! paper's §4.1 description:
//!
//! * AUTO maintains a *maximum level* `M`; all LSM-tree levels `<= M` are
//!   placed on fast storage (the SSD).
//! * When the SSD write throughput is below 40% of its sequential-write
//!   bandwidth, `M` is incremented (SSD underutilized → move more levels
//!   in); above 65%, `M` is decremented.
//! * When the remaining SSD space is below 13.3% of the total, `M` is fixed
//!   at 1; below 8%, no SST data is written to the SSD at all.
//! * AUTO reserves SSD space for the WAL, as HHZS does.

use crate::config::Config;
use crate::hints::Hint;
use crate::lsm::SstId;
use crate::sim::Ns;
use crate::zone::Dev;

use super::{MigrationOp, Policy, SstOrigin, SstStats, View};

const LOW_UTIL: f64 = 0.40;
const HIGH_UTIL: f64 = 0.65;
const SPACE_PIN_M1: f64 = 0.133;
const SPACE_NO_SST: f64 = 0.08;

pub struct AutoPolicy {
    max_level: usize,
    stats: SstStats,
    /// (virtual time, cumulative SSD write bytes) of the last tick sample.
    last_sample: Option<(Ns, u64)>,
}

impl AutoPolicy {
    pub fn new() -> Self {
        AutoPolicy { max_level: 1, stats: SstStats::default(), last_sample: None }
    }

    pub fn max_level(&self) -> usize {
        self.max_level
    }

    fn remaining_space_frac(&self, view: &View) -> f64 {
        let total = view.fs.ssd.num_zones() as f64;
        let free = view.fs.ssd.empty_zone_count() as f64;
        free / total
    }
}

impl Default for AutoPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for AutoPolicy {
    fn name(&self) -> String {
        "AUTO".into()
    }

    fn reserved_pool_zones(&self, cfg: &Config) -> u32 {
        // "AUTO reserves the SSD space for the WAL, as in HHZS" (§4.1).
        cfg.geometry.wal_cache_zones
    }

    fn on_hint(&mut self, _hint: &Hint, _view: &View) {}

    fn on_sst_read(&mut self, sst: SstId, dev: Dev, now: Ns) {
        self.stats.on_read(sst, dev, now);
    }

    fn on_sst_deleted(&mut self, sst: SstId) {
        self.stats.on_deleted(sst);
    }

    fn place_sst(&mut self, level: usize, _size: u64, _origin: SstOrigin, view: &View) -> Dev {
        let frac = self.remaining_space_frac(view);
        if frac < SPACE_NO_SST {
            return Dev::Hdd;
        }
        if frac < SPACE_PIN_M1 {
            return if level <= 1 { Dev::Ssd } else { Dev::Hdd };
        }
        if level <= self.max_level {
            Dev::Ssd
        } else {
            Dev::Hdd
        }
    }

    fn pick_migration(&mut self, _view: &View) -> Option<MigrationOp> {
        None // AUTO does not migrate data between tiers
    }

    fn tick(&mut self, now: Ns, view: &View) {
        // Cumulative SSD write traffic from the device's timing server.
        // Under the shard tier this server is shared substrate-wide, so
        // the estimate would be the aggregate of all shards — AUTO is a
        // §4.1 single-engine baseline and is not used by the shard tier;
        // a per-shard monotone write counter is needed before it is.
        let written = view.fs.ssd.timer.traffic().write_bytes;
        if let Some((t0, b0)) = self.last_sample {
            let dt = now.saturating_sub(t0);
            // Tune at ~1-virtual-second granularity.
            if dt >= 1_000_000_000 {
                let bps = (written - b0) as f64 / (dt as f64 / 1e9);
                let util = bps / view.cfg.ssd.seq_write_bps;
                if util < LOW_UTIL {
                    self.max_level = (self.max_level + 1).min(view.version.num_levels() - 1);
                } else if util > HIGH_UTIL {
                    self.max_level = self.max_level.saturating_sub(1).max(1);
                }
                self.last_sample = Some((now, written));
            }
        } else {
            self.last_sample = Some((now, written));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::Version;
    use crate::zenfs::ZenFs;

    fn setup() -> (Config, ZenFs, Version) {
        let cfg = Config::tiny();
        let fs = ZenFs::new(
            cfg.geometry.ssd_zone_cap,
            20,
            cfg.geometry.hdd_zone_cap,
            64,
            cfg.ssd.clone(),
            cfg.hdd.clone(),
        );
        let version = Version::new(7, 1 << 20, 10, 4);
        (cfg, fs, version)
    }

    fn view<'a>(
        cfg: &'a Config,
        fs: &'a ZenFs,
        version: &'a Version,
        now: Ns,
        busy: &'a dyn Fn(SstId) -> bool,
    ) -> View<'a> {
        View { now, cfg, fs, version, wal_zones_in_use: 0, busy_ssts: busy }
    }

    #[test]
    fn low_utilization_raises_max_level() {
        let (cfg, fs, version) = setup();
        let busy = |_: SstId| false;
        let mut p = AutoPolicy::new();
        p.tick(0, &view(&cfg, &fs, &version, 0, &busy));
        // No SSD writes happened → 0% utilization → M goes up.
        p.tick(2_000_000_000, &view(&cfg, &fs, &version, 2_000_000_000, &busy));
        assert_eq!(p.max_level(), 2);
    }

    #[test]
    fn high_utilization_lowers_max_level() {
        let (cfg, mut fs, version) = setup();
        let mut p = AutoPolicy::new();
        p.max_level = 3;
        {
            let busy = |_: SstId| false;
            p.tick(0, &view(&cfg, &fs, &version, 0, &busy));
        }
        // Saturate the SSD for 2 virtual seconds (~100% of seq-write bw).
        let bytes = (2.0 * cfg.ssd.seq_write_bps) as u64;
        fs.charge(0, Dev::Ssd, crate::sim::AccessKind::SeqWrite, bytes);
        let busy = |_: SstId| false;
        p.tick(2_000_000_000, &view(&cfg, &fs, &version, 2_000_000_000, &busy));
        assert_eq!(p.max_level(), 2);
    }

    #[test]
    fn space_cutoffs_override_level() {
        let (cfg, mut fs, version) = setup();
        let mut p = AutoPolicy::new();
        p.max_level = 4;
        // Fill SSD zones until < 8% remain (20 zones → fewer than 2 free).
        for i in 0..19u64 {
            let data = crate::wire::WireBuf::from_bytes(&[0u8; 64]);
            fs.create_file(0, i, Dev::Ssd, &data, true).unwrap();
        }
        let busy = |_: SstId| false;
        let v = view(&cfg, &fs, &version, 0, &busy);
        assert_eq!(p.place_sst(0, 64, SstOrigin::Flush, &v), Dev::Hdd, "below 8% → no SSTs");
        // Free some zones into the 8–13.3% band → pinned at M=1.
        fs.delete_file(0).unwrap();
        fs.delete_file(1).unwrap(); // 3/20 = 15% > 13.3 → normal again
        let v = view(&cfg, &fs, &version, 0, &busy);
        assert_eq!(p.place_sst(4, 64, SstOrigin::Compaction, &v), Dev::Ssd);
    }

    #[test]
    fn pinned_band_allows_only_low_levels() {
        let (cfg, mut fs, version) = setup();
        let mut p = AutoPolicy::new();
        p.max_level = 4;
        // Leave exactly 2 of 20 zones free → 10% (between 8% and 13.3%).
        for i in 0..18u64 {
            let data = crate::wire::WireBuf::from_bytes(&[0u8; 64]);
            fs.create_file(0, i, Dev::Ssd, &data, true).unwrap();
        }
        let busy = |_: SstId| false;
        let v = view(&cfg, &fs, &version, 0, &busy);
        assert_eq!(p.place_sst(1, 64, SstOrigin::Compaction, &v), Dev::Ssd);
        assert_eq!(p.place_sst(2, 64, SstOrigin::Compaction, &v), Dev::Hdd);
    }

    #[test]
    fn never_migrates() {
        let (cfg, fs, version) = setup();
        let busy = |_: SstId| false;
        let mut p = AutoPolicy::new();
        assert!(p.pick_migration(&view(&cfg, &fs, &version, 0, &busy)).is_none());
    }
}
