//! Hints (§3.1) — the paper's core interface between the LSM-tree KV store
//! and the hybrid-zoned-storage middleware.
//!
//! Each hint is tens of bytes and is passed synchronously alongside the
//! operation it describes. The engine forwards every hint to the active
//! [`crate::policy::Policy`]; only HHZS consumes all three kinds.

use crate::lsm::SstId;
use crate::wire::WireBuf;

/// A flushing operation produced a new SST at L0 (§3.1).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FlushHint {
    pub sst: SstId,
    pub bytes: u64,
}

/// Compaction hints are issued in three phases (§3.1).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CompactionHint {
    /// Phase (i): compaction triggered — identifies the selected input SSTs
    /// and the output level they merge into.
    Start { job: u64, inputs: Vec<SstId>, output_level: usize },
    /// Phase (ii): the compaction wrote one output SST at `level`.
    OutputSst { job: u64, sst: SstId, level: usize, bytes: u64 },
    /// Phase (iii): compaction finished — identifies all generated SSTs.
    Finish { job: u64, outputs: Vec<SstId>, output_level: usize },
}

/// The in-memory block cache evicted a data block (§3.1). Identifies the
/// SST and the block's offset within it; the block contents ride along so
/// the SSD cache can admit without re-reading the HDD (§3.5 workflow
/// step 2 — admission happens at eviction time, not on the next miss).
#[derive(Clone, Debug)]
pub struct CacheEvictHint {
    pub sst: SstId,
    pub block_offset: u64,
    pub block_len: u64,
    /// The evicted block's wire-form contents (shared, not copied — the
    /// hint is passed synchronously and the SSD cache admits from this
    /// buffer).
    pub data: std::sync::Arc<WireBuf>,
}

/// Union of all hints the KV store can issue.
#[derive(Clone, Debug)]
pub enum Hint {
    Flush(FlushHint),
    Compaction(CompactionHint),
    CacheEvict(CacheEvictHint),
}

impl Hint {
    /// Approximate wire size in bytes (the paper notes hints are tens of
    /// bytes; we track this to show the overhead is negligible). A cache
    /// hint's *identity* is tens of bytes; its block payload rides along
    /// and is accounted explicitly here (§3.5 — the block would otherwise
    /// be re-read from the HDD, so the payload replaces device traffic,
    /// not hint-channel overhead).
    pub fn wire_size(&self) -> usize {
        match self {
            Hint::Flush(_) => 16,
            Hint::Compaction(CompactionHint::Start { inputs, .. }) => 24 + 8 * inputs.len(),
            Hint::Compaction(CompactionHint::OutputSst { .. }) => 32,
            Hint::Compaction(CompactionHint::Finish { outputs, .. }) => 24 + 8 * outputs.len(),
            Hint::CacheEvict(h) => 24 + h.data.len() as usize,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_sizes_are_tens_of_bytes() {
        let h = Hint::Compaction(CompactionHint::Start {
            job: 1,
            inputs: vec![1, 2, 3, 4],
            output_level: 2,
        });
        assert!(h.wire_size() < 100);
        assert!(Hint::Flush(FlushHint { sst: 9, bytes: 1 }).wire_size() < 32);
    }

    #[test]
    fn cache_hint_accounts_for_its_payload() {
        let block = std::sync::Arc::new(WireBuf::from_bytes(&[7u8; 4096]));
        let h = Hint::CacheEvict(CacheEvictHint {
            sst: 3,
            block_offset: 8192,
            block_len: block.len(),
            data: block.clone(),
        });
        assert_eq!(h.wire_size(), 24 + 4096);
        // The payload is shared, not copied, across hint clones.
        let h2 = h.clone();
        drop(h);
        assert_eq!(h2.wire_size(), 24 + 4096);
        assert_eq!(std::sync::Arc::strong_count(&block), 2);
    }
}
