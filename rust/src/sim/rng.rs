//! Deterministic xorshift64* RNG + FNV-1a hashing.
//!
//! No external RNG crate: determinism across runs is a requirement for the
//! DES (same seed → bit-identical experiment output), and the generators
//! here are exactly reproducible from the seed recorded in EXPERIMENTS.md.

/// xorshift64* — fast, decent-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero state; mix the seed through splitmix64.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        Rng { state: (z ^ (z >> 31)) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping (Lemire); bias is
        // negligible for our n ≪ 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fork an independent stream (for per-client RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

/// FNV-1a 64-bit hash — used to scatter YCSB item numbers over the keyspace
/// so Zipf-hot keys land in distinct SSTs (matches YCSB's hashed insert
/// order, which is what makes O4's "hot SSTs" phenomenon appear).
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// FNV-1a over a u64 (item number) without materializing bytes.
#[inline]
pub fn fnv1a_u64(v: u64) -> u64 {
    fnv1a(&v.to_le_bytes())
}

/// 32-bit key fingerprint used by the Bloom filters (both the Rust-native
/// and the XLA/Pallas implementations hash this same fingerprint).
#[inline]
pub fn fingerprint32(key: &[u8]) -> u32 {
    let h = fnv1a(key);
    ((h >> 32) ^ h) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Rng::new(1);
        for n in [1u64, 2, 7, 1000, 1 << 40] {
            for _ in 0..200 {
                assert!(r.next_below(n) < n);
            }
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fnv_known_vector() {
        // FNV-1a("a") = 0xaf63dc4c8601ec8c
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn fingerprint_spreads() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            seen.insert(fingerprint32(&i.to_be_bytes()));
        }
        assert!(seen.len() > 9_990);
    }
}
