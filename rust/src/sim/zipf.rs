//! YCSB-style key choosers: Zipfian, Latest, Uniform.
//!
//! The Zipfian generator follows the YCSB / Gray et al. "quick zipf"
//! algorithm: O(1) sampling after an O(n)-ish zeta precomputation, with
//! incremental zeta extension when the item count grows (needed by the
//! Latest distribution during loads).

use super::rng::Rng;

/// A distribution over item indices `[0, n)`.
pub trait KeyChooser {
    /// Draw an item index.
    fn next(&mut self, rng: &mut Rng) -> u64;
    /// Number of items covered.
    fn n(&self) -> u64;
}

/// Uniform over `[0, n)`.
#[derive(Clone, Debug)]
pub struct Uniform {
    n: u64,
}

impl Uniform {
    pub fn new(n: u64) -> Self {
        assert!(n > 0);
        Uniform { n }
    }
}

impl KeyChooser for Uniform {
    fn next(&mut self, rng: &mut Rng) -> u64 {
        rng.next_below(self.n)
    }
    fn n(&self) -> u64 {
        self.n
    }
}

/// Zipfian over `[0, n)` with exponent `theta` (the paper's α).
///
/// Item 0 is the most popular. Callers that want popularity scattered over
/// the keyspace (as YCSB does) hash the returned rank.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    zeta2: f64,
    eta: f64,
}

impl Zipf {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        assert!(theta > 0.0 && theta < 10.0 && (theta - 1.0).abs() > 1e-9);
        let zetan = Self::zeta_static(0, n, theta, 0.0);
        let zeta2 = Self::zeta_static(0, 2, theta, 0.0);
        let mut z = Zipf { n, theta, alpha: 1.0 / (1.0 - theta), zetan, zeta2, eta: 0.0 };
        z.update_eta();
        z
    }

    fn update_eta(&mut self) {
        self.eta = (1.0 - (2.0 / self.n as f64).powf(1.0 - self.theta))
            / (1.0 - self.zeta2 / self.zetan);
    }

    fn zeta_static(from: u64, to: u64, theta: f64, base: f64) -> f64 {
        let mut sum = base;
        for i in from..to {
            sum += 1.0 / ((i + 1) as f64).powf(theta);
        }
        sum
    }

    /// Extend the range to `n2 > n` incrementally (Latest distribution).
    pub fn grow(&mut self, n2: u64) {
        if n2 <= self.n {
            return;
        }
        self.zetan = Self::zeta_static(self.n, n2, self.theta, self.zetan);
        self.n = n2;
        self.update_eta();
    }
}

impl KeyChooser for Zipf {
    fn next(&mut self, rng: &mut Rng) -> u64 {
        let u = rng.next_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let v = ((self.eta * u) - self.eta + 1.0).powf(self.alpha);
        let idx = (self.n as f64 * v) as u64;
        idx.min(self.n - 1)
    }
    fn n(&self) -> u64 {
        self.n
    }
}

/// YCSB "latest" distribution: Zipfian over recency — item `n-1-z` where
/// `z` is Zipfian-distributed, so the most recently inserted keys are the
/// most popular (workload D).
#[derive(Clone, Debug)]
pub struct Latest {
    zipf: Zipf,
}

impl Latest {
    pub fn new(n: u64, theta: f64) -> Self {
        Latest { zipf: Zipf::new(n, theta) }
    }
    /// Account for a newly inserted item.
    pub fn grow(&mut self, n2: u64) {
        self.zipf.grow(n2);
    }
}

impl KeyChooser for Latest {
    fn next(&mut self, rng: &mut Rng) -> u64 {
        let z = self.zipf.next(rng);
        self.zipf.n() - 1 - z
    }
    fn n(&self) -> u64 {
        self.zipf.n()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head_mass(theta: f64, n: u64, draws: usize, head: u64) -> f64 {
        let mut z = Zipf::new(n, theta);
        let mut rng = Rng::new(11);
        let mut hits = 0usize;
        for _ in 0..draws {
            if z.next(&mut rng) < head {
                hits += 1;
            }
        }
        hits as f64 / draws as f64
    }

    #[test]
    fn zipf_in_range() {
        let mut z = Zipf::new(1000, 0.9);
        let mut rng = Rng::new(5);
        for _ in 0..10_000 {
            assert!(z.next(&mut rng) < 1000);
        }
    }

    #[test]
    fn higher_theta_is_more_skewed() {
        let m09 = head_mass(0.9, 100_000, 50_000, 100);
        let m12 = head_mass(1.2, 100_000, 50_000, 100);
        assert!(m12 > m09 + 0.1, "m09={m09} m12={m12}");
    }

    #[test]
    fn zipf_head_mass_roughly_theoretical() {
        // For theta=0.99, n=1000: P(top-10) ≈ zeta_10/zeta_1000.
        let theta = 0.99;
        let n = 1000u64;
        let z10 = Zipf::zeta_static(0, 10, theta, 0.0);
        let zn = Zipf::zeta_static(0, n, theta, 0.0);
        let expect = z10 / zn;
        let got = head_mass(theta, n, 200_000, 10);
        assert!((got - expect).abs() < 0.03, "got={got} expect={expect}");
    }

    #[test]
    fn latest_prefers_recent() {
        let mut l = Latest::new(10_000, 0.9);
        let mut rng = Rng::new(3);
        let mut recent = 0;
        for _ in 0..10_000 {
            if l.next(&mut rng) >= 9_000 {
                recent += 1;
            }
        }
        assert!(recent > 6_000, "recent={recent}");
    }

    #[test]
    fn grow_extends_range() {
        let mut z = Zipf::new(10, 0.9);
        z.grow(1000);
        assert_eq!(z.n(), 1000);
        let mut rng = Rng::new(1);
        let saw_big = (0..20_000).any(|_| z.next(&mut rng) >= 10);
        assert!(saw_big);
    }

    #[test]
    fn uniform_covers_range() {
        let mut u = Uniform::new(16);
        let mut rng = Rng::new(2);
        let mut seen = [false; 16];
        for _ in 0..1000 {
            seen[u.next(&mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
