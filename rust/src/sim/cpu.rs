//! Background-CPU service model: ONE pool of `bg_threads` slots shared by
//! every engine on the substrate.
//!
//! The paper's testbed runs flush and compaction over a single host thread
//! pool (§4.1: 12 threads); sharded runs used to give every shard a
//! private copy of that pool, so a 4-shard simulation modeled 48 phantom
//! threads. This mirrors the [`super::device::SharedTimer`] pattern for the
//! last unshared resource: the shard layer points every engine at one
//! `Rc<RefCell<CpuPool>>`, and acquire/release happen in the frontend's
//! global `(time, seq)` event order, so background-CPU contention is as
//! real (and as measurable — [`crate::metrics::Metrics::cpu_wait`]) as
//! device-queue contention.
//!
//! Admission rules, all enforced **pool-wide**:
//!
//! * slots-in-use never exceeds `bg_threads`;
//! * the flush reservation keeps `min(2, bg_threads - 1)` slots that
//!   compactions may not take (RocksDB's separate flush pool), preserving
//!   the `bg_threads <= 2` anti-livelock invariant globally: every
//!   non-empty pool keeps at least one compaction-eligible slot;
//! * flush priority: a compaction grant must leave at least one free slot
//!   per *waiting* flush, so a shard finishing a job cannot steal the slot
//!   another shard's ready flush is blocked on;
//! * under [`CpuSched::Fair`], a per-shard cap of
//!   `ceil(bg_threads / shards)` bounds how many compaction slots one
//!   shard may hold; [`CpuSched::WorkConserving`] is free-for-all.
//!
//! With a single shard every rule degenerates to the seed engine's
//! `busy_threads` arithmetic — that identity is what keeps `shards = 1`
//! bit-for-bit (pinned by `tests/integration.rs` and `tests/frontend.rs`).

use crate::config::{CpuSched, WakePolicy};
use crate::sim::Ns;

/// Stall-risk scores are clamped here before aging is added, so a waiter
/// aged past `RISK_MAX / AGE_STEP` wake rounds outranks ANY fresh waiter
/// regardless of its live pressure — the bounded-wait / no-starvation
/// guarantee of [`WakePolicy::StallAware`].
pub const RISK_MAX: u64 = 1024;
/// Priority added per wake round a shard keeps waiting.
pub const AGE_STEP: u64 = 256;

/// The effective wake priority of a waiter: live stall risk (clamped)
/// plus the aging term. Public so the trace checker replays the exact
/// ordering the pool used (mirrored like [`crate::trace`]'s
/// `flush_reserved`).
pub fn effective_priority(risk: u64, age: u64) -> u64 {
    risk.min(RISK_MAX) + age.saturating_mul(AGE_STEP)
}

/// Copyable snapshot of the pool's bookkeeping, for tests and reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct CpuPoolStats {
    pub total: usize,
    pub in_use: usize,
    /// High-water mark of slots-in-use — `<= total` at every DES event is
    /// the global-bound invariant `tests/cpu_pool.rs` pins.
    pub high_water: usize,
    pub acquires: u64,
    pub releases: u64,
    /// Times a compaction grant left a waiting flush without a free slot.
    /// Unreachable by construction; counted (not just debug-asserted) so
    /// the property suite can pin it at zero in release builds too.
    pub flush_priority_violations: u64,
    /// Wake rounds where the stall-aware policy put a different shard at
    /// the head than FIFO would have — slots redirected toward the shard
    /// closest to a write stall. Always 0 under [`WakePolicy::Fifo`].
    pub stalls_avoided: u64,
}

/// One waiter of the most recent stall-aware wake round, in offer order —
/// what the trace layer serializes so `hhzs trace check` can replay the
/// scheduler's decision.
#[derive(Clone, Copy, Debug)]
pub struct WakeSlot {
    pub shard: usize,
    /// Flush waiter (the hard-priority class) vs compaction waiter.
    pub flush: bool,
    pub risk: u64,
    pub age: u64,
}

/// The shared pool of background-CPU slots. Time-free by design: the DES
/// clock lives with the callers; the pool only arbitrates *who may start*,
/// and engines measure how long a ready job waited.
#[derive(Debug)]
pub struct CpuPool {
    total: usize,
    sched: CpuSched,
    in_use: usize,
    /// Slots held per shard (`len` = shard count of the pool's domain).
    per_shard: Vec<usize>,
    /// Compaction slots held per shard — the fair cap binds on THESE
    /// only, so an active flush never shrinks its shard's compaction
    /// entitlement (flushes are exempt from the cap by design).
    per_shard_comp: Vec<usize>,
    /// Shards with a ready flush that was denied a slot.
    flush_waiter: Vec<bool>,
    /// Shards with an eligible compaction that was denied a slot.
    comp_waiter: Vec<bool>,
    /// Set on release while any waiter is registered; the frontend drains
    /// it to re-poll starved shards at the release's event time.
    wake_pending: bool,
    /// Wake-order policy for [`CpuPool::take_wake_list`].
    wake: WakePolicy,
    /// Live per-shard stall-risk scores, pushed by the engines (L0
    /// pressure, memtable fill, parked writers, zone-reset debt).
    risk: Vec<u64>,
    /// Wake rounds each registered waiter has been offered without
    /// acquiring — the no-starvation aging term. Reset when the shard
    /// acquires a slot or stops waiting.
    age: Vec<u64>,
    /// Shards put at the head of a wake round ahead of the FIFO order;
    /// consumed by the engine at acquire time to attribute
    /// `Metrics::stalls_avoided`.
    promoted: Vec<bool>,
    /// Monotone id of stall-aware wake rounds (trace grouping).
    wake_rounds: u64,
    /// The most recent stall-aware wake round, in offer order (empty
    /// under FIFO — FIFO traces stay byte-identical).
    last_wake: Vec<WakeSlot>,
    stats: CpuPoolStats,
}

impl CpuPool {
    pub fn new(total: usize, shards: usize, sched: CpuSched) -> Self {
        assert!(shards >= 1, "a CPU pool needs at least one shard");
        CpuPool {
            total,
            sched,
            in_use: 0,
            per_shard: vec![0; shards],
            per_shard_comp: vec![0; shards],
            flush_waiter: vec![false; shards],
            comp_waiter: vec![false; shards],
            wake_pending: false,
            wake: WakePolicy::Fifo,
            risk: vec![0; shards],
            age: vec![0; shards],
            promoted: vec![false; shards],
            wake_rounds: 0,
            last_wake: Vec::new(),
            stats: CpuPoolStats { total, ..Default::default() },
        }
    }

    /// Rebind the pool to a sharded domain (called by the shard layer
    /// before any background work exists).
    pub fn configure(&mut self, shards: usize, sched: CpuSched, wake: WakePolicy) {
        assert!(shards >= 1);
        assert_eq!(self.in_use, 0, "cannot reshape a pool with slots in use");
        self.sched = sched;
        self.wake = wake;
        self.per_shard = vec![0; shards];
        self.per_shard_comp = vec![0; shards];
        self.flush_waiter = vec![false; shards];
        self.comp_waiter = vec![false; shards];
        self.risk = vec![0; shards];
        self.age = vec![0; shards];
        self.promoted = vec![false; shards];
        self.last_wake.clear();
    }

    /// Set the wake-order policy without reshaping (standalone engines).
    pub fn set_wake(&mut self, wake: WakePolicy) {
        self.wake = wake;
    }

    pub fn wake_policy(&self) -> WakePolicy {
        self.wake
    }

    /// Push one shard's live stall-risk score (engines call this whenever
    /// their pressure signals change; time-free, so FIFO timelines are
    /// untouched).
    pub fn set_stall_risk(&mut self, shard: usize, score: u64) {
        self.risk[shard] = score;
    }

    pub fn stall_risk(&self, shard: usize) -> u64 {
        self.risk[shard]
    }

    /// Was this shard promoted past the FIFO head since its last acquire?
    /// Consumed (cleared) by the engine when the promoted shard actually
    /// takes the slot, to attribute `Metrics::stalls_avoided`.
    pub fn take_promoted(&mut self, shard: usize) -> bool {
        std::mem::replace(&mut self.promoted[shard], false)
    }

    /// The most recent stall-aware wake round in offer order, with the
    /// round id (for trace emission). Empty under FIFO.
    pub fn last_wake(&self) -> (u64, &[WakeSlot]) {
        (self.wake_rounds, &self.last_wake)
    }

    /// Slots compactions may never take (RocksDB's flush pool), shrunk so
    /// every non-empty pool keeps ≥ 1 compaction-eligible slot — the
    /// `bg_threads <= 2` anti-livelock invariant, now pool-wide.
    pub fn flush_reserved(&self) -> usize {
        match self.total {
            0 | 1 => 0,
            t => 2.min(t - 1),
        }
    }

    /// Per-shard ceiling on *compaction* slots.
    pub fn compaction_cap(&self) -> usize {
        match self.sched {
            CpuSched::WorkConserving => self.total,
            CpuSched::Fair => self.total.div_ceil(self.per_shard.len()).max(1),
        }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    pub fn shard_in_use(&self, shard: usize) -> usize {
        self.per_shard[shard]
    }

    /// Compaction slots a shard currently holds (what the fair cap binds).
    pub fn shard_compactions(&self, shard: usize) -> usize {
        self.per_shard_comp[shard]
    }

    /// Shards whose ready flush is currently blocked on a slot.
    pub fn waiting_flushes(&self) -> usize {
        self.flush_waiter.iter().filter(|&&w| w).count()
    }

    /// Flushes only contend for the global slot count — never the fair cap
    /// and never the reservation (the reservation exists *for* them).
    pub fn can_admit_flush(&self) -> bool {
        self.in_use < self.total
    }

    /// Compaction admission: global count behind the flush reservation,
    /// the per-shard cap, and first claim of free slots by waiting flushes.
    pub fn can_admit_compaction(&self, shard: usize) -> bool {
        self.in_use + self.flush_reserved() < self.total
            && self.per_shard_comp[shard] < self.compaction_cap()
            && self.waiting_flushes() + 1 <= self.total - self.in_use
    }

    fn grab(&mut self, shard: usize) {
        // A granted slot ends the shard's waiting episode.
        self.age[shard] = 0;
        self.in_use += 1;
        self.per_shard[shard] += 1;
        self.stats.acquires += 1;
        self.stats.in_use = self.in_use;
        self.stats.high_water = self.stats.high_water.max(self.in_use);
        debug_assert!(self.in_use <= self.total, "slot bound violated");
    }

    /// Take a slot for a flush. On denial the shard is registered as a
    /// flush waiter — the claim that blocks compactions from stealing the
    /// next freed slot.
    pub fn acquire_flush(&mut self, shard: usize) -> bool {
        if self.can_admit_flush() {
            self.flush_waiter[shard] = false;
            self.grab(shard);
            true
        } else {
            self.flush_waiter[shard] = true;
            false
        }
    }

    /// Register a ready-but-denied flush without attempting the grab.
    pub fn flush_denied(&mut self, shard: usize) {
        self.flush_waiter[shard] = true;
    }

    pub fn clear_flush_waiter(&mut self, shard: usize) {
        self.flush_waiter[shard] = false;
        if !self.comp_waiter[shard] {
            // Aging measures a *continuous* waiting episode only.
            self.age[shard] = 0;
        }
    }

    /// Take a slot for a compaction, subject to every pool-wide rule.
    pub fn acquire_compaction(&mut self, shard: usize) -> bool {
        if !self.can_admit_compaction(shard) {
            return false;
        }
        self.comp_waiter[shard] = false;
        self.per_shard_comp[shard] += 1;
        self.grab(shard);
        if self.waiting_flushes() > self.total - self.in_use {
            // Unreachable: can_admit_compaction reserves a free slot per
            // waiting flush. Counted so tests pin it.
            self.stats.flush_priority_violations += 1;
        }
        true
    }

    /// Is this shard currently claiming a flush wake-up? (Read by the
    /// tracer so "waiter cleared" events are emitted only on transitions.)
    pub fn is_flush_waiter(&self, shard: usize) -> bool {
        self.flush_waiter[shard]
    }

    /// Mark/unmark a shard as having an eligible compaction starved of CPU.
    pub fn set_comp_waiter(&mut self, shard: usize, waiting: bool) {
        self.comp_waiter[shard] = waiting;
        if !waiting && !self.flush_waiter[shard] {
            self.age[shard] = 0;
        }
    }

    /// Is this shard currently claiming a compaction wake-up?
    pub fn is_comp_waiter(&self, shard: usize) -> bool {
        self.comp_waiter[shard]
    }

    /// Return a flush's slot. Flags a wake if any shard is starved, so
    /// the event loop re-polls it at this release's event time.
    pub fn release_flush(&mut self, shard: usize) {
        self.release(shard);
    }

    /// Return a compaction's slot (also credits the shard's fair cap).
    pub fn release_compaction(&mut self, shard: usize) {
        debug_assert!(self.per_shard_comp[shard] > 0, "compaction release without acquire");
        self.per_shard_comp[shard] -= 1;
        self.release(shard);
    }

    fn release(&mut self, shard: usize) {
        debug_assert!(self.in_use > 0 && self.per_shard[shard] > 0, "release without acquire");
        self.in_use -= 1;
        self.per_shard[shard] -= 1;
        self.stats.releases += 1;
        self.stats.in_use = self.in_use;
        if self.flush_waiter.iter().any(|&w| w) || self.comp_waiter.iter().any(|&w| w) {
            self.wake_pending = true;
        }
    }

    pub fn wake_pending(&self) -> bool {
        self.wake_pending
    }

    /// Drain the wake flag and list the starved shards, flush waiters
    /// first so the re-poll order respects flush priority
    /// deterministically. Waiter flags stay set — a re-poll that is denied
    /// again keeps its claim.
    ///
    /// Within each class the order is the wake policy's: FIFO keeps the
    /// PR 4 shard order (bit-identical goldens); stall-aware sorts by
    /// [`effective_priority`] (clamped live risk + aging) descending, with
    /// the shard index as the deterministic tie-break — so the next freed
    /// slot is offered to the shard closest to a write stall, and any
    /// waiter's wait is bounded by `RISK_MAX / AGE_STEP` wake rounds
    /// against fresh competitors (no starvation). Flush-before-compaction
    /// and the flush reservation stay hard constraints under both.
    pub fn take_wake_list(&mut self) -> Vec<usize> {
        self.wake_pending = false;
        let n = self.per_shard.len();
        let mut out: Vec<usize> = (0..n).filter(|&s| self.flush_waiter[s]).collect();
        let nflush = out.len();
        out.extend((0..n).filter(|&s| self.comp_waiter[s] && !self.flush_waiter[s]));
        if self.wake == WakePolicy::Fifo || out.is_empty() {
            return out;
        }
        let fifo_head = out[0];
        {
            let (risk, age) = (&self.risk, &self.age);
            let prio =
                |s: &usize| (std::cmp::Reverse(effective_priority(risk[*s], age[*s])), *s);
            out[..nflush].sort_by_key(prio);
            out[nflush..].sort_by_key(prio);
        }
        if out[0] != fifo_head {
            // A higher-risk shard jumped the FIFO head: the slot goes to
            // the shard most likely to stall instead.
            self.promoted[out[0]] = true;
            self.stats.stalls_avoided += 1;
        }
        self.wake_rounds += 1;
        self.last_wake.clear();
        for (i, &s) in out.iter().enumerate() {
            self.last_wake.push(WakeSlot {
                shard: s,
                flush: i < nflush,
                risk: self.risk[s],
                age: self.age[s],
            });
        }
        // Every offered-but-still-waiting shard ages one round; ages reset
        // on acquire or when the shard stops waiting.
        for &s in &out {
            self.age[s] += 1;
        }
        out
    }

    pub fn stats(&self) -> CpuPoolStats {
        self.stats
    }

    /// Drop one shard's scheduler claims (risk, age, promotion) — the
    /// crash-restart unwind, symmetric with the waiter-flag clearing the
    /// engine already does. Slots themselves are released per job by
    /// `crash_volatile`.
    pub fn reset_shard_sched_state(&mut self, shard: usize) {
        self.risk[shard] = 0;
        self.age[shard] = 0;
        self.promoted[shard] = false;
    }
}

/// The foreground-CPU slot pool: per-op `CPU_*_NS` costs are charged
/// against `fg_threads` slots in the callers' global `(time, seq)` event
/// order, so saturating closed-loop load queues on host CPU exactly like
/// it queues on the device FIFOs. Time-indexed rather than span-based —
/// a charge occupies `[start, start + cost)` of the least-loaded slot and
/// needs no explicit release (and therefore no crash unwind: occupancy
/// decays with virtual time).
///
/// With zero threads the pool is disabled and `charge` is the identity
/// (`start = now`, `wait = 0`) — bit-for-bit the seed's contention-free
/// arithmetic, which is what keeps the committed goldens at
/// `fg_threads = 0`.
#[derive(Debug, Clone)]
pub struct FgPool {
    /// Virtual time each slot is busy until. Empty = disabled.
    busy_until: Vec<Ns>,
}

impl FgPool {
    pub fn new(threads: usize) -> Self {
        FgPool { busy_until: vec![0; threads] }
    }

    pub fn threads(&self) -> usize {
        self.busy_until.len()
    }

    pub fn is_enabled(&self) -> bool {
        !self.busy_until.is_empty()
    }

    /// Charge `cost` ns of foreground CPU issued at `now`; returns
    /// `(start, wait)` where `start = max(now, earliest free slot)` and
    /// the chosen slot becomes busy until `start + cost`.
    pub fn charge(&mut self, now: Ns, cost: Ns) -> (Ns, Ns) {
        if self.busy_until.is_empty() {
            return (now, 0);
        }
        let slot = self
            .busy_until
            .iter()
            .enumerate()
            .min_by_key(|&(i, &b)| (b, i))
            .map(|(i, _)| i)
            .unwrap();
        let start = now.max(self.busy_until[slot]);
        self.busy_until[slot] = start + cost;
        (start, start - now)
    }

    /// Slots still busy strictly after `t` (tests / occupancy probes).
    pub fn busy_at(&self, t: Ns) -> usize {
        self.busy_until.iter().filter(|&&b| b > t).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_pool_matches_seed_arithmetic() {
        // total = 12, reserved = 2: flush admitted while in_use < 12,
        // compaction while in_use < 10 — exactly the seed engine's
        // busy_threads checks.
        let mut p = CpuPool::new(12, 1, CpuSched::WorkConserving);
        for _ in 0..10 {
            assert!(p.acquire_compaction(0));
        }
        assert!(!p.can_admit_compaction(0), "reservation must hold the last 2 slots");
        assert!(p.acquire_flush(0));
        assert!(p.acquire_flush(0));
        assert!(!p.acquire_flush(0), "pool exhausted");
        assert_eq!(p.stats().high_water, 12);
        p.release_flush(0);
        p.release_flush(0);
        for _ in 0..10 {
            p.release_compaction(0);
        }
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.shard_compactions(0), 0);
        assert_eq!(p.stats().acquires, p.stats().releases);
    }

    #[test]
    fn tiny_pools_keep_a_compaction_slot() {
        // The anti-livelock invariant, pool-wide: reserved = 0 at 1 thread,
        // 1 at 2 threads.
        let p1 = CpuPool::new(1, 4, CpuSched::WorkConserving);
        assert_eq!(p1.flush_reserved(), 0);
        assert!(p1.can_admit_compaction(3));
        let p2 = CpuPool::new(2, 4, CpuSched::WorkConserving);
        assert_eq!(p2.flush_reserved(), 1);
        assert!(p2.can_admit_compaction(0));
    }

    #[test]
    fn waiting_flush_blocks_compaction_from_stealing_the_freed_slot() {
        let mut p = CpuPool::new(1, 2, CpuSched::WorkConserving);
        assert!(p.acquire_compaction(0));
        // Shard 1's flush is ready but denied → registered waiter.
        assert!(!p.acquire_flush(1));
        p.release_compaction(0);
        assert!(p.wake_pending(), "release with waiters must request a wake");
        assert_eq!(p.take_wake_list(), vec![1], "the starved shard gets the wake");
        // Shard 0 may NOT grab the freed slot for another compaction: the
        // waiting flush has first claim.
        assert!(!p.can_admit_compaction(0));
        assert!(p.acquire_flush(1));
        assert_eq!(p.waiting_flushes(), 0, "the claim clears on grant");
        assert_eq!(p.stats().flush_priority_violations, 0);
    }

    #[test]
    fn fair_cap_bounds_one_shards_compactions_but_not_flushes() {
        let mut p = CpuPool::new(8, 2, CpuSched::Fair);
        assert_eq!(p.compaction_cap(), 4);
        // An active flush must NOT shrink the shard's compaction
        // entitlement: the cap binds on compaction slots only.
        assert!(p.acquire_flush(0));
        for _ in 0..3 {
            assert!(p.acquire_compaction(0));
        }
        // 1 flush + 3 compactions held: a 4th compaction must still admit
        // (with a cap on total held slots this would wrongly be denied).
        assert!(p.can_admit_compaction(0), "flush slot must not count against the cap");
        assert!(p.acquire_compaction(0));
        assert_eq!(p.shard_compactions(0), 4);
        assert!(!p.can_admit_compaction(0), "fair cap reached");
        assert!(p.can_admit_compaction(1), "the other shard still admits");
        // Flushes ignore the cap entirely.
        assert!(p.acquire_flush(0));
        assert_eq!(p.in_use(), 6);
    }

    #[test]
    fn reshaping_an_idle_pool() {
        let mut p = CpuPool::new(3, 1, CpuSched::WorkConserving);
        p.configure(4, CpuSched::Fair, WakePolicy::Fifo);
        assert_eq!(p.compaction_cap(), 1);
        assert!(p.acquire_compaction(3));
        p.release_compaction(3);
    }

    #[test]
    fn stall_aware_wakes_the_highest_risk_waiter_first() {
        let mut p = CpuPool::new(1, 4, CpuSched::WorkConserving);
        p.configure(4, CpuSched::WorkConserving, WakePolicy::StallAware);
        p.set_stall_risk(1, 100);
        p.set_stall_risk(3, 900);
        assert!(p.acquire_compaction(0));
        p.set_comp_waiter(1, true);
        p.set_comp_waiter(3, true);
        p.release_compaction(0);
        assert!(p.wake_pending());
        // FIFO would offer shard 1 first; stall-aware promotes shard 3.
        assert_eq!(p.take_wake_list(), vec![3, 1]);
        assert_eq!(p.stats().stalls_avoided, 1);
        assert!(p.take_promoted(3));
        assert!(!p.take_promoted(3), "promotion is consumed once");
        assert!(!p.take_promoted(1));
        let (round, slots) = p.last_wake();
        assert_eq!(round, 1);
        assert_eq!(slots.len(), 2);
        assert_eq!((slots[0].shard, slots[0].risk), (3, 900));
    }

    #[test]
    fn stall_aware_keeps_flush_class_ahead_of_any_compaction_risk() {
        let mut p = CpuPool::new(1, 3, CpuSched::WorkConserving);
        p.configure(3, CpuSched::WorkConserving, WakePolicy::StallAware);
        assert!(p.acquire_compaction(0));
        // Shard 2's compaction has sky-high risk; shard 1 has a waiting
        // FLUSH with zero risk — the flush class still comes first.
        p.set_stall_risk(2, u64::MAX);
        assert!(!p.acquire_flush(1));
        p.set_comp_waiter(2, true);
        p.release_compaction(0);
        assert_eq!(p.take_wake_list(), vec![1, 2]);
    }

    #[test]
    fn aging_outranks_any_fresh_risk_after_bounded_rounds() {
        let mut p = CpuPool::new(1, 2, CpuSched::WorkConserving);
        p.configure(2, CpuSched::WorkConserving, WakePolicy::StallAware);
        assert!(p.acquire_compaction(0));
        p.set_comp_waiter(1, true);
        p.set_stall_risk(1, 0);
        // Shard 1 keeps being offered and re-denied; a fresh max-risk
        // competitor (shard 0) reappears every round and takes the slot.
        // Within RISK_MAX / AGE_STEP + 1 rounds (clamp + the shard-index
        // tie-break) shard 1 must reach the head anyway.
        let bound = (RISK_MAX / AGE_STEP) as usize + 2;
        let mut won = false;
        for _ in 0..bound {
            p.set_comp_waiter(0, true);
            p.set_stall_risk(0, RISK_MAX * 100); // clamped to RISK_MAX
            p.release_compaction(0);
            let list = p.take_wake_list();
            if list[0] == 1 {
                won = true;
                break;
            }
            // The fresh competitor wins the round and holds the slot
            // again (acquire resets its age; shard 1 keeps aging).
            assert!(p.acquire_compaction(0));
        }
        assert!(won, "aging must bound the wait to {bound} rounds");
    }

    #[test]
    fn uniform_priorities_reduce_to_fifo_order() {
        // The pool-level half of the fifo-identity pin: zero risk and
        // equal ages sort to shard order in both classes.
        let mut fifo = CpuPool::new(1, 4, CpuSched::WorkConserving);
        fifo.configure(4, CpuSched::WorkConserving, WakePolicy::Fifo);
        let mut sa = CpuPool::new(1, 4, CpuSched::WorkConserving);
        sa.configure(4, CpuSched::WorkConserving, WakePolicy::StallAware);
        for p in [&mut fifo, &mut sa] {
            assert!(p.acquire_compaction(0));
            assert!(!p.acquire_flush(2));
            p.set_comp_waiter(1, true);
            p.set_comp_waiter(3, true);
            p.release_compaction(0);
        }
        assert_eq!(fifo.take_wake_list(), sa.take_wake_list());
        assert_eq!(sa.stats().stalls_avoided, 0, "no promotion under uniform priority");
    }

    #[test]
    fn fg_pool_queues_at_saturation_and_is_identity_when_disabled() {
        let mut off = FgPool::new(0);
        assert!(!off.is_enabled());
        assert_eq!(off.charge(5_000, 1_000), (5_000, 0), "disabled pool is the seed arithmetic");
        let mut p = FgPool::new(2);
        // Three simultaneous 1000ns charges on 2 slots: the third waits.
        assert_eq!(p.charge(0, 1_000), (0, 0));
        assert_eq!(p.charge(0, 1_000), (0, 0));
        assert_eq!(p.charge(0, 1_000), (1_000, 1_000));
        assert_eq!(p.busy_at(500), 2);
        assert_eq!(p.busy_at(1_500), 1);
        assert_eq!(p.busy_at(2_000), 0);
        // A later charge after the backlog drains starts immediately.
        assert_eq!(p.charge(10_000, 500), (10_000, 0));
    }

    #[test]
    fn crash_unwind_clears_risk_age_and_promotion() {
        let mut p = CpuPool::new(1, 2, CpuSched::WorkConserving);
        p.configure(2, CpuSched::WorkConserving, WakePolicy::StallAware);
        assert!(p.acquire_compaction(0));
        p.set_stall_risk(1, 700);
        p.set_comp_waiter(1, true);
        p.release_compaction(0);
        let _ = p.take_wake_list();
        assert!(p.take_promoted(1) || p.stall_risk(1) == 700);
        p.set_stall_risk(1, 700);
        p.reset_shard_sched_state(1);
        assert_eq!(p.stall_risk(1), 0);
        assert!(!p.take_promoted(1));
    }
}
