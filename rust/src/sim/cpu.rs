//! Background-CPU service model: ONE pool of `bg_threads` slots shared by
//! every engine on the substrate.
//!
//! The paper's testbed runs flush and compaction over a single host thread
//! pool (§4.1: 12 threads); sharded runs used to give every shard a
//! private copy of that pool, so a 4-shard simulation modeled 48 phantom
//! threads. This mirrors the [`super::device::SharedTimer`] pattern for the
//! last unshared resource: the shard layer points every engine at one
//! `Rc<RefCell<CpuPool>>`, and acquire/release happen in the frontend's
//! global `(time, seq)` event order, so background-CPU contention is as
//! real (and as measurable — [`crate::metrics::Metrics::cpu_wait`]) as
//! device-queue contention.
//!
//! Admission rules, all enforced **pool-wide**:
//!
//! * slots-in-use never exceeds `bg_threads`;
//! * the flush reservation keeps `min(2, bg_threads - 1)` slots that
//!   compactions may not take (RocksDB's separate flush pool), preserving
//!   the `bg_threads <= 2` anti-livelock invariant globally: every
//!   non-empty pool keeps at least one compaction-eligible slot;
//! * flush priority: a compaction grant must leave at least one free slot
//!   per *waiting* flush, so a shard finishing a job cannot steal the slot
//!   another shard's ready flush is blocked on;
//! * under [`CpuSched::Fair`], a per-shard cap of
//!   `ceil(bg_threads / shards)` bounds how many compaction slots one
//!   shard may hold; [`CpuSched::WorkConserving`] is free-for-all.
//!
//! With a single shard every rule degenerates to the seed engine's
//! `busy_threads` arithmetic — that identity is what keeps `shards = 1`
//! bit-for-bit (pinned by `tests/integration.rs` and `tests/frontend.rs`).

use crate::config::CpuSched;

/// Copyable snapshot of the pool's bookkeeping, for tests and reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct CpuPoolStats {
    pub total: usize,
    pub in_use: usize,
    /// High-water mark of slots-in-use — `<= total` at every DES event is
    /// the global-bound invariant `tests/cpu_pool.rs` pins.
    pub high_water: usize,
    pub acquires: u64,
    pub releases: u64,
    /// Times a compaction grant left a waiting flush without a free slot.
    /// Unreachable by construction; counted (not just debug-asserted) so
    /// the property suite can pin it at zero in release builds too.
    pub flush_priority_violations: u64,
}

/// The shared pool of background-CPU slots. Time-free by design: the DES
/// clock lives with the callers; the pool only arbitrates *who may start*,
/// and engines measure how long a ready job waited.
#[derive(Debug)]
pub struct CpuPool {
    total: usize,
    sched: CpuSched,
    in_use: usize,
    /// Slots held per shard (`len` = shard count of the pool's domain).
    per_shard: Vec<usize>,
    /// Compaction slots held per shard — the fair cap binds on THESE
    /// only, so an active flush never shrinks its shard's compaction
    /// entitlement (flushes are exempt from the cap by design).
    per_shard_comp: Vec<usize>,
    /// Shards with a ready flush that was denied a slot.
    flush_waiter: Vec<bool>,
    /// Shards with an eligible compaction that was denied a slot.
    comp_waiter: Vec<bool>,
    /// Set on release while any waiter is registered; the frontend drains
    /// it to re-poll starved shards at the release's event time.
    wake_pending: bool,
    stats: CpuPoolStats,
}

impl CpuPool {
    pub fn new(total: usize, shards: usize, sched: CpuSched) -> Self {
        assert!(shards >= 1, "a CPU pool needs at least one shard");
        CpuPool {
            total,
            sched,
            in_use: 0,
            per_shard: vec![0; shards],
            per_shard_comp: vec![0; shards],
            flush_waiter: vec![false; shards],
            comp_waiter: vec![false; shards],
            wake_pending: false,
            stats: CpuPoolStats { total, ..Default::default() },
        }
    }

    /// Rebind the pool to a sharded domain (called by the shard layer
    /// before any background work exists).
    pub fn configure(&mut self, shards: usize, sched: CpuSched) {
        assert!(shards >= 1);
        assert_eq!(self.in_use, 0, "cannot reshape a pool with slots in use");
        self.sched = sched;
        self.per_shard = vec![0; shards];
        self.per_shard_comp = vec![0; shards];
        self.flush_waiter = vec![false; shards];
        self.comp_waiter = vec![false; shards];
    }

    /// Slots compactions may never take (RocksDB's flush pool), shrunk so
    /// every non-empty pool keeps ≥ 1 compaction-eligible slot — the
    /// `bg_threads <= 2` anti-livelock invariant, now pool-wide.
    pub fn flush_reserved(&self) -> usize {
        match self.total {
            0 | 1 => 0,
            t => 2.min(t - 1),
        }
    }

    /// Per-shard ceiling on *compaction* slots.
    pub fn compaction_cap(&self) -> usize {
        match self.sched {
            CpuSched::WorkConserving => self.total,
            CpuSched::Fair => self.total.div_ceil(self.per_shard.len()).max(1),
        }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    pub fn shard_in_use(&self, shard: usize) -> usize {
        self.per_shard[shard]
    }

    /// Compaction slots a shard currently holds (what the fair cap binds).
    pub fn shard_compactions(&self, shard: usize) -> usize {
        self.per_shard_comp[shard]
    }

    /// Shards whose ready flush is currently blocked on a slot.
    pub fn waiting_flushes(&self) -> usize {
        self.flush_waiter.iter().filter(|&&w| w).count()
    }

    /// Flushes only contend for the global slot count — never the fair cap
    /// and never the reservation (the reservation exists *for* them).
    pub fn can_admit_flush(&self) -> bool {
        self.in_use < self.total
    }

    /// Compaction admission: global count behind the flush reservation,
    /// the per-shard cap, and first claim of free slots by waiting flushes.
    pub fn can_admit_compaction(&self, shard: usize) -> bool {
        self.in_use + self.flush_reserved() < self.total
            && self.per_shard_comp[shard] < self.compaction_cap()
            && self.waiting_flushes() + 1 <= self.total - self.in_use
    }

    fn grab(&mut self, shard: usize) {
        self.in_use += 1;
        self.per_shard[shard] += 1;
        self.stats.acquires += 1;
        self.stats.in_use = self.in_use;
        self.stats.high_water = self.stats.high_water.max(self.in_use);
        debug_assert!(self.in_use <= self.total, "slot bound violated");
    }

    /// Take a slot for a flush. On denial the shard is registered as a
    /// flush waiter — the claim that blocks compactions from stealing the
    /// next freed slot.
    pub fn acquire_flush(&mut self, shard: usize) -> bool {
        if self.can_admit_flush() {
            self.flush_waiter[shard] = false;
            self.grab(shard);
            true
        } else {
            self.flush_waiter[shard] = true;
            false
        }
    }

    /// Register a ready-but-denied flush without attempting the grab.
    pub fn flush_denied(&mut self, shard: usize) {
        self.flush_waiter[shard] = true;
    }

    pub fn clear_flush_waiter(&mut self, shard: usize) {
        self.flush_waiter[shard] = false;
    }

    /// Take a slot for a compaction, subject to every pool-wide rule.
    pub fn acquire_compaction(&mut self, shard: usize) -> bool {
        if !self.can_admit_compaction(shard) {
            return false;
        }
        self.comp_waiter[shard] = false;
        self.per_shard_comp[shard] += 1;
        self.grab(shard);
        if self.waiting_flushes() > self.total - self.in_use {
            // Unreachable: can_admit_compaction reserves a free slot per
            // waiting flush. Counted so tests pin it.
            self.stats.flush_priority_violations += 1;
        }
        true
    }

    /// Is this shard currently claiming a flush wake-up? (Read by the
    /// tracer so "waiter cleared" events are emitted only on transitions.)
    pub fn is_flush_waiter(&self, shard: usize) -> bool {
        self.flush_waiter[shard]
    }

    /// Mark/unmark a shard as having an eligible compaction starved of CPU.
    pub fn set_comp_waiter(&mut self, shard: usize, waiting: bool) {
        self.comp_waiter[shard] = waiting;
    }

    /// Is this shard currently claiming a compaction wake-up?
    pub fn is_comp_waiter(&self, shard: usize) -> bool {
        self.comp_waiter[shard]
    }

    /// Return a flush's slot. Flags a wake if any shard is starved, so
    /// the event loop re-polls it at this release's event time.
    pub fn release_flush(&mut self, shard: usize) {
        self.release(shard);
    }

    /// Return a compaction's slot (also credits the shard's fair cap).
    pub fn release_compaction(&mut self, shard: usize) {
        debug_assert!(self.per_shard_comp[shard] > 0, "compaction release without acquire");
        self.per_shard_comp[shard] -= 1;
        self.release(shard);
    }

    fn release(&mut self, shard: usize) {
        debug_assert!(self.in_use > 0 && self.per_shard[shard] > 0, "release without acquire");
        self.in_use -= 1;
        self.per_shard[shard] -= 1;
        self.stats.releases += 1;
        self.stats.in_use = self.in_use;
        if self.flush_waiter.iter().any(|&w| w) || self.comp_waiter.iter().any(|&w| w) {
            self.wake_pending = true;
        }
    }

    pub fn wake_pending(&self) -> bool {
        self.wake_pending
    }

    /// Drain the wake flag and list the starved shards, flush waiters
    /// first (in shard order) so the re-poll order respects flush priority
    /// deterministically. Waiter flags stay set — a re-poll that is denied
    /// again keeps its claim.
    pub fn take_wake_list(&mut self) -> Vec<usize> {
        self.wake_pending = false;
        let n = self.per_shard.len();
        let mut out: Vec<usize> = (0..n).filter(|&s| self.flush_waiter[s]).collect();
        out.extend((0..n).filter(|&s| self.comp_waiter[s] && !self.flush_waiter[s]));
        out
    }

    pub fn stats(&self) -> CpuPoolStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_pool_matches_seed_arithmetic() {
        // total = 12, reserved = 2: flush admitted while in_use < 12,
        // compaction while in_use < 10 — exactly the seed engine's
        // busy_threads checks.
        let mut p = CpuPool::new(12, 1, CpuSched::WorkConserving);
        for _ in 0..10 {
            assert!(p.acquire_compaction(0));
        }
        assert!(!p.can_admit_compaction(0), "reservation must hold the last 2 slots");
        assert!(p.acquire_flush(0));
        assert!(p.acquire_flush(0));
        assert!(!p.acquire_flush(0), "pool exhausted");
        assert_eq!(p.stats().high_water, 12);
        p.release_flush(0);
        p.release_flush(0);
        for _ in 0..10 {
            p.release_compaction(0);
        }
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.shard_compactions(0), 0);
        assert_eq!(p.stats().acquires, p.stats().releases);
    }

    #[test]
    fn tiny_pools_keep_a_compaction_slot() {
        // The anti-livelock invariant, pool-wide: reserved = 0 at 1 thread,
        // 1 at 2 threads.
        let p1 = CpuPool::new(1, 4, CpuSched::WorkConserving);
        assert_eq!(p1.flush_reserved(), 0);
        assert!(p1.can_admit_compaction(3));
        let p2 = CpuPool::new(2, 4, CpuSched::WorkConserving);
        assert_eq!(p2.flush_reserved(), 1);
        assert!(p2.can_admit_compaction(0));
    }

    #[test]
    fn waiting_flush_blocks_compaction_from_stealing_the_freed_slot() {
        let mut p = CpuPool::new(1, 2, CpuSched::WorkConserving);
        assert!(p.acquire_compaction(0));
        // Shard 1's flush is ready but denied → registered waiter.
        assert!(!p.acquire_flush(1));
        p.release_compaction(0);
        assert!(p.wake_pending(), "release with waiters must request a wake");
        assert_eq!(p.take_wake_list(), vec![1], "the starved shard gets the wake");
        // Shard 0 may NOT grab the freed slot for another compaction: the
        // waiting flush has first claim.
        assert!(!p.can_admit_compaction(0));
        assert!(p.acquire_flush(1));
        assert_eq!(p.waiting_flushes(), 0, "the claim clears on grant");
        assert_eq!(p.stats().flush_priority_violations, 0);
    }

    #[test]
    fn fair_cap_bounds_one_shards_compactions_but_not_flushes() {
        let mut p = CpuPool::new(8, 2, CpuSched::Fair);
        assert_eq!(p.compaction_cap(), 4);
        // An active flush must NOT shrink the shard's compaction
        // entitlement: the cap binds on compaction slots only.
        assert!(p.acquire_flush(0));
        for _ in 0..3 {
            assert!(p.acquire_compaction(0));
        }
        // 1 flush + 3 compactions held: a 4th compaction must still admit
        // (with a cap on total held slots this would wrongly be denied).
        assert!(p.can_admit_compaction(0), "flush slot must not count against the cap");
        assert!(p.acquire_compaction(0));
        assert_eq!(p.shard_compactions(0), 4);
        assert!(!p.can_admit_compaction(0), "fair cap reached");
        assert!(p.can_admit_compaction(1), "the other shard still admits");
        // Flushes ignore the cap entirely.
        assert!(p.acquire_flush(0));
        assert_eq!(p.in_use(), 6);
    }

    #[test]
    fn reshaping_an_idle_pool() {
        let mut p = CpuPool::new(3, 1, CpuSched::WorkConserving);
        p.configure(4, CpuSched::Fair);
        assert_eq!(p.compaction_cap(), 1);
        assert!(p.acquire_compaction(3));
        p.release_compaction(3);
    }
}
