//! Discrete-event-simulation substrate: virtual clock, deterministic RNG,
//! key-distribution generators, and the zoned-device service-time model.
//!
//! Everything in the reproduction runs under a *virtual* nanosecond clock.
//! Device accesses charge service time against a QD1 FIFO server per device
//! (`DeviceTimer`), which is how contention — compaction vs. foreground
//! reads, migration interference (Exp#6) — emerges without real hardware.
//! Background CPU is the same kind of resource: flush/compaction jobs take
//! slots from one shared [`CpuPool`] of `bg_threads` threads (§4.1: 12),
//! so cross-shard scheduling contention emerges — and is measured — too.

pub mod cpu;
pub mod crash;
pub mod device;
pub mod rng;
pub mod zipf;

pub use cpu::{CpuPool, CpuPoolStats};
pub use crash::{CrashInjector, CrashPoint};
pub use device::{AccessKind, DeviceTimer, SharedTimer};
pub use rng::Rng;
pub use zipf::{KeyChooser, Latest, Uniform, Zipf};

/// Virtual time in nanoseconds.
pub type Ns = u64;

pub const SECOND: Ns = 1_000_000_000;
pub const MILLI: Ns = 1_000_000;
pub const MICRO: Ns = 1_000;

/// Format a virtual duration for reports.
pub fn fmt_ns(ns: Ns) -> String {
    if ns >= SECOND {
        format!("{:.2}s", ns as f64 / SECOND as f64)
    } else if ns >= MILLI {
        format!("{:.2}ms", ns as f64 / MILLI as f64)
    } else if ns >= MICRO {
        format!("{:.2}us", ns as f64 / MICRO as f64)
    } else {
        format!("{ns}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(5), "5ns");
        assert_eq!(fmt_ns(5_000), "5.00us");
        assert_eq!(fmt_ns(5_000_000), "5.00ms");
        assert_eq!(fmt_ns(5_000_000_000), "5.00s");
    }
}
