//! Deterministic crash & power-loss injection over the DES.
//!
//! A [`CrashInjector`] is armed at a chosen virtual time or operation count
//! and fires at the first matching [`CrashPoint`] hook the engine passes
//! afterwards. Firing models *physical* power loss: the engine truncates
//! in-flight zone appends at a byte chosen by the injector's seeded RNG
//! (the write pointer lands mid-record — torn WAL tails and torn SST
//! blocks become real on-media states), drops all volatile state, unwinds
//! shared-substrate spans, and restarts from surviving zones/WAL only.
//!
//! Determinism: the injector is a pure function of `(point, arm, seed)` —
//! the same configuration tears the same byte of the same zone on every
//! run. An armed injector that never fires is observationally free: it
//! only reads the clock/op counter, so the run stays bit-identical to one
//! without it (pinned in `tests/datapath.rs`).

use super::rng::Rng;
use super::Ns;

/// Where in the engine's lifecycle the crash fires. Each variant names one
/// injection hook on the datapath; see `Engine::crash_*` in `coordinator`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashPoint {
    /// Inside a flush job, between output-chunk device writes.
    MidFlush,
    /// Inside a compaction job, between read/write chunks.
    MidCompaction,
    /// Immediately after a WAL zone append commits (the torn tail lands in
    /// the record that very append wrote).
    MidZoneAppend,
    /// Inside a migration, between relocation chunks.
    MidMigration,
    /// After the WAL append, before the MemTable apply — the classic
    /// durability window (the record is on media, the apply never ran).
    WalBeforeMemtable,
    /// During WAL replay of a previous recovery (double-fault).
    MidRecovery,
}

impl CrashPoint {
    pub const ALL: [CrashPoint; 6] = [
        CrashPoint::MidFlush,
        CrashPoint::MidCompaction,
        CrashPoint::MidZoneAppend,
        CrashPoint::MidMigration,
        CrashPoint::WalBeforeMemtable,
        CrashPoint::MidRecovery,
    ];

    pub fn name(self) -> &'static str {
        match self {
            CrashPoint::MidFlush => "mid_flush",
            CrashPoint::MidCompaction => "mid_compaction",
            CrashPoint::MidZoneAppend => "mid_zone_append",
            CrashPoint::MidMigration => "mid_migration",
            CrashPoint::WalBeforeMemtable => "wal_before_memtable",
            CrashPoint::MidRecovery => "mid_recovery",
        }
    }

    pub fn parse(s: &str) -> Option<CrashPoint> {
        CrashPoint::ALL.iter().copied().find(|p| p.name() == s)
    }
}

/// The armed injector. Owned by at most one engine (the victim shard);
/// `fired` stays true after the crash so it fires at most once.
#[derive(Clone, Debug)]
pub struct CrashInjector {
    pub point: CrashPoint,
    /// Fire at the first matching hook at or after this virtual time
    /// (0 = no time trigger).
    pub at_time: Ns,
    /// Fire at the first matching hook once this many client write ops
    /// have been issued (0 = no op trigger).
    pub at_op: u64,
    rng: Rng,
    pub fired: bool,
    /// Bytes of the in-flight append that survived the power loss, when the
    /// fire tore a record mid-write (`None` until fired, or when nothing was
    /// in flight to tear). Introspection for the grid harness.
    pub torn: Option<u64>,
    ops_seen: u64,
}

impl CrashInjector {
    pub fn new(point: CrashPoint, at_time: Ns, at_op: u64, seed: u64) -> CrashInjector {
        CrashInjector {
            point,
            at_time,
            at_op,
            rng: Rng::new(seed ^ 0xC4A5_7EA2_D00F_1234),
            fired: false,
            torn: None,
            ops_seen: 0,
        }
    }

    /// Build from the `[crash]` config section; `None` when disabled.
    pub fn from_config(c: &crate::config::CrashConfig) -> Option<CrashInjector> {
        if !c.enabled {
            return None;
        }
        let point = CrashPoint::parse(&c.point)
            .unwrap_or_else(|| panic!("unknown crash point {:?}", c.point));
        Some(CrashInjector::new(point, c.at_time_ns, c.at_op, c.seed))
    }

    /// Count one client write op (the `--crash-at <op>` trigger axis).
    pub fn note_op(&mut self) {
        self.ops_seen += 1;
    }

    pub fn ops_seen(&self) -> u64 {
        self.ops_seen
    }

    /// Should the crash fire at this hook, now? True once per injector.
    pub fn should_fire(&self, point: CrashPoint, now: Ns) -> bool {
        !self.fired
            && self.point == point
            && ((self.at_time > 0 && now >= self.at_time)
                || (self.at_op > 0 && self.ops_seen >= self.at_op))
    }

    /// Pick the surviving byte count of an in-flight append of `len`
    /// logical bytes: strictly inside the record when possible, so the
    /// write pointer lands mid-record and the tail is genuinely torn.
    pub fn torn_byte(&mut self, len: u64) -> u64 {
        if len <= 1 {
            return 0;
        }
        1 + self.rng.next_below(len - 1)
    }

    /// Deterministic draw in `[0, n)` — e.g. which replay entry the
    /// MidRecovery double fault aborts at.
    pub fn pick_below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.rng.next_below(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_names_round_trip() {
        for p in CrashPoint::ALL {
            assert_eq!(CrashPoint::parse(p.name()), Some(p));
        }
        assert_eq!(CrashPoint::parse("nope"), None);
    }

    #[test]
    fn fires_once_at_time_or_op_trigger() {
        let mut inj = CrashInjector::new(CrashPoint::MidFlush, 1_000, 0, 7);
        assert!(!inj.should_fire(CrashPoint::MidFlush, 999));
        assert!(!inj.should_fire(CrashPoint::MidCompaction, 2_000), "wrong point never fires");
        assert!(inj.should_fire(CrashPoint::MidFlush, 1_000));
        inj.fired = true;
        assert!(!inj.should_fire(CrashPoint::MidFlush, 2_000), "at most once");

        let mut by_op = CrashInjector::new(CrashPoint::WalBeforeMemtable, 0, 3, 7);
        for _ in 0..2 {
            by_op.note_op();
        }
        assert!(!by_op.should_fire(CrashPoint::WalBeforeMemtable, u64::MAX));
        by_op.note_op();
        assert!(by_op.should_fire(CrashPoint::WalBeforeMemtable, 0));
    }

    #[test]
    fn torn_byte_is_strictly_mid_record_and_deterministic() {
        let mut a = CrashInjector::new(CrashPoint::MidZoneAppend, 1, 0, 42);
        let mut b = CrashInjector::new(CrashPoint::MidZoneAppend, 1, 0, 42);
        for len in [2u64, 3, 100, 4096] {
            let t = a.torn_byte(len);
            assert!(t >= 1 && t < len, "tear {t} outside (0, {len})");
            assert_eq!(t, b.torn_byte(len), "same seed, same tear");
        }
        assert_eq!(a.torn_byte(1), 0);
        assert_eq!(a.torn_byte(0), 0);
    }
}
