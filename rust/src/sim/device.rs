//! Device service-time model: each zoned device is a QD1 FIFO server.
//!
//! An access at virtual time `now` starts at `max(now, free_at)`, takes a
//! service time derived from the `DeviceProfile` (Table 1 numbers), and
//! pushes `free_at` forward. Queue wait is therefore part of every caller's
//! latency, which is how compaction/migration interference with foreground
//! reads materializes (paper Exp#6).

use std::cell::RefCell;
use std::rc::Rc;

use crate::config::DeviceProfile;
use crate::trace::{Event, TraceSink};
use crate::zone::Dev;

use super::Ns;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    SeqRead,
    SeqWrite,
    /// Random read at 4-KiB-block granularity (cost = blocks / IOPS).
    RandRead,
}

/// Cumulative traffic counters for one device.
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    pub read_bytes: u64,
    pub write_bytes: u64,
    pub read_ios: u64,
    pub write_ios: u64,
    pub busy_ns: u64,
    /// Logical requests absorbed by fused accesses: a fused access with
    /// `members > 1` counts 1 in `read_ios`/`write_ios` (it is one
    /// device-visible request) and `members` here, so utilization
    /// attribution stays exact before/after fusion.
    pub fused_ios: u64,
}

/// QD1 FIFO timing server for one device.
#[derive(Clone, Debug)]
pub struct DeviceTimer {
    pub profile: DeviceProfile,
    free_at: Ns,
    pub traffic: Traffic,
    /// Observation-only trace sink + the device tag to stamp on service
    /// intervals. Disabled (no-op) by default; the engine attaches a live
    /// sink via [`SharedTimer::set_trace`] when tracing is configured.
    trace: TraceSink,
    trace_dev: Option<Dev>,
}

impl DeviceTimer {
    pub fn new(profile: DeviceProfile) -> Self {
        DeviceTimer {
            profile,
            free_at: 0,
            traffic: Traffic::default(),
            trace: TraceSink::disabled(),
            trace_dev: None,
        }
    }

    /// Pure service time of an access (no queueing).
    pub fn service_ns(&self, kind: AccessKind, bytes: u64) -> Ns {
        let p = &self.profile;
        match kind {
            AccessKind::SeqRead => {
                p.per_req_overhead_ns + (bytes as f64 / p.seq_read_bps * 1e9) as Ns
            }
            AccessKind::SeqWrite => {
                p.per_req_overhead_ns + (bytes as f64 / p.seq_write_bps * 1e9) as Ns
            }
            AccessKind::RandRead => {
                let blocks = bytes.div_ceil(4096).max(1);
                (blocks as f64 / p.rand_read_iops * 1e9) as Ns
            }
        }
    }

    /// Perform an access: returns `(start, finish)` in virtual time and
    /// advances the server.
    pub fn access(&mut self, now: Ns, kind: AccessKind, bytes: u64) -> (Ns, Ns) {
        self.access_fused(now, kind, bytes, 1)
    }

    /// Perform one device-visible access carrying `members` logical
    /// requests fused into a single transfer of `bytes`: one
    /// `per_req_overhead_ns` (or IOP) charge for the whole batch. With
    /// `members <= 1` this is exactly [`DeviceTimer::access`] — same
    /// timing, same counters, same trace bytes.
    pub fn access_fused(
        &mut self,
        now: Ns,
        kind: AccessKind,
        bytes: u64,
        members: u32,
    ) -> (Ns, Ns) {
        let start = now.max(self.free_at);
        let svc = self.service_ns(kind, bytes);
        let finish = start + svc;
        self.free_at = finish;
        self.traffic.busy_ns += svc;
        if let Some(dev) = self.trace_dev {
            self.trace.stamp(start);
            self.trace.emit(|| Event::Dev {
                dev,
                kind,
                bytes,
                issue: now,
                start,
                finish,
                members,
            });
        }
        match kind {
            AccessKind::SeqRead | AccessKind::RandRead => {
                self.traffic.read_bytes += bytes;
                self.traffic.read_ios += 1;
            }
            AccessKind::SeqWrite => {
                self.traffic.write_bytes += bytes;
                self.traffic.write_ios += 1;
            }
        }
        if members > 1 {
            self.traffic.fused_ios += members as u64;
        }
        (start, finish)
    }

    /// Next time the device is idle.
    pub fn free_at(&self) -> Ns {
        self.free_at
    }

    /// Utilization over `[0, now]`.
    pub fn utilization(&self, now: Ns) -> f64 {
        if now == 0 {
            0.0
        } else {
            self.traffic.busy_ns as f64 / now as f64
        }
    }

    pub fn reset_traffic(&mut self) {
        self.traffic = Traffic::default();
    }
}

/// A shareable handle to one [`DeviceTimer`].
///
/// A standalone engine owns one handle per device; the shard layer points
/// every shard's device at the *same* handle, so all shards' accesses
/// serialize through one physical FIFO and cross-shard queue wait is part
/// of every caller's latency (the paper's single shared SSD/HDD pair).
/// With a single owner this is behaviour-identical to an inline timer.
#[derive(Clone, Debug)]
pub struct SharedTimer(Rc<RefCell<DeviceTimer>>);

impl SharedTimer {
    pub fn new(profile: DeviceProfile) -> Self {
        SharedTimer(Rc::new(RefCell::new(DeviceTimer::new(profile))))
    }

    /// Perform an access: `(start, finish)`; `start - now` is queue wait.
    pub fn access(&self, now: Ns, kind: AccessKind, bytes: u64) -> (Ns, Ns) {
        self.0.borrow_mut().access(now, kind, bytes)
    }

    /// One fused device-visible access for `members` logical requests.
    pub fn access_fused(
        &self,
        now: Ns,
        kind: AccessKind,
        bytes: u64,
        members: u32,
    ) -> (Ns, Ns) {
        self.0.borrow_mut().access_fused(now, kind, bytes, members)
    }

    pub fn service_ns(&self, kind: AccessKind, bytes: u64) -> Ns {
        self.0.borrow().service_ns(kind, bytes)
    }

    pub fn free_at(&self) -> Ns {
        self.0.borrow().free_at()
    }

    pub fn utilization(&self, now: Ns) -> f64 {
        self.0.borrow().utilization(now)
    }

    /// Snapshot of the cumulative traffic counters.
    pub fn traffic(&self) -> Traffic {
        self.0.borrow().traffic
    }

    pub fn reset_traffic(&self) {
        self.0.borrow_mut().reset_traffic()
    }

    /// Attach a trace sink: every access emits one `DEV` service-interval
    /// event tagged `dev`. Observation-only — timing is untouched.
    pub fn set_trace(&self, trace: TraceSink, dev: Dev) {
        let mut t = self.0.borrow_mut();
        t.trace = trace;
        t.trace_dev = Some(dev);
    }

    /// Do two handles refer to the same physical FIFO server?
    pub fn shares_with(&self, other: &SharedTimer) -> bool {
        Rc::ptr_eq(&self.0, &other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceProfile, MIB};

    #[test]
    fn table1_seq_write_hdd() {
        // 1 MiB seq writes at QD1 should sustain ≈210 MiB/s on the HDD.
        let mut t = DeviceTimer::new(DeviceProfile::st14000_smr_hdd());
        let mut now = 0;
        let n = 1000u64;
        for _ in 0..n {
            let (_, f) = t.access(now, AccessKind::SeqWrite, MIB);
            now = f;
        }
        let mibs = (n * MIB) as f64 / (now as f64 / 1e9) / MIB as f64;
        assert!((mibs - 210.0).abs() / 210.0 < 0.05, "mibs={mibs}");
    }

    #[test]
    fn table1_rand_read_hdd_iops() {
        let mut t = DeviceTimer::new(DeviceProfile::st14000_smr_hdd());
        let mut now = 0;
        for _ in 0..500 {
            let (_, f) = t.access(now, AccessKind::RandRead, 4096);
            now = f;
        }
        let iops = 500.0 / (now as f64 / 1e9);
        assert!((iops - 115.0).abs() / 115.0 < 0.02, "iops={iops}");
    }

    #[test]
    fn table1_rand_read_ssd_iops() {
        let mut t = DeviceTimer::new(DeviceProfile::zn540_ssd());
        let mut now = 0;
        for _ in 0..5000 {
            let (_, f) = t.access(now, AccessKind::RandRead, 4096);
            now = f;
        }
        let iops = 5000.0 / (now as f64 / 1e9);
        assert!((iops - 16928.3).abs() / 16928.3 < 0.02, "iops={iops}");
    }

    #[test]
    fn qd1_serializes() {
        let mut t = DeviceTimer::new(DeviceProfile::zn540_ssd());
        let (s1, f1) = t.access(0, AccessKind::SeqWrite, MIB);
        // Second request issued at t=0 must wait for the first.
        let (s2, f2) = t.access(0, AccessKind::SeqWrite, MIB);
        assert_eq!(s1, 0);
        assert_eq!(s2, f1);
        assert!(f2 > f1);
    }

    #[test]
    fn idle_gap_not_charged() {
        let mut t = DeviceTimer::new(DeviceProfile::zn540_ssd());
        let (_, f1) = t.access(0, AccessKind::SeqWrite, MIB);
        let (s2, f2) = t.access(f1 + 1_000_000, AccessKind::SeqWrite, MIB);
        assert_eq!(s2, f1 + 1_000_000);
        // The 1 ms idle gap is not busy time.
        assert!(t.utilization(f2) < 1.0);
        assert_eq!(t.traffic.busy_ns, f2 - 1_000_000);
    }

    #[test]
    fn fused_access_is_one_request() {
        // A fused append of N records costs ONE per_req_overhead_ns plus
        // the bytes of all members — strictly cheaper than N separate
        // appends, and it occupies exactly one QD1 service interval.
        let mut t = DeviceTimer::new(DeviceProfile::zn540_ssd());
        let rec = 1032u64;
        let n = 8u32;
        let split: Ns = (0..n)
            .map(|_| t.service_ns(AccessKind::SeqWrite, rec))
            .sum();
        let fused = t.service_ns(AccessKind::SeqWrite, rec * n as u64);
        let overhead = t.profile.per_req_overhead_ns;
        assert!(fused < split, "fused={fused} split={split}");
        assert!(split - fused >= (n as u64 - 1) * overhead - n as u64);
        let (s, f) = t.access_fused(0, AccessKind::SeqWrite, rec * n as u64, n);
        assert_eq!((s, f), (0, fused));
        assert_eq!(t.traffic.write_ios, 1);
        assert_eq!(t.traffic.fused_ios, n as u64);
        assert_eq!(t.traffic.write_bytes, rec * n as u64);
    }

    #[test]
    fn qd1_serializes_fused() {
        // A fused access holds the FIFO server exactly like a plain one:
        // the next request issued at t=0 starts at the fused finish.
        let mut t = DeviceTimer::new(DeviceProfile::zn540_ssd());
        let (s1, f1) = t.access_fused(0, AccessKind::SeqWrite, MIB, 4);
        let (s2, f2) = t.access(0, AccessKind::SeqWrite, MIB);
        assert_eq!(s1, 0);
        assert_eq!(s2, f1);
        assert!(f2 > f1);
        assert_eq!(t.traffic.busy_ns, f2);
    }

    #[test]
    fn fused_members_one_is_plain_access() {
        let mut a = DeviceTimer::new(DeviceProfile::zn540_ssd());
        let mut b = DeviceTimer::new(DeviceProfile::zn540_ssd());
        let ra = a.access(7, AccessKind::RandRead, 4096);
        let rb = b.access_fused(7, AccessKind::RandRead, 4096, 1);
        assert_eq!(ra, rb);
        assert_eq!(a.traffic.read_ios, b.traffic.read_ios);
        assert_eq!(b.traffic.fused_ios, 0);
    }

    #[test]
    fn fused_span_promotes_random_to_sequential() {
        // Two adjacent 4-KiB random reads fused into one 8-KiB sequential
        // read are cheaper than the two IOPs on both profiles (the 8-KiB
        // span is past each profile's rand/seq crossover).
        for p in [DeviceProfile::zn540_ssd(), DeviceProfile::st14000_smr_hdd()] {
            let t = DeviceTimer::new(p);
            let two_rand = 2 * t.service_ns(AccessKind::RandRead, 4096);
            let fused_seq = t.service_ns(AccessKind::SeqRead, 8192);
            assert!(
                fused_seq < two_rand,
                "{}: fused={fused_seq} rand2={two_rand}",
                t.profile.name
            );
        }
    }

    #[test]
    fn ssd_much_faster_random_than_hdd() {
        let ssd = DeviceTimer::new(DeviceProfile::zn540_ssd());
        let hdd = DeviceTimer::new(DeviceProfile::st14000_smr_hdd());
        let r = hdd.service_ns(AccessKind::RandRead, 4096) as f64
            / ssd.service_ns(AccessKind::RandRead, 4096) as f64;
        // Paper: 147.2× gap.
        assert!(r > 140.0 && r < 155.0, "ratio={r}");
    }
}
