//! Experiment metrics: latency histograms, per-category traffic splits,
//! level-size samplers, throughput — everything Figures 2, 5–10 report.

mod hist;

pub use hist::LogHistogram;

use crate::sim::Ns;
use crate::zone::Dev;
use std::collections::BTreeMap;

/// What a write belonged to — drives the Fig 2(b)/(e) traffic breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum WriteCategory {
    Wal,
    Sst(usize), // level
    CacheZone,
    Migration,
}

impl WriteCategory {
    pub fn label(&self) -> String {
        match self {
            WriteCategory::Wal => "WAL".into(),
            WriteCategory::Sst(l) => format!("L{l}"),
            WriteCategory::CacheZone => "cache".into(),
            WriteCategory::Migration => "migr".into(),
        }
    }
}

/// One (category, device) traffic cell.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cell {
    pub bytes: u64,
    pub ios: u64,
}

/// A periodic sample of per-level actual sizes (Fig 2(a)/(d) boxplots).
#[derive(Clone, Debug)]
pub struct LevelSizeSample {
    pub at: Ns,
    pub wal_bytes: u64,
    pub level_bytes: Vec<u64>,
}

/// Aggregate metrics for one run.
#[derive(Clone, Default)]
pub struct Metrics {
    /// Client operation latencies by kind.
    pub read_lat: LogHistogram,
    pub write_lat: LogHistogram,
    pub scan_lat: LogHistogram,
    pub ops_done: u64,
    pub reads_done: u64,
    pub writes_done: u64,
    pub scans_done: u64,
    /// Write traffic split by (category, device).
    pub write_traffic: BTreeMap<(WriteCategory, Dev), Cell>,
    /// Read traffic split by device (data-block reads only).
    pub read_traffic: BTreeMap<Dev, Cell>,
    /// Virtual time spent queued behind the per-device FIFO before service
    /// started, by device. With shards sharing one SSD/HDD pair on one
    /// clock, cross-shard device contention lands here (Exp#6-style
    /// interference, now across engines too).
    pub queue_wait: BTreeMap<Dev, Ns>,
    /// Virtual time a *ready* background job (flush or compaction) waited
    /// for a slot of the shared CPU pool before it could start; one sample
    /// per job start (0 when a slot was free immediately). With shards
    /// sharing one `bg_threads` pool, cross-shard CPU contention lands
    /// here — the scheduling analogue of `queue_wait`.
    pub cpu_wait: LogHistogram,
    /// Virtual time a client op's per-op CPU cost waited for a foreground
    /// slot (`fg_threads` pool); one sample per charged op-path site.
    /// Always empty at `fg_threads = 0` (contention-free seed arithmetic).
    pub fg_cpu_wait: LogHistogram,
    /// Times the stall-aware wake policy promoted a higher-risk shard over
    /// the FIFO head — the ROADMAP "stalls avoided vs FIFO" measurement.
    /// Always 0 under `wake = fifo`.
    pub stalls_avoided: u64,
    /// SSD-cache effectiveness (§3.5).
    pub ssd_cache_hits: u64,
    pub ssd_cache_misses: u64,
    pub block_cache_hits: u64,
    pub block_cache_misses: u64,
    pub memtable_hits: u64,
    /// Level-size samples, taken every virtual minute during loads.
    pub level_samples: Vec<LevelSizeSample>,
    /// Per-SST read counts: sst id -> (level, device at last read, reads).
    pub sst_reads: BTreeMap<u64, (usize, Dev, u64)>,
    /// Stall accounting.
    pub stall_ns: Ns,
    pub stalls: u64,
    /// Migration accounting.
    pub migrations_cap: u64,
    pub migrations_pop: u64,
    pub migration_bytes: u64,
    /// Compaction/flush accounting.
    pub flushes: u64,
    pub compactions: u64,
    pub compaction_read_bytes: u64,
    pub compaction_write_bytes: u64,
    /// Group-commit batch sizes: one sample per fused WAL append, value =
    /// member count. Empty when group commit is off (every append then is
    /// its own device request and is not sampled here).
    pub wal_group_size: LogHistogram,
    /// Fused SST read accesses (one per coalesced device access carrying
    /// >= 2 member block reads) and the data bytes they carried.
    pub fused_reads: u64,
    pub fused_read_bytes: u64,
    /// Bytes stranded at active WAL zone tails when a record didn't fit
    /// and the writer moved to a fresh zone (the zone-fill loss group
    /// commit reduces).
    pub wal_pad_bytes: u64,
    /// Resident interned-key bytes (unique key bytes + per-key overhead)
    /// of the engine's key arena at phase end. A *gauge*, not a counter —
    /// and a domain-level one: shards of one frontend share ONE arena and
    /// each stamps the same value, so the merge takes the max instead of
    /// summing duplicates.
    pub key_arena_bytes: u64,
    /// Physically resident bytes at phase end, by where they are pinned —
    /// the demand-paging residency breakdown. Gauges, not counters, and
    /// *per-shard* ones (each engine owns its zones), so the merge sums:
    /// SSD SST zones, HDD SST zones, WAL zones (either device), and the
    /// caches (SSD cache zones + the in-memory block cache's hydrated
    /// copies). The conservation identity `ssd + hdd + wal + cache ==
    /// fs phys + block-cache phys` is pinned by `tests/datapath.rs`.
    pub resident_ssd_bytes: u64,
    pub resident_hdd_bytes: u64,
    pub resident_wal_bytes: u64,
    pub resident_cache_bytes: u64,
    /// Start/end of run (virtual).
    pub start_ns: Ns,
    pub finished_at: Ns,
}

impl Metrics {
    pub fn record_write(&mut self, cat: WriteCategory, dev: Dev, bytes: u64) {
        let c = self.write_traffic.entry((cat, dev)).or_default();
        c.bytes += bytes;
        c.ios += 1;
    }

    /// Like [`Metrics::record_write`] but with an explicit device-visible
    /// request count: a fused group-commit append attributes its single
    /// device IO to the first member's shard (`ios = 1`) and `ios = 0` to
    /// the rest, so the merged `write_ios` counts device-visible requests
    /// exactly.
    pub fn record_write_ios(&mut self, cat: WriteCategory, dev: Dev, bytes: u64, ios: u64) {
        let c = self.write_traffic.entry((cat, dev)).or_default();
        c.bytes += bytes;
        c.ios += ios;
    }

    pub fn record_read(&mut self, dev: Dev, bytes: u64) {
        let c = self.read_traffic.entry(dev).or_default();
        c.bytes += bytes;
        c.ios += 1;
    }

    /// Account FIFO queue wait (`service start - issue time`) on `dev`.
    pub fn record_queue_wait(&mut self, dev: Dev, wait_ns: Ns) {
        if wait_ns > 0 {
            *self.queue_wait.entry(dev).or_default() += wait_ns;
        }
    }

    /// Total device queue wait across both devices.
    pub fn total_queue_wait_ns(&self) -> Ns {
        self.queue_wait.values().sum()
    }

    pub fn record_sst_read(&mut self, sst: u64, level: usize, dev: Dev) {
        let e = self.sst_reads.entry(sst).or_insert((level, dev, 0));
        e.0 = level;
        e.1 = dev;
        e.2 += 1;
    }

    /// Throughput in operations/virtual-second.
    pub fn ops_per_sec(&self) -> f64 {
        let dur = self.finished_at.saturating_sub(self.start_ns);
        if dur == 0 {
            return 0.0;
        }
        self.ops_done as f64 / (dur as f64 / 1e9)
    }

    /// Fraction of write traffic (for `cat`, or all SST+WAL when None)
    /// that went to the SSD.
    pub fn ssd_write_fraction(&self, cat: Option<WriteCategory>) -> f64 {
        let mut ssd = 0u64;
        let mut all = 0u64;
        for ((c, d), cell) in &self.write_traffic {
            if matches!(c, WriteCategory::CacheZone | WriteCategory::Migration) {
                continue;
            }
            if let Some(want) = cat {
                if *c != want {
                    continue;
                }
            }
            all += cell.bytes;
            if *d == Dev::Ssd {
                ssd += cell.bytes;
            }
        }
        if all == 0 {
            0.0
        } else {
            ssd as f64 / all as f64
        }
    }

    /// Fold another run's metrics into this one — the cross-shard
    /// aggregation of [`crate::shard`]: histograms merge bucket-wise,
    /// counters and traffic cells sum, level samples interleave by time.
    /// Per-SST read counts rely on the shards' disjoint (strided) file-id
    /// namespaces; on an id collision the reads still sum.
    pub fn merge(&mut self, other: &Metrics) {
        self.read_lat.merge(&other.read_lat);
        self.write_lat.merge(&other.write_lat);
        self.scan_lat.merge(&other.scan_lat);
        self.ops_done += other.ops_done;
        self.reads_done += other.reads_done;
        self.writes_done += other.writes_done;
        self.scans_done += other.scans_done;
        for ((cat, dev), cell) in &other.write_traffic {
            let c = self.write_traffic.entry((*cat, *dev)).or_default();
            c.bytes += cell.bytes;
            c.ios += cell.ios;
        }
        for (dev, cell) in &other.read_traffic {
            let c = self.read_traffic.entry(*dev).or_default();
            c.bytes += cell.bytes;
            c.ios += cell.ios;
        }
        for (dev, w) in &other.queue_wait {
            *self.queue_wait.entry(*dev).or_default() += w;
        }
        self.cpu_wait.merge(&other.cpu_wait);
        self.fg_cpu_wait.merge(&other.fg_cpu_wait);
        self.stalls_avoided += other.stalls_avoided;
        self.ssd_cache_hits += other.ssd_cache_hits;
        self.ssd_cache_misses += other.ssd_cache_misses;
        self.block_cache_hits += other.block_cache_hits;
        self.block_cache_misses += other.block_cache_misses;
        self.memtable_hits += other.memtable_hits;
        self.level_samples.extend(other.level_samples.iter().cloned());
        self.level_samples.sort_by_key(|s| s.at);
        for (sst, (level, dev, reads)) in &other.sst_reads {
            let e = self.sst_reads.entry(*sst).or_insert((*level, *dev, 0));
            e.0 = *level;
            e.1 = *dev;
            e.2 += reads;
        }
        self.stall_ns += other.stall_ns;
        self.stalls += other.stalls;
        self.migrations_cap += other.migrations_cap;
        self.migrations_pop += other.migrations_pop;
        self.migration_bytes += other.migration_bytes;
        self.flushes += other.flushes;
        self.compactions += other.compactions;
        self.compaction_read_bytes += other.compaction_read_bytes;
        self.compaction_write_bytes += other.compaction_write_bytes;
        self.wal_group_size.merge(&other.wal_group_size);
        self.fused_reads += other.fused_reads;
        self.fused_read_bytes += other.fused_read_bytes;
        self.wal_pad_bytes += other.wal_pad_bytes;
        // Domain gauge: engines sharing one arena stamp the same value;
        // max (not sum) keeps the merged number the domain's residency.
        self.key_arena_bytes = self.key_arena_bytes.max(other.key_arena_bytes);
        // Residency gauges are per-shard (each engine owns its zones and
        // block cache), so the domain total is the sum.
        self.resident_ssd_bytes += other.resident_ssd_bytes;
        self.resident_hdd_bytes += other.resident_hdd_bytes;
        self.resident_wal_bytes += other.resident_wal_bytes;
        self.resident_cache_bytes += other.resident_cache_bytes;
        // Shards run on one shared clock (the async frontend), so per-shard
        // windows coincide; taking the envelope also keeps the merge
        // correct for runs recorded on separate clocks.
        self.start_ns = self.start_ns.min(other.start_ns);
        self.finished_at = self.finished_at.max(other.finished_at);
    }

    /// Fraction of data-block read traffic served by the HDD (Fig 2(h)).
    pub fn hdd_read_fraction(&self) -> f64 {
        let ssd = self.read_traffic.get(&Dev::Ssd).map_or(0, |c| c.bytes);
        let hdd = self.read_traffic.get(&Dev::Hdd).map_or(0, |c| c.bytes);
        if ssd + hdd == 0 {
            0.0
        } else {
            hdd as f64 / (ssd + hdd) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_fractions() {
        let mut m = Metrics::default();
        m.record_write(WriteCategory::Wal, Dev::Ssd, 100);
        m.record_write(WriteCategory::Wal, Dev::Hdd, 300);
        m.record_write(WriteCategory::Sst(0), Dev::Ssd, 600);
        assert!((m.ssd_write_fraction(Some(WriteCategory::Wal)) - 0.25).abs() < 1e-9);
        assert!((m.ssd_write_fraction(None) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn cache_and_migration_excluded_from_placement_fraction() {
        let mut m = Metrics::default();
        m.record_write(WriteCategory::Sst(1), Dev::Hdd, 100);
        m.record_write(WriteCategory::CacheZone, Dev::Ssd, 1000);
        m.record_write(WriteCategory::Migration, Dev::Ssd, 1000);
        assert_eq!(m.ssd_write_fraction(None), 0.0);
    }

    #[test]
    fn hdd_read_fraction() {
        let mut m = Metrics::default();
        m.record_read(Dev::Hdd, 75);
        m.record_read(Dev::Ssd, 25);
        assert!((m.hdd_read_fraction() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn ops_per_sec() {
        let mut m = Metrics::default();
        m.ops_done = 5000;
        m.finished_at = 2_000_000_000; // 2 virtual seconds
        assert!((m.ops_per_sec() - 2500.0).abs() < 1e-6);
    }

    #[test]
    fn cpu_wait_merges_across_shards() {
        let mut a = Metrics::default();
        a.cpu_wait.record(0);
        a.cpu_wait.record(5_000);
        let mut b = Metrics::default();
        b.cpu_wait.record(7_000);
        a.merge(&b);
        assert_eq!(a.cpu_wait.n, 3);
        assert_eq!(a.cpu_wait.sum, 12_000);
        assert_eq!(a.cpu_wait.max, 7_000);
    }

    #[test]
    fn fg_cpu_wait_and_stalls_avoided_merge() {
        let mut a = Metrics::default();
        a.fg_cpu_wait.record(2_000);
        a.stalls_avoided = 3;
        let mut b = Metrics::default();
        b.fg_cpu_wait.record(500);
        b.fg_cpu_wait.record(1_500);
        b.stalls_avoided = 4;
        a.merge(&b);
        assert_eq!(a.fg_cpu_wait.n, 3);
        assert_eq!(a.fg_cpu_wait.sum, 4_000);
        assert_eq!(a.stalls_avoided, 7);
    }

    #[test]
    fn merge_sums_counters_and_traffic() {
        let mut a = Metrics::default();
        a.record_write(WriteCategory::Wal, Dev::Ssd, 100);
        a.record_read(Dev::Hdd, 10);
        a.read_lat.record(1_000);
        a.ops_done = 5;
        a.start_ns = 100;
        a.finished_at = 200;
        let mut b = Metrics::default();
        b.record_write(WriteCategory::Wal, Dev::Ssd, 50);
        b.record_write(WriteCategory::Sst(2), Dev::Hdd, 70);
        b.record_read(Dev::Hdd, 30);
        b.read_lat.record(9_000);
        b.ops_done = 7;
        b.start_ns = 150;
        b.finished_at = 400;
        a.merge(&b);
        assert_eq!(a.ops_done, 12);
        assert_eq!(a.read_lat.n, 2);
        assert_eq!(a.write_traffic[&(WriteCategory::Wal, Dev::Ssd)].bytes, 150);
        assert_eq!(a.write_traffic[&(WriteCategory::Sst(2), Dev::Hdd)].bytes, 70);
        assert_eq!(a.read_traffic[&Dev::Hdd].bytes, 40);
        assert_eq!(a.read_traffic[&Dev::Hdd].ios, 2);
        assert_eq!((a.start_ns, a.finished_at), (100, 400));
    }

    #[test]
    fn residency_gauges_sum_on_merge() {
        let mut a = Metrics::default();
        a.resident_ssd_bytes = 100;
        a.resident_wal_bytes = 10;
        let mut b = Metrics::default();
        b.resident_ssd_bytes = 50;
        b.resident_hdd_bytes = 30;
        b.resident_cache_bytes = 7;
        a.merge(&b);
        assert_eq!(a.resident_ssd_bytes, 150);
        assert_eq!(a.resident_hdd_bytes, 30);
        assert_eq!(a.resident_wal_bytes, 10);
        assert_eq!(a.resident_cache_bytes, 7);
    }

    #[test]
    fn fusion_counters_merge() {
        let mut a = Metrics::default();
        a.wal_group_size.record(4);
        a.fused_reads = 2;
        a.fused_read_bytes = 8192;
        a.wal_pad_bytes = 100;
        let mut b = Metrics::default();
        b.wal_group_size.record(8);
        b.fused_reads = 1;
        b.fused_read_bytes = 4096;
        b.wal_pad_bytes = 23;
        a.merge(&b);
        assert_eq!(a.wal_group_size.n, 2);
        assert_eq!(a.wal_group_size.sum, 12);
        assert_eq!(a.fused_reads, 3);
        assert_eq!(a.fused_read_bytes, 12_288);
        assert_eq!(a.wal_pad_bytes, 123);
    }

    #[test]
    fn record_write_ios_controls_request_count() {
        let mut m = Metrics::default();
        m.record_write_ios(WriteCategory::Wal, Dev::Ssd, 100, 1);
        m.record_write_ios(WriteCategory::Wal, Dev::Ssd, 100, 0);
        m.record_write_ios(WriteCategory::Wal, Dev::Ssd, 100, 0);
        let c = m.write_traffic[&(WriteCategory::Wal, Dev::Ssd)];
        assert_eq!((c.bytes, c.ios), (300, 1));
    }

    #[test]
    fn sst_read_counter_updates_location() {
        let mut m = Metrics::default();
        m.record_sst_read(7, 3, Dev::Hdd);
        m.record_sst_read(7, 3, Dev::Ssd);
        let (lvl, dev, n) = m.sst_reads[&7];
        assert_eq!((lvl, dev, n), (3, Dev::Ssd, 2));
    }
}
