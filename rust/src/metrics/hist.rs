//! Log-bucketed latency histogram (HDR-style, ~3% relative error) with
//! O(1) record and O(buckets) quantile — used for the p99/p99.9/p99.99
//! read-latency results in Exp#6.

/// 16 sub-buckets per power of two, covering 1ns .. ~2^40ns (~18 min).
const SUB_BITS: u32 = 4;
const SUB: usize = 1 << SUB_BITS;
const DECADES: usize = 41;
const BUCKETS: usize = DECADES * SUB;

#[derive(Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    pub n: u64,
    pub sum: u128,
    pub max: u64,
    pub min: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { counts: vec![0; BUCKETS], n: 0, sum: 0, max: 0, min: u64::MAX }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let decade = (msb - SUB_BITS + 1) as usize;
        let sub = (v >> (msb - SUB_BITS)) as usize & (SUB - 1);
        (decade * SUB + sub + SUB).min(BUCKETS - 1)
    }

    /// Representative (upper-bound) value of a bucket.
    fn value_of(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        let decade = (idx - SUB) / SUB;
        let sub = (idx - SUB) % SUB;
        // Bucket for values in [2^m, 2^(m+1)) where m = decade + SUB_BITS - 1;
        // each of the SUB sub-buckets spans base/SUB values.
        let base = 1u64 << (decade as u32 + SUB_BITS - 1);
        base + ((sub as u64 + 1) * base) / SUB as u64 - 1
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.n += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
        self.min = self.min.min(v);
    }

    pub fn quantile(&self, q: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let target = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::value_of(i).min(self.max);
            }
        }
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Smallest recorded value, or 0 when the histogram is empty. The raw
    /// `min` field starts at `u64::MAX` (the running-minimum sentinel) —
    /// render through this accessor, never the field.
    pub fn min(&self) -> u64 {
        if self.n == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_quantile_is_zero() {
        let h = LogHistogram::new();
        assert_eq!(h.quantile(0.99), 0);
    }

    #[test]
    fn exact_small_values() {
        let mut h = LogHistogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.min, 0);
        assert_eq!(h.max, 15);
        assert_eq!(h.quantile(1.0), 15);
    }

    #[test]
    fn quantile_within_relative_error() {
        let mut h = LogHistogram::new();
        // Uniform 1..=100_000.
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for q in [0.5, 0.9, 0.99, 0.999] {
            let expect = (q * 100_000.0) as f64;
            let got = h.quantile(q) as f64;
            let rel = (got - expect).abs() / expect;
            assert!(rel < 0.08, "q={q} got={got} expect={expect}");
        }
    }

    #[test]
    fn tail_sensitivity() {
        let mut h = LogHistogram::new();
        for _ in 0..19_997 {
            h.record(1_000);
        }
        for _ in 0..3 {
            h.record(50_000_000);
        }
        // p99 unaffected; p99.99 (rank 19,999 of 20,000) is an outlier.
        assert!(h.quantile(0.99) < 2_000);
        assert!(h.quantile(0.9999) > 40_000_000);
    }

    #[test]
    fn merge_combines() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(10);
        b.record(1_000_000);
        a.merge(&b);
        assert_eq!(a.n, 2);
        assert_eq!(a.max, 1_000_000);
        assert_eq!(a.min, 10);
    }

    #[test]
    fn empty_min_is_zero_not_sentinel() {
        let h = LogHistogram::new();
        assert_eq!(h.min(), 0, "empty histogram must not leak the u64::MAX sentinel");
        let mut h = LogHistogram::new();
        h.record(42);
        assert_eq!(h.min(), 42);
    }

    #[test]
    fn merge_with_empty_is_sentinel_safe() {
        // Non-empty ∪ empty keeps the real minimum.
        let mut a = LogHistogram::new();
        a.record(10);
        a.merge(&LogHistogram::new());
        assert_eq!(a.min(), 10);
        // Empty ∪ non-empty adopts the other side's minimum.
        let mut b = LogHistogram::new();
        let mut c = LogHistogram::new();
        c.record(7);
        b.merge(&c);
        assert_eq!(b.min(), 7);
        // Empty ∪ empty still renders as 0.
        let mut d = LogHistogram::new();
        d.merge(&LogHistogram::new());
        assert_eq!(d.n, 0);
        assert_eq!(d.min(), 0);
    }

    #[test]
    fn mean_exact() {
        let mut h = LogHistogram::new();
        h.record(100);
        h.record(300);
        assert!((h.mean() - 200.0).abs() < 1e-9);
    }
}
