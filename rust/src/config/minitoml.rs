//! A minimal TOML-subset parser: `[section]` headers, `key = value` lines,
//! `#` comments. Values: integers, floats, booleans, quoted strings.
//! Sufficient for the config files in `configs/` without external crates.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

#[derive(Default, Debug)]
pub struct Doc {
    /// (section, key) → value
    map: BTreeMap<(String, String), Value>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.map.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_u64(&self, s: &str, k: &str, out: &mut u64) {
        if let Some(Value::Int(v)) = self.get(s, k) {
            *out = *v as u64;
        }
    }
    pub fn get_u32(&self, s: &str, k: &str, out: &mut u32) {
        if let Some(Value::Int(v)) = self.get(s, k) {
            *out = *v as u32;
        }
    }
    pub fn get_usize(&self, s: &str, k: &str, out: &mut usize) {
        if let Some(Value::Int(v)) = self.get(s, k) {
            *out = *v as usize;
        }
    }
    pub fn get_f64(&self, s: &str, k: &str, out: &mut f64) {
        match self.get(s, k) {
            Some(Value::Float(v)) => *out = *v,
            Some(Value::Int(v)) => *out = *v as f64,
            _ => {}
        }
    }
    pub fn get_bool(&self, s: &str, k: &str, out: &mut bool) {
        if let Some(Value::Bool(v)) = self.get(s, k) {
            *out = *v;
        }
    }
    pub fn get_str(&self, s: &str, k: &str, out: &mut String) {
        if let Some(Value::Str(v)) = self.get(s, k) {
            *out = v.clone();
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

fn parse_value(raw: &str) -> anyhow::Result<Value> {
    let t = raw.trim();
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Ok(Value::Str(t[1..t.len() - 1].to_string()));
    }
    let cleaned = t.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    anyhow::bail!("unparseable value: {raw:?}")
}

pub fn parse(text: &str) -> anyhow::Result<Doc> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = match line.find('#') {
            // Only strip comments outside of quotes (good enough for our files).
            Some(i) if !line[..i].contains('"') && !line[..i].contains('\'') => &line[..i],
            _ => line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                anyhow::bail!("line {}: malformed section header {line:?}", lineno + 1);
            }
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            anyhow::bail!("line {}: expected key = value, got {line:?}", lineno + 1);
        };
        let key = line[..eq].trim().to_string();
        let val = parse_value(&line[eq + 1..])?;
        doc.map.insert((section.clone(), key), val);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            "# top comment\n\
             [a]\n\
             x = 5\n\
             y = 2.5\n\
             z = true\n\
             s = \"hello\"\n\
             [b]\n\
             x = 1_000_000\n",
        )
        .unwrap();
        assert_eq!(doc.get("a", "x"), Some(&Value::Int(5)));
        assert_eq!(doc.get("a", "y"), Some(&Value::Float(2.5)));
        assert_eq!(doc.get("a", "z"), Some(&Value::Bool(true)));
        assert_eq!(doc.get("a", "s"), Some(&Value::Str("hello".into())));
        assert_eq!(doc.get("b", "x"), Some(&Value::Int(1_000_000)));
        assert_eq!(doc.get("a", "missing"), None);
        assert_eq!(doc.get("c", "x"), None);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = parse("\n# c\n[s]\nk = 1 # trailing\n\n").unwrap();
        assert_eq!(doc.get("s", "k"), Some(&Value::Int(1)));
    }

    #[test]
    fn bad_lines_error() {
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("[s]\nnovalue\n").is_err());
        assert!(parse("[s]\nk = @@@\n").is_err());
    }

    #[test]
    fn typed_getters_apply_only_on_match() {
        let doc = parse("[s]\ni = 7\nf = 1.5\nb = false\n").unwrap();
        let mut u = 0u64;
        doc.get_u64("s", "i", &mut u);
        assert_eq!(u, 7);
        let mut f = 0.0f64;
        doc.get_f64("s", "f", &mut f);
        assert_eq!(f, 1.5);
        doc.get_f64("s", "i", &mut f); // int promotes to float
        assert_eq!(f, 7.0);
        let mut b = true;
        doc.get_bool("s", "b", &mut b);
        assert!(!b);
        let mut untouched = 99u64;
        doc.get_u64("s", "missing", &mut untouched);
        assert_eq!(untouched, 99);
    }
}
