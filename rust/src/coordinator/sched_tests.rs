//! Scheduler-adjacent engine regressions: writer-park `stall_ns`
//! accounting across a phase boundary, and its agreement with the paired
//! UNSTALL trace span (the contract `trace::check_lines` enforces).

use super::*;
use crate::policy::HhzsPolicy;
use crate::lsm::Payload;

fn traced_engine() -> Engine {
    let mut cfg = Config::tiny();
    cfg.trace.enabled = true;
    let levels = cfg.lsm.num_levels;
    Engine::new(cfg, Box::new(HhzsPolicy::new(levels)))
}

fn unstall_durs(e: &Engine) -> Vec<u64> {
    e.trace
        .lines()
        .iter()
        .filter(|l| l.starts_with("UNSTALL|"))
        .map(|l| l.rsplit('|').next().unwrap().parse().unwrap())
        .collect()
}

#[test]
fn cross_phase_park_charges_only_from_the_boundary() {
    let mut e = traced_engine();
    // A writer parked at t=400k survives a phase boundary at t=1M and
    // finally executes at t=1.2M. The fresh phase owns only the 200k ns
    // after its own start — not the 800k the op spent parked overall.
    e.begin_phase(1_000_000, false);
    let op = Op::Insert { key: b"k".to_vec(), value: Payload::from_bytes(b"v") };
    let FrontendOp::Done(_) = e.frontend_client_op(7, op, 400_000, 1_200_000) else {
        panic!("fresh engine cannot be write-blocked");
    };
    assert_eq!(e.metrics.stall_ns, 200_000, "post-reset phase charges from the boundary");
    assert_eq!(unstall_durs(&e), vec![200_000], "trace span must agree with Metrics::stall_ns");
}

#[test]
fn park_resolved_at_the_boundary_charges_nothing() {
    let mut e = traced_engine();
    // The whole park happened before the reset: the new phase sees zero
    // stall time and no UNSTALL span (a zero-length span would desync the
    // checker's sum against an earlier-phase STALL record).
    e.begin_phase(2_000_000, false);
    let op = Op::Insert { key: b"k".to_vec(), value: Payload::from_bytes(b"v") };
    let FrontendOp::Done(_) = e.frontend_client_op(3, op, 1_000_000, 2_000_000) else {
        panic!("fresh engine cannot be write-blocked");
    };
    assert_eq!(e.metrics.stall_ns, 0);
    assert!(unstall_durs(&e).is_empty(), "no span for a pre-boundary park");
}

#[test]
fn in_phase_park_accounting_is_unchanged() {
    let mut e = traced_engine();
    e.begin_phase(0, false);
    let op = Op::Insert { key: b"k".to_vec(), value: Payload::from_bytes(b"v") };
    let FrontendOp::Done(_) = e.frontend_client_op(1, op, 500, 1_500) else {
        panic!("fresh engine cannot be write-blocked");
    };
    assert_eq!(e.metrics.stall_ns, 1_000, "same-phase parks charge issue-to-execute as before");
    assert_eq!(unstall_durs(&e), vec![1_000]);
}
