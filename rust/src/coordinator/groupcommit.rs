//! Cross-shard WAL group commit: the per-domain batch ledger.
//!
//! A [`GroupCommitter`] is a shared handle rebound across shards in
//! `ShardedEngine::new` exactly like the `SharedTimer`/`CpuPool`/`KeyArena`/
//! `TraceSink`: all engines of one frontend stage WAL records into ONE
//! ledger, so records from different shards arriving within a commit window
//! fuse into a single device-visible append on the shared SSD/HDD pair.
//!
//! The ledger itself is pure bookkeeping — it never touches the clock, the
//! devices, or the metrics. An engine *stages* a member (its record is
//! already on media, appended untimed) and the frontend later *closes* due
//! batches from the global event loop: one fused `charge` on the shared
//! timer, then per-member acks. A batch becomes due when its deadline event
//! fires (`staged_at + commit_window_ns` of its first member) or when it
//! fills to `commit_batch_max`.
//!
//! Batch ids are unique for the life of the committer, so a deadline event
//! for a batch that already closed by fill is recognisably stale (no-op).

use std::cell::RefCell;
use std::rc::Rc;

use crate::config::BatchConfig;
use crate::sim::Ns;
use crate::zone::Dev;

/// One staged WAL record awaiting its batch's fused append.
#[derive(Clone, Copy, Debug)]
pub struct Member {
    pub shard: usize,
    pub client: usize,
    /// Record length on media (its share of the fused transfer).
    pub bytes: u64,
    /// When the client op was issued (latency base).
    pub issued_at: Ns,
    /// When the record was staged (queue-wait base: per-op wait is still
    /// measured from its own issue point).
    pub staged_at: Ns,
    /// When the op's foreground CPU work completes; the ack is
    /// `max(fused finish, cpu_ready)`.
    pub cpu_ready: Ns,
}

/// An open or due batch: all members bound for one fused append on `dev`.
#[derive(Debug)]
pub struct Batch {
    pub id: u64,
    pub dev: Dev,
    pub opened_at: Ns,
    pub deadline: Ns,
    pub members: Vec<Member>,
}

impl Batch {
    pub fn total_bytes(&self) -> u64 {
        self.members.iter().map(|m| m.bytes).sum()
    }
}

/// What [`GroupCommitter::stage`] did, so the staging engine can schedule
/// the window-deadline event for a batch it just opened.
#[derive(Clone, Copy, Debug)]
pub struct StageOutcome {
    pub batch_id: u64,
    /// This member opened a new batch: push a `WalCommit(batch_id)` event
    /// at `deadline` and emit the `BATCHO` trace record.
    pub opened: bool,
    pub deadline: Ns,
}

#[derive(Debug)]
struct Inner {
    enabled: bool,
    window_ns: u64,
    batch_max: usize,
    next_id: u64,
    /// At most one open batch per device (Ssd = 0, Hdd = 1).
    open: [Option<Batch>; 2],
    /// Closed batches awaiting the frontend's fused append, close order.
    due: Vec<Batch>,
    /// Total members ever staged (test/assert visibility).
    staged_total: u64,
}

fn dev_ix(dev: Dev) -> usize {
    match dev {
        Dev::Ssd => 0,
        Dev::Hdd => 1,
    }
}

/// Cloneable per-domain handle (see module docs).
#[derive(Clone, Debug)]
pub struct GroupCommitter(Rc<RefCell<Inner>>);

impl GroupCommitter {
    pub fn new(cfg: &BatchConfig) -> Self {
        GroupCommitter(Rc::new(RefCell::new(Inner {
            enabled: cfg.group_commit_enabled(),
            window_ns: cfg.commit_window_ns,
            batch_max: cfg.commit_batch_max.max(1),
            next_id: 0,
            open: [None, None],
            due: Vec::new(),
            staged_total: 0,
        })))
    }

    /// Does group commit engage at all? (`group_commit && batch_max > 1`;
    /// the off path never calls any other method.)
    pub fn enabled(&self) -> bool {
        self.0.borrow().enabled
    }

    /// Stage one record into the open batch for `dev` (opening one if
    /// needed). A batch that reaches `commit_batch_max` moves to the due
    /// queue immediately.
    pub fn stage(&self, dev: Dev, m: Member) -> StageOutcome {
        let mut g = self.0.borrow_mut();
        g.staged_total += 1;
        let window = g.window_ns;
        let batch_max = g.batch_max;
        let ix = dev_ix(dev);
        let mut opened = false;
        if g.open[ix].is_none() {
            let id = g.next_id;
            g.next_id += 1;
            g.open[ix] = Some(Batch {
                id,
                dev,
                opened_at: m.staged_at,
                deadline: m.staged_at + window,
                members: Vec::new(),
            });
            opened = true;
        }
        let batch = g.open[ix].as_mut().unwrap();
        batch.members.push(m);
        let (batch_id, deadline, full) =
            (batch.id, batch.deadline, batch.members.len() >= batch_max);
        if full {
            let b = g.open[ix].take().unwrap();
            g.due.push(b);
        }
        StageOutcome { batch_id, opened, deadline }
    }

    /// The window-deadline event for `id` fired: close the batch if it is
    /// still open. Stale ids (batch already closed by fill) are a no-op —
    /// ids are never reused.
    pub fn on_deadline(&self, id: u64) {
        let mut g = self.0.borrow_mut();
        for ix in 0..2 {
            if g.open[ix].as_ref().is_some_and(|b| b.id == id) {
                let b = g.open[ix].take().unwrap();
                g.due.push(b);
                return;
            }
        }
    }

    pub fn has_due(&self) -> bool {
        !self.0.borrow().due.is_empty()
    }

    /// Drain the due queue in close order (the frontend's post-event hook).
    pub fn take_due(&self) -> Vec<Batch> {
        std::mem::take(&mut self.0.borrow_mut().due)
    }

    /// Members currently staged in open batches (not yet due).
    pub fn open_members(&self) -> usize {
        let g = self.0.borrow();
        g.open.iter().flatten().map(|b| b.members.len()).sum()
    }

    /// Total members ever staged through this committer.
    pub fn staged_total(&self) -> u64 {
        self.0.borrow().staged_total
    }

    /// Two handles share one ledger (the shard-layer rebinding invariant,
    /// mirroring `SharedTimer::shares_with`).
    pub fn shares_with(&self, other: &GroupCommitter) -> bool {
        Rc::ptr_eq(&self.0, &other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(window_ns: u64, batch_max: usize) -> BatchConfig {
        BatchConfig {
            group_commit: true,
            commit_window_ns: window_ns,
            commit_batch_max: batch_max,
            ..BatchConfig::default()
        }
    }

    fn member(shard: usize, at: Ns) -> Member {
        Member { shard, client: 0, bytes: 100, issued_at: at, staged_at: at, cpu_ready: at }
    }

    #[test]
    fn first_member_opens_and_deadline_closes() {
        let gc = GroupCommitter::new(&cfg(1_000, 64));
        let o = gc.stage(Dev::Ssd, member(0, 50));
        assert!(o.opened);
        assert_eq!(o.deadline, 1_050);
        let o2 = gc.stage(Dev::Ssd, member(1, 300));
        assert!(!o2.opened, "window already open");
        assert_eq!(o2.batch_id, o.batch_id);
        assert!(!gc.has_due());
        assert_eq!(gc.open_members(), 2);
        gc.on_deadline(o.batch_id);
        assert!(gc.has_due());
        let due = gc.take_due();
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].members.len(), 2);
        assert_eq!(due[0].total_bytes(), 200);
        assert_eq!(gc.open_members(), 0);
        assert_eq!(gc.staged_total(), 2);
    }

    #[test]
    fn fill_closes_early_and_stale_deadline_is_noop() {
        let gc = GroupCommitter::new(&cfg(1_000, 2));
        let o = gc.stage(Dev::Ssd, member(0, 10));
        gc.stage(Dev::Ssd, member(1, 20));
        assert!(gc.has_due(), "batch_max reached must close the batch");
        // A third record opens a NEW batch with a fresh id.
        let o3 = gc.stage(Dev::Ssd, member(2, 30));
        assert!(o3.opened);
        assert_ne!(o3.batch_id, o.batch_id);
        // The first batch's deadline event is now stale: no-op.
        gc.on_deadline(o.batch_id);
        assert_eq!(gc.take_due().len(), 1);
        assert_eq!(gc.open_members(), 1);
    }

    #[test]
    fn devices_batch_independently() {
        let gc = GroupCommitter::new(&cfg(1_000, 64));
        let a = gc.stage(Dev::Ssd, member(0, 10));
        let b = gc.stage(Dev::Hdd, member(0, 10));
        assert!(a.opened && b.opened);
        assert_ne!(a.batch_id, b.batch_id);
        gc.on_deadline(a.batch_id);
        let due = gc.take_due();
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].dev, Dev::Ssd);
        assert_eq!(gc.open_members(), 1, "the HDD batch stays open");
        gc.on_deadline(b.batch_id);
        assert_eq!(gc.take_due()[0].dev, Dev::Hdd);
    }

    #[test]
    fn handles_share_one_ledger() {
        let gc = GroupCommitter::new(&cfg(1_000, 64));
        let clone = gc.clone();
        clone.stage(Dev::Ssd, member(0, 10));
        assert_eq!(gc.open_members(), 1);
        assert!(gc.shares_with(&clone));
        assert!(!gc.shares_with(&GroupCommitter::new(&cfg(1_000, 64))));
    }

    #[test]
    fn disabled_config_reports_disabled() {
        let mut c = cfg(1_000, 1);
        assert!(!GroupCommitter::new(&c).enabled(), "batch_max 1 reduces to off");
        c.commit_batch_max = 8;
        c.group_commit = false;
        assert!(!GroupCommitter::new(&c).enabled());
        c.group_commit = true;
        assert!(GroupCommitter::new(&c).enabled());
    }
}
