//! WAL + SSD-cache zone pool (§3.2, §3.5).
//!
//! HHZS (and AUTO) reserve a fixed number of SSD zones — the configured
//! maximum WAL size divided by the zone capacity — shared between WAL zones
//! and cache zones. All WAL data is guaranteed to fit; empty pool zones may
//! be converted into *cache zones* holding data blocks evicted from the
//! in-memory block cache, and are reclaimed FIFO (oldest cache zone first)
//! when the WAL needs space or the cache grows.
//!
//! The basic schemes (§2.3) run in *dynamic* mode instead: WAL zones are
//! allocated like any other zone (SSD if one is empty, else HDD).
//!
//! The cache bookkeeping is exactly §3.5: an in-memory mapping table
//! `(SST id, block offset) → SSD cache location` plus an in-memory FIFO
//! queue used to identify the blocks of an evicted zone.

use std::collections::{HashMap, VecDeque};

use crate::lsm::SstId;
use crate::metrics::{Metrics, WriteCategory};
use crate::sim::Ns;
use crate::trace::{Event, IoOp, TraceSink};
use crate::wire::WireBuf;
use crate::zenfs::ZenFs;
use crate::zone::{Dev, ZoneId};

/// Outcome of [`PoolManager::append_wal_staged`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StagedAppend {
    /// The record is on media (untimed); the caller must register it with
    /// the group committer so the batch close charges the fused transfer.
    Staged { dev: Dev, len: u64 },
    /// No pool zone could host it — fell back to a timed overflow append
    /// completing at `finish`; the record must NOT join a batch.
    Overflow { finish: Ns },
}

/// Location of a cached block inside an SSD cache zone.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheLoc {
    pub zone: ZoneId,
    pub offset: u64,
    pub len: u32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct FifoEntry {
    sst: SstId,
    block_offset: u64,
    zone: ZoneId,
}

enum Mode {
    /// HHZS/AUTO: fixed SSD zone pool.
    Reserved { pool: Vec<ZoneId> },
    /// Basic schemes: allocate WAL zones anywhere on demand.
    Dynamic,
}

/// One WAL segment = the log of one MemTable. Released when flushed.
#[derive(Default, Clone, Debug)]
struct Segment {
    zones: Vec<(Dev, ZoneId)>,
    bytes: u64,
    /// Byte runs of this segment's records: (dev, zone, offset, len) —
    /// segments interleave within zones, so recovery needs exact runs.
    runs: Vec<(Dev, ZoneId, u64, u64)>,
}

pub struct PoolManager {
    mode: Mode,
    /// Live WAL segments: segment id → zones holding its records.
    segments: HashMap<u64, Segment>,
    /// (dev, zone) → number of live segments with records in it.
    zone_refs: HashMap<(Dev, ZoneId), u32>,
    active_wal: Option<(Dev, ZoneId)>,
    cur_segment: u64,
    next_segment: u64,
    /// Cache zones in creation (FIFO) order; the active one is last.
    cache_zones: VecDeque<ZoneId>,
    mapping: HashMap<(SstId, u64), CacheLoc>,
    fifo: VecDeque<FifoEntry>,
    /// The most recent WAL record's placement — (segment, dev, zone,
    /// offset, len) — the append a power-loss crash tears mid-record.
    last_record: Option<(u64, Dev, ZoneId, u64, u64)>,
    /// Overflow WAL appends that could not be placed in the pool (should
    /// stay 0 when the pool is sized per §3.2).
    pub wal_overflows: u64,
    pub cache_zone_evictions: u64,
    /// Observation-only trace sink + the owning shard's id to stamp on
    /// WAL/cache I/O events. Disabled by default.
    trace: TraceSink,
    trace_shard: usize,
}

impl PoolManager {
    pub fn reserved(pool: Vec<ZoneId>) -> Self {
        Self::with_mode(Mode::Reserved { pool })
    }

    pub fn dynamic() -> Self {
        Self::with_mode(Mode::Dynamic)
    }

    fn with_mode(mode: Mode) -> Self {
        PoolManager {
            mode,
            segments: HashMap::from([(0, Segment::default())]),
            zone_refs: HashMap::new(),
            active_wal: None,
            cur_segment: 0,
            next_segment: 1,
            cache_zones: VecDeque::new(),
            mapping: HashMap::new(),
            fifo: VecDeque::new(),
            last_record: None,
            wal_overflows: 0,
            cache_zone_evictions: 0,
            trace: TraceSink::disabled(),
            trace_shard: 0,
        }
    }

    /// Attach a trace sink; `shard` tags this pool's I/O events.
    pub fn set_trace(&mut self, trace: TraceSink, shard: usize) {
        self.trace = trace;
        self.trace_shard = shard;
    }

    fn trace_io(&self, dev: Dev, op: IoOp, sst: Option<u64>, bytes: u64, wait: Ns, at: Ns) {
        let shard = self.trace_shard;
        self.trace
            .emit(|| Event::Io { dev, op, shard, job: None, sst, bytes, wait, at });
    }

    pub fn is_reserved_mode(&self) -> bool {
        matches!(self.mode, Mode::Reserved { .. })
    }

    /// Zones currently holding live WAL data (D_0 proxy, §3.3).
    pub fn wal_zones_in_use(&self) -> u32 {
        self.zone_refs.len() as u32
    }

    pub fn cached_blocks(&self) -> usize {
        self.mapping.len()
    }

    pub fn cache_zone_count(&self) -> usize {
        self.cache_zones.len()
    }

    /// An empty pool zone not used by WAL or cache.
    fn find_empty_pool_zone(&self, fs: &ZenFs) -> Option<ZoneId> {
        let Mode::Reserved { pool } = &self.mode else { return None };
        pool.iter()
            .find(|z| {
                fs.ssd.zone(**z).is_empty()
                    && !self.cache_zones.contains(z)
                    && self.active_wal != Some((Dev::Ssd, **z))
            })
            .copied()
    }

    // ------------------------------------------------------------------
    // WAL
    // ------------------------------------------------------------------

    /// A record that does not fit the active WAL zone strands the zone's
    /// tail remainder — those bytes are write-pointer dead space until the
    /// zone resets. Account them (metric + trace) before switching zones;
    /// they were previously dropped silently.
    fn account_stranded_tail(&mut self, fs: &ZenFs, metrics: &mut Metrics, at: Ns) {
        let Some((dev, z)) = self.active_wal else { return };
        let pad = fs.device_ref(dev).zone(z).remaining();
        if pad == 0 {
            return;
        }
        metrics.wal_pad_bytes += pad;
        let shard = self.trace_shard;
        self.trace.emit(|| Event::WalPad { shard, dev, zone: z, bytes: pad, at });
    }

    /// Append a WAL record for the current segment. Returns the device used
    /// and the virtual completion time. `preferred` is the policy's WAL
    /// placement for dynamic mode.
    pub fn append_wal(
        &mut self,
        fs: &mut ZenFs,
        metrics: &mut Metrics,
        now: Ns,
        record: &WireBuf,
        preferred: Dev,
    ) -> Ns {
        let len = record.len();
        // Ensure an active WAL zone with room.
        let need_new = match self.active_wal {
            None => true,
            Some((dev, z)) => fs.device_ref(dev).zone(z).remaining() < len,
        };
        if need_new {
            self.account_stranded_tail(fs, metrics, now);
            self.active_wal = self.allocate_wal_zone(fs, preferred);
        }
        let Some((dev, z)) = self.active_wal else {
            // Nowhere to put WAL data at all (pathological) — charge the
            // preferred device anyway so time advances, and count it.
            self.wal_overflows += 1;
            let (s, f) = fs.charge(now, preferred, crate::sim::AccessKind::SeqWrite, len);
            metrics.record_queue_wait(preferred, s.saturating_sub(now));
            metrics.record_write(WriteCategory::Wal, preferred, len);
            self.trace_io(preferred, IoOp::WalOverflow, None, len, s.saturating_sub(now), now);
            self.last_record = None;
            return f;
        };
        let (offset, start, finish) = fs
            .device(dev)
            .append(now, z, record)
            .expect("WAL append within checked capacity");
        metrics.record_queue_wait(dev, start.saturating_sub(now));
        metrics.record_write(WriteCategory::Wal, dev, len);
        self.trace_io(dev, IoOp::Wal, None, len, start.saturating_sub(now), now);
        self.note_record(dev, z, offset, len);
        finish
    }

    /// Stage a WAL record for a cross-shard group commit: the record lands
    /// on media *untimed* (full segment/run/ref bookkeeping, so crash
    /// recovery replays it), but no device time, queue wait, or write
    /// traffic is charged — the frontend's batch close issues ONE fused
    /// append for the whole window and attributes those there. The
    /// overflow path (nowhere to place the record) cannot batch and falls
    /// back to the timed behaviour.
    pub fn append_wal_staged(
        &mut self,
        fs: &mut ZenFs,
        metrics: &mut Metrics,
        now: Ns,
        record: &WireBuf,
        preferred: Dev,
    ) -> StagedAppend {
        let len = record.len();
        let need_new = match self.active_wal {
            None => true,
            Some((dev, z)) => fs.device_ref(dev).zone(z).remaining() < len,
        };
        if need_new {
            self.account_stranded_tail(fs, metrics, now);
            self.active_wal = self.allocate_wal_zone(fs, preferred);
        }
        let Some((dev, z)) = self.active_wal else {
            self.wal_overflows += 1;
            let (s, f) = fs.charge(now, preferred, crate::sim::AccessKind::SeqWrite, len);
            metrics.record_queue_wait(preferred, s.saturating_sub(now));
            metrics.record_write(WriteCategory::Wal, preferred, len);
            self.trace_io(preferred, IoOp::WalOverflow, None, len, s.saturating_sub(now), now);
            self.last_record = None;
            return StagedAppend::Overflow { finish: f };
        };
        let offset = fs
            .device(dev)
            .append_untimed(z, record)
            .expect("WAL append within checked capacity");
        self.note_record(dev, z, offset, len);
        StagedAppend::Staged { dev, len }
    }

    /// Segment/run/zone-ref/tail bookkeeping shared by the timed and
    /// staged append paths.
    fn note_record(&mut self, dev: Dev, z: ZoneId, offset: u64, len: u64) {
        let seg = self.segments.entry(self.cur_segment).or_default();
        if !seg.zones.contains(&(dev, z)) {
            seg.zones.push((dev, z));
            *self.zone_refs.entry((dev, z)).or_insert(0) += 1;
        }
        seg.bytes += len;
        // Extend the last run if contiguous, else start a new one.
        match seg.runs.last_mut() {
            Some((rd, rz, roff, rlen)) if *rd == dev && *rz == z && *roff + *rlen == offset => {
                *rlen += len;
            }
            _ => seg.runs.push((dev, z, offset, len)),
        }
        self.last_record = Some((self.cur_segment, dev, z, offset, len));
    }

    /// Logical length of the most recent WAL record, if it is still the
    /// log tail (the crash injector's tear-size input).
    pub fn last_record_len(&self) -> Option<u64> {
        self.last_record.map(|(_, _, _, _, len)| len)
    }

    /// Physically tear the most recent WAL record at `keep` surviving bytes
    /// (crash injection): the zone's write pointer lands mid-record and the
    /// pool's run bookkeeping shrinks to match the surviving media, so
    /// post-recovery appends and the decode discipline both see exactly
    /// what a power loss would leave. Returns the torn (dev, zone, new wp).
    pub fn tear_wal_tail(&mut self, fs: &mut ZenFs, keep: u64) -> Option<(Dev, ZoneId, u64)> {
        let (seg_id, dev, zone, offset, len) = self.last_record.take()?;
        let keep = keep.min(len);
        let wp = fs.device(dev).power_loss_truncate(zone, offset + keep);
        if let Some(seg) = self.segments.get_mut(&seg_id) {
            let lost = len - keep;
            seg.bytes = seg.bytes.saturating_sub(lost);
            if let Some((_, _, roff, rlen)) = seg.runs.last_mut() {
                debug_assert_eq!(*roff + *rlen, offset + len, "record is the tail of the log");
                *rlen = rlen.saturating_sub(lost);
                if *rlen == 0 {
                    seg.runs.pop();
                }
            }
        }
        Some((dev, zone, wp))
    }

    /// Zones currently holding live WAL data: every zone with live
    /// segment refs, plus the active WAL zone. Used for recovery's orphan
    /// GC exclusion and the residency-gauge partition (WAL vs SST bytes).
    pub fn wal_zone_ids(&self) -> Vec<(Dev, ZoneId)> {
        let mut v: Vec<(Dev, ZoneId)> = self.zone_refs.keys().copied().collect();
        if let Some(az) = self.active_wal {
            if !v.contains(&az) {
                v.push(az);
            }
        }
        v
    }

    /// SSD cache zones, oldest first (residency-gauge partition).
    pub fn cache_zone_ids(&self) -> Vec<ZoneId> {
        self.cache_zones.iter().copied().collect()
    }

    /// Every (dev, zone) the pool currently holds live data in: WAL zones
    /// (per-segment refs + the active zone) and SSD cache zones. Recovery's
    /// orphan GC must not touch these.
    pub fn referenced_zones(&self) -> Vec<(Dev, ZoneId)> {
        let mut v = self.wal_zone_ids();
        for z in &self.cache_zones {
            let k = (Dev::Ssd, *z);
            if !v.contains(&k) {
                v.push(k);
            }
        }
        v
    }

    /// WAL runs of every live segment (for write-pointer validation):
    /// (dev, zone, offset, len) tuples.
    pub fn live_runs(&self) -> Vec<(Dev, ZoneId, u64, u64)> {
        let mut v = Vec::new();
        for seg in self.segments.values() {
            v.extend(seg.runs.iter().copied());
        }
        v
    }

    /// Cached-block locations (for write-pointer validation).
    pub fn cache_locs(&self) -> Vec<CacheLoc> {
        self.mapping.values().copied().collect()
    }

    /// Read back the wire-form records of every live (unflushed) WAL
    /// segment, oldest first — the crash-recovery input. Charges
    /// sequential reads for the replayed (logical) bytes.
    ///
    /// Torn-tail hardened: a power loss can leave a zone's write pointer
    /// short of a recorded run (the final record was truncated mid-bytes).
    /// Each run is clamped to the surviving media — the intact prefix is
    /// read, the run metadata shrinks to match, and the segment's remaining
    /// runs (which can only postdate the tear) are dropped rather than
    /// replayed as garbage. Torn *middle* runs cannot occur: a run only
    /// closes when its zone fills, so any tear is at the log tail.
    pub fn recover_segments(
        &mut self,
        fs: &mut ZenFs,
        metrics: &mut Metrics,
        now: Ns,
    ) -> Vec<(u64, WireBuf)> {
        let mut ids: Vec<u64> = self.segments.keys().copied().collect();
        ids.sort_unstable();
        let mut out = Vec::new();
        for id in ids {
            let runs = self.segments[&id].runs.clone();
            let mut bytes = WireBuf::new();
            let mut new_runs = Vec::with_capacity(runs.len());
            let mut seg_bytes = 0u64;
            for (dev, zone, offset, len) in runs {
                let wp = fs.device_ref(dev).zone(zone).wp();
                let avail = wp.saturating_sub(offset).min(len);
                if avail > 0 {
                    let data = fs
                        .device(dev)
                        .read_untimed(zone, offset, avail)
                        .expect("surviving WAL run readable");
                    let (s, _) = fs.charge(now, dev, crate::sim::AccessKind::SeqRead, avail);
                    metrics.record_queue_wait(dev, s.saturating_sub(now));
                    self.trace_io(dev, IoOp::WalRecover, None, avail, s.saturating_sub(now), now);
                    bytes.append_buf(&data);
                    new_runs.push((dev, zone, offset, avail));
                    seg_bytes += avail;
                }
                if avail < len {
                    break; // torn tail — nothing after it survived
                }
            }
            if let Some(seg) = self.segments.get_mut(&id) {
                seg.runs = new_runs;
                seg.bytes = seg_bytes;
            }
            out.push((id, bytes));
        }
        out
    }

    fn allocate_wal_zone(&mut self, fs: &mut ZenFs, preferred: Dev) -> Option<(Dev, ZoneId)> {
        match &self.mode {
            Mode::Reserved { .. } => {
                if let Some(z) = self.find_empty_pool_zone(fs) {
                    return Some((Dev::Ssd, z));
                }
                // Reclaim the oldest cache zone for the WAL (§3.5: "HHZS
                // evicts cached blocks if it runs out of space ... when
                // writing new WAL data").
                if self.evict_oldest_cache_zone(fs) {
                    if let Some(z) = self.find_empty_pool_zone(fs) {
                        return Some((Dev::Ssd, z));
                    }
                }
                None
            }
            Mode::Dynamic => {
                // Any empty zone on the preferred device, else the other.
                for dev in [preferred, other(preferred)] {
                    let free = match dev {
                        Dev::Ssd => {
                            // Respect zenfs reservations (none for basics).
                            (0..fs.ssd.num_zones()).find(|z| {
                                fs.ssd.zone(*z).is_empty()
                                    && !fs.reserved_ssd_zones().contains(z)
                            })
                        }
                        Dev::Hdd => fs.hdd.find_empty_zone(),
                    };
                    if let Some(z) = free {
                        return Some((dev, z));
                    }
                }
                None
            }
        }
    }

    /// Seal the current WAL segment (MemTable rotation); returns its id and
    /// switches appends to a fresh segment.
    pub fn seal_segment(&mut self) -> u64 {
        let sealed = self.cur_segment;
        self.cur_segment = self.next_segment;
        self.next_segment += 1;
        self.segments.entry(self.cur_segment).or_default();
        sealed
    }

    /// Release a flushed segment: decrement zone refs; zones that no longer
    /// hold live WAL data are reset (pool zones become reusable; dynamic
    /// zones return to the device).
    pub fn release_segment(&mut self, fs: &mut ZenFs, seg: u64) {
        let Some(segment) = self.segments.remove(&seg) else { return };
        for (dev, z) in segment.zones {
            let refs = self.zone_refs.get_mut(&(dev, z)).expect("ref tracked");
            *refs -= 1;
            if *refs == 0 {
                self.zone_refs.remove(&(dev, z));
                if self.active_wal == Some((dev, z)) {
                    self.active_wal = None;
                }
                fs.device(dev).reset(z);
            }
        }
    }

    // ------------------------------------------------------------------
    // SSD cache (§3.5)
    // ------------------------------------------------------------------

    /// Look up a cached block; on hit, charges an SSD random read and
    /// returns the data plus completion time.
    pub fn cache_lookup(
        &mut self,
        fs: &mut ZenFs,
        metrics: &mut Metrics,
        now: Ns,
        sst: SstId,
        block_offset: u64,
    ) -> Option<(WireBuf, Ns)> {
        let loc = *self.mapping.get(&(sst, block_offset))?;
        let (data, start, finish) =
            fs.ssd.read_random(now, loc.zone, loc.offset, loc.len as u64).ok()?;
        metrics.record_queue_wait(Dev::Ssd, start.saturating_sub(now));
        self.trace_io(
            Dev::Ssd,
            IoOp::CacheRead,
            Some(sst),
            loc.len as u64,
            start.saturating_sub(now),
            now,
        );
        Some((data, finish))
    }

    pub fn cache_contains(&self, sst: SstId, block_offset: u64) -> bool {
        self.mapping.contains_key(&(sst, block_offset))
    }

    /// Admit an evicted block (§3.5 workflow step 2). The engine has
    /// already verified the SST lives on the HDD. Charges an SSD
    /// sequential write. Returns false if no pool zone could host it.
    pub fn cache_admit(
        &mut self,
        fs: &mut ZenFs,
        metrics: &mut Metrics,
        now: Ns,
        sst: SstId,
        block_offset: u64,
        data: &WireBuf,
    ) -> bool {
        if !self.is_reserved_mode() || self.mapping.contains_key(&(sst, block_offset)) {
            return false;
        }
        let len = data.len();
        // Active cache zone = back of the FIFO deque.
        let need_new = match self.cache_zones.back() {
            None => true,
            Some(z) => fs.ssd.zone(*z).remaining() < len,
        };
        if need_new {
            let z = match self.find_empty_pool_zone(fs) {
                Some(z) => Some(z),
                None => {
                    // Evict the oldest cache zone; never the active one
                    // (it is full anyway when we get here).
                    if self.evict_oldest_cache_zone(fs) {
                        self.find_empty_pool_zone(fs)
                    } else {
                        None
                    }
                }
            };
            match z {
                Some(z) => self.cache_zones.push_back(z),
                None => return false, // pool fully claimed by WAL
            }
        }
        let zone = *self.cache_zones.back().expect("active cache zone");
        let (offset, start, _) = fs.ssd.append(now, zone, data).expect("cache append fits");
        metrics.record_queue_wait(Dev::Ssd, start.saturating_sub(now));
        metrics.record_write(WriteCategory::CacheZone, Dev::Ssd, len);
        self.trace_io(Dev::Ssd, IoOp::CacheWrite, Some(sst), len, start.saturating_sub(now), now);
        {
            let (shard, at) = (self.trace_shard, now);
            self.trace.emit(|| Event::CacheAdmit { shard, sst, zone, bytes: len, at });
        }
        self.mapping
            .insert((sst, block_offset), CacheLoc { zone, offset, len: len as u32 });
        self.fifo.push_back(FifoEntry { sst, block_offset, zone });
        true
    }

    /// FIFO zone-granular eviction (§3.5): drop the oldest cache zone,
    /// removing its blocks from the mapping table via the FIFO queue.
    fn evict_oldest_cache_zone(&mut self, fs: &mut ZenFs) -> bool {
        let Some(zone) = self.cache_zones.pop_front() else { return false };
        while let Some(head) = self.fifo.front() {
            if head.zone != zone {
                break;
            }
            let e = self.fifo.pop_front().unwrap();
            self.mapping.remove(&(e.sst, e.block_offset));
        }
        fs.ssd.reset(zone);
        self.cache_zone_evictions += 1;
        let (shard, at) = (self.trace_shard, self.trace.now_hint());
        self.trace.emit(|| Event::CacheEvict { shard, zone, at });
        true
    }

    /// Drop mapping entries of a deleted SST (stale FIFO entries are
    /// skipped at eviction time via the mapping check).
    pub fn invalidate_sst(&mut self, sst: SstId) {
        self.mapping.retain(|(s, _), _| *s != sst);
    }
}

fn other(d: Dev) -> Dev {
    match d {
        Dev::Ssd => Dev::Hdd,
        Dev::Hdd => Dev::Ssd,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, MIB};

    fn wire(bytes: &[u8]) -> WireBuf {
        WireBuf::from_bytes(bytes)
    }

    fn fs_with_pool() -> (ZenFs, PoolManager, Metrics) {
        let cfg = Config::tiny();
        let mut fs = ZenFs::new(
            cfg.geometry.ssd_zone_cap,
            20,
            cfg.geometry.hdd_zone_cap,
            64,
            cfg.ssd.clone(),
            cfg.hdd.clone(),
        );
        let pool = fs.reserve_ssd_zones(2);
        (fs, PoolManager::reserved(pool), Metrics::default())
    }

    #[test]
    fn wal_appends_fill_pool_zone() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        let rec = wire(&[0u8; 1024]);
        let f = pm.append_wal(&mut fs, &mut m, 0, &rec, Dev::Ssd);
        assert!(f > 0);
        assert_eq!(pm.wal_zones_in_use(), 1);
        assert_eq!(pm.wal_overflows, 0);
    }

    #[test]
    fn segment_release_resets_zone() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        pm.append_wal(&mut fs, &mut m, 0, &wire(&[0u8; 512]), Dev::Ssd);
        let seg = pm.seal_segment();
        pm.append_wal(&mut fs, &mut m, 0, &wire(&[0u8; 512]), Dev::Ssd);
        assert_eq!(pm.wal_zones_in_use(), 1, "both segments share the zone");
        pm.release_segment(&mut fs, seg);
        // Second segment still holds the zone.
        assert_eq!(pm.wal_zones_in_use(), 1);
        let seg2 = pm.seal_segment();
        pm.release_segment(&mut fs, seg2);
        assert_eq!(pm.wal_zones_in_use(), 0);
    }

    #[test]
    fn wal_spans_zones_when_full() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        let zone_cap = fs.ssd.zone_cap;
        // Fill past one zone.
        let rec = wire(&vec![0u8; (zone_cap / 2 + 100) as usize]);
        pm.append_wal(&mut fs, &mut m, 0, &rec, Dev::Ssd);
        pm.append_wal(&mut fs, &mut m, 0, &rec, Dev::Ssd);
        assert_eq!(pm.wal_zones_in_use(), 2);
    }

    #[test]
    fn cache_admit_lookup_roundtrip() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        let block = wire(&[7u8; 4096]);
        assert!(pm.cache_admit(&mut fs, &mut m, 0, 42, 8192, &block));
        assert!(pm.cache_contains(42, 8192));
        let (data, _) = pm.cache_lookup(&mut fs, &mut m, 0, 42, 8192).unwrap();
        assert_eq!(data, block);
        assert!(pm.cache_lookup(&mut fs, &mut m, 0, 42, 0).is_none());
    }

    #[test]
    fn duplicate_admission_rejected() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        let block = wire(&[1u8; 4096]);
        assert!(pm.cache_admit(&mut fs, &mut m, 0, 1, 0, &block));
        assert!(!pm.cache_admit(&mut fs, &mut m, 0, 1, 0, &block));
        assert_eq!(pm.cached_blocks(), 1);
    }

    #[test]
    fn fifo_zone_eviction_when_pool_exhausted() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        let zone_cap = fs.ssd.zone_cap;
        let block = wire(&[2u8; 4096]);
        let blocks_per_zone = zone_cap / 4096;
        // Fill both pool zones with cache blocks, then one more.
        let total = blocks_per_zone * 2 + 1;
        for i in 0..total {
            assert!(pm.cache_admit(&mut fs, &mut m, 0, 9, i * 4096, &block));
        }
        assert!(pm.cache_zone_evictions >= 1);
        // The first zone's blocks are gone from the mapping.
        assert!(!pm.cache_contains(9, 0));
        // The newest block is present.
        assert!(pm.cache_contains(9, (total - 1) * 4096));
    }

    #[test]
    fn wal_reclaims_cache_zones() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        let block = wire(&[3u8; 4096]);
        // Turn both pool zones into cache zones.
        let zone_cap = fs.ssd.zone_cap;
        for i in 0..(zone_cap / 4096) * 2 {
            pm.cache_admit(&mut fs, &mut m, 0, 5, i * 4096, &block);
        }
        assert_eq!(pm.cache_zone_count(), 2);
        // WAL append must evict a cache zone rather than overflow.
        let f = pm.append_wal(&mut fs, &mut m, 0, &wire(&[0u8; 1024]), Dev::Ssd);
        assert!(f > 0);
        assert_eq!(pm.wal_overflows, 0);
        assert_eq!(pm.wal_zones_in_use(), 1);
    }

    #[test]
    fn invalidate_sst_drops_mappings() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        pm.cache_admit(&mut fs, &mut m, 0, 1, 0, &wire(&[0u8; 128]));
        pm.cache_admit(&mut fs, &mut m, 0, 2, 0, &wire(&[0u8; 128]));
        pm.invalidate_sst(1);
        assert!(!pm.cache_contains(1, 0));
        assert!(pm.cache_contains(2, 0));
    }

    fn wal_record(i: u64) -> WireBuf {
        let mut rec = WireBuf::new();
        let key = format!("key-{i:04}");
        let val = format!("value-{i:04}");
        let payload = crate::wire::Payload::from_bytes(val.as_bytes());
        rec.push_entry(key.as_bytes(), i + 1, Some(payload));
        rec
    }

    #[test]
    fn tear_wal_tail_shrinks_run_and_media() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        let first_len = wal_record(0).len();
        pm.append_wal(&mut fs, &mut m, 0, &wal_record(0), Dev::Ssd);
        pm.append_wal(&mut fs, &mut m, 0, &wal_record(1), Dev::Ssd);
        let (dev, zone, wp) = pm.tear_wal_tail(&mut fs, 3).expect("tail tracked");
        assert_eq!(dev, Dev::Ssd);
        assert_eq!(wp, first_len + 3, "write pointer lands 3 bytes into record 1");
        assert_eq!(fs.device_ref(dev).zone(zone).wp(), wp);
        // The run bookkeeping shrank with the media.
        assert_eq!(pm.live_runs(), vec![(Dev::Ssd, zone, 0, first_len + 3)]);
        // The tail can only be torn once.
        assert!(pm.tear_wal_tail(&mut fs, 0).is_none());
    }

    #[test]
    fn recover_segments_clamps_torn_tail_instead_of_panicking() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        for i in 0..3 {
            pm.append_wal(&mut fs, &mut m, 0, &wal_record(i), Dev::Ssd);
        }
        // Surgically truncate the zone mid-final-record, bypassing the
        // pool's own bookkeeping — recovery must cope with stale runs.
        let (_, dev, zone, offset, len) = pm.last_record.unwrap();
        fs.device(dev).power_loss_truncate(zone, offset + len / 2);
        let segs = pm.recover_segments(&mut fs, &mut m, 0);
        assert_eq!(segs.len(), 1);
        let entries: Vec<_> = segs[0].1.entries().collect();
        assert_eq!(entries.len(), 2, "intact prefix replays; torn record is dropped");
        assert_eq!(entries[0].key.to_vec(), b"key-0000");
        assert_eq!(entries[1].key.to_vec(), b"key-0001");
        // Run metadata now matches the surviving media exactly.
        assert_eq!(pm.live_runs(), vec![(dev, zone, 0, offset + len / 2)]);
    }

    #[test]
    fn recover_segments_intact_log_round_trips() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        pm.append_wal(&mut fs, &mut m, 0, &wal_record(0), Dev::Ssd);
        let seg0 = pm.seal_segment();
        pm.append_wal(&mut fs, &mut m, 0, &wal_record(1), Dev::Ssd);
        let segs = pm.recover_segments(&mut fs, &mut m, 0);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].0, seg0);
        assert_eq!(segs[0].1.entries().count(), 1);
        assert_eq!(segs[1].1.entries().count(), 1);
    }

    #[test]
    fn stranded_zone_tail_is_accounted_as_pad() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        let zone_cap = fs.ssd.zone_cap;
        let rec = wire(&vec![0u8; (zone_cap / 2 + 100) as usize]);
        pm.append_wal(&mut fs, &mut m, 0, &rec, Dev::Ssd);
        assert_eq!(m.wal_pad_bytes, 0, "first record opens a fresh zone");
        // The second record does not fit zone 1's tail: the remainder is
        // stranded behind the write pointer and must be accounted.
        pm.append_wal(&mut fs, &mut m, 0, &rec, Dev::Ssd);
        assert_eq!(m.wal_pad_bytes, zone_cap - (zone_cap / 2 + 100));
        assert_eq!(pm.wal_zones_in_use(), 2);
    }

    #[test]
    fn staged_append_lands_on_media_without_charging() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        let rec = wal_record(0);
        let len = rec.len();
        match pm.append_wal_staged(&mut fs, &mut m, 0, &rec, Dev::Ssd) {
            StagedAppend::Staged { dev, len: l } => {
                assert_eq!(dev, Dev::Ssd);
                assert_eq!(l, len);
            }
            StagedAppend::Overflow { .. } => panic!("pool has room"),
        }
        // No write traffic yet — the batch close attributes the fused
        // transfer — but the record is durable and recoverable.
        assert!(m.write_traffic.get(&(WriteCategory::Wal, Dev::Ssd)).is_none());
        assert_eq!(pm.wal_zones_in_use(), 1);
        assert_eq!(pm.last_record_len(), Some(len));
        let segs = pm.recover_segments(&mut fs, &mut m, 0);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].1.entries().count(), 1);
    }

    #[test]
    fn staged_record_tears_like_a_timed_one() {
        let (mut fs, mut pm, mut m) = fs_with_pool();
        let first_len = wal_record(0).len();
        pm.append_wal(&mut fs, &mut m, 0, &wal_record(0), Dev::Ssd);
        pm.append_wal_staged(&mut fs, &mut m, 0, &wal_record(1), Dev::Ssd);
        let (dev, zone, wp) = pm.tear_wal_tail(&mut fs, 5).expect("tail tracked");
        assert_eq!(wp, first_len + 5, "write pointer lands 5 bytes into the staged record");
        assert_eq!(fs.device_ref(dev).zone(zone).wp(), wp);
    }

    #[test]
    fn dynamic_mode_allocates_anywhere() {
        let cfg = Config::tiny();
        let mut fs = ZenFs::new(
            cfg.geometry.ssd_zone_cap,
            2,
            cfg.geometry.hdd_zone_cap,
            8,
            cfg.ssd.clone(),
            cfg.hdd.clone(),
        );
        let mut pm = PoolManager::dynamic();
        let mut m = Metrics::default();
        // Occupy both SSD zones with files → WAL falls through to the HDD.
        fs.create_file(0, 1, Dev::Ssd, &wire(&[0u8; 64]), true).unwrap();
        fs.create_file(0, 2, Dev::Ssd, &wire(&[0u8; 64]), true).unwrap();
        pm.append_wal(&mut fs, &mut m, 0, &wire(&[0u8; 512]), Dev::Ssd);
        let hdd_wal = m
            .write_traffic
            .get(&(WriteCategory::Wal, Dev::Hdd))
            .map(|c| c.bytes)
            .unwrap_or(0);
        assert_eq!(hdd_wal, 512);
        // Cache is a no-op in dynamic mode.
        assert!(!pm.cache_admit(&mut fs, &mut m, 0, 1, 0, &wire(&[0u8; 64])));
    }
}
