//! Engine integration tests: correctness of the full KV path over the
//! hybrid zoned substrate, placement/migration/caching behaviour, stalls,
//! and the metric plumbing the experiments depend on.

use super::*;
use crate::policy::{AutoPolicy, BasicPolicy, HhzsPolicy};
use crate::lsm::Payload;
use crate::ycsb::{key_for, value_for};

fn engine_with(policy: Box<dyn Policy>) -> Engine {
    let mut cfg = Config::tiny();
    cfg.workload.load_objects = 20_000;
    Engine::new(cfg, policy)
}

fn hhzs_engine() -> Engine {
    engine_with(Box::new(HhzsPolicy::new(Config::tiny().lsm.num_levels)))
}

#[test]
fn put_get_roundtrip_memtable() {
    let mut e = hhzs_engine();
    e.put(b"alpha", b"one");
    e.put(b"beta", b"two");
    assert_eq!(e.get(b"alpha"), Some(Payload::from_bytes(b"one")));
    assert_eq!(e.get(b"beta"), Some(Payload::from_bytes(b"two")));
    assert_eq!(e.get(b"gamma"), None);
}

#[test]
fn overwrite_returns_latest() {
    let mut e = hhzs_engine();
    e.put(b"k", b"v1");
    e.put(b"k", b"v2");
    assert_eq!(e.get(b"k"), Some(Payload::from_bytes(b"v2")));
}

#[test]
fn delete_hides_key() {
    let mut e = hhzs_engine();
    e.put(b"k", b"v");
    e.delete(b"k");
    assert_eq!(e.get(b"k"), None);
}

#[test]
fn values_survive_flush_and_compaction() {
    let mut e = hhzs_engine();
    let n = 3_000u64;
    for i in 0..n {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    assert!(e.metrics.flushes > 0, "flushes should have happened");
    assert!(e.version.total_ssts() > 0);
    // Spot-check reads across the whole range, including keys that are now
    // deep in the tree.
    for i in (0..n).step_by(97) {
        assert_eq!(
            e.get(&key_for(i, 24)),
            Some(value_for(i, 1000)),
            "lost key {i} after flush/compaction"
        );
    }
}

#[test]
fn overwrites_survive_compaction() {
    let mut e = hhzs_engine();
    for round in 0..3u64 {
        for i in 0..1_500u64 {
            let v = format!("round{round}-{i}");
            e.put(&key_for(i, 24), v.as_bytes());
        }
    }
    e.quiesce();
    for i in (0..1_500u64).step_by(53) {
        let v = format!("round2-{i}");
        assert_eq!(e.get(&key_for(i, 24)), Some(Payload::from_bytes(v.as_bytes())), "key {i}");
    }
}

#[test]
fn virtual_time_advances_monotonically() {
    let mut e = hhzs_engine();
    let t0 = e.now;
    for i in 0..500u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    assert!(e.now > t0, "puts must cost virtual time");
}

#[test]
fn levels_populate_beyond_l0() {
    let mut e = hhzs_engine();
    for i in 0..20_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    let deep: usize = (1..e.version.num_levels()).map(|l| e.version.level(l).len()).sum();
    assert!(deep > 0, "compaction should push SSTs beyond L0");
    for lvl in 1..e.version.num_levels() {
        assert!(e.version.disjoint(lvl), "L{lvl} must be disjoint");
    }
}

#[test]
fn hhzs_utilizes_ssd_and_prioritizes_low_levels() {
    let mut e = hhzs_engine();
    for i in 0..20_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    // Write-guided placement should leave the SSD well-utilized after a
    // load that is ~2× the SSD size (O2's complaint about basics is
    // under-utilization or displacement).
    let free = e.fs.ssd_file_zones_free();
    let total = e.fs.ssd_file_zones_total();
    assert!(free * 4 <= total, "SSD under-utilized: {free}/{total} zones free");
    // L0 (flush outputs) go to the SSD whenever a zone is empty.
    let share = e.ssd_share_by_level();
    let (ssd0, all0) = share[0];
    if all0 > 0 {
        assert!(ssd0 * 2 >= all0, "most of L0 on SSD: {ssd0}/{all0}");
    }
    // After a skewed read phase, popularity migration + placement harmony
    // must not leave hot low-level data stranded: run reads then check
    // that *some* HDD→SSD or SSD→HDD refinement happened (full Fig 5(b)
    // behaviour is asserted by the exp1 harness).
    let mut reads = crate::ycsb::YcsbSource::new(
        crate::ycsb::Spec {
            kind: crate::ycsb::Kind::C,
            records: 20_000,
            ops: 8_000,
            alpha: 1.1,
            key_size: 24,
            value_size: 1000,
            seed: 11,
        },
        4,
    );
    e.run(&mut reads, 4, None, false);
    e.quiesce();
    assert!(
        e.metrics.migrations_cap + e.metrics.migrations_pop > 0
            || e.fs.ssd_file_zones_free() == 0,
        "workload-aware migration should engage under skewed reads"
    );
}

#[test]
fn wal_traffic_recorded() {
    let mut e = hhzs_engine();
    for i in 0..100u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    let wal_ssd = e
        .metrics
        .write_traffic
        .get(&(WriteCategory::Wal, Dev::Ssd))
        .map(|c| c.bytes)
        .unwrap_or(0);
    assert!(wal_ssd > 100 * 1000, "WAL bytes on SSD: {wal_ssd}");
}

#[test]
fn basic_scheme_places_high_levels_on_hdd() {
    let mut e = engine_with(Box::new(BasicPolicy::new(1)));
    for i in 0..20_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    // With h=1, everything at L1+ must be on the HDD.
    for lvl in 1..e.version.num_levels() {
        for m in e.version.level(lvl) {
            assert_eq!(
                e.fs.file_dev(m.id),
                Some(Dev::Hdd),
                "B1 must not place L{lvl} SSTs on the SSD"
            );
        }
    }
}

#[test]
fn auto_policy_runs_and_serves_reads() {
    let mut e = engine_with(Box::new(AutoPolicy::new()));
    for i in 0..8_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    for i in (0..8_000u64).step_by(211) {
        assert_eq!(e.get(&key_for(i, 24)), Some(value_for(i, 1000)));
    }
}

#[test]
fn stalls_are_counted_under_write_burst() {
    let mut cfg = Config::tiny();
    // Tiny memtables + tiny L0 stop bound to force stalls.
    cfg.lsm.memtable_size = 64 * 1024;
    cfg.lsm.l0_stop_files = 6;
    let mut e = Engine::new(cfg, Box::new(HhzsPolicy::new(7)));
    let mut src = crate::ycsb::YcsbSource::new(
        crate::ycsb::Spec {
            kind: crate::ycsb::Kind::Load,
            records: 30_000,
            ops: 30_000,
            alpha: 0.9,
            key_size: 24,
            value_size: 1000,
            seed: 1,
        },
        4,
    );
    e.run(&mut src, 4, None, false);
    assert_eq!(e.metrics.writes_done, 30_000);
    assert!(e.metrics.stalls > 0, "write burst should hit stalls");
}

#[test]
fn two_bg_threads_do_not_starve_compaction() {
    // Regression: with `bg_threads = 2` the flush reservation consumed the
    // whole pool (`total - flush_reserved == 0`), compaction never
    // scheduled, L0 reached `l0_stop_files`, and parked writers livelocked
    // — this test HUNG before the fix. At least one slot must stay
    // compaction-eligible whenever the pool has ≥ 2 threads.
    let mut cfg = Config::tiny();
    cfg.lsm.bg_threads = 2;
    cfg.lsm.memtable_size = 64 * 1024;
    cfg.lsm.l0_stop_files = 8;
    let mut e = Engine::new(cfg, Box::new(HhzsPolicy::new(7)));
    let spec = |kind, ops, seed| crate::ycsb::Spec {
        kind,
        records: 30_000,
        ops,
        alpha: 0.9,
        key_size: 24,
        value_size: 1000,
        seed,
    };
    let mut load =
        crate::ycsb::YcsbSource::new(spec(crate::ycsb::Kind::Load, 30_000, 1), 4);
    e.run(&mut load, 4, None, false);
    assert_eq!(e.metrics.writes_done, 30_000);
    assert!(e.metrics.compactions > 0, "compaction must run with bg_threads = 2");
    // And a measured YCSB-A phase on the loaded store terminates too.
    let mut a = crate::ycsb::YcsbSource::new(spec(crate::ycsb::Kind::A, 4_000, 2), 4);
    e.run(&mut a, 4, None, false);
    assert_eq!(e.metrics.ops_done, 4_000);

    // The degenerate single-thread pool must also survive: the one slot
    // serves flushes (priority) and compactions alternately.
    let mut cfg1 = Config::tiny();
    cfg1.lsm.bg_threads = 1;
    cfg1.lsm.memtable_size = 64 * 1024;
    cfg1.lsm.l0_stop_files = 8;
    let mut e1 = Engine::new(cfg1, Box::new(HhzsPolicy::new(7)));
    let mut load1 = crate::ycsb::YcsbSource::new(
        crate::ycsb::Spec {
            kind: crate::ycsb::Kind::Load,
            records: 15_000,
            ops: 15_000,
            alpha: 0.9,
            key_size: 24,
            value_size: 1000,
            seed: 3,
        },
        4,
    );
    e1.run(&mut load1, 4, None, false);
    assert_eq!(e1.metrics.writes_done, 15_000);
    assert!(e1.metrics.compactions > 0, "compaction must run with bg_threads = 1");
}

#[test]
fn long_scans_return_all_live_entries_across_many_ssts() {
    // Regression for the do_scan truncation bugs: deep levels were capped
    // at 3 SSTs each, and per-source reads broke on raw (not live) entry
    // counts, so long scans silently dropped qualifying entries once a
    // level's run spanned more than 3 files. With no tombstones in the
    // store, a scan must return exactly min(n, #keys >= start).
    let mut e = hhzs_engine();
    let total = 20_000u64;
    for i in 0..total {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    // Overwrite a slice so deep levels hold obsolete versions that the
    // merge dedups away.
    for i in 0..2_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i ^ 1, 1000));
    }
    e.flush_all();
    e.quiesce();
    let widest_level = (1..e.version.num_levels())
        .map(|l| e.version.level(l).len())
        .max()
        .unwrap();
    assert!(
        widest_level > 3,
        "scale check: a deep level must exceed the old 3-SST cap (got {widest_level})"
    );
    let mut keys: Vec<Vec<u8>> = (0..total).map(|i| key_for(i, 24)).collect();
    keys.sort();
    for (rank, n) in [(0usize, 10_000usize), (5_000, 8_000), (19_000, 5_000)] {
        let start = keys[rank].clone();
        let expected = (total as usize - rank).min(n);
        assert_eq!(
            e.scan(&start, n),
            expected,
            "scan from key rank {rank} with n = {n}"
        );
    }
}

#[test]
fn run_records_throughput_and_latencies() {
    let mut e = hhzs_engine();
    let mut load = crate::ycsb::YcsbSource::new(
        crate::ycsb::Spec {
            kind: crate::ycsb::Kind::Load,
            records: 10_000,
            ops: 10_000,
            alpha: 0.9,
            key_size: 24,
            value_size: 1000,
            seed: 3,
        },
        4,
    );
    e.run(&mut load, 4, None, true);
    assert_eq!(e.metrics.ops_done, 10_000);
    assert!(e.metrics.ops_per_sec() > 0.0);
    assert!(e.metrics.write_lat.n == 10_000);
    let mut reads = crate::ycsb::YcsbSource::new(
        crate::ycsb::Spec {
            kind: crate::ycsb::Kind::C,
            records: 10_000,
            ops: 2_000,
            alpha: 0.9,
            key_size: 24,
            value_size: 1000,
            seed: 3,
        },
        4,
    );
    e.run(&mut reads, 4, None, false);
    assert_eq!(e.metrics.reads_done, 2_000);
    assert!(e.metrics.read_lat.n == 2_000);
    assert!(e.metrics.read_lat.quantile(0.99) >= e.metrics.read_lat.quantile(0.5));
}

#[test]
fn throttling_caps_throughput() {
    let mut e = hhzs_engine();
    let spec = crate::ycsb::Spec {
        kind: crate::ycsb::Kind::Load,
        records: 5_000,
        ops: 5_000,
        alpha: 0.9,
        key_size: 24,
        value_size: 1000,
        seed: 5,
    };
    let mut src = crate::ycsb::YcsbSource::new(spec, 4);
    e.run(&mut src, 4, Some(2_000.0), false);
    let tput = e.metrics.ops_per_sec();
    assert!(tput <= 2_200.0, "throttled tput {tput} > target 2000 (+10%)");
    assert!(tput > 1_500.0, "throttled tput {tput} unreasonably low");
}

#[test]
fn scans_return_entries_and_charge_devices() {
    let mut e = hhzs_engine();
    for i in 0..5_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 100));
    }
    e.quiesce();
    let got = e.scan(&key_for(100, 24), 50);
    assert!(got > 0, "scan should see entries");
    let read_bytes: u64 = e.metrics.read_traffic.values().map(|c| c.bytes).sum();
    assert!(read_bytes > 0, "scan must charge device reads");
}

#[test]
fn ssd_cache_serves_hot_hdd_blocks() {
    let mut cfg = Config::tiny();
    cfg.lsm.block_cache_bytes = 16 * 1024; // tiny → rapid evictions
    let mut e = Engine::new(cfg, Box::new(HhzsPolicy::new(7)));
    for i in 0..20_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    // Hammer a small hot set: evictions → cache hints → SSD-cache
    // admissions; repeats then hit the SSD cache.
    for _ in 0..30 {
        for i in 0..40u64 {
            e.get(&key_for(i * 37, 24));
        }
    }
    assert!(
        e.pool.cached_blocks() > 0 || e.metrics.ssd_cache_hits > 0,
        "hot HDD blocks should reach the SSD cache (cached={} hits={})",
        e.pool.cached_blocks(),
        e.metrics.ssd_cache_hits
    );
}

#[test]
fn migration_respects_rate_limit_pacing() {
    // A migration of one SST at 4 MiB/s must take ≈ size/rate virtual time.
    let mut e = hhzs_engine();
    for i in 0..20_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    let migrated = e.metrics.migrations_cap + e.metrics.migrations_pop;
    let bytes = e.metrics.migration_bytes;
    if migrated > 0 {
        // Rate limiting means migration bytes / total time ≤ rate (+ slack).
        let dur_s = (e.now - 0) as f64 / 1e9;
        let avg_rate = bytes as f64 / dur_s;
        assert!(
            avg_rate <= e.cfg.hhzs.migration_rate_bps * 1.5,
            "migration rate {avg_rate} exceeds limit"
        );
    }
}

#[test]
fn hints_flow_to_policy() {
    // A counting policy verifies flush + all three compaction hint phases.
    use std::cell::RefCell;
    use std::rc::Rc;

    #[derive(Default)]
    struct Counts {
        flush: usize,
        start: usize,
        output: usize,
        finish: usize,
    }
    struct CountingPolicy(Rc<RefCell<Counts>>);
    impl Policy for CountingPolicy {
        fn name(&self) -> String {
            "counting".into()
        }
        fn reserved_pool_zones(&self, cfg: &Config) -> u32 {
            cfg.geometry.wal_cache_zones
        }
        fn on_hint(&mut self, hint: &Hint, _view: &View) {
            let mut c = self.0.borrow_mut();
            match hint {
                Hint::Flush(_) => c.flush += 1,
                Hint::Compaction(CompactionHint::Start { .. }) => c.start += 1,
                Hint::Compaction(CompactionHint::OutputSst { .. }) => c.output += 1,
                Hint::Compaction(CompactionHint::Finish { .. }) => c.finish += 1,
                Hint::CacheEvict(_) => {}
            }
        }
        fn on_sst_read(&mut self, _: SstId, _: Dev, _: Ns) {}
        fn on_sst_deleted(&mut self, _: SstId) {}
        fn place_sst(&mut self, level: usize, _: u64, _: SstOrigin, _: &View) -> Dev {
            if level < 2 {
                Dev::Ssd
            } else {
                Dev::Hdd
            }
        }
        fn pick_migration(&mut self, _: &View) -> Option<crate::policy::MigrationOp> {
            None
        }
    }

    let counts = Rc::new(RefCell::new(Counts::default()));
    let mut e = engine_with(Box::new(CountingPolicy(counts.clone())));
    for i in 0..20_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    let c = counts.borrow();
    assert!(c.flush > 0, "flush hints");
    assert!(c.start > 0, "compaction start hints");
    assert!(c.output > 0, "compaction output hints");
    assert_eq!(c.start, c.finish, "every compaction start gets a finish");
}

#[test]
fn zone_accounting_stays_consistent() {
    let mut e = hhzs_engine();
    for i in 0..20_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    // Every SST in the version has a zenfs file; every SSD-resident SST
    // occupies exactly one SSD zone.
    let mut ssd_ssts = 0u32;
    for m in e.version.all_ssts() {
        let f = e.fs.file(m.id).expect("version SST has a file");
        if f.dev == Dev::Ssd {
            assert_eq!(f.extents.len(), 1, "SSD SST must occupy one zone");
            ssd_ssts += 1;
        } else {
            assert!(f.extents.len() >= 1);
        }
    }
    assert!(ssd_ssts <= e.fs.ssd_file_zones_total());
}
