//! The coordinator: a deterministic discrete-event engine that drives the
//! LSM-tree KV store over the hybrid zoned-storage substrate under a
//! virtual clock.
//!
//! Everything the paper's testbed does in real time happens here in
//! virtual time: closed-loop client operations, WAL appends, MemTable
//! rotation and write stalls, background flush/compaction over a shared
//! thread pool (§4.1: 12 threads), rate-limited migration (§3.4), and the
//! SSD cache (§3.5). Device contention emerges from the QD1 FIFO timers in
//! [`crate::sim::device`]; latencies include queue wait, so migration and
//! compaction interference show up in the measured tails (Exp#6).

pub mod groupcommit;
pub mod walcache;

use std::cell::{Cell, RefCell};
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};
use std::rc::Rc;
use std::sync::Arc;

use crate::config::{Config, WakePolicy};
use crate::hints::{CacheEvictHint, CompactionHint, FlushHint, Hint};
use crate::lsm::block_cache::BlockKey;
use crate::lsm::compaction::{merge_entries, streaming_merge, OutputShape};
use crate::lsm::sst::{search_block, SstBuilder};
use crate::lsm::{
    BlockCache, Entry, KeyArena, MemTable, Payload, SstId, SstMeta, Version, WireBuf,
};
use crate::metrics::{LevelSizeSample, Metrics, WriteCategory};
use crate::policy::{MigrationKind, Policy, SstOrigin, View};
use crate::residency::{Residency, ResidencyHandle};
use crate::sim::cpu::{CpuPool, CpuPoolStats, FgPool};
use crate::sim::rng::fingerprint32;
use crate::sim::{AccessKind, CrashInjector, CrashPoint, Ns};
use crate::trace::{hint_kind, Event, IoOp, JobKind, TraceSink};
use crate::zenfs::ZenFs;
use crate::zone::{Dev, ZoneId};

use self::groupcommit::{Batch, GroupCommitter, Member};
use self::walcache::{PoolManager, StagedAppend};

/// CPU cost constants (virtual ns) for non-I/O work on the op path.
const CPU_MEMTABLE_NS: Ns = 1_000;
const CPU_BLOOM_NS: Ns = 200;
const CPU_BLOCK_SEARCH_NS: Ns = 1_000;
const CPU_CACHE_HIT_NS: Ns = 500;

/// A client operation (the YCSB op alphabet). Values are synthetic
/// [`Payload`]s — length + fingerprint — never materialized bytes.
#[derive(Clone, Debug)]
pub enum Op {
    Insert { key: Vec<u8>, value: Payload },
    Update { key: Vec<u8>, value: Payload },
    Read { key: Vec<u8> },
    Scan { key: Vec<u8>, len: usize },
    ReadModifyWrite { key: Vec<u8>, value: Payload },
}

/// Produces each client's operation stream.
pub trait OpSource {
    /// Next op for `client`, or `None` when that client's stream ends.
    fn next_op(&mut self, client: usize) -> Option<Op>;
}

#[derive(Clone, Debug, PartialEq, Eq)]
enum EventKind {
    Client(usize),
    JobStep(u64),
    MigrationStep,
    PolicyTick,
    Sample,
    /// Group-commit window deadline for batch `id` (see
    /// [`groupcommit::GroupCommitter`]): closes the batch if it is still
    /// open; stale for a batch already closed by fill (no-op).
    WalCommit(u64),
}

#[derive(Clone, Debug, PartialEq, Eq)]
struct Ev {
    at: Ns,
    seq: u64,
    kind: EventKind,
}

/// What [`Engine::frontend_client_op`] did with a routed client op.
pub(crate) enum FrontendOp {
    /// Writes are blocked; the op is handed back and the client is parked
    /// on this engine (an `EventKind::Client` fires when it unblocks).
    Parked(Op),
    /// Executed; the op completes at this virtual time.
    Done(Ns),
    /// Staged into the shared group committer: the WAL record is on media
    /// (untimed) and the MemTable apply ran, but the client is acked only
    /// when its batch's fused append completes (the frontend reschedules
    /// it from the batch-close hook).
    Staged,
}

/// What [`Engine::stage_put`] did with a write bound for group commit.
enum StagePut {
    /// Joined a batch; the ack arrives at the batch close.
    Staged,
    /// Could not batch (WAL overflow fallback, or a crash fired mid-put):
    /// the op completes at this virtual time like an unbatched one.
    Immediate(Ns),
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap via reversed compare; seq breaks ties deterministically.
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// An SST being written by a background job. `data` is wire-form: its
/// logical length drives placement and chunked write charging, while only
/// the compact physical bytes are resident.
struct PendingOutput {
    meta: Arc<SstMeta>,
    data: WireBuf,
    dev: Option<Dev>,
    written: u64,
}

struct FlushJob {
    segs: Vec<u64>,
    outputs: Vec<PendingOutput>,
    cur: usize,
}

enum CompactionPhase {
    Read,
    Write,
}

struct CompactionJob {
    level: usize,
    input_ids: Vec<SstId>,
    /// Per-device bytes left to read (charged in chunks).
    read_plan: Vec<(Dev, u64)>,
    outputs: Vec<PendingOutput>,
    installed: Vec<Arc<SstMeta>>,
    cur: usize,
    phase: CompactionPhase,
}

enum Job {
    Flush(FlushJob),
    Compaction(CompactionJob),
}

struct MigrationTask {
    sst: SstId,
    to: Dev,
    kind: MigrationKind,
    remaining: u64,
    from: Dev,
}

/// The engine. Construct with [`Engine::new`], drive with [`Engine::run`]
/// (workload mode) or the synchronous `put`/`get`/`scan` API (DB mode).
/// Workload mode is served by the async frontend ([`crate::shard`]): the
/// engine exposes a step-one-event API and executes ops the frontend
/// routes to it on a frontend-owned virtual clock.
pub struct Engine {
    pub cfg: Config,
    pub fs: ZenFs,
    pub version: Version,
    pub policy: Box<dyn Policy>,
    pub pool: PoolManager,
    pub cache: BlockCache,
    pub metrics: Metrics,
    /// Observation-only trace sink (disabled unless `cfg.trace.enabled`).
    /// The shard layer rebinds every engine to ONE shared ring, so the
    /// merged stream carries the global `(time, seq)` emission order.
    pub trace: TraceSink,
    pub now: Ns,
    seq: u64,
    next_file_id: u64,
    /// File-id increment. 1 for a standalone engine; shard `s` of `n`
    /// leases the strided namespace `{s + 1, s + 1 + n, ...}` so file ids
    /// stay globally unique across engines sharing the substrate.
    file_id_stride: u64,
    next_job_id: u64,
    /// Event sequence counter — the deterministic tie-break of the DES
    /// heap. A shared handle: every engine on a frontend's clock (and the
    /// frontend itself) draws from ONE counter, so events carry globally
    /// unique, push-ordered sequence numbers and the merged event order is
    /// exactly the seed single-heap order at `shards = 1`.
    event_seq: Rc<Cell<u64>>,
    mem: MemTable,
    immutables: VecDeque<(u64, MemTable)>,
    events: BinaryHeap<Ev>,
    jobs: HashMap<u64, Job>,
    flush_active: bool,
    /// The background-CPU slot pool. A standalone engine owns its own;
    /// [`crate::shard::ShardedEngine`] rebinds every shard's engine to ONE
    /// shared pool of `bg_threads` slots, so background CPU is arbitrated
    /// globally in `(time, seq)` event order exactly like the device
    /// FIFOs (the seed's `busy_threads` counter is this pool at 1 shard).
    cpu: Rc<RefCell<CpuPool>>,
    /// This engine's shard index in the pool's domain (0 standalone).
    cpu_shard: usize,
    /// The foreground-CPU slot pool (`fg_threads` slots). Empty =
    /// uncontended: every `CPU_*_NS` charge completes at `now + cost`,
    /// bit-identical to the seed's free-foreground arithmetic. The shard
    /// layer rebinds every engine to ONE pool per frontend domain.
    fg: Rc<RefCell<FgPool>>,
    /// Latest stall-risk score pushed to the shared pool (push-on-change
    /// only, so FIFO runs never touch the pool and traces stay quiet).
    last_risk: u64,
    /// The interned-key arena. Like the CPU pool: a standalone engine owns
    /// its own; [`crate::shard::ShardedEngine`] rebinds every shard to ONE
    /// shared arena per frontend domain, so a unique key costs its bytes
    /// once no matter how many layers (MemTable, SST bounds, cursors)
    /// reference it. Reclamation is epoch-based, retired on Version GC
    /// (see [`KeyArena::retire_epoch`]).
    arena: KeyArena,
    /// When this engine's pending flush first lost a slot race (drives the
    /// `Metrics::cpu_wait` sample recorded at flush start).
    flush_ready_since: Option<Ns>,
    /// When an eligible compaction first went CPU-starved.
    comp_ready_since: Option<Ns>,
    busy_ssts: HashSet<SstId>,
    busy_levels: HashSet<usize>,
    migration_queue: VecDeque<MigrationTask>,
    migration_active: bool,
    /// Frontend client ids parked on this engine (blocked writes).
    parked: Vec<usize>,
    sampling: bool,
    /// Reused WAL-record encode buffer (hot path: one put per record).
    wal_buf: WireBuf,
    /// Armed crash injector (`[crash]` config / `--crash-at`). `None` when
    /// disabled or when this engine is not the victim shard; stays present
    /// (with `fired = true`) after the crash so harnesses can introspect
    /// it. Armed-but-unfired it only reads the clock/op counter — the run
    /// stays bit-identical to an uninjected one.
    crash: Option<CrashInjector>,
    /// Optional XLA-backed bloom prober for the batched read path
    /// (`multi_get`); also attachable to the HHZS migration scorer.
    pub xla: Option<std::rc::Rc<crate::runtime::XlaKernels>>,
    /// The demand-paging residency manager both devices page through.
    /// Like the CPU pool and key arena: a standalone engine owns its own,
    /// [`crate::shard::ShardedEngine`] rebinds every shard to ONE manager
    /// per domain, so the paging knob and counters are domain-global.
    residency: ResidencyHandle,
    /// The cross-shard group-commit ledger ([`cfg.batch`]). Rebound to ONE
    /// shared committer per frontend domain by
    /// [`crate::shard::ShardedEngine`]; disabled (never consulted) with the
    /// knobs off, keeping the off path bit-identical.
    gc: GroupCommitter,
}

impl Engine {
    pub fn new(cfg: Config, policy: Box<dyn Policy>) -> Self {
        let mut fs = ZenFs::new(
            cfg.geometry.ssd_zone_cap,
            cfg.geometry.ssd_zones,
            cfg.geometry.hdd_zone_cap,
            cfg.geometry.hdd_zones,
            cfg.ssd.clone(),
            cfg.hdd.clone(),
        );
        let reserve = policy.reserved_pool_zones(&cfg);
        let mut pool = if reserve > 0 {
            PoolManager::reserved(fs.reserve_ssd_zones(reserve))
        } else {
            PoolManager::dynamic()
        };
        // Attach emission sites only when tracing is on: with the sink
        // disabled the data path keeps its no-trace fast paths.
        let trace = TraceSink::from_config(&cfg.trace);
        if trace.is_enabled() {
            fs.set_trace(&trace);
            pool.set_trace(trace.clone(), 0);
        }
        // One residency manager for the engine's device pair: zone-bound
        // writes dehydrate through it, reads hydrate. (The shard layer
        // rebinds all shards to shard 0's manager.)
        let residency = Residency::new(cfg.residency.paging);
        fs.set_residency(&residency);
        let version = Version::new(
            cfg.lsm.num_levels,
            cfg.lsm.l0_target,
            cfg.lsm.level_multiplier,
            cfg.lsm.l0_compaction_trigger,
        );
        let cache = BlockCache::new(cfg.lsm.block_cache_bytes);
        let cpu = Rc::new(RefCell::new(CpuPool::new(cfg.lsm.bg_threads, 1, cfg.lsm.cpu_sched)));
        cpu.borrow_mut().set_wake(cfg.lsm.wake);
        let fg = Rc::new(RefCell::new(FgPool::new(cfg.lsm.fg_threads)));
        let gc = GroupCommitter::new(&cfg.batch);
        let mut e = Engine {
            cfg,
            fs,
            version,
            policy,
            pool,
            cache,
            metrics: Metrics::default(),
            trace,
            now: 0,
            seq: 0,
            next_file_id: 1,
            file_id_stride: 1,
            next_job_id: 1,
            event_seq: Rc::new(Cell::new(0)),
            mem: MemTable::new(),
            immutables: VecDeque::new(),
            events: BinaryHeap::new(),
            jobs: HashMap::new(),
            flush_active: false,
            cpu,
            cpu_shard: 0,
            fg,
            last_risk: 0,
            arena: KeyArena::new(),
            flush_ready_since: None,
            comp_ready_since: None,
            busy_ssts: HashSet::new(),
            busy_levels: HashSet::new(),
            migration_queue: VecDeque::new(),
            migration_active: false,
            parked: Vec::new(),
            sampling: false,
            wal_buf: WireBuf::new(),
            crash: None,
            xla: None,
            residency,
            gc,
        };
        e.crash = CrashInjector::from_config(&e.cfg.crash);
        let tick = e.cfg.hhzs.scan_interval_ns;
        e.push_event(tick, EventKind::PolicyTick);
        e
    }

    /// Lease this engine a strided file-id namespace (`base`, `base +
    /// stride`, ...). Used by [`crate::shard`] so engines sharing the
    /// substrate never collide on file ids; must be called before the
    /// first SST is created. The default standalone namespace is
    /// `base = 1, stride = 1`.
    pub fn set_file_id_namespace(&mut self, base: u64, stride: u64) {
        assert!(base >= 1 && stride >= 1, "degenerate file-id namespace");
        assert_eq!(
            self.next_file_id, 1,
            "file-id namespace must be set before any SST exists"
        );
        self.next_file_id = base;
        self.file_id_stride = stride;
    }

    fn push_event(&mut self, at: Ns, kind: EventKind) {
        let seq = self.event_seq.get() + 1;
        self.event_seq.set(seq);
        self.events.push(Ev { at, seq, kind });
    }

    /// Handle to this engine's event-sequence counter (for the frontend).
    pub(crate) fn event_seq_handle(&self) -> Rc<Cell<u64>> {
        self.event_seq.clone()
    }

    /// Join a shared event-sequence counter (the frontend's clock domain).
    /// The shared counter must be at least as advanced as this engine's so
    /// already-queued events keep unique sequence numbers.
    pub(crate) fn share_event_seq(&mut self, seq: Rc<Cell<u64>>) {
        seq.set(seq.get().max(self.event_seq.get()));
        self.event_seq = seq;
    }

    /// Handle to this engine's CPU pool (for the shard layer / frontend).
    pub(crate) fn cpu_pool_handle(&self) -> Rc<RefCell<CpuPool>> {
        self.cpu.clone()
    }

    /// Join a shared CPU pool as shard `shard` of its domain. Must happen
    /// before any background job exists — slots held by the private pool
    /// would leak.
    pub(crate) fn share_cpu_pool(&mut self, pool: Rc<RefCell<CpuPool>>, shard: usize) {
        assert!(self.jobs.is_empty(), "CPU pool must be shared before any job runs");
        self.cpu = pool;
        self.cpu_shard = shard;
    }

    /// Snapshot of the (possibly shared) CPU pool's bookkeeping.
    pub fn cpu_pool_stats(&self) -> CpuPoolStats {
        self.cpu.borrow().stats()
    }

    /// Handle to this engine's foreground-CPU pool (for the shard layer).
    pub(crate) fn fg_pool_handle(&self) -> Rc<RefCell<FgPool>> {
        self.fg.clone()
    }

    /// Join a shared foreground-CPU pool (the frontend's domain). Must
    /// happen before any op is charged — grants made against the private
    /// pool would not occupy the shared slots.
    pub(crate) fn share_fg_pool(&mut self, fg: Rc<RefCell<FgPool>>) {
        assert!(
            self.seq == 0 && self.metrics.ops_done == 0,
            "fg pool must be shared before any op is charged"
        );
        self.fg = fg;
    }

    /// Do two engines charge foreground CPU against the same pool?
    pub fn shares_fg_pool_with(&self, other: &Engine) -> bool {
        Rc::ptr_eq(&self.fg, &other.fg)
    }

    /// Handle to this engine's group committer (for the shard layer /
    /// frontend).
    pub(crate) fn group_committer_handle(&self) -> GroupCommitter {
        self.gc.clone()
    }

    /// Join a shared group-commit ledger (the frontend's domain). Must
    /// happen before any op runs — members staged into the private ledger
    /// would never be closed by the shared frontend hook.
    pub(crate) fn share_group_committer(&mut self, gc: GroupCommitter) {
        assert!(
            self.seq == 0 && self.metrics.ops_done == 0,
            "group committer must be shared before any op is staged"
        );
        self.gc = gc;
    }

    /// Do two engines stage WAL records into the same committer?
    pub fn shares_group_committer_with(&self, other: &Engine) -> bool {
        self.gc.shares_with(&other.gc)
    }

    /// Total WAL records this engine's (possibly shared) committer ever
    /// staged — test visibility that group commit actually engaged; 0
    /// with the knobs off.
    pub fn group_commit_staged_total(&self) -> u64 {
        self.gc.staged_total()
    }

    /// Charge `cost` ns of foreground CPU issued at `now`. Uncontended
    /// (`fg_threads = 0`) this is the identity `now + cost` — the seed's
    /// free-foreground arithmetic, bit-for-bit, with no metrics sample and
    /// no trace record. Contended, the op queues for the earliest slot;
    /// the wait lands in `Metrics::fg_cpu_wait` and one FG trace record.
    fn fg_charge(&mut self, now: Ns, cost: Ns) -> Ns {
        if !self.fg.borrow().is_enabled() {
            return now + cost;
        }
        let (start, wait) = self.fg.borrow_mut().charge(now, cost);
        self.metrics.fg_cpu_wait.record(wait);
        let shard = self.cpu_shard;
        self.trace.emit(|| Event::FgCharge { shard, start, cost, wait, at: now });
        start + cost
    }

    /// Handle to this engine's trace sink (for the shard layer).
    pub(crate) fn trace_handle(&self) -> TraceSink {
        self.trace.clone()
    }

    /// Join a shared trace ring as shard `shard` of its domain, rebinding
    /// every emission site (devices, WAL/cache pool) to it. The shard
    /// layer's device/pool rebinding happens first, so re-attaching here
    /// tags the *shared* timers exactly once per physical device.
    pub(crate) fn share_trace(&mut self, trace: TraceSink, shard: usize) {
        if trace.is_enabled() {
            self.fs.set_trace(&trace);
            self.pool.set_trace(trace.clone(), shard);
        }
        self.trace = trace;
    }

    /// Emit the wait/acquire/start triple for an admitted background job.
    fn trace_job_start(&self, kind: JobKind, job: u64, wait: Ns) {
        if !self.trace.is_enabled() {
            return;
        }
        let (shard, at) = (self.cpu_shard, self.now);
        let in_use = self.cpu.borrow().in_use();
        self.trace.emit(|| Event::CpuWait { shard, kind, job, wait, at });
        self.trace.emit(|| Event::CpuAcquire { shard, kind, job, at, in_use });
        let queued = at.saturating_sub(wait);
        self.trace.emit(|| Event::JobStart { shard, kind, job, queued, at });
    }

    /// Emit the release/end pair for a finished (or abandoned) job.
    fn trace_job_end(&self, kind: JobKind, job: u64) {
        if !self.trace.is_enabled() {
            return;
        }
        let (shard, at) = (self.cpu_shard, self.now);
        let in_use = self.cpu.borrow().in_use();
        self.trace.emit(|| Event::CpuRelease { shard, kind, job, at, in_use });
        self.trace.emit(|| Event::JobEnd { shard, kind, job, at });
    }

    /// Mirror one `Metrics::record_queue_wait` site into the trace: `start`
    /// is the device-granted start time, `at` the issue time, so the event
    /// carries the same wait the metrics accumulated.
    #[allow(clippy::too_many_arguments)]
    fn trace_io(
        &self,
        dev: Dev,
        op: IoOp,
        job: Option<u64>,
        sst: Option<u64>,
        bytes: u64,
        start: Ns,
        at: Ns,
    ) {
        let (shard, wait) = (self.cpu_shard, start.saturating_sub(at));
        self.trace.emit(|| Event::Io { dev, op, shard, job, sst, bytes, wait, at });
    }

    /// Emit `UNWAIT` only when this shard actually held a flush claim, so
    /// the stream stays transition-edged (no per-poll noise).
    fn trace_flush_unwait(&self) {
        if self.cpu.borrow().is_flush_waiter(self.cpu_shard) {
            let (shard, at) = (self.cpu_shard, self.now);
            self.trace.emit(|| Event::FlushUnwait { shard, at });
        }
    }

    /// Emit a snapshot of the current (unreset) metrics — the record that
    /// closes this shard's open checker segment. Exporters call this once
    /// per engine right before serializing the ring.
    pub fn trace_snapshot(&self) {
        if self.trace.is_enabled() {
            let ev = Event::snapshot(self.cpu_shard, self.now, &self.metrics);
            self.trace.emit(|| ev);
        }
    }

    /// Serialize this engine's trace ring (standalone; the shard layer
    /// exports through [`crate::shard::ShardedEngine::export_trace_string`]
    /// instead). Emits the closing snapshot first.
    pub fn trace_export_string(&self) -> String {
        self.trace_snapshot();
        self.trace.export_string(1, self.cfg.lsm.bg_threads, self.cfg.lsm.fg_threads)
    }

    /// This engine's interned-key arena (shared across the frontend
    /// domain once [`crate::shard::ShardedEngine`] rebinds it).
    pub fn key_arena(&self) -> &KeyArena {
        &self.arena
    }

    /// Handle to this engine's key arena (for the shard layer).
    pub(crate) fn key_arena_handle(&self) -> KeyArena {
        self.arena.clone()
    }

    /// Join a shared key arena (the frontend's clock domain). Must happen
    /// before any key is interned — refs held in the private arena would
    /// escape dedup and the gauge.
    pub(crate) fn share_key_arena(&mut self, arena: KeyArena) {
        assert!(
            self.seq == 0 && self.version.total_ssts() == 0,
            "key arena must be shared before any key is interned"
        );
        self.arena = arena;
    }

    /// Do two engines intern keys into the same arena?
    pub fn shares_key_arena_with(&self, other: &Engine) -> bool {
        self.arena.shares_with(&other.arena)
    }

    /// Handle to this engine's residency manager (shared across the
    /// frontend domain once [`crate::shard::ShardedEngine`] rebinds it).
    pub fn residency_handle(&self) -> ResidencyHandle {
        self.residency.clone()
    }

    /// Join a shared residency manager (the frontend's domain): rebinds
    /// both devices' paging choke points, so the knob and the paging
    /// counters are domain-global like the timers/CPU pool/key arena.
    /// Safe at any time — data dehydrated under the old manager still
    /// hydrates on read (`page_in` is unconditional).
    pub(crate) fn share_residency(&mut self, residency: ResidencyHandle) {
        self.fs.set_residency(&residency);
        self.residency = residency;
    }

    /// Do two engines page through the same residency manager?
    pub fn shares_residency_with(&self, other: &Engine) -> bool {
        Rc::ptr_eq(&self.residency, &other.residency)
    }

    /// Do two engines draw background-CPU slots from the same pool?
    pub fn shares_cpu_pool_with(&self, other: &Engine) -> bool {
        Rc::ptr_eq(&self.cpu, &other.cpu)
    }

    /// Arm (or replace) this engine's crash injector.
    pub fn arm_crash(&mut self, inj: CrashInjector) {
        self.crash = Some(inj);
    }

    /// Disarm the injector — the shard layer calls this on every engine
    /// except `cfg.crash.shard`, so exactly one victim exists per run.
    pub fn disarm_crash(&mut self) {
        self.crash = None;
    }

    /// The armed (or fired) injector, if any.
    pub fn crash_injector(&self) -> Option<&CrashInjector> {
        self.crash.as_ref()
    }

    /// Has this engine's injector fired (crash + recovery happened)?
    pub fn crash_fired(&self) -> bool {
        self.crash.as_ref().map_or(false, |i| i.fired)
    }

    /// Re-run the background scheduler because another shard released a
    /// CPU slot this engine was starved for. `at` is the (frontend) event
    /// time of the release; in sync mode callers pass 0 and the local
    /// clock stands.
    pub(crate) fn poll_cpu(&mut self, at: Ns) {
        self.now = self.now.max(at);
        self.maybe_schedule_jobs();
    }

    // ------------------------------------------------------------------
    // Policy plumbing
    // ------------------------------------------------------------------

    /// Run `f` with a read-only [`View`] and mutable access to the policy.
    fn with_view<R>(&mut self, f: impl FnOnce(&mut dyn Policy, &View) -> R) -> R {
        let busy = &self.busy_ssts;
        let busy_fn = move |id: SstId| busy.contains(&id);
        let view = View {
            now: self.now,
            cfg: &self.cfg,
            fs: &self.fs,
            version: &self.version,
            wal_zones_in_use: self.pool.wal_zones_in_use(),
            busy_ssts: &busy_fn,
        };
        f(self.policy.as_mut(), &view)
    }

    fn emit_hint(&mut self, hint: Hint) {
        let (shard, kind, at) = (self.cpu_shard, hint_kind(&hint), self.now);
        self.trace.emit(|| Event::HintIssued { shard, kind, at });
        self.with_view(|p, v| p.on_hint(&hint, v));
    }

    /// Placement with the engine-side fallback: if the chosen device cannot
    /// host the SST right now, it goes to the other one (§2.3/§3.3: "if
    /// there is no empty SSD zone ... selects empty HDD zones").
    fn place_with_fallback(&mut self, level: usize, size: u64, origin: SstOrigin) -> Dev {
        let want = self.with_view(|p, v| p.place_sst(level, size, origin, v));
        if self.fs.can_place(want, size) {
            return want;
        }
        let alt = match want {
            Dev::Ssd => Dev::Hdd,
            Dev::Hdd => Dev::Ssd,
        };
        if self.fs.can_place(alt, size) {
            alt
        } else {
            // Both full: HDD zones are sized generously, so this indicates
            // a misconfigured run; prefer the HDD and let zenfs error out.
            Dev::Hdd
        }
    }

    // ------------------------------------------------------------------
    // Write path
    // ------------------------------------------------------------------

    pub(crate) fn write_blocked(&self) -> bool {
        let seal_needed = self.mem.approx_bytes() as u64 >= self.cfg.lsm.memtable_size;
        let mem_full = self.immutables.len() + 1 >= self.cfg.lsm.max_memtables;
        let l0_stop = self.version.level(0).len() >= self.cfg.lsm.l0_stop_files;
        (seal_needed && mem_full) || l0_stop
    }

    /// Append WAL + MemTable insert. The key is interned here — the WAL
    /// record carries the bytes, every in-memory layer shares one
    /// allocation per unique key. Returns completion time.
    fn do_put(&mut self, key: &[u8], value: Option<Payload>) -> Ns {
        self.seq += 1;
        let seq = self.seq;
        self.wal_buf.clear();
        self.wal_buf.push_entry(key, seq, value);
        let preferred = if self.pool.is_reserved_mode() {
            Dev::Ssd
        } else {
            self.with_view(|p, v| p.place_wal(v))
        };
        let Engine { fs, metrics, pool, now, wal_buf, .. } = self;
        let wal_finish = pool.append_wal(fs, metrics, *now, wal_buf, preferred);
        let record_len = self.wal_buf.len();
        // Crash hooks in the WAL→MemTable window: the record this put just
        // appended is on media but unapplied and unacked — the injector
        // tears it mid-byte and the client never hears back.
        if let Some(p) = self.wal_crash_point() {
            self.crash_fire(p);
            return self.now + CPU_MEMTABLE_NS;
        }
        let key = self.arena.intern(key);
        self.mem.insert(key, seq, value);
        self.mem.wal_bytes += record_len;
        if self.mem.approx_bytes() as u64 >= self.cfg.lsm.memtable_size {
            self.seal_memtable();
        }
        self.metrics.writes_done += 1;
        let cpu_done = self.fg_charge(self.now, CPU_MEMTABLE_NS);
        wal_finish.max(cpu_done)
    }

    /// The group-commit variant of [`Engine::do_put`]: the WAL record
    /// lands on media untimed and joins the shared committer's open batch
    /// for its device; the MemTable apply, seal check, and foreground CPU
    /// all happen now, but the device time is charged once per batch when
    /// the window closes — which is when the client is acked. Two ways
    /// out of batching: the overflow fallback (pool full, timed append
    /// already charged) and a crash firing in the WAL→MemTable window
    /// (the torn record never registered as a member, so earlier staged
    /// members stay durable on media and ack after recovery).
    fn stage_put(
        &mut self,
        c: usize,
        key: &[u8],
        value: Option<Payload>,
        issued_at: Ns,
    ) -> StagePut {
        self.seq += 1;
        let seq = self.seq;
        self.wal_buf.clear();
        self.wal_buf.push_entry(key, seq, value);
        let preferred = if self.pool.is_reserved_mode() {
            Dev::Ssd
        } else {
            self.with_view(|p, v| p.place_wal(v))
        };
        let staged = {
            let Engine { fs, metrics, pool, now, wal_buf, .. } = self;
            pool.append_wal_staged(fs, metrics, *now, wal_buf, preferred)
        };
        let record_len = self.wal_buf.len();
        if let Some(p) = self.wal_crash_point() {
            self.crash_fire(p);
            return StagePut::Immediate(self.now + CPU_MEMTABLE_NS);
        }
        let key = self.arena.intern(key);
        self.mem.insert(key, seq, value);
        self.mem.wal_bytes += record_len;
        if self.mem.approx_bytes() as u64 >= self.cfg.lsm.memtable_size {
            self.seal_memtable();
        }
        self.metrics.writes_done += 1;
        let cpu_done = self.fg_charge(self.now, CPU_MEMTABLE_NS);
        match staged {
            StagedAppend::Overflow { finish } => StagePut::Immediate(finish.max(cpu_done)),
            StagedAppend::Staged { dev, len } => {
                let m = Member {
                    shard: self.cpu_shard,
                    client: c,
                    bytes: len,
                    issued_at,
                    staged_at: self.now,
                    cpu_ready: cpu_done,
                };
                let outcome = self.gc.stage(dev, m);
                if outcome.opened {
                    let (id, at) = (outcome.batch_id, self.now);
                    self.trace.emit(|| Event::BatchOpen { id, dev, at });
                    self.push_event(outcome.deadline, EventKind::WalCommit(outcome.batch_id));
                }
                StagePut::Staged
            }
        }
    }

    fn seal_memtable(&mut self) {
        debug_assert!(self.immutables.len() + 1 < self.cfg.lsm.max_memtables);
        let seg = self.pool.seal_segment();
        let full = std::mem::take(&mut self.mem);
        self.immutables.push_back((seg, full));
        self.maybe_schedule_jobs();
    }

    // ------------------------------------------------------------------
    // Read path
    // ------------------------------------------------------------------

    /// Point lookup. Returns (value, completion time).
    fn do_get(&mut self, key: &[u8]) -> (Option<Payload>, Ns) {
        self.metrics.reads_done += 1;
        // 1. MemTables (active, then immutables newest-first).
        if let Some(v) = self.mem.get(key) {
            self.metrics.memtable_hits += 1;
            let f = self.fg_charge(self.now, CPU_MEMTABLE_NS);
            return (v, f);
        }
        let im_hit = self.immutables.iter().rev().find_map(|(_, im)| im.get(key));
        if let Some(v) = im_hit {
            self.metrics.memtable_hits += 1;
            let f = self.fg_charge(self.now, CPU_MEMTABLE_NS);
            return (v, f);
        }
        // 2. SSTs, L0 newest-first then one candidate per level.
        let fp = fingerprint32(key);
        let candidates = self.version.candidates_for(key);
        let mut finish = self.now;
        for meta in candidates {
            finish = self.fg_charge(finish, CPU_BLOOM_NS);
            if !meta.bloom.may_contain(fp) {
                continue;
            }
            let Some(bi) = meta.find_block(key) else { continue };
            let handle = meta.blocks[bi];
            let (block, f) = self.fetch_block(&meta, handle.offset, handle.len as u64, finish);
            finish = self.fg_charge(finish.max(f), CPU_BLOCK_SEARCH_NS);
            if let Some(e) = search_block(&block, key) {
                return (e.value, finish);
            }
            // Bloom false positive or key absent from the block: continue
            // to deeper levels.
        }
        (None, finish)
    }

    /// Fetch one data block through: block cache → SSD cache → device.
    /// Returns the block (wire form) and the completion time.
    fn fetch_block(
        &mut self,
        meta: &Arc<SstMeta>,
        offset: u64,
        len: u64,
        now: Ns,
    ) -> (Arc<WireBuf>, Ns) {
        let bk = BlockKey { sst: meta.id, offset };
        if let Some(b) = self.cache.get(&bk) {
            self.metrics.block_cache_hits += 1;
            let f = self.fg_charge(now, CPU_CACHE_HIT_NS);
            return (b, f);
        }
        self.metrics.block_cache_misses += 1;
        let dev = self.fs.file_dev(meta.id).expect("SST file exists");
        // Storage-level read of this SST: update per-SST stats (fig 2(g),
        // §3.4 read rates).
        let use_ssd_cache = self.policy.ssd_cache_enabled() && dev == Dev::Hdd;
        let (data, finish, served_by) = if use_ssd_cache {
            if let Some((data, f)) = {
                let Engine { pool, fs, metrics, .. } = &mut *self;
                pool.cache_lookup(fs, metrics, now, meta.id, offset)
            } {
                self.metrics.ssd_cache_hits += 1;
                (data, f, Dev::Ssd)
            } else {
                self.metrics.ssd_cache_misses += 1;
                let (data, s, f) =
                    self.fs.read_file(now, meta.id, offset, len).expect("block read");
                self.metrics.record_queue_wait(dev, s.saturating_sub(now));
                self.trace_io(dev, IoOp::BlockRead, None, Some(meta.id), len, s, now);
                (data, f, dev)
            }
        } else {
            let (data, s, f) = self.fs.read_file(now, meta.id, offset, len).expect("block read");
            self.metrics.record_queue_wait(dev, s.saturating_sub(now));
            self.trace_io(dev, IoOp::BlockRead, None, Some(meta.id), len, s, now);
            (data, f, dev)
        };
        self.metrics.record_read(served_by, len);
        self.metrics.record_sst_read(meta.id, meta.level, served_by);
        self.policy.on_sst_read(meta.id, served_by, now);
        let arc = Arc::new(data);
        debug_assert!(arc.is_hydrated(), "cache admits hydrated copies only");
        let evicted = self.cache.insert(bk, arc.clone());
        for ev in evicted {
            self.handle_cache_eviction(ev.key.sst, ev.key.offset, ev.data);
        }
        (arc, finish)
    }

    /// Forward a block-cache eviction as a cache hint (§3.1) and run the
    /// §3.5 admission flow.
    fn handle_cache_eviction(&mut self, sst: SstId, offset: u64, data: Arc<WireBuf>) {
        let hint = Hint::CacheEvict(CacheEvictHint {
            sst,
            block_offset: offset,
            block_len: data.len(),
            data: data.clone(),
        });
        self.emit_hint(hint);
        if !self.policy.ssd_cache_enabled() {
            return;
        }
        // Admit only blocks whose SST still exists on the HDD (§3.5).
        if self.fs.file_dev(sst) != Some(Dev::Hdd) {
            return;
        }
        let Engine { pool, fs, metrics, now, .. } = self;
        pool.cache_admit(fs, metrics, *now, sst, offset, &data);
    }

    /// Range scan: merged iteration over MemTables and all levels,
    /// bypassing the block cache (RocksDB iterators default to
    /// `fill_cache = false`). Returns (#entries, completion time).
    fn do_scan(&mut self, start: &[u8], n: usize) -> (usize, Ns) {
        self.metrics.scans_done += 1;
        let (merged, finish) = self.scan_entries(start, n);
        (merged.len(), finish)
    }

    /// The scan body: collect up to `n` distinct live entries ≥ `start`,
    /// merged (newest version wins, tombstones dropped) across MemTables
    /// and every level. Shared by [`Engine::scan`]/workload scans and the
    /// cross-shard scatter-gather frontend, which merges the per-shard
    /// results itself.
    ///
    /// Known bounded-read limitation: each source's `n`-live budget counts
    /// entries that a tombstone in a *newer* source may later shadow, so a
    /// scan over heavily-deleted ranges can still return fewer than
    /// `min(n, live keys)` — resolving that exactly needs a global
    /// streaming merge over cursors, not per-source budgets (RocksDB's
    /// iterator model). With no cross-source tombstone shadowing the count
    /// is exact, which is what the regression tests pin.
    pub(crate) fn scan_entries(&mut self, start: &[u8], n: usize) -> (Vec<Entry>, Ns) {
        let mut sources: Vec<Vec<Entry>> = Vec::new();
        let mem_src: Vec<Entry> = self
            .mem
            .range(start, n)
            .into_iter()
            .map(|(k, s, v)| Entry { key: k.clone(), seq: s, value: v })
            .collect();
        sources.push(mem_src);
        for (_, im) in &self.immutables {
            sources.push(
                im.range(start, n)
                    .into_iter()
                    .map(|(k, s, v)| Entry { key: k.clone(), seq: s, value: v })
                    .collect(),
            );
        }
        let mut finish = self.now;
        // L0 files all overlap: each one is its own sorted source. (L0 is
        // bounded by `l0_stop_files`, so cloning the metas is cheap.)
        let l0: Vec<Arc<SstMeta>> = self
            .version
            .level(0)
            .iter()
            .filter(|m| m.largest.as_slice() >= start)
            .cloned()
            .collect();
        for meta in l0 {
            let mut src = Vec::new();
            let mut live = 0usize;
            self.scan_sst_file(&meta, start, n, &mut live, &mut src, &mut finish);
            sources.push(src);
        }
        // Deeper levels are key-disjoint: the files from the partition
        // point onward form ONE sorted run, read file by file until `n`
        // live keys are in hand or the run is exhausted. (The seed capped
        // each level at 3 files and broke on raw — tombstone-inflated —
        // entry counts, silently dropping qualifying entries from long
        // scans.) Short scans stop after the first file, so no O(level)
        // work happens for them.
        for lvl in 1..self.version.num_levels() {
            let mut fi = self.version.level(lvl).partition_point(|m| m.largest.as_slice() < start);
            let mut src = Vec::new();
            let mut live = 0usize;
            while live < n {
                let Some(meta) = self.version.level(lvl).get(fi).cloned() else { break };
                self.scan_sst_file(&meta, start, n, &mut live, &mut src, &mut finish);
                fi += 1;
            }
            sources.push(src);
        }
        let mut merged = merge_entries(sources, true);
        merged.truncate(n);
        // The final merge CPU overlaps the in-flight reads: completion is
        // whichever ends later, the last read or the charged CPU span.
        let cpu_done = self.fg_charge(self.now, CPU_BLOCK_SEARCH_NS);
        (merged, finish.max(cpu_done))
    }

    /// Read one SST's qualifying blocks into `collected`, counting *live*
    /// (non-tombstone) entries ≥ `start` toward the caller's budget and
    /// stopping early once `n` live keys are in hand. Within one sorted
    /// run keys are distinct, so counting live entries counts distinct
    /// live keys.
    fn scan_sst_file(
        &mut self,
        meta: &Arc<SstMeta>,
        start: &[u8],
        n: usize,
        live: &mut usize,
        collected: &mut Vec<Entry>,
        finish: &mut Ns,
    ) {
        let dev = self.fs.file_dev(meta.id).expect("scan SST exists");
        let from_block = meta.find_block(start).unwrap_or(0);
        // With `read_coalesce` on, this file's scatter-gather leg fuses
        // into ONE charged device access: the blocks (adjacent in the
        // file) are consumed untimed and the fused span is charged after
        // the loop, promoted to a sequential read when more than one
        // block joined (a lone block keeps its random-read cost).
        let coalesce = self.cfg.batch.read_coalesce;
        let mut fused_bytes = 0u64;
        let mut fused_members = 0u32;
        for (i, h) in meta.blocks.iter().enumerate().skip(from_block) {
            let data = self
                .fs
                .read_file_untimed(meta.id, h.offset, h.len as u64)
                .expect("scan block");
            if coalesce {
                fused_bytes += h.len as u64;
                fused_members += 1;
            } else {
                // First block of a file random (seek), subsequent
                // sequential.
                let kind =
                    if i == from_block { AccessKind::RandRead } else { AccessKind::SeqRead };
                let (s, f) = self.fs.charge(self.now, dev, kind, h.len as u64);
                self.metrics.record_queue_wait(dev, s.saturating_sub(self.now));
                self.trace_io(dev, IoOp::ScanRead, None, Some(meta.id), h.len as u64, s, self.now);
                self.metrics.record_read(dev, h.len as u64);
                *finish = (*finish).max(f);
            }
            // Zero-copy block walk (prefix-shared keys compare in place);
            // only qualifying entries are cloned into the merge sources.
            for e in data.entries() {
                if e.key.cmp_bytes(start) != std::cmp::Ordering::Less {
                    if e.value.is_some() {
                        *live += 1;
                    }
                    collected.push(e.to_entry());
                }
            }
            if *live >= n {
                break;
            }
        }
        if coalesce && fused_members > 0 {
            let kind =
                if fused_members > 1 { AccessKind::SeqRead } else { AccessKind::RandRead };
            let (s, f) = self.fs.charge_fused(self.now, dev, kind, fused_bytes, fused_members);
            self.metrics.record_queue_wait(dev, s.saturating_sub(self.now));
            self.trace_io(dev, IoOp::ScanRead, None, Some(meta.id), fused_bytes, s, self.now);
            self.metrics.record_read(dev, fused_bytes);
            *finish = (*finish).max(f);
            if fused_members > 1 {
                self.metrics.fused_reads += 1;
                self.metrics.fused_read_bytes += fused_bytes;
                let (shard, members, bytes, at) =
                    (self.cpu_shard, fused_members, fused_bytes, self.now);
                self.trace.emit(|| Event::ReadFuse {
                    dev,
                    shard,
                    members,
                    bytes,
                    member_bytes: bytes,
                    gap_bytes: 0,
                    at,
                });
            }
        }
        self.metrics.record_sst_read(meta.id, meta.level, dev);
        self.policy.on_sst_read(meta.id, dev, self.now);
    }

    // ------------------------------------------------------------------
    // Background jobs
    // ------------------------------------------------------------------

    fn flush_wanted(&self) -> bool {
        !self.flush_active && self.immutables.len() + 1 >= self.cfg.lsm.min_flush_memtables
    }

    /// Schedule background work against the shared CPU pool. The pool
    /// enforces every slot rule globally: the total `bg_threads` bound,
    /// the flush reservation (`min(2, bg_threads - 1)` — the anti-livelock
    /// shape that keeps ≥ 1 compaction-eligible slot in every non-empty
    /// pool), flush priority over freed slots, and the per-shard fair cap
    /// when `cpu_sched = fair`. Flush is attempted first (RocksDB's flush
    /// priority; at `bg_threads = 1` the lone thread serves both roles).
    ///
    /// A denied-but-ready job registers as a pool waiter: the event loop
    /// re-polls this engine when another shard releases a slot, and the
    /// time from first denial to job start is recorded in
    /// [`Metrics::cpu_wait`].
    fn maybe_schedule_jobs(&mut self) {
        self.push_stall_risk();
        if self.flush_wanted() {
            self.start_flush();
        } else {
            self.trace_flush_unwait();
            self.cpu.borrow_mut().clear_flush_waiter(self.cpu_shard);
            self.flush_ready_since = None;
        }
        loop {
            if !self.cpu.borrow().can_admit_compaction(self.cpu_shard) {
                break;
            }
            if !self.start_compaction() {
                break;
            }
        }
        // Compaction-starvation bookkeeping: an eligible pick without an
        // admissible slot claims a wake-up (and starts the cpu_wait
        // clock). The probe is read-only — the round-robin cursor moves
        // only on real picks — and runs once per starvation episode: an
        // existing claim is kept without re-probing (O(1) on the hot
        // path); a stale claim costs one harmless no-op re-poll and is
        // cleared the first time admission succeeds again.
        let starved = if self.cpu.borrow().can_admit_compaction(self.cpu_shard) {
            false
        } else {
            self.cpu.borrow().is_comp_waiter(self.cpu_shard) || self.compaction_ready()
        };
        self.cpu.borrow_mut().set_comp_waiter(self.cpu_shard, starved);
        if starved {
            self.comp_ready_since.get_or_insert(self.now);
        } else {
            self.comp_ready_since = None;
        }
    }

    /// Recompute this shard's stall-risk score from live signals and push
    /// it to the shared pool: L0 depth vs the write-stop trigger, memtable
    /// fill fraction, parked-writer count, and SSD zone-reset debt — each
    /// component capped at 256 (the pool clamps the sum at `RISK_MAX`).
    /// Pushed on change only, with one RISK trace record per change, so a
    /// `wake = fifo` run never touches the pool and stays byte-identical.
    fn push_stall_risk(&mut self) {
        if self.cfg.lsm.wake != WakePolicy::StallAware {
            return;
        }
        let l0 = self.version.level(0).len() as u64;
        let l0_stop = self.cfg.lsm.l0_stop_files.max(1) as u64;
        let mem = self.mem.approx_bytes() as u64;
        let mem_cap = self.cfg.lsm.memtable_size.max(1);
        let parked = self.parked.len() as u64;
        let zones = self.fs.ssd.num_zones() as u64;
        let used =
            (0..self.fs.ssd.num_zones()).filter(|&z| !self.fs.ssd.zone(z).is_empty()).count()
                as u64;
        let score = (l0 * 256 / l0_stop).min(256)
            + (mem * 256 / mem_cap).min(256)
            + (parked * 64).min(256)
            + if zones > 0 { 256 * used / zones } else { 0 };
        if score != self.last_risk {
            self.last_risk = score;
            self.cpu.borrow_mut().set_stall_risk(self.cpu_shard, score);
            let (shard, at) = (self.cpu_shard, self.now);
            self.trace.emit(|| Event::StallRisk { shard, score, at });
        }
    }

    /// Read-only: does an admissible compaction pick exist right now?
    fn compaction_ready(&self) -> bool {
        let busy_ssts = &self.busy_ssts;
        let busy_levels = &self.busy_levels;
        self.version
            .compaction_ready(&|id| busy_ssts.contains(&id), &|l| busy_levels.contains(&l))
    }

    fn start_flush(&mut self) {
        // CPU first: a ready flush denied a slot registers its claim (so
        // no compaction can steal the next freed slot pool-wide) and
        // starts the cpu_wait clock.
        if !self.cpu.borrow().can_admit_flush() {
            self.cpu.borrow_mut().flush_denied(self.cpu_shard);
            if self.flush_ready_since.is_none() {
                // First denial of this starvation episode only.
                let (shard, at) = (self.cpu_shard, self.now);
                self.trace.emit(|| Event::FlushDenied { shard, at });
            }
            self.flush_ready_since.get_or_insert(self.now);
            return;
        }
        // Merge ALL pending immutable MemTables into one stream (RocksDB
        // merges immutables on flush).
        let mut segs = Vec::new();
        let mut streams = Vec::new();
        while let Some((seg, im)) = self.immutables.pop_front() {
            segs.push(seg);
            streams.push(im.into_entries());
        }
        if streams.is_empty() {
            return;
        }
        let builders = streaming_merge(&[], streams, false, self.output_shape(), |_, _| {
            unreachable!("flush has no SST inputs")
        });
        let outputs = self.finish_builders(builders, 0);
        if outputs.is_empty() {
            for seg in segs {
                let Engine { pool, fs, .. } = &mut *self;
                pool.release_segment(fs, seg);
            }
            self.trace_flush_unwait();
            self.cpu.borrow_mut().clear_flush_waiter(self.cpu_shard);
            self.flush_ready_since = None;
            return;
        }
        let acquired = self.cpu.borrow_mut().acquire_flush(self.cpu_shard);
        debug_assert!(acquired, "admission re-check cannot fail within one call");
        if self.cpu.borrow_mut().take_promoted(self.cpu_shard) {
            // This grant jumped the FIFO order because this shard was the
            // highest stall risk — one avoided stall episode.
            self.metrics.stalls_avoided += 1;
        }
        let wait = self.flush_ready_since.take().map_or(0, |t| self.now.saturating_sub(t));
        self.metrics.cpu_wait.record(wait);
        let id = self.next_job_id;
        self.next_job_id += 1;
        self.trace_job_start(JobKind::Flush, id, wait);
        self.jobs.insert(id, Job::Flush(FlushJob { segs, outputs, cur: 0 }));
        self.flush_active = true;
        self.push_event(self.now, EventKind::JobStep(id));
        self.metrics.flushes += 1;
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape {
            sst_size: self.cfg.geometry.sst_size,
            block_size: self.cfg.lsm.block_size,
            bloom_bits_per_key: self.cfg.lsm.bloom_bits_per_key,
        }
    }

    /// Assign file ids to sealed builders and finish them into pending
    /// outputs (streaming path). The metas' `smallest`/`largest` bounds
    /// are canonicalized through the key arena so they share allocations
    /// with the MemTable/other metas instead of duplicating the bytes.
    fn finish_builders(&mut self, builders: Vec<SstBuilder>, level: usize) -> Vec<PendingOutput> {
        let mut outputs = Vec::with_capacity(builders.len());
        for b in builders {
            if b.is_empty() {
                continue;
            }
            let id = self.next_file_id;
            self.next_file_id += self.file_id_stride;
            let (mut meta, data) = b.finish(id, level, self.now);
            meta.smallest = self.arena.intern_ref(&meta.smallest);
            meta.largest = self.arena.intern_ref(&meta.largest);
            outputs.push(PendingOutput { meta: Arc::new(meta), data, dev: None, written: 0 });
        }
        outputs
    }

    fn start_compaction(&mut self) -> bool {
        let pick = {
            let busy_ssts = self.busy_ssts.clone();
            let busy_levels = self.busy_levels.clone();
            self.version.pick_compaction(
                &move |id| busy_ssts.contains(&id),
                &move |l| busy_levels.contains(&l),
            )
        };
        let Some(pick) = pick else { return false };
        let input_ids = pick.input_ids();
        if input_ids.is_empty() {
            return false;
        }
        let job = self.next_job_id;
        self.next_job_id += 1;
        // Phase (i) hint: compaction triggered.
        self.emit_hint(Hint::Compaction(CompactionHint::Start {
            job,
            inputs: input_ids.clone(),
            output_level: pick.output_level(),
        }));
        // Device time for input reads is charged chunk-by-chunk by JobStep
        // events; the merge below moves data untimed. BTreeMap: the chunk
        // charging order must be deterministic for replay.
        let mut read_plan: std::collections::BTreeMap<Dev, u64> = Default::default();
        let inputs: Vec<Arc<SstMeta>> = pick.all_inputs().cloned().collect();
        for m in &inputs {
            let dev = self.fs.file_dev(m.id).expect("input exists");
            *read_plan.entry(dev).or_insert(0) += m.file_size;
        }
        let last_level = pick.output_level() == self.version.num_levels() - 1;
        // Streaming pipeline: cursor-based k-way merge over per-SST block
        // readers feeding the builders incrementally — memory is O(one
        // block per input), not O(total input bytes). (The seed's
        // materialize-everything pipeline is retired from the engine; the
        // merge equivalence lives on in `lsm::compaction` and the
        // `tests/datapath.rs` property + golden digests.)
        let outputs = {
            let shape = self.output_shape();
            let builders = {
                let Engine { fs, .. } = self;
                streaming_merge(&inputs, Vec::new(), last_level, shape, |m, h| {
                    fs.read_file_untimed(m.id, h.offset, h.len as u64)
                        .expect("compaction block read")
                })
            };
            self.finish_builders(builders, pick.output_level())
        };
        self.metrics.compactions += 1;
        for id in &input_ids {
            self.busy_ssts.insert(*id);
        }
        self.busy_levels.insert(pick.level);
        self.busy_levels.insert(pick.output_level());
        let acquired = self.cpu.borrow_mut().acquire_compaction(self.cpu_shard);
        debug_assert!(acquired, "caller checked admission within this call");
        if self.cpu.borrow_mut().take_promoted(self.cpu_shard) {
            self.metrics.stalls_avoided += 1;
        }
        let wait = self.comp_ready_since.take().map_or(0, |t| self.now.saturating_sub(t));
        self.metrics.cpu_wait.record(wait);
        self.trace_job_start(JobKind::Compaction, job, wait);
        self.jobs.insert(
            job,
            Job::Compaction(CompactionJob {
                level: pick.level,
                input_ids,
                read_plan: read_plan.into_iter().collect(),
                outputs,
                installed: Vec::new(),
                cur: 0,
                phase: CompactionPhase::Read,
            }),
        );
        self.push_event(self.now, EventKind::JobStep(job));
        true
    }

    fn handle_job_step(&mut self, id: u64) {
        if let Some(p) = self.job_crash_point(id) {
            self.crash_fire(p);
            return;
        }
        let chunk = self.cfg.hhzs.chunk_bytes;
        let Some(job) = self.jobs.remove(&id) else { return };
        match job {
            Job::Flush(mut j) => {
                if j.cur >= j.outputs.len() {
                    self.finish_flush(id, j);
                    return;
                }
                let next_at = self.step_output(&mut j.outputs, &mut j.cur, 0, id, chunk, SstOrigin::Flush);
                self.jobs.insert(id, Job::Flush(j));
                self.push_event(next_at, EventKind::JobStep(id));
            }
            Job::Compaction(mut j) => match j.phase {
                CompactionPhase::Read => {
                    // Charge the next read chunk on some device. With
                    // `read_coalesce` on, up to 8 adjacent chunks of one
                    // input fuse into a single charged request (one
                    // per-request overhead for the span).
                    if let Some(slot) = j.read_plan.iter_mut().find(|(_, rem)| *rem > 0) {
                        let fuse = if self.cfg.batch.read_coalesce { 8 } else { 1 };
                        let n = (chunk * fuse).min(slot.1);
                        let members = (n.div_ceil(chunk.max(1)) as u32).max(1);
                        slot.1 -= n;
                        let dev = slot.0;
                        let (s, f) =
                            self.fs.charge_fused(self.now, dev, AccessKind::SeqRead, n, members);
                        self.metrics.record_queue_wait(dev, s.saturating_sub(self.now));
                        self.trace_io(dev, IoOp::CompactionRead, Some(id), None, n, s, self.now);
                        self.metrics.compaction_read_bytes += n;
                        if members > 1 {
                            self.metrics.fused_reads += 1;
                            self.metrics.fused_read_bytes += n;
                            let (shard, bytes, at) = (self.cpu_shard, n, self.now);
                            self.trace.emit(|| Event::ReadFuse {
                                dev,
                                shard,
                                members,
                                bytes,
                                member_bytes: bytes,
                                gap_bytes: 0,
                                at,
                            });
                        }
                        self.jobs.insert(id, Job::Compaction(j));
                        self.push_event(f, EventKind::JobStep(id));
                    } else {
                        j.phase = CompactionPhase::Write;
                        self.jobs.insert(id, Job::Compaction(j));
                        self.push_event(self.now, EventKind::JobStep(id));
                    }
                }
                CompactionPhase::Write => {
                    if j.cur >= j.outputs.len() {
                        self.finish_compaction(id, j);
                        return;
                    }
                    let level = j.outputs[j.cur].meta.level;
                    let before = j.cur;
                    let next_at = self.step_output(
                        &mut j.outputs,
                        &mut j.cur,
                        level,
                        id,
                        chunk,
                        SstOrigin::Compaction,
                    );
                    // Collect metas installed by step_output.
                    if j.cur != before {
                        let meta = j.outputs[before].meta.clone();
                        j.installed.push(meta);
                    }
                    self.jobs.insert(id, Job::Compaction(j));
                    self.push_event(next_at, EventKind::JobStep(id));
                }
            },
        }
    }

    /// Write the next chunk of the current pending output; on completion,
    /// install the file (zenfs) and advance the cursor. Returns the time of
    /// the next step.
    fn step_output(
        &mut self,
        outputs: &mut [PendingOutput],
        cur: &mut usize,
        level: usize,
        job: u64,
        chunk: u64,
        origin: SstOrigin,
    ) -> Ns {
        let out = &mut outputs[*cur];
        if out.dev.is_none() {
            let size = out.data.len();
            let dev = self.place_with_fallback(level, size, origin);
            out.dev = Some(dev);
            if origin == SstOrigin::Compaction {
                // Phase (ii) hint: an output SST is being generated.
                self.emit_hint(Hint::Compaction(CompactionHint::OutputSst {
                    job,
                    sst: out.meta.id,
                    level,
                    bytes: size,
                }));
            }
        }
        let dev = out.dev.unwrap();
        let remaining = out.data.len() - out.written;
        let n = chunk.min(remaining);
        let (s, f) = self.fs.charge(self.now, dev, AccessKind::SeqWrite, n);
        self.metrics.record_queue_wait(dev, s.saturating_sub(self.now));
        self.trace_io(dev, IoOp::SstWrite, Some(job), Some(out.meta.id), n, s, self.now);
        self.metrics.record_write(WriteCategory::Sst(level), dev, n);
        if origin == SstOrigin::Compaction {
            self.metrics.compaction_write_bytes += n;
        }
        out.written += n;
        if out.written >= out.data.len() {
            // Install the file. Fall back at install time if the planned
            // device filled up while we were writing.
            let mut dev = dev;
            if !self.fs.can_place(dev, out.data.len()) {
                let alt = if dev == Dev::Ssd { Dev::Hdd } else { Dev::Ssd };
                if self.fs.can_place(alt, out.data.len()) {
                    dev = alt;
                }
            }
            self.fs
                .create_file(self.now, out.meta.id, dev, &out.data, false)
                .expect("output placement");
            out.data = WireBuf::new();
            if origin == SstOrigin::Flush {
                self.version.add_l0(out.meta.clone());
                let hint =
                    Hint::Flush(FlushHint { sst: out.meta.id, bytes: out.meta.file_size });
                self.emit_hint(hint);
            }
            *cur += 1;
        }
        f
    }

    fn finish_flush(&mut self, job: u64, j: FlushJob) {
        for seg in j.segs {
            let Engine { pool, fs, .. } = &mut *self;
            pool.release_segment(fs, seg);
        }
        self.flush_active = false;
        self.cpu.borrow_mut().release_flush(self.cpu_shard);
        self.trace_job_end(JobKind::Flush, job);
        self.unpark_writers();
        self.maybe_schedule_jobs();
    }

    fn finish_compaction(&mut self, job: u64, j: CompactionJob) {
        // Install outputs atomically; delete inputs; reset zones.
        self.version.apply_compaction(j.level, &j.input_ids, j.installed.clone());
        for id in &j.input_ids {
            self.fs.delete_file(*id).expect("input file");
            self.cache.invalidate_sst(*id);
            self.pool.invalidate_sst(*id);
            self.policy.on_sst_deleted(*id);
            self.busy_ssts.remove(id);
        }
        self.busy_levels.remove(&j.level);
        self.busy_levels.remove(&(j.level + 1));
        // Phase (iii) hint: compaction complete.
        let outputs = j.installed.iter().map(|m| m.id).collect();
        self.emit_hint(Hint::Compaction(CompactionHint::Finish {
            job,
            outputs,
            output_level: j.level + 1,
        }));
        self.cpu.borrow_mut().release_compaction(self.cpu_shard);
        self.trace_job_end(JobKind::Compaction, job);
        // Version GC just deleted SSTs — the bulk-death point for key
        // references. Retire an arena epoch so dead interned keys are
        // reclaimed on the sweep cadence.
        self.arena.retire_epoch();
        self.unpark_writers();
        self.maybe_schedule_jobs();
    }

    // ------------------------------------------------------------------
    // Migration (§3.4)
    // ------------------------------------------------------------------

    fn start_migration_if_idle(&mut self) {
        if self.migration_active {
            return;
        }
        let op = self.with_view(|p, v| p.pick_migration(v));
        let Some(op) = op else { return };
        // Queue the swap victim first so its zone frees up.
        if let Some(victim) = op.swap_with {
            if let Some(f) = self.fs.file(victim) {
                let task = MigrationTask {
                    sst: victim,
                    to: Dev::Hdd,
                    kind: op.kind,
                    remaining: f.size,
                    from: f.dev,
                };
                self.busy_ssts.insert(victim);
                let (shard, sst, from, to, at) =
                    (self.cpu_shard, task.sst, task.from, task.to, self.now);
                self.trace.emit(|| Event::MigStart { shard, sst, from, to, at });
                self.migration_queue.push_back(task);
            }
        }
        if let Some(f) = self.fs.file(op.sst) {
            let task = MigrationTask {
                sst: op.sst,
                to: op.to,
                kind: op.kind,
                remaining: f.size,
                from: f.dev,
            };
            self.busy_ssts.insert(op.sst);
            let (shard, sst, from, to, at) =
                (self.cpu_shard, task.sst, task.from, task.to, self.now);
            self.trace.emit(|| Event::MigStart { shard, sst, from, to, at });
            self.migration_queue.push_back(task);
        }
        if !self.migration_queue.is_empty() {
            self.migration_active = true;
            self.push_event(self.now, EventKind::MigrationStep);
        }
    }

    fn handle_migration_step(&mut self) {
        if !self.migration_queue.is_empty()
            && self
                .crash
                .as_ref()
                .map_or(false, |i| i.should_fire(CrashPoint::MidMigration, self.now))
        {
            self.crash_fire(CrashPoint::MidMigration);
            return;
        }
        let Some(task) = self.migration_queue.front_mut() else {
            self.migration_active = false;
            return;
        };
        if task.remaining == 0 {
            // Complete this task.
            let task = self.migration_queue.pop_front().unwrap();
            let ok = self.fs.relocate_file(task.sst, task.to).is_ok();
            self.busy_ssts.remove(&task.sst);
            let (shard, sst, at) = (self.cpu_shard, task.sst, self.now);
            self.trace.emit(|| Event::MigEnd { shard, sst, at });
            if ok {
                match task.kind {
                    MigrationKind::Capacity => self.metrics.migrations_cap += 1,
                    MigrationKind::Popularity => self.metrics.migrations_pop += 1,
                }
                if task.to == Dev::Ssd {
                    // Cached copies of a now-SSD-resident SST are stale
                    // bandwidth — drop them.
                    self.pool.invalidate_sst(task.sst);
                }
            }
            if self.migration_queue.is_empty() {
                self.migration_active = false;
            } else {
                self.push_event(self.now, EventKind::MigrationStep);
            }
            // The migrated SST is no longer busy — if writers are stalled,
            // compactions that were blocked on it (e.g. the L0→L1 pick
            // while an L0/L1 SST was in flight) must be rescheduled now or
            // the parked writers would never wake (the livelock this guard
            // exists for).
            if !self.parked.is_empty() {
                self.maybe_schedule_jobs();
                self.unpark_writers();
            }
            return;
        }
        // SST got deleted mid-migration (compaction won the race despite
        // busy marking — defensive) → abort.
        if self.fs.file(task.sst).is_none() {
            let task = self.migration_queue.pop_front().unwrap();
            self.busy_ssts.remove(&task.sst);
            let (shard, sst, at) = (self.cpu_shard, task.sst, self.now);
            self.trace.emit(|| Event::MigEnd { shard, sst, at });
            if self.migration_queue.is_empty() {
                self.migration_active = false;
            } else {
                self.push_event(self.now, EventKind::MigrationStep);
            }
            return;
        }
        let chunk = self.cfg.hhzs.chunk_bytes.min(task.remaining);
        task.remaining -= chunk;
        let (from, to, sst) = (task.from, task.to, task.sst);
        let (s1, f1) = self.fs.charge(self.now, from, AccessKind::SeqRead, chunk);
        let (s2, f2) = self.fs.charge(self.now, to, AccessKind::SeqWrite, chunk);
        self.metrics.record_queue_wait(from, s1.saturating_sub(self.now));
        self.metrics.record_queue_wait(to, s2.saturating_sub(self.now));
        self.trace_io(from, IoOp::MigrationRead, None, Some(sst), chunk, s1, self.now);
        self.trace_io(to, IoOp::MigrationWrite, None, Some(sst), chunk, s2, self.now);
        self.metrics.migration_bytes += chunk;
        self.metrics.record_write(WriteCategory::Migration, to, chunk);
        // Rate limiting (§3.4): chunks are spaced at chunk / rate.
        let pace = (chunk as f64 / self.cfg.hhzs.migration_rate_bps * 1e9) as Ns;
        let next = (self.now + pace).max(f1).max(f2);
        self.push_event(next, EventKind::MigrationStep);
    }

    // ------------------------------------------------------------------
    // Client loop
    // ------------------------------------------------------------------

    fn unpark_writers(&mut self) {
        if self.write_blocked() {
            return;
        }
        let parked = std::mem::take(&mut self.parked);
        for c in parked {
            self.push_event(self.now, EventKind::Client(c));
        }
    }

    fn execute_op(&mut self, op: Op) -> Ns {
        match op {
            Op::Insert { key, value } | Op::Update { key, value } => {
                self.do_put(&key, Some(value))
            }
            Op::Read { key } => self.do_get(&key).1,
            Op::Scan { key, len } => self.do_scan(&key, len).1,
            Op::ReadModifyWrite { key, value } => {
                let (_, f1) = self.do_get(&key);
                let dt = f1 - self.now;
                let f2 = self.do_put(&key, Some(value));
                f2 + dt
            }
        }
    }

    fn op_kind_is_write(op: &Op) -> bool {
        matches!(op, Op::Insert { .. } | Op::Update { .. } | Op::ReadModifyWrite { .. })
    }

    /// Execute one client op the frontend routed here, on the frontend's
    /// clock (`at` = the global event time; `issued_at` = when the client
    /// first pulled the op — earlier than `at` if it was parked).
    ///
    /// Blocked writes park: this engine records the stall, remembers the
    /// client id, and re-arms it (via [`Engine::unpark_writers`] pushing an
    /// `EventKind::Client` event) once background work unblocks writes.
    pub(crate) fn frontend_client_op(
        &mut self,
        c: usize,
        op: Op,
        issued_at: Ns,
        at: Ns,
    ) -> FrontendOp {
        debug_assert!(at >= self.now, "frontend time went backwards");
        self.now = at;
        self.trace.stamp(at);
        if Self::op_kind_is_write(&op) && self.write_blocked() {
            // Park until a flush/compaction unblocks writes.
            self.metrics.stalls += 1;
            self.parked.push(c);
            let (shard, at) = (self.cpu_shard, self.now);
            self.trace.emit(|| Event::Stall { shard, client: c, at });
            return FrontendOp::Parked(op);
        }
        let is_write = Self::op_kind_is_write(&op);
        let is_scan = matches!(op, Op::Scan { .. });
        // Cross-shard group commit: plain writes stage into the shared
        // committer and ack at the batch's fused append. Reads, scans, and
        // RMW (whose read half pins the op to this event) keep the
        // immediate path; with the knobs off `gc.enabled()` is false and
        // this block never runs.
        let finish = if self.gc.enabled() {
            match op {
                Op::Insert { key, value } | Op::Update { key, value } => {
                    match self.stage_put(c, &key, Some(value), issued_at) {
                        StagePut::Staged => {
                            self.note_unstall(c, issued_at);
                            return FrontendOp::Staged;
                        }
                        StagePut::Immediate(f) => f,
                    }
                }
                other => self.execute_op(other),
            }
        } else {
            self.execute_op(op)
        };
        let lat = finish.saturating_sub(issued_at);
        self.note_unstall(c, issued_at);
        if is_write {
            self.metrics.write_lat.record(lat);
        } else if is_scan {
            self.metrics.scan_lat.record(lat);
        } else {
            self.metrics.read_lat.record(lat);
        }
        self.metrics.ops_done += 1;
        FrontendOp::Done(finish)
    }

    /// Charge the stall to the measured phase only: a writer parked across
    /// a `begin_phase` boundary starts charging at the boundary, not at
    /// its pre-reset issue time — so the UNSTALL span and
    /// `Metrics::stall_ns` agree (checker-enforced) and the fresh phase
    /// never inherits pre-reset stall time.
    fn note_unstall(&mut self, c: usize, issued_at: Ns) {
        if issued_at < self.now {
            let base = issued_at.max(self.metrics.start_ns);
            let dur = self.now.saturating_sub(base);
            if dur > 0 {
                self.metrics.stall_ns += dur;
                let (shard, at) = (self.cpu_shard, self.now);
                self.trace.emit(|| Event::Unstall { shard, client: c, at, dur });
            }
        }
    }

    /// Charge one closed batch's fused WAL append on the shared device
    /// timer — ONE `per_req_overhead_ns` for the whole window — and emit
    /// the close record. Called by the frontend's batch-close hook on the
    /// first member's engine (any engine reaches the same shared timer).
    /// Returns the fused grant `(start, finish)`.
    pub(crate) fn charge_batch_close(&mut self, at: Ns, b: &Batch) -> (Ns, Ns) {
        self.now = self.now.max(at);
        self.trace.stamp(self.now);
        let bytes = b.total_bytes();
        let members = b.members.len() as u32;
        let (start, finish) =
            self.fs.charge_fused(self.now, b.dev, AccessKind::SeqWrite, bytes, members);
        self.metrics.wal_group_size.record(members as u64);
        let (id, dev, now) = (b.id, b.dev, self.now);
        self.trace
            .emit(|| Event::BatchClose { id, dev, members, bytes, start, finish, at: now });
        (start, finish)
    }

    /// Book one member's share of a closed batch on its own engine: queue
    /// wait measured from its stage point, Wal byte traffic (the request
    /// count lands on the first member only — the batch was ONE device
    /// request), the per-member Io record the snapshot checker sums, and
    /// the ack-time latency sample. Returns the ack time for the
    /// frontend's client rescheduling.
    pub(crate) fn book_batch_member(
        &mut self,
        batch_id: u64,
        dev: Dev,
        m: &Member,
        first: bool,
        start: Ns,
        finish: Ns,
    ) -> Ns {
        self.metrics.record_queue_wait(dev, start.saturating_sub(m.staged_at));
        self.metrics.record_write_ios(
            WriteCategory::Wal,
            dev,
            m.bytes,
            if first { 1 } else { 0 },
        );
        self.trace_io(dev, IoOp::Wal, None, None, m.bytes, start, m.staged_at);
        let ack = finish.max(m.cpu_ready);
        self.metrics.write_lat.record(ack.saturating_sub(m.issued_at));
        self.metrics.ops_done += 1;
        let (id, shard, client, bytes, staged) =
            (batch_id, m.shard, m.client, m.bytes, m.staged_at);
        self.trace.emit(|| Event::BatchAck { id, shard, client, bytes, staged, ack });
        ack
    }

    /// One shard's share of a scatter-gathered scan, charged at the global
    /// event time. `count_op` attributes the scan to this shard's
    /// `scans_done` (the frontend sets it on the home shard only, so
    /// merged op counts stay exact).
    pub(crate) fn frontend_scan(
        &mut self,
        at: Ns,
        start: &[u8],
        n: usize,
        count_op: bool,
    ) -> (Vec<Entry>, Ns) {
        debug_assert!(at >= self.now, "frontend time went backwards");
        self.now = at;
        if count_op {
            self.metrics.scans_done += 1;
        }
        self.scan_entries(start, n)
    }

    /// `(time, sequence)` of this engine's earliest pending event.
    pub(crate) fn next_event_at(&self) -> Option<(Ns, u64)> {
        self.events.peek().map(|e| (e.at, e.seq))
    }

    /// Pop and process this engine's earliest event (the frontend already
    /// established it is the global minimum). Background events are
    /// handled here exactly as the workload loop always did; a client
    /// readiness event (an unparked writer) is returned to the frontend,
    /// which owns the clients.
    pub(crate) fn step_event(&mut self) -> Option<usize> {
        let ev = self.events.pop()?;
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        self.trace.stamp(self.now);
        match ev.kind {
            EventKind::Client(c) => return Some(c),
            EventKind::JobStep(id) => self.handle_job_step(id),
            EventKind::MigrationStep => self.handle_migration_step(),
            EventKind::PolicyTick => {
                self.with_view(|p, v| p.tick(v.now, v));
                self.start_migration_if_idle();
                // Safety net: if writers are parked, re-check
                // schedulability so no ordering of job/migration
                // completions can strand them.
                if !self.parked.is_empty() {
                    self.maybe_schedule_jobs();
                    self.unpark_writers();
                }
                let next = self.now + self.cfg.hhzs.scan_interval_ns;
                self.push_event(next, EventKind::PolicyTick);
            }
            EventKind::Sample => {
                if self.sampling {
                    self.take_level_sample();
                    self.push_event(self.now + self.cfg.hhzs.sample_interval_ns, EventKind::Sample);
                }
            }
            // The frontend's post-event hook drains the due queue and
            // issues the fused append.
            EventKind::WalCommit(id) => self.gc.on_deadline(id),
        }
        None
    }

    /// Start a measured phase: reset metrics, stamp the shared-clock start,
    /// and arm the level sampler.
    ///
    /// Faithful to the seed loop, a residual `Sample` event from an
    /// earlier sampled phase is NOT drained — two back-to-back sampled
    /// phases on one engine would sample at double cadence (latent: every
    /// in-tree caller samples only the first phase of a fresh engine).
    pub(crate) fn begin_phase(&mut self, start_ns: Ns, sample: bool) {
        // Close the previous phase's checker segment BEFORE the reset wipes
        // its accumulators — the snapshot is what the replay sums against.
        self.trace_snapshot();
        self.metrics = Metrics::default();
        self.metrics.start_ns = start_ns;
        self.parked.clear();
        self.sampling = sample;
        if sample {
            self.push_event(self.now + self.cfg.hhzs.sample_interval_ns, EventKind::Sample);
        }
    }

    /// End a measured phase at the shared clock's final time. Sweeps the
    /// key arena (no virtual-time cost) and stamps the `key_arena_bytes`
    /// gauge — with a shared arena every shard stamps the same
    /// domain-level value, which the metrics merge takes the max of.
    pub(crate) fn end_phase(&mut self, finished_at: Ns) {
        self.sampling = false;
        self.metrics.finished_at = finished_at;
        // One sweep per domain per phase end: shard 0 sweeps the (shared)
        // arena, the other shards just stamp the post-sweep gauge — the
        // frontend ends phases in shard order, so a redundant full-table
        // scan per extra shard is avoided.
        if self.cpu_shard == 0 {
            self.arena.sweep();
        }
        self.metrics.key_arena_bytes = self.arena.bytes();
        self.stamp_residency_gauges();
    }

    /// Stamp the four physical-residency gauges from this engine's zones
    /// and block cache. The partition is exact by construction:
    ///
    ///   ssd + hdd + wal + cache == fs.phys_bytes() + cache.phys_bytes()
    ///
    /// WAL zones are carved out of whichever device holds them; SSD cache
    /// zones are reported under `cache` together with the block cache's
    /// pinned (hydrated) copies. The gauges are host-side diagnostics and
    /// never feed the DES timeline or digests. Public so the conservation
    /// test (tests/datapath.rs) can restamp at arbitrary instants.
    pub fn stamp_residency_gauges(&mut self) {
        let (mut ssd_wal, mut hdd_wal) = (0u64, 0u64);
        for (dev, z) in self.pool.wal_zone_ids() {
            let b = self.fs.device_ref(dev).zone(z).phys_bytes();
            match dev {
                Dev::Ssd => ssd_wal += b,
                Dev::Hdd => hdd_wal += b,
            }
        }
        let mut cache_zones = 0u64;
        for z in self.pool.cache_zone_ids() {
            cache_zones += self.fs.ssd.zone(z).phys_bytes();
        }
        let m = &mut self.metrics;
        m.resident_wal_bytes = ssd_wal + hdd_wal;
        m.resident_cache_bytes = cache_zones + self.cache.phys_bytes();
        m.resident_ssd_bytes = self.fs.ssd.phys_bytes() - ssd_wal - cache_zones;
        m.resident_hdd_bytes = self.fs.hdd.phys_bytes() - hdd_wal;
    }

    fn take_level_sample(&mut self) {
        let wal_bytes: u64 = self.pool.wal_zones_in_use() as u64 * self.cfg.geometry.ssd_zone_cap;
        let level_bytes: Vec<u64> =
            (0..self.version.num_levels()).map(|l| self.version.level_bytes(l)).collect();
        self.metrics.level_samples.push(LevelSizeSample {
            at: self.now,
            wal_bytes,
            level_bytes,
        });
    }

    /// Drive a workload: `clients` closed-loop clients pulling ops from
    /// `source`, optionally throttled to `target_ops_per_sec` (Fig 2(d–f))
    /// and sampling level sizes every virtual minute (Fig 2(a)/(d)).
    ///
    /// The loop itself lives in the async frontend ([`crate::shard`]):
    /// a standalone engine is the 1-shard special case of the same event
    /// loop, which is what pins `shards = 1` to the seed system.
    pub fn run(
        &mut self,
        source: &mut dyn OpSource,
        clients: usize,
        target_ops_per_sec: Option<f64>,
        sample_levels: bool,
    ) {
        let seq = self.event_seq.clone();
        let router = crate::shard::Router::new(1);
        crate::shard::Frontend::new(std::slice::from_mut(self), router, seq, source).run(
            clients,
            target_ops_per_sec,
            sample_levels,
        );
    }

    // ------------------------------------------------------------------
    // Synchronous DB-style API (examples / integration tests)
    // ------------------------------------------------------------------

    /// Process all queued events up to (and including) time `t`.
    fn drain_until(&mut self, t: Ns) {
        while let Some(ev) = self.events.peek() {
            if ev.at > t {
                break;
            }
            let ev = self.events.pop().unwrap();
            self.now = ev.at;
            match ev.kind {
                EventKind::Client(_) => {} // no clients in sync mode
                EventKind::JobStep(id) => self.handle_job_step(id),
                EventKind::MigrationStep => self.handle_migration_step(),
                EventKind::PolicyTick => {
                    self.with_view(|p, v| p.tick(v.now, v));
                    self.start_migration_if_idle();
                    let next = self.now + self.cfg.hhzs.scan_interval_ns;
                    self.push_event(next, EventKind::PolicyTick);
                }
                EventKind::Sample => {}
                // Sync mode never stages (group commit is frontend-driven)
                // — drain stale deadline events defensively.
                EventKind::WalCommit(id) => self.gc.on_deadline(id),
            }
        }
        self.now = self.now.max(t);
    }

    /// Synchronous put of real bytes: the value is fingerprinted into a
    /// [`Payload`] at this API boundary — the engine never stores it.
    pub fn put(&mut self, key: &[u8], value: &[u8]) {
        self.put_payload(key, Payload::from_bytes(value));
    }

    /// Synchronous put: advances the virtual clock past the op.
    pub fn put_payload(&mut self, key: &[u8], value: Payload) {
        while self.write_blocked() {
            // Let background work run until writes unblock.
            let next = self.events.peek().map(|e| e.at).expect("background progress");
            self.drain_until(next);
        }
        let f = self.do_put(key, Some(value));
        self.drain_until(f);
    }

    /// Synchronous delete (tombstone).
    pub fn delete(&mut self, key: &[u8]) {
        while self.write_blocked() {
            let next = self.events.peek().map(|e| e.at).expect("background progress");
            self.drain_until(next);
        }
        let f = self.do_put(key, None);
        self.drain_until(f);
    }

    /// Synchronous get. Returns the value's [`Payload`] (length +
    /// fingerprint) — bit-identical read path to a byte-materialized
    /// engine, without the bytes.
    pub fn get(&mut self, key: &[u8]) -> Option<Payload> {
        let (v, f) = self.do_get(key);
        self.drain_until(f);
        v
    }

    /// Synchronous scan; returns the number of entries touched.
    pub fn scan(&mut self, start: &[u8], n: usize) -> usize {
        let (got, f) = self.do_scan(start, n);
        self.drain_until(f);
        got
    }

    /// Synchronous scan returning the collected entries — the per-shard
    /// half of [`crate::shard::ShardedEngine::scan`]'s scatter-gather (the
    /// shard layer k-way merges the parts). `count_op` attributes the scan
    /// to this shard's `scans_done`; the shard layer sets it on the home
    /// shard only, so one logical scan counts once in merged metrics.
    pub fn scan_collect(&mut self, start: &[u8], n: usize, count_op: bool) -> Vec<Entry> {
        if count_op {
            self.metrics.scans_done += 1;
        }
        let (entries, f) = self.scan_entries(start, n);
        self.drain_until(f);
        entries
    }

    /// Flush every MemTable (including the active one) and wait for the
    /// flushes to land — the state a RocksDB reopen leaves behind, which
    /// is what happens between YCSB's load and run phases (§4.1 evaluates
    /// each workload after a fresh load). Releases all WAL zones.
    pub fn flush_all(&mut self) {
        loop {
            if !self.mem.is_empty() && self.immutables.len() + 1 < self.cfg.lsm.max_memtables {
                self.seal_memtable();
                self.maybe_schedule_jobs();
            }
            if self.mem.is_empty() && self.immutables.is_empty() && !self.flush_active {
                break;
            }
            // min_flush_memtables may keep a single immutable waiting —
            // force it.
            if !self.flush_active && !self.immutables.is_empty() {
                self.start_flush();
                if !self.flush_active && self.jobs.is_empty() {
                    // CPU-starved from outside: the slots are held by other
                    // shards' jobs and nothing local can free one. Return
                    // and let the shard layer drive the holder forward
                    // (`ShardedEngine::flush_all`); a standalone engine
                    // can never hit this (denial implies local jobs).
                    break;
                }
            }
            let Some(next) = self.events.peek().map(|e| e.at) else { break };
            self.drain_until(next);
        }
    }

    /// Is [`Engine::flush_all`]'s goal state reached? (Used by the shard
    /// layer to drive cross-shard progress when the shared CPU pool keeps
    /// one shard's flush waiting on another shard's slots.)
    pub(crate) fn flush_settled(&self) -> bool {
        self.mem.is_empty() && self.immutables.is_empty() && !self.flush_active
    }

    /// Is [`Engine::quiesce`]'s goal state reached (modulo a policy that
    /// would start fresh migrations — callers re-run `quiesce` to probe)?
    pub(crate) fn background_settled(&self) -> bool {
        self.jobs.is_empty()
            && !self.migration_active
            && self.migration_queue.is_empty()
            && !self.flush_wanted()
    }

    /// Let all background work (flushes, compactions, and any migrations
    /// the policy still wants) finish.
    pub fn quiesce(&mut self) {
        loop {
            // A flush that was CPU-starved earlier retries here once other
            // shards' releases free slots (sync mode has no event-loop
            // wake; for a standalone engine this is a no-op — a denied
            // flush implies local jobs whose finish reschedules it).
            if self.flush_wanted() {
                self.maybe_schedule_jobs();
            }
            let has_work = !self.jobs.is_empty()
                || self.migration_active
                || self.flush_wanted()
                || !self.migration_queue.is_empty();
            if !has_work {
                // Background is idle — ask the policy whether migration
                // work remains (capacity violations, hot HDD SSTs).
                self.start_migration_if_idle();
                if !self.migration_active {
                    break;
                }
            }
            if self.jobs.is_empty()
                && !self.migration_active
                && self.migration_queue.is_empty()
                && self.flush_wanted()
                && !self.cpu.borrow().can_admit_flush()
            {
                // CPU-starved from outside (slots held by other shards,
                // nothing local to drain but the eternal PolicyTick):
                // return and let the shard layer advance the slot holder.
                break;
            }
            let Some(next) = self.events.peek().map(|e| e.at) else { break };
            self.drain_until(next);
        }
    }

    /// Simulate a crash + restart: all in-memory state (MemTables,
    /// immutables, block cache) is lost and rebuilt by replaying the live
    /// WAL segments from their zones — the §2.2 crash-consistency
    /// contract. Returns the number of entries replayed.
    ///
    /// Background jobs in flight are discarded (their outputs were never
    /// published in a crash-surviving version, so their files and zones
    /// are reclaimed), exactly as a restart would find them. This is the
    /// *cooperative* form — no media damage; the injected form
    /// ([`CrashInjector`] + the `crash_fire` hooks) additionally tears the
    /// in-flight zone append mid-record first.
    pub fn crash_and_recover(&mut self) -> usize {
        self.crash_volatile();
        self.recover_replay(None)
    }

    /// Which WAL-window crash point (if any) fires on this put. All three
    /// tear the record this very put just appended: it is on media but the
    /// MemTable apply has not run and the client was never acked.
    fn wal_crash_point(&mut self) -> Option<CrashPoint> {
        let now = self.now;
        let inj = self.crash.as_mut()?;
        inj.note_op();
        [CrashPoint::MidZoneAppend, CrashPoint::WalBeforeMemtable, CrashPoint::MidRecovery]
            .into_iter()
            .find(|p| inj.should_fire(*p, now))
    }

    /// Does an armed injector fire on this job's next step?
    fn job_crash_point(&self, id: u64) -> Option<CrashPoint> {
        let inj = self.crash.as_ref()?;
        let p = match self.jobs.get(&id)? {
            Job::Flush(_) => CrashPoint::MidFlush,
            Job::Compaction(_) => CrashPoint::MidCompaction,
        };
        if inj.should_fire(p, self.now) {
            Some(p)
        } else {
            None
        }
    }

    /// Fire the armed injector at `point`: inflict the physical power-loss
    /// media state (a zone append truncated at an RNG-chosen byte — the
    /// write pointer lands mid-record), drop all volatile state, unwind
    /// the shared substrate, and restart from surviving zones/WAL only.
    /// The injector is kept (with `fired = true`) for introspection.
    fn crash_fire(&mut self, point: CrashPoint) {
        let mut inj = self.crash.take().expect("crash point checked armed");
        inj.fired = true;
        match point {
            CrashPoint::MidZoneAppend | CrashPoint::WalBeforeMemtable | CrashPoint::MidRecovery => {
                // Tear the WAL record the interrupted put just appended.
                if let Some(len) = self.pool.last_record_len() {
                    let keep = inj.torn_byte(len);
                    let Engine { fs, pool, .. } = self;
                    if pool.tear_wal_tail(fs, keep).is_some() {
                        inj.torn = Some(keep);
                    }
                }
            }
            CrashPoint::MidFlush | CrashPoint::MidCompaction => {
                self.write_torn_job_orphan(point, &mut inj);
            }
            CrashPoint::MidMigration => self.write_torn_migration_orphan(&mut inj),
        }
        let (shard, name, at) = (self.cpu_shard, point.name(), self.now);
        self.trace.emit(|| Event::CrashFired { shard, point: name, at });
        self.crash_volatile();
        let double_fault = if point == CrashPoint::MidRecovery { Some(&mut inj) } else { None };
        self.recover_replay(double_fault);
        self.crash = Some(inj);
    }

    /// Write the torn prefix of the crashed job's in-flight output SST
    /// into a fresh empty zone, with no zenfs file over it — the real
    /// on-media state a power loss leaves mid-SST-write. Recovery's
    /// orphan GC must find and reclaim it.
    fn write_torn_job_orphan(&mut self, point: CrashPoint, inj: &mut CrashInjector) {
        let mut job_ids: Vec<u64> = self.jobs.keys().copied().collect();
        job_ids.sort_unstable();
        for id in job_ids {
            let (outputs, cur, want) = match (point, &self.jobs[&id]) {
                (CrashPoint::MidFlush, Job::Flush(j)) => (&j.outputs, j.cur, Dev::Ssd),
                (CrashPoint::MidCompaction, Job::Compaction(j)) => (&j.outputs, j.cur, Dev::Hdd),
                _ => continue,
            };
            if let Some(out) = outputs.get(cur) {
                if out.data.len() > 1 {
                    let keep = inj.torn_byte(out.data.len());
                    let prefix = out.data.slice_to_buf(0, keep);
                    let dev = out.dev.unwrap_or(want);
                    inj.torn = self.write_orphan(&prefix, dev);
                    return;
                }
            }
        }
    }

    /// Same, for the SST copy a migration was writing to its target device.
    fn write_torn_migration_orphan(&mut self, inj: &mut CrashInjector) {
        let Some(task) = self.migration_queue.front() else { return };
        let (sst, to) = (task.sst, task.to);
        let size = match self.fs.file(sst) {
            Some(f) if f.size > 1 => f.size,
            _ => return,
        };
        let keep = inj.torn_byte(size);
        let Ok(prefix) = self.fs.read_file_untimed(sst, 0, keep) else { return };
        inj.torn = self.write_orphan(&prefix, to);
    }

    /// Append `data` into an empty zone on `want` (falling back to the
    /// other device), bypassing zenfs: an unreferenced on-media orphan
    /// whose write pointer sits mid-record. Returns the bytes that landed.
    fn write_orphan(&mut self, data: &WireBuf, want: Dev) -> Option<u64> {
        if data.is_empty() {
            return None;
        }
        let alt = if want == Dev::Ssd { Dev::Hdd } else { Dev::Ssd };
        for dev in [want, alt] {
            let zone = match dev {
                // Never a reserved pool zone: those belong to the WAL/
                // cache allocator, which only ever appends there itself.
                Dev::Ssd => (0..self.fs.ssd.num_zones()).find(|z| {
                    self.fs.ssd.zone(*z).is_empty() && !self.fs.reserved_ssd_zones().contains(z)
                }),
                Dev::Hdd => self.fs.hdd.find_empty_zone(),
            };
            if let Some(z) = zone {
                let cap = self.fs.device_ref(dev).zone(z).capacity;
                let chunk = data.slice_to_buf(0, data.len().min(cap));
                if self.fs.device(dev).append_untimed(z, &chunk).is_ok() {
                    return Some(chunk.len());
                }
                return None;
            }
        }
        // No empty zone anywhere: the power loss had nowhere to leave a
        // torn write — media stays as-is.
        None
    }

    /// Drop all volatile state and unwind in-flight background work — the
    /// restart's view before WAL replay. Outputs a crashed job had already
    /// installed in zenfs but not yet published in a crash-surviving
    /// version are deleted; flush outputs additionally leave L0, where
    /// install had optimistically placed them (their WAL segments are
    /// still live, so replay restores every entry). Queued migrations are
    /// unwound span-by-span so no busy mark or open trace span leaks.
    fn crash_volatile(&mut self) {
        self.mem = MemTable::new();
        self.immutables.clear();
        self.cache = BlockCache::new(self.cfg.lsm.block_cache_bytes);
        let mut job_ids: Vec<u64> = self.jobs.keys().copied().collect();
        job_ids.sort_unstable();
        for id in job_ids {
            if let Some(job) = self.jobs.remove(&id) {
                match job {
                    Job::Flush(j) => {
                        // Outputs before `cur` were installed in zenfs AND
                        // added to L0 at install time — reclaim their file
                        // and zone space symmetrically with the compaction
                        // arm below (the crash loses the whole flush; its
                        // WAL segments survive for replay).
                        for out in &j.outputs[..j.cur] {
                            self.version.remove_l0(out.meta.id);
                            let _ = self.fs.delete_file(out.meta.id);
                            self.pool.invalidate_sst(out.meta.id);
                            self.policy.on_sst_deleted(out.meta.id);
                        }
                        self.flush_active = false;
                        self.cpu.borrow_mut().release_flush(self.cpu_shard);
                        self.trace_job_end(JobKind::Flush, id);
                    }
                    Job::Compaction(j) => {
                        for m in &j.installed {
                            let _ = self.fs.delete_file(m.id);
                            self.pool.invalidate_sst(m.id);
                        }
                        for sst in &j.input_ids {
                            self.busy_ssts.remove(sst);
                        }
                        self.busy_levels.remove(&j.level);
                        self.busy_levels.remove(&(j.level + 1));
                        self.cpu.borrow_mut().release_compaction(self.cpu_shard);
                        self.trace_job_end(JobKind::Compaction, id);
                    }
                }
            }
        }
        // The restart drops any CPU claims with the in-flight jobs, and
        // the scheduler forgets the victim: risk, age and any pending
        // promotion die with the process (the checker mirrors this reset
        // at the CRASH record). The fg pool needs no unwind — its slot
        // clocks decay with virtual time and grants are never held open.
        self.trace_flush_unwait();
        self.cpu.borrow_mut().clear_flush_waiter(self.cpu_shard);
        self.cpu.borrow_mut().set_comp_waiter(self.cpu_shard, false);
        self.cpu.borrow_mut().reset_shard_sched_state(self.cpu_shard);
        self.last_risk = 0;
        self.flush_ready_since = None;
        self.comp_ready_since = None;
        // Unwind queued migrations: close their spans and busy marks (a
        // leaked busy mark would block those SSTs' compactions forever
        // after recovery).
        while let Some(task) = self.migration_queue.pop_front() {
            self.busy_ssts.remove(&task.sst);
            let (shard, sst, at) = (self.cpu_shard, task.sst, self.now);
            self.trace.emit(|| Event::MigEnd { shard, sst, at });
        }
        self.migration_active = false;
    }

    /// Reset any non-empty zone no surviving metadata references: zenfs
    /// file extents, live WAL zones, and SSD cache zones. These are
    /// exactly the zones a power loss stranded (torn SST outputs, partial
    /// migration copies) — for an unreferenced zone, "write pointer
    /// consistent with metadata" (invariant I3) means `wp == 0`.
    fn recovery_orphan_gc(&mut self) -> usize {
        let mut live: HashSet<(Dev, ZoneId)> = HashSet::new();
        for f in self.fs.files() {
            for ext in &f.extents {
                live.insert((f.dev, ext.zone));
            }
        }
        for z in self.pool.referenced_zones() {
            live.insert(z);
        }
        let mut reclaimed = 0;
        for dev in [Dev::Ssd, Dev::Hdd] {
            for z in 0..self.fs.device_ref(dev).num_zones() {
                if !self.fs.device_ref(dev).zone(z).is_empty() && !live.contains(&(dev, z)) {
                    self.fs.device(dev).reset(z);
                    reclaimed += 1;
                }
            }
        }
        reclaimed
    }

    /// Restart from surviving media only: GC orphan zones, read back the
    /// live WAL segments (each clamped to its zone's surviving write
    /// pointer — a torn tail replays its intact prefix), and replay them
    /// oldest-first. `double_fault` aborts the replay partway once (the
    /// MidRecovery crash), drops the half-built MemTable, and restarts it
    /// from scratch — the media is untouched, so the retry converges on
    /// the same state.
    fn recover_replay(&mut self, double_fault: Option<&mut CrashInjector>) -> usize {
        self.recovery_orphan_gc();
        let segments = {
            let Engine { pool, fs, metrics, now, .. } = &mut *self;
            pool.recover_segments(fs, metrics, *now)
        };
        let total: u64 = segments.iter().map(|(_, b)| b.entries().count() as u64).sum();
        let mut abort_at = match double_fault {
            Some(inj) if total > 0 => Some(inj.pick_below(total)),
            _ => None,
        };
        let mut key_buf: Vec<u8> = Vec::new();
        'replay: loop {
            let mut replayed = 0usize;
            let mut max_seq = self.seq;
            for (_, buf) in &segments {
                for e in buf.entries() {
                    if abort_at == Some(replayed as u64) {
                        abort_at = None;
                        self.mem = MemTable::new();
                        let (shard, at) = (self.cpu_shard, self.now);
                        let point = CrashPoint::MidRecovery.name();
                        self.trace.emit(|| Event::CrashFired { shard, point, at });
                        continue 'replay;
                    }
                    max_seq = max_seq.max(e.seq);
                    e.key.copy_into(&mut key_buf);
                    let key = self.arena.intern(&key_buf);
                    self.mem.insert(key, e.seq, e.value);
                    replayed += 1;
                }
            }
            self.seq = max_seq;
            let (shard, at, n) = (self.cpu_shard, self.now, replayed as u64);
            self.trace.emit(|| Event::Recovered { shard, replayed: n, at });
            return replayed;
        }
    }

    /// Post-recovery structural invariants (the crash harness's I2/I3);
    /// returns human-readable violations, empty when consistent.
    ///
    /// I2 — no torn SST visible in any version: every SST the version
    /// references has a zenfs file of exactly `file_size` bytes whose
    /// blocks are fully readable and decode to exactly `num_entries`
    /// whole entries (a torn block decodes short — the wire format stops
    /// at a severed record).
    ///
    /// I3 — every zone's write pointer consistent with zenfs metadata:
    /// all file extents, live WAL runs, and cached blocks lie at or below
    /// their zone's write pointer, and every non-empty zone is referenced
    /// by some surviving metadata (no orphans escape GC).
    pub fn verify_recovery_invariants(&mut self) -> Vec<String> {
        let mut viol = Vec::new();
        // I2: version SSTs fully present and decodable.
        let metas: Vec<Arc<SstMeta>> = (0..self.version.num_levels())
            .flat_map(|l| self.version.level(l).iter().cloned())
            .collect();
        for m in metas {
            let Some(f) = self.fs.file(m.id) else {
                viol.push(format!("I2: sst {} (L{}) has no zenfs file", m.id, m.level));
                continue;
            };
            if f.size != m.file_size {
                viol.push(format!(
                    "I2: sst {} file size {} != meta file_size {}",
                    m.id, f.size, m.file_size
                ));
                continue;
            }
            let mut entries = 0u64;
            let mut unreadable = false;
            for h in &m.blocks {
                match self.fs.read_file_untimed(m.id, h.offset, h.len as u64) {
                    Ok(b) => entries += b.entries().count() as u64,
                    Err(e) => {
                        viol.push(format!(
                            "I2: sst {} block @{} unreadable: {e:?}",
                            m.id, h.offset
                        ));
                        unreadable = true;
                    }
                }
            }
            if !unreadable && entries != m.num_entries {
                viol.push(format!(
                    "I2: sst {} decodes {} entries, meta says {} (torn block)",
                    m.id, entries, m.num_entries
                ));
            }
        }
        // I3a: every referenced byte range is below its zone's wp.
        let mut referenced: HashSet<(Dev, ZoneId)> = HashSet::new();
        let mut ranges: Vec<(Dev, ZoneId, u64, u64, String)> = Vec::new();
        for f in self.fs.files() {
            for ext in &f.extents {
                ranges.push((f.dev, ext.zone, ext.offset, ext.len, format!("file {}", f.id)));
            }
        }
        for (dev, zone, offset, len) in self.pool.live_runs() {
            ranges.push((dev, zone, offset, len, "wal run".to_string()));
        }
        for loc in self.pool.cache_locs() {
            ranges.push((Dev::Ssd, loc.zone, loc.offset, loc.len as u64, "cache block".into()));
        }
        for (dev, zone, offset, len, what) in ranges {
            referenced.insert((dev, zone));
            let wp = self.fs.device_ref(dev).zone(zone).wp();
            if offset + len > wp {
                viol.push(format!(
                    "I3: {what} [{offset}, {}) beyond wp {wp} of {dev:?} zone {zone}",
                    offset + len
                ));
            }
        }
        // I3b: no unreferenced non-empty zones (orphans escape GC). The
        // active WAL / cache zones are referenced-by-construction even
        // when their runs were fully released.
        for z in self.pool.referenced_zones() {
            referenced.insert(z);
        }
        for dev in [Dev::Ssd, Dev::Hdd] {
            for z in 0..self.fs.device_ref(dev).num_zones() {
                if !self.fs.device_ref(dev).zone(z).is_empty() && !referenced.contains(&(dev, z)) {
                    viol.push(format!("I3: {dev:?} zone {z} non-empty but unreferenced"));
                }
            }
        }
        viol
    }

    /// Attach the AOT XLA kernels: enables the batched bloom read path
    /// ([`Engine::multi_get`]) and, when the policy supports it, XLA-scored
    /// migration decisions.
    pub fn attach_xla(&mut self, k: std::rc::Rc<crate::runtime::XlaKernels>) {
        self.xla = Some(k);
    }

    /// Batched point lookups. With XLA attached, Bloom filters of candidate
    /// SSTs are probed through the AOT Pallas kernel — one PJRT dispatch
    /// per (SST, key-batch) pair — before any block I/O is issued; results
    /// are identical to per-key [`Engine::get`] (asserted in tests).
    pub fn multi_get(&mut self, keys: &[Vec<u8>]) -> Vec<Option<Payload>> {
        let Some(xla) = self.xla.clone() else {
            return keys.iter().map(|k| self.get(k)).collect();
        };
        let mut out: Vec<Option<Payload>> = vec![None; keys.len()];
        let mut resolved = vec![false; keys.len()];
        // 1. MemTable hits need no probing.
        for (i, key) in keys.iter().enumerate() {
            if let Some(v) = self.mem.get(key) {
                out[i] = v;
                resolved[i] = true;
                self.metrics.memtable_hits += 1;
                self.metrics.reads_done += 1;
                continue;
            }
            for (_, im) in self.immutables.iter().rev() {
                if let Some(v) = im.get(key) {
                    out[i] = v;
                    resolved[i] = true;
                    self.metrics.memtable_hits += 1;
                    self.metrics.reads_done += 1;
                    break;
                }
            }
        }
        // One fingerprint per UNRESOLVED key for the whole batch: the
        // bloom probes (native fallback + kernel chunks) and the
        // post-probe fallback below all reuse it. (The seed hashed each
        // key once per probing site — twice or more per key on the common
        // path; memtable hits never needed a hash at all.)
        let fps_by_key: Vec<u32> = keys
            .iter()
            .enumerate()
            .map(|(i, k)| if resolved[i] { 0 } else { fingerprint32(k) })
            .collect();
        // 2. Group (key → candidate SSTs) by SST and batch-probe blooms.
        let mut per_sst: std::collections::HashMap<SstId, Vec<usize>> = Default::default();
        let mut candidates: Vec<Vec<Arc<SstMeta>>> = vec![Vec::new(); keys.len()];
        for (i, key) in keys.iter().enumerate() {
            if resolved[i] {
                continue;
            }
            candidates[i] = self.version.candidates_for(key);
            for m in &candidates[i] {
                per_sst.entry(m.id).or_default().push(i);
            }
        }
        let mut bloom_pass: std::collections::HashSet<(SstId, usize)> = Default::default();
        for (sst, key_idxs) in &per_sst {
            let meta = self.version.find(*sst).expect("candidate SST exists");
            if meta.bloom.words().len() > crate::runtime::BLOOM_WORDS {
                // Filter too large for the AOT shape — treat as pass and
                // let the block search decide (native path would probe).
                for &i in key_idxs {
                    if meta.bloom.may_contain(fps_by_key[i]) {
                        bloom_pass.insert((*sst, i));
                    }
                }
                continue;
            }
            for chunk in key_idxs.chunks(crate::runtime::BLOOM_BATCH) {
                let fps: Vec<u32> = chunk.iter().map(|&i| fps_by_key[i]).collect();
                let hits = xla
                    .bloom_probe(&fps, meta.bloom.words(), meta.bloom.nbits(), meta.bloom.k())
                    .expect("bloom kernel");
                for (&i, hit) in chunk.iter().zip(hits) {
                    if hit {
                        bloom_pass.insert((*sst, i));
                    }
                }
            }
        }
        // 2½. Fused prefetch (`read_coalesce`): adjacent bloom-positive
        //     candidate blocks of one SST are read as one device request
        //     and installed in the block cache, so the per-key fetches
        //     below hit memory instead of issuing a random read each.
        if self.cfg.batch.read_coalesce {
            self.prefetch_fused_blocks(keys, &resolved, &per_sst, &bloom_pass);
        }
        // 3. Per-key block fetches for bloom-positive candidates, in the
        //    usual search order. Background work advanced by drain_until
        //    may compact candidates away between keys, so re-resolve the
        //    candidate list per key; SSTs created after the batch probe
        //    (unseen by the kernel) fall back to the native bloom.
        for (i, key) in keys.iter().enumerate() {
            if resolved[i] {
                continue;
            }
            self.metrics.reads_done += 1;
            let mut finish = self.now;
            for meta in self.version.candidates_for(key) {
                let passed = if per_sst.contains_key(&meta.id) {
                    bloom_pass.contains(&(meta.id, i))
                } else {
                    meta.bloom.may_contain(fps_by_key[i])
                };
                if !passed {
                    continue;
                }
                let Some(bi) = meta.find_block(key) else { continue };
                let handle = meta.blocks[bi];
                let (block, f) =
                    self.fetch_block(&meta, handle.offset, handle.len as u64, finish);
                finish = self.fg_charge(finish.max(f), CPU_BLOCK_SEARCH_NS);
                if let Some(e) = search_block(&block, key) {
                    out[i] = e.value;
                    break;
                }
            }
            self.drain_until(finish.max(self.now));
        }
        out
    }

    /// The `read_coalesce` half of the batched read path: for each SST
    /// with bloom-positive candidates, sort the distinct candidate block
    /// handles by offset, group runs whose inter-block gaps are within
    /// `coalesce_gap_bytes`, and charge every ≥2-member run as ONE fused
    /// sequential read of the whole span (gaps included in the transfer,
    /// conserved in the FUSE trace record). The member blocks are read
    /// untimed and installed in the block cache; single-block runs are
    /// left to [`Engine::fetch_block`]'s unfused path.
    fn prefetch_fused_blocks(
        &mut self,
        keys: &[Vec<u8>],
        resolved: &[bool],
        per_sst: &std::collections::HashMap<SstId, Vec<usize>>,
        bloom_pass: &std::collections::HashSet<(SstId, usize)>,
    ) {
        let gap_max = self.cfg.batch.coalesce_gap_bytes;
        let mut sst_ids: Vec<SstId> = per_sst.keys().copied().collect();
        sst_ids.sort_unstable();
        let mut ready = self.now;
        for sst in sst_ids {
            let Some(meta) = self.version.find(sst) else { continue };
            let Some(dev) = self.fs.file_dev(sst) else { continue };
            let mut handles: Vec<(u64, u64)> = Vec::new();
            for &i in &per_sst[&sst] {
                if resolved[i] || !bloom_pass.contains(&(sst, i)) {
                    continue;
                }
                if let Some(bi) = meta.find_block(&keys[i]) {
                    let h = meta.blocks[bi];
                    handles.push((h.offset, h.len as u64));
                }
            }
            handles.sort_unstable();
            handles.dedup();
            handles.retain(|&(off, _)| !self.cache.contains(&BlockKey { sst, offset: off }));
            // Group into gap-bounded runs of adjacent blocks.
            let mut runs: Vec<Vec<(u64, u64)>> = Vec::new();
            for h in handles {
                match runs.last_mut() {
                    Some(r)
                        if {
                            let (o, l) = *r.last().unwrap();
                            h.0 <= o + l + gap_max
                        } =>
                    {
                        r.push(h)
                    }
                    _ => runs.push(vec![h]),
                }
            }
            for run in runs {
                if run.len() < 2 {
                    continue;
                }
                let (first_off, _) = run[0];
                let (last_off, last_len) = *run.last().unwrap();
                let span = last_off + last_len - first_off;
                let member_bytes: u64 = run.iter().map(|&(_, l)| l).sum();
                let members = run.len() as u32;
                let (s, f) =
                    self.fs.charge_fused(self.now, dev, AccessKind::SeqRead, span, members);
                self.metrics.record_queue_wait(dev, s.saturating_sub(self.now));
                self.trace_io(dev, IoOp::BlockRead, None, Some(sst), span, s, self.now);
                self.metrics.record_read(dev, span);
                self.metrics.fused_reads += 1;
                self.metrics.fused_read_bytes += span;
                let (shard, bytes, gap_bytes, at) =
                    (self.cpu_shard, span, span - member_bytes, self.now);
                self.trace.emit(|| Event::ReadFuse {
                    dev,
                    shard,
                    members,
                    bytes,
                    member_bytes,
                    gap_bytes,
                    at,
                });
                self.metrics.record_sst_read(sst, meta.level, dev);
                self.policy.on_sst_read(sst, dev, self.now);
                ready = ready.max(f);
                for (off, len) in run {
                    let Ok(data) = self.fs.read_file_untimed(sst, off, len) else { continue };
                    let arc = Arc::new(data);
                    debug_assert!(arc.is_hydrated(), "cache admits hydrated copies only");
                    let evicted = self.cache.insert(BlockKey { sst, offset: off }, arc);
                    for ev in evicted {
                        self.handle_cache_eviction(ev.key.sst, ev.key.offset, ev.data);
                    }
                }
            }
        }
        // The per-key fetches start after the fused transfers land: cache
        // hits must not complete before the device read that filled them.
        self.drain_until(ready);
    }

    /// Bytes of SSTs currently on the SSD, per level (Fig 5(b)).
    pub fn ssd_share_by_level(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        for lvl in 0..self.version.num_levels() {
            let mut ssd = 0u64;
            let mut all = 0u64;
            for m in self.version.level(lvl) {
                all += m.file_size;
                if self.fs.file_dev(m.id) == Some(Dev::Ssd) {
                    ssd += m.file_size;
                }
            }
            out.push((ssd, all));
        }
        out
    }
}

#[cfg(test)]
mod sched_tests;
#[cfg(test)]
mod tests;
