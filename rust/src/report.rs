//! Plain-text table / CSV rendering for the experiment harness (no
//! external crates in this environment).

/// A simple column-aligned text table with a CSV twin.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &w));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let line = |cells: &[String]| -> String {
            cells.iter().map(|c| csv_escape(c)).collect::<Vec<_>>().join(",")
        };
        let mut out = String::new();
        out.push_str(&line(&self.headers));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    /// Print the table and optionally write the CSV beside it.
    pub fn emit(&self, csv_dir: Option<&str>, name: &str) {
        println!("{}", self.render());
        if let Some(dir) = csv_dir {
            let _ = std::fs::create_dir_all(dir);
            let path = format!("{dir}/{name}.csv");
            if std::fs::write(&path, self.to_csv()).is_ok() {
                println!("  [csv: {path}]");
            }
        }
    }
}

/// RFC 4180 cell quoting: cells containing a comma, double quote, CR, or
/// LF are wrapped in double quotes with embedded quotes doubled, so cells
/// can never silently shift columns in the CSV exports.
fn csv_escape(cell: &str) -> String {
    if cell.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Human format helpers.
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let bf = b as f64;
    if bf >= K * K * K {
        format!("{:.2}GiB", bf / K / K / K)
    } else if bf >= K * K {
        format!("{:.2}MiB", bf / K / K)
    } else if bf >= K {
        format!("{:.2}KiB", bf / K)
    } else {
        format!("{b}B")
    }
}

pub fn fmt_pct(f: f64) -> String {
    format!("{:.1}%", f * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["scheme", "ops"]);
        t.row(vec!["B3".into(), "9000".into()]);
        t.row(vec!["HHZS".into(), "12000".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("B3"));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    fn csv_roundtrips_cells() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn csv_escapes_commas_quotes_and_newlines() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1,5".into(), "plain".into()]);
        t.row(vec!["he said \"hi\"".into(), "two\nlines".into()]);
        assert_eq!(
            t.to_csv(),
            "a,b\n\"1,5\",plain\n\"he said \"\"hi\"\"\",\"two\nlines\"\n"
        );
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.00KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MiB");
        assert_eq!(fmt_pct(0.123), "12.3%");
    }
}
