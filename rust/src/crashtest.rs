//! The `hhzs crash` harness: deterministic crash & power-loss injection
//! cells over the DES, with recovery correctness pinned per cell.
//!
//! A **cell** is one (CrashPoint × trigger × seed × shard-count) run: a
//! scripted write workload drives a [`ShardedEngine`] with an armed
//! [`CrashInjector`] on shard 0, the injector fires (tearing the
//! in-flight zone append mid-record), the engine recovers from surviving
//! zones/WAL, and the cell then asserts the four recovery invariants:
//!
//! * **I1 — no acked write lost**: every key acked before the crash is
//!   readable with its last acked value.
//! * **I2 — no torn SST visible**: every SST in any recovered version is
//!   fully readable and decodes to exactly its manifest entry count
//!   (checked by [`Engine::verify_recovery_invariants`]).
//! * **I3 — write-pointer consistency**: every extent, WAL run, and
//!   cache block lies below its zone's write pointer, and no non-empty
//!   zone is unreferenced (same checker).
//! * **I4 — digest matches a crash-free reference**: the recovered
//!   key→value state equals the state a crash-free run would reach over
//!   the acked prefix — either all issued ops, or all-but-the-last when
//!   the crash tore the in-flight (never acked) record. Completeness is
//!   checked with a full scatter-gather scan so resurrected phantom
//!   entries are caught too, not just lost ones.
//!
//! An armed cell whose trigger never crosses validates the same
//! invariants over the intact store (and `tests/datapath.rs` pins that
//! an armed-but-unfired run stays bit-identical to golden digests).
//!
//! [`run_grid`] sweeps the full cell matrix; `--quick` is the CI shape
//! (≥ 100 cells, shard counts {1, 4}, and at least one cell per
//! [`CrashPoint`] variant whose fire left a mid-record torn zone
//! append on media).

use std::collections::{BTreeMap, HashSet};

use crate::config::{Config, WakePolicy};
use crate::exp::common::make_policy;
use crate::hints::Hint;
use crate::lsm::SstId;
use crate::policy::{MigrationKind, MigrationOp, Policy, SstOrigin, View};
use crate::shard::ShardedEngine;
use crate::sim::{CrashPoint, Ns};
use crate::wire::Payload;
use crate::ycsb::{key_for, value_for};
use crate::zone::Dev;

/// One grid cell: a crash point, its trigger (op count or virtual time —
/// exactly one is non-zero; both zero = armed but never crossing), the
/// injector seed, and the shard count of the run.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub point: CrashPoint,
    pub shards: usize,
    /// Fire once shard 0 has issued this many write ops (0 = no op
    /// trigger).
    pub at_op: u64,
    /// Fire at the first matching hook at or after this virtual time
    /// (0 = no time trigger).
    pub at_time: Ns,
    pub seed: u64,
    /// Wake-order policy of the shared CPU pool for this cell. The grid
    /// sweeps stall-aware cells too: the crash unwind must drop the
    /// victim's scheduler claims (risk, age, promotion) symmetrically
    /// with its CPU-slot release, or recovery would replay against a
    /// stale priority and the I1–I4 battery catches the divergence.
    pub wake: WakePolicy,
    /// Foreground CPU slots for this cell. The fg pool needs no crash
    /// unwind by construction (slot busy-clocks decay with virtual time;
    /// nothing is held across the power loss) — stall-aware cells run
    /// with it enabled to pin exactly that.
    pub fg_threads: usize,
}

/// The outcome of one cell.
#[derive(Clone, Debug)]
pub struct CellReport {
    pub cell: Cell,
    /// Did the injector fire?
    pub fired: bool,
    /// Surviving bytes of the torn in-flight append, when the fire left
    /// a mid-record torn zone write on media.
    pub torn: Option<u64>,
    /// Write ops issued before the cell stopped (the crash ends the
    /// scripted stream).
    pub ops_issued: u64,
    /// Invariant violations; empty = the cell passed.
    pub violations: Vec<String>,
    /// Physically resident zone bytes of the victim shard after
    /// recovery. Cells run with demand paging on (the production
    /// default), so the power loss tears an append while the at-rest
    /// blocks around it are dehydrated — this is the evidence.
    pub victim_phys_bytes: u64,
}

/// Whole-grid outcome.
#[derive(Clone, Debug, Default)]
pub struct GridSummary {
    pub cells: usize,
    pub fired: usize,
    pub torn: usize,
    pub failures: Vec<String>,
}

impl GridSummary {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Leveled placement (L0 → SSD, rest → HDD) with a scripted migration
/// stream: every SSD-resident SST is migrated to the HDD exactly once.
/// MidMigration cells use this instead of the HHZS heuristics so the
/// migration hook is exercised deterministically, without depending on
/// read-rate thresholds or virtual-time scan cadence.
#[derive(Default)]
struct MigratePolicy {
    picked: HashSet<SstId>,
}

impl Policy for MigratePolicy {
    fn name(&self) -> String {
        "crash-grid-migrate".into()
    }

    fn reserved_pool_zones(&self, cfg: &Config) -> u32 {
        cfg.geometry.wal_cache_zones
    }

    fn on_hint(&mut self, _: &Hint, _: &View) {}

    fn on_sst_read(&mut self, _: SstId, _: Dev, _: Ns) {}

    fn on_sst_deleted(&mut self, _: SstId) {}

    fn place_sst(&mut self, level: usize, _: u64, _: SstOrigin, _: &View) -> Dev {
        if level == 0 {
            Dev::Ssd
        } else {
            Dev::Hdd
        }
    }

    fn pick_migration(&mut self, view: &View) -> Option<MigrationOp> {
        for level in 0..view.version.num_levels() {
            for m in view.version.level(level) {
                if view.fs.file_dev(m.id) == Some(Dev::Ssd)
                    && !(view.busy_ssts)(m.id)
                    && self.picked.insert(m.id)
                {
                    return Some(MigrationOp {
                        sst: m.id,
                        to: Dev::Hdd,
                        kind: MigrationKind::Capacity,
                        swap_with: None,
                    });
                }
            }
        }
        None
    }
}

/// Scripted ops per cell, sized so the point's machinery (flushes,
/// compactions, migrations) is reliably in flight when the trigger
/// crosses, at both shard counts.
fn total_ops(point: CrashPoint) -> u64 {
    match point {
        CrashPoint::MidZoneAppend | CrashPoint::WalBeforeMemtable | CrashPoint::MidRecovery => 900,
        CrashPoint::MidFlush => 1_600,
        CrashPoint::MidCompaction => 4_000,
        CrashPoint::MidMigration => 2_400,
    }
}

/// The trigger arms swept per point: two op-count triggers (guaranteed
/// to cross) plus one virtual-time trigger. Times are sized to the
/// victim shard's clock at these workloads; a time arm that does not
/// cross still validates the armed-unfired invariants.
fn arms(point: CrashPoint) -> &'static [(u64, Ns)] {
    match point {
        CrashPoint::MidZoneAppend | CrashPoint::WalBeforeMemtable | CrashPoint::MidRecovery => {
            &[(40, 0), (160, 0), (0, 300_000)]
        }
        CrashPoint::MidFlush => &[(60, 0), (150, 0), (0, 800_000)],
        CrashPoint::MidCompaction => &[(200, 0), (500, 0), (0, 2_000_000)],
        CrashPoint::MidMigration => &[(80, 0), (200, 0), (0, 500_000)],
    }
}

/// Deterministic op `i` of a cell: key index (with a ~1-in-6 overwrite
/// of an earlier key, so torn-tail recovery must restore *prior* values,
/// not just drop keys) and a per-op value payload.
fn op_kv(i: u64, seed: u64) -> (Vec<u8>, Payload) {
    let idx = if i % 6 == 5 { i / 3 } else { i };
    let val = value_for(seed.wrapping_mul(1_000_003).wrapping_add(i), 1000);
    (key_for(idx, 24), val)
}

/// Key→value state a crash-free run reaches after ops `0..n`.
fn expect_map(n: u64, seed: u64) -> BTreeMap<Vec<u8>, Payload> {
    let mut m = BTreeMap::new();
    for i in 0..n {
        let (k, v) = op_kv(i, seed);
        m.insert(k, v);
    }
    m
}

/// Does the recovered store equal `want` exactly? Point lookups catch
/// lost or rewritten values; the scatter-gather scan count catches
/// resurrected phantoms.
fn state_matches(se: &mut ShardedEngine, want: &BTreeMap<Vec<u8>, Payload>) -> bool {
    if !want.iter().all(|(k, v)| se.get(k) == Some(*v)) {
        return false;
    }
    se.scan(b"", want.len() + 8) == want.len()
}

/// Run one cell end to end. Never panics on an invariant violation —
/// failures are reported in [`CellReport::violations`] so the grid can
/// sweep every cell and report them all.
pub fn run_cell(cell: &Cell) -> CellReport {
    run_cell_traced(cell, false).0
}

/// [`run_cell`] with the shared trace ring on: also returns the
/// Perfetto/JSON export, carrying the `CRASH`/`RECOV`/`ZTRUNC` events,
/// for `hhzs trace check` (CI pipes a traced crash run through it to
/// validate span unwinding across the power loss).
pub fn run_cell_traced(cell: &Cell, trace: bool) -> (CellReport, Option<String>) {
    run_cell_opts(cell, trace, true)
}

/// Cell runner with the demand-paging knob explicit. The grid always
/// runs paged (power loss over dehydrated at-rest blocks is the default
/// reality); the unpaged variant exists so tests can pin that paging is
/// crash-transparent — same fire, same torn byte, same violations.
fn run_cell_opts(cell: &Cell, trace: bool, paging: bool) -> (CellReport, Option<String>) {
    let mut cfg = Config::paper_scaled(2048);
    cfg.trace.enabled = trace;
    cfg.residency.paging = paging;
    cfg.workload.load_objects = 0;
    cfg.shards = cell.shards;
    cfg.lsm.wake = cell.wake;
    cfg.lsm.fg_threads = cell.fg_threads;
    cfg.crash.enabled = true;
    cfg.crash.point = cell.point.name().to_string();
    cfg.crash.at_op = cell.at_op;
    cfg.crash.at_time_ns = cell.at_time;
    cfg.crash.seed = cell.seed;
    cfg.crash.shard = 0;
    let forced_migration = cell.point == CrashPoint::MidMigration;
    let mut se = ShardedEngine::new(&cfg, |c| {
        if forced_migration {
            Box::new(MigratePolicy::default())
        } else {
            make_policy("HHZS", c)
        }
    });

    let mut issued = 0u64;
    for i in 0..total_ops(cell.point) {
        if se.engines[0].crash_fired() {
            break;
        }
        let (k, v) = op_kv(i, cell.seed);
        se.put_payload(&k, v);
        issued = i + 1;
    }
    if forced_migration && !se.engines[0].crash_fired() {
        // The scripted migrations drain here; the hook fires mid-step.
        se.quiesce();
    }
    let fired = se.engines[0].crash_fired();
    let torn = se.engines[0].crash_injector().and_then(|i| i.torn);

    let mut violations = Vec::new();
    // I1 + I4: the recovered state must equal the crash-free reference
    // over the acked prefix. The in-flight op (the put the crash
    // interrupted) may or may not have reached durability, so a fired
    // cell accepts either reference; an unfired cell must match all
    // issued ops exactly.
    let full = expect_map(issued, cell.seed);
    let mut ok = state_matches(&mut se, &full);
    if !ok && fired && issued > 0 {
        ok = state_matches(&mut se, &expect_map(issued - 1, cell.seed));
    }
    if !ok {
        violations.push(
            "I1/I4: recovered state matches neither the acked prefix nor \
             acked-plus-in-flight reference"
                .to_string(),
        );
    }
    // I2 + I3 on every engine (non-victim shards must be untouched).
    for (s, e) in se.engines.iter_mut().enumerate() {
        violations.extend(
            e.verify_recovery_invariants().into_iter().map(|v| format!("shard {s}: {v}")),
        );
    }
    let victim_phys_bytes = se.engines[0].fs.phys_bytes();
    let export = trace.then(|| se.export_trace_string());
    (
        CellReport { cell: *cell, fired, torn, ops_issued: issued, violations, victim_phys_bytes },
        export,
    )
}

/// The cell matrix: shard counts {1, 4} × all six points × the point's
/// trigger arms × seeds, under the FIFO wake policy — plus stall-aware
/// cells (mid_flush and mid_compaction × both shard counts, with the
/// contended foreground pool on) pinning that the crash unwind of the
/// scheduler state is symmetric with the slot unwind. Quick mode (CI)
/// runs 3 seeds — 108 FIFO + 12 stall-aware = 120 cells.
pub fn grid_cells(quick: bool) -> Vec<Cell> {
    let seeds: &[u64] = if quick { &[1, 2, 3] } else { &[1, 2, 3, 4, 5, 6] };
    let mut cells = Vec::new();
    for &shards in &[1usize, 4] {
        for point in CrashPoint::ALL {
            for &(at_op, at_time) in arms(point) {
                for &seed in seeds {
                    cells.push(Cell {
                        point,
                        shards,
                        at_op,
                        at_time,
                        seed,
                        wake: WakePolicy::Fifo,
                        fg_threads: 0,
                    });
                }
            }
        }
    }
    for &shards in &[1usize, 4] {
        for point in [CrashPoint::MidFlush, CrashPoint::MidCompaction] {
            // The first arm is the op trigger that reliably crosses
            // mid-job — the interesting unwind for scheduler state.
            let (at_op, at_time) = arms(point)[0];
            for &seed in seeds {
                cells.push(Cell {
                    point,
                    shards,
                    at_op,
                    at_time,
                    seed,
                    wake: WakePolicy::StallAware,
                    fg_threads: 2,
                });
            }
        }
    }
    cells
}

/// Sweep the grid; `progress` receives one line per cell. The grid
/// fails if any cell reports a violation, or if any [`CrashPoint`]
/// variant never produced a fired cell with a mid-record torn zone
/// append (the whole point of power-loss injection).
pub fn run_grid(quick: bool, mut progress: impl FnMut(&str)) -> GridSummary {
    let cells = grid_cells(quick);
    let mut sum = GridSummary { cells: cells.len(), ..GridSummary::default() };
    let mut torn_by_point: BTreeMap<&'static str, usize> = BTreeMap::new();
    for (n, cell) in cells.iter().enumerate() {
        let r = run_cell(cell);
        let sched = match cell.wake {
            WakePolicy::Fifo => String::new(),
            WakePolicy::StallAware => {
                format!(" wake=stall_aware fg_threads={}", cell.fg_threads)
            }
        };
        let label = format!(
            "[{:>3}/{}] {} shards={} at_op={} at_time={} seed={}{sched}",
            n + 1,
            cells.len(),
            cell.point.name(),
            cell.shards,
            cell.at_op,
            cell.at_time,
            cell.seed
        );
        sum.fired += usize::from(r.fired);
        if r.torn.is_some() {
            sum.torn += 1;
            *torn_by_point.entry(cell.point.name()).or_insert(0) += 1;
        }
        if r.violations.is_empty() {
            let state = match (r.fired, r.torn) {
                (true, Some(t)) => format!("fired, torn@{t}B — ok"),
                (true, None) => "fired — ok".to_string(),
                (false, _) => "armed-unfired — ok".to_string(),
            };
            progress(&format!("{label}: {state}"));
        } else {
            for v in &r.violations {
                sum.failures.push(format!("{label}: {v}"));
            }
            progress(&format!("{label}: FAILED ({} violations)", r.violations.len()));
        }
    }
    for point in CrashPoint::ALL {
        if torn_by_point.get(point.name()).copied().unwrap_or(0) == 0 {
            sum.failures.push(format!(
                "coverage: no {} cell left a mid-record torn zone append",
                point.name()
            ));
        }
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One op-triggered cell per point at one shard: fires, recovers,
    /// and upholds all four invariants. Aggregated over the points,
    /// every variant must tear at least one mid-record zone append —
    /// the same coverage bar the CI grid enforces.
    #[test]
    fn every_point_fires_and_recovers_clean() {
        let mut torn_points = 0;
        for point in CrashPoint::ALL {
            let (at_op, at_time) = arms(point)[0];
            let cell = Cell {
                point,
                shards: 1,
                at_op,
                at_time,
                seed: 1,
                wake: WakePolicy::Fifo,
                fg_threads: 0,
            };
            let r = run_cell(&cell);
            assert!(r.fired, "{} cell never fired", point.name());
            assert!(
                r.violations.is_empty(),
                "{} cell violations: {:?}",
                point.name(),
                r.violations
            );
            torn_points += usize::from(r.torn.is_some());
        }
        assert!(
            torn_points >= 4,
            "most points should tear a mid-record append (got {torn_points}/6)"
        );
    }

    /// A fired cell at 4 shards: the victim recovers, the other three
    /// shards' stores stay untouched, and routed reads see one
    /// consistent keyspace.
    #[test]
    fn sharded_cell_recovers_with_nonvictim_shards_intact() {
        let cell = Cell {
            point: CrashPoint::MidZoneAppend,
            shards: 4,
            at_op: 40,
            at_time: 0,
            seed: 2,
            wake: WakePolicy::Fifo,
            fg_threads: 0,
        };
        let r = run_cell(&cell);
        assert!(r.fired, "victim shard never fired");
        assert!(r.torn.is_some(), "WAL tail should be torn mid-record");
        assert!(r.violations.is_empty(), "violations: {:?}", r.violations);
    }

    /// An armed injector whose trigger never crosses must leave a fully
    /// intact store that passes the same invariant battery.
    #[test]
    fn armed_unfired_cell_validates_intact_store() {
        let cell = Cell {
            point: CrashPoint::MidFlush,
            shards: 1,
            at_op: u64::MAX,
            at_time: 0,
            seed: 3,
            wake: WakePolicy::Fifo,
            fg_threads: 0,
        };
        let r = run_cell(&cell);
        assert!(!r.fired);
        assert_eq!(r.torn, None);
        assert!(r.violations.is_empty(), "violations: {:?}", r.violations);
    }

    /// Power loss while the victim's at-rest blocks are dehydrated: the
    /// cell fires, tears, recovers clean — and an identical cell with
    /// paging off reaches the same fire/torn/violation outcome, pinning
    /// that demand paging is crash-transparent. The paged victim holds
    /// strictly fewer resident bytes than the unpaged one, the evidence
    /// that dehydration was live through the power loss.
    #[test]
    fn power_loss_over_dehydrated_blocks_recovers_and_matches_unpaged() {
        for point in [CrashPoint::MidZoneAppend, CrashPoint::MidFlush, CrashPoint::MidCompaction]
        {
            let (at_op, at_time) = arms(point)[0];
            let cell = Cell {
                point,
                shards: 4,
                at_op,
                at_time,
                seed: 5,
                wake: WakePolicy::Fifo,
                fg_threads: 0,
            };
            let (paged, _) = run_cell_opts(&cell, false, true);
            assert!(paged.fired, "{} paged cell never fired", point.name());
            assert!(
                paged.violations.is_empty(),
                "{} paged cell violations: {:?}",
                point.name(),
                paged.violations
            );
            let (unpaged, _) = run_cell_opts(&cell, false, false);
            assert_eq!(paged.fired, unpaged.fired, "{}", point.name());
            assert_eq!(paged.torn, unpaged.torn, "{}: torn byte differs", point.name());
            assert_eq!(paged.ops_issued, unpaged.ops_issued, "{}", point.name());
            assert!(
                unpaged.violations.is_empty(),
                "{} unpaged cell violations: {:?}",
                point.name(),
                unpaged.violations
            );
            assert!(
                paged.victim_phys_bytes < unpaged.victim_phys_bytes,
                "{}: victim must be dehydrated through the crash \
                 (paged {} >= unpaged {} resident bytes)",
                point.name(),
                paged.victim_phys_bytes,
                unpaged.victim_phys_bytes
            );
        }
    }

    #[test]
    fn quick_grid_matrix_has_ci_coverage() {
        let cells = grid_cells(true);
        assert!(cells.len() >= 100, "quick grid too small: {}", cells.len());
        assert!(cells.iter().any(|c| c.shards == 1) && cells.iter().any(|c| c.shards == 4));
        for point in CrashPoint::ALL {
            assert!(
                cells.iter().any(|c| c.point == point && c.at_op > 0 && c.at_op < 1_000),
                "{} needs a crossing op-trigger cell",
                point.name()
            );
        }
        // Stall-aware scheduler-unwind coverage: mid-job points at both
        // shard counts, with the contended foreground pool on.
        for point in [CrashPoint::MidFlush, CrashPoint::MidCompaction] {
            for &shards in &[1usize, 4] {
                assert!(
                    cells.iter().any(|c| c.point == point
                        && c.shards == shards
                        && c.wake == WakePolicy::StallAware
                        && c.fg_threads > 0
                        && c.at_op > 0),
                    "{} needs a stall_aware cell at {shards} shard(s)",
                    point.name()
                );
            }
        }
    }

    /// A stall-aware cell with the foreground pool on: fires mid-job,
    /// recovers, and upholds I1–I4 — the crash unwind of the scheduler
    /// claims (risk/age/promotion) is symmetric with the slot unwind,
    /// and the fg pool needs none (busy-clocks decay with virtual time).
    #[test]
    fn stall_aware_cell_fires_and_recovers_clean() {
        let (at_op, at_time) = arms(CrashPoint::MidFlush)[0];
        let cell = Cell {
            point: CrashPoint::MidFlush,
            shards: 4,
            at_op,
            at_time,
            seed: 1,
            wake: WakePolicy::StallAware,
            fg_threads: 2,
        };
        let r = run_cell(&cell);
        assert!(r.fired, "stall-aware cell never fired");
        assert!(r.violations.is_empty(), "violations: {:?}", r.violations);
    }
}
