//! Sharded multi-engine subsystem: stripes the LSM key space over `N`
//! independent engines sharing the hybrid SSD/HDD zoned substrate.
//!
//! The seed system is one LSM engine over one coordinator; production
//! traffic needs the key space partitioned (KeystoneDB stripes 256 ways
//! for the same reason). This module adds exactly that, without touching
//! the engine's own semantics:
//!
//! * [`router`] — deterministic hash routing: every client op is owned by
//!   exactly one shard;
//! * [`lease`] — the substrate lease layer: zone quotas, per-shard
//!   WAL/cache pool reservations, strided file-id namespaces, and memory
//!   budget slices that make `N` engines safe on the shared substrate;
//! * [`arbiter`] — splits the paper's global migration-rate budget
//!   (§3.4) across shards proportionally to their storage demand;
//! * [`frontend`] — the async request frontend: ONE global event loop
//!   (single virtual clock, globally ordered event heap) that owns the
//!   closed-loop clients, routes each op to its home shard, and drives
//!   every engine's background jobs interleaved in timestamp order;
//! * [`ShardedEngine`] — owns the engines, routes synchronous ops, drives
//!   workload phases through the frontend, and merges per-shard metrics
//!   into one report.
//!
//! All shards charge their I/O against ONE [`crate::sim::SharedTimer`]
//! per physical device — the paper's single SSD/HDD pair — so cross-shard
//! device-queue contention shows up in every latency (Exp#6's
//! interference, now across engines), and draw background-CPU slots from
//! ONE [`crate::sim::CpuPool`] of `bg_threads` threads, so flush and
//! compaction contend for host CPU across shards too (the time a ready
//! job waits for a slot is `Metrics::cpu_wait`). Scans scatter-gather
//! over all shards; throttling is global pacing in the frontend.
//!
//! `shards = 1` is bit-for-bit the seed single-engine system: the lease
//! is the identity, the router maps everything to shard 0, the arbiter
//! returns the untouched budget, the CPU pool is the engine's own
//! `busy_threads` arithmetic, and the frontend *is* the engine's own
//! workload loop. Tests pin this.

pub mod arbiter;
mod frontend;
pub mod lease;
pub mod router;

pub use arbiter::MigrationArbiter;
pub(crate) use frontend::merge_gather;
pub(crate) use frontend::Frontend;
pub use lease::{carve, ShardLease};
pub use router::Router;

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use crate::config::{Config, WakePolicy};
use crate::coordinator::{Engine, OpSource};
use crate::metrics::Metrics;
use crate::policy::Policy;
use crate::sim::cpu::{CpuPool, CpuPoolStats};
use crate::sim::Ns;
use crate::trace::{Event, TraceSink};

/// Consecutive drive rounds with an unchanged progress signature before
/// the settle loops declare a stall. Legitimate long waits (deep device
/// queues, paced migration) move bytes every few events, resetting the
/// count; only a genuine scheduling bug (e.g. a leaked CPU slot) leaves
/// the signature frozen while PolicyTicks spin.
const STALL_ROUNDS: u32 = 100_000;

/// `N` engines + a router over the shared substrate.
pub struct ShardedEngine {
    pub engines: Vec<Engine>,
    pub router: Router,
    /// The global §3.4 budget the arbiter re-splits.
    total_migration_rate_bps: f64,
    /// The shared event-sequence counter of the frontend's clock domain.
    event_seq: Rc<Cell<u64>>,
    /// The shared background-CPU pool every shard draws slots from.
    cpu: Rc<RefCell<CpuPool>>,
}

impl ShardedEngine {
    /// Build `cfg.shards` engines from substrate leases. `policy_fn`
    /// constructs each shard's placement policy from its leased config
    /// (shards keep independent policy state — their own demand trackers
    /// and read-rate maps — exactly like independent stores).
    pub fn new(cfg: &Config, mut policy_fn: impl FnMut(&Config) -> Box<dyn Policy>) -> Self {
        let leases = carve(cfg);
        let router = Router::new(leases.len());
        let mut engines: Vec<Engine> = leases
            .into_iter()
            .map(|l| {
                let policy = policy_fn(&l.cfg);
                let mut e = Engine::new(l.cfg, policy);
                e.set_file_id_namespace(l.file_id_base, l.file_id_stride);
                e
            })
            .collect();
        // One physical device pair, one clock domain, ONE background
        // thread pool, and ONE interned-key arena for the whole system:
        // every shard's zoned devices charge the SAME per-device FIFO
        // server, all engines draw event sequence numbers from shard 0's
        // counter, all engines take flush/compaction slots from shard 0's
        // CPU pool — `bg_threads` is a global budget, not a per-shard one
        // (a 4-shard run used to simulate 4 × 12 phantom threads) — and
        // all engines intern keys into shard 0's arena, so the router and
        // every shard hash/compare the same shared key bytes and a unique
        // key costs its bytes once across the domain. Likewise ONE
        // residency manager: every shard's zones page through shard 0's,
        // so the paging knob and dehydrate/rehydrate counters are
        // domain-global. With one shard all five are the identity.
        let event_seq = engines[0].event_seq_handle();
        let ssd_timer = engines[0].fs.ssd.timer.clone();
        let hdd_timer = engines[0].fs.hdd.timer.clone();
        let cpu = engines[0].cpu_pool_handle();
        let fg = engines[0].fg_pool_handle();
        let arena = engines[0].key_arena_handle();
        let trace = engines[0].trace_handle();
        let residency = engines[0].residency_handle();
        let gc = engines[0].group_committer_handle();
        cpu.borrow_mut().configure(engines.len(), cfg.lsm.cpu_sched, cfg.lsm.wake);
        for (s, e) in engines.iter_mut().enumerate().skip(1) {
            e.fs.ssd.set_timer(ssd_timer.clone());
            e.fs.hdd.set_timer(hdd_timer.clone());
            e.share_event_seq(event_seq.clone());
            e.share_cpu_pool(cpu.clone(), s);
            e.share_fg_pool(fg.clone());
            e.share_key_arena(arena.clone());
            e.share_residency(residency.clone());
            // ONE group-commit ledger: WAL records staged by any shard
            // fuse into the same per-device commit windows.
            e.share_group_committer(gc.clone());
            // ONE trace ring for the domain: rebinding AFTER the timer
            // swap re-tags the shared per-device FIFOs, and events from
            // every shard land in the shared buffer in emission order.
            e.share_trace(trace.clone(), s);
        }
        // Exactly ONE victim per crash-injected run: every engine armed
        // itself from its lease's (cloned) `[crash]` section — disarm all
        // but the configured victim shard.
        if cfg.crash.enabled {
            let victim = cfg.crash.shard.min(engines.len() - 1);
            for (s, e) in engines.iter_mut().enumerate() {
                if s != victim {
                    e.disarm_crash();
                }
            }
        }
        ShardedEngine {
            engines,
            router,
            total_migration_rate_bps: cfg.hhzs.migration_rate_bps,
            event_seq,
            cpu,
        }
    }

    /// Snapshot of the shared CPU pool's bookkeeping (slot bound, high
    /// water, conservation counters) — what `tests/cpu_pool.rs` pins.
    pub fn cpu_pool_stats(&self) -> CpuPoolStats {
        self.cpu.borrow().stats()
    }

    pub fn num_shards(&self) -> usize {
        self.engines.len()
    }

    // ------------------------------------------------------------------
    // Workload mode
    // ------------------------------------------------------------------

    /// Drive one workload phase through the async frontend: `clients`
    /// closed-loop clients pull from ONE shared stream, every op routes to
    /// its home shard, and all engines' background jobs interleave on the
    /// shared clock.
    ///
    /// `make_source` is called once, with shard 0, and must yield the
    /// *global* stream — `ycsb::RoutedSource` is a transparent view of it
    /// (the frontend routes; source-side filtering would drop ops). The
    /// closure signature is kept so PR 1 callers compile unchanged.
    ///
    /// `target_ops_per_sec` is global pacing in the frontend: one paced
    /// client pool over the whole system (hot shards under Zipf draw more
    /// of the budget than cold ones), not the old even `t / n` split.
    pub fn run(
        &mut self,
        mut make_source: impl FnMut(usize) -> Box<dyn OpSource>,
        clients: usize,
        target_ops_per_sec: Option<f64>,
        sample_levels: bool,
    ) {
        let mut src = make_source(0);
        self.run_shared(&mut *src, clients, target_ops_per_sec, sample_levels);
    }

    /// [`ShardedEngine::run`] with the shared stream passed directly.
    pub fn run_shared(
        &mut self,
        source: &mut dyn OpSource,
        clients: usize,
        target_ops_per_sec: Option<f64>,
        sample_levels: bool,
    ) {
        Frontend::new(&mut self.engines, self.router, self.event_seq.clone(), source).run(
            clients,
            target_ops_per_sec,
            sample_levels,
        );
    }

    /// Flush every shard's MemTables (the between-phases reopen of §4.1).
    ///
    /// With the shared CPU pool one shard's flush can wait on slots held
    /// by another shard's jobs, so this drives *global* progress: each
    /// round lets every engine flush as far as it can, then steps the
    /// globally earliest pending event to free slots, until every shard
    /// settles. With one shard this is exactly `Engine::flush_all`.
    pub fn flush_all(&mut self) {
        self.settle("flush_all", Engine::flush_all, Engine::flush_settled);
    }

    /// Let all shards' background work settle (cross-shard CPU handoffs
    /// included, like [`ShardedEngine::flush_all`]).
    pub fn quiesce(&mut self) {
        self.settle("quiesce", Engine::quiesce, Engine::background_settled);
    }

    /// Drive every engine with `drive` until all satisfy `settled`,
    /// stepping the globally earliest event between rounds so cross-shard
    /// CPU handoffs happen. Stall detection cannot use heap emptiness —
    /// every engine re-arms an eternal PolicyTick — so it watches the
    /// [`ShardedEngine::progress_sig`] observables instead: if nothing
    /// observable changes across many rounds while shards stay unsettled
    /// (e.g. a leaked CPU slot), this panics loudly instead of spinning
    /// on self-perpetuating ticks forever.
    fn settle(
        &mut self,
        what: &str,
        mut drive: impl FnMut(&mut Engine),
        settled: impl Fn(&Engine) -> bool,
    ) {
        let mut last_sig = None;
        let mut idle_rounds = 0u32;
        loop {
            for e in &mut self.engines {
                drive(e);
            }
            self.poll_cpu_wakes();
            if self.engines.iter().all(|e| settled(e)) {
                break;
            }
            idle_rounds = self.bump_idle_rounds(&mut last_sig, idle_rounds);
            assert!(
                idle_rounds < STALL_ROUNDS,
                "{what} stalled: shards unsettled with no observable background progress"
            );
            if !self.step_earliest() {
                panic!("{what} stalled: pending work but no events anywhere");
            }
        }
    }

    /// Everything background progress must move: the pool's ledger and
    /// each engine's cumulative I/O / job counters (metrics are not reset
    /// outside measured phases, so between phases these are monotone).
    fn progress_sig(&self) -> (u64, u64, Vec<(u64, u64, u64, u64, u64)>) {
        let st = self.cpu.borrow().stats();
        let per = self
            .engines
            .iter()
            .map(|e| {
                let m = &e.metrics;
                let w: u64 = m.write_traffic.values().map(|c| c.bytes).sum();
                let r: u64 = m.read_traffic.values().map(|c| c.bytes).sum();
                (w, r, m.migration_bytes, m.flushes, m.compactions)
            })
            .collect();
        (st.acquires, st.releases, per)
    }

    /// One round of stall accounting: returns the updated idle-round
    /// count (0 whenever the progress signature moved).
    fn bump_idle_rounds(
        &self,
        last_sig: &mut Option<(u64, u64, Vec<(u64, u64, u64, u64, u64)>)>,
        idle_rounds: u32,
    ) -> u32 {
        let sig = self.progress_sig();
        if last_sig.as_ref() == Some(&sig) {
            idle_rounds + 1
        } else {
            *last_sig = Some(sig);
            0
        }
    }

    /// Process the globally earliest pending engine event (sync-mode
    /// analogue of the frontend's merged pop; engines keep their own
    /// clocks here). Returns false when no engine has events.
    fn step_earliest(&mut self) -> bool {
        let mut best: Option<(Ns, u64, usize)> = None;
        for (s, e) in self.engines.iter().enumerate() {
            if let Some((at, seq)) = e.next_event_at() {
                if best.map_or(true, |(ba, bs, _)| (at, seq) < (ba, bs)) {
                    best = Some((at, seq, s));
                }
            }
        }
        let Some((_, _, s)) = best else { return false };
        // Client readiness events are frontend-mode only; ignore the id.
        let _ = self.engines[s].step_event();
        self.poll_cpu_wakes();
        true
    }

    /// Re-poll shards whose background work was starved of a CPU slot
    /// another shard just released (sync-mode wake; the frontend does the
    /// same inside its event loop on the shared clock).
    fn poll_cpu_wakes(&mut self) {
        if !self.cpu.borrow().wake_pending() {
            return;
        }
        let list = self.cpu.borrow_mut().take_wake_list();
        if !list.is_empty() {
            // Sync mode has no shared clock; WAKE ordering is what the
            // checker replays, so `at = 0` is fine here.
            trace_wake_round(&self.engines[0].trace, &self.cpu.borrow(), 0);
        }
        for s in list {
            // Sync mode: each engine stays on its local clock.
            self.engines[s].poll_cpu(0);
        }
    }

    /// Re-split the global migration budget (§3.4) across shards in
    /// proportion to their live SST bytes; returns the per-shard rates.
    /// Call between phases (migration pacing reads the config live).
    pub fn rebalance_migration_budgets(&mut self) -> Vec<f64> {
        let demands: Vec<u64> =
            self.engines.iter().map(|e| e.fs.total_file_bytes()).collect();
        let rates = MigrationArbiter::new(self.total_migration_rate_bps).split(&demands);
        for (e, r) in self.engines.iter_mut().zip(&rates) {
            e.cfg.hhzs.migration_rate_bps = *r;
        }
        rates
    }

    // ------------------------------------------------------------------
    // Merged reporting
    // ------------------------------------------------------------------

    /// One metrics record for the whole system: histograms merged
    /// bucket-wise, counters and traffic cells summed.
    pub fn merged_metrics(&self) -> Metrics {
        let mut m = self.engines[0].metrics.clone();
        for e in &self.engines[1..] {
            m.merge(&e.metrics);
        }
        m
    }

    /// Aggregate throughput of the last phase: total ops over the shared
    /// virtual window. All shards run on one frontend clock, so their
    /// phase windows coincide and the max below is that common window.
    pub fn aggregate_ops_per_sec(&self) -> f64 {
        let total_ops: u64 = self.engines.iter().map(|e| e.metrics.ops_done).sum();
        let max_dur: Ns = self
            .engines
            .iter()
            .map(|e| e.metrics.finished_at.saturating_sub(e.metrics.start_ns))
            .max()
            .unwrap_or(0);
        if max_dur == 0 {
            0.0
        } else {
            total_ops as f64 / (max_dur as f64 / 1e9)
        }
    }

    /// Ops executed per shard in the last phase (load-balance reporting).
    pub fn ops_per_shard(&self) -> Vec<u64> {
        self.engines.iter().map(|e| e.metrics.ops_done).collect()
    }

    /// Per-shard metrics snapshots of the last phase (Exp#7 breakdown).
    pub fn per_shard_metrics(&self) -> Vec<Metrics> {
        self.engines.iter().map(|e| e.metrics.clone()).collect()
    }

    // ------------------------------------------------------------------
    // Trace export
    // ------------------------------------------------------------------

    /// Is the shared trace ring live?
    pub fn trace_enabled(&self) -> bool {
        self.engines[0].trace.is_enabled()
    }

    /// Serialize the domain's shared trace ring: every shard emits its
    /// closing metrics snapshot (the record the checker sums each shard's
    /// final segment against), then the one ring is exported with the
    /// domain's shard count and CPU-slot total.
    pub fn export_trace_string(&self) -> String {
        for e in &self.engines {
            e.trace_snapshot();
        }
        let bg = self.engines[0].cfg.lsm.bg_threads;
        let fg = self.engines[0].cfg.lsm.fg_threads;
        self.engines[0].trace.export_string(self.engines.len(), bg, fg)
    }

    /// Write the trace export to `path` (Perfetto-loadable JSON).
    pub fn export_trace(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.export_trace_string())
    }

    // ------------------------------------------------------------------
    // Synchronous DB-style API (routed)
    // ------------------------------------------------------------------

    /// Drive other shards' events until writes on shard `s` unblock — a
    /// blocked write may be waiting on a flush whose CPU slot is held by
    /// another shard's job (the engine's own loop can only drain its local
    /// events). Same progress-based stall guard as [`ShardedEngine::settle`]
    /// (heap emptiness can never signal a stall: PolicyTicks are eternal).
    fn unblock_writes(&mut self, s: usize) {
        let mut last_sig = None;
        let mut idle_rounds = 0u32;
        while self.engines[s].write_blocked() {
            idle_rounds = self.bump_idle_rounds(&mut last_sig, idle_rounds);
            assert!(
                idle_rounds < STALL_ROUNDS,
                "shard {s}: writes blocked with no observable background progress"
            );
            if !self.step_earliest() {
                // No events anywhere: let the engine's own loop surface
                // the (pre-existing) "background progress" diagnostic.
                break;
            }
        }
    }

    pub fn put(&mut self, key: &[u8], value: &[u8]) {
        let s = self.router.route(key);
        self.unblock_writes(s);
        self.engines[s].put(key, value);
    }

    pub fn put_payload(&mut self, key: &[u8], value: crate::wire::Payload) {
        let s = self.router.route(key);
        self.unblock_writes(s);
        self.engines[s].put_payload(key, value);
    }

    pub fn delete(&mut self, key: &[u8]) {
        let s = self.router.route(key);
        self.unblock_writes(s);
        self.engines[s].delete(key);
    }

    pub fn get(&mut self, key: &[u8]) -> Option<crate::wire::Payload> {
        let s = self.router.route(key);
        self.engines[s].get(key)
    }

    /// Scatter-gather scan: hash partitioning scatters ranges over every
    /// shard, so the range fans out to all of them and the sorted partial
    /// results k-way merge (shards hold disjoint key sets). Returns the
    /// number of distinct live entries gathered, exactly what a single
    /// engine holding the union of the data would return. The op counts
    /// once (home shard) in merged metrics. Note: in this DB-style sync
    /// mode each engine charges the shared device FIFO at its own local
    /// clock (workload mode runs all shards on one frontend clock), so
    /// per-shard timing here includes cross-clock skew — use the frontend
    /// (`run`/`run_shared`) for contention measurements.
    pub fn scan(&mut self, start: &[u8], n: usize) -> usize {
        if self.engines.len() == 1 {
            return self.engines[0].scan(start, n);
        }
        let home = self.router.route(start);
        let parts: Vec<_> = self
            .engines
            .iter_mut()
            .enumerate()
            .map(|(s, e)| e.scan_collect(start, n, s == home))
            .collect();
        merge_gather(parts, n).len()
    }
}

/// Emit one `WAKE` record per waiter of the stall-aware round the pool
/// just computed (rank = offer order), so `hhzs trace check` can replay
/// the scheduler's exact decision. Under FIFO the pool leaves
/// [`CpuPool::last_wake`] empty and nothing is emitted — FIFO traces stay
/// byte-identical to the committed goldens. Call only after a non-empty
/// `take_wake_list` (the pool skips round bookkeeping on empty rounds).
pub(crate) fn trace_wake_round(trace: &TraceSink, cpu: &CpuPool, at: Ns) {
    if cpu.wake_policy() != WakePolicy::StallAware || !trace.is_enabled() {
        return;
    }
    let (round, slots) = cpu.last_wake();
    for (rank, w) in slots.iter().enumerate() {
        trace.emit(|| Event::SchedWake {
            shard: w.shard,
            flush: w.flush,
            risk: w.risk,
            age: w.age,
            rank,
            round,
            at,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::HhzsPolicy;
    use crate::wire::Payload;
    use crate::ycsb::{key_for, value_for};

    fn sharded(n: usize) -> ShardedEngine {
        let mut cfg = Config::tiny();
        cfg.shards = n;
        ShardedEngine::new(&cfg, |c| Box::new(HhzsPolicy::new(c.lsm.num_levels)))
    }

    #[test]
    fn routed_put_get_roundtrip() {
        let mut se = sharded(4);
        for i in 0..2_000u64 {
            se.put_payload(&key_for(i, 24), value_for(i, 100));
        }
        se.quiesce();
        for i in (0..2_000u64).step_by(31) {
            assert_eq!(se.get(&key_for(i, 24)), Some(value_for(i, 100)), "key {i}");
        }
        assert_eq!(se.get(b"never-written"), None);
        // Overwrite + delete stay on the owning shard.
        let k = key_for(7, 24);
        se.put(&k, b"fresh");
        assert_eq!(se.get(&k), Some(Payload::from_bytes(b"fresh")));
        se.delete(&k);
        assert_eq!(se.get(&k), None);
    }

    #[test]
    fn data_lands_on_multiple_shards_with_disjoint_file_ids() {
        let mut se = sharded(4);
        for i in 0..8_000u64 {
            se.put_payload(&key_for(i, 24), value_for(i, 500));
        }
        se.quiesce();
        let mut seen = std::collections::HashSet::new();
        let mut shards_with_files = 0;
        for (s, e) in se.engines.iter().enumerate() {
            let mut any = false;
            for f in e.fs.files() {
                assert!(seen.insert(f.id), "file id {} on two shards", f.id);
                // Strided namespace: id ≡ shard + 1 (mod N).
                assert_eq!((f.id - 1) % 4, s as u64, "file {} outside its lease", f.id);
                any = true;
            }
            shards_with_files += usize::from(any);
        }
        assert!(shards_with_files >= 3, "hash routing should hit most shards");
    }

    #[test]
    fn merged_metrics_sum_per_shard_ops() {
        let mut se = sharded(2);
        for i in 0..500u64 {
            se.put_payload(&key_for(i, 24), value_for(i, 64));
        }
        let per: u64 = se.engines.iter().map(|e| e.metrics.writes_done).sum();
        assert_eq!(per, 500);
        assert_eq!(se.merged_metrics().writes_done, 500);
    }

    #[test]
    fn rebalanced_budgets_follow_data_demand() {
        let mut se = sharded(2);
        for i in 0..6_000u64 {
            se.put_payload(&key_for(i, 24), value_for(i, 500));
        }
        se.flush_all();
        se.quiesce();
        let rates = se.rebalance_migration_budgets();
        let total: f64 = rates.iter().sum();
        assert!((total - se.total_migration_rate_bps).abs() < 1e-6);
        let demands: Vec<u64> =
            se.engines.iter().map(|e| e.fs.total_file_bytes()).collect();
        // More data ⇒ at least as much budget.
        if demands[0] > demands[1] {
            assert!(rates[0] >= rates[1]);
        } else if demands[1] > demands[0] {
            assert!(rates[1] >= rates[0]);
        }
    }
}
