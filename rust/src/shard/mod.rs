//! Sharded multi-engine subsystem: stripes the LSM key space over `N`
//! independent engines sharing the hybrid SSD/HDD zoned substrate.
//!
//! The seed system is one LSM engine over one coordinator; production
//! traffic needs the key space partitioned (KeystoneDB stripes 256 ways
//! for the same reason). This module adds exactly that, without touching
//! the engine's own semantics:
//!
//! * [`router`] — deterministic hash routing: every client op is owned by
//!   exactly one shard;
//! * [`lease`] — the substrate lease layer: zone quotas, per-shard
//!   WAL/cache pool reservations, strided file-id namespaces, and memory
//!   budget slices that make `N` engines safe on the shared substrate;
//! * [`arbiter`] — splits the paper's global migration-rate budget
//!   (§3.4) across shards proportionally to their storage demand;
//! * [`ShardedEngine`] — owns the engines, routes synchronous ops, drives
//!   workload phases, and merges per-shard metrics into one report.
//!
//! Two deliberate simplifications, both recorded as ROADMAP open items:
//! each shard runs its own virtual clock (cross-shard device-queue
//! contention is not modeled — zoned devices serve concurrent per-zone
//! streams largely in parallel, which is what independent clocks
//! approximate), and scans are served by the start key's home shard
//! (no scatter-gather).
//!
//! `shards = 1` is bit-for-bit the seed single-engine system: the lease
//! is the identity, the router maps everything to shard 0, and the
//! arbiter returns the untouched budget. Tests pin this.

pub mod arbiter;
pub mod lease;
pub mod router;

pub use arbiter::MigrationArbiter;
pub use lease::{carve, ShardLease};
pub use router::Router;

use crate::config::Config;
use crate::coordinator::{Engine, OpSource};
use crate::metrics::Metrics;
use crate::policy::Policy;
use crate::sim::Ns;

/// `N` engines + a router over the shared substrate.
pub struct ShardedEngine {
    pub engines: Vec<Engine>,
    pub router: Router,
    /// The global §3.4 budget the arbiter re-splits.
    total_migration_rate_bps: f64,
}

impl ShardedEngine {
    /// Build `cfg.shards` engines from substrate leases. `policy_fn`
    /// constructs each shard's placement policy from its leased config
    /// (shards keep independent policy state — their own demand trackers
    /// and read-rate maps — exactly like independent stores).
    pub fn new(cfg: &Config, mut policy_fn: impl FnMut(&Config) -> Box<dyn Policy>) -> Self {
        let leases = carve(cfg);
        let router = Router::new(leases.len());
        let engines = leases
            .into_iter()
            .map(|l| {
                let policy = policy_fn(&l.cfg);
                let mut e = Engine::new(l.cfg, policy);
                e.set_file_id_namespace(l.file_id_base, l.file_id_stride);
                e
            })
            .collect();
        ShardedEngine {
            engines,
            router,
            total_migration_rate_bps: cfg.hhzs.migration_rate_bps,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.engines.len()
    }

    // ------------------------------------------------------------------
    // Workload mode
    // ------------------------------------------------------------------

    /// Drive one workload phase on every shard. `make_source` builds the
    /// shard-local op stream (normally a router-filtered view of the same
    /// deterministic global stream — see `ycsb::RoutedSource`); each shard
    /// serves `clients` closed-loop clients of its own frontend.
    ///
    /// `target_ops_per_sec` is a *global* budget: it is split evenly
    /// across shards so the aggregate pace matches what a single engine
    /// would be throttled to (`t / 1` is exact, preserving the
    /// single-shard reproduction).
    pub fn run(
        &mut self,
        mut make_source: impl FnMut(usize) -> Box<dyn OpSource>,
        clients: usize,
        target_ops_per_sec: Option<f64>,
        sample_levels: bool,
    ) {
        let n = self.engines.len() as f64;
        let per_shard_target = target_ops_per_sec.map(|t| t / n);
        for (shard, e) in self.engines.iter_mut().enumerate() {
            let mut src = make_source(shard);
            e.run(&mut *src, clients, per_shard_target, sample_levels);
        }
    }

    /// Flush every shard's MemTables (the between-phases reopen of §4.1).
    pub fn flush_all(&mut self) {
        for e in &mut self.engines {
            e.flush_all();
        }
    }

    /// Let all shards' background work settle.
    pub fn quiesce(&mut self) {
        for e in &mut self.engines {
            e.quiesce();
        }
    }

    /// Re-split the global migration budget (§3.4) across shards in
    /// proportion to their live SST bytes; returns the per-shard rates.
    /// Call between phases (migration pacing reads the config live).
    pub fn rebalance_migration_budgets(&mut self) -> Vec<f64> {
        let demands: Vec<u64> =
            self.engines.iter().map(|e| e.fs.total_file_bytes()).collect();
        let rates = MigrationArbiter::new(self.total_migration_rate_bps).split(&demands);
        for (e, r) in self.engines.iter_mut().zip(&rates) {
            e.cfg.hhzs.migration_rate_bps = *r;
        }
        rates
    }

    // ------------------------------------------------------------------
    // Merged reporting
    // ------------------------------------------------------------------

    /// One metrics record for the whole system: histograms merged
    /// bucket-wise, counters and traffic cells summed.
    pub fn merged_metrics(&self) -> Metrics {
        let mut m = self.engines[0].metrics.clone();
        for e in &self.engines[1..] {
            m.merge(&e.metrics);
        }
        m
    }

    /// Aggregate throughput of the last phase: total ops over the slowest
    /// shard's duration (shards run concurrently in deployment, so the
    /// straggler bounds the wall time).
    pub fn aggregate_ops_per_sec(&self) -> f64 {
        let total_ops: u64 = self.engines.iter().map(|e| e.metrics.ops_done).sum();
        let max_dur: Ns = self
            .engines
            .iter()
            .map(|e| e.metrics.finished_at.saturating_sub(e.metrics.start_ns))
            .max()
            .unwrap_or(0);
        if max_dur == 0 {
            0.0
        } else {
            total_ops as f64 / (max_dur as f64 / 1e9)
        }
    }

    /// Ops executed per shard in the last phase (load-balance reporting).
    pub fn ops_per_shard(&self) -> Vec<u64> {
        self.engines.iter().map(|e| e.metrics.ops_done).collect()
    }

    // ------------------------------------------------------------------
    // Synchronous DB-style API (routed)
    // ------------------------------------------------------------------

    pub fn put(&mut self, key: &[u8], value: &[u8]) {
        let s = self.router.route(key);
        self.engines[s].put(key, value);
    }

    pub fn put_payload(&mut self, key: &[u8], value: crate::wire::Payload) {
        let s = self.router.route(key);
        self.engines[s].put_payload(key, value);
    }

    pub fn delete(&mut self, key: &[u8]) {
        let s = self.router.route(key);
        self.engines[s].delete(key);
    }

    pub fn get(&mut self, key: &[u8]) -> Option<crate::wire::Payload> {
        let s = self.router.route(key);
        self.engines[s].get(key)
    }

    /// Scan served by the start key's home shard (hash partitioning
    /// scatters ranges; cross-shard scatter-gather is an open item).
    pub fn scan(&mut self, start: &[u8], n: usize) -> usize {
        let s = self.router.route(start);
        self.engines[s].scan(start, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::HhzsPolicy;
    use crate::wire::Payload;
    use crate::ycsb::{key_for, value_for};

    fn sharded(n: usize) -> ShardedEngine {
        let mut cfg = Config::tiny();
        cfg.shards = n;
        ShardedEngine::new(&cfg, |c| Box::new(HhzsPolicy::new(c.lsm.num_levels)))
    }

    #[test]
    fn routed_put_get_roundtrip() {
        let mut se = sharded(4);
        for i in 0..2_000u64 {
            se.put_payload(&key_for(i, 24), value_for(i, 100));
        }
        se.quiesce();
        for i in (0..2_000u64).step_by(31) {
            assert_eq!(se.get(&key_for(i, 24)), Some(value_for(i, 100)), "key {i}");
        }
        assert_eq!(se.get(b"never-written"), None);
        // Overwrite + delete stay on the owning shard.
        let k = key_for(7, 24);
        se.put(&k, b"fresh");
        assert_eq!(se.get(&k), Some(Payload::from_bytes(b"fresh")));
        se.delete(&k);
        assert_eq!(se.get(&k), None);
    }

    #[test]
    fn data_lands_on_multiple_shards_with_disjoint_file_ids() {
        let mut se = sharded(4);
        for i in 0..8_000u64 {
            se.put_payload(&key_for(i, 24), value_for(i, 500));
        }
        se.quiesce();
        let mut seen = std::collections::HashSet::new();
        let mut shards_with_files = 0;
        for (s, e) in se.engines.iter().enumerate() {
            let mut any = false;
            for f in e.fs.files() {
                assert!(seen.insert(f.id), "file id {} on two shards", f.id);
                // Strided namespace: id ≡ shard + 1 (mod N).
                assert_eq!((f.id - 1) % 4, s as u64, "file {} outside its lease", f.id);
                any = true;
            }
            shards_with_files += usize::from(any);
        }
        assert!(shards_with_files >= 3, "hash routing should hit most shards");
    }

    #[test]
    fn merged_metrics_sum_per_shard_ops() {
        let mut se = sharded(2);
        for i in 0..500u64 {
            se.put_payload(&key_for(i, 24), value_for(i, 64));
        }
        let per: u64 = se.engines.iter().map(|e| e.metrics.writes_done).sum();
        assert_eq!(per, 500);
        assert_eq!(se.merged_metrics().writes_done, 500);
    }

    #[test]
    fn rebalanced_budgets_follow_data_demand() {
        let mut se = sharded(2);
        for i in 0..6_000u64 {
            se.put_payload(&key_for(i, 24), value_for(i, 500));
        }
        se.flush_all();
        se.quiesce();
        let rates = se.rebalance_migration_budgets();
        let total: f64 = rates.iter().sum();
        assert!((total - se.total_migration_rate_bps).abs() < 1e-6);
        let demands: Vec<u64> =
            se.engines.iter().map(|e| e.fs.total_file_bytes()).collect();
        // More data ⇒ at least as much budget.
        if demands[0] > demands[1] {
            assert!(rates[0] >= rates[1]);
        } else if demands[1] > demands[0] {
            assert!(rates[1] >= rates[0]);
        }
    }
}
