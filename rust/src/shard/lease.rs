//! Substrate lease layer: lets `N` independent LSM engines share the one
//! hybrid SSD/HDD zoned substrate safely.
//!
//! Sharing is made safe by *partitioning up front* instead of locking at
//! run time: each shard leases
//!
//! * a disjoint zone quota on both devices (the SSD's 20 zones and the
//!   HDD zone pool are split with remainders going to the lowest shard
//!   indices — conservation is exact: the leased quotas sum to the
//!   substrate totals);
//! * its own WAL/cache pool reservation, re-derived from the §3.2 rule
//!   (per-shard maximum WAL size / zone capacity) because each shard runs
//!   its own WAL stream over its own MemTables;
//! * a strided slice of the shared file-id namespace (`shard + 1`,
//!   `shard + 1 + N`, ...), so SST ids — which double as zenfs file ids
//!   and metric keys — never collide across engines;
//! * proportional slices of the memory budgets (MemTable, L0 target,
//!   block cache), keeping the aggregate footprint equal to the
//!   single-engine system's;
//! * an initial `1/N` slice of the §3.4 migration-rate budget, later
//!   refined by the demand-proportional [`crate::shard::arbiter`].
//!
//! Physical residency is NOT carved: all shards page through shard 0's
//! [`crate::residency::Residency`] manager (rebound in
//! [`crate::shard::ShardedEngine::new`] like the timers/CPU pool/key
//! arena), so dehydrated descriptors cost the same domain-wide whether a
//! keyspace is served by 1 engine or 256. The per-shard
//! `resident_*_bytes` gauges still partition exactly — each engine owns
//! disjoint zones — and sum on metrics merge.
//!
//! `shards = 1` short-circuits to the untouched config (base 1, stride 1),
//! which is what makes the single-shard system reproduce the seed engine
//! bit-for-bit — the regression guard for this whole subsystem.

use crate::config::{Config, KIB};

/// What one shard is allowed to use of the shared substrate.
pub struct ShardLease {
    pub shard: usize,
    /// The shard-local view of the configuration (leased geometry and
    /// budget slices applied).
    pub cfg: Config,
    /// First file id of this shard's namespace slice.
    pub file_id_base: u64,
    /// Distance between consecutive ids of the slice (= shard count).
    pub file_id_stride: u64,
}

/// `i`-th part of `total` split into `n` near-equal parts (remainder to
/// the lowest indices). Exact: the parts sum back to `total`.
fn split_zones(total: u32, n: u32, i: u32) -> u32 {
    total / n + u32::from(i < total % n)
}

/// Carve the substrate described by `cfg` into `cfg.shards` leases.
///
/// Panics when the substrate cannot host that many engines (every shard
/// needs at least one WAL/cache zone plus one SST zone on the SSD, and at
/// least `hdd_zones_per_sst` zones on the HDD).
pub fn carve(cfg: &Config) -> Vec<ShardLease> {
    let n = cfg.shards.max(1);
    if n == 1 {
        // Exact single-engine reproduction: untouched config, unit stride.
        return vec![ShardLease {
            shard: 0,
            cfg: cfg.clone(),
            file_id_base: 1,
            file_id_stride: 1,
        }];
    }
    let n32 = n as u32;
    assert!(
        cfg.geometry.ssd_zones >= 2 * n32,
        "substrate too small: {} SSD zones cannot host {} shards \
         (each needs ≥ 1 pool zone + 1 file zone)",
        cfg.geometry.ssd_zones,
        n
    );
    assert!(
        cfg.geometry.hdd_zones >= n32 * cfg.hdd_zones_per_sst(),
        "substrate too small: {} HDD zones cannot host {} shards",
        cfg.geometry.hdd_zones,
        n
    );
    (0..n)
        .map(|i| {
            let mut c = cfg.clone();
            c.geometry.ssd_zones = split_zones(cfg.geometry.ssd_zones, n32, i as u32);
            c.geometry.hdd_zones = split_zones(cfg.geometry.hdd_zones, n32, i as u32);
            // Memory budgets are split so N shards together spend what the
            // single engine did.
            c.lsm.memtable_size = (cfg.lsm.memtable_size / n as u64).max(4 * KIB);
            c.lsm.l0_target = (cfg.lsm.l0_target / n as u64).max(c.lsm.memtable_size);
            c.lsm.block_cache_bytes = (cfg.lsm.block_cache_bytes / n as u64).max(64 * KIB);
            // §3.2 per shard: pool zones = ceil(max WAL size / zone cap),
            // where max WAL = max_memtables × (per-shard) memtable size.
            // Capped to leave at least one SST zone in the shard's slice.
            let max_wal = c.lsm.memtable_size * cfg.lsm.max_memtables as u64;
            let pool = max_wal.div_ceil(cfg.geometry.ssd_zone_cap).max(1) as u32;
            c.geometry.wal_cache_zones = pool.min(c.geometry.ssd_zones - 1);
            // Initial even split of the global migration budget; the
            // arbiter refines this from measured storage demand.
            c.hhzs.migration_rate_bps = cfg.hhzs.migration_rate_bps / n as f64;
            ShardLease {
                shard: i,
                cfg: c,
                file_id_base: i as u64 + 1,
                file_id_stride: n as u64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_lease_is_the_identity() {
        let cfg = Config::tiny();
        let leases = carve(&cfg);
        assert_eq!(leases.len(), 1);
        assert_eq!(leases[0].cfg, cfg);
        assert_eq!((leases[0].file_id_base, leases[0].file_id_stride), (1, 1));
    }

    #[test]
    fn zone_quotas_conserve_the_substrate() {
        for n in [2usize, 3, 4, 8] {
            let mut cfg = Config::tiny();
            cfg.shards = n;
            let leases = carve(&cfg);
            assert_eq!(leases.len(), n);
            let ssd: u32 = leases.iter().map(|l| l.cfg.geometry.ssd_zones).sum();
            let hdd: u32 = leases.iter().map(|l| l.cfg.geometry.hdd_zones).sum();
            assert_eq!(ssd, cfg.geometry.ssd_zones, "SSD zones leak at n={n}");
            assert_eq!(hdd, cfg.geometry.hdd_zones, "HDD zones leak at n={n}");
        }
    }

    #[test]
    fn every_shard_keeps_pool_and_file_zones() {
        for n in [2usize, 4, 8] {
            let mut cfg = Config::tiny();
            cfg.shards = n;
            for l in carve(&cfg) {
                let g = &l.cfg.geometry;
                assert!(g.wal_cache_zones >= 1, "shard {} has no pool zone", l.shard);
                assert!(
                    g.ssd_zones > g.wal_cache_zones,
                    "shard {} has no SST zone ({} total, {} pool)",
                    l.shard,
                    g.ssd_zones,
                    g.wal_cache_zones
                );
            }
        }
    }

    #[test]
    fn file_id_namespaces_are_disjoint() {
        let mut cfg = Config::tiny();
        cfg.shards = 4;
        let leases = carve(&cfg);
        let mut seen = std::collections::HashSet::new();
        for l in &leases {
            // First 1000 ids of each shard's strided namespace.
            for k in 0..1000u64 {
                let id = l.file_id_base + k * l.file_id_stride;
                assert!(seen.insert(id), "file id {id} leased to two shards");
            }
        }
    }

    #[test]
    fn memory_budgets_split_but_floor() {
        let mut cfg = Config::tiny();
        cfg.shards = 4;
        for l in carve(&cfg) {
            assert!(l.cfg.lsm.memtable_size <= cfg.lsm.memtable_size / 4 + 4 * KIB);
            assert!(l.cfg.lsm.block_cache_bytes >= 64 * KIB);
            assert!(l.cfg.lsm.l0_target >= l.cfg.lsm.memtable_size);
        }
    }

    #[test]
    #[should_panic(expected = "substrate too small")]
    fn oversharding_is_rejected() {
        let mut cfg = Config::tiny();
        cfg.shards = cfg.geometry.ssd_zones as usize; // needs 2 zones/shard
        carve(&cfg);
    }

    #[test]
    fn migration_budget_splits_evenly_at_carve_time() {
        let mut cfg = Config::tiny();
        cfg.shards = 4;
        let total: f64 = carve(&cfg).iter().map(|l| l.cfg.hhzs.migration_rate_bps).sum();
        assert!((total - cfg.hhzs.migration_rate_bps).abs() < 1e-6);
    }
}
