//! The async request frontend: one global event loop over `N` engines.
//!
//! PR 1 drove each shard's closed-loop clients in a *sequential per-shard
//! loop*, each shard on its own virtual clock — cross-shard device-queue
//! contention (the effect the paper's Exp#6 measures in the tails) was
//! invisible, and scans were served by the start key's home shard only.
//! This frontend replaces that: it owns the clients and the virtual clock,
//! pulls ops from ONE shared stream, routes each op to its home shard, and
//! drives every engine's background jobs interleaved in global timestamp
//! order. All shards' I/O therefore lands on the shared per-device FIFO
//! timers ([`crate::sim::SharedTimer`]) in causal order, and queue wait
//! shows up across shards.
//!
//! Mechanically the DES is still one event heap: client readiness events
//! live in the frontend's heap, background events in the engines' heaps,
//! and every event carries a sequence number drawn from ONE shared counter
//! — the frontend always pops the globally minimal `(time, seq)` event
//! across all heaps, which is exactly the seed engine's single-heap order
//! when `N = 1`. That is the `shards = 1` bit-for-bit guarantee:
//! [`crate::coordinator::Engine::run`] itself is the 1-engine instance of
//! this loop.
//!
//! Scans scatter-gather: the range fans out to every shard (hash
//! partitioning scatters ranges), each shard charges its own reads on the
//! shared clock, and the partial results k-way merge; latency is the
//! gather barrier (slowest shard). Throttling is *global* pacing: one
//! `clients / target` interval per client over the whole system, so hot
//! shards under Zipf draw more of the budget than cold ones instead of the
//! old even `target / N` split.

use std::cell::{Cell, RefCell};
use std::collections::BinaryHeap;
use std::rc::Rc;

use crate::coordinator::groupcommit::{Batch, GroupCommitter};
use crate::coordinator::{Engine, FrontendOp, Op, OpSource};
use crate::lsm::Entry;
use crate::sim::cpu::CpuPool;
use crate::sim::Ns;
use crate::trace::TraceSink;

use super::Router;

/// A client readiness event in the frontend's heap.
#[derive(PartialEq, Eq)]
struct FrontEv {
    at: Ns,
    seq: u64,
    client: usize,
}

impl Ord for FrontEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap via reversed compare; seq breaks ties deterministically.
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for FrontEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct FrontClient {
    /// A parked op and the shard it is parked on.
    pending: Option<(Op, usize)>,
    issued_at: Ns,
    done: bool,
    next_allowed: Ns,
}

enum NextEvent {
    Client,
    Engine(usize),
}

/// The frontend. Borrowed views: engines, the shared op stream, and the
/// shared event-sequence counter; consumed by [`Frontend::run`].
pub struct Frontend<'a> {
    engines: &'a mut [Engine],
    router: Router,
    source: &'a mut dyn OpSource,
    event_seq: Rc<Cell<u64>>,
    /// The shared background-CPU pool (shard 0's handle; all engines on
    /// this frontend share it). The event loop drains its wake requests
    /// so a slot released by one shard re-schedules the shards starved
    /// for it at the same `(time, seq)` point of the merged order.
    cpu: Rc<RefCell<CpuPool>>,
    /// The domain's shared trace ring (shard 0's handle). The frontend is
    /// the authority on the merged clock, so it stamps the ring's time
    /// hint once per popped event — clockless emission sites (zone
    /// resets, cache-zone evictions) then carry the exact global time.
    trace: TraceSink,
    /// The domain's shared group-commit ledger (shard 0's handle; the
    /// shard layer rebinds every engine to it). `batching` caches
    /// `gc.enabled()` so the off path costs one bool test per event.
    gc: GroupCommitter,
    batching: bool,
    events: BinaryHeap<FrontEv>,
    clients: Vec<FrontClient>,
    done_clients: usize,
    throttle_interval: Option<Ns>,
    now: Ns,
}

impl<'a> Frontend<'a> {
    pub(crate) fn new(
        engines: &'a mut [Engine],
        router: Router,
        event_seq: Rc<Cell<u64>>,
        source: &'a mut dyn OpSource,
    ) -> Self {
        assert!(!engines.is_empty(), "a frontend needs at least one engine");
        assert_eq!(router.shards(), engines.len(), "router does not match the engines");
        let cpu = engines[0].cpu_pool_handle();
        let trace = engines[0].trace_handle();
        let gc = engines[0].group_committer_handle();
        let batching = gc.enabled();
        Frontend {
            engines,
            router,
            source,
            event_seq,
            cpu,
            trace,
            gc,
            batching,
            events: BinaryHeap::new(),
            clients: Vec::new(),
            done_clients: 0,
            throttle_interval: None,
            now: 0,
        }
    }

    fn push(&mut self, at: Ns, client: usize) {
        let seq = self.event_seq.get() + 1;
        self.event_seq.set(seq);
        self.events.push(FrontEv { at, seq, client });
    }

    /// Drive one workload phase: `clients` closed-loop clients over the
    /// shared stream, optionally throttled to a *global* `target` ops/s.
    pub fn run(mut self, clients: usize, target: Option<f64>, sample: bool) {
        // The shared clock starts at the most advanced engine (phases that
        // ran through this frontend leave all engines near the same time;
        // a lagging engine's pending events are simply processed first).
        let t0 = self.engines.iter().map(|e| e.now).max().unwrap_or(0);
        self.now = t0;
        for e in self.engines.iter_mut() {
            e.begin_phase(t0, sample);
        }
        self.clients = (0..clients)
            .map(|_| FrontClient {
                pending: None,
                issued_at: t0,
                done: false,
                next_allowed: t0,
            })
            .collect();
        self.done_clients = 0;
        self.throttle_interval = target.map(|t| (clients as f64 / t * 1e9) as Ns);
        for c in 0..clients {
            self.push(t0, c);
        }
        let diag = std::env::var("HHZS_DIAG").is_ok();
        let mut processed: u64 = 0;
        while self.done_clients < clients {
            // Globally minimal (time, seq) across the frontend heap and
            // every engine heap. Seqs are unique within one clock domain;
            // the only possible collision is the engines' construction-time
            // PolicyTicks, broken deterministically by shard order.
            let mut best: Option<(Ns, u64, NextEvent)> =
                self.events.peek().map(|e| (e.at, e.seq, NextEvent::Client));
            for (s, e) in self.engines.iter().enumerate() {
                if let Some((at, seq)) = e.next_event_at() {
                    let earlier = match &best {
                        None => true,
                        Some((ba, bs, _)) => (at, seq) < (*ba, *bs),
                    };
                    if earlier {
                        best = Some((at, seq, NextEvent::Engine(s)));
                    }
                }
            }
            let Some((at, _, which)) = best else { break };
            self.now = at;
            self.trace.stamp(at);
            processed += 1;
            if diag && processed % 5_000_000 == 0 {
                eprintln!(
                    "[diag] ev={}M now={} done_clients={}/{} heap={}",
                    processed / 1_000_000,
                    crate::sim::fmt_ns(self.now),
                    self.done_clients,
                    clients,
                    self.events.len(),
                );
            }
            match which {
                NextEvent::Engine(s) => {
                    // Background event, or a client this shard unparked.
                    if let Some(c) = self.engines[s].step_event() {
                        self.ready(c, at);
                    }
                }
                NextEvent::Client => {
                    let ev = self.events.pop().expect("peeked event exists");
                    self.ready(ev.client, ev.at);
                }
            }
            // CPU handoff: if this event released pool slots that other
            // shards' ready flushes/compactions were starved for, re-poll
            // those shards NOW (same virtual time, flush waiters first) so
            // a freed slot never idles past an event boundary. At one
            // shard this is a no-op: the releasing engine already
            // rescheduled itself inside its finish path.
            if self.cpu.borrow().wake_pending() {
                let wake = self.cpu.borrow_mut().take_wake_list();
                if !wake.is_empty() {
                    super::trace_wake_round(&self.trace, &self.cpu.borrow(), at);
                }
                for s in wake {
                    self.engines[s].poll_cpu(at);
                }
            }
            // Batch-close hook: a window deadline (`WalCommit` event) or a
            // fill during this event moved batches to the due queue —
            // issue each one's fused append NOW, at the same `(time, seq)`
            // point of the merged order, and ack its members.
            if self.batching && self.gc.has_due() {
                for b in self.gc.take_due() {
                    self.close_batch(&b, at);
                }
            }
        }
        let end = self.now;
        for e in self.engines.iter_mut() {
            e.end_phase(end);
        }
    }

    /// Client `c` is ready at time `at`: retry its parked op or pull the
    /// next one from the shared stream, route it home, and execute.
    fn ready(&mut self, c: usize, at: Ns) {
        if self.clients[c].done {
            return;
        }
        let (op, shard) = match self.clients[c].pending.take() {
            Some(parked) => parked,
            None => {
                self.clients[c].issued_at = at;
                match self.source.next_op(c) {
                    Some(op) => {
                        let s = self.router.route_op(&op);
                        (op, s)
                    }
                    None => {
                        self.clients[c].done = true;
                        self.done_clients += 1;
                        return;
                    }
                }
            }
        };
        let issued_at = self.clients[c].issued_at;
        if self.engines.len() > 1 {
            if let Op::Scan { key, len } = &op {
                let finish = self.scatter_scan(shard, key, *len, at, issued_at);
                self.schedule_next(c, at, finish);
                return;
            }
        }
        match self.engines[shard].frontend_client_op(c, op, issued_at, at) {
            FrontendOp::Parked(op) => {
                // The engine recorded the stall and remembers `c`; it will
                // push a client event when background work unblocks writes.
                self.clients[c].pending = Some((op, shard));
            }
            FrontendOp::Done(finish) => self.schedule_next(c, at, finish),
            FrontendOp::Staged => {
                // The record is on media and its batch is ledgered; the
                // client sleeps until the batch's fused append acks it from
                // the close hook (which reschedules it via `close_batch`).
            }
        }
    }

    /// Issue one due batch's fused append and wake its members. The first
    /// member's shard charges the shared device timer ONCE (one
    /// `per_req_overhead_ns` for the whole batch); every member then books
    /// its own queue wait, latency, and trace records on its home shard,
    /// and its client reschedules at `max(fused finish, cpu_ready)`.
    fn close_batch(&mut self, b: &Batch, at: Ns) {
        let s0 = b.members[0].shard;
        let (start, finish) = self.engines[s0].charge_batch_close(at, b);
        for (i, m) in b.members.iter().enumerate() {
            let ack = self.engines[m.shard].book_batch_member(b.id, b.dev, m, i == 0, start, finish);
            self.schedule_next(m.client, at, ack);
        }
    }

    /// Cross-shard scatter-gather scan: fan the range out to every shard,
    /// charge each shard's reads at the shared time `at`, k-way merge the
    /// partials, and account the op on the home shard. The latency is the
    /// gather barrier — the slowest shard's finish.
    fn scatter_scan(&mut self, home: usize, start: &[u8], n: usize, at: Ns, issued_at: Ns) -> Ns {
        let mut parts: Vec<Vec<Entry>> = Vec::with_capacity(self.engines.len());
        let mut finish = at;
        for (s, e) in self.engines.iter_mut().enumerate() {
            let (entries, f) = e.frontend_scan(at, start, n, s == home);
            finish = finish.max(f);
            parts.push(entries);
        }
        // The workload driver, like the seed engine, discards the scanned
        // entries, and the gather merge costs no *virtual* time (`finish`
        // is the fan-out barrier above) — so skip the O(shards·n) host
        // work in release builds and only validate the merge under debug
        // assertions. `ShardedEngine::scan` is the observable gather path.
        if cfg!(debug_assertions) {
            let gathered = merge_gather(parts, n);
            debug_assert!(gathered.len() <= n, "gather must respect the scan budget");
        }
        // Scans never park (only writes do), so there is no stall window:
        // the op was issued at this very event.
        debug_assert_eq!(issued_at, at, "scans are never parked");
        let m = &mut self.engines[home].metrics;
        m.scan_lat.record(finish.saturating_sub(issued_at));
        m.ops_done += 1;
        finish
    }

    /// Closed loop: the client's next op fires at completion, or at the
    /// globally paced slot when throttled.
    fn schedule_next(&mut self, c: usize, at: Ns, finish: Ns) {
        let mut next = finish;
        if let Some(interval) = self.throttle_interval {
            let na = self.clients[c].next_allowed.max(at) + interval;
            self.clients[c].next_allowed = na;
            next = next.max(na);
        }
        self.push(next, c);
    }
}

/// K-way merge of per-shard scan results. Hash partitioning makes the
/// shards' key sets disjoint and every part arrives sorted, so this is a
/// pure merge (no dedup, no clones — the parts are consumed); an
/// (impossible between shards) key tie breaks by part order.
pub(crate) fn merge_gather(parts: Vec<Vec<Entry>>, n: usize) -> Vec<Entry> {
    let mut queues: Vec<std::collections::VecDeque<Entry>> =
        parts.into_iter().map(Into::into).collect();
    let mut out = Vec::new();
    while out.len() < n {
        let mut best: Option<usize> = None;
        for (i, q) in queues.iter().enumerate() {
            let Some(head) = q.front() else { continue };
            best = match best {
                Some(b) if queues[b].front().expect("best is nonempty").key <= head.key => {
                    Some(b)
                }
                _ => Some(i),
            };
        }
        let Some(b) = best else { break };
        out.push(queues[b].pop_front().expect("best is nonempty"));
    }
    out
}
