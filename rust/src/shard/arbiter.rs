//! Cross-shard migration arbiter.
//!
//! The paper rate-limits SST migration to a single global budget (§3.4,
//! default 4 MiB/s) so migration I/O cannot swamp foreground requests.
//! With the LSM striped over `N` engines there are `N` independent
//! migration actors; this arbiter splits the one global budget across
//! them **proportionally to each shard's storage demand** (bytes of live
//! SST data), so HHZS's hints still govern global SSD/HDD placement: a
//! shard holding twice the data gets twice the migration bandwidth, and
//! the sum over all shards never exceeds the configured global rate.

/// Splits the global §3.4 migration-rate budget across shards.
#[derive(Clone, Copy, Debug)]
pub struct MigrationArbiter {
    total_bps: f64,
}

impl MigrationArbiter {
    pub fn new(total_bps: f64) -> Self {
        MigrationArbiter { total_bps }
    }

    pub fn total_bps(&self) -> f64 {
        self.total_bps
    }

    /// Per-shard rates (bytes/second), proportional to `demand_bytes`.
    ///
    /// Every shard keeps a trickle (zero demand counts as one byte) so a
    /// freshly emptied shard can still react to capacity violations; the
    /// returned rates always sum to exactly the global budget. A single
    /// shard receives the untouched budget — the `shards = 1` identity
    /// the regression guard depends on.
    pub fn split(&self, demand_bytes: &[u64]) -> Vec<f64> {
        assert!(!demand_bytes.is_empty(), "no shards to arbitrate");
        if demand_bytes.len() == 1 {
            return vec![self.total_bps];
        }
        let weights: Vec<f64> = demand_bytes.iter().map(|&d| d.max(1) as f64).collect();
        let sum: f64 = weights.iter().sum();
        weights.iter().map(|w| self.total_bps * (w / sum)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_gets_the_exact_budget() {
        let a = MigrationArbiter::new(4.0 * 1024.0 * 1024.0);
        let rates = a.split(&[123_456_789]);
        assert_eq!(rates, vec![4.0 * 1024.0 * 1024.0]);
    }

    #[test]
    fn rates_are_demand_proportional_and_conserve_the_budget() {
        let total = 8.0 * 1024.0 * 1024.0;
        let a = MigrationArbiter::new(total);
        let rates = a.split(&[300, 100, 100, 0]);
        assert_eq!(rates.len(), 4);
        let sum: f64 = rates.iter().sum();
        assert!((sum - total).abs() < 1e-6, "budget leaked: {sum} vs {total}");
        // 3:1 demand ratio → 3:1 rate ratio.
        assert!((rates[0] / rates[1] - 3.0).abs() < 1e-9);
        // Zero demand still gets a (tiny) positive trickle.
        assert!(rates[3] > 0.0);
        assert!(rates[3] < rates[1]);
    }

    #[test]
    fn equal_demands_split_evenly() {
        let a = MigrationArbiter::new(1000.0);
        let rates = a.split(&[5, 5, 5, 5]);
        for r in rates {
            assert!((r - 250.0).abs() < 1e-9);
        }
    }
}
