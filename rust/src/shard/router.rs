//! Deterministic key → shard routing.
//!
//! Every client operation is owned by exactly one shard, decided by an
//! FNV-1a hash of the user key modulo the shard count (the same scheme
//! KeystoneDB's 256-stripe LSM uses). The mapping is a pure function of
//! `(key, shard count)` — no state, no RNG — so op streams, replays, and
//! recovery all agree on ownership across runs and processes.

use crate::coordinator::Op;
use crate::sim::rng::fnv1a;

/// The shard router. Cheap to copy; embed it anywhere a placement
/// decision is needed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Router {
    shards: usize,
}

impl Router {
    pub fn new(shards: usize) -> Self {
        assert!(shards >= 1, "a router needs at least one shard");
        Router { shards }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Home shard of a user key. Total (every key maps to exactly one
    /// shard in `0..shards`) and deterministic.
    pub fn route(&self, key: &[u8]) -> usize {
        if self.shards == 1 {
            return 0;
        }
        (fnv1a(key) % self.shards as u64) as usize
    }

    /// Home shard of a client operation (scans are routed by their start
    /// key; cross-shard scatter-gather scans are an open ROADMAP item).
    pub fn route_op(&self, op: &Op) -> usize {
        let key = match op {
            Op::Insert { key, .. }
            | Op::Update { key, .. }
            | Op::Read { key }
            | Op::Scan { key, .. }
            | Op::ReadModifyWrite { key, .. } => key,
        };
        self.route(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_shard_routes_everything_to_zero() {
        let r = Router::new(1);
        for i in 0..100u64 {
            assert_eq!(r.route(&i.to_be_bytes()), 0);
        }
    }

    #[test]
    fn routing_is_total_and_deterministic() {
        for n in [2usize, 3, 4, 8] {
            let a = Router::new(n);
            let b = Router::new(n);
            for i in 0..1000u64 {
                let key = crate::ycsb::key_for(i, 24);
                let s = a.route(&key);
                assert!(s < n, "route out of range");
                assert_eq!(s, b.route(&key), "routers must agree");
            }
        }
    }

    #[test]
    fn hashing_spreads_ycsb_keys() {
        let n = 4;
        let r = Router::new(n);
        let mut counts = vec![0u64; n];
        for i in 0..10_000u64 {
            counts[r.route(&crate::ycsb::key_for(i, 24))] += 1;
        }
        for (s, c) in counts.iter().enumerate() {
            // Loose balance bound: each shard gets 15–35% of a fair 25%.
            assert!(
                (1_500..=3_500).contains(c),
                "shard {s} got {c} of 10000 keys"
            );
        }
    }

    #[test]
    fn ops_route_by_their_key() {
        let r = Router::new(8);
        let key = crate::ycsb::key_for(42, 24);
        let home = r.route(&key);
        let p = crate::wire::Payload::from_bytes(b"v");
        let ops = [
            Op::Insert { key: key.clone(), value: p },
            Op::Update { key: key.clone(), value: p },
            Op::Read { key: key.clone() },
            Op::Scan { key: key.clone(), len: 10 },
            Op::ReadModifyWrite { key: key.clone(), value: p },
        ];
        for op in &ops {
            assert_eq!(r.route_op(op), home);
        }
    }
}
