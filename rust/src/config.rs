//! Configuration system.
//!
//! All geometry is expressed relative to the paper's testbed (a 4-TiB WD
//! ZN540 ZNS SSD with 1,077 MiB zones and a 14-TiB Seagate ST14000NM0007
//! HM-SMR HDD with 256 MiB zones) and scaled down by a configurable
//! denominator so experiments run in RAM under the discrete-event clock.
//! `Config::paper_scaled(d)` derives every size from the paper constants;
//! `Config::default()` uses `d = 256` (the CI-friendly profile).
//!
//! Configs round-trip through a TOML subset (`[section]`, `key = value`)
//! parsed by the in-tree [`minitoml`] module — no external crates are
//! available in this offline environment.

pub mod minitoml;

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * 1024;
pub const GIB: u64 = 1024 * 1024 * 1024;

/// Paper constants (§2.3, §4.1) — unscaled.
pub mod paper {
    use super::MIB;
    pub const SSD_ZONE_CAP: u64 = (1077.0 * MIB as f64) as u64;
    pub const HDD_ZONE_CAP: u64 = 256 * MIB;
    /// §3.2: 1,011.2 MiB — 93.9% of an SSD zone, exactly 4 HDD zones.
    pub const SST_SIZE: u64 = (1011.2 * MIB as f64) as u64;
    pub const MEMTABLE_SIZE: u64 = 512 * MIB;
    pub const L0_TARGET: u64 = 1024 * MIB;
    pub const BLOCK_CACHE: u64 = 8 * MIB;

    pub const SSD_SEQ_READ_MIBS: f64 = 1039.6;
    pub const SSD_SEQ_WRITE_MIBS: f64 = 1002.8;
    pub const SSD_RAND_READ_IOPS: f64 = 16928.3;
    pub const HDD_SEQ_READ_MIBS: f64 = 210.0;
    pub const HDD_SEQ_WRITE_MIBS: f64 = 210.0;
    pub const HDD_RAND_READ_IOPS: f64 = 115.0;
    pub const SSD_PRICE_GIB: f64 = 0.28;
    pub const HDD_PRICE_GIB: f64 = 0.021;
}

/// Timing profile of one zoned device (drives the DES service model).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceProfile {
    pub name: String,
    /// Sequential read bandwidth, bytes/second.
    pub seq_read_bps: f64,
    /// Sequential write bandwidth, bytes/second.
    pub seq_write_bps: f64,
    /// Random 4-KiB read rate, IO/second.
    pub rand_read_iops: f64,
    /// Fixed per-request overhead in nanoseconds (command setup; seek is
    /// folded into `rand_read_iops` for HDDs).
    pub per_req_overhead_ns: u64,
}

impl DeviceProfile {
    pub fn zn540_ssd() -> Self {
        DeviceProfile {
            name: "ZN540-ZNS-SSD".into(),
            seq_read_bps: paper::SSD_SEQ_READ_MIBS * MIB as f64,
            seq_write_bps: paper::SSD_SEQ_WRITE_MIBS * MIB as f64,
            rand_read_iops: paper::SSD_RAND_READ_IOPS,
            per_req_overhead_ns: 10_000, // ~10 µs NVMe command overhead
        }
    }
    pub fn st14000_smr_hdd() -> Self {
        DeviceProfile {
            name: "ST14000-HM-SMR-HDD".into(),
            seq_read_bps: paper::HDD_SEQ_READ_MIBS * MIB as f64,
            seq_write_bps: paper::HDD_SEQ_WRITE_MIBS * MIB as f64,
            rand_read_iops: paper::HDD_RAND_READ_IOPS,
            per_req_overhead_ns: 100_000, // ~100 µs SATA/queueing overhead
        }
    }
}

/// Zone/file geometry (scaled from the paper's §3.2/§4.1 values).
#[derive(Clone, Debug, PartialEq)]
pub struct Geometry {
    /// Scale denominator relative to the paper testbed (1 = full size).
    pub scale_denom: u64,
    pub ssd_zone_cap: u64,
    pub hdd_zone_cap: u64,
    /// Target SST size: fits one SSD zone (93.9%) or exactly 4 HDD zones.
    pub sst_size: u64,
    /// Number of SSD zones made available (paper default: 20 → 21.0 GiB).
    pub ssd_zones: u32,
    /// HDD zones (effectively unbounded in the paper; sized to fit the
    /// workload here).
    pub hdd_zones: u32,
    /// Zones reserved for WAL + SSD cache (§3.2: max WAL size / zone cap = 2).
    pub wal_cache_zones: u32,
}

/// How the shared background-CPU pool arbitrates flush/compaction slots
/// across shards (see [`crate::sim::CpuPool`]). With one shard both modes
/// are the identity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CpuSched {
    /// Per-shard fair-share cap: no shard may hold more than
    /// `ceil(bg_threads / shards)` compaction slots, so a backlogged shard
    /// cannot monopolize the pool (flushes are exempt — they only contend
    /// for the global slot count).
    Fair,
    /// Free-for-all: any shard may grab any compaction-eligible slot.
    WorkConserving,
}

impl CpuSched {
    pub fn as_str(&self) -> &'static str {
        match self {
            CpuSched::Fair => "fair",
            CpuSched::WorkConserving => "work_conserving",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fair" => Some(CpuSched::Fair),
            "work_conserving" => Some(CpuSched::WorkConserving),
            _ => None,
        }
    }
}

/// Wake-order policy of the shared background-CPU pool: which starved
/// shard gets re-polled first when a slot frees up (see
/// [`crate::sim::CpuPool::take_wake_list`]). Orthogonal to [`CpuSched`]:
/// `CpuSched` caps how many slots a shard may *hold*, `WakePolicy` orders
/// who is *offered* the next freed one. Flush-before-compaction stays a
/// hard constraint under both.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WakePolicy {
    /// Shard-order wake (the PR 4 behavior; bit-identical goldens).
    Fifo,
    /// Highest stall risk first: waiters are ordered by live per-shard
    /// pressure (L0 files vs the stop limit, memtable fill, parked
    /// writers, zone-reset debt) plus an aging term that bounds any
    /// waiter's wait (no starvation).
    StallAware,
}

impl WakePolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            WakePolicy::Fifo => "fifo",
            WakePolicy::StallAware => "stall_aware",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fifo" => Some(WakePolicy::Fifo),
            "stall_aware" => Some(WakePolicy::StallAware),
            _ => None,
        }
    }
}

/// LSM-tree store parameters (§4.1 setup).
#[derive(Clone, Debug, PartialEq)]
pub struct LsmConfig {
    pub memtable_size: u64,
    /// Keep at most this many MemTables in memory (writes stall beyond).
    pub max_memtables: usize,
    /// Flush when at least this many MemTables exist.
    pub min_flush_memtables: usize,
    pub block_size: u64,
    pub block_cache_bytes: u64,
    pub bloom_bits_per_key: u32,
    /// Target size of L0 and L1; higher levels grow by `level_multiplier`.
    pub l0_target: u64,
    pub level_multiplier: u64,
    pub num_levels: usize,
    /// Background flush+compaction thread slots (§4.1: 12). This is a
    /// *global* budget: with `shards > 1` every engine draws from ONE
    /// shared [`crate::sim::CpuPool`] of this many slots (the substrate
    /// lease layer deliberately does not split it).
    pub bg_threads: usize,
    /// Cross-shard arbitration policy for the shared CPU pool.
    pub cpu_sched: CpuSched,
    /// Wake-order policy for freed CPU slots (`fifo` = the golden-pinned
    /// shard-order wake; `stall_aware` = highest stall risk first).
    pub wake: WakePolicy,
    /// Foreground CPU slots: per-op `CPU_*_NS` costs are charged against a
    /// pool of this many slots in global event order, so saturating
    /// closed-loop load queues on host CPU. `0` = contention-free (the
    /// seed arithmetic; golden-pinned).
    pub fg_threads: usize,
    /// Hard write stall when L0 reaches this many files.
    pub l0_stop_files: usize,
    /// L0→L1 compaction trigger (number of L0 files).
    pub l0_compaction_trigger: usize,
}

/// HHZS-specific knobs (§3.4, §3.5).
#[derive(Clone, Debug, PartialEq)]
pub struct HhzsConfig {
    /// Migration rate limit in bytes/second (§3.4 default 4 MiB/s).
    pub migration_rate_bps: f64,
    /// Popularity migration triggers when the aggregate HDD read rate
    /// exceeds this fraction of the HDD's max random-read IOPS (§3.4: 0.5).
    pub hdd_rate_threshold: f64,
    /// Virtual interval between migration scans, nanoseconds.
    pub scan_interval_ns: u64,
    /// Background I/O chunk size (bytes) — the interleaving granularity of
    /// flush/compaction/migration against foreground requests. Real
    /// devices interleave small (WAL) writes with bulk traffic at command
    /// granularity; 128 KiB keeps queue-wait distortion of point ops low
    /// while still charging full bulk bandwidth.
    pub chunk_bytes: u64,
    /// Virtual interval between level-size samples (Fig 2(a)/(d)); the
    /// paper samples every minute over an 8-hour load — scaled alike.
    pub sample_interval_ns: u64,
}

/// Workload defaults (YCSB §4.1).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    pub key_size: usize,
    pub value_size: usize,
    /// Number of KV objects loaded before each experiment.
    pub load_objects: u64,
    /// Operations per measured workload.
    pub ops: u64,
    /// Closed-loop client threads.
    pub clients: usize,
    pub zipf_alpha: f64,
    pub seed: u64,
}

/// Deterministic virtual-time tracing knobs (see [`crate::trace`]).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    /// Buffer trace events (observation-only; golden digests are pinned
    /// bit-identical with tracing on or off).
    pub enabled: bool,
    /// Export path for the Perfetto/JSON trace; empty = don't write a file
    /// (the buffer is still exportable programmatically).
    pub out: String,
    /// Ring capacity in events; the ring drops oldest on overflow and
    /// `hhzs trace check` refuses lossy traces.
    pub buffer_events: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            enabled: false,
            out: String::new(),
            buffer_events: crate::trace::DEFAULT_BUFFER_EVENTS,
        }
    }
}

/// Deterministic crash & power-loss injection knobs (see
/// [`crate::sim::crash`]). Disabled by default; an armed-but-unfired
/// injector is observationally free (runs stay bit-identical).
#[derive(Clone, Debug, PartialEq)]
pub struct CrashConfig {
    pub enabled: bool,
    /// Which [`crate::sim::CrashPoint`] hook fires (its `name()` string).
    pub point: String,
    /// Fire at the first matching hook at or after this virtual time
    /// (0 = no time trigger).
    pub at_time_ns: u64,
    /// Fire once this many client write ops have been issued
    /// (0 = no op trigger).
    pub at_op: u64,
    /// Seed of the injector's private RNG (chooses the torn byte).
    pub seed: u64,
    /// Which shard the injector arms on (`shards > 1`: exactly one victim
    /// domain crashes; the others keep their leases).
    pub shard: usize,
}

impl Default for CrashConfig {
    fn default() -> Self {
        CrashConfig {
            enabled: false,
            point: "mid_flush".into(),
            at_time_ns: 0,
            at_op: 0,
            seed: 1,
            shard: 0,
        }
    }
}

/// Block-granular demand-paged residency knobs (see [`crate::residency`]).
/// Paging is observationally free: the DES timeline, golden digests, and
/// crash invariants are bit-identical with it on or off — only host-side
/// physical memory (and the `resident_*_bytes` gauges) change.
#[derive(Clone, Debug, PartialEq)]
pub struct ResidencyConfig {
    /// Dehydrate synthesizable zone-resident blocks to compact descriptors
    /// and rehydrate them on demand. On by default; turn off to keep every
    /// written byte physically resident (debugging aid).
    pub paging: bool,
}

impl Default for ResidencyConfig {
    fn default() -> Self {
        ResidencyConfig { paging: true }
    }
}

/// Request-fusion knobs (group commit + read coalescing; see
/// [`crate::coordinator::groupcommit`]). All off by default: the off path
/// is bit-identical to the golden digests, and `commit_batch_max = 1`
/// reduces group commit to off.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchConfig {
    /// Hold frontend WAL records arriving within a commit window and issue
    /// ONE fused device append per window per device (one
    /// `per_req_overhead_ns` charge for the whole batch). Each member op is
    /// acked at the batch's finish time; its queue wait is still measured
    /// from its own issue point.
    pub group_commit: bool,
    /// Commit window length in virtual nanoseconds: the first record of a
    /// batch opens the window, and the batch closes when virtual time
    /// passes `open + commit_window_ns` (or when it fills). `0` groups only
    /// records staged at the same virtual instant.
    pub commit_window_ns: u64,
    /// Close the batch early once it holds this many records. `1` disables
    /// grouping entirely (every record commits alone, exactly the
    /// ungrouped path).
    pub commit_batch_max: usize,
    /// Coalesce adjacent/overlapping SST block reads from one logical op
    /// (multi-get candidate blocks, scan scatter-gather legs, compaction
    /// input chunks) into one charged device access, promoting contiguous
    /// random reads to a single sequential read.
    pub read_coalesce: bool,
    /// Max byte gap between two block reads that may still fuse into one
    /// sequential access (the gap bytes are read and discarded, so they
    /// count toward the fused transfer length but not toward data bytes).
    pub coalesce_gap_bytes: u64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            group_commit: false,
            commit_window_ns: 100_000, // 100 µs — ~10 WAL overheads
            commit_batch_max: 32,
            read_coalesce: false,
            coalesce_gap_bytes: 4096,
        }
    }
}

impl BatchConfig {
    /// Group commit engages only when enabled AND batches may exceed one
    /// record; `commit_batch_max = 1` must reduce to the ungrouped path.
    pub fn group_commit_enabled(&self) -> bool {
        self.group_commit && self.commit_batch_max > 1
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    pub geometry: Geometry,
    pub ssd: DeviceProfile,
    pub hdd: DeviceProfile,
    pub lsm: LsmConfig,
    pub hhzs: HhzsConfig,
    pub workload: WorkloadConfig,
    /// Virtual-time tracing (off by default; zero-cost when off).
    pub trace: TraceConfig,
    /// Crash injection (off by default; observationally free when armed
    /// but unfired).
    pub crash: CrashConfig,
    /// Demand-paged residency (on by default; observationally free).
    pub residency: ResidencyConfig,
    /// Request fusion: WAL group commit + SST read coalescing (off by
    /// default; the off path is golden-pinned).
    pub batch: BatchConfig,
    /// Number of independent LSM engines the key space is striped over
    /// (see [`crate::shard`]). `1` = the paper's single-engine system; the
    /// substrate lease layer splits zones/memory budgets for `> 1`.
    pub shards: usize,
    /// Use the AOT-compiled XLA kernels on the hot path when artifacts exist.
    pub use_xla_kernels: bool,
}

impl Config {
    /// Derive a configuration from the paper constants divided by `d`.
    ///
    /// Every ratio the analysis depends on is preserved: SST ≈ 0.94 SSD
    /// zones = 4 HDD zones; SSD = 20 zones; WAL+cache = 2 zones; L0/L1
    /// target = 1 paper-GiB; level multiplier 10×.
    pub fn paper_scaled(d: u64) -> Self {
        assert!(d >= 1);
        let ssd_zone_cap = paper::SSD_ZONE_CAP / d;
        let hdd_zone_cap = paper::HDD_ZONE_CAP / d;
        let sst_size = hdd_zone_cap * 4 - hdd_zone_cap / 20; // 3.95 HDD zones
        let memtable = paper::MEMTABLE_SIZE / d;
        let l0_target = paper::L0_TARGET / d;
        // 200 GiB of 1-KiB objects scaled.
        let load_objects = (200 * GIB / d) / 1024;
        Config {
            geometry: Geometry {
                scale_denom: d,
                ssd_zone_cap,
                hdd_zone_cap,
                sst_size,
                ssd_zones: 20,
                hdd_zones: 8192,
                wal_cache_zones: 2,
            },
            ssd: DeviceProfile::zn540_ssd(),
            hdd: DeviceProfile::st14000_smr_hdd(),
            lsm: LsmConfig {
                memtable_size: memtable,
                max_memtables: 4,
                min_flush_memtables: 2,
                block_size: 4096,
                block_cache_bytes: (paper::BLOCK_CACHE / d).max(64 * KIB),
                bloom_bits_per_key: 10,
                l0_target,
                level_multiplier: 10,
                num_levels: 7,
                bg_threads: 12,
                cpu_sched: CpuSched::WorkConserving,
                wake: WakePolicy::Fifo,
                fg_threads: 0,
                l0_stop_files: 64,
                l0_compaction_trigger: 4,
            },
            hhzs: HhzsConfig {
                migration_rate_bps: 4.0 * MIB as f64,
                hdd_rate_threshold: 0.5,
                scan_interval_ns: 100_000_000, // 100 ms virtual
                chunk_bytes: 128 * KIB,
                // One paper-minute, compressed by the scale factor.
                sample_interval_ns: (60_000_000_000 / d).max(10_000_000),
            },
            workload: WorkloadConfig {
                key_size: 24,
                value_size: 1000,
                load_objects,
                ops: 1_000_000,
                clients: 8,
                zipf_alpha: 0.9,
                seed: 42,
            },
            trace: TraceConfig::default(),
            crash: CrashConfig::default(),
            residency: ResidencyConfig::default(),
            batch: BatchConfig::default(),
            shards: 1,
            use_xla_kernels: false,
        }
    }

    /// CI-friendly default (scale 1/256; ~800 MiB load, quick workloads).
    pub fn small() -> Self {
        let mut c = Config::paper_scaled(256);
        c.workload.ops = 200_000;
        c
    }

    /// Tiny profile for unit tests / bench inner loops.
    pub fn tiny() -> Self {
        let mut c = Config::paper_scaled(2048);
        c.workload.load_objects = 60_000;
        c.workload.ops = 20_000;
        c
    }

    /// Total bytes of SSD capacity given to the experiment.
    pub fn ssd_capacity(&self) -> u64 {
        self.geometry.ssd_zone_cap * self.geometry.ssd_zones as u64
    }

    /// HDD zones an SST occupies (§3.2: 4 at paper geometry).
    pub fn hdd_zones_per_sst(&self) -> u32 {
        self.geometry.sst_size.div_ceil(self.geometry.hdd_zone_cap) as u32
    }

    /// Serialize to the TOML subset understood by [`minitoml`].
    pub fn to_toml(&self) -> String {
        let g = &self.geometry;
        let l = &self.lsm;
        let h = &self.hhzs;
        let w = &self.workload;
        format!(
            "[geometry]\n\
             scale_denom = {}\nssd_zone_cap = {}\nhdd_zone_cap = {}\n\
             sst_size = {}\nssd_zones = {}\nhdd_zones = {}\nwal_cache_zones = {}\n\n\
             [lsm]\n\
             memtable_size = {}\nmax_memtables = {}\nmin_flush_memtables = {}\n\
             block_size = {}\nblock_cache_bytes = {}\nbloom_bits_per_key = {}\n\
             l0_target = {}\nlevel_multiplier = {}\nnum_levels = {}\n\
             bg_threads = {}\ncpu_sched = \"{}\"\nwake_sched = \"{}\"\nfg_threads = {}\n\
             l0_stop_files = {}\nl0_compaction_trigger = {}\n\n\
             [hhzs]\n\
             migration_rate_bps = {}\nhdd_rate_threshold = {}\n\
             scan_interval_ns = {}\nchunk_bytes = {}\nsample_interval_ns = {}\n\n\
             [workload]\n\
             key_size = {}\nvalue_size = {}\nload_objects = {}\nops = {}\n\
             clients = {}\nzipf_alpha = {}\nseed = {}\n\n\
             [trace]\nenabled = {}\nout = \"{}\"\nbuffer_events = {}\n\n\
             [crash]\nenabled = {}\npoint = \"{}\"\nat_time_ns = {}\nat_op = {}\n\
             seed = {}\nshard = {}\n\n\
             [residency]\npaging = {}\n\n\
             [batch]\ngroup_commit = {}\ncommit_window_ns = {}\n\
             commit_batch_max = {}\nread_coalesce = {}\ncoalesce_gap_bytes = {}\n\n\
             [sharding]\nshards = {}\n\n\
             [runtime]\nuse_xla_kernels = {}\n",
            g.scale_denom, g.ssd_zone_cap, g.hdd_zone_cap, g.sst_size, g.ssd_zones,
            g.hdd_zones, g.wal_cache_zones,
            l.memtable_size, l.max_memtables, l.min_flush_memtables, l.block_size,
            l.block_cache_bytes, l.bloom_bits_per_key, l.l0_target, l.level_multiplier,
            l.num_levels, l.bg_threads, l.cpu_sched.as_str(), l.wake.as_str(), l.fg_threads,
            l.l0_stop_files, l.l0_compaction_trigger,
            h.migration_rate_bps, h.hdd_rate_threshold, h.scan_interval_ns, h.chunk_bytes,
            h.sample_interval_ns,
            w.key_size, w.value_size, w.load_objects, w.ops, w.clients, w.zipf_alpha, w.seed,
            self.trace.enabled, self.trace.out, self.trace.buffer_events,
            self.crash.enabled, self.crash.point, self.crash.at_time_ns, self.crash.at_op,
            self.crash.seed, self.crash.shard,
            self.residency.paging,
            self.batch.group_commit, self.batch.commit_window_ns,
            self.batch.commit_batch_max, self.batch.read_coalesce,
            self.batch.coalesce_gap_bytes,
            self.shards,
            self.use_xla_kernels,
        )
    }

    /// Parse a config from TOML text; unspecified keys keep the defaults of
    /// `Config::small()`.
    pub fn from_toml_str(s: &str) -> anyhow::Result<Self> {
        let doc = minitoml::parse(s)?;
        let mut c = Config::small();
        {
            let g = &mut c.geometry;
            doc.get_u64("geometry", "scale_denom", &mut g.scale_denom);
            doc.get_u64("geometry", "ssd_zone_cap", &mut g.ssd_zone_cap);
            doc.get_u64("geometry", "hdd_zone_cap", &mut g.hdd_zone_cap);
            doc.get_u64("geometry", "sst_size", &mut g.sst_size);
            doc.get_u32("geometry", "ssd_zones", &mut g.ssd_zones);
            doc.get_u32("geometry", "hdd_zones", &mut g.hdd_zones);
            doc.get_u32("geometry", "wal_cache_zones", &mut g.wal_cache_zones);
        }
        {
            let l = &mut c.lsm;
            doc.get_u64("lsm", "memtable_size", &mut l.memtable_size);
            doc.get_usize("lsm", "max_memtables", &mut l.max_memtables);
            doc.get_usize("lsm", "min_flush_memtables", &mut l.min_flush_memtables);
            doc.get_u64("lsm", "block_size", &mut l.block_size);
            doc.get_u64("lsm", "block_cache_bytes", &mut l.block_cache_bytes);
            doc.get_u32("lsm", "bloom_bits_per_key", &mut l.bloom_bits_per_key);
            doc.get_u64("lsm", "l0_target", &mut l.l0_target);
            doc.get_u64("lsm", "level_multiplier", &mut l.level_multiplier);
            doc.get_usize("lsm", "num_levels", &mut l.num_levels);
            doc.get_usize("lsm", "bg_threads", &mut l.bg_threads);
            let mut sched = l.cpu_sched.as_str().to_string();
            doc.get_str("lsm", "cpu_sched", &mut sched);
            // The `cpu_sched` key accepts wake-policy names too (the CLI
            // exposes all four under one `--cpu-sched` flag): a fifo/
            // stall_aware value under this key sets `wake` instead.
            match (CpuSched::parse(&sched), WakePolicy::parse(&sched)) {
                (Some(cs), _) => l.cpu_sched = cs,
                (None, Some(wp)) => l.wake = wp,
                (None, None) => anyhow::bail!(
                    "bad lsm.cpu_sched {sched:?} \
                     (fair|work_conserving|fifo|stall_aware)"
                ),
            }
            let mut wake = l.wake.as_str().to_string();
            doc.get_str("lsm", "wake_sched", &mut wake);
            l.wake = WakePolicy::parse(&wake)
                .ok_or_else(|| anyhow::anyhow!("bad lsm.wake_sched {wake:?}"))?;
            doc.get_usize("lsm", "fg_threads", &mut l.fg_threads);
            doc.get_usize("lsm", "l0_stop_files", &mut l.l0_stop_files);
            doc.get_usize("lsm", "l0_compaction_trigger", &mut l.l0_compaction_trigger);
        }
        {
            let h = &mut c.hhzs;
            doc.get_f64("hhzs", "migration_rate_bps", &mut h.migration_rate_bps);
            doc.get_f64("hhzs", "hdd_rate_threshold", &mut h.hdd_rate_threshold);
            doc.get_u64("hhzs", "scan_interval_ns", &mut h.scan_interval_ns);
            doc.get_u64("hhzs", "chunk_bytes", &mut h.chunk_bytes);
            doc.get_u64("hhzs", "sample_interval_ns", &mut h.sample_interval_ns);
        }
        {
            let w = &mut c.workload;
            doc.get_usize("workload", "key_size", &mut w.key_size);
            doc.get_usize("workload", "value_size", &mut w.value_size);
            doc.get_u64("workload", "load_objects", &mut w.load_objects);
            doc.get_u64("workload", "ops", &mut w.ops);
            doc.get_usize("workload", "clients", &mut w.clients);
            doc.get_f64("workload", "zipf_alpha", &mut w.zipf_alpha);
            doc.get_u64("workload", "seed", &mut w.seed);
        }
        {
            let t = &mut c.trace;
            doc.get_bool("trace", "enabled", &mut t.enabled);
            doc.get_str("trace", "out", &mut t.out);
            doc.get_usize("trace", "buffer_events", &mut t.buffer_events);
        }
        {
            let k = &mut c.crash;
            doc.get_bool("crash", "enabled", &mut k.enabled);
            doc.get_str("crash", "point", &mut k.point);
            if crate::sim::CrashPoint::parse(&k.point).is_none() {
                anyhow::bail!("bad crash.point {:?}", k.point);
            }
            doc.get_u64("crash", "at_time_ns", &mut k.at_time_ns);
            doc.get_u64("crash", "at_op", &mut k.at_op);
            doc.get_u64("crash", "seed", &mut k.seed);
            doc.get_usize("crash", "shard", &mut k.shard);
        }
        doc.get_bool("residency", "paging", &mut c.residency.paging);
        {
            let b = &mut c.batch;
            doc.get_bool("batch", "group_commit", &mut b.group_commit);
            doc.get_u64("batch", "commit_window_ns", &mut b.commit_window_ns);
            doc.get_usize("batch", "commit_batch_max", &mut b.commit_batch_max);
            if b.commit_batch_max == 0 {
                anyhow::bail!("batch.commit_batch_max must be >= 1");
            }
            doc.get_bool("batch", "read_coalesce", &mut b.read_coalesce);
            doc.get_u64("batch", "coalesce_gap_bytes", &mut b.coalesce_gap_bytes);
        }
        doc.get_usize("sharding", "shards", &mut c.shards);
        c.shards = c.shards.max(1);
        doc.get_bool("runtime", "use_xla_kernels", &mut c.use_xla_kernels);
        Ok(c)
    }

    pub fn from_toml(path: &str) -> anyhow::Result<Self> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
        Self::from_toml_str(&s)
    }
}

impl Default for Config {
    fn default() -> Self {
        Config::small()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ratios_preserved() {
        for d in [1, 64, 256, 1024] {
            let c = Config::paper_scaled(d);
            // SST fits in one SSD zone at ~94% utilization.
            assert!(c.geometry.sst_size <= c.geometry.ssd_zone_cap);
            let util = c.geometry.sst_size as f64 / c.geometry.ssd_zone_cap as f64;
            assert!(util > 0.90 && util < 0.97, "util={util} at d={d}");
            // SST spans exactly 4 HDD zones.
            assert_eq!(c.hdd_zones_per_sst(), 4);
            // 20 SSD zones, 2 reserved for WAL+cache.
            assert_eq!(c.geometry.ssd_zones, 20);
            assert_eq!(c.geometry.wal_cache_zones, 2);
        }
    }

    #[test]
    fn full_scale_matches_paper_constants() {
        let c = Config::paper_scaled(1);
        assert_eq!(c.geometry.ssd_zone_cap, (1077.0 * MIB as f64) as u64);
        assert_eq!(c.geometry.hdd_zone_cap, 256 * MIB);
        assert_eq!(c.lsm.memtable_size, 512 * MIB);
        // 200 GiB of 1 KiB objects.
        assert_eq!(c.workload.load_objects, 200 * 1024 * 1024);
    }

    #[test]
    fn toml_roundtrip() {
        let c = Config::small();
        let s = c.to_toml();
        let c2 = Config::from_toml_str(&s).unwrap();
        assert_eq!(c2, c);
    }

    #[test]
    fn toml_partial_override() {
        let c = Config::from_toml_str("[workload]\nops = 777\n").unwrap();
        assert_eq!(c.workload.ops, 777);
        assert_eq!(c.geometry.ssd_zones, 20); // default kept
    }

    #[test]
    fn shards_knob_defaults_to_one_and_round_trips() {
        assert_eq!(Config::small().shards, 1);
        let c = Config::from_toml_str("[sharding]\nshards = 4\n").unwrap();
        assert_eq!(c.shards, 4);
        // A zero in a config file degrades to the single-engine system.
        let c = Config::from_toml_str("[sharding]\nshards = 0\n").unwrap();
        assert_eq!(c.shards, 1);
    }

    #[test]
    fn cpu_sched_knob_round_trips() {
        assert_eq!(Config::small().lsm.cpu_sched, CpuSched::WorkConserving);
        let c = Config::from_toml_str("[lsm]\ncpu_sched = \"fair\"\n").unwrap();
        assert_eq!(c.lsm.cpu_sched, CpuSched::Fair);
        assert!(Config::from_toml_str("[lsm]\ncpu_sched = \"nope\"\n").is_err());
    }

    #[test]
    fn wake_policy_and_fg_threads_round_trip() {
        let c = Config::small();
        assert_eq!(c.lsm.wake, WakePolicy::Fifo);
        assert_eq!(c.lsm.fg_threads, 0);
        let c = Config::from_toml_str(
            "[lsm]\nwake_sched = \"stall_aware\"\nfg_threads = 8\n",
        )
        .unwrap();
        assert_eq!(c.lsm.wake, WakePolicy::StallAware);
        assert_eq!(c.lsm.fg_threads, 8);
        let c2 = Config::from_toml_str(&c.to_toml()).unwrap();
        assert_eq!(c2, c);
        assert!(Config::from_toml_str("[lsm]\nwake_sched = \"nope\"\n").is_err());
    }

    #[test]
    fn cpu_sched_key_accepts_wake_policy_names() {
        // ISSUE naming: `cpu_sched = fifo | stall_aware` routes to `wake`
        // and leaves the hold-cap policy untouched.
        let c = Config::from_toml_str("[lsm]\ncpu_sched = \"stall_aware\"\n").unwrap();
        assert_eq!(c.lsm.wake, WakePolicy::StallAware);
        assert_eq!(c.lsm.cpu_sched, CpuSched::WorkConserving);
        let c = Config::from_toml_str("[lsm]\ncpu_sched = \"fifo\"\n").unwrap();
        assert_eq!(c.lsm.wake, WakePolicy::Fifo);
    }

    #[test]
    fn trace_knobs_default_off_and_round_trip() {
        let c = Config::small();
        assert!(!c.trace.enabled);
        assert!(c.trace.out.is_empty());
        let c = Config::from_toml_str(
            "[trace]\nenabled = true\nout = \"t.json\"\nbuffer_events = 4096\n",
        )
        .unwrap();
        assert!(c.trace.enabled);
        assert_eq!(c.trace.out, "t.json");
        assert_eq!(c.trace.buffer_events, 4096);
        let c2 = Config::from_toml_str(&c.to_toml()).unwrap();
        assert_eq!(c2, c);
    }

    #[test]
    fn crash_knobs_default_off_and_round_trip() {
        let c = Config::small();
        assert!(!c.crash.enabled);
        let c = Config::from_toml_str(
            "[crash]\nenabled = true\npoint = \"mid_zone_append\"\n\
             at_time_ns = 5000\nat_op = 0\nseed = 9\nshard = 1\n",
        )
        .unwrap();
        assert!(c.crash.enabled);
        assert_eq!(c.crash.point, "mid_zone_append");
        assert_eq!(c.crash.at_time_ns, 5000);
        assert_eq!(c.crash.seed, 9);
        assert_eq!(c.crash.shard, 1);
        let c2 = Config::from_toml_str(&c.to_toml()).unwrap();
        assert_eq!(c2, c);
        assert!(Config::from_toml_str("[crash]\npoint = \"nope\"\n").is_err());
    }

    #[test]
    fn residency_knob_defaults_on_and_round_trips() {
        assert!(Config::small().residency.paging);
        let c = Config::from_toml_str("[residency]\npaging = false\n").unwrap();
        assert!(!c.residency.paging);
        let c2 = Config::from_toml_str(&c.to_toml()).unwrap();
        assert_eq!(c2, c);
    }

    #[test]
    fn batch_knobs_default_off_and_round_trip() {
        let c = Config::small();
        assert!(!c.batch.group_commit);
        assert!(!c.batch.read_coalesce);
        assert!(!c.batch.group_commit_enabled());
        let c = Config::from_toml_str(
            "[batch]\ngroup_commit = true\ncommit_window_ns = 50000\n\
             commit_batch_max = 16\nread_coalesce = true\n\
             coalesce_gap_bytes = 8192\n",
        )
        .unwrap();
        assert!(c.batch.group_commit);
        assert_eq!(c.batch.commit_window_ns, 50_000);
        assert_eq!(c.batch.commit_batch_max, 16);
        assert!(c.batch.read_coalesce);
        assert_eq!(c.batch.coalesce_gap_bytes, 8192);
        assert!(c.batch.group_commit_enabled());
        let c2 = Config::from_toml_str(&c.to_toml()).unwrap();
        assert_eq!(c2, c);
        assert!(Config::from_toml_str("[batch]\ncommit_batch_max = 0\n").is_err());
    }

    #[test]
    fn batch_of_one_is_disabled() {
        let mut c = Config::small();
        c.batch.group_commit = true;
        c.batch.commit_batch_max = 1;
        assert!(!c.batch.group_commit_enabled());
    }

    #[test]
    fn dataset_much_larger_than_ssd() {
        let c = Config::paper_scaled(256);
        let dataset = c.workload.load_objects * 1024;
        assert!(dataset > 5 * c.ssd_capacity());
    }
}
